//! Synthetic class-structured image datasets ("SynthDigits",
//! "SynthCIFAR").
//!
//! Each class is a smooth random prototype field (coarse Gaussian grid,
//! bilinearly upsampled — mimicking the low-frequency structure of
//! natural images); a sample is its class prototype plus i.i.d. pixel
//! noise and a small random global intensity shift. This yields data
//! that (a) a small CNN/MLP can learn to the paper's accuracy band,
//! (b) exhibits genuine class structure so the non-IID split produces
//! the weight divergence AsyncFLEO's grouping relies on.

use crate::util::Rng;

/// Which paper dataset this stands in for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// 28x28x1, 10 classes (MNIST stand-in).
    Digits,
    /// 32x32x3, 10 classes (CIFAR-10 stand-in).
    Cifar,
}

impl DatasetKind {
    pub fn dims(&self) -> (usize, usize, usize) {
        match self {
            DatasetKind::Digits => (28, 28, 1),
            DatasetKind::Cifar => (32, 32, 3),
        }
    }

    pub fn feat(&self) -> usize {
        let (h, w, c) = self.dims();
        h * w * c
    }

    pub fn classes(&self) -> usize {
        10
    }

    /// Artifact-name fragment (matches python/compile/aot.py).
    pub fn tag(&self) -> &'static str {
        match self {
            DatasetKind::Digits => "digits",
            DatasetKind::Cifar => "cifar",
        }
    }
}

/// A labelled dataset with flattened f32 features (row-major [n, feat]).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub x: Vec<f32>,
    pub y: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn feat(&self) -> usize {
        self.kind.feat()
    }

    /// Borrow sample `i`'s features.
    pub fn sample(&self, i: usize) -> &[f32] {
        let f = self.feat();
        &self.x[i * f..(i + 1) * f]
    }

    /// Indices of all samples with label `c`.
    pub fn class_indices(&self, c: u8) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.y[i] == c).collect()
    }
}

/// Per-class smooth prototypes.
struct Prototypes {
    fields: Vec<Vec<f32>>, // [classes][feat]
}

/// Pixel-noise std relative to the unit-variance prototypes. Tuned so
/// a small CNN/MLP plateaus in the paper's 80–90% accuracy band (not
/// at 100%, which would flatten every comparison curve).
const NOISE_STD: f64 = 1.6;
const COARSE: usize = 4; // coarse grid reduction factor

fn smooth_field(rng: &mut Rng, h: usize, w: usize, c: usize) -> Vec<f32> {
    let ch = (h + COARSE - 1) / COARSE + 1;
    let cw = (w + COARSE - 1) / COARSE + 1;
    // coarse Gaussian grid per channel
    let coarse: Vec<f32> =
        (0..ch * cw * c).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let mut out = vec![0.0f32; h * w * c];
    for ci in 0..c {
        for i in 0..h {
            for j in 0..w {
                let fi = i as f64 / COARSE as f64;
                let fj = j as f64 / COARSE as f64;
                let (i0, j0) = (fi.floor() as usize, fj.floor() as usize);
                let (di, dj) = (fi - i0 as f64, fj - j0 as f64);
                let at = |a: usize, b: usize| coarse[(ci * ch + a) * cw + b] as f64;
                let v = at(i0, j0) * (1.0 - di) * (1.0 - dj)
                    + at(i0 + 1, j0) * di * (1.0 - dj)
                    + at(i0, j0 + 1) * (1.0 - di) * dj
                    + at(i0 + 1, j0 + 1) * di * dj;
                out[(i * w + j) * c + ci] = v as f32;
            }
        }
    }
    out
}

impl Prototypes {
    fn new(kind: DatasetKind, rng: &mut Rng) -> Self {
        let (h, w, c) = kind.dims();
        let fields = (0..kind.classes()).map(|_| smooth_field(rng, h, w, c)).collect();
        Prototypes { fields }
    }
}

/// Generate a dataset of `n` samples with roughly balanced classes.
///
/// Deterministic in `(kind, seed, n)`; the *same* seed must be used for
/// train and test so they share prototypes — use [`generate_split`].
pub fn generate(kind: DatasetKind, seed: u64, n: usize) -> Dataset {
    let (train, _) = generate_split(kind, seed, n, 0);
    train
}

/// Generate (train, test) sharing class prototypes but with
/// independent sample noise.
pub fn generate_split(
    kind: DatasetKind,
    seed: u64,
    n_train: usize,
    n_test: usize,
) -> (Dataset, Dataset) {
    let mut rng = Rng::new(seed ^ 0xD1_6E57);
    let protos = Prototypes::new(kind, &mut rng);
    let train = sample_set(kind, &protos, &mut rng.fork(1), n_train);
    let test = sample_set(kind, &protos, &mut rng.fork(2), n_test);
    (train, test)
}

fn sample_set(kind: DatasetKind, protos: &Prototypes, rng: &mut Rng, n: usize) -> Dataset {
    let feat = kind.feat();
    let k = kind.classes();
    let mut x = Vec::with_capacity(n * feat);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = (i % k) as u8; // balanced
        let shift = rng.normal(0.0, 0.15) as f32;
        let proto = &protos.fields[class as usize];
        for p in proto {
            x.push(p + rng.normal(0.0, NOISE_STD) as f32 + shift);
        }
        y.push(class);
    }
    // shuffle sample order (keep x/y aligned)
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut xs = vec![0.0f32; n * feat];
    let mut ys = vec![0u8; n];
    for (new_i, &old_i) in order.iter().enumerate() {
        xs[new_i * feat..(new_i + 1) * feat]
            .copy_from_slice(&x[old_i * feat..(old_i + 1) * feat]);
        ys[new_i] = y[old_i];
    }
    Dataset { kind, x: xs, y: ys }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let d = generate(DatasetKind::Digits, 0, 1000);
        assert_eq!(d.len(), 1000);
        assert_eq!(d.x.len(), 1000 * 784);
        for c in 0..10u8 {
            let n = d.class_indices(c).len();
            assert_eq!(n, 100, "class {c} has {n}");
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(DatasetKind::Digits, 7, 100);
        let b = generate(DatasetKind::Digits, 7, 100);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(DatasetKind::Digits, 1, 50);
        let b = generate(DatasetKind::Digits, 2, 50);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn cifar_geometry() {
        let d = generate(DatasetKind::Cifar, 0, 20);
        assert_eq!(d.feat(), 3072);
        assert_eq!(d.sample(3).len(), 3072);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-prototype classification on fresh samples must beat
        // chance by a wide margin, else FL training can't reach the
        // paper's accuracy band.
        let (train, test) = generate_split(DatasetKind::Digits, 3, 2000, 500);
        let feat = train.feat();
        // class means from train
        let mut means = vec![vec![0.0f64; feat]; 10];
        let mut counts = [0usize; 10];
        for i in 0..train.len() {
            let c = train.y[i] as usize;
            counts[c] += 1;
            for (m, v) in means[c].iter_mut().zip(train.sample(i)) {
                *m += *v as f64;
            }
        }
        for c in 0..10 {
            for m in means[c].iter_mut() {
                *m /= counts[c] as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let s = test.sample(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = s.iter().zip(&means[a]).map(|(x, m)| (*x as f64 - m).powi(2)).sum();
                    let db: f64 = s.iter().zip(&means[b]).map(|(x, m)| (*x as f64 - m).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "nearest-prototype acc {acc} too low");
    }

    #[test]
    fn train_test_share_prototypes() {
        // A train-class mean must be closer to the matching test-class
        // mean than to other classes.
        let (train, test) = generate_split(DatasetKind::Digits, 5, 1000, 1000);
        let feat = train.feat();
        let class_mean = |d: &Dataset, c: u8| -> Vec<f64> {
            let idx = d.class_indices(c);
            let mut m = vec![0.0f64; feat];
            for &i in &idx {
                for (mm, v) in m.iter_mut().zip(d.sample(i)) {
                    *mm += *v as f64;
                }
            }
            m.iter_mut().for_each(|v| *v /= idx.len() as f64);
            m
        };
        let m0_train = class_mean(&train, 0);
        let m0_test = class_mean(&test, 0);
        let m1_test = class_mean(&test, 1);
        let d_same: f64 = m0_train.iter().zip(&m0_test).map(|(a, b)| (a - b).powi(2)).sum();
        let d_diff: f64 = m0_train.iter().zip(&m1_test).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(d_same < d_diff);
    }
}
