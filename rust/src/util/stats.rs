//! Descriptive statistics for the bench harness and metrics pipeline.

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

/// Compute summary statistics. Returns `None` for empty input.
pub fn summarize(xs: &[f64]) -> Option<Summary> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
    })
}

/// Percentile (linear interpolation) over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    crate::util::lerp(sorted[lo], sorted[hi], rank - lo as f64)
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_gives_none() {
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn single_value() {
        let s = summarize(&[3.0]).unwrap();
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p95, 3.0);
    }

    #[test]
    fn known_distribution() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs).unwrap();
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p95 - 95.05).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
