//! Minimal TOML-subset parser (no `toml`/`serde` crates offline).
//!
//! Supports what experiment configs need: `[section.sub]` headers,
//! `key = value` with string / integer / float / bool / homogeneous
//! array values, `#` comments, and blank lines. Keys are flattened to
//! dotted paths (`section.sub.key`).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Flattened key→value document.
pub type Doc = BTreeMap<String, Value>;

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::new();
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| ParseError { line: lineno + 1, msg: msg.to_string() };
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(err("empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let val_str = line[eq + 1..].trim();
        let value = parse_value(val_str).map_err(|m| err(&m))?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        doc.insert(full, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: comments only outside strings in our configs
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            split_top_level(inner).iter().map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Split an array body on commas not inside strings or nested arrays.
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(doc["a"], Value::Int(1));
        assert_eq!(doc["b"], Value::Float(2.5));
        assert_eq!(doc["c"], Value::Str("hi".into()));
        assert_eq!(doc["d"], Value::Bool(true));
    }

    #[test]
    fn sections_flatten() {
        let doc = parse("[fl]\nlr = 0.01\n[fl.deep]\nx = 2\n").unwrap();
        assert_eq!(doc["fl.lr"], Value::Float(0.01));
        assert_eq!(doc["fl.deep.x"], Value::Int(2));
    }

    #[test]
    fn comments_and_blanks() {
        let doc = parse("# top\na = 1  # trailing\n\nb = \"x # not comment\"\n").unwrap();
        assert_eq!(doc["a"], Value::Int(1));
        assert_eq!(doc["b"], Value::Str("x # not comment".into()));
    }

    #[test]
    fn arrays() {
        let doc = parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nempty = []\n").unwrap();
        assert_eq!(
            doc["xs"],
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(doc["ys"].as_array().unwrap().len(), 2);
        assert_eq!(doc["empty"], Value::Array(vec![]));
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("m = [[1, 2], [3, 4]]\n").unwrap();
        let outer = doc["m"].as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap()[1], Value::Int(2));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[oops\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("x = \"unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn negative_and_exp_floats() {
        let doc = parse("a = -4\nb = 1e6\nc = -2.5e-3\n").unwrap();
        assert_eq!(doc["a"], Value::Int(-4));
        assert_eq!(doc["b"], Value::Float(1e6));
        assert_eq!(doc["c"], Value::Float(-2.5e-3));
    }

    #[test]
    fn as_f64_accepts_int() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Str("x".into()).as_f64(), None);
    }

    #[test]
    fn later_keys_override() {
        let doc = parse("a = 1\na = 2\n").unwrap();
        assert_eq!(doc["a"], Value::Int(2));
    }
}
