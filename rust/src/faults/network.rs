//! The network impairment engine: latency jitter, per-link bandwidth
//! queueing, scheduled partitions and Sun-vector eclipses
//! ([`NetworkConfig`](super::config::NetworkConfig)).
//!
//! Every axis keeps the fault subsystem's two contracts:
//!
//! * **Zero intensity is bit-identical.** A nominal `NetworkConfig`
//!   never touches the delay path, the RNG or the schedule cache key —
//!   runs are provably byte-equal to the pre-engine code
//!   (`tests/network_equivalence.rs`).
//! * **Pure oracle / per-run commit.** The *pure* terms — jitter draws
//!   (hash-derived per (link, coherence window)), partition deferral
//!   and umbra deferral — live in `FaultSchedule::channel_outcome`, so
//!   probe lanes evaluate them concurrently and order-independently.
//!   The *stateful* terms — FIFO queue waits, reorder detection and
//!   every counter — live in `FaultPlan::commit`, folded exactly once
//!   per channel event in serial replay order. Queueing is the one axis
//!   whose outcome depends on commit order, so an active queue forces
//!   the run to a single lane (`SimEnv::lanes`), the same way the
//!   reference path does.
//!
//! This module holds the order-sensitive half: the [`LinkQueue`] a
//! `FaultPlan` keeps per (endpoint-pair, link-class), and the partition
//! scope test shared by the oracle and the tests. The pure halves live
//! where their inputs are: jitter and window deferral in
//! `faults::plan`, the solar ephemeris in `orbit::sun`.

use super::config::PartitionScope;
use super::plan::LinkClass;
use crate::orbit::WalkerConstellation;

/// Node-layout inputs of the network axes, alongside the `plane_of`
/// mapping the fault schedule already takes: which shell each satellite
/// flies in (partition scope `Shell`), which sites are HAPs (scopes
/// `Ground`/`Hap`), and the constellation geometry for umbra windows.
#[derive(Clone, Copy)]
pub struct NetWorld<'a> {
    /// Orbital shell per satellite id (empty = everything shell 0).
    pub shell_of: &'a [usize],
    /// Which sites are HAPs (true) vs ground stations (false; empty =
    /// all ground).
    pub hap_site: &'a [bool],
    /// Constellation geometry, needed when `eclipse_from_sun` computes
    /// umbra windows at schedule build time.
    pub constellation: Option<&'a WalkerConstellation>,
}

impl NetWorld<'static> {
    /// No layout information: single-shell, all-ground, no geometry.
    /// What the legacy build entry points pass — only valid alongside a
    /// nominal `NetworkConfig`.
    pub fn empty() -> Self {
        NetWorld { shell_of: &[], hap_site: &[], constellation: None }
    }
}

/// Does a partition of `scope` cut this link? Pure — both the channel
/// oracle and the tests query it.
///
/// * `Ground` isolates every ground-station site: SAT↔GS star links and
///   any IHL leg touching a GS are unreachable; the HAP layer keeps
///   flying and relaying.
/// * `Hap` isolates the HAP layer: SAT↔HAP links and the IHL backbone
///   go dark; SAT↔GS links survive.
/// * `Shell` cuts shell `shell` off the rest of the system: its star
///   links and every boundary-crossing ISL are unreachable, while
///   intra-shell ISLs keep working (the island stays internally
///   connected, but isolated).
pub fn partition_blocks(
    scope: PartitionScope,
    shell: usize,
    class: &LinkClass,
    shell_of: &[usize],
    hap_site: &[bool],
) -> bool {
    let is_hap = |site: usize| hap_site.get(site).copied().unwrap_or(false);
    let in_shell = |sat: usize| shell_of.get(sat).copied().unwrap_or(0) == shell;
    match (scope, *class) {
        (PartitionScope::Ground, LinkClass::SatSite { site, .. }) => !is_hap(site),
        (PartitionScope::Ground, LinkClass::Ihl { site_a, site_b }) => {
            !is_hap(site_a) || !is_hap(site_b)
        }
        (PartitionScope::Ground, LinkClass::Isl { .. }) => false,
        (PartitionScope::Hap, LinkClass::SatSite { site, .. }) => is_hap(site),
        (PartitionScope::Hap, LinkClass::Ihl { site_a, site_b }) => {
            is_hap(site_a) || is_hap(site_b)
        }
        (PartitionScope::Hap, LinkClass::Isl { .. }) => false,
        (PartitionScope::Shell, LinkClass::SatSite { sat, .. }) => in_shell(sat),
        (PartitionScope::Shell, LinkClass::Isl { sat_a, sat_b }) => {
            in_shell(sat_a) != in_shell(sat_b)
        }
        (PartitionScope::Shell, LinkClass::Ihl { .. }) => false,
    }
}

/// What one offer did at a [`LinkQueue`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueueOutcome {
    /// Head-of-line wait before the transfer starts transmitting.
    pub wait_s: f64,
    /// The wait exceeded the cap: a typed drop — the transfer never
    /// occupies the link and its model never arrives.
    pub dropped: bool,
}

/// One link's FIFO transmission queue: each committed transfer occupies
/// the link for its service time, later offers wait for the residual
/// capacity instead of all seeing a fixed rate.
///
/// Deterministic and order-sensitive by design: offers arrive in the
/// run's serial commit order (event pop order, nondecreasing time), so
/// a queue never needs timers or reentrancy — `busy_until` is the whole
/// state. Conservation (`serviced == offered - dropped`, in bits and in
/// offers) and FIFO start order are pinned by a seeded property test
/// below.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkQueue {
    busy_until_s: f64,
    offered_bits: u64,
    serviced_bits: u64,
    dropped_bits: u64,
    offers: u64,
    drops: u64,
}

impl LinkQueue {
    /// Offer a `bits`-sized transfer at time `t` that will occupy the
    /// link for `service_s` once it starts. Returns the FIFO wait, or a
    /// typed drop when the wait would exceed `max_wait_s` (> 0).
    pub fn offer(&mut self, t: f64, bits: u64, service_s: f64, max_wait_s: f64) -> QueueOutcome {
        self.offers += 1;
        self.offered_bits += bits;
        let start = self.busy_until_s.max(t);
        let wait = start - t;
        if max_wait_s > 0.0 && wait > max_wait_s {
            self.drops += 1;
            self.dropped_bits += bits;
            return QueueOutcome { wait_s: wait, dropped: true };
        }
        self.busy_until_s = start + service_s.max(0.0);
        self.serviced_bits += bits;
        QueueOutcome { wait_s: wait, dropped: false }
    }

    /// The instant the link finishes its last accepted transfer.
    pub fn busy_until_s(&self) -> f64 {
        self.busy_until_s
    }

    pub fn offered_bits(&self) -> u64 {
        self.offered_bits
    }

    pub fn serviced_bits(&self) -> u64 {
        self.serviced_bits
    }

    pub fn dropped_bits(&self) -> u64 {
        self.dropped_bits
    }

    pub fn offers(&self) -> u64 {
        self.offers
    }

    pub fn drops(&self) -> u64 {
        self.drops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn queue_serializes_concurrent_offers_fifo() {
        let mut q = LinkQueue::default();
        let a = q.offer(0.0, 100, 10.0, 0.0);
        assert_eq!(a, QueueOutcome { wait_s: 0.0, dropped: false });
        // offered while busy: waits for the residual capacity
        let b = q.offer(1.0, 100, 10.0, 0.0);
        assert_eq!(b, QueueOutcome { wait_s: 9.0, dropped: false });
        let c = q.offer(2.0, 100, 10.0, 0.0);
        assert_eq!(c, QueueOutcome { wait_s: 18.0, dropped: false });
        // offered after the backlog drains: untouched
        let d = q.offer(40.0, 100, 10.0, 0.0);
        assert_eq!(d, QueueOutcome { wait_s: 0.0, dropped: false });
        assert_eq!(q.serviced_bits(), 400);
    }

    #[test]
    fn queue_cap_surfaces_typed_drops() {
        let mut q = LinkQueue::default();
        q.offer(0.0, 10, 100.0, 30.0);
        let dropped = q.offer(1.0, 10, 100.0, 30.0);
        assert!(dropped.dropped, "99 s wait exceeds the 30 s cap");
        // a drop never occupies the link: the next offer sees the
        // first transfer's backlog only
        let after = q.offer(50.0, 10, 100.0, 60.0);
        assert_eq!(after, QueueOutcome { wait_s: 50.0, dropped: false });
        assert_eq!(q.offers(), 3);
        assert_eq!(q.drops(), 1);
        assert_eq!(q.offered_bits(), 30);
        assert_eq!(q.serviced_bits(), 20);
        assert_eq!(q.dropped_bits(), 10);
    }

    #[test]
    fn queue_conservation_and_fifo_order_hold_under_random_offers() {
        // the satellite-task proptest: bits serviced == bits offered -
        // typed drops, and accepted transfers start in FIFO order, for
        // random concurrent offer sequences
        forall(|rng| {
            let mut q = LinkQueue::default();
            let n = 1 + rng.below(60);
            let max_wait = if rng.f64() < 0.5 { 0.0 } else { rng.range_f64(1.0, 50.0) };
            let mut t = 0.0;
            let mut last_start = f64::NEG_INFINITY;
            for _ in 0..n {
                t += rng.range_f64(0.0, 8.0);
                let bits = rng.below(10_000) as u64;
                let service = rng.range_f64(0.0, 12.0);
                let out = q.offer(t, bits, service, max_wait);
                assert!(out.wait_s >= 0.0);
                if !out.dropped {
                    let start = t + out.wait_s;
                    assert!(
                        start >= last_start,
                        "FIFO start order violated: {start} < {last_start}"
                    );
                    last_start = start;
                    if max_wait > 0.0 {
                        assert!(out.wait_s <= max_wait);
                    }
                }
            }
            assert_eq!(
                q.serviced_bits(),
                q.offered_bits() - q.dropped_bits(),
                "queue must conserve bits"
            );
            assert!(q.drops() <= q.offers());
        });
    }

    #[test]
    fn partition_scopes_cut_the_right_links() {
        let shell_of = [0, 0, 1, 1];
        let hap_site = [true, false]; // site 0 = HAP, site 1 = GS
        let sat_hap = LinkClass::SatSite { sat: 0, site: 0 };
        let sat_gs = LinkClass::SatSite { sat: 0, site: 1 };
        let isl_intra = LinkClass::Isl { sat_a: 2, sat_b: 3 };
        let isl_cross = LinkClass::Isl { sat_a: 1, sat_b: 2 };
        let ihl = LinkClass::Ihl { site_a: 0, site_b: 1 };
        let blocks = |scope, shell, class: &LinkClass| {
            partition_blocks(scope, shell, class, &shell_of, &hap_site)
        };
        // ground segment out: GS links dark, HAP layer keeps relaying
        assert!(blocks(PartitionScope::Ground, 0, &sat_gs));
        assert!(!blocks(PartitionScope::Ground, 0, &sat_hap));
        assert!(blocks(PartitionScope::Ground, 0, &ihl), "IHL leg touches a GS");
        assert!(!blocks(PartitionScope::Ground, 0, &isl_cross));
        // HAP layer out: the backbone and HAP star links go dark
        assert!(blocks(PartitionScope::Hap, 0, &sat_hap));
        assert!(!blocks(PartitionScope::Hap, 0, &sat_gs));
        assert!(blocks(PartitionScope::Hap, 0, &ihl));
        // shell 1 isolated: boundary ISLs cut, the island survives
        assert!(blocks(PartitionScope::Shell, 1, &isl_cross));
        assert!(!blocks(PartitionScope::Shell, 1, &isl_intra));
        assert!(blocks(PartitionScope::Shell, 1, &LinkClass::SatSite { sat: 2, site: 0 }));
        assert!(!blocks(PartitionScope::Shell, 1, &sat_hap));
        assert!(!blocks(PartitionScope::Shell, 1, &ihl));
    }
}
