//! Experiment drivers regenerating every paper table & figure
//! (DESIGN.md §4 maps each driver to its paper artifact), plus the
//! [`resilience`] sweep comparing graceful degradation across schemes
//! under the `crate::faults` scenarios and the [`scenarios`] sweep
//! comparing schemes across the declarative `crate::scenario` catalog.
//!
//! Every driver describes its grid as [`executor::Cell`]s and runs it
//! through the deterministic streaming [`executor`] (`--jobs N`,
//! longest-cell-first scheduling): rows are written in cell order as
//! the ordered prefix completes, so output files are byte-identical at
//! any job count and a late error keeps every completed row.

pub mod drivers;
pub mod executor;
pub mod resilience;
pub mod scenarios;

pub use drivers::{run_experiment, ExpOptions, ALL_EXPERIMENTS, TABLE2_ROWS};
pub use executor::{run_cells, run_cells_streaming, Cell, CellStrategy};
