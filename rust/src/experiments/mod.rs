//! Experiment drivers regenerating every paper table & figure
//! (DESIGN.md §4 maps each driver to its paper artifact).

pub mod drivers;

pub use drivers::{run_experiment, ExpOptions, ALL_EXPERIMENTS, TABLE2_ROWS};
