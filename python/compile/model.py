"""L2: the satellites' on-board compute graphs, in JAX.

Defines the two paper models (CNN and MLP, Sec. V-A) over the two
dataset geometries (digits 28x28x1, cifar 32x32x3), with:

  * flat-parameter packing — the Rust coordinator only ever sees a
    single f32[D] vector per model, which makes model relay, grouping
    distances and aggregation trivial buffer operations on L3;
  * a `lax.scan`-folded local-SGD train step (J mini-batch steps per
    dispatch) so one PJRT execute == one on-board training visit;
  * an eval step returning (correct_count, loss_sum) partial sums so L3
    can stream the test set through fixed-size chunks.

All dense layers go through the L1 Pallas kernel
(`kernels.linear.fused_linear`); convolutions are lowered to im2col +
the same Pallas kernel (see `_im2col3`), so every matmul FLOP of the
forward AND backward pass runs on L1.

This module is build-time only: `aot.py` lowers everything to HLO text
and Python never runs at L3.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.linear import fused_linear

# ----------------------------------------------------------------------
# Model specs
# ----------------------------------------------------------------------

DATASETS = {
    "digits": dict(h=28, w=28, c=1, classes=10),
    "cifar": dict(h=32, w=32, c=3, classes=10),
}

HIDDEN = 128
CONV1, CONV2 = 8, 16
POOL = 4


def layer_shapes(kind, dataset):
    """Ordered (name, shape, fan_in) for flat packing. Order is frozen:
    it defines the layout of the f32[D] vector the Rust side handles."""
    ds = DATASETS[dataset]
    h, w, c, k = ds["h"], ds["w"], ds["c"], ds["classes"]
    feat = h * w * c
    if kind == "mlp":
        return [
            ("w1", (feat, HIDDEN), feat),
            ("b1", (HIDDEN,), feat),
            ("w2", (HIDDEN, k), HIDDEN),
            ("b2", (k,), HIDDEN),
        ]
    if kind == "cnn":
        hp, wp = h // POOL, w // POOL
        flat = hp * wp * CONV2
        return [
            ("k1", (3, 3, c, CONV1), 9 * c),
            ("c1", (CONV1,), 9 * c),
            ("k2", (3, 3, CONV1, CONV2), 9 * CONV1),
            ("c2", (CONV2,), 9 * CONV1),
            ("w1", (flat, HIDDEN), flat),
            ("b1", (HIDDEN,), flat),
            ("w2", (HIDDEN, k), HIDDEN),
            ("b2", (k,), HIDDEN),
        ]
    raise ValueError(f"unknown model kind {kind!r}")


def param_dim(kind, dataset):
    return sum(
        int(functools.reduce(lambda a, b: a * b, s, 1))
        for _, s, _ in layer_shapes(kind, dataset)
    )


def unpack(flat, kind, dataset):
    """f32[D] -> dict of named arrays (frozen layout)."""
    out, off = {}, 0
    for name, shape, _ in layer_shapes(kind, dataset):
        size = int(functools.reduce(lambda a, b: a * b, shape, 1))
        out[name] = flat[off : off + size].reshape(shape)
        off += size
    return out


def pack(tree, kind, dataset):
    """dict -> f32[D] (inverse of unpack)."""
    return jnp.concatenate(
        [tree[name].reshape(-1) for name, _, _ in layer_shapes(kind, dataset)]
    )


# ----------------------------------------------------------------------
# Forward passes
# ----------------------------------------------------------------------


def _im2col3(x):
    """[B,H,W,C] -> [B*H*W, 9C] patches of the SAME-padded 3x3 window.

    Convolution is lowered to im2col + the L1 Pallas matmul so the conv
    FLOPs (and, through the custom VJP, their backward) run on the same
    fused kernel as the dense layers. The shifted-slice construction has
    exact, cheap VJPs (pad/slice), unlike lax.conv's CPU transpose path.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [
        xp[:, di : di + h, dj : dj + w, :]
        for di in range(3)
        for dj in range(3)
    ]
    return jnp.concatenate(cols, axis=-1).reshape(n * h * w, 9 * c)


def _conv(x, k, b):
    """3x3 SAME conv + bias + relu via im2col + fused Pallas linear."""
    n, h, w, c = x.shape
    oc = k.shape[-1]
    patches = _im2col3(x)                      # [B*H*W, 9C]
    kmat = k.reshape(9 * c, oc)                # HWIO rows match patch order
    o = fused_linear(patches, kmat, b, "relu", bm=8192, bn=32)
    return o.reshape(n, h, w, oc)


def _avg_pool(x, p):
    n, h, w, c = x.shape
    return jnp.mean(x.reshape(n, h // p, p, w // p, p, c), axis=(2, 4))


def forward(flat, x, kind, dataset, interpret=True):
    """flat: f32[D] params, x: f32[B, H*W*C] flattened images -> logits."""
    p = unpack(flat, kind, dataset)
    ds = DATASETS[dataset]
    if kind == "mlp":
        h = fused_linear(x, p["w1"], p["b1"], "relu", interpret=interpret)
        return fused_linear(h, p["w2"], p["b2"], "none", interpret=interpret)
    # CNN
    img = x.reshape(-1, ds["h"], ds["w"], ds["c"])
    o = _conv(img, p["k1"], p["c1"])
    o = _conv(o, p["k2"], p["c2"])
    o = _avg_pool(o, POOL)
    o = o.reshape(o.shape[0], -1)
    h = fused_linear(o, p["w1"], p["b1"], "relu", interpret=interpret)
    return fused_linear(h, p["w2"], p["b2"], "none", interpret=interpret)


def loss_fn(flat, x, y_onehot, kind, dataset, interpret=True):
    """Mean softmax cross-entropy."""
    logits = forward(flat, x, kind, dataset, interpret=interpret)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


# ----------------------------------------------------------------------
# AOT entry points (what aot.py lowers)
# ----------------------------------------------------------------------


def make_train_fn(kind, dataset, local_steps, batch, interpret=True):
    """(params f32[D], xs f32[J*b, F], ys f32[J*b, K], lr f32[]) ->
    (params' f32[D], mean_loss f32[]) — J SGD steps folded by scan.
    One call == one on-board local-training dispatch (paper Eq. 3)."""
    feat = DATASETS[dataset]["h"] * DATASETS[dataset]["w"] * DATASETS[dataset]["c"]
    k = DATASETS[dataset]["classes"]

    grad_fn = jax.value_and_grad(
        lambda p, x, y: loss_fn(p, x, y, kind, dataset, interpret=interpret)
    )

    def train(params, xs, ys, lr):
        xs = xs.reshape(local_steps, batch, feat)
        ys = ys.reshape(local_steps, batch, k)

        def step(p, xy):
            x, y = xy
            l, g = grad_fn(p, x, y)
            return p - lr * g, l

        params, losses = lax.scan(step, params, (xs, ys))
        return params, jnp.mean(losses)

    return train


def make_eval_fn(kind, dataset, interpret=True):
    """(params f32[D], x f32[B, F], y f32[B, K]) ->
    (correct f32[], loss_sum f32[]) partial sums over the chunk.
    Rows with all-zero labels (padding of the final chunk) count 0."""

    def evaluate(params, x, y_onehot):
        logits = forward(params, x, kind, dataset, interpret=interpret)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = jnp.sum(y_onehot, axis=-1)  # 1 for real rows, 0 for pad
        pred = jnp.argmax(logits, axis=-1)
        label = jnp.argmax(y_onehot, axis=-1)
        correct = jnp.sum((pred == label).astype(jnp.float32) * valid)
        loss_sum = -jnp.sum(jnp.sum(y_onehot * logp, axis=-1))
        return correct, loss_sum

    return evaluate


def make_init_fn(kind, dataset):
    """(seed i32[]) -> params f32[D]: He-normal weights, zero biases.
    Lowered to an artifact so L3 and L2 agree on init numerics."""
    shapes = layer_shapes(kind, dataset)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        parts = []
        for i, (name, shape, fan_in) in enumerate(shapes):
            if len(shape) == 1:  # bias
                parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
            else:
                sub = jax.random.fold_in(key, i)
                scale = jnp.sqrt(2.0 / fan_in)
                parts.append(
                    (jax.random.normal(sub, shape, jnp.float32) * scale).reshape(-1)
                )
        return jnp.concatenate(parts)

    return init


def make_agg_fn(n_slab, dim, tile_d=2048, interpret=True):
    """(models_ext f32[N+1, D], coeffs f32[N+1]) -> f32[D] (Eq. 14)."""
    from .kernels.aggregate import aggregate

    def agg(models_ext, coeffs):
        return aggregate(models_ext, coeffs, tile_d=min(tile_d, dim),
                         interpret=interpret)

    del n_slab
    return agg


def make_dist_fn(n_rows, dim, tile_d=2048, interpret=True):
    """(models f32[N, D], ref f32[D]) -> f32[N] divergences (Sec. IV-C1)."""
    from .kernels.distance import distance

    def dist(models, ref):
        return distance(models, ref, tile_d=min(tile_d, dim),
                        interpret=interpret)

    del n_rows
    return dist
