//! Run metrics: accuracy/loss curves, convergence detection, CSV output.
//!
//! The paper reports *accuracy vs. convergence time* (Table II, Figs.
//! 6–8) where convergence time is the simulated clock at which the
//! accuracy curve reaches its plateau. [`ConvergenceDetector`]
//! implements that: earliest time after which accuracy never drops more
//! than `tolerance` below the final plateau.

pub mod chart;
pub mod csv;

pub use csv::CsvWriter;

/// One evaluation point on the training curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CurvePoint {
    /// Simulated time, seconds.
    pub time_s: f64,
    /// Global epoch β at evaluation.
    pub epoch: u64,
    /// Test accuracy in [0, 1].
    pub accuracy: f64,
    /// Mean test loss.
    pub loss: f64,
}

/// A recorded accuracy/loss curve for one run.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn push(&mut self, p: CurvePoint) {
        if let Some(last) = self.points.last() {
            assert!(p.time_s >= last.time_s, "curve must be time-ordered");
        }
        self.points.push(p);
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.points.last().map(|p| p.accuracy)
    }

    pub fn best_accuracy(&self) -> Option<f64> {
        self.points.iter().map(|p| p.accuracy).fold(None, |acc, a| {
            Some(match acc {
                None => a,
                Some(b) => b.max(a),
            })
        })
    }

    /// Convergence point: the earliest recorded time from which the
    /// accuracy stays within `tolerance` of the final plateau (mean of
    /// the last `tail` points). Returns `(time_s, plateau_accuracy)`.
    pub fn convergence(&self, tolerance: f64, tail: usize) -> Option<(f64, f64)> {
        if self.points.is_empty() {
            return None;
        }
        let tail = tail.max(1).min(self.points.len());
        let plateau: f64 = self.points[self.points.len() - tail..]
            .iter()
            .map(|p| p.accuracy)
            .sum::<f64>()
            / tail as f64;
        // earliest index from which all accuracies >= plateau - tolerance
        let mut idx = self.points.len() - 1;
        for i in (0..self.points.len()).rev() {
            if self.points[i].accuracy >= plateau - tolerance {
                idx = i;
            } else {
                break;
            }
        }
        Some((self.points[idx].time_s, plateau))
    }
}

/// Streaming convergence check used to stop runs early.
#[derive(Clone, Debug)]
pub struct ConvergenceDetector {
    /// Stop when this many consecutive evaluations improve less than
    /// `min_delta` over the running best.
    pub patience: usize,
    pub min_delta: f64,
    best: f64,
    stale: usize,
}

impl ConvergenceDetector {
    pub fn new(patience: usize, min_delta: f64) -> Self {
        ConvergenceDetector { patience, min_delta, best: f64::NEG_INFINITY, stale: 0 }
    }

    /// Feed an accuracy; returns true when converged.
    pub fn update(&mut self, accuracy: f64) -> bool {
        if accuracy > self.best + self.min_delta {
            self.best = accuracy;
            self.stale = 0;
        } else {
            self.stale += 1;
        }
        self.stale >= self.patience
    }

    pub fn best(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(points: &[(f64, f64)]) -> Curve {
        let mut c = Curve::default();
        for (i, &(t, a)) in points.iter().enumerate() {
            c.push(CurvePoint { time_s: t, epoch: i as u64, accuracy: a, loss: 1.0 - a });
        }
        c
    }

    #[test]
    fn convergence_simple_plateau() {
        let c = curve(&[(0.0, 0.1), (1.0, 0.5), (2.0, 0.8), (3.0, 0.81), (4.0, 0.805)]);
        let (t, plateau) = c.convergence(0.02, 3).unwrap();
        assert_eq!(t, 2.0);
        assert!((plateau - 0.805).abs() < 0.01);
    }

    #[test]
    fn convergence_handles_monotone() {
        let c = curve(&[(0.0, 0.2), (1.0, 0.4), (2.0, 0.6)]);
        let (t, _) = c.convergence(0.01, 1).unwrap();
        assert_eq!(t, 2.0);
    }

    #[test]
    fn convergence_empty_none() {
        assert!(Curve::default().convergence(0.01, 3).is_none());
    }

    #[test]
    fn convergence_single_point_curve() {
        let c = curve(&[(5.0, 0.42)]);
        let (t, plateau) = c.convergence(0.01, 3).unwrap();
        assert_eq!(t, 5.0);
        assert_eq!(plateau, 0.42);
    }

    #[test]
    fn convergence_tail_longer_than_curve_clamps() {
        // a 100-point tail over a 3-point curve averages what exists
        let c = curve(&[(0.0, 0.2), (1.0, 0.4), (2.0, 0.6)]);
        let (t, plateau) = c.convergence(0.5, 100).unwrap();
        assert!((plateau - 0.4).abs() < 1e-12);
        // tolerance 0.5 admits every point: convergence at the start
        assert_eq!(t, 0.0);
    }

    #[test]
    fn convergence_zero_tail_acts_as_final_point() {
        let c = curve(&[(0.0, 0.2), (1.0, 0.8)]);
        let (t, plateau) = c.convergence(0.01, 0).unwrap();
        assert_eq!(plateau, 0.8);
        assert_eq!(t, 1.0);
    }

    #[test]
    fn convergence_non_monotone_dip_resets_entry_point() {
        // a late dip below plateau - tolerance disqualifies everything
        // before it: convergence is the earliest *suffix* inside the
        // band, not the first crossing
        let c = curve(&[(0.0, 0.1), (1.0, 0.8), (2.0, 0.5), (3.0, 0.8), (4.0, 0.8)]);
        let (t, plateau) = c.convergence(0.05, 2).unwrap();
        assert!((plateau - 0.8).abs() < 1e-12);
        assert_eq!(t, 3.0, "the dip at t=2 must push convergence past it");
    }

    #[test]
    fn best_and_final() {
        let c = curve(&[(0.0, 0.3), (1.0, 0.9), (2.0, 0.7)]);
        assert_eq!(c.best_accuracy(), Some(0.9));
        assert_eq!(c.final_accuracy(), Some(0.7));
    }

    #[test]
    #[should_panic]
    fn rejects_time_regression() {
        let mut c = Curve::default();
        c.push(CurvePoint { time_s: 2.0, epoch: 0, accuracy: 0.5, loss: 0.5 });
        c.push(CurvePoint { time_s: 1.0, epoch: 1, accuracy: 0.6, loss: 0.4 });
    }

    #[test]
    fn detector_stops_on_plateau() {
        let mut d = ConvergenceDetector::new(3, 0.005);
        assert!(!d.update(0.5));
        assert!(!d.update(0.6));
        assert!(!d.update(0.601)); // stale 1
        assert!(!d.update(0.602)); // stale 2
        assert!(d.update(0.6)); // stale 3 -> converged
        assert!((d.best() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn detector_matches_documented_definition() {
        // doc'd rule: converged exactly when `patience` consecutive
        // updates fail to improve more than `min_delta` over the
        // running best (hand-traced expectations, patience 3, δ 0.005)
        let mut d = ConvergenceDetector::new(3, 0.005);
        let steps = [
            (0.3, false),    // best := 0.3
            (0.31, false),   // 0.31 > 0.305: best := 0.31
            (0.305, false),  // stale 1
            (0.32, false),   // 0.32 > 0.315: best := 0.32, stale resets
            (0.321, false),  // stale 1 (not > 0.325)
            (0.3215, false), // stale 2
            (0.3205, true),  // stale 3 = patience -> converged
        ];
        for (i, &(a, expect)) in steps.iter().enumerate() {
            assert_eq!(d.update(a), expect, "step {i} (acc {a})");
        }
        assert!((d.best() - 0.32).abs() < 1e-12, "ties below delta never move best");
    }

    #[test]
    fn detector_resets_on_improvement() {
        let mut d = ConvergenceDetector::new(2, 0.0);
        assert!(!d.update(0.5));
        assert!(!d.update(0.5)); // stale 1
        assert!(!d.update(0.7)); // improvement resets
        assert!(!d.update(0.7)); // stale 1
        assert!(d.update(0.69)); // stale 2
    }
}
