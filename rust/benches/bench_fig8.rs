//! Fig. 8 bench: the CIFAR-scale variant of the setting grid. At L3
//! the CIFAR experiments differ from Fig. 7 by the model payload size
//! (larger parameter vectors -> longer transmission delays -> slower
//! propagation), so this bench sweeps the *payload* dimension to show
//! the coordinator's sensitivity, using the surrogate for compute.
//! The real CIFAR CNN/MLP runs are `asyncfleo exp fig8a..c`.
//!
//! Run: `cargo bench --offline --bench bench_fig8`

use asyncfleo::bench::{bench, print_header, BenchConfig};
use asyncfleo::comm::delay::{model_bits, total_delay_s};
use asyncfleo::comm::LinkParams;
use asyncfleo::config::{ExperimentConfig, PsPlacement, SchemeKind};
use asyncfleo::coordinator::SimEnv;
use asyncfleo::fl::make_strategy;
use asyncfleo::train::SurrogateBackend;
use asyncfleo::util::fmt_hm;

fn main() {
    print_header("Fig. 8 (CIFAR-scale payloads)");

    // payload sensitivity: the four real model variants
    let link = LinkParams::default();
    println!("\nmodel payload -> one-hop transfer delay @2000 km:");
    for (name, dim) in [
        ("mlp_digits", 101_770usize),
        ("cnn_digits", 103_018),
        ("cnn_cifar", 133_882),
        ("mlp_cifar", 394_634),
    ] {
        let d = total_delay_s(&link, model_bits(dim), 2000.0);
        println!("  {name:<12} D={dim:>7}  {d:>6.3} s");
    }

    let bcfg = BenchConfig::endtoend();
    let mut reports = Vec::new();
    println!("\n{:<28} {:>9} {:>12} {:>7}", "cell", "acc(%)", "conv(h:mm)", "epochs");
    for iid in [true, false] {
        for placement in [PsPlacement::HapRolla, PsPlacement::TwoHaps] {
            let mut cfg = ExperimentConfig::paper_defaults();
            cfg.fl.scheme = SchemeKind::AsyncFleo;
            cfg.fl.dataset = asyncfleo::data::DatasetKind::Cifar;
            cfg.placement = placement;
            cfg.fl.horizon_s = 48.0 * 3600.0;
            cfg.fl.max_epochs = 40;
            let label = format!(
                "cifar/{}/{}",
                if iid { "iid" } else { "non-iid" },
                placement.name()
            );
            let run_once = || {
                let mut backend = SurrogateBackend::paper_split(5, 8, iid, 100);
                let mut env = SimEnv::new(&cfg, &mut backend);
                make_strategy(SchemeKind::AsyncFleo).run(&mut env)
            };
            let r = run_once();
            let (conv_t, acc) = match r.converged {
                Some((t, a)) => (t, a),
                None => (cfg.fl.horizon_s, r.final_accuracy),
            };
            println!(
                "{:<28} {:>9.2} {:>12} {:>7}",
                label,
                acc * 100.0,
                fmt_hm(conv_t),
                r.epochs
            );
            reports.push(bench(&label, &bcfg, run_once));
        }
    }

    print_header("wall-clock per cell");
    for r in &reports {
        println!("{}", r.report());
    }
}
