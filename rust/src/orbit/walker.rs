//! Walker-delta constellation builder (paper Fig. 1, Sec. V-A).
//!
//! A Walker-delta constellation `i:T/P/F` spreads `P` orbital planes
//! evenly over 360 degrees of RAAN, with `T/P` satellites equally
//! spaced in each plane and an inter-plane phasing factor `F`.

use super::elements::OrbitalElements;
use crate::util::Vec3;

/// A satellite's identity + orbital elements. IDs follow the paper's
/// `(orbit#, sat#)` convention (Fig. 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Satellite {
    /// Global index in [0, T).
    pub id: usize,
    /// Orbital plane index in [0, P).
    pub orbit: usize,
    /// In-plane index in [0, T/P).
    pub slot: usize,
    pub elements: OrbitalElements,
}

/// A full Walker-delta constellation.
#[derive(Clone, Debug)]
pub struct WalkerConstellation {
    pub satellites: Vec<Satellite>,
    pub n_orbits: usize,
    pub sats_per_orbit: usize,
}

impl WalkerConstellation {
    /// Build `P = n_orbits` planes x `n = sats_per_orbit` satellites.
    ///
    /// `phasing` is the Walker F factor (relative phase shift between
    /// adjacent planes, in units of 360/T degrees). The paper uses the
    /// standard delta pattern; F = 1 avoids synchronized planes.
    pub fn new(
        n_orbits: usize,
        sats_per_orbit: usize,
        altitude_km: f64,
        inclination_deg: f64,
        phasing: usize,
    ) -> Self {
        assert!(n_orbits > 0 && sats_per_orbit > 0);
        let total = n_orbits * sats_per_orbit;
        let tau = 2.0 * std::f64::consts::PI;
        let mut satellites = Vec::with_capacity(total);
        for o in 0..n_orbits {
            let raan = tau * o as f64 / n_orbits as f64;
            for s in 0..sats_per_orbit {
                let phase = tau * s as f64 / sats_per_orbit as f64
                    + tau * phasing as f64 * o as f64 / total as f64;
                satellites.push(Satellite {
                    id: o * sats_per_orbit + s,
                    orbit: o,
                    slot: s,
                    elements: OrbitalElements {
                        altitude_km,
                        inclination_rad: inclination_deg.to_radians(),
                        raan_rad: raan,
                        phase_rad: phase,
                    },
                });
            }
        }
        WalkerConstellation { satellites, n_orbits, sats_per_orbit }
    }

    /// The paper's evaluation constellation: 40 satellites over 5 orbits
    /// at 2000 km, inclination 80 degrees (Sec. V-A).
    pub fn paper() -> Self {
        WalkerConstellation::new(5, 8, 2000.0, 80.0, 1)
    }

    pub fn len(&self) -> usize {
        self.satellites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.satellites.is_empty()
    }

    /// Position of satellite `id` at time `t` (ECI, km).
    pub fn position(&self, id: usize, t: f64) -> Vec3 {
        super::propagation::satellite_position_eci(&self.satellites[id].elements, t)
    }

    /// Intra-orbit ring neighbours of a satellite: the two adjacent
    /// slots in the same plane (paper Sec. IV-A: ISLs only within an
    /// orbit, because inter-orbit relative velocity makes links
    /// unstable / Doppler-dominated).
    pub fn ring_neighbors(&self, id: usize) -> (usize, usize) {
        let sat = &self.satellites[id];
        let n = self.sats_per_orbit;
        let base = sat.orbit * n;
        let prev = base + (sat.slot + n - 1) % n;
        let next = base + (sat.slot + 1) % n;
        (prev, next)
    }

    /// All satellite IDs in one orbital plane.
    pub fn orbit_members(&self, orbit: usize) -> Vec<usize> {
        (0..self.sats_per_orbit).map(|s| orbit * self.sats_per_orbit + s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constellation_counts() {
        let c = WalkerConstellation::paper();
        assert_eq!(c.len(), 40);
        assert_eq!(c.n_orbits, 5);
        assert_eq!(c.sats_per_orbit, 8);
    }

    #[test]
    fn ids_are_dense_and_ordered() {
        let c = WalkerConstellation::new(3, 4, 800.0, 60.0, 1);
        for (i, s) in c.satellites.iter().enumerate() {
            assert_eq!(s.id, i);
            assert_eq!(s.orbit, i / 4);
            assert_eq!(s.slot, i % 4);
        }
    }

    #[test]
    fn raan_evenly_spread() {
        let c = WalkerConstellation::new(5, 8, 2000.0, 80.0, 1);
        let expect = 2.0 * std::f64::consts::PI / 5.0;
        for o in 1..5 {
            let d = c.satellites[o * 8].elements.raan_rad - c.satellites[(o - 1) * 8].elements.raan_rad;
            assert!((d - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn in_plane_spacing_uniform() {
        let c = WalkerConstellation::paper();
        let tau = 2.0 * std::f64::consts::PI;
        for s in 1..8 {
            let d = c.satellites[s].elements.phase_rad - c.satellites[s - 1].elements.phase_rad;
            assert!((d - tau / 8.0).abs() < 1e-12);
        }
    }

    #[test]
    fn equal_spacing_preserved_over_time() {
        // Satellites in the same plane keep constant angular separation.
        let c = WalkerConstellation::paper();
        let t = 5000.0;
        let p0 = c.position(0, t);
        let p1 = c.position(1, t);
        let expect = 2.0 * std::f64::consts::PI / 8.0;
        assert!((p0.angle_to(p1) - expect).abs() < 1e-9);
    }

    #[test]
    fn ring_neighbors_wrap() {
        let c = WalkerConstellation::paper();
        assert_eq!(c.ring_neighbors(0), (7, 1));
        assert_eq!(c.ring_neighbors(7), (6, 0));
        assert_eq!(c.ring_neighbors(8), (15, 9)); // first sat of orbit 1
        assert_eq!(c.ring_neighbors(39), (38, 32));
    }

    #[test]
    fn ring_neighbor_relation_is_symmetric() {
        let c = WalkerConstellation::paper();
        for id in 0..c.len() {
            let (p, n) = c.ring_neighbors(id);
            let (_, pn) = c.ring_neighbors(p);
            let (np, _) = c.ring_neighbors(n);
            assert_eq!(pn, id);
            assert_eq!(np, id);
        }
    }

    #[test]
    fn orbit_members_partition_constellation() {
        let c = WalkerConstellation::paper();
        let mut all: Vec<usize> = (0..5).flat_map(|o| c.orbit_members(o)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<_>>());
    }
}
