//! Structured run observability: typed event tracing, a metrics
//! registry and phase profiling — observe-only, with a bit-identity
//! guarantee.
//!
//! The paper's central claims (22× lower convergence delay, 40% higher
//! accuracy) rest on mechanisms the accuracy curves alone cannot show:
//! idle-waiting eliminated by asynchrony, staleness bounded by grouping
//! and discounting, link load concentrated on few HAP contacts. This
//! module makes those observable:
//!
//! * **typed event trace** — a [`TraceSink`] carried by
//!   `coordinator::RunState` receives typed records from every scheme,
//!   the faults engine and the event loop, written as JSONL by a
//!   hand-rolled serde-free writer ([`trace`]). Record kinds (one flat
//!   JSON object per line, tagged `"ev"`): `meta`, `contact_open` /
//!   `contact_close`, `model_tx` (every fault-adjusted link-delay call:
//!   src, dst, link class, base vs effective delay, retransmissions),
//!   `relay_hop`, `aggregate` (group count, staleness, discount factor,
//!   models folded), `model_dropped` / `model_retained`, `fault_hit`
//!   (with a `kind` tag: `loss` / `defer` from the legacy axes, plus
//!   `queue` / `queue_drop` / `partition` / `reorder` / `eclipse` /
//!   `retry_drop` from the network impairment engine), `eval`;
//! * **metrics registry** ([`metrics`]) — counters and fixed-bucket
//!   histograms (staleness at aggregation, per-link busy-time and
//!   bits, event-queue depth, delay calls, retransmissions, pool
//!   recycles) folded into an [`ObsReport`] and `results/report.json`;
//! * **phase profiling** ([`phase`]) — scoped wall-time timers around
//!   geometry build / contact scan / pass-map memoization (process-wide
//!   registry) and per-scheme event processing / aggregation (per-run),
//!   surfaced in `report.json` and `BENCH_runloop.json`, never in the
//!   trace (wall time would break trace determinism).
//!
//! # The bit-identity contract
//!
//! Observation is strictly *observe-only*: enabling it draws nothing
//! from any RNG, reorders no events and changes no arithmetic, so
//! curves, transfer counts and result CSVs are **bit-identical** with
//! tracing on or off (`tests/obs_equivalence.rs` pins this for every
//! preset × scheme, and pins trace determinism: same seed → identical
//! JSONL). The multi-lane event core (PR 9, `sim::lanes`) upholds the
//! same contract from the other side: lanes parallelize only pure
//! probes between pops and replay every observed effect in pop order,
//! so traces are **byte-identical at any lane count** (also pinned by
//! `tests/obs_equivalence.rs`). A run without observation carries
//! `None` and pays one branch per delay call; the
//! [`TraceSink::Disabled`] variant additionally supports metrics-only
//! observation (no record formatting) for sweep drivers.
//!
//! Entry points: `asyncfleo trace --preset X --scheme Y` writes one
//! instrumented run's `trace.jsonl` + `report.json`;
//! `asyncfleo report` renders the staleness histogram, top links by
//! utilization and the time-in-phase table from them.

pub mod metrics;
pub mod phase;
pub mod report;
pub mod trace;

pub use metrics::{Histogram, LinkKey, LinkLoad, Metrics};
pub use phase::{global_phase, global_phases, PhaseTimes, ScopedPhase};
pub use report::{summarize_trace, LinkRow, ObsReport};
pub use trace::TraceSink;

use crate::faults::LinkClass;
use trace::{jnum, json_escape};

/// Per-run observability state: the trace sink, the metrics registry
/// and the per-run phase timers. Carried as
/// `Option<Box<RunObs>>` by `coordinator::RunState` — `None` (the
/// default) means observation is off and every hook is one branch.
pub struct RunObs {
    pub sink: TraceSink,
    pub metrics: Metrics,
    pub phases: PhaseTimes,
    /// Simulated horizon, for link-utilization denominators (set by
    /// [`RunObs::meta`]).
    pub horizon_s: f64,
}

impl RunObs {
    fn with_sink(sink: TraceSink) -> Self {
        RunObs {
            sink,
            metrics: Metrics::default(),
            phases: PhaseTimes::default(),
            horizon_s: 0.0,
        }
    }

    /// Metrics-only observation (disabled sink): counters, histograms
    /// and phase timers without trace formatting. What sweep drivers
    /// enable for `report.json`.
    pub fn metrics_only() -> Self {
        Self::with_sink(TraceSink::Disabled)
    }

    /// Trace into memory (tests, in-process summaries).
    pub fn to_memory() -> Self {
        Self::with_sink(TraceSink::Memory(Vec::new()))
    }

    /// Trace into a JSONL file.
    pub fn to_file(path: &std::path::Path) -> std::io::Result<Self> {
        Ok(Self::with_sink(TraceSink::file(path)?))
    }

    /// Run header: world identity + denominators. Emit once, first.
    pub fn meta(
        &mut self,
        preset: &str,
        scheme: &str,
        seed: u64,
        horizon_s: f64,
        n_sats: usize,
        n_sites: usize,
    ) {
        self.horizon_s = horizon_s;
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"meta\",\"preset\":\"{}\",\"scheme\":\"{}\",\"seed\":{seed},\"horizon_s\":{},\"n_sats\":{n_sats},\"n_sites\":{n_sites}}}",
                json_escape(preset),
                json_escape(scheme),
                jnum(horizon_s),
            );
            self.sink.write_line(&line);
        }
    }

    /// A contact window opens between `site` and `sat`.
    pub fn contact_open(&mut self, t: f64, site: usize, sat: usize) {
        self.metrics.inc("contacts");
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"contact_open\",\"t\":{},\"site\":{site},\"sat\":{sat}}}",
                jnum(t)
            );
            self.sink.write_line(&line);
        }
    }

    /// A contact window closes between `site` and `sat`.
    pub fn contact_close(&mut self, t: f64, site: usize, sat: usize) {
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"contact_close\",\"t\":{},\"site\":{site},\"sat\":{sat}}}",
                jnum(t)
            );
            self.sink.write_line(&line);
        }
    }

    /// One fault-adjusted link-delay call: the model-transfer primitive
    /// every scheme's traffic flows through (aligned 1:1 with the
    /// `transfers` accounting). `retransmits` counts only newly
    /// observed channel events, matching `FaultStats`.
    pub fn model_tx(
        &mut self,
        t: f64,
        class: &LinkClass,
        base_s: f64,
        delay_s: f64,
        retransmits: u32,
        payload_bits: f64,
    ) {
        let (tag, a, b, ctr) = match *class {
            LinkClass::SatSite { sat, site } => ("site", sat as u32, site as u32, "tx.site"),
            LinkClass::Isl { sat_a, sat_b } => (
                "isl",
                sat_a.min(sat_b) as u32,
                sat_a.max(sat_b) as u32,
                "tx.isl",
            ),
            LinkClass::Ihl { site_a, site_b } => (
                "ihl",
                site_a.min(site_b) as u32,
                site_a.max(site_b) as u32,
                "tx.ihl",
            ),
        };
        self.metrics.inc(ctr);
        if retransmits > 0 {
            self.metrics.add("retransmissions", retransmits as u64);
        }
        self.metrics.observe("delay_s", metrics::DELAY_BUCKETS, delay_s);
        self.metrics
            .link(tag, a, b, delay_s, payload_bits * (1.0 + retransmits as f64));
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"model_tx\",\"t\":{},\"link\":\"{tag}\",\"src\":{a},\"dst\":{b},\"base_s\":{},\"delay_s\":{},\"retx\":{retransmits}}}",
                jnum(t),
                jnum(base_s),
                jnum(delay_s),
            );
            self.sink.write_line(&line);
        }
    }

    /// One hop of a routed multi-hop path (ISL graph routes, the HAP
    /// relay ring). The underlying delay call already accounts the
    /// link load; this marks path structure.
    pub fn relay_hop(&mut self, t: f64, kind: &'static str, a: usize, b: usize, delay_s: f64) {
        self.metrics.inc("relay_hops");
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"relay_hop\",\"t\":{},\"kind\":\"{kind}\",\"a\":{a},\"b\":{b},\"delay_s\":{}}}",
                jnum(t),
                jnum(delay_s),
            );
            self.sink.write_line(&line);
        }
    }

    /// Observe one aggregated model's staleness (global epochs behind).
    pub fn staleness(&mut self, s: f64) {
        self.metrics
            .observe("staleness", metrics::STALENESS_BUCKETS, s);
    }

    /// One aggregation: `group` partitions folded, `n_models` models,
    /// worst `staleness` among them, applied discount factor.
    pub fn aggregate(&mut self, t: f64, group: u64, n_models: usize, staleness: f64, discount: f64) {
        self.metrics.inc("aggregations");
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"aggregate\",\"t\":{},\"group\":{group},\"n_models\":{n_models},\"staleness\":{},\"discount\":{}}}",
                jnum(t),
                jnum(staleness),
                jnum(discount),
            );
            self.sink.write_line(&line);
        }
    }

    /// A buffered model was discarded (`reason`: `"stale"`, `"dead"`,
    /// `"past_horizon"`, …).
    pub fn model_dropped(&mut self, t: f64, sat: usize, epoch: u64, reason: &'static str) {
        self.metrics.inc("models_dropped");
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"model_dropped\",\"t\":{},\"sat\":{sat},\"epoch\":{epoch},\"reason\":\"{reason}\"}}",
                jnum(t)
            );
            self.sink.write_line(&line);
        }
    }

    /// A buffered model was kept for a later aggregation round.
    pub fn model_retained(&mut self, t: f64, sat: usize, epoch: u64) {
        self.metrics.inc("models_retained");
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"model_retained\",\"t\":{},\"sat\":{sat},\"epoch\":{epoch}}}",
                jnum(t)
            );
            self.sink.write_line(&line);
        }
    }

    /// The faults engine impaired a transfer (`kind`: `"loss"`,
    /// `"defer"`, or a network-impairment kind — `"queue"` /
    /// `"queue_drop"` / `"partition"` / `"reorder"` / `"eclipse"` /
    /// `"retry_drop"`), `n` events.
    pub fn fault_hit(&mut self, t: f64, kind: &'static str, n: u64) {
        match kind {
            "loss" => self.metrics.add("faults.loss", n),
            "defer" => self.metrics.add("faults.defer", n),
            "queue" => self.metrics.add("faults.queue", n),
            "queue_drop" => self.metrics.add("faults.queue_drop", n),
            "partition" => self.metrics.add("faults.partition", n),
            "reorder" => self.metrics.add("faults.reorder", n),
            "eclipse" => self.metrics.add("faults.eclipse", n),
            "retry_drop" => self.metrics.add("faults.retry_drop", n),
            _ => self.metrics.add("faults.other", n),
        }
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"fault_hit\",\"t\":{},\"kind\":\"{kind}\",\"n\":{n}}}",
                jnum(t)
            );
            self.sink.write_line(&line);
        }
    }

    /// One global-model evaluation (mirrors the accuracy curve).
    pub fn eval(&mut self, t: f64, epoch: u64, accuracy: f64, loss: f64) {
        self.metrics.inc("evals");
        if self.sink.enabled() {
            let line = format!(
                "{{\"ev\":\"eval\",\"t\":{},\"epoch\":{epoch},\"accuracy\":{},\"loss\":{}}}",
                jnum(t),
                jnum(accuracy),
                jnum(loss),
            );
            self.sink.write_line(&line);
        }
    }

    /// Sample the event-queue depth (called at pops; also feeds the
    /// high-water counter).
    pub fn queue_depth(&mut self, depth: usize) {
        self.metrics
            .observe("queue_depth", metrics::DEPTH_BUCKETS, depth as f64);
        self.metrics.set_max("queue_high_water", depth as u64);
    }

    /// Snapshot this run's metrics + phases into a serializable report.
    pub fn report(&self) -> ObsReport {
        ObsReport::of(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_only_mode_formats_nothing() {
        let mut o = RunObs::metrics_only();
        o.meta("p", "s", 1, 100.0, 4, 2);
        o.model_tx(
            1.0,
            &LinkClass::SatSite { sat: 3, site: 0 },
            0.1,
            0.2,
            1,
            1000.0,
        );
        o.eval(2.0, 1, 0.5, 1.0);
        assert!(o.sink.lines().is_empty());
        assert_eq!(o.metrics.counter("tx.site"), 1);
        assert_eq!(o.metrics.counter("retransmissions"), 1);
        assert_eq!(o.metrics.counter("evals"), 1);
        assert_eq!(o.horizon_s, 100.0);
    }

    #[test]
    fn memory_trace_is_valid_flat_jsonl() {
        let mut o = RunObs::to_memory();
        o.meta("paper-40", "asyncfleo", 42, 259200.0, 40, 2);
        o.contact_open(10.0, 0, 7);
        o.model_tx(
            11.0,
            &LinkClass::Isl { sat_a: 5, sat_b: 4 },
            0.05,
            0.05,
            0,
            1e6,
        );
        o.relay_hop(11.5, "isl", 4, 3, 0.05);
        o.staleness(2.0);
        o.aggregate(12.0, 3, 5, 2.0, 0.5);
        o.model_dropped(12.0, 9, 1, "stale");
        o.model_retained(12.0, 8, 2);
        o.fault_hit(13.0, "loss", 2);
        o.eval(14.0, 1, 0.7, 0.9);
        o.contact_close(20.0, 0, 7);
        let lines = o.sink.lines();
        assert_eq!(lines.len(), 10);
        for line in lines {
            assert!(line.starts_with("{\"ev\":\""), "line {line}");
            assert!(line.ends_with('}'), "line {line}");
            // flat records: no nested objects, so brace balance is 1+1
            assert_eq!(line.matches('{').count(), 1, "line {line}");
            assert_eq!(line.matches('}').count(), 1, "line {line}");
        }
        // ISL endpoints are direction-normalized in the load table
        assert_eq!(
            o.metrics.sorted_links()[0].0,
            LinkKey { class: "isl", a: 4, b: 5 }
        );
        assert_eq!(o.metrics.histogram("staleness").unwrap().total(), 1);
    }

    #[test]
    fn queue_depth_tracks_high_water() {
        let mut o = RunObs::metrics_only();
        o.queue_depth(3);
        o.queue_depth(17);
        o.queue_depth(5);
        assert_eq!(o.metrics.counter("queue_high_water"), 17);
        assert_eq!(o.metrics.histogram("queue_depth").unwrap().total(), 3);
    }
}
