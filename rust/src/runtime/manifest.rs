//! Parser for `artifacts/manifest.txt` — the machine-readable registry
//! written by `python/compile/aot.py`.
//!
//! Format (one record per line):
//! ```text
//! config local_steps=10 batch=32 eval_batch=256 n_sats=40
//! model mlp_digits dim=101770 feat=784 classes=10
//! artifact train_mlp_digits file=... in=f32[101770];f32[320,784];... out=f32[101770];f32[]
//! ```

use std::collections::BTreeMap;

/// Element type of a tensor (we only traffic in f32 and i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Parse `f32[320,784]`, `i32[]`, `f32[]`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let open = s.find('[').ok_or_else(|| format!("bad tensor spec: {s}"))?;
        let close = s.strip_suffix(']').ok_or_else(|| format!("bad tensor spec: {s}"))?;
        let dtype = match &s[..open] {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => return Err(format!("unsupported dtype {other}")),
        };
        let body = &close[open + 1..];
        let dims = if body.is_empty() {
            vec![]
        } else {
            body.split(',')
                .map(|d| d.trim().parse::<usize>().map_err(|e| format!("bad dim {d}: {e}")))
                .collect::<Result<Vec<_>, _>>()?
        };
        Ok(TensorSpec { dtype, dims })
    }
}

/// One AOT artifact record.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Per model-variant info.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelEntry {
    pub name: String,
    pub dim: usize,
    pub feat: usize,
    pub classes: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub models: BTreeMap<String, ModelEntry>,
    /// Training geometry: J local steps folded into one train dispatch.
    pub local_steps: usize,
    /// Mini-batch size b.
    pub batch: usize,
    /// Eval chunk size.
    pub eval_batch: usize,
    /// Aggregation slab rows = n_sats (+1 for the previous global model).
    pub n_sats: usize,
}

fn kv(parts: &[&str]) -> BTreeMap<String, String> {
    parts
        .iter()
        .filter_map(|p| p.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let mut m = Manifest::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let rest: Vec<&str> = parts.collect();
            let err = |msg: String| format!("manifest line {}: {msg}", lineno + 1);
            match tag {
                "config" => {
                    let map = kv(&rest);
                    let get = |k: &str| -> Result<usize, String> {
                        map.get(k)
                            .ok_or_else(|| err(format!("missing {k}")))?
                            .parse()
                            .map_err(|e| err(format!("bad {k}: {e}")))
                    };
                    m.local_steps = get("local_steps")?;
                    m.batch = get("batch")?;
                    m.eval_batch = get("eval_batch")?;
                    m.n_sats = get("n_sats")?;
                }
                "model" => {
                    let name = rest.first().ok_or_else(|| err("missing model name".into()))?;
                    let map = kv(&rest[1..]);
                    let get = |k: &str| -> Result<usize, String> {
                        map.get(k)
                            .ok_or_else(|| err(format!("missing {k}")))?
                            .parse()
                            .map_err(|e| err(format!("bad {k}: {e}")))
                    };
                    m.models.insert(
                        name.to_string(),
                        ModelEntry {
                            name: name.to_string(),
                            dim: get("dim")?,
                            feat: get("feat")?,
                            classes: get("classes")?,
                        },
                    );
                }
                "artifact" => {
                    let name = rest.first().ok_or_else(|| err("missing artifact name".into()))?;
                    let map = kv(&rest[1..]);
                    let file =
                        map.get("file").ok_or_else(|| err("missing file".into()))?.clone();
                    let parse_specs = |k: &str| -> Result<Vec<TensorSpec>, String> {
                        map.get(k)
                            .ok_or_else(|| err(format!("missing {k}")))?
                            .split(';')
                            .map(TensorSpec::parse)
                            .collect()
                    };
                    m.artifacts.insert(
                        name.to_string(),
                        ArtifactEntry {
                            name: name.to_string(),
                            file,
                            inputs: parse_specs("in")?,
                            outputs: parse_specs("out")?,
                        },
                    );
                }
                other => return Err(err(format!("unknown record tag {other}"))),
            }
        }
        Ok(m)
    }

    pub fn load(dir: &std::path::Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry, String> {
        self.artifacts.get(name).ok_or_else(|| format!("artifact {name} not in manifest"))
    }

    pub fn model(&self, tag: &str) -> Result<&ModelEntry, String> {
        self.models.get(tag).ok_or_else(|| format!("model {tag} not in manifest"))
    }

    /// Samples consumed by one train dispatch (J * b).
    pub fn dispatch_samples(&self) -> usize {
        self.local_steps * self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
config local_steps=10 batch=32 eval_batch=256 n_sats=40
model mlp_digits dim=101770 feat=784 classes=10
artifact train_mlp_digits file=train_mlp_digits.hlo.txt in=f32[101770];f32[320,784];f32[320,10];f32[] out=f32[101770];f32[]
artifact init_mlp_digits file=init_mlp_digits.hlo.txt in=i32[] out=f32[101770]
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.local_steps, 10);
        assert_eq!(m.batch, 32);
        assert_eq!(m.dispatch_samples(), 320);
        assert_eq!(m.models["mlp_digits"].dim, 101_770);
        let a = m.artifact("train_mlp_digits").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[1].dims, vec![320, 784]);
        assert_eq!(a.outputs[1].dims, Vec::<usize>::new());
        let i = m.artifact("init_mlp_digits").unwrap();
        assert_eq!(i.inputs[0].dtype, DType::I32);
    }

    #[test]
    fn tensor_spec_parse() {
        assert_eq!(
            TensorSpec::parse("f32[320,784]").unwrap(),
            TensorSpec { dtype: DType::F32, dims: vec![320, 784] }
        );
        assert_eq!(TensorSpec::parse("f32[]").unwrap().dims, Vec::<usize>::new());
        assert_eq!(TensorSpec::parse("i32[]").unwrap().dtype, DType::I32);
        assert!(TensorSpec::parse("f64[2]").is_err());
        assert!(TensorSpec::parse("f32").is_err());
    }

    #[test]
    fn elements_product() {
        assert_eq!(TensorSpec::parse("f32[320,784]").unwrap().elements(), 250_880);
        assert_eq!(TensorSpec::parse("f32[]").unwrap().elements(), 1);
    }

    #[test]
    fn missing_artifact_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_unknown_tags() {
        assert!(Manifest::parse("bogus x=1\n").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // integration: if `make artifacts` has run, the real manifest
        // must parse and contain all 4 model variants x 5 artifacts.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if let Ok(m) = Manifest::load(&dir) {
            assert_eq!(m.models.len(), 4);
            assert_eq!(m.artifacts.len(), 20);
            for tag in ["mlp_digits", "mlp_cifar", "cnn_digits", "cnn_cifar"] {
                for op in ["init", "train", "eval", "agg", "dist"] {
                    assert!(m.artifacts.contains_key(&format!("{op}_{tag}")));
                }
            }
        }
    }
}
