//! Discrete-event simulation engine (the coordinator's event loop).
//!
//! Built from scratch (no `tokio` offline): a monotonic clock plus a
//! binary-heap event queue with deterministic FIFO tie-breaking. The
//! coordinator schedules typed [`event::Event`]s (contact edges, model
//! arrivals, training completions, aggregations) and consumes them in
//! time order.
//!
//! [`lanes`] adds the multi-lane variant: events sharded by their
//! natural independence domain (orbital plane, HAP star group) into
//! per-lane heaps sharing one global push counter, merged back with a
//! deterministic k-way pop that is provably identical to the single
//! queue — the substrate for intra-run parallelism.

pub mod event;
pub mod lanes;
pub mod queue;

pub use event::{Event, EventKind};
pub use lanes::{EventSink, LanedQueue, RunOptions};
pub use queue::EventQueue;
