//! Mini-batch assembly: shard indices -> (xs, ys-onehot) buffers shaped
//! for the AOT train/eval artifacts.

use crate::data::{Dataset, Shard};
use crate::util::Rng;

/// Builds training dispatch buffers for one satellite.
pub struct BatchSampler {
    /// Shuffled cursor over the shard (epoch-style without replacement,
    /// reshuffling when exhausted).
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl BatchSampler {
    pub fn new(shard: &Shard, rng: Rng) -> Self {
        let mut s = BatchSampler { order: shard.indices.clone(), cursor: 0, rng };
        assert!(!s.order.is_empty(), "satellite shard is empty");
        s.rng.shuffle(&mut s.order);
        s
    }

    fn next_index(&mut self) -> usize {
        if self.cursor >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let i = self.order[self.cursor];
        self.cursor += 1;
        i
    }

    /// Fill `xs` ([n, feat] row-major) and `ys` ([n, classes] one-hot)
    /// with the next `n` samples.
    pub fn fill(&mut self, data: &Dataset, n: usize, xs: &mut Vec<f32>, ys: &mut Vec<f32>) {
        let feat = data.feat();
        let k = data.kind.classes();
        xs.clear();
        ys.clear();
        xs.reserve(n * feat);
        ys.resize(n * k, 0.0);
        for row in 0..n {
            let i = self.next_index();
            xs.extend_from_slice(data.sample(i));
            ys[row * k + data.y[i] as usize] = 1.0;
        }
    }
}

/// Build one eval chunk [chunk, feat] / [chunk, classes] starting at
/// test index `start`; rows beyond the dataset end are zero-padded
/// (all-zero labels are ignored by the eval artifact).
pub fn eval_chunk(
    data: &Dataset,
    start: usize,
    chunk: usize,
    xs: &mut Vec<f32>,
    ys: &mut Vec<f32>,
) -> usize {
    let feat = data.feat();
    let k = data.kind.classes();
    xs.clear();
    ys.clear();
    xs.resize(chunk * feat, 0.0);
    ys.resize(chunk * k, 0.0);
    let n_real = chunk.min(data.len().saturating_sub(start));
    for row in 0..n_real {
        let i = start + row;
        xs[row * feat..(row + 1) * feat].copy_from_slice(data.sample(i));
        ys[row * k + data.y[i] as usize] = 1.0;
    }
    n_real
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, DatasetKind};

    fn setup() -> (Dataset, Shard) {
        let d = generate(DatasetKind::Digits, 0, 100);
        let shard = Shard { indices: (0..50).collect() };
        (d, shard)
    }

    #[test]
    fn fill_shapes() {
        let (d, s) = setup();
        let mut sampler = BatchSampler::new(&s, Rng::new(1));
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        sampler.fill(&d, 32, &mut xs, &mut ys);
        assert_eq!(xs.len(), 32 * 784);
        assert_eq!(ys.len(), 32 * 10);
        // each row one-hot
        for row in 0..32 {
            let sum: f32 = ys[row * 10..(row + 1) * 10].iter().sum();
            assert_eq!(sum, 1.0);
        }
    }

    #[test]
    fn sampler_stays_within_shard() {
        let (d, s) = setup();
        let mut sampler = BatchSampler::new(&s, Rng::new(2));
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        // 5 fills of 32 from a 50-sample shard: must recycle, never OOB
        for _ in 0..5 {
            sampler.fill(&d, 32, &mut xs, &mut ys);
            // labels must come from shard classes (shard = indices 0..50)
            for row in 0..32 {
                let label = ys[row * 10..(row + 1) * 10].iter().position(|&v| v == 1.0).unwrap();
                assert!(label < 10);
            }
        }
    }

    #[test]
    fn epoch_coverage_before_reshuffle() {
        let (d, s) = setup();
        let mut sampler = BatchSampler::new(&s, Rng::new(3));
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        // one pass of exactly the shard size touches every index once;
        // verify via per-class sample counts matching the shard's
        let mut class_counts = [0usize; 10];
        for &i in &s.indices {
            class_counts[d.y[i] as usize] += 1;
        }
        sampler.fill(&d, 50, &mut xs, &mut ys);
        let mut seen = [0usize; 10];
        for row in 0..50 {
            let label = ys[row * 10..(row + 1) * 10].iter().position(|&v| v == 1.0).unwrap();
            seen[label] += 1;
        }
        assert_eq!(seen, class_counts);
    }

    #[test]
    fn eval_chunk_pads_tail() {
        let (d, _) = setup();
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        let n = eval_chunk(&d, 90, 32, &mut xs, &mut ys);
        assert_eq!(n, 10);
        assert_eq!(xs.len(), 32 * 784);
        // padded rows are all-zero labels
        for row in 10..32 {
            assert!(ys[row * 10..(row + 1) * 10].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    #[should_panic]
    fn empty_shard_panics() {
        let (_, _) = setup();
        let empty = Shard::default();
        BatchSampler::new(&empty, Rng::new(0));
    }
}
