//! Multi-lane event core: per-domain event lanes with a deterministic
//! k-way merge.
//!
//! The single [`EventQueue`](super::EventQueue) orders events by
//! `(time_s, push seq)`. A [`LanedQueue`] shards the *storage* of
//! pending events into `lanes` heaps — satellite-carrying events by
//! orbital plane, HAP/site events by their dense id — while stamping
//! every push with **one global** sequence counter. Popping takes the
//! minimum `(time_s, seq)` over the lane heads.
//!
//! **Determinism contract.** A binary heap pops the global minimum of
//! its `(time_s, seq)` keys; the k-way merge pops the minimum over
//! per-lane minima of the *same* keys, and the global `seq` makes every
//! key unique — so for any push/pop sequence the popped-event order of
//! a `LanedQueue` is provably identical to a single `EventQueue`, at
//! any lane count, regardless of how events were sharded. Sharding
//! affects only *where* an event waits, never *when* it pops. That
//! property is pinned by a property test over randomized event sets
//! (`tests/proptests.rs`) and, end to end, by the run-loop and obs
//! bit-identity suites at lanes ∈ {1, 2, 4}.
//!
//! The lanes exist so the expensive *pre-pop* work (delay probes for
//! broadcasts, uplink routes, collection chains — the geometry and
//! fault-channel math that dominates a mega-constellation run) can be
//! computed concurrently per lane between synchronization points, then
//! replayed serially in merged order. See `coordinator::env::LaneProbe`
//! and `fl::propagation`.
//!
//! The probes demand a *pure* delay oracle, which every impairment axis
//! honors except bandwidth queueing: a FIFO wait depends on the commit
//! order of earlier transfers, so runs with active link queues force
//! `lanes = 1` (`coordinator::SimEnv::lanes`, same escape hatch the
//! reference path uses) rather than let lane probes race queue state.

use super::event::{Event, EventKind};
use super::queue::Entry;
use std::collections::BinaryHeap;

/// Per-run execution options (how to run, not what to simulate — these
/// must never change results, only speed).
#[derive(Clone, Copy, Debug)]
pub struct RunOptions {
    /// Number of event lanes (and probe worker threads) for intra-run
    /// parallelism. `1` is op-for-op the historical single-queue path;
    /// any other value is bit-identical to it by the merge contract.
    pub lanes: usize,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { lanes: 1 }
    }
}

/// Anything events can be scheduled into. Lets the fault planner and
/// other schedulers target either queue flavour generically.
pub trait EventSink {
    fn push(&mut self, e: Event);
}

impl EventSink for super::EventQueue {
    fn push(&mut self, e: Event) {
        super::EventQueue::push(self, e);
    }
}

impl EventSink for LanedQueue {
    fn push(&mut self, e: Event) {
        LanedQueue::push(self, e);
    }
}

/// A sharded event queue whose pop order is identical to
/// [`EventQueue`](super::EventQueue) (see the module docs for the
/// argument). Drop-in API: `push` / `push_in` / `pop` / `now` / `len` /
/// `high_water` report exactly what the single queue would.
pub struct LanedQueue {
    /// One min-heap per lane, all ordered by the shared `(time_s, seq)`
    /// key (the `Entry` ordering is the single queue's).
    heaps: Vec<BinaryHeap<Entry>>,
    /// Satellite id → orbital plane, for routing satellite events to
    /// their plane's lane. May be empty (fall back to `sat % lanes`).
    plane_of: Vec<usize>,
    /// The **global** push counter — shared across lanes so FIFO ties
    /// break exactly as they would in one queue.
    seq: u64,
    now_s: f64,
    /// Total pending events (sum over lanes), kept incrementally.
    total: usize,
    /// Deepest the queue has ever been, counted across all lanes —
    /// matches the single queue's mark for the same push/pop sequence.
    high_water: usize,
}

impl LanedQueue {
    /// A queue with `lanes` lanes (clamped to ≥ 1). `plane_of` maps
    /// satellite ids to orbital planes for lane routing; an empty map
    /// degrades to `sat % lanes` routing — either way pop order is
    /// unaffected, only shard balance.
    pub fn new(lanes: usize, plane_of: Vec<usize>) -> Self {
        let lanes = lanes.max(1);
        LanedQueue {
            heaps: (0..lanes).map(|_| BinaryHeap::new()).collect(),
            plane_of,
            seq: 0,
            now_s: 0.0,
            total: 0,
            high_water: 0,
        }
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.heaps.len()
    }

    /// Which lane an event waits in: satellite traffic by orbital
    /// plane (up/downlink and ring collection are per-plane
    /// independent), HAP/site traffic by dense id (per-star-group),
    /// global barriers (aggregation ticks, sweeps) in lane 0.
    fn lane_for(&self, kind: &EventKind) -> usize {
        let lanes = self.heaps.len();
        let sat_lane = |sat: usize| {
            if let Some(&plane) = self.plane_of.get(sat) {
                plane % lanes
            } else {
                sat % lanes
            }
        };
        match *kind {
            EventKind::TrainingDone { sat }
            | EventKind::SatModelArrival { sat, .. }
            | EventKind::Retransmit { sat, .. }
            | EventKind::SatChurn { sat, .. } => sat_lane(sat),
            EventKind::HapLocalArrival { hap, .. }
            | EventKind::HapGlobalArrival { hap, .. }
            | EventKind::HapChurn { hap, .. } => hap % lanes,
            EventKind::SinkBatchArrival { from_hap, .. } => from_hap % lanes,
            EventKind::OutageStart { site } | EventKind::OutageEnd { site } => site % lanes,
            EventKind::AggregationTick | EventKind::Sweep => 0,
        }
    }

    /// Schedule an event. Same panics as the single queue: non-finite
    /// times and the simulated past are rejected up front.
    pub fn push(&mut self, e: Event) {
        assert!(
            e.time_s.is_finite(),
            "event time must be finite, got {} ({:?})",
            e.time_s,
            e.kind
        );
        assert!(
            e.time_s >= self.now_s,
            "cannot schedule into the past: {} < {} ({:?})",
            e.time_s,
            self.now_s,
            e.kind
        );
        let lane = self.lane_for(&e.kind);
        self.heaps[lane].push(Entry { time_s: e.time_s, seq: self.seq, event: e });
        self.seq += 1;
        self.total += 1;
        if self.total > self.high_water {
            self.high_water = self.total;
        }
    }

    /// Schedule `kind` at `now + delay`.
    pub fn push_in(&mut self, delay_s: f64, kind: EventKind) {
        let t = self.now_s + delay_s.max(0.0);
        self.push(Event::new(t, kind));
    }

    /// Pop the earliest event across all lanes, advancing the clock.
    /// The winner is the lane head with the least `(time_s, seq)` —
    /// i.e. exactly the entry a single heap would pop.
    pub fn pop(&mut self) -> Option<Event> {
        let mut best: Option<(usize, f64, u64)> = None;
        for (lane, heap) in self.heaps.iter().enumerate() {
            if let Some(head) = heap.peek() {
                let earlier = match best {
                    None => true,
                    Some((_, t, s)) => {
                        head.time_s < t || (head.time_s == t && head.seq < s)
                    }
                };
                if earlier {
                    best = Some((lane, head.time_s, head.seq));
                }
            }
        }
        best.map(|(lane, _, _)| {
            let entry = self.heaps[lane].pop().expect("peeked head exists");
            debug_assert!(entry.time_s >= self.now_s);
            self.now_s = entry.time_s;
            self.total -= 1;
            entry.event
        })
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now_s
    }

    /// Total pending events across all lanes.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Deepest the queue has ever been (total across lanes) — equal to
    /// what the single queue's mark would read.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heaps
            .iter()
            .filter_map(|h| h.peek())
            .map(|e| (e.time_s, e.seq))
            .min_by(|a, b| a.0.partial_cmp(&b.0).expect("finite").then(a.1.cmp(&b.1)))
            .map(|(t, _)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EventQueue;

    fn mixed_kinds(i: usize) -> EventKind {
        match i % 5 {
            0 => EventKind::TrainingDone { sat: i },
            1 => EventKind::HapLocalArrival { hap: i, origin_sat: i, epoch: 1 },
            2 => EventKind::Sweep,
            3 => EventKind::SatChurn { sat: i, up: true },
            _ => EventKind::OutageStart { site: i },
        }
    }

    #[test]
    fn default_options_are_the_historical_path() {
        assert_eq!(RunOptions::default().lanes, 1);
    }

    #[test]
    fn pop_order_matches_single_queue_with_ties() {
        for lanes in [1, 2, 3, 4, 7] {
            let mut single = EventQueue::new();
            let mut laned = LanedQueue::new(lanes, vec![0, 0, 1, 1, 2, 2]);
            for i in 0..60 {
                // coarse grid forces time ties so the seq tie-break is
                // exercised across lanes
                let t = ((i * 7) % 10) as f64;
                let e = Event::new(t, mixed_kinds(i));
                single.push(e.clone());
                laned.push(e);
            }
            loop {
                let a = single.pop();
                let b = laned.pop();
                assert_eq!(a, b, "lanes={lanes}");
                if a.is_none() {
                    break;
                }
            }
        }
    }

    #[test]
    fn len_and_high_water_match_single_queue() {
        let mut single = EventQueue::new();
        let mut laned = LanedQueue::new(4, Vec::new());
        for i in 0..30 {
            let e = Event::new(i as f64, mixed_kinds(i));
            single.push(e.clone());
            laned.push(e);
        }
        for _ in 0..10 {
            single.pop();
            laned.pop();
        }
        assert_eq!(laned.len(), single.len());
        assert_eq!(laned.high_water(), single.high_water());
        assert_eq!(laned.now(), single.now());
        assert_eq!(laned.peek_time(), single.peek_time());
    }

    #[test]
    fn routing_uses_planes_and_barrier_lane() {
        let q = LanedQueue::new(3, vec![0, 1, 2, 0]);
        assert_eq!(q.lane_for(&EventKind::TrainingDone { sat: 3 }), 0);
        assert_eq!(q.lane_for(&EventKind::TrainingDone { sat: 2 }), 2);
        // beyond the plane map: id-mod fallback
        assert_eq!(q.lane_for(&EventKind::TrainingDone { sat: 100 }), 1);
        assert_eq!(q.lane_for(&EventKind::AggregationTick), 0);
        assert_eq!(q.lane_for(&EventKind::Sweep), 0);
        assert_eq!(q.lane_for(&EventKind::HapGlobalArrival { hap: 5, epoch: 0 }), 2);
        assert_eq!(q.lane_for(&EventKind::SinkBatchArrival { from_hap: 4, count: 1 }), 1);
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn rejects_past_events_like_single_queue() {
        let mut q = LanedQueue::new(2, Vec::new());
        q.push(Event::new(5.0, EventKind::Sweep));
        q.pop();
        q.push(Event::new(1.0, EventKind::Sweep));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nonfinite_time() {
        let mut q = LanedQueue::new(2, Vec::new());
        q.push(Event { time_s: f64::NAN, kind: EventKind::Sweep });
    }

    #[test]
    fn push_in_is_relative_and_clamped() {
        let mut q = LanedQueue::new(2, Vec::new());
        q.push(Event::new(10.0, EventKind::Sweep));
        q.pop();
        q.push_in(-3.0, EventKind::Sweep);
        assert_eq!(q.peek_time(), Some(10.0));
        q.push_in(5.0, EventKind::AggregationTick);
        q.pop();
        assert_eq!(q.peek_time(), Some(15.0));
    }

    #[test]
    fn event_sink_is_object_safe_over_both_queues() {
        let mut single = EventQueue::new();
        let mut laned = LanedQueue::new(2, Vec::new());
        for q in [&mut single as &mut dyn EventSink, &mut laned as &mut dyn EventSink] {
            q.push(Event::new(1.0, EventKind::Sweep));
        }
        assert_eq!(single.len(), 1);
        assert_eq!(laned.len(), 1);
    }
}
