//! The real compute backend: every operation executes an AOT artifact
//! on the PJRT CPU client (L2 JAX graphs calling L1 Pallas kernels).

use super::sampler::{eval_chunk, BatchSampler};
use super::{Backend, EvalResult};
use crate::data::{partition_planes, Dataset, Partition, Shard};
use crate::model::ModelParams;
use crate::runtime::executor::Input;
use crate::runtime::{Executable, Runtime};
use crate::util::Rng;
use anyhow::Result;
use std::rc::Rc;

/// PJRT-backed FL compute for one model variant (e.g. "cnn_digits").
pub struct PjrtBackend {
    runtime: Rc<Runtime>,
    init_exe: Rc<Executable>,
    train_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
    agg_exe: Rc<Executable>,
    dist_exe: Rc<Executable>,
    dim: usize,
    lr: f32,
    train_data: Dataset,
    test_data: Dataset,
    shards: Vec<Shard>,
    samplers: Vec<BatchSampler>,
    // Reused buffers (no allocation on the training hot path).
    xs_buf: Vec<f32>,
    ys_buf: Vec<f32>,
    slab_buf: Vec<f32>,
}

impl PjrtBackend {
    /// Assemble from a runtime + datasets + a partitioning scheme.
    /// `plane_of` maps each satellite id to its global orbital-plane
    /// index (multi-shell aware; see `WalkerConstellation::plane_of`).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        runtime: Rc<Runtime>,
        model_tag: &str,
        train_data: Dataset,
        test_data: Dataset,
        scheme: Partition,
        plane_of: &[usize],
        lr: f32,
        seed: u64,
    ) -> Result<Self> {
        let dim = runtime.manifest.model(model_tag).map_err(anyhow::Error::msg)?.dim;
        let shards = partition_planes(&train_data, scheme, plane_of, seed);
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        let samplers = shards
            .iter()
            .map(|s| BatchSampler::new(s, rng.fork(s.indices.first().copied().unwrap_or(0) as u64)))
            .collect();
        Ok(PjrtBackend {
            init_exe: runtime.compile(&format!("init_{model_tag}"))?,
            train_exe: runtime.compile(&format!("train_{model_tag}"))?,
            eval_exe: runtime.compile(&format!("eval_{model_tag}"))?,
            agg_exe: runtime.compile(&format!("agg_{model_tag}"))?,
            dist_exe: runtime.compile(&format!("dist_{model_tag}"))?,
            runtime,
            dim,
            lr,
            train_data,
            test_data,
            shards,
            samplers,
            xs_buf: Vec::new(),
            ys_buf: Vec::new(),
            slab_buf: Vec::new(),
        })
    }

    /// Build a backend straight from an experiment config.
    pub fn from_config(
        runtime: Rc<Runtime>,
        cfg: &crate::config::ExperimentConfig,
    ) -> Result<Self> {
        let (train, test) = crate::data::synth::generate_split(
            cfg.fl.dataset,
            cfg.seed,
            cfg.data.train_samples,
            cfg.data.test_samples,
        );
        Self::new(
            runtime,
            &cfg.model_tag(),
            train,
            test,
            cfg.fl.partition,
            &cfg.constellation.plane_of(),
            cfg.fl.lr,
            cfg.seed,
        )
    }

    /// Total PJRT execute() time across all artifacts (perf accounting).
    pub fn total_exec_seconds(&self) -> f64 {
        [&self.init_exe, &self.train_exe, &self.eval_exe, &self.agg_exe, &self.dist_exe]
            .iter()
            .map(|e| e.exec_seconds.get())
            .sum()
    }

    fn manifest(&self) -> &crate::runtime::Manifest {
        &self.runtime.manifest
    }

    /// Shared slab set-up + agg-artifact execution of [`Backend::aggregate`]
    /// and [`Backend::aggregate_into`] — one definition, same floats.
    fn agg_slab_run(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
    ) -> Vec<f32> {
        assert_eq!(models.len(), coeffs.len());
        let slab_rows = self.manifest().n_sats + 1;
        assert!(
            models.len() <= slab_rows - 1,
            "{} models exceed the aggregation slab",
            models.len()
        );
        let d = self.dim;
        self.slab_buf.clear();
        self.slab_buf.resize(slab_rows * d, 0.0);
        self.slab_buf[..d].copy_from_slice(&prev.data);
        let mut cvec = vec![0.0f32; slab_rows];
        cvec[0] = coeff_prev;
        for (i, (m, &c)) in models.iter().zip(coeffs).enumerate() {
            self.slab_buf[(i + 1) * d..(i + 2) * d].copy_from_slice(&m.data);
            cvec[i + 1] = c;
        }
        let out = self
            .agg_exe
            .run(&[Input::F32(&self.slab_buf), Input::F32(&cvec)])
            .expect("agg artifact");
        out.into_iter().next().unwrap()
    }
}

impl Backend for PjrtBackend {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_sats(&self) -> usize {
        self.shards.len()
    }

    fn shard_size(&self, sat: usize) -> usize {
        self.shards[sat].len()
    }

    fn init_global(&mut self, seed: i32) -> ModelParams {
        let out = self
            .init_exe
            .run(&[Input::I32(&[seed])])
            .expect("init artifact");
        ModelParams { data: out.into_iter().next().unwrap() }
    }

    fn train_local(
        &mut self,
        sat: usize,
        params: &ModelParams,
        dispatches: usize,
    ) -> (ModelParams, f64) {
        assert!(dispatches > 0);
        let n = self.manifest().dispatch_samples();
        let mut cur = params.clone();
        let mut loss_sum = 0.0f64;
        for _ in 0..dispatches {
            // buffers are moved out to appease the borrow checker, then
            // restored — no reallocation across dispatches.
            let mut xs = std::mem::take(&mut self.xs_buf);
            let mut ys = std::mem::take(&mut self.ys_buf);
            self.samplers[sat].fill(&self.train_data, n, &mut xs, &mut ys);
            let out = self
                .train_exe
                .run(&[
                    Input::F32(&cur.data),
                    Input::F32(&xs),
                    Input::F32(&ys),
                    Input::F32(&[self.lr]),
                ])
                .expect("train artifact");
            self.xs_buf = xs;
            self.ys_buf = ys;
            let mut it = out.into_iter();
            cur = ModelParams { data: it.next().unwrap() };
            loss_sum += it.next().unwrap()[0] as f64;
        }
        (cur, loss_sum / dispatches as f64)
    }

    fn train_local_into(
        &mut self,
        sat: usize,
        params: &ModelParams,
        dispatches: usize,
        out: &mut ModelParams,
    ) -> f64 {
        assert!(dispatches > 0);
        let n = self.manifest().dispatch_samples();
        let mut loss_sum = 0.0f64;
        for k in 0..dispatches {
            let mut xs = std::mem::take(&mut self.xs_buf);
            let mut ys = std::mem::take(&mut self.ys_buf);
            self.samplers[sat].fill(&self.train_data, n, &mut xs, &mut ys);
            // dispatch 0 reads the caller's params, later ones chain on
            // `out` — same sampler stream, same floats as train_local,
            // but the result lands in the caller's reused buffer
            let cur: &[f32] = if k == 0 { &params.data } else { &out.data };
            let res = self
                .train_exe
                .run(&[
                    Input::F32(cur),
                    Input::F32(&xs),
                    Input::F32(&ys),
                    Input::F32(&[self.lr]),
                ])
                .expect("train artifact");
            self.xs_buf = xs;
            self.ys_buf = ys;
            let mut it = res.into_iter();
            let new = it.next().unwrap();
            out.data.clear();
            out.data.extend_from_slice(&new);
            loss_sum += it.next().unwrap()[0] as f64;
        }
        loss_sum / dispatches as f64
    }

    fn evaluate(&mut self, params: &ModelParams) -> EvalResult {
        let chunk = self.manifest().eval_batch;
        let mut correct = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut start = 0usize;
        let total = self.test_data.len();
        let mut xs = std::mem::take(&mut self.xs_buf);
        let mut ys = std::mem::take(&mut self.ys_buf);
        while start < total {
            eval_chunk(&self.test_data, start, chunk, &mut xs, &mut ys);
            let out = self
                .eval_exe
                .run(&[Input::F32(&params.data), Input::F32(&xs), Input::F32(&ys)])
                .expect("eval artifact");
            correct += out[0][0] as f64;
            loss_sum += out[1][0] as f64;
            start += chunk;
        }
        self.xs_buf = xs;
        self.ys_buf = ys;
        EvalResult { accuracy: correct / total as f64, loss: loss_sum / total as f64 }
    }

    fn aggregate(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
    ) -> ModelParams {
        ModelParams { data: self.agg_slab_run(prev, models, coeffs, coeff_prev) }
    }

    fn aggregate_into(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
        out: &mut ModelParams,
    ) {
        let new = self.agg_slab_run(prev, models, coeffs, coeff_prev);
        out.data.clear();
        out.data.extend_from_slice(&new);
    }

    fn distances(&mut self, models: &[&ModelParams], reference: &ModelParams) -> Vec<f64> {
        let rows = self.manifest().n_sats;
        assert!(models.len() <= rows);
        let d = self.dim;
        self.slab_buf.clear();
        self.slab_buf.resize(rows * d, 0.0);
        for (i, m) in models.iter().enumerate() {
            self.slab_buf[i * d..(i + 1) * d].copy_from_slice(&m.data);
        }
        let out = self
            .dist_exe
            .run(&[Input::F32(&self.slab_buf), Input::F32(&reference.data)])
            .expect("dist artifact");
        out[0][..models.len()].iter().map(|&v| v as f64).collect()
    }
}

// PJRT-dependent behaviour is integration-tested in
// rust/tests/runtime_e2e.rs (requires `make artifacts`).
