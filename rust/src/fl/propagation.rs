//! Model propagation timing (paper Algorithm 1, Sec. IV-B).
//!
//! Algorithm 1 relays models hop-by-hop: global models flow source-HAP
//! → ring → all HAPs → star-downlink → visible satellites → intra-orbit
//! ISL to the invisible ones; local models flow the reverse way, each
//! satellite relaying toward whichever ring position reaches a HAP
//! soonest. We implement the algorithm as a *path oracle*: for each
//! model we compute the arrival time the relay achieves (per-hop link
//! delays from the geometry at relay time), which is exactly the
//! event-timing the hop-by-hop process produces, without paying one
//! queue event per hop. Hop counts still enter the transfer accounting.
//!
//! Geometry reads go through a cloned `Arc<Geometry>` so the contact
//! plan can be iterated allocation-free while the env's delay calls
//! mutate the per-run state.
//!
//! Every oracle has a `_into` variant writing into a caller-owned
//! buffer: the run loops call these once per broadcast/epoch, so the
//! receive-time vectors are allocated once per run, not per event.
//! Plane membership is a contiguous id range
//! (`WalkerConstellation::orbit_members`), so relay sweeps and uplink
//! routing never materialize member lists either. Intra-plane neighbor
//! and slot lookups go through the [`IslGraph`] ring tables (PR 7),
//! keeping ring-routed schemes independent of the general ISL edge set.

//! # Lane probes (PR 9)
//!
//! The multi-lane run path splits every oracle into a **pure probe**
//! (geometry + fault-channel math over `Arc`-shared immutable state,
//! evaluated concurrently per lane) and a **serial replay** (the
//! recorded [`TxAction`]s re-run through `SimEnv::replay_tx` in the
//! exact serial call order). Every delay is a pure function of
//! `(link, t, base)`, so the probe's answer equals the replay's bit
//! for bit, and the replay reproduces the serial path's `transfers`
//! count, fault stats and trace records op for op — which is how
//! `lanes=N` stays bit-identical to `lanes=1`.

use crate::coordinator::{Geometry, LaneProbe, SimEnv, TxAction};
use crate::faults::{FaultSchedule, LinkClass};
use crate::topology::{HapRing, IslGraph};

/// Receive time of the global model at every HAP when `source` starts
/// the ring relay at `t` (Sec. IV-B1; Fig. 4a). Index = site id.
pub fn hap_ring_receive_times(env: &mut SimEnv, ring: &HapRing, source: usize, t: f64) -> Vec<f64> {
    let mut recv = Vec::new();
    hap_ring_receive_times_into(env, ring, source, t, &mut recv);
    recv
}

/// In-place [`hap_ring_receive_times`] (reused `recv` allocation).
pub fn hap_ring_receive_times_into(
    env: &mut SimEnv,
    ring: &HapRing,
    source: usize,
    t: f64,
    recv: &mut Vec<f64>,
) {
    recv.clear();
    recv.resize(ring.len(), f64::INFINITY);
    recv[source] = t;
    // Relay along the plan: each forwarding hop adds one IHL delay.
    for (h, fwds) in ring.relay_plan(source) {
        for fwd in fwds {
            let t_h = recv[h];
            debug_assert!(t_h.is_finite(), "relay plan visits {h} before receiving");
            let d = env.ihl_hop_delay(h, fwd, t_h);
            if let Some(obs) = env.obs() {
                obs.relay_hop(t_h, "ihl_ring", h, fwd, d);
            }
            recv[fwd] = recv[fwd].min(t_h + d);
        }
    }
}

/// Receive time of the global model at every satellite, given the HAP
/// broadcast instants `bcasts[site]` (Sec. IV-B2; Fig. 4b).
///
/// Visible satellites receive by star downlink; the rest by intra-orbit
/// ISL relay from whoever got it first. An orbit with nobody visible at
/// broadcast time receives at its earliest subsequent site contact.
/// Returns `f64::INFINITY` past-horizon entries when an orbit never
/// makes contact.
pub fn sat_receive_times(env: &mut SimEnv, bcasts: &[f64]) -> Vec<f64> {
    let mut recv = Vec::new();
    sat_receive_times_into(env, bcasts, &mut recv);
    recv
}

/// In-place [`sat_receive_times`] (reused `recv` allocation).
pub fn sat_receive_times_into(env: &mut SimEnv, bcasts: &[f64], recv: &mut Vec<f64>) {
    let geo = env.geo.clone();
    let n_sats = geo.constellation.len();
    recv.clear();
    recv.resize(n_sats, f64::INFINITY);

    // 1. direct star downlink to currently-visible satellites
    for (site, &tb) in bcasts.iter().enumerate() {
        if !tb.is_finite() {
            continue;
        }
        for sat in geo.plan.visible_sats(site, tb) {
            let d = env.site_link_delay(site, sat, tb);
            recv[sat] = recv[sat].min(tb + d);
        }
    }

    // 2. per-orbit: seed stranded orbits, then ISL ring relaxation
    for orbit in 0..geo.constellation.n_orbits {
        let members = geo.constellation.orbit_members(orbit);
        if members.clone().all(|m| !recv[m].is_finite()) {
            // nobody visible at broadcast: earliest later contact wins
            let mut best: Option<(f64, usize, usize)> = None; // (time, sat, site)
            for m in members.clone() {
                for (site, &tb) in bcasts.iter().enumerate() {
                    if !tb.is_finite() {
                        continue;
                    }
                    if let Some(tv) = geo.plan.next_visible(site, m, tb) {
                        if best.map_or(true, |b| tv < b.0) {
                            best = Some((tv, m, site));
                        }
                    }
                }
            }
            if let Some((tv, m, site)) = best {
                let d = env.site_link_delay(site, m, tv);
                recv[m] = tv + d;
            } else {
                continue; // orbit unreachable within horizon
            }
        }
        relax_ring(env, &geo.isl, members, recv);
    }
}

/// Multi-lane [`sat_receive_times_into`]: identical results (and
/// identical accounting, stats and trace) at any lane count.
///
/// Phase 1 probes the star downlinks in parallel over contiguous site
/// chunks; phase 2 runs the per-orbit seed scan + ring relaxation in
/// parallel over contiguous plane chunks (plane membership is a
/// contiguous id range, so each lane owns a disjoint `recv` sub-slice).
/// Both phases record their [`TxAction`]s in the serial call order and
/// the single replay pass re-runs them through the env.
pub fn sat_receive_times_lanes_into(env: &mut SimEnv, bcasts: &[f64], recv: &mut Vec<f64>) {
    let lanes = env.lanes();
    if lanes <= 1 {
        return sat_receive_times_into(env, bcasts, recv);
    }
    let geo = env.geo.clone();
    let probe = env.lane_probe();
    let n_sats = geo.constellation.len();
    recv.clear();
    recv.resize(n_sats, f64::INFINITY);

    // -- phase 1: star downlink probes, parallel by site chunk --
    let n_sites = bcasts.len();
    let mut site_actions: Vec<Vec<TxAction>> = vec![Vec::new(); n_sites];
    let chunk = ((n_sites + lanes - 1) / lanes).max(1);
    std::thread::scope(|s| {
        for (ci, out) in site_actions.chunks_mut(chunk).enumerate() {
            let probe = &probe;
            let geo = &geo;
            s.spawn(move || {
                for (k, acts) in out.iter_mut().enumerate() {
                    let site = ci * chunk + k;
                    let tb = bcasts[site];
                    if !tb.is_finite() {
                        continue;
                    }
                    for sat in geo.plan.visible_sats(site, tb) {
                        let (_, act) = probe.site_link_delay(site, sat, tb);
                        acts.push(act);
                    }
                }
            });
        }
    });
    // serial replay in (site asc, visible-sat asc) order — the exact
    // iteration order of the single-lane loop
    for (site, acts) in site_actions.iter().enumerate() {
        let tb = bcasts[site];
        for act in acts {
            let d = env.replay_tx(act);
            let sat = match act.class {
                LinkClass::SatSite { sat, .. } => sat,
                _ => unreachable!("phase 1 records star downlinks only"),
            };
            recv[sat] = recv[sat].min(tb + d);
        }
    }

    // -- phase 2: seed + ring relaxation, parallel by plane chunk --
    let n_orbits = geo.constellation.n_orbits;
    let ochunk = ((n_orbits + lanes - 1) / lanes).max(1);
    let mut orbit_actions: Vec<Vec<TxAction>> = vec![Vec::new(); n_orbits];
    std::thread::scope(|s| {
        let mut rest: &mut [f64] = &mut recv[..];
        let mut offset = 0usize;
        for (ci, acts_chunk) in orbit_actions.chunks_mut(ochunk).enumerate() {
            let o_lo = ci * ochunk;
            let o_hi = o_lo + acts_chunk.len();
            let sat_end = geo.constellation.orbit_members(o_hi - 1).end;
            let (mine, tail) = rest.split_at_mut(sat_end - offset);
            rest = tail;
            let my_offset = offset;
            offset = sat_end;
            let probe = &probe;
            let geo = &geo;
            s.spawn(move || {
                for (oi, orbit) in (o_lo..o_hi).enumerate() {
                    let members = geo.constellation.orbit_members(orbit);
                    let out = &mut acts_chunk[oi];
                    if members.clone().all(|m| !mine[m - my_offset].is_finite()) {
                        let mut best: Option<(f64, usize, usize)> = None;
                        for m in members.clone() {
                            for (site, &tb) in bcasts.iter().enumerate() {
                                if !tb.is_finite() {
                                    continue;
                                }
                                if let Some(tv) = geo.plan.next_visible(site, m, tb) {
                                    if best.map_or(true, |b| tv < b.0) {
                                        best = Some((tv, m, site));
                                    }
                                }
                            }
                        }
                        if let Some((tv, m, site)) = best {
                            let (d, act) = probe.site_link_delay(site, m, tv);
                            out.push(act);
                            mine[m - my_offset] = tv + d;
                        } else {
                            continue; // orbit unreachable within horizon
                        }
                    }
                    let mut rec = HopRecorder { probe, actions: out };
                    relax_ring_at(&mut rec, &geo.isl, members, mine, my_offset);
                }
            });
        }
    });
    // serial replay, orbit ascending — recv already holds the lane
    // results (bit-equal to serial by probe purity); the replay re-runs
    // the accounting and trace on the env
    for acts in &orbit_actions {
        for act in acts {
            let _ = env.replay_tx(act);
        }
    }
}

/// The ring relaxation's delay source: the env itself (serial path —
/// accounting inline, exactly the historical calls) or a lane recorder
/// (pure probe + action log for later replay). One generic body keeps
/// the two paths structurally identical.
trait HopOracle {
    fn hop_delay(&mut self, a: usize, b: usize, t: f64) -> f64;
}

impl HopOracle for SimEnv<'_> {
    fn hop_delay(&mut self, a: usize, b: usize, t: f64) -> f64 {
        self.isl_hop_delay(a, b, t)
    }
}

/// Lane-side oracle: probes delays purely and logs the action sequence
/// (which, by the purity argument in the module docs, is exactly the
/// call sequence the serial path would have made).
struct HopRecorder<'a> {
    probe: &'a LaneProbe,
    actions: &'a mut Vec<TxAction>,
}

impl HopOracle for HopRecorder<'_> {
    fn hop_delay(&mut self, a: usize, b: usize, t: f64) -> f64 {
        let (d, act) = self.probe.isl_hop_delay(a, b, t);
        self.actions.push(act);
        d
    }
}

/// Bidirectional ring relaxation of receive times within one orbit
/// (`members` is the plane's contiguous id range). Neighbors come from
/// the [`IslGraph`] ring tables, which pin the intra-plane ring for
/// every topology — so ring-routed schemes stay bit-identical whichever
/// general edge set the graph carries.
fn relax_ring(
    env: &mut SimEnv,
    graph: &IslGraph,
    members: std::ops::Range<usize>,
    recv: &mut [f64],
) {
    relax_ring_at(env, graph, members, recv, 0);
}

/// [`relax_ring`] over a delay oracle and an offset view: `recv[i]`
/// holds the receive time of satellite `offset + i`, so probe lanes can
/// relax their plane chunk on a disjoint sub-slice of the full vector.
fn relax_ring_at<O: HopOracle>(
    oracle: &mut O,
    graph: &IslGraph,
    members: std::ops::Range<usize>,
    recv: &mut [f64],
    offset: usize,
) {
    let start = members.start;
    let n = members.len();
    if n <= 1 {
        return;
    }
    // repeated sweeps until fixpoint (≤ n/2 hops from any seed)
    for _ in 0..n {
        let mut changed = false;
        for i in 0..n {
            let cur = start + i;
            if !recv[cur - offset].is_finite() {
                continue;
            }
            let (prev, next) = graph.ring_neighbors(cur);
            for nb in [next, prev] {
                let d = oracle.hop_delay(cur, nb, recv[cur - offset]);
                if recv[cur - offset] + d < recv[nb - offset] {
                    recv[nb - offset] = recv[cur - offset] + d;
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
}

/// Where a finished local model ends up: the satellite relays it along
/// its orbit's ring to whichever member can hand it to a site soonest
/// (Sec. IV-B2 last paragraph). Returns `(site, arrival_time, hops)`,
/// or `None` if no member ever sees a site again within the horizon.
pub fn uplink_route(env: &mut SimEnv, sat: usize, t_ready: f64) -> Option<(usize, f64, usize)> {
    let geo = env.geo.clone();
    let orbit = geo.constellation.satellites[sat].orbit;
    let members = geo.constellation.orbit_members(orbit);
    let n = members.len();
    let my_slot = geo.isl.ring_pos(sat);

    // Estimate the (near-constant) intra-orbit hop delay once.
    let hop_delay = if n > 1 {
        let (prev, _) = geo.isl.ring_neighbors(sat);
        env.isl_hop_delay(sat, prev, t_ready)
    } else {
        0.0
    };

    let mut best: Option<(usize, f64, usize)> = None;
    for (j_idx, j) in members.enumerate() {
        let fwd = (j_idx + n - my_slot) % n;
        let hops = fwd.min(n - fwd);
        let t_at_j = t_ready + hops as f64 * hop_delay;
        if let Some((tv, site)) = geo.plan.next_visible_any(j, t_at_j) {
            let d_up = env.site_link_delay(site, j, tv);
            let arrival = tv + d_up;
            if best.map_or(true, |b| arrival < b.1) {
                best = Some((site, arrival, hops));
            }
        }
    }
    // account the relay hops as transfers
    if let Some((site, arrival, hops)) = best {
        env.state.transfers += hops as u64;
        if let Some(obs) = env.obs() {
            obs.relay_hop(t_ready, "isl_uplink", sat, site, arrival - t_ready);
        }
    }
    best
}

/// A pre-computed [`uplink_route`]: the probe's action log plus its
/// answer, ready for a later serial replay. The route depends only on
/// `(geometry, fault schedule, sat, t_ready)` — all immutable within a
/// run — so computing it at event push time on a lane and replaying at
/// pop time yields the identical result.
pub struct RouteProbe {
    pub sat: usize,
    pub t_ready: f64,
    actions: Vec<TxAction>,
    best: Option<(usize, f64, usize)>,
}

/// Lane-side [`uplink_route`]: same scan, same probe order (one ring
/// hop estimate when the plane has more than one member, then the
/// ascending member scan), pure over the shared probe state.
pub fn uplink_route_probe(probe: &LaneProbe, sat: usize, t_ready: f64) -> RouteProbe {
    let geo = probe.geo();
    let orbit = geo.constellation.satellites[sat].orbit;
    let members = geo.constellation.orbit_members(orbit);
    let n = members.len();
    let my_slot = geo.isl.ring_pos(sat);
    let mut actions = Vec::new();

    let hop_delay = if n > 1 {
        let (prev, _) = geo.isl.ring_neighbors(sat);
        let (d, act) = probe.isl_hop_delay(sat, prev, t_ready);
        actions.push(act);
        d
    } else {
        0.0
    };

    let mut best: Option<(usize, f64, usize)> = None;
    for (j_idx, j) in members.enumerate() {
        let fwd = (j_idx + n - my_slot) % n;
        let hops = fwd.min(n - fwd);
        let t_at_j = t_ready + hops as f64 * hop_delay;
        if let Some((tv, site)) = geo.plan.next_visible_any(j, t_at_j) {
            let (d_up, act) = probe.site_link_delay(site, j, tv);
            actions.push(act);
            let arrival = tv + d_up;
            if best.map_or(true, |b| arrival < b.1) {
                best = Some((site, arrival, hops));
            }
        }
    }
    RouteProbe { sat, t_ready, actions, best }
}

/// Serial replay of a [`RouteProbe`]: re-runs the recorded delay calls
/// against the env (transfers, stats, trace — op-for-op the serial
/// [`uplink_route`]) and returns the probed answer. An unreplayed probe
/// (its satellite died, or its event went stale) costs nothing: probes
/// are pure and unobservable until replayed.
pub fn uplink_route_replay(env: &mut SimEnv, rp: &RouteProbe) -> Option<(usize, f64, usize)> {
    for act in &rp.actions {
        let _ = env.replay_tx(act);
    }
    if let Some((site, arrival, hops)) = rp.best {
        env.state.transfers += hops as u64;
        if let Some(obs) = env.obs() {
            obs.relay_hop(rp.t_ready, "isl_uplink", rp.sat, site, arrival - rp.t_ready);
        }
    }
    rp.best
}

/// Earliest `(t_visible, site)` contact of `sat` at/after `from` whose
/// PS is alive — the pure (schedule-only) contact search the sync
/// baselines retry on. Bounded retries: a dead-site pass re-queries
/// 300 s after the found contact, at most 8 times.
pub fn next_live_contact(
    geo: &Geometry,
    schedule: &FaultSchedule,
    sat: usize,
    from: f64,
) -> Option<(f64, usize)> {
    let mut t_try = from;
    for _ in 0..8 {
        let (tv, site) = geo.plan.next_visible_any(sat, t_try)?;
        if schedule.hap_alive(site, tv) {
            return Some((tv, site));
        }
        t_try = tv + 300.0;
    }
    None
}

/// Arrival time at the sink HAP of a local-model batch handed to
/// `from_site` at `t` (Sec. IV-B3: relayed along the ring to the sink).
pub fn ihl_to_sink(env: &mut SimEnv, ring: &HapRing, from_site: usize, t: f64) -> f64 {
    let mut cur = from_site;
    let mut time = t;
    while let Some(next) = ring.next_hop_toward(cur, ring.sink()) {
        let d = env.ihl_hop_delay(cur, next, time);
        if let Some(obs) = env.obs() {
            obs.relay_hop(time, "ihl_sink", cur, next, d);
        }
        time += d;
        cur = next;
    }
    time
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::SimEnv;
    use crate::train::SurrogateBackend;

    fn env_with(placement: crate::config::PsPlacement) -> (ExperimentConfig, SurrogateBackend) {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = placement;
        cfg.fl.horizon_s = 86_400.0;
        let b = SurrogateBackend::paper_split(5, 8, false, 100);
        (cfg, b)
    }

    #[test]
    fn hap_ring_two_haps() {
        let (cfg, mut b) = env_with(crate::config::PsPlacement::TwoHaps);
        let mut env = SimEnv::new(&cfg, &mut b);
        let ring = HapRing::new(2);
        let recv = hap_ring_receive_times(&mut env, &ring, 0, 100.0);
        assert_eq!(recv[0], 100.0);
        assert!(recv[1] > 100.0 && recv[1] < 101.0, "IHL delay ~0.2s, got {}", recv[1] - 100.0);
    }

    #[test]
    fn sat_receive_times_cover_constellation() {
        let (cfg, mut b) = env_with(crate::config::PsPlacement::TwoHaps);
        let mut env = SimEnv::new(&cfg, &mut b);
        let recv = sat_receive_times(&mut env, &[0.0, 0.3]);
        let finite = recv.iter().filter(|r| r.is_finite()).count();
        assert_eq!(finite, 40, "all sats reachable within a day: {recv:?}");
        // visible sats receive almost immediately; stranded orbits later
        let min = recv.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min < 10.0, "someone visible at t=0 gets it fast");
    }

    #[test]
    fn isl_relay_beats_waiting() {
        // satellites in an orbit with one visible member must receive
        // within a few ISL hops (~seconds), not wait for their own pass
        let (cfg, mut b) = env_with(crate::config::PsPlacement::HapRolla);
        let mut env = SimEnv::new(&cfg, &mut b);
        let t0 = env.geo.plan.windows(0, 0).first().map(|w| w.start_s + 1.0).unwrap_or(0.0);
        let recv = sat_receive_times(&mut env, &[t0]);
        let visible: Vec<usize> = env.geo.plan.visible_sats(0, t0).collect();
        for &v in &visible {
            let orbit = env.geo.constellation.satellites[v].orbit;
            for m in env.geo.constellation.orbit_members(orbit) {
                assert!(
                    recv[m] - t0 < 60.0,
                    "sat {m} in seeded orbit {orbit} took {}s",
                    recv[m] - t0
                );
            }
        }
    }

    #[test]
    fn uplink_route_exists_and_is_causal() {
        let (cfg, mut b) = env_with(crate::config::PsPlacement::HapRolla);
        let mut env = SimEnv::new(&cfg, &mut b);
        for sat in [0usize, 7, 21, 39] {
            let (site, arrival, hops) = uplink_route(&mut env, sat, 1000.0).unwrap();
            assert!(site < 1 + 0 + 1);
            assert!(arrival > 1000.0);
            assert!(hops <= 4, "ring of 8: at most 4 hops");
        }
    }

    #[test]
    fn uplink_route_visible_sat_is_fast() {
        let (cfg, mut b) = env_with(crate::config::PsPlacement::HapRolla);
        let mut env = SimEnv::new(&cfg, &mut b);
        // find a moment a satellite is visible
        let w = env.geo.plan.windows(0, 5).first().copied().expect("sat 5 window");
        let t = 0.5 * (w.start_s + w.end_s);
        let (_, arrival, hops) = uplink_route(&mut env, 5, t).unwrap();
        assert_eq!(hops, 0, "already visible: no relay needed");
        assert!(arrival - t < 5.0, "direct uplink, got {}", arrival - t);
    }

    #[test]
    fn laned_sat_receive_times_match_serial_bitwise() {
        for lanes in [2usize, 3, 4, 7] {
            let (cfg, mut b1) = env_with(crate::config::PsPlacement::TwoHaps);
            let mut serial = SimEnv::new(&cfg, &mut b1);
            let mut b2 = SurrogateBackend::paper_split(5, 8, false, 100);
            let mut laned = SimEnv::new(&cfg, &mut b2);
            laned.set_lanes(lanes);
            let bcasts = [0.0, 0.3];
            let mut a = Vec::new();
            let mut b = Vec::new();
            sat_receive_times_into(&mut serial, &bcasts, &mut a);
            sat_receive_times_lanes_into(&mut laned, &bcasts, &mut b);
            assert_eq!(a.len(), b.len());
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "sat {i} at lanes={lanes}");
            }
            assert_eq!(serial.state.transfers, laned.state.transfers, "lanes={lanes}");
        }
    }

    #[test]
    fn uplink_route_probe_replay_matches_serial() {
        let (cfg, mut b1) = env_with(crate::config::PsPlacement::HapRolla);
        let mut serial = SimEnv::new(&cfg, &mut b1);
        let mut b2 = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut laned = SimEnv::new(&cfg, &mut b2);
        let probe = laned.lane_probe();
        for sat in [0usize, 7, 21, 39] {
            let a = uplink_route(&mut serial, sat, 1000.0);
            let rp = uplink_route_probe(&probe, sat, 1000.0);
            let b = uplink_route_replay(&mut laned, &rp);
            assert_eq!(a, b, "sat {sat}");
        }
        assert_eq!(serial.state.transfers, laned.state.transfers);
    }

    #[test]
    fn sink_forwarding_adds_delay() {
        let (cfg, mut b) = env_with(crate::config::PsPlacement::TwoHaps);
        let mut env = SimEnv::new(&cfg, &mut b);
        let ring = HapRing::new(2);
        let t_sink = ihl_to_sink(&mut env, &ring, 0, 500.0);
        assert!(t_sink > 500.0);
        let t_already = ihl_to_sink(&mut env, &ring, ring.sink(), 500.0);
        assert_eq!(t_already, 500.0);
    }
}
