//! Tiny CSV writer for experiment outputs (results/*.csv).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with `#`-prefixed header comments (we embed the
/// full experiment config so every table regenerates from its CSV).
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
    autoflush: bool,
}

impl CsvWriter {
    pub fn create(
        path: impl AsRef<Path>,
        comments: &[&str],
        header: &[&str],
    ) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        for c in comments {
            for line in c.lines() {
                writeln!(out, "# {line}")?;
            }
        }
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len(), autoflush: false })
    }

    /// Flush after every row. The streaming sweep drivers enable this
    /// so completed rows are durable on disk the moment their cell
    /// finishes — an error later in the grid can't lose them.
    pub fn autoflush(mut self, on: bool) -> Self {
        self.autoflush = on;
        self
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.columns, "row arity mismatch");
        writeln!(self.out, "{}", fields.join(","))?;
        if self.autoflush {
            self.out.flush()?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Escape-free field formatting helpers.
pub fn f(v: f64) -> String {
    format!("{v:.6}")
}

pub fn i(v: u64) -> String {
    v.to_string()
}

pub fn s(v: &str) -> String {
    assert!(!v.contains(','), "CSV fields must not contain commas");
    v.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_comments_rows() {
        let dir = std::env::temp_dir().join("asyncfleo_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["cfg line1\nline2"], &["a", "b"]).unwrap();
            w.row(&[f(1.0), i(2)]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("# cfg line1\n# line2\na,b\n"));
        assert!(text.contains("1.000000,2"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let dir = std::env::temp_dir().join("asyncfleo_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = CsvWriter::create(dir.join("u.csv"), &[], &["a", "b"]).unwrap();
        let _ = w.row(&[f(1.0)]);
    }

    #[test]
    #[should_panic]
    fn comma_in_string_panics() {
        s("a,b");
    }
}
