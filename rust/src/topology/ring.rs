//! The HAP "backbone" ring: roles, arcs, and relay routing.

/// The ring of HAPs with current source/sink designation.
///
/// Indices are positions on the ring (HAPs are placed on the ring in
/// construction order; with the paper's 2-HAP setup the ring degenerates
/// to a single bidirectional link, and with 1 HAP to a no-op).
#[derive(Clone, Debug)]
pub struct HapRing {
    n: usize,
    source: usize,
    sink: usize,
}

impl HapRing {
    /// Build a ring of `n` HAPs. The initial source is index 0 and the
    /// sink is the farthest node around the ring (paper Sec. IV-B1).
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "ring needs at least one HAP");
        let source = 0;
        let sink = if n == 1 { 0 } else { n / 2 };
        HapRing { n, source, sink }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn source(&self) -> usize {
        self.source
    }

    pub fn sink(&self) -> usize {
        self.sink
    }

    /// Ring neighbours (prev, next) of HAP `i`.
    pub fn neighbors(&self, i: usize) -> (usize, usize) {
        assert!(i < self.n);
        ((i + self.n - 1) % self.n, (i + 1) % self.n)
    }

    /// Swap source and sink roles (done after each aggregation so the
    /// fresh global model flows back along the reverse path, IV-B3).
    pub fn swap_roles(&mut self) {
        std::mem::swap(&mut self.source, &mut self.sink);
    }

    /// Hop distance from `i` to `j` going clockwise (`next` direction).
    fn cw_dist(&self, i: usize, j: usize) -> usize {
        (j + self.n - i) % self.n
    }

    /// Next hop from `i` toward `target` along the shorter arc
    /// (ties broken clockwise). Returns `None` when already there.
    pub fn next_hop_toward(&self, i: usize, target: usize) -> Option<usize> {
        assert!(i < self.n && target < self.n);
        if i == target {
            return None;
        }
        let cw = self.cw_dist(i, target);
        let ccw = self.n - cw;
        let (prev, next) = self.neighbors(i);
        Some(if cw <= ccw { next } else { prev })
    }

    /// The broadcast relay plan from `from`: each entry is
    /// `(hap, forwards_to)` in BFS order along both arcs; the sink
    /// forwards to nobody (Sec. IV-B1: "stop relaying at the sink").
    /// Every HAP appears exactly once.
    pub fn relay_plan(&self, from: usize) -> Vec<(usize, Vec<usize>)> {
        assert!(from < self.n);
        let mut plan = Vec::with_capacity(self.n);
        if self.n == 1 {
            plan.push((from, vec![]));
            return plan;
        }
        // Each node j != from receives from exactly one parent: the
        // neighbour one hop closer to `from` along j's shorter arc
        // (clockwise on ties). Invert the parent relation into
        // forwarding lists, ordered by arc distance (= relay order).
        let mut order: Vec<usize> = (0..self.n).collect();
        order.sort_by_key(|&j| {
            let cw = self.cw_dist(from, j);
            cw.min(self.n - cw)
        });
        let mut fwd: Vec<Vec<usize>> = vec![Vec::new(); self.n];
        for &j in &order {
            if j == from {
                continue;
            }
            let cw = self.cw_dist(from, j); // hops if travelling clockwise
            let ccw = self.n - cw;
            let parent = if cw <= ccw {
                (j + self.n - 1) % self.n // came from the cw direction
            } else {
                (j + 1) % self.n // came from the ccw direction
            };
            fwd[parent].push(j);
        }
        for &h in &order {
            plan.push((h, fwd[h].clone()));
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn single_hap_degenerate() {
        let r = HapRing::new(1);
        assert_eq!(r.source(), 0);
        assert_eq!(r.sink(), 0);
        assert_eq!(r.next_hop_toward(0, 0), None);
        assert_eq!(r.relay_plan(0), vec![(0, vec![])]);
    }

    #[test]
    fn two_haps_link() {
        let r = HapRing::new(2);
        assert_eq!(r.sink(), 1);
        assert_eq!(r.next_hop_toward(0, 1), Some(1));
        assert_eq!(r.next_hop_toward(1, 0), Some(0));
    }

    #[test]
    fn sink_is_farthest() {
        for n in 1..10 {
            let r = HapRing::new(n);
            let d = |i: usize, j: usize| {
                let cw = (j + n - i) % n;
                cw.min(n - cw)
            };
            let dist_sink = d(r.source(), r.sink());
            for j in 0..n {
                assert!(d(r.source(), j) <= dist_sink);
            }
        }
    }

    #[test]
    fn swap_roles_swaps() {
        let mut r = HapRing::new(4);
        let (s0, k0) = (r.source(), r.sink());
        r.swap_roles();
        assert_eq!(r.source(), k0);
        assert_eq!(r.sink(), s0);
    }

    #[test]
    fn next_hop_reaches_target() {
        for n in 2..9 {
            let r = HapRing::new(n);
            for i in 0..n {
                for j in 0..n {
                    let mut cur = i;
                    let mut hops = 0;
                    while cur != j {
                        cur = r.next_hop_toward(cur, j).unwrap();
                        hops += 1;
                        assert!(hops <= n, "routing loop {i}->{j}");
                    }
                    assert!(hops <= n / 2 + 1, "not shortest arc: {i}->{j} took {hops}");
                }
            }
        }
    }

    #[test]
    fn relay_plan_covers_all_once() {
        for n in 1..9 {
            let r = HapRing::new(n);
            for from in 0..n {
                let plan = r.relay_plan(from);
                let nodes: HashSet<usize> = plan.iter().map(|(h, _)| *h).collect();
                assert_eq!(nodes.len(), n, "n={n} from={from}");
                // Each non-origin node receives the model exactly once.
                let mut recv_count = vec![0usize; n];
                for (_, fwd) in &plan {
                    for &t in fwd {
                        recv_count[t] += 1;
                    }
                }
                for j in 0..n {
                    if j == from {
                        assert_eq!(recv_count[j], 0, "origin must not receive");
                    } else {
                        assert_eq!(recv_count[j], 1, "n={n} from={from} node={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn relay_plan_first_entry_is_origin() {
        let r = HapRing::new(5);
        let plan = r.relay_plan(2);
        assert_eq!(plan[0].0, 2);
        assert_eq!(plan[0].1.len(), 2, "origin transmits to both neighbors");
    }
}
