//! Deterministic fault injection: link impairments, outages, and node
//! churn for resilience scenarios.
//!
//! The paper's whole argument is robustness to stragglers, so the
//! reproduction must be able to *create* stragglers. This subsystem
//! injects four failure modes into the simulated network:
//!
//! * **packet loss with retransmission** — per-transfer Bernoulli
//!   draws add ARQ retries (extra delay + extra `transfers`);
//! * **scheduled link outages** — periodic eclipse/solar-conjunction
//!   windows black out SAT↔HAP contacts and (optionally) ISL hops;
//! * **satellite churn** — dropouts and rejoins, so a training result
//!   can be lost in flight or simply never arrive;
//! * **HAP failures** — a PS node goes dark and the
//!   [`crate::topology::HapRing`] re-heals around it.
//!
//! Everything is derived from the experiment seed through
//! [`crate::util::Rng`] (never wall-clock), so the same seed reproduces
//! bit-identical impairment timelines, and a [`FaultConfig`] with all
//! intensities at zero is provably invisible: the plan never touches
//! the delay path or the RNG ([`FaultPlan::enabled`] is false).
//!
//! Integration: `coordinator::RunState` carries a [`FaultPlan`] and
//! the env routes every `site_link_delay` / `isl_hop_delay` /
//! `ihl_hop_delay` call through [`FaultPlan::transfer`], so AsyncFLEO
//! and all five baselines transparently experience the same
//! impairments. The engine is split along the sweep axis: the
//! immutable seeded timeline lives in a shareable [`FaultSchedule`],
//! the per-run counters in [`FaultPlan`]. `experiments::resilience`
//! sweeps the named [`FaultScenario`] presets across schemes and
//! intensities.

pub mod config;
pub mod plan;
pub mod schedule;

pub use config::{FaultConfig, FaultScenario};
pub use plan::{FaultPlan, FaultSchedule, FaultStats, LinkClass, LinkOutcome};
pub use schedule::{ChurnSchedule, OutageWindows};
