//! END-TO-END VALIDATION DRIVER (DESIGN.md, EXPERIMENTS.md §E2E).
//!
//! Exercises the *full* system on a real workload, proving all three
//! layers compose:
//!
//! * L1 — Pallas kernels (fused linear fwd+bwd, aggregation, distance)
//! * L2 — JAX CNN train/eval graphs, AOT-lowered to HLO text
//! * L3 — Rust constellation simulator + AsyncFLEO coordinator
//!
//! Runs AsyncFLEO-HAP on the paper constellation (40 satellites) with
//! the CNN on SynthDigits non-IID, for a multi-hour simulated horizon,
//! training through the PJRT executables, and logs the full loss /
//! accuracy curve plus wall-clock and PJRT-time accounting.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_train
//! ```
//! Accepts optional overrides: `--model cnn|mlp --horizon-hours H
//! --max-epochs N --train-samples N --test-samples N`.

use asyncfleo::cli::Args;
use asyncfleo::config::{ExperimentConfig, ModelKind, PsPlacement, SchemeKind};
use asyncfleo::coordinator::SimEnv;
use asyncfleo::data::Partition;
use asyncfleo::fl::make_strategy;
use asyncfleo::runtime::Runtime;
use asyncfleo::train::PjrtBackend;
use asyncfleo::util::fmt_hm;
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, false, &[]).map_err(anyhow::Error::msg)?;

    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    cfg.fl.model = match args.opt_or("model", "cnn") {
        "mlp" => ModelKind::Mlp,
        _ => ModelKind::Cnn,
    };
    cfg.fl.partition = Partition::NonIidPaper;
    cfg.placement = PsPlacement::HapRolla;
    cfg.data.train_samples =
        args.opt_parse::<usize>("train-samples").map_err(anyhow::Error::msg)?.unwrap_or(4000);
    cfg.data.test_samples =
        args.opt_parse::<usize>("test-samples").map_err(anyhow::Error::msg)?.unwrap_or(1000);
    cfg.fl.max_epochs =
        args.opt_parse::<u64>("max-epochs").map_err(anyhow::Error::msg)?.unwrap_or(25);
    if let Some(h) = args.opt_parse::<f64>("horizon-hours").map_err(anyhow::Error::msg)? {
        cfg.fl.horizon_s = h * 3600.0;
    }

    println!("=== AsyncFLEO end-to-end validation ===");
    println!(
        "constellation: {} orbits x {} sats @ {} km | PS: {} | model: {} | non-IID",
        cfg.constellation.n_orbits,
        cfg.constellation.sats_per_orbit,
        cfg.constellation.altitude_km,
        cfg.placement.name(),
        cfg.model_tag()
    );

    let wall0 = Instant::now();
    let runtime = Rc::new(Runtime::new(Runtime::default_dir())?);
    let mut backend = PjrtBackend::from_config(runtime.clone(), &cfg)?;
    println!(
        "PJRT: {} | artifacts compiled: {} | setup {:.1}s",
        runtime.platform(),
        runtime.compiled_count(),
        wall0.elapsed().as_secs_f64()
    );

    let run0 = Instant::now();
    let mut env = SimEnv::new(&cfg, &mut backend);
    let result = make_strategy(cfg.fl.scheme).run(&mut env);
    let wall = run0.elapsed().as_secs_f64();

    println!("\nepoch  sim-time   accuracy     loss");
    for p in &result.curve.points {
        println!(
            "{:>5}  {:>8}  {:>8.2}%  {:>7.4}",
            p.epoch,
            fmt_hm(p.time_s),
            p.accuracy * 100.0,
            p.loss
        );
    }

    println!("\n--- summary ---");
    match result.converged {
        Some((t, acc)) => println!(
            "converged: {} simulated ({} epochs) at {:.2}% plateau accuracy",
            fmt_hm(t),
            result.epochs,
            acc * 100.0
        ),
        None => println!(
            "no plateau within horizon: final {:.2}% after {} epochs",
            result.final_accuracy * 100.0,
            result.epochs
        ),
    }
    println!("model transfers (up+down+relay hops): {}", result.transfers);
    let pjrt_s = backend.total_exec_seconds();
    println!(
        "wall clock: {wall:.1}s | PJRT execute: {pjrt_s:.1}s ({:.0}% of wall)",
        100.0 * pjrt_s / wall
    );
    println!(
        "L3 coordinator overhead: {:.1}s ({:.1}%) — target: PJRT-dominated",
        wall - pjrt_s,
        100.0 * (wall - pjrt_s) / wall
    );
    Ok(())
}
