//! Circular two-body propagation: elements + time -> ECI position.

use super::elements::OrbitalElements;
use crate::util::Vec3;

/// Position of a satellite in the Earth-centered inertial frame at
/// simulated time `t` seconds.
///
/// For a circular orbit the argument of latitude advances uniformly:
/// `u(t) = phase + n * t`; the in-plane position is then rotated by the
/// inclination about X and the RAAN about Z.
pub fn satellite_position_eci(e: &OrbitalElements, t: f64) -> Vec3 {
    let u = e.phase_rad + e.mean_motion_rad_s() * t;
    let r = e.semi_major_axis_km();
    let in_plane = Vec3::new(r * u.cos(), r * u.sin(), 0.0);
    in_plane.rot_x(e.inclination_rad).rot_z(e.raan_rad)
}

/// Velocity vector in ECI, km/s (tangential for circular orbits).
pub fn satellite_velocity_eci(e: &OrbitalElements, t: f64) -> Vec3 {
    let u = e.phase_rad + e.mean_motion_rad_s() * t;
    let v = e.velocity_km_s();
    let in_plane = Vec3::new(-v * u.sin(), v * u.cos(), 0.0);
    in_plane.rot_x(e.inclination_rad).rot_z(e.raan_rad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::elements::{EARTH_RADIUS_KM, MU_EARTH};

    fn e() -> OrbitalElements {
        OrbitalElements {
            altitude_km: 2000.0,
            inclination_rad: 80f64.to_radians(),
            raan_rad: 0.7,
            phase_rad: 0.3,
        }
    }

    #[test]
    fn radius_constant_over_time() {
        let e = e();
        let r0 = e.semi_major_axis_km();
        for i in 0..50 {
            let t = i as f64 * 431.7;
            let r = satellite_position_eci(&e, t).norm();
            assert!((r - r0).abs() < 1e-6, "t={t}: r={r} vs {r0}");
        }
    }

    #[test]
    fn returns_to_start_after_one_period() {
        let e = e();
        let p0 = satellite_position_eci(&e, 0.0);
        let p1 = satellite_position_eci(&e, e.period_s());
        assert!(p0.distance(p1) < 1e-6);
    }

    #[test]
    fn half_period_is_antipodal() {
        let e = e();
        let p0 = satellite_position_eci(&e, 0.0);
        let ph = satellite_position_eci(&e, e.period_s() / 2.0);
        assert!(p0.distance(-ph) < 1e-6);
    }

    #[test]
    fn velocity_orthogonal_to_position() {
        let e = e();
        for i in 0..10 {
            let t = i as f64 * 997.0;
            let p = satellite_position_eci(&e, t);
            let v = satellite_velocity_eci(&e, t);
            assert!(p.dot(v).abs() < 1e-6);
        }
    }

    #[test]
    fn speed_matches_vis_viva() {
        let e = e();
        let v = satellite_velocity_eci(&e, 123.0).norm();
        let expect = (MU_EARTH / (EARTH_RADIUS_KM + 2000.0)).sqrt();
        assert!((v - expect).abs() < 1e-9);
    }

    #[test]
    fn inclination_bounds_z_extent() {
        let e = e();
        // |z| <= a * sin(i)
        let bound = e.semi_major_axis_km() * e.inclination_rad.sin() + 1e-6;
        for i in 0..200 {
            let p = satellite_position_eci(&e, i as f64 * 61.3);
            assert!(p.z.abs() <= bound);
        }
    }
}
