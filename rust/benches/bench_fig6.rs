//! Fig. 6 bench: accuracy-vs-time curve generation for the three
//! AsyncFLEO variants against the strongest baseline (FedHAP), on the
//! surrogate backend. Measures the coordinator cost of producing each
//! full curve and prints the regenerated series.
//!
//! Run: `cargo bench --offline --bench bench_fig6`

use asyncfleo::bench::{bench, print_header, BenchConfig};
use asyncfleo::config::{ExperimentConfig, PsPlacement, SchemeKind};
use asyncfleo::coordinator::SimEnv;
use asyncfleo::fl::make_strategy;
use asyncfleo::train::SurrogateBackend;

const SERIES: &[(&str, SchemeKind, PsPlacement)] = &[
    ("AsyncFLEO-GS", SchemeKind::AsyncFleo, PsPlacement::GsRolla),
    ("AsyncFLEO-HAP", SchemeKind::AsyncFleo, PsPlacement::HapRolla),
    ("AsyncFLEO-twoHAP", SchemeKind::AsyncFleo, PsPlacement::TwoHaps),
    ("FedHAP", SchemeKind::FedHap, PsPlacement::HapRolla),
];

fn main() {
    print_header("Fig. 6 curves (surrogate backend)");
    let bcfg = BenchConfig::endtoend();
    let mut reports = Vec::new();

    for &(label, scheme, placement) in SERIES {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.fl.scheme = scheme;
        cfg.placement = placement;
        cfg.fl.horizon_s = 48.0 * 3600.0;
        cfg.fl.max_epochs = 40;
        let run_once = || {
            let mut backend = SurrogateBackend::paper_split(5, 8, false, 100);
            let mut env = SimEnv::new(&cfg, &mut backend);
            make_strategy(scheme).run(&mut env)
        };
        let r = run_once();
        println!("\n{label}: {} curve points", r.curve.points.len());
        for p in r.curve.points.iter().step_by(3) {
            println!("  t={:>6.2}h  acc={:>6.2}%", p.time_s / 3600.0, p.accuracy * 100.0);
        }
        reports.push(bench(label, &bcfg, run_once));
    }

    print_header("wall-clock per curve");
    for r in &reports {
        println!("{}", r.report());
    }
}
