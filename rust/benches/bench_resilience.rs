//! Micro-benchmarks of the fault-injection subsystem: plan
//! construction, the per-transfer oracle (disabled vs active — the
//! disabled path must be ~free, it sits on every link-delay call), ring
//! re-healing, and event materialization.
//!
//! Run: `cargo bench --offline --bench bench_resilience`

use asyncfleo::bench::{bench, black_box, print_header, BenchConfig};
use asyncfleo::faults::{FaultConfig, FaultPlan, FaultScenario, LinkClass};
use asyncfleo::sim::EventQueue;
use asyncfleo::topology::HapRing;

const HORIZON_S: f64 = 72.0 * 3600.0;

fn plan_for(scenario: FaultScenario, intensity: f64) -> FaultPlan {
    let cfg = FaultConfig::preset(scenario, intensity);
    FaultPlan::new(&cfg, 42, 40, 2, 8, HORIZON_S)
}

fn main() {
    let cfg = BenchConfig::default();
    print_header("fault-injection subsystem");

    println!(
        "{}",
        bench("plan build: nominal (no-op)", &cfg, || {
            plan_for(FaultScenario::Nominal, 1.0)
        })
        .report()
    );
    println!(
        "{}",
        bench("plan build: churn @1.0 (40 sats, 72 h)", &cfg, || {
            plan_for(FaultScenario::Churn, 1.0)
        })
        .report()
    );

    // The oracle overhead per link call, disabled vs each scenario.
    for (name, scenario) in [
        ("transfer x1k: disabled", FaultScenario::Nominal),
        ("transfer x1k: lossy", FaultScenario::Lossy),
        ("transfer x1k: eclipse", FaultScenario::Eclipse),
        ("transfer x1k: churn", FaultScenario::Churn),
    ] {
        let mut plan = plan_for(scenario, 1.0);
        println!(
            "{}",
            bench(name, &cfg, || {
                let mut acc = 0.0;
                for i in 0..1000u64 {
                    let t = (i * 61) as f64 % HORIZON_S;
                    acc += plan
                        .transfer(
                            LinkClass::SatSite { sat: (i % 40) as usize, site: 0 },
                            t,
                            0.2,
                        )
                        .delay_s;
                }
                black_box(acc)
            })
            .report()
        );
    }

    println!(
        "{}",
        bench("hap ring: fail/heal/recover cycle (n=8)", &cfg, || {
            let mut ring = HapRing::new(8);
            for i in 0..8 {
                ring.set_alive(i % 8, false);
                black_box(ring.relay_plan(ring.source()));
                ring.set_alive(i % 8, true);
            }
            ring.sink()
        })
        .report()
    );

    let plan = plan_for(FaultScenario::Churn, 1.0);
    println!(
        "{}",
        bench("schedule_events: churn @1.0", &cfg, || {
            let mut q = EventQueue::new();
            plan.schedule_events(&mut q);
            q.len()
        })
        .report()
    );
}
