//! FedSpace (So et al. [4]): the GS schedules aggregation rounds from
//! predicted connectivity, and satellites upload a *fraction of their
//! raw data* so the GS can tune that schedule — the privacy/bandwidth
//! contradiction the paper calls out (Sec. II).
//!
//! Model implemented here:
//! * fixed aggregation cadence (the schedule FedSpace optimizes; we use
//!   its steady-state period);
//! * satellites upload trained models at contacts; the raw-data
//!   fraction inflates every upload by `DATA_OVERHEAD`×;
//! * at each tick the GS averages whatever arrived since the last tick
//!   (no staleness discounting, no grouping — stale and biased models
//!   enter at full weight), which is what caps its accuracy in the
//!   paper's Table II.

use crate::coordinator::{RunResult, SimEnv};
use crate::fl::Strategy;
use crate::metrics::ConvergenceDetector;
use crate::model::ModelParams;

/// Aggregation cadence, seconds.
const AGG_PERIOD_S: f64 = 2.0 * 3600.0;
/// Raw-image upload inflates the transfer by this factor.
const DATA_OVERHEAD: f64 = 3.0;

#[derive(Default)]
pub struct FedSpace;

impl Strategy for FedSpace {
    fn name(&self) -> &'static str {
        "fedspace"
    }

    fn run(&mut self, env: &mut SimEnv) -> RunResult {
        let n_sats = env.geo.constellation.len();
        let dispatches = env.cfg.fl.local_dispatches;
        let train_time = env.cfg.fl.train_time_s;
        let horizon = env.cfg.fl.horizon_s;
        let mut detector = ConvergenceDetector::new(10, 0.003);

        let mut global = env.state.backend.init_global(env.cfg.seed as i32);
        let e0 = env.state.backend.evaluate(&global);
        env.record(0.0, 0, e0.accuracy, e0.loss);

        // contact list as in FedSat (finite by construction: total_cmp)
        let mut visits: Vec<(f64, usize, usize)> = Vec::new();
        for sat in 0..n_sats {
            for site in 0..env.geo.sites.len() {
                for w in env.geo.plan.windows(site, sat) {
                    visits.push((w.start_s, sat, site));
                }
            }
        }
        visits.sort_by(|a, b| a.0.total_cmp(&b.0));

        let mut ready_at: Vec<Option<f64>> = vec![None; n_sats];
        // (arrival time, sat, model)
        let mut pending: Vec<(f64, usize, ModelParams)> = Vec::new();
        let mut visit_iter = visits.into_iter().peekable();
        let mut rounds: u64 = 0;
        let mut converged = false;

        // Reused per-tick buffers: the arrived/later split, the FedAvg
        // weight vectors, the aggregate double-buffer, and a free pool
        // of model buffers recycled from aggregated uploads. Only the
        // per-aggregation ref list still allocates (it borrows the
        // arrived batch). Same floats: the split preserves
        // `partition`'s relative order.
        let mut arrived: Vec<(f64, usize, ModelParams)> = Vec::new();
        let mut later: Vec<(f64, usize, ModelParams)> = Vec::new();
        let mut sizes: Vec<usize> = Vec::new();
        let mut weights: Vec<f32> = Vec::new();
        let mut next = ModelParams { data: Vec::with_capacity(global.dim()) };
        let mut pool: Vec<ModelParams> = Vec::new();

        let mut recycles: u64 = 0;
        let mut tick = AGG_PERIOD_S;
        let ph_loop = env.phase_start();
        while tick <= horizon && !converged && rounds < env.cfg.fl.max_epochs * 4 {
            // process all visits before this tick
            while let Some(&(t, sat, site)) = visit_iter.peek() {
                if t > tick {
                    break;
                }
                visit_iter.next();
                // typed churn consumption (ROADMAP PR-1 follow-up):
                // skip the pass of a dead satellite or a failed PS site
                // instead of only feeling faults through link delays;
                // both predicates are always true with faults disabled,
                // so clean runs are bit-identical
                if !env.state.faults.sat_alive(sat, t) || !env.state.faults.hap_alive(site, t)
                {
                    continue;
                }
                match ready_at[sat] {
                    None => {
                        let d = env.site_link_delay(site, sat, t);
                        ready_at[sat] = Some(t + d + train_time);
                    }
                    Some(ready) if ready <= t => {
                        let mut local = pool.pop().unwrap_or(ModelParams { data: Vec::new() });
                        env.state.backend.train_local_into(sat, &global, dispatches, &mut local);
                        // model + raw-data fraction upload
                        let d_up = env.site_link_delay(site, sat, t) * DATA_OVERHEAD;
                        pending.push((t + d_up, sat, local));
                        let d_down = env.site_link_delay(site, sat, t + d_up);
                        ready_at[sat] = Some(t + d_up + d_down + train_time);
                    }
                    Some(_) => {}
                }
            }
            // scheduled aggregation: average arrivals at full weight
            arrived.clear();
            later.clear();
            for item in pending.drain(..) {
                if item.0 <= tick {
                    arrived.push(item);
                } else {
                    later.push(item);
                }
            }
            std::mem::swap(&mut pending, &mut later);
            if !arrived.is_empty() {
                sizes.clear();
                sizes.extend(arrived.iter().map(|(_, s, _)| env.state.backend.shard_size(*s)));
                crate::train::fedavg_weights_into(&sizes, &mut weights);
                let refs: Vec<&ModelParams> = arrived.iter().map(|(_, _, m)| m).collect();
                // naive: overwrite with the partial average (no staleness
                // discount, no previous-model anchoring)
                env.state.backend.aggregate_into(&global, &refs, &weights, 0.0, &mut next);
                std::mem::swap(&mut global, &mut next);
                rounds += 1;
                if let Some(obs) = env.obs() {
                    // whatever arrived enters at full weight: no
                    // staleness discount by design
                    obs.staleness(0.0);
                    obs.aggregate(tick, 1, arrived.len(), 0.0, 1.0);
                }
                let e = env.state.backend.evaluate(&global);
                env.record(tick, rounds, e.accuracy, e.loss);
                converged = detector.update(e.accuracy) && rounds >= 12;
                // recycle the aggregated model buffers
                recycles += arrived.len() as u64;
                pool.extend(arrived.drain(..).map(|(_, _, m)| m));
            }
            tick += AGG_PERIOD_S;
        }
        env.phase_end("event_loop", ph_loop);
        if let Some(obs) = env.obs() {
            obs.metrics.add("pool_recycles", recycles);
        }
        RunResult::from_env("fedspace", env, rounds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement};
    use crate::coordinator::SimEnv;
    use crate::train::SurrogateBackend;

    #[test]
    fn runs_and_aggregates() {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = PsPlacement::GsRolla;
        cfg.fl.horizon_s = 48.0 * 3600.0;
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        let r = FedSpace.run(&mut env);
        assert!(r.epochs >= 2, "rounds {}", r.epochs);
    }

    #[test]
    fn noniid_partial_aggregation_is_slower_to_learn() {
        // FedSpace's fixed 2 h schedule + arbitrary-GS visits must not
        // reach a given accuracy level earlier than AsyncFLEO's
        // quorum-triggered epochs (the accuracy *ceiling* gap needs
        // real non-IID training and is shown by `asyncfleo exp table2`)
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = PsPlacement::GsRolla;
        cfg.fl.horizon_s = 24.0 * 3600.0;
        cfg.fl.max_epochs = 30;
        let mut b1 = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env1 = SimEnv::new(&cfg, &mut b1);
        let fs = FedSpace.run(&mut env1);
        let mut b2 = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env2 = SimEnv::new(&cfg, &mut b2);
        let af = crate::fl::asyncfleo::AsyncFleo::default().run(&mut env2);
        let t_af = af.time_to_accuracy(0.6).expect("asyncfleo reaches 60%");
        let t_fs = fs.time_to_accuracy(0.6).unwrap_or(f64::INFINITY);
        assert!(
            t_af <= t_fs + 1800.0,
            "asyncfleo to 60% in {} h vs fedspace {} h",
            t_af / 3600.0,
            t_fs / 3600.0
        );
    }
}
