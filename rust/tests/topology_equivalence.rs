//! ISL topology graph equivalence suite (the PR-6 bit-identity
//! contract):
//!
//! * with the explicit ISL graph built into every `Geometry`, all six
//!   pre-existing schemes still produce **bit-identical** curves and
//!   transfer counts against the kept pre-graph reference path
//!   (`SimEnv::set_reference_path(true)` + `testkit::ReferenceSurrogate`)
//!   on every built-in preset — the graph subsystem must not perturb
//!   the ring semantics those schemes were built on;
//! * the graph is pure *plumbing* for them: rebuilding the world with a
//!   different `[isl]` topology (grid + cross-shell instead of the ring
//!   default) leaves ring-routed schemes bit-identical, because only
//!   graph-routed schemes read the edge set;
//! * the new `sinksat` scheme is deterministic under the sweep
//!   executor: `scenarios.csv` (which now carries a SinkSat row per
//!   world) is byte-identical at `--jobs 1` and `--jobs 4`;
//! * topology properties hold on every preset: intra-plane rings plus
//!   the cross-plane grid (with cross-shell gateways where there are
//!   stacked shells) form one connected component, and every edge's
//!   delay is finite, positive, and direction-free.

use asyncfleo::comm::LinkParams;
use asyncfleo::config::{ExperimentConfig, SchemeKind};
use asyncfleo::coordinator::{RunResult, SimEnv};
use asyncfleo::experiments::drivers::ExpOptions;
use asyncfleo::experiments::scenarios::run_compare;
use asyncfleo::fl::make_strategy;
use asyncfleo::scenario::{Scenario, ScenarioRegistry};
use asyncfleo::testkit::{assert_runs_identical, ReferenceSurrogate};
use asyncfleo::topology::{IslConfig, IslGraph, IslTopology};
use asyncfleo::train::SurrogateBackend;
use std::path::PathBuf;

/// The six schemes that existed before the graph subsystem landed.
const PRE_GRAPH_SCHEMES: &[SchemeKind] = &[
    SchemeKind::AsyncFleo,
    SchemeKind::FedAvg,
    SchemeKind::FedIsl,
    SchemeKind::FedSat,
    SchemeKind::FedSpace,
    SchemeKind::FedHap,
];

/// The six presets that existed before this PR.
const EXISTING_PRESETS: &[&str] = &[
    "paper-40",
    "starlink-lite",
    "polar-star",
    "sparse-iot",
    "equatorial-dense",
    "haps-degraded",
];

/// Equivalence needs events, not convergence: shortened horizons keep
/// debug-mode runs fast while still driving every code path.
fn trimmed(cfg: &ExperimentConfig) -> ExperimentConfig {
    let mut c = cfg.clone();
    if c.n_sats() >= 1000 {
        c.fl.horizon_s = 2.0 * 3600.0;
        c.fl.max_epochs = 2;
    } else if c.n_sats() >= 100 {
        c.fl.horizon_s = 6.0 * 3600.0;
        c.fl.max_epochs = 3;
    } else {
        c.fl.horizon_s = 12.0 * 3600.0;
        c.fl.max_epochs = 4;
    }
    c
}

/// One run on the graph-bearing fast path.
fn run_fast(cfg: &ExperimentConfig) -> RunResult {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    make_strategy(cfg.fl.scheme).run(&mut env)
}

/// One run on the kept pre-graph reference path.
fn run_reference(cfg: &ExperimentConfig) -> RunResult {
    let mut b = ReferenceSurrogate(SurrogateBackend::for_config(cfg));
    let mut env = SimEnv::new(cfg, &mut b);
    env.set_reference_path(true);
    make_strategy(cfg.fl.scheme).run(&mut env)
}

#[test]
fn all_pre_graph_schemes_bitwise_equal_on_all_presets() {
    let reg = ScenarioRegistry::builtin();
    for name in EXISTING_PRESETS {
        let sc = reg.get(name).unwrap_or_else(|| panic!("missing preset {name}"));
        for &scheme in PRE_GRAPH_SCHEMES {
            let mut cfg = trimmed(&sc.cfg);
            cfg.fl.scheme = scheme;
            let fast = run_fast(&cfg);
            let reference = run_reference(&cfg);
            assert_runs_identical(&fast, &reference, &format!("{name}/{}", scheme.name()));
        }
    }
}

#[test]
fn isl_topology_choice_does_not_perturb_ring_routed_schemes() {
    // Ring-routed schemes never read the edge set, so swapping the
    // world's [isl] topology must leave them bit-identical — the graph
    // only changes behaviour for schemes that route over it.
    let reg = ScenarioRegistry::builtin();
    let sc = reg.get("starlink-lite").expect("multi-shell preset");
    for &scheme in &[SchemeKind::AsyncFleo, SchemeKind::FedIsl, SchemeKind::FedHap] {
        let mut ring_cfg = trimmed(&sc.cfg);
        ring_cfg.fl.scheme = scheme;
        let mut grid_cfg = ring_cfg.clone();
        grid_cfg.isl.topology = IslTopology::Grid;
        grid_cfg.isl.cross_shell = true;
        let a = run_fast(&ring_cfg);
        let b = run_fast(&grid_cfg);
        assert_runs_identical(&a, &b, &format!("ring-vs-grid world/{}", scheme.name()));
    }
}

fn temp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("asyncfleo_topology_equiv_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn sinksat_scenario_rows_byte_identical_jobs_1_vs_4() {
    let reg = ScenarioRegistry::builtin();
    // a representative world slice: the paper's constellation, a
    // two-shell design, and the sparse low-connectivity one
    let scenarios: Vec<Scenario> = ["paper-40", "starlink-lite", "sparse-iot"]
        .iter()
        .map(|name| {
            let sc = reg.get(name).unwrap();
            Scenario::new(sc.name.clone(), sc.summary.clone(), trimmed(&sc.cfg))
        })
        .collect();
    let dir1 = temp_out("jobs1");
    let dir4 = temp_out("jobs4");
    let opts1 = ExpOptions {
        out_dir: dir1.clone(),
        fast: true,
        surrogate: true,
        seed: 42,
        jobs: 1,
        report: false,
    };
    let opts4 = ExpOptions { out_dir: dir4.clone(), jobs: 4, ..opts1.clone() };
    run_compare(&scenarios, &opts1).expect("--jobs 1 sweep");
    run_compare(&scenarios, &opts4).expect("--jobs 4 sweep");
    let a = std::fs::read(dir1.join("scenarios.csv")).unwrap();
    let b = std::fs::read(dir4.join("scenarios.csv")).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "scenarios.csv must be byte-identical at --jobs 1 and --jobs 4");
    let text = String::from_utf8(a).unwrap();
    for sc in &scenarios {
        assert!(
            text.contains(&format!("{},sinksat", sc.name)),
            "{} sinksat row present",
            sc.name
        );
    }
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir4);
}

#[test]
fn graph_properties_hold_on_every_preset() {
    let reg = ScenarioRegistry::builtin();
    for sc in reg.iter() {
        let cfg = &sc.cfg;
        let c = asyncfleo::orbit::WalkerConstellation::from_shells(&cfg.constellation.shells());
        let link = LinkParams::default();

        // the ring reference: intra-plane edges only, each plane with
        // >= 2 members internally connected
        let ring = IslGraph::build(&c, &IslConfig::default(), &link);
        for e in ring.edges() {
            assert_eq!(
                c.satellites[e.a as usize].orbit,
                c.satellites[e.b as usize].orbit,
                "{}: ring edge crosses planes",
                sc.name
            );
        }

        // ring + grid (+ cross-shell gateways when shells stack) must
        // form one component
        let full = IslGraph::build(
            &c,
            &IslConfig {
                topology: IslTopology::Grid,
                cross_shell: true,
                ..Default::default()
            },
            &link,
        );
        assert!(full.is_connected(), "{}: grid+gateways disconnected", sc.name);

        // every edge: registered in both directions (delay is therefore
        // direction-free by construction) and finite positive delay
        let payload = 1.0e6;
        for (e, edge) in full.edges().iter().enumerate() {
            let (a, b) = (edge.a as usize, edge.b as usize);
            assert_eq!(full.edge_between(a, b), Some(e), "{}: edge {e}", sc.name);
            assert_eq!(full.edge_between(b, a), Some(e), "{}: edge {e} reversed", sc.name);
            for &t in &[0.0, 3600.0] {
                let d = full.edge_delay_s(&c, e, t, payload);
                assert!(
                    d.is_finite() && d > 0.0,
                    "{}: edge {e} delay {d} at t={t}",
                    sc.name
                );
            }
        }

        // routing over the component is symmetric up to float
        // re-association along the reversed path
        let plan_fwd = full.shortest_delays(&c, 0, 0.0, payload);
        let far = c.len() - 1;
        let plan_rev = full.shortest_delays(&c, far, 0.0, payload);
        let (df, dr) = (plan_fwd.dist[far], plan_rev.dist[0]);
        assert!(df.is_finite() && dr.is_finite(), "{}: route unreachable", sc.name);
        assert!(
            (df - dr).abs() <= 1e-9 * df.max(1.0),
            "{}: asymmetric routes {df} vs {dr}",
            sc.name
        );
    }
}
