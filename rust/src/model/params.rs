//! Flat model parameter buffers and the linear algebra the coordinator
//! needs on them.
//!
//! Every operation has an in-place variant (`*_into`,
//! [`ModelParams::reset_zeros`]) so the event-loop hot paths can reuse
//! buffers instead of allocating per call. The in-place variants
//! perform the same arithmetic in the same order as their allocating
//! counterparts — results are bit-identical, only the allocation
//! disappears.

use crate::util::Rng;

/// A model's parameters: one contiguous f32 vector whose layout is
/// defined by the AOT manifest (python/compile/model.py).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelParams {
    pub data: Vec<f32>,
}

impl ModelParams {
    pub fn zeros(dim: usize) -> Self {
        ModelParams { data: vec![0.0; dim] }
    }

    /// Random init for simulator-only runs / tests (the real runs use
    /// the AOT `init_*` artifact so L2/L3 agree on numerics).
    pub fn random(dim: usize, std: f32, rng: &mut Rng) -> Self {
        ModelParams { data: (0..dim).map(|_| rng.normal(0.0, std as f64) as f32).collect() }
    }

    pub fn dim(&self) -> usize {
        self.data.len()
    }

    /// Reset to the all-zero vector of dimension `dim`, reusing the
    /// existing allocation whenever capacity allows.
    pub fn reset_zeros(&mut self, dim: usize) {
        self.data.clear();
        self.data.resize(dim, 0.0);
    }

    /// Euclidean distance ‖self − other‖₂ (pure-Rust fallback of the
    /// `dist_*` artifact; used for grouping in simulator-only mode and
    /// to cross-check the kernel in tests).
    pub fn l2_distance(&self, other: &ModelParams) -> f64 {
        assert_eq!(self.dim(), other.dim());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|a| (*a as f64) * (*a as f64)).sum::<f64>().sqrt()
    }

    /// self += k * other.
    pub fn axpy(&mut self, k: f32, other: &ModelParams) {
        assert_eq!(self.dim(), other.dim());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += k * b;
        }
    }

    /// self *= k.
    pub fn scale(&mut self, k: f32) {
        for a in self.data.iter_mut() {
            *a *= k;
        }
    }

    /// Weighted sum Σ wᵢ·modelsᵢ (pure-Rust fallback of the `agg_*`
    /// artifact — Eq. 14 with coeffs computed by the caller).
    pub fn weighted_sum(models: &[&ModelParams], weights: &[f32]) -> ModelParams {
        let mut out = ModelParams { data: Vec::new() };
        Self::weighted_sum_into(models, weights, &mut out);
        out
    }

    /// In-place [`Self::weighted_sum`]: writes Σ wᵢ·modelsᵢ into `out`,
    /// reusing its allocation. Same zero-init + axpy sequence as the
    /// allocating version, so the floats are bit-identical.
    pub fn weighted_sum_into(models: &[&ModelParams], weights: &[f32], out: &mut ModelParams) {
        assert_eq!(models.len(), weights.len());
        assert!(!models.is_empty());
        out.reset_zeros(models[0].dim());
        for (m, &w) in models.iter().zip(weights) {
            out.axpy(w, m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_dim() {
        let p = ModelParams::zeros(10);
        assert_eq!(p.dim(), 10);
        assert_eq!(p.l2_norm(), 0.0);
    }

    #[test]
    fn distance_triangle_symmetric() {
        let mut rng = Rng::new(0);
        let a = ModelParams::random(100, 1.0, &mut rng);
        let b = ModelParams::random(100, 1.0, &mut rng);
        let c = ModelParams::random(100, 1.0, &mut rng);
        assert!((a.l2_distance(&b) - b.l2_distance(&a)).abs() < 1e-9);
        assert!(a.l2_distance(&c) <= a.l2_distance(&b) + b.l2_distance(&c) + 1e-9);
        assert_eq!(a.l2_distance(&a), 0.0);
    }

    #[test]
    fn axpy_scale() {
        let mut a = ModelParams { data: vec![1.0, 2.0] };
        let b = ModelParams { data: vec![10.0, 20.0] };
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.data, vec![12.0, 24.0]);
    }

    #[test]
    fn weighted_sum_is_convex_mean_for_uniform() {
        let a = ModelParams { data: vec![1.0, 3.0] };
        let b = ModelParams { data: vec![3.0, 5.0] };
        let m = ModelParams::weighted_sum(&[&a, &b], &[0.5, 0.5]);
        assert_eq!(m.data, vec![2.0, 4.0]);
    }

    #[test]
    fn weighted_sum_identity() {
        let a = ModelParams { data: vec![1.0, 3.0] };
        let b = ModelParams { data: vec![9.0, 9.0] };
        let m = ModelParams::weighted_sum(&[&a, &b], &[1.0, 0.0]);
        assert_eq!(m.data, a.data);
    }

    #[test]
    #[should_panic]
    fn mismatched_dims_panic() {
        let a = ModelParams::zeros(3);
        let b = ModelParams::zeros(4);
        a.l2_distance(&b);
    }

    #[test]
    fn weighted_sum_into_matches_allocating_bitwise() {
        let mut rng = Rng::new(7);
        let models: Vec<ModelParams> =
            (0..5).map(|_| ModelParams::random(33, 1.0, &mut rng)).collect();
        let refs: Vec<&ModelParams> = models.iter().collect();
        let ws: Vec<f32> = (0..5).map(|i| 0.1 + 0.07 * i as f32).collect();
        let alloc = ModelParams::weighted_sum(&refs, &ws);
        // reused buffer starts dirty and over-sized on purpose
        let mut out = ModelParams::zeros(100);
        out.data[0] = 42.0;
        ModelParams::weighted_sum_into(&refs, &ws, &mut out);
        assert_eq!(out.dim(), 33);
        for (a, b) in alloc.data.iter().zip(&out.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn reset_zeros_reuses_allocation() {
        let mut p = ModelParams { data: vec![3.0; 8] };
        let cap = p.data.capacity();
        p.reset_zeros(5);
        assert_eq!(p.dim(), 5);
        assert!(p.data.capacity() >= cap, "reset must not shrink capacity");
        assert!(p.data.iter().all(|&v| v == 0.0));
    }
}
