//! The typed-event trace sink: JSONL output with a hand-rolled,
//! serde-free writer (crates.io is unreachable; see DESIGN.md §1).
//!
//! Every record is one flat JSON object per line with an `"ev"` tag
//! (`model_tx`, `aggregate`, `eval`, …— the full schema is documented
//! in [`super`]'s module docs and ROADMAP.md). Records carry only
//! *simulated*-time data — never wall-clock readings — so two traced
//! runs of the same seed produce byte-identical JSONL
//! (`tests/obs_equivalence.rs` pins that). Wall-clock phase timings go
//! to `report.json` instead (see [`super::phase`]).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Where trace lines go. `Disabled` is the no-op variant carried by
/// metrics-only observation (the scenario driver's `report.json` path):
/// emission helpers skip record formatting entirely when the sink is
/// disabled, so the only cost left is the metrics fold.
pub enum TraceSink {
    /// Drop every record (metrics-only observation).
    Disabled,
    /// Collect lines in memory (tests, `summarize_trace` inputs).
    Memory(Vec<String>),
    /// Stream lines to a JSONL file (`asyncfleo trace --out PATH`).
    File(BufWriter<File>),
}

impl TraceSink {
    /// Open a file sink, creating parent directories as needed.
    pub fn file(path: &Path) -> std::io::Result<TraceSink> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        Ok(TraceSink::File(BufWriter::new(File::create(path)?)))
    }

    /// Does this sink record anything? Emission helpers check this
    /// before formatting a record, so `Disabled` pays no allocation.
    #[inline]
    pub fn enabled(&self) -> bool {
        !matches!(self, TraceSink::Disabled)
    }

    /// Append one record line (without trailing newline).
    pub fn write_line(&mut self, line: &str) {
        match self {
            TraceSink::Disabled => {}
            TraceSink::Memory(lines) => lines.push(line.to_string()),
            TraceSink::File(w) => {
                // trace output is best-effort diagnostics: an I/O error
                // must never abort (or perturb) the run it observes
                let _ = w.write_all(line.as_bytes());
                let _ = w.write_all(b"\n");
            }
        }
    }

    /// The collected lines of a `Memory` sink (empty otherwise).
    pub fn lines(&self) -> &[String] {
        match self {
            TraceSink::Memory(lines) => lines,
            _ => &[],
        }
    }

    /// Flush buffered file output (no-op for the other variants).
    pub fn flush(&mut self) {
        if let TraceSink::File(w) = self {
            let _ = w.flush();
        }
    }
}

/// JSON string escaping (quotes, backslashes, control characters).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A JSON number literal for `x` (`null` for non-finite values, which
/// JSON cannot represent). Rust's shortest-roundtrip `Display` is
/// deterministic, so identical values always serialize identically.
pub(crate) fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing() {
        let mut s = TraceSink::Disabled;
        assert!(!s.enabled());
        s.write_line("{\"ev\":\"x\"}");
        assert!(s.lines().is_empty());
    }

    #[test]
    fn memory_sink_collects_lines_in_order() {
        let mut s = TraceSink::Memory(Vec::new());
        assert!(s.enabled());
        s.write_line("a");
        s.write_line("b");
        assert_eq!(s.lines(), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn file_sink_writes_jsonl() {
        let path = std::env::temp_dir().join("asyncfleo_obs_trace_sink_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut s = TraceSink::file(&path).unwrap();
        s.write_line("{\"ev\":\"meta\"}");
        s.write_line("{\"ev\":\"eval\"}");
        s.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "{\"ev\":\"meta\"}\n{\"ev\":\"eval\"}\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn escaping_and_numbers() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(jnum(1.5), "1.5");
        assert_eq!(jnum(259200.0), "259200");
        assert_eq!(jnum(f64::NAN), "null");
        assert_eq!(jnum(f64::INFINITY), "null");
    }
}
