//! Fig. 7 bench: AsyncFLEO setting grid on the digits geometry —
//! IID vs non-IID x GS/HAP/two-HAP placements, surrogate backend.
//! Measures per-cell coordinator cost and prints the regenerated
//! convergence summaries (the PJRT CNN/MLP split is exercised by
//! `asyncfleo exp fig7a..c`).
//!
//! Run: `cargo bench --offline --bench bench_fig7`

use asyncfleo::bench::{bench, print_header, BenchConfig};
use asyncfleo::config::{ExperimentConfig, PsPlacement, SchemeKind};
use asyncfleo::coordinator::SimEnv;
use asyncfleo::fl::make_strategy;
use asyncfleo::train::SurrogateBackend;
use asyncfleo::util::fmt_hm;

fn main() {
    print_header("Fig. 7 grid (surrogate backend)");
    let bcfg = BenchConfig::endtoend();
    let mut reports = Vec::new();

    println!("\n{:<28} {:>9} {:>12} {:>7}", "cell", "acc(%)", "conv(h:mm)", "epochs");
    for iid in [true, false] {
        for placement in [PsPlacement::GsRolla, PsPlacement::HapRolla, PsPlacement::TwoHaps] {
            let mut cfg = ExperimentConfig::paper_defaults();
            cfg.fl.scheme = SchemeKind::AsyncFleo;
            cfg.placement = placement;
            cfg.fl.horizon_s = 48.0 * 3600.0;
            cfg.fl.max_epochs = 40;
            let label = format!(
                "{}/{}",
                if iid { "iid" } else { "non-iid" },
                placement.name()
            );
            let run_once = || {
                let mut backend = SurrogateBackend::paper_split(5, 8, iid, 100);
                let mut env = SimEnv::new(&cfg, &mut backend);
                make_strategy(SchemeKind::AsyncFleo).run(&mut env)
            };
            let r = run_once();
            let (conv_t, acc) = match r.converged {
                Some((t, a)) => (t, a),
                None => (cfg.fl.horizon_s, r.final_accuracy),
            };
            println!(
                "{:<28} {:>9.2} {:>12} {:>7}",
                label,
                acc * 100.0,
                fmt_hm(conv_t),
                r.epochs
            );
            reports.push(bench(&label, &bcfg, run_once));
        }
    }

    print_header("wall-clock per cell");
    for r in &reports {
        println!("{}", r.report());
    }
}
