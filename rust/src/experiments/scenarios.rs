//! E10: the scenario comparison sweep — scheme × scenario grids over
//! the declarative [`crate::scenario`] catalog.
//!
//! For every selected [`Scenario`] (a complete experiment world:
//! multi-shell constellation, site layout, data distribution, optional
//! faults) the driver runs AsyncFLEO plus one synchronous (FedHAP)
//! baseline, one asynchronous (FedSat) baseline, and the sink-satellite
//! scheme (SinkSat, routed over the ISL topology graph) *in that
//! world* — same geometry, same seeds, same impairments — and
//! tabulates accuracy, convergence
//! and communication cost into `results/scenarios.csv`. This is the
//! cross-design generalization probe: the paper's claims are about
//! contact-pattern statistics, and every scenario has different ones.
//!
//! The grid runs through the deterministic streaming executor: rows
//! land in cell order at any `--jobs N` (byte-identical output), and
//! each scenario's geometry is built exactly once per process via the
//! shared `Geometry` cache (keyed by the scenario's shell list + site
//! layout).

use super::drivers::{summary_of, ExpOptions};
use super::executor::{run_cells_streaming, Cell};
use crate::config::{ModelKind, SchemeKind};
use crate::metrics::csv::{f, i, s, CsvWriter};
use crate::scenario::Scenario;
use crate::util::fmt_hm;
use anyhow::Result;

/// Schemes compared in every scenario: ours plus one synchronous
/// baseline, one asynchronous baseline, and the sink-satellite
/// follow-up scheme routed over the ISL graph. All run at the
/// *scenario's* placement — the world is the variable under test, not
/// the sink layout.
pub const SCENARIO_SCHEMES: &[(&str, SchemeKind)] = &[
    ("AsyncFLEO", SchemeKind::AsyncFleo),
    ("FedHAP", SchemeKind::FedHap),
    ("FedSat", SchemeKind::FedSat),
    ("SinkSat", SchemeKind::SinkSat),
];

/// Accuracy level for the stopping-rule-independent speed column.
const TARGET_ACC: f64 = 0.70;

/// The scheme×scenario grid as executor cells, in CSV row order.
pub fn compare_cells(scenarios: &[Scenario], opts: &ExpOptions) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(scenarios.len() * SCENARIO_SCHEMES.len());
    for sc in scenarios {
        for &(label, scheme) in SCENARIO_SCHEMES {
            // the scenario's own seed is part of the world definition;
            // an explicit CLI --seed is applied by the caller before
            // the grid is built (cmd_scenario), never silently here
            let mut cfg = sc.cfg.clone();
            cfg.fl.scheme = scheme;
            // coordinator dynamics are the object of study: MLP keeps
            // compute cheap without changing visit/staleness behaviour
            cfg.fl.model = ModelKind::Mlp;
            if opts.fast {
                cfg.fl.horizon_s = cfg.fl.horizon_s.min(24.0 * 3600.0);
                cfg.fl.max_epochs = cfg.fl.max_epochs.min(20);
                cfg.data.train_samples =
                    cfg.data.train_samples.min(2000.max(4 * cfg.n_sats()));
                cfg.data.test_samples = cfg.data.test_samples.min(500);
            }
            cells.push(Cell::new(format!("{}/{label}", sc.name), cfg));
        }
    }
    cells
}

/// Run the comparison grid, writing `results/scenarios.csv`.
pub fn run_compare(scenarios: &[Scenario], opts: &ExpOptions) -> Result<()> {
    let mut header = vec!["scenarios: scheme x scenario comparison grid".to_string()];
    for sc in scenarios {
        header.push(format!(
            "  {} -- {} ({}, {})",
            sc.name,
            sc.summary,
            sc.cfg.constellation.summary(),
            sc.cfg.placement.name()
        ));
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut w = CsvWriter::create(
        opts.out_dir.join("scenarios.csv"),
        &header_refs,
        &[
            "scenario",
            "scheme",
            "placement",
            "sats",
            "shells",
            "accuracy_pct",
            "convergence_h",
            "convergence_hm",
            "t70_h",
            "epochs",
            "transfers",
        ],
    )?
    .autoflush(true);

    let cells = compare_cells(scenarios, opts);
    println!(
        "\n=== scenarios ({} worlds x {} schemes) ===",
        scenarios.len(),
        SCENARIO_SCHEMES.len()
    );
    println!(
        "{:<18} {:<10} {:>5} {:>8} {:>10} {:>8} {:>7}",
        "scenario", "scheme", "sats", "acc(%)", "conv(h:mm)", "t70(h)", "epochs"
    );
    // --report: cells run with metrics-only observation attached (see
    // ExpOptions::report); their snapshots stream out with the rows in
    // cell order, so report.json is deterministic at any --jobs N
    let mut reports: Vec<(String, Box<crate::obs::ObsReport>)> = Vec::new();
    run_cells_streaming(&cells, opts, |idx, r| {
        if let Some(rep) = &r.obs {
            reports.push((cells[idx].label.clone(), rep.clone()));
        }
        let sc = &scenarios[idx / SCENARIO_SCHEMES.len()];
        let (label, scheme) = SCENARIO_SCHEMES[idx % SCENARIO_SCHEMES.len()];
        let cfg = &cells[idx].cfg;
        let (conv_t, acc) = summary_of(r);
        let t70 = r.time_to_accuracy(TARGET_ACC);
        w.row(&[
            s(&sc.name),
            s(scheme.name()),
            s(cfg.placement.name()),
            i(cfg.n_sats() as u64),
            i(cfg.constellation.shells().len() as u64),
            f(acc * 100.0),
            f(conv_t / 3600.0),
            s(&fmt_hm(conv_t)),
            t70.map(|t| f(t / 3600.0)).unwrap_or_else(|| "inf".to_string()),
            i(r.epochs),
            i(r.transfers),
        ])?;
        println!(
            "{:<18} {:<10} {:>5} {:>8.2} {:>10} {:>8} {:>7}",
            sc.name,
            label,
            cfg.n_sats(),
            acc * 100.0,
            fmt_hm(conv_t),
            t70.map(|t| format!("{:.1}", t / 3600.0)).unwrap_or_else(|| "-".to_string()),
            r.epochs
        );
        Ok(())
    })?;
    w.flush()?;
    if opts.report {
        let path = opts.out_dir.join("report.json");
        write_report_json(&path, &reports)?;
        println!("report: {}", path.display());
    }
    Ok(())
}

/// Fold the per-cell observation snapshots into one `report.json`:
/// a `"runs"` object keyed by cell label, plus the process-wide
/// substrate phases (geometry build, contact scan, pass-map
/// memoization — wall-clock, so explicitly non-deterministic).
fn write_report_json(
    path: &std::path::Path,
    reports: &[(String, Box<crate::obs::ObsReport>)],
) -> Result<()> {
    use crate::obs::trace::{jnum, json_escape};
    let mut out = String::from("{\n  \"runs\": {\n");
    let runs: Vec<String> = reports
        .iter()
        .map(|(label, rep)| format!("    \"{}\": {}", json_escape(label), rep.to_json("    ")))
        .collect();
    out.push_str(&runs.join(",\n"));
    out.push_str("\n  },\n  \"substrate_phases\": [\n");
    let phases: Vec<String> = crate::obs::global_phases()
        .into_iter()
        .map(|(n, s, c)| {
            format!(
                "    {{\"name\": \"{}\", \"secs\": {}, \"count\": {c}}}",
                json_escape(n),
                jnum(s)
            )
        })
        .collect();
    out.push_str(&phases.join(",\n"));
    out.push_str("\n  ]\n}\n");
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ScenarioRegistry;

    #[test]
    fn grid_covers_every_scenario_and_scheme() {
        let reg = ScenarioRegistry::builtin();
        let scenarios: Vec<Scenario> = reg.iter().cloned().collect();
        let opts = ExpOptions { surrogate: true, fast: true, ..Default::default() };
        let cells = compare_cells(&scenarios, &opts);
        assert_eq!(cells.len(), scenarios.len() * SCENARIO_SCHEMES.len());
        assert!(cells.iter().any(|c| c.label == "starlink-lite/FedHAP"));
        // schemes within one scenario share its geometry key inputs
        for group in cells.chunks(SCENARIO_SCHEMES.len()) {
            for c in &group[1..] {
                assert_eq!(c.cfg.constellation, group[0].cfg.constellation);
                assert_eq!(c.cfg.placement, group[0].cfg.placement);
                assert_eq!(c.cfg.fl.horizon_s, group[0].cfg.fl.horizon_s);
            }
        }
    }

    #[test]
    fn ours_plus_sync_and_async_baselines() {
        assert!(SCENARIO_SCHEMES.len() >= 2);
        assert!(SCENARIO_SCHEMES.iter().any(|&(_, s)| s == SchemeKind::AsyncFleo));
        assert!(SCENARIO_SCHEMES.iter().any(|&(_, s)| s == SchemeKind::FedHap));
        assert!(SCENARIO_SCHEMES.iter().any(|&(_, s)| s == SchemeKind::SinkSat));
    }
}
