//! # AsyncFLEO — asynchronous federated learning for LEO constellations
//!
//! Reproduction of *"AsyncFLEO: Asynchronous Federated Learning for LEO
//! Satellite Constellations with High-Altitude Platforms"*
//! (Elmahallawy & Luo, 2022) as a three-layer Rust + JAX + Pallas system.
//!
//! This crate is **Layer 3**: the coordination contribution of the paper
//! plus every substrate it depends on —
//!
//! * [`orbit`] — Keplerian constellation propagation, multi-shell
//!   Walker builder (delta and star patterns, per-shell altitude /
//!   inclination / planes / phasing with globally unique satellite
//!   ids), ground/HAP sites, visibility and contact windows. Positions
//!   evaluate through precomputed per-satellite `PlaneBasis` / per-site
//!   `SitePropagator` values (time-independent trigonometry hoisted to
//!   construction, bit-identical to the original rotation-chain
//!   formulas — pinned by bitwise tests);
//! * [`comm`] — the paper's RF link model (Eqs. 5–9): FSPL, SNR,
//!   Shannon rate, delay composition;
//! * [`topology`] — the ring-of-stars SAT↔HAP topology (Sec. IV-A)
//!   plus the explicit ISL graph (PR 6): satellites as nodes, typed
//!   edges (intra-plane ring / cross-plane grid / cross-shell
//!   gateways) carrying per-shell `LinkParams`, per-edge delays from
//!   the actual geometry with Doppler-derated rates
//!   (`orbit::doppler` in the hot path), and deterministic
//!   shortest-delay routing. The `Ring` edge set is the executable
//!   reference — it reproduces `ring_neighbors` exactly, so every
//!   pre-graph scheme keeps its semantics
//!   (`tests/topology_equivalence.rs` pins all six bitwise against
//!   the kept reference path on every preset;
//!   `BENCH_topology.json` tracks build/route throughput);
//! * [`sim`] — a discrete-event simulation engine (the "event loop").
//!   Since PR 9 it carries the **deterministic multi-lane event core**
//!   (`sim::lanes`): events shard across per-lane heaps by natural
//!   independence domain (satellite events by orbital plane, HAP/site
//!   events by id, barrier events in lane 0) while one *global*
//!   sequence counter is stamped at push, and popping takes the k-way
//!   minimum over lane heads keyed `(time, seq)` — provably the exact
//!   pop order of a single queue, for any lane count. The determinism
//!   contract: lanes never parallelize *effects*; between pops, lane
//!   threads run *pure probes* (`coordinator::LaneProbe` over the
//!   immutable geometry + fault schedule — broadcast receive times,
//!   uplink routes, sync-round contact scans, sinksat collection hop
//!   chains) and the run loop *replays* each probed outcome serially
//!   in pop order, so delays, transfer counts, fault stats and obs
//!   traces are bit-identical at `lanes=N` for every N
//!   (`RunOptions { lanes: 1 }` is op-for-op the historical path;
//!   `tests/runloop_equivalence.rs` and `tests/obs_equivalence.rs`
//!   pin curves, transfers, CSVs and JSONL traces across lane counts,
//!   and `BENCH_runloop.json` tracks the lanes speedup);
//! * [`data`] — synthetic class-structured datasets + IID / paper
//!   non-IID partitioning (MNIST/CIFAR stand-ins, DESIGN.md §1);
//! * [`model`] — flat `f32` parameter buffers and satellite metadata;
//! * [`runtime`] — the PJRT bridge: loads the AOT HLO artifacts emitted
//!   by `python/compile/aot.py` and executes them (L2/L1 compute);
//! * [`train`] — per-satellite local training / evaluation on top of
//!   [`runtime`]. The `Backend` trait carries in-place variants
//!   (`train_local_into` / `aggregate_into` / `distances_into`) the
//!   strategies call with per-run reusable buffers, so the event-loop's
//!   model steps are allocation-free on the surrogate (bit-identical to
//!   the allocating calls; `testkit::ReferenceSurrogate` keeps the old
//!   plumbing executable as the reference);
//! * [`fl`] — the FL strategies: AsyncFLEO (grouping, staleness
//!   discounting, model propagation — Algorithms 1 & 2), the five
//!   baselines (FedAvg, FedISL, FedSat, FedSpace, FedHAP), and the
//!   authors' follow-up sink-satellite scheme (`sinksat`,
//!   arXiv 2302.13447): one scheduled sink per orbital plane collects
//!   the plane's models over the ISL graph and uploads at its
//!   earliest PS visibility;
//! * [`faults`] — deterministic fault injection: packet loss with
//!   retransmission, eclipse outage windows, typed per-ISL-edge
//!   outage windows (per-edge deterministic phases), satellite churn
//!   and HAP failures, applied transparently to every strategy
//!   through the env's link-delay calls — and consumed as *typed
//!   events* by every scheme (a dead satellite or failed PS site
//!   skips the pass); split into an immutable shareable
//!   `FaultSchedule` and per-run `FaultPlan` counters;
//! * [`coordinator`] — the orchestrator that drives everything. Split
//!   along the sweep axis: `coordinator::Geometry` holds everything
//!   immutable across runs (constellation, sites, contact plan, link
//!   params) behind a process-wide `Arc` cache keyed by the
//!   geometry-relevant config subset, `coordinator::env::RunState`
//!   holds what a single run mutates (backend, RNG, curve, transfer
//!   counter, fault counters), and `SimEnv` is the thin facade the
//!   strategies program against. The `ContactPlan` inside a geometry
//!   is built by the fast scanner (`coordinator::contact`): time-major
//!   position sharing, a provable elevation-rate bound that skips whole
//!   grid intervals, an analytic pass-gap predictor
//!   (`coordinator::analytic`, PR 7: the closed-form `γ(t) = γ_max`
//!   condition bucketed over the (phase, Δ-longitude) torus, memoized
//!   process-wide per (shell, site-latitude-band) so same-shell
//!   satellites and same-latitude sites share one map), chunked
//!   materialization into a flat window arena indexed by (site, sat),
//!   and per-satellite rows fanned across a scoped thread pool —
//!   bit-identical to the kept-as-reference naive sweep at any thread
//!   count (`tests/contact_equivalence.rs` asserts it on every preset,
//!   analytic layer on and off; `BENCH_geometry.json` tracks the
//!   speedup and peak memory up to the 10,440-satellite preset). The
//!   *run loop* on top of it has the same two-tier design (PR 5):
//!   every `SimEnv` delay call evaluates through the geometry's cached
//!   per-site `SitePropagator`s / per-satellite `PlaneBasis` values
//!   plus run-constant payload/transmission terms hoisted onto
//!   `RunState` — pure cached-trig multiply-adds, op-for-op the
//!   original formulas, with the pre-cache path kept runnable behind
//!   `SimEnv::set_reference_path` (`tests/runloop_equivalence.rs`
//!   asserts bit-identical curves and transfer counts on every preset;
//!   `BENCH_runloop.json` tracks delay-call throughput and per-scheme
//!   run speedups);
//! * [`obs`] — structured run observability (PR 8): a typed event
//!   trace (JSONL via a hand-rolled serde-free writer), a metrics
//!   registry (counters, fixed-bucket histograms, per-link loads) and
//!   scoped phase profiling, carried as an `Option` by the run state
//!   and threaded through every scheme, the faults engine and the
//!   event loop. Strictly observe-only: tracing on vs. off produces
//!   bit-identical curves, transfers and CSVs
//!   (`tests/obs_equivalence.rs`), and same-seed traces are
//!   byte-identical. `asyncfleo trace` writes one instrumented run's
//!   `trace.jsonl` + `report.json`; `asyncfleo report` renders the
//!   staleness histogram, top links by utilization and time-in-phase
//!   table;
//! * [`scenario`] — declarative experiment worlds: a named preset or a
//!   TOML file (with `[shellN]` sections for multi-shell
//!   constellations and `[isl]` / `[isl_linkN]` sections for the ISL
//!   graph topology and per-shell link budgets) becomes a complete,
//!   reproducible
//!   `ExperimentConfig`; the built-in `ScenarioRegistry` catalogs ≥8
//!   presets (paper-40, starlink-lite, polar-star, sparse-iot,
//!   equatorial-dense, haps-degraded, the 1584-satellite
//!   starlink-phase1 stress shell, and the 10,440-satellite four-shell
//!   starlink-gen2 world — see the module docs for how to add one)
//!   behind `asyncfleo scenario`;
//! * [`experiments`] — drivers regenerating every paper table & figure,
//!   plus the `resilience` sweep comparing graceful degradation across
//!   schemes under the fault scenarios and the `scenarios` sweep
//!   comparing schemes across the scenario catalog. Every driver
//!   describes its grid as `experiments::executor::Cell`s and runs
//!   them through the deterministic streaming executor (`--jobs N`,
//!   surrogate mode): cells fan out longest-first to
//!   `std::thread::scope` workers sharing the cached `Geometry`, the
//!   per-result callback consumes the ordered prefix as it completes
//!   (CSV rows stream to disk; a late error keeps finished work), and
//!   output CSVs are byte-identical to a sequential run;
//! * [`config`], [`cli`], [`metrics`], [`bench`], [`testkit`],
//!   [`util`] — supporting substrates built from scratch (crates.io is
//!   unreachable; see DESIGN.md §1).
//!
//! Python never runs at this layer: `make artifacts` AOT-compiles the
//! JAX/Pallas compute once, and the `asyncfleo` binary is self-contained
//! afterwards.

pub mod bench;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod faults;
pub mod fl;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod orbit;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod testkit;
pub mod topology;
pub mod train;
pub mod util;
