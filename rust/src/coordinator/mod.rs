//! The L3 orchestrator: wires constellation geometry, contact plans,
//! link delays, the event queue and a compute [`crate::train::Backend`]
//! into a [`SimEnv`] that FL strategies run against.

pub mod contact;
pub mod env;

pub use contact::ContactPlan;
pub use env::{RunResult, SimEnv};
