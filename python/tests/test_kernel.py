"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against the
oracle is the CORE correctness signal for everything the Rust runtime
will later execute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import aggregate, distance, linear, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ----------------------------------------------------------------------
# fused_linear
# ----------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 96),
    n=st.integers(1, 40),
    bm=st.sampled_from([8, 32, 64]),
    bn=st.sampled_from([8, 16, 128]),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, bm, bn, act, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), np.float32)
    w = _rand(rng, (k, n), np.float32)
    b = _rand(rng, (n,), np.float32)
    got = linear.fused_linear(x, w, b, act, bm=bm, bn=bn)
    want = ref.fused_linear_ref(x, w, b, act)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    m=st.integers(2, 33),
    k=st.integers(2, 48),
    n=st.integers(2, 24),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_grads_match_ref(m, k, n, act, seed):
    """The custom VJP (backward also via Pallas) must match jnp autodiff."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), np.float32)
    w = _rand(rng, (k, n), np.float32)
    b = _rand(rng, (n,), np.float32)

    def f_kernel(x, w, b):
        return jnp.sum(linear.fused_linear(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.fused_linear_ref(x, w, b, act) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-4)


def test_fused_linear_rejects_bad_activation():
    x = jnp.zeros((2, 2))
    w = jnp.zeros((2, 2))
    b = jnp.zeros((2,))
    with pytest.raises(ValueError):
        linear.fused_linear(x, w, b, "gelu")


def test_fused_linear_relu_clamps():
    x = -jnp.ones((4, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    out = linear.fused_linear(x, w, b, "relu")
    assert float(jnp.max(out)) == 0.0


def test_vmem_estimate_within_budget():
    # DESIGN.md perf target: one grid step's working set far below 16 MiB.
    assert linear.vmem_bytes(320, 3136, 128) < 4 * 2**20
    assert aggregate.vmem_bytes(41) < 1 * 2**20
    assert distance.vmem_bytes(40) < 1 * 2**20


# ----------------------------------------------------------------------
# aggregate (Eq. 14)
# ----------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n1=st.integers(1, 41),
    d=st.integers(1, 5000),
    tile=st.sampled_from([64, 512, 2048]),
    seed=st.integers(0, 2**31 - 1),
)
def test_aggregate_matches_ref(n1, d, tile, seed):
    rng = np.random.default_rng(seed)
    m = _rand(rng, (n1, d), np.float32)
    c = _rand(rng, (n1,), np.float32)
    got = aggregate.aggregate(m, c, tile_d=tile)
    want = ref.aggregate_ref(m, c)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_aggregate_identity_coeffs():
    """coeffs = e_0 returns the previous global model exactly."""
    rng = np.random.default_rng(0)
    m = _rand(rng, (5, 1000), np.float32)
    c = jnp.zeros((5,), jnp.float32).at[0].set(1.0)
    got = aggregate.aggregate(m, c)
    assert_allclose(np.asarray(got), np.asarray(m[0]), rtol=1e-6)


def test_aggregate_convex_mean():
    """Uniform coeffs over identical models is a fixpoint."""
    row = np.arange(700, dtype=np.float32)
    m = jnp.asarray(np.tile(row, (4, 1)))
    c = jnp.full((4,), 0.25, jnp.float32)
    got = aggregate.aggregate(m, c)
    assert_allclose(np.asarray(got), row, rtol=1e-5)


# ----------------------------------------------------------------------
# distance (Sec. IV-C1 grouping metric)
# ----------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 40),
    d=st.integers(1, 5000),
    tile=st.sampled_from([64, 512, 2048]),
    seed=st.integers(0, 2**31 - 1),
)
def test_distance_matches_ref(n, d, tile, seed):
    rng = np.random.default_rng(seed)
    m = _rand(rng, (n, d), np.float32)
    r = _rand(rng, (d,), np.float32)
    got = distance.distance(m, r, tile_d=tile)
    want = ref.distance_ref(m, r)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_distance_zero_for_identical():
    m = jnp.ones((3, 4096), jnp.float32)
    r = jnp.ones((4096,), jnp.float32)
    got = distance.distance(m, r)
    assert_allclose(np.asarray(got), np.zeros(3), atol=1e-6)


def test_distance_scale_invariance_relation():
    """||2w - 0|| = 2 ||w - 0||."""
    rng = np.random.default_rng(3)
    w = _rand(rng, (1, 3000), np.float32)
    r = jnp.zeros((3000,), jnp.float32)
    d1 = distance.distance(w, r)
    d2 = distance.distance(2 * w, r)
    assert_allclose(np.asarray(d2), 2 * np.asarray(d1), rtol=1e-5)
