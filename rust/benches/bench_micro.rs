//! Micro-benchmarks of the L3 substrates and the PJRT artifact hot
//! paths (the per-operation costs every experiment is built from).
//!
//! Run: `cargo bench --offline --bench bench_micro`
//!
//! The geometry section (reference vs fast contact scanner per
//! scenario preset, 1 vs 4 threads) emits `BENCH_geometry.json` so the
//! perf trajectory of `ContactPlan::build` is tracked across PRs. Run
//! just that section (CI does, on the cheap presets) with
//! `cargo bench --offline --bench bench_micro -- geometry
//! --presets paper-40,sparse-iot`.

use asyncfleo::bench::{bench, black_box, print_header, BenchConfig};
use asyncfleo::coordinator::ContactPlan;
use asyncfleo::fl::aggregation::{select_and_weigh, Candidate};
use asyncfleo::model::{ModelMetadata, ModelParams};
use asyncfleo::orbit::{GeodeticSite, WalkerConstellation};
use asyncfleo::runtime::executor::Input;
use asyncfleo::runtime::Runtime;
use asyncfleo::scenario::ScenarioRegistry;
use asyncfleo::sim::{Event, EventKind, EventQueue};
use asyncfleo::util::Rng;
use std::io::Write;
use std::rc::Rc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let presets: Vec<String> = match args.iter().position(|a| a == "--presets") {
        Some(i) => {
            let value = args
                .get(i + 1)
                .unwrap_or_else(|| panic!("--presets needs a comma-separated preset list"));
            value.split(',').map(str::to_string).collect()
        }
        None => {
            vec!["paper-40".to_string(), "starlink-lite".to_string(), "sparse-iot".to_string()]
        }
    };
    if args.iter().any(|a| a == "geometry") {
        geometry_benches(&presets);
        return;
    }

    let cfg = BenchConfig::default();
    print_header("substrate micro-benchmarks");

    // PRNG
    let mut rng = Rng::new(1);
    println!(
        "{}",
        bench("rng: 1k gaussians", &cfg, || {
            (0..1000).map(|_| rng.gaussian()).sum::<f64>()
        })
        .report()
    );

    // Event queue
    println!(
        "{}",
        bench("event queue: 10k push+pop", &cfg, || {
            let mut q = EventQueue::new();
            for i in 0..10_000 {
                q.push(Event::new((i % 997) as f64, EventKind::Sweep));
            }
            while q.pop().is_some() {}
        })
        .report()
    );

    // Orbit propagation + visibility predicate
    let constellation = WalkerConstellation::paper();
    let hap = GeodeticSite::rolla_hap();
    println!(
        "{}",
        bench("orbit: 40-sat snapshot + elevation", &cfg, || {
            let t = 4321.0;
            let site = hap.position_eci(t);
            (0..40)
                .map(|s| {
                    asyncfleo::orbit::elevation_deg(site, constellation.position(s, t))
                })
                .sum::<f64>()
        })
        .report()
    );

    // Contact plan construction (the big precompute)
    let plan_cfg = BenchConfig { warmup_iters: 1, sample_iters: 5, max_seconds: 60.0 };
    println!(
        "{}",
        bench("contact plan: 40 sats x 1 site x 24h", &plan_cfg, || {
            ContactPlan::build(&constellation, &[hap], 10.0, 86_400.0)
        })
        .report()
    );
    let plan = ContactPlan::build(&constellation, &[hap], 10.0, 86_400.0);
    println!(
        "{}",
        bench("contact plan: 1k next_visible queries", &cfg, || {
            (0..1000)
                .map(|i| plan.next_visible(0, i % 40, (i * 61) as f64).unwrap_or(0.0))
                .sum::<f64>()
        })
        .report()
    );

    // Aggregation decision (Eq. 13/14 coefficient computation)
    let candidates: Vec<Candidate> = (0..40)
        .map(|i| Candidate {
            meta: ModelMetadata {
                sat_id: i,
                orbit: i / 8,
                data_size: 100 + i,
                loc_rad: 0.0,
                ts_s: 0.0,
                epoch: (i % 5) as u64,
            },
            group: i / 14,
        })
        .collect();
    println!(
        "{}",
        bench("aggregation: select+weigh 40 candidates", &cfg, || {
            select_and_weigh(black_box(&candidates), 4, 8000)
        })
        .report()
    );

    // Pure-rust weighted sum at real model size (fallback path)
    let dim = 101_770;
    let mut r2 = Rng::new(2);
    let models: Vec<ModelParams> =
        (0..10).map(|_| ModelParams::random(dim, 0.1, &mut r2)).collect();
    let refs: Vec<&ModelParams> = models.iter().collect();
    let ws = vec![0.1f32; 10];
    println!(
        "{}",
        bench("rust weighted_sum: 10 x 101k params", &cfg, || {
            ModelParams::weighted_sum(black_box(&refs), black_box(&ws))
        })
        .report()
    );

    geometry_benches(&presets);

    // PJRT artifact hot paths (needs `make artifacts`)
    match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => pjrt_benches(Rc::new(rt)),
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }
}

/// Per-preset `ContactPlan` build timings: the kept-as-specification
/// reference sweep, the rate-bound-only scanner (analytic pass maps
/// disabled) and the full analytic scanner at 1 and 4 threads — gated
/// on window equality so a speedup can never be reported on diverged
/// output. On mega-constellation presets (> 2000 satellites) the dense
/// reference is only too slow to *time*; the analytic-vs-scan gate
/// still pins correctness (both are reference-bitwise by the
/// equivalence suite). Emits `BENCH_geometry.json`, including the
/// process peak RSS after each preset.
fn geometry_benches(preset_names: &[String]) {
    print_header("geometry: ContactPlan build, reference vs scan vs analytic (24 h horizon)");
    let reg = ScenarioRegistry::builtin();
    let horizon_s = 86_400.0;
    let plan_cfg = BenchConfig { warmup_iters: 1, sample_iters: 3, max_seconds: 240.0 };
    let mut rows: Vec<String> = Vec::new();
    for name in preset_names {
        let sc = reg
            .get(name)
            .unwrap_or_else(|| panic!("unknown preset {name}; known: {:?}", reg.names()));
        let constellation = WalkerConstellation::from_shells(&sc.cfg.constellation.shells());
        let sites = sc.cfg.placement.sites();
        let min_elev = sc.cfg.min_elevation_deg;
        let n_sats = constellation.len();
        let time_reference = n_sats <= 2000;

        // identity gates: analytic scanner ≡ rate-bound-only scanner,
        // and both ≡ dense reference where we can afford to build it
        let scan_only =
            ContactPlan::build_with_options(&constellation, &sites, min_elev, horizon_s, 1, false);
        let fast = ContactPlan::build_with_threads(&constellation, &sites, min_elev, horizon_s, 1);
        for site in 0..sites.len() {
            for sat in 0..n_sats {
                assert_eq!(
                    scan_only.windows(site, sat),
                    fast.windows(site, sat),
                    "{name}: analytic scanner diverged from rate-bound scan (site {site} sat {sat})"
                );
            }
        }
        if time_reference {
            let reference =
                ContactPlan::build_reference(&constellation, &sites, min_elev, horizon_s);
            for site in 0..sites.len() {
                for sat in 0..n_sats {
                    assert_eq!(
                        reference.windows(site, sat),
                        fast.windows(site, sat),
                        "{name}: fast scanner diverged from reference (site {site} sat {sat})"
                    );
                }
            }
        }

        let r_ref = time_reference.then(|| {
            let r = bench(&format!("{name}: reference scan"), &plan_cfg, || {
                ContactPlan::build_reference(&constellation, &sites, min_elev, horizon_s)
            });
            println!("{}", r.report());
            r
        });
        let r_scan1 = bench(&format!("{name}: rate-bound scan, 1 thread"), &plan_cfg, || {
            ContactPlan::build_with_options(&constellation, &sites, min_elev, horizon_s, 1, false)
        });
        println!("{}", r_scan1.report());
        let r_an1 = bench(&format!("{name}: analytic scan, 1 thread"), &plan_cfg, || {
            ContactPlan::build_with_threads(&constellation, &sites, min_elev, horizon_s, 1)
        });
        println!("{}", r_an1.report());
        let r_an4 = bench(&format!("{name}: analytic scan, 4 threads"), &plan_cfg, || {
            ContactPlan::build_with_threads(&constellation, &sites, min_elev, horizon_s, 4)
        });
        println!("{}", r_an4.report());

        let speedup_analytic = r_scan1.stats.mean / r_an1.stats.mean.max(1e-12);
        println!("{name}: analytic vs rate-bound scan {speedup_analytic:.2}x (1 thread)");
        let ref_ms = r_ref
            .as_ref()
            .map(|r| format!("{:.3}", r.stats.mean * 1e3))
            .unwrap_or_else(|| "null".to_string());
        let speedup1 = r_ref
            .as_ref()
            .map(|r| format!("{:.3}", r.stats.mean / r_an1.stats.mean.max(1e-12)))
            .unwrap_or_else(|| "null".to_string());
        let speedup4 = r_ref
            .as_ref()
            .map(|r| format!("{:.3}", r.stats.mean / r_an4.stats.mean.max(1e-12)))
            .unwrap_or_else(|| "null".to_string());
        let rss = asyncfleo::bench::peak_rss_mb()
            .map(|mb| format!("{mb:.1}"))
            .unwrap_or_else(|| "null".to_string());
        rows.push(format!(
            "    {{\"name\": \"{name}\", \"sats\": {n_sats}, \"sites\": {}, \"horizon_s\": {horizon_s:.1}, \"reference_ms\": {ref_ms}, \"scan_1thread_ms\": {:.3}, \"analytic_1thread_ms\": {:.3}, \"analytic_4thread_ms\": {:.3}, \"speedup_1thread\": {speedup1}, \"speedup_4thread\": {speedup4}, \"speedup_analytic_vs_scan\": {speedup_analytic:.3}, \"peak_rss_mb\": {rss}}}",
            sites.len(),
            r_scan1.stats.mean * 1e3,
            r_an1.stats.mean * 1e3,
            r_an4.stats.mean * 1e3,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"geometry\",\n  \"scan_step_s\": {:.1},\n  \"presets\": [\n{}\n  ]\n}}\n",
        ContactPlan::SCAN_STEP_S,
        rows.join(",\n")
    );
    let mut f = std::fs::File::create("BENCH_geometry.json").expect("create BENCH_geometry.json");
    f.write_all(json.as_bytes()).expect("write BENCH_geometry.json");
    println!("wrote BENCH_geometry.json");
}

fn pjrt_benches(rt: Rc<Runtime>) {
    print_header("PJRT artifact hot paths (L1/L2 compute)");
    let cfg = BenchConfig { warmup_iters: 2, sample_iters: 10, max_seconds: 120.0 };

    let init = rt.compile("init_mlp_digits").unwrap();
    let params = init.run(&[Input::I32(&[0])]).unwrap().remove(0);
    let mut rng = Rng::new(3);
    let xs: Vec<f32> = (0..320 * 784).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let mut ys = vec![0.0f32; 320 * 10];
    for i in 0..320 {
        ys[i * 10 + i % 10] = 1.0;
    }

    let train = rt.compile("train_mlp_digits").unwrap();
    println!(
        "{}",
        bench("train_mlp_digits: 1 dispatch (10 SGD steps)", &cfg, || {
            train
                .run(&[
                    Input::F32(&params),
                    Input::F32(&xs),
                    Input::F32(&ys),
                    Input::F32(&[0.05]),
                ])
                .unwrap()
        })
        .report()
    );

    let train_cnn = rt.compile("train_cnn_digits").unwrap();
    let init_cnn = rt.compile("init_cnn_digits").unwrap();
    let params_cnn = init_cnn.run(&[Input::I32(&[0])]).unwrap().remove(0);
    println!(
        "{}",
        bench("train_cnn_digits: 1 dispatch (10 SGD steps)", &cfg, || {
            train_cnn
                .run(&[
                    Input::F32(&params_cnn),
                    Input::F32(&xs),
                    Input::F32(&ys),
                    Input::F32(&[0.05]),
                ])
                .unwrap()
        })
        .report()
    );

    let eval = rt.compile("eval_mlp_digits").unwrap();
    let ex: Vec<f32> = xs[..256 * 784].to_vec();
    let ey: Vec<f32> = ys[..256 * 10].to_vec();
    println!(
        "{}",
        bench("eval_mlp_digits: 256-sample chunk", &cfg, || {
            eval.run(&[Input::F32(&params), Input::F32(&ex), Input::F32(&ey)]).unwrap()
        })
        .report()
    );

    let agg = rt.compile("agg_mlp_digits").unwrap();
    let slab: Vec<f32> = (0..41 * 101_770).map(|_| 0.01f32).collect();
    let coeffs = vec![1.0 / 41.0; 41];
    println!(
        "{}",
        bench("agg_mlp_digits: 41 x 101k slab (Eq. 14)", &cfg, || {
            agg.run(&[Input::F32(&slab), Input::F32(&coeffs)]).unwrap()
        })
        .report()
    );

    let dist = rt.compile("dist_mlp_digits").unwrap();
    let dslab: Vec<f32> = (0..40 * 101_770).map(|_| 0.01f32).collect();
    println!(
        "{}",
        bench("dist_mlp_digits: 40 x 101k rows (IV-C1)", &cfg, || {
            dist.run(&[Input::F32(&dslab), Input::F32(&params)]).unwrap()
        })
        .report()
    );
}
