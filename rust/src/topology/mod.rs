//! The communication topology: the ring-of-stars of the paper
//! (Sec. IV-A, Fig. 3) plus the explicit ISL graph of the follow-up
//! work (arXiv 2302.13447).
//!
//! Layers:
//!
//! * **HAP layer** ([`ring::HapRing`]) — the HAPs form a ring; one is
//!   designated *source* and one *sink* (typically the farthest around
//!   the ring); global models flow source→sink along both arcs,
//!   local-model sets flow the same way toward the sink, and the roles
//!   swap each global epoch (Sec. IV-B3).
//! * **SAT layer, implicit** — each HAP runs a star over its currently
//!   visible satellites, and satellites in the same orbit form
//!   intra-orbit ISL rings
//!   ([`crate::orbit::WalkerConstellation::ring_neighbors`]).
//!   Inter-orbit ISLs are deliberately absent (Doppler, Sec. IV-A).
//!   This is the path every pre-graph scheme still runs on,
//!   bit-identical (pinned by `tests/topology_equivalence.rs`).
//! * **SAT layer, explicit** ([`graph::IslGraph`]) — the same
//!   satellites as a typed graph: intra-plane ring edges, optional
//!   cross-plane grid and cross-shell gateway edges, per-shell
//!   [`crate::comm::LinkParams`] budgets, Doppler-derated per-edge
//!   delays, and deterministic shortest-delay routing. Built once per
//!   [`crate::coordinator::Geometry`] from the `[isl]` scenario
//!   section; a `ring` topology reproduces `ring_neighbors` exactly
//!   (the executable reference). The sink-satellite scheme
//!   (`fl::baselines::sinksat`) routes plane collection over it.

pub mod graph;
pub mod ring;

pub use graph::{IslConfig, IslEdge, IslEdgeKind, IslGraph, IslTopology, RoutePlan};
pub use ring::HapRing;
