//! Determinism contract of the parallel sweep executor and the shared
//! geometry cache:
//!
//! * `--jobs 4` produces byte-identical `results/*.csv` to `--jobs 1`
//!   on the fast surrogate Table II sweep (same seed ⇒ same bytes,
//!   regardless of worker scheduling);
//! * bit-identical `RunResult` curves at the executor level;
//! * the `Geometry` cache returns the same `Arc` for
//!   geometry-identical configs, a fresh one when altitude / elevation
//!   / horizon change, and builds each unique geometry exactly once.

use asyncfleo::config::ExperimentConfig;
use asyncfleo::coordinator::Geometry;
use asyncfleo::experiments::drivers::{table2_cells, ExpOptions};
use asyncfleo::experiments::executor::run_cells;
use asyncfleo::experiments::run_experiment;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("asyncfleo_parallel_sweep_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn opts(out: PathBuf, jobs: usize) -> ExpOptions {
    ExpOptions { out_dir: out, fast: true, surrogate: true, seed: 42, jobs, report: false }
}

#[test]
fn table2_fast_surrogate_csvs_are_byte_identical_across_jobs() {
    let dir1 = temp_out("jobs1");
    let dir4 = temp_out("jobs4");
    run_experiment("table2", &opts(dir1.clone(), 1)).expect("--jobs 1 run");
    run_experiment("table2", &opts(dir4.clone(), 4)).expect("--jobs 4 run");
    for file in ["table2.csv", "fig6.csv"] {
        let a = std::fs::read(dir1.join(file)).unwrap();
        let b = std::fs::read(dir4.join(file)).unwrap();
        assert!(!a.is_empty(), "{file} must not be empty");
        assert_eq!(a, b, "{file}: --jobs 4 bytes must equal --jobs 1 bytes");
    }
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir4);
}

#[test]
fn executor_curves_are_bit_identical_across_jobs() {
    let o1 = opts(temp_out("curves"), 1);
    let o4 = ExpOptions { jobs: 4, ..o1.clone() };
    let cells = table2_cells(&o1);
    let seq = run_cells(&cells, &o1).expect("sequential");
    let par = run_cells(&cells, &o4).expect("parallel");
    assert_eq!(seq.len(), par.len());
    for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
        assert_eq!(a.epochs, b.epochs, "cell {i}: epochs");
        assert_eq!(a.transfers, b.transfers, "cell {i}: transfers");
        assert_eq!(a.fault_stats, b.fault_stats, "cell {i}: fault stats");
        assert_eq!(a.curve.points.len(), b.curve.points.len(), "cell {i}: curve len");
        for (x, y) in a.curve.points.iter().zip(&b.curve.points) {
            assert_eq!(x.time_s, y.time_s, "cell {i}: point time");
            assert_eq!(x.accuracy, y.accuracy, "cell {i}: point accuracy");
            assert_eq!(x.loss, y.loss, "cell {i}: point loss");
        }
    }
}

#[test]
fn geometry_cache_identity_and_keying() {
    // a geometry unique to this test binary (altitude no other config
    // uses), so build counts are isolated from the other tests here
    let mut cfg = ExperimentConfig::test_small();
    cfg.constellation.altitude_km = 1414.5;

    let a = Geometry::shared(&cfg);
    let b = Geometry::shared(&cfg);
    assert!(Arc::ptr_eq(&a, &b), "geometry-identical configs share one Arc");
    assert_eq!(Geometry::build_count(&cfg), 1, "built exactly once");

    // non-geometry knobs keep sharing
    let mut same_geo = cfg.clone();
    same_geo.seed = 9001;
    same_geo.fl.max_epochs = 1;
    assert!(Arc::ptr_eq(&a, &Geometry::shared(&same_geo)));

    // altitude / elevation / horizon each key a fresh instance
    let mut alt = cfg.clone();
    alt.constellation.altitude_km = 1415.5;
    assert!(!Arc::ptr_eq(&a, &Geometry::shared(&alt)));
    let mut elev = cfg.clone();
    elev.min_elevation_deg = 17.25;
    assert!(!Arc::ptr_eq(&a, &Geometry::shared(&elev)));
    let mut hor = cfg.clone();
    hor.fl.horizon_s = cfg.fl.horizon_s + 600.0;
    assert!(!Arc::ptr_eq(&a, &Geometry::shared(&hor)));

    assert_eq!(Geometry::build_count(&cfg), 1, "base entry never rebuilt");
}
