//! Sweep-executor benchmark: the fast surrogate Table II grid run
//! sequentially vs on `--jobs N` worker threads, plus the cost of one
//! cold geometry build (what every sweep cell used to pay before the
//! shared `Geometry` cache).
//!
//! Emits `BENCH_sweep.json` (cells/sec, geometry-build time, speedup)
//! so the perf trajectory of the executor is tracked across PRs.
//!
//! Run: `cargo bench --offline --bench bench_sweep`

use asyncfleo::bench::black_box;
use asyncfleo::coordinator::Geometry;
use asyncfleo::experiments::drivers::{table2_cells, ExpOptions};
use asyncfleo::experiments::executor::run_cells;
use std::io::Write;
use std::time::Instant;

const PAR_JOBS: usize = 4;

fn main() {
    let opts_seq = ExpOptions { fast: true, surrogate: true, jobs: 1, ..Default::default() };
    let opts_par = ExpOptions { jobs: PAR_JOBS, ..opts_seq.clone() };
    let cells = table2_cells(&opts_seq);
    let n_cells = cells.len();

    // One cold geometry build (cache bypassed): the per-cell cost the
    // shared cache amortizes to once per unique geometry.
    let t0 = Instant::now();
    black_box(Geometry::build(&cells[0].cfg));
    let geometry_build_s = t0.elapsed().as_secs_f64();

    // Warm the cache so both timed passes measure pure run time.
    for cell in &cells {
        Geometry::shared(&cell.cfg);
    }

    let t0 = Instant::now();
    let seq = run_cells(&cells, &opts_seq).expect("sequential sweep");
    let sequential_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let par = run_cells(&cells, &opts_par).expect("parallel sweep");
    let parallel_s = t0.elapsed().as_secs_f64();

    // sanity: the executor's determinism contract, checked here too so
    // a bench run can never silently report a speedup on wrong results
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.epochs, b.epochs, "parallel run diverged from sequential");
        assert_eq!(a.transfers, b.transfers, "parallel run diverged from sequential");
    }

    let speedup = sequential_s / parallel_s.max(1e-9);
    println!("\n== sweep executor (table2 fast surrogate, {n_cells} cells) ==");
    println!("geometry build (cold):    {geometry_build_s:>9.3} s");
    println!("sequential (--jobs 1):    {sequential_s:>9.3} s  ({:.2} cells/s)", n_cells as f64 / sequential_s);
    println!("parallel   (--jobs {PAR_JOBS}):    {parallel_s:>9.3} s  ({:.2} cells/s)", n_cells as f64 / parallel_s);
    println!("speedup:                  {speedup:>9.2} x");

    let json = format!(
        "{{\n  \"bench\": \"sweep\",\n  \"cells\": {n_cells},\n  \"jobs\": {PAR_JOBS},\n  \"geometry_build_s\": {geometry_build_s:.6},\n  \"sequential_s\": {sequential_s:.6},\n  \"parallel_s\": {parallel_s:.6},\n  \"speedup\": {speedup:.4},\n  \"cells_per_sec_sequential\": {:.4},\n  \"cells_per_sec_parallel\": {:.4}\n}}\n",
        n_cells as f64 / sequential_s,
        n_cells as f64 / parallel_s,
    );
    let mut f = std::fs::File::create("BENCH_sweep.json").expect("create BENCH_sweep.json");
    f.write_all(json.as_bytes()).expect("write BENCH_sweep.json");
    println!("wrote BENCH_sweep.json");
}
