//! Observability bit-identity suite (the PR-8 contract, `src/obs`):
//!
//! * for every built-in preset, each covered scheme produces
//!   **bit-identical** accuracy curves, transfer counts and fault
//!   accounting with tracing ON (memory sink) and OFF — observation
//!   draws nothing from any RNG, reorders no events and changes no
//!   arithmetic;
//! * the trace itself is deterministic: two traced runs of the same
//!   seed emit identical JSONL line-for-line;
//! * the scenario sweep writes byte-identical `scenarios.csv` with
//!   `--report` (metrics-only observation on every cell) on and off,
//!   and `--report` additionally produces a well-formed `report.json`;
//! * `summarize_trace` renders the staleness histogram, link table and
//!   time-in-phase table from a real traced run.

use asyncfleo::config::{ExperimentConfig, SchemeKind};
use asyncfleo::coordinator::{RunResult, SimEnv};
use asyncfleo::experiments::drivers::ExpOptions;
use asyncfleo::experiments::scenarios::run_compare;
use asyncfleo::fl::{make_strategy, Strategy};
use asyncfleo::obs::{summarize_trace, RunObs};
use asyncfleo::scenario::{Scenario, ScenarioRegistry};
use asyncfleo::testkit::assert_runs_identical;
use asyncfleo::train::SurrogateBackend;
use std::path::PathBuf;

/// The schemes the contract covers: ours, one synchronous baseline and
/// the ISL-routed sink-satellite scheme (the widest-instrumented trio).
const SCHEMES: &[SchemeKind] = &[SchemeKind::AsyncFleo, SchemeKind::FedHap, SchemeKind::SinkSat];

/// Every built-in preset the suite sweeps.
const PRESETS: &[&str] = &[
    "paper-40",
    "starlink-lite",
    "polar-star",
    "sparse-iot",
    "equatorial-dense",
    "haps-degraded",
];

/// Trim a preset for the suite (same clamps as the run-loop equivalence
/// suite): identity needs events, not convergence.
fn trimmed(cfg: &ExperimentConfig) -> ExperimentConfig {
    let mut c = cfg.clone();
    if c.n_sats() >= 1000 {
        c.fl.horizon_s = 2.0 * 3600.0;
        c.fl.max_epochs = 2;
    } else if c.n_sats() >= 100 {
        c.fl.horizon_s = 6.0 * 3600.0;
        c.fl.max_epochs = 3;
    } else {
        c.fl.horizon_s = 12.0 * 3600.0;
        c.fl.max_epochs = 4;
    }
    c
}

/// One unobserved run (the historical code path: `state.obs == None`).
fn run_plain(cfg: &ExperimentConfig) -> RunResult {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    make_strategy(cfg.fl.scheme).run(&mut env)
}

/// One fully traced run (memory sink); returns the observation state
/// alongside the result so callers can inspect the emitted JSONL.
fn run_observed(cfg: &ExperimentConfig) -> (RunResult, Box<RunObs>) {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    let mut obs = RunObs::to_memory();
    obs.meta(
        "test",
        cfg.fl.scheme.name(),
        cfg.seed,
        cfg.fl.horizon_s,
        cfg.n_sats(),
        cfg.placement.sites().len(),
    );
    env.enable_obs(obs);
    let r = make_strategy(cfg.fl.scheme).run(&mut env);
    let obs = env.take_obs().expect("run was observed");
    (r, obs)
}

/// [`run_observed`] with the PR-9 multi-lane event core enabled.
fn run_observed_lanes(cfg: &ExperimentConfig, lanes: usize) -> (RunResult, Box<RunObs>) {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    env.set_lanes(lanes);
    let mut obs = RunObs::to_memory();
    obs.meta(
        "test",
        cfg.fl.scheme.name(),
        cfg.seed,
        cfg.fl.horizon_s,
        cfg.n_sats(),
        cfg.placement.sites().len(),
    );
    env.enable_obs(obs);
    let r = make_strategy(cfg.fl.scheme).run(&mut env);
    let obs = env.take_obs().expect("run was observed");
    (r, obs)
}

#[test]
fn traces_are_byte_identical_at_any_lane_count() {
    // The PR-9 contract: lanes parallelize pure probes between pops,
    // never the observed effects — so the JSONL trace of a multi-lane
    // run is byte-for-byte the single-lane trace.
    let reg = ScenarioRegistry::builtin();
    for name in PRESETS {
        let sc = reg.get(name).unwrap_or_else(|| panic!("missing preset {name}"));
        for &scheme in SCHEMES {
            let mut cfg = trimmed(&sc.cfg);
            cfg.fl.scheme = scheme;
            let what = format!("{name}/{}", scheme.name());
            let (one, obs_one) = run_observed_lanes(&cfg, 1);
            let (four, obs_four) = run_observed_lanes(&cfg, 4);
            assert_runs_identical(&four, &one, &what);
            assert_eq!(
                obs_four.sink.lines(),
                obs_one.sink.lines(),
                "{what}: lanes=4 must emit the lanes=1 JSONL byte-for-byte"
            );
            assert!(!obs_one.sink.lines().is_empty(), "{what}: trace must be non-empty");
        }
    }
}

#[test]
fn tracing_on_vs_off_is_bit_identical_and_traces_are_deterministic() {
    let reg = ScenarioRegistry::builtin();
    for name in PRESETS {
        let sc = reg.get(name).unwrap_or_else(|| panic!("missing preset {name}"));
        for &scheme in SCHEMES {
            let mut cfg = trimmed(&sc.cfg);
            cfg.fl.scheme = scheme;
            let what = format!("{name}/{}", scheme.name());
            let plain = run_plain(&cfg);
            let (traced_a, obs_a) = run_observed(&cfg);
            let (traced_b, obs_b) = run_observed(&cfg);
            assert_runs_identical(&plain, &traced_a, &what);
            assert_runs_identical(&traced_a, &traced_b, &what);
            assert_eq!(
                obs_a.sink.lines(),
                obs_b.sink.lines(),
                "{what}: same seed must emit identical JSONL"
            );
            assert!(
                !obs_a.sink.lines().is_empty(),
                "{what}: a traced run must emit records"
            );
            assert!(
                plain.obs.is_none() && traced_a.obs.is_some(),
                "{what}: only the observed result carries a report"
            );
        }
    }
}

fn temp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("asyncfleo_obs_equiv_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn report_flag_leaves_scenarios_csv_bytes_unchanged() {
    let reg = ScenarioRegistry::builtin();
    let scenarios: Vec<Scenario> = ["paper-40", "sparse-iot"]
        .iter()
        .map(|name| {
            let sc = reg.get(name).unwrap();
            Scenario::new(sc.name.clone(), sc.summary.clone(), trimmed(&sc.cfg))
        })
        .collect();
    let dir_off = temp_out("report_off");
    let dir_on = temp_out("report_on");
    let opts_off = ExpOptions {
        out_dir: dir_off.clone(),
        fast: true,
        surrogate: true,
        seed: 42,
        jobs: 1,
        report: false,
    };
    let opts_on = ExpOptions { out_dir: dir_on.clone(), report: true, ..opts_off.clone() };
    run_compare(&scenarios, &opts_off).expect("sweep without report");
    run_compare(&scenarios, &opts_on).expect("sweep with report");
    let a = std::fs::read(dir_off.join("scenarios.csv")).unwrap();
    let b = std::fs::read(dir_on.join("scenarios.csv")).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "--report must not change scenarios.csv bytes");
    assert!(!dir_off.join("report.json").exists(), "no report without --report");
    let report = std::fs::read_to_string(dir_on.join("report.json")).unwrap();
    assert!(report.contains("\"runs\""), "{report}");
    assert!(report.contains("paper-40/AsyncFLEO"), "cell labels key the runs");
    assert!(report.contains("sparse-iot/SinkSat"), "every cell reports");
    assert!(report.contains("\"tx.site\""), "counters folded per cell");
    assert!(report.contains("\"substrate_phases\""), "{report}");
    let _ = std::fs::remove_dir_all(&dir_off);
    let _ = std::fs::remove_dir_all(&dir_on);
}

#[test]
fn summarize_trace_renders_staleness_links_and_phases_from_a_real_run() {
    let reg = ScenarioRegistry::builtin();
    let sc = reg.get("paper-40").expect("paper preset in catalog");
    let mut cfg = trimmed(&sc.cfg);
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    let (_r, obs) = run_observed(&cfg);

    // every line is one flat JSON record tagged "ev"
    let lines = obs.sink.lines();
    for line in lines {
        assert!(
            line.starts_with("{\"ev\":\"") && line.ends_with('}'),
            "malformed trace line: {line}"
        );
    }
    let has = |kind: &str| lines.iter().any(|l| l.starts_with(&format!("{{\"ev\":\"{kind}\"")));
    assert!(has("meta"), "meta header present");
    assert!(has("model_tx"), "transfers traced");
    assert!(has("aggregate"), "aggregations traced");
    assert!(has("eval"), "evaluations traced");
    assert!(obs.metrics.counter("aggregations") >= 1);
    assert!(obs.phases.get("event_loop").is_some(), "event loop phase timed");
    assert!(obs.phases.get("aggregate").is_some(), "aggregation phase timed");

    let trace = lines.join("\n");
    let report = obs.report().to_json("");
    let s = summarize_trace(&trace, Some(&report));
    assert!(s.contains("staleness at aggregation"), "{s}");
    assert!(s.contains("aggregations, mean"), "histogram is populated:\n{s}");
    assert!(s.contains("top links by utilization"), "{s}");
    assert!(s.contains("time in phase"), "{s}");
    assert!(s.contains("event_loop"), "phase table rendered from report.json:\n{s}");
    // without the sibling report the phase table degrades gracefully
    assert!(summarize_trace(&trace, None).contains("wall-clock phases unavailable"));
}
