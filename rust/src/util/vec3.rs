//! Minimal 3-D vector math for orbital mechanics (ECI/ECEF frames).

use std::ops::{Add, Mul, Neg, Sub};

/// Cartesian 3-vector (km, in whichever frame the caller tracks).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Vec3 { x, y, z }
    }

    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    pub fn norm2(self) -> f64 {
        self.dot(self)
    }

    pub fn norm(self) -> f64 {
        self.norm2().sqrt()
    }

    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        assert!(n > 0.0, "normalizing zero vector");
        self * (1.0 / n)
    }

    pub fn distance(self, o: Vec3) -> f64 {
        (self - o).norm()
    }

    /// Angle between two vectors in radians, in [0, pi].
    pub fn angle_to(self, o: Vec3) -> f64 {
        let c = self.dot(o) / (self.norm() * o.norm());
        crate::util::clamp(c, -1.0, 1.0).acos()
    }

    /// Rotate about the Z axis by `theta` radians (RAAN / Earth spin).
    pub fn rot_z(self, theta: f64) -> Vec3 {
        let (s, c) = theta.sin_cos();
        Vec3::new(c * self.x - s * self.y, s * self.x + c * self.y, self.z)
    }

    /// Rotate about the X axis by `theta` radians (inclination).
    pub fn rot_x(self, theta: f64) -> Vec3 {
        let (s, c) = theta.sin_cos();
        Vec3::new(self.x, c * self.y - s * self.z, s * self.y + c * self.z)
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, k: f64) -> Vec3 {
        Vec3::new(self.x * k, self.y * k, self.z * k)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        self * -1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-12;

    #[test]
    fn dot_and_cross() {
        let x = Vec3::new(1.0, 0.0, 0.0);
        let y = Vec3::new(0.0, 1.0, 0.0);
        assert_eq!(x.dot(y), 0.0);
        assert_eq!(x.cross(y), Vec3::new(0.0, 0.0, 1.0));
    }

    #[test]
    fn norm_and_distance() {
        let v = Vec3::new(3.0, 4.0, 0.0);
        assert!((v.norm() - 5.0).abs() < EPS);
        assert!((v.distance(Vec3::ZERO) - 5.0).abs() < EPS);
    }

    #[test]
    fn angle_orthogonal_and_parallel() {
        let x = Vec3::new(1.0, 0.0, 0.0);
        let y = Vec3::new(0.0, 2.0, 0.0);
        assert!((x.angle_to(y) - std::f64::consts::FRAC_PI_2).abs() < EPS);
        assert!(x.angle_to(x * 5.0).abs() < EPS);
        assert!((x.angle_to(-x) - std::f64::consts::PI).abs() < EPS);
    }

    #[test]
    fn rot_z_quarter_turn() {
        let v = Vec3::new(1.0, 0.0, 0.0).rot_z(std::f64::consts::FRAC_PI_2);
        assert!(v.distance(Vec3::new(0.0, 1.0, 0.0)) < EPS);
    }

    #[test]
    fn rot_x_quarter_turn() {
        let v = Vec3::new(0.0, 1.0, 0.0).rot_x(std::f64::consts::FRAC_PI_2);
        assert!(v.distance(Vec3::new(0.0, 0.0, 1.0)) < EPS);
    }

    #[test]
    fn rotations_preserve_norm() {
        let v = Vec3::new(1.2, -3.4, 5.6);
        assert!((v.rot_z(0.7).norm() - v.norm()).abs() < EPS);
        assert!((v.rot_x(1.3).norm() - v.norm()).abs() < EPS);
    }
}
