//! Pre-computed contact plan: visibility windows between every
//! satellite and every PS site over the experiment horizon.
//!
//! The PS knows each satellite's TLE (paper Sec. V-A) and can predict
//! visits; pre-computing the windows once keeps the event loop free of
//! trigonometry (perf: the coordinator must never be the bottleneck).
//!
//! # The fast scanner (PRs 4 + 7)
//!
//! [`ContactPlan::build`] used to re-propagate the whole constellation
//! per (site, sat) pair over the full horizon — ~8 M predicate calls on
//! a `starlink-lite` world, each paying two rotation matrices and fresh
//! site trig, on one thread. The production path now stacks six
//! optimizations, all of them **bit-identity preserving** (the naive
//! per-pair sweep is kept as [`ContactPlan::build_reference`], and
//! `tests/contact_equivalence.rs` asserts bitwise-equal windows on
//! every scenario preset):
//!
//! 1. **Plane-basis propagation** — satellite positions evaluate
//!    through the constellation's cached [`PlaneBasis`] values (one
//!    sin/cos pair + multiply-adds per call instead of a fresh
//!    `rot_x`+`rot_z` chain).
//! 2. **Time-major sharing** — each site's position is computed once
//!    per grid step into a shared table (instead of once per
//!    (pair, step)), and each satellite's position once per step across
//!    all its site pairs; per grid step the scan does O(sites + sats)
//!    position work, not O(sites × sats).
//! 3. **Provable interval skipping (rate bound)** — see below: whole
//!    grid intervals where no visibility flip can occur evaluate
//!    *nothing*; the remaining steps sample the exact same grid points
//!    and bisection brackets as the reference.
//! 4. **Analytic first-contact prediction (PR 7)** — the closed-form
//!    `γ(t) = γ_max` pass maps of [`super::analytic`], shared per
//!    (shell, site-latitude-band) and across presets, prove whole
//!    *pass gaps* invisible at once: while a pair is out of contact the
//!    scanner jumps straight to the next analytically-possible pass
//!    instead of rate-bound-stepping through the gap, and pairs whose
//!    class can never be visible (a low-inclination shell seen from a
//!    high-latitude site) are pruned without a single predicate call.
//! 5. **Chunked, flat materialization (PR 7)** — the horizon is
//!    scanned in fixed chunks with per-chunk site tables in reused
//!    buffers, window events append to one per-satellite vector, and
//!    the final plan is a single flat arena indexed by (site, sat) —
//!    no per-pair `Vec` allocations anywhere, so memory stays flat as
//!    satellite count grows into the 10k+ regime.
//! 6. **Parallel build** — satellites fan out across a
//!    `std::thread::scope` pool per chunk ([`worker_count`] governs the
//!    pool size here and in the sweep executor); each satellite owns
//!    its scan state, so the plan is deterministic — and bit-identical
//!    — regardless of thread count or chunk partitioning.
//!
//! # Why interval skipping is safe (the rate bound)
//!
//! For a site at geocentric radius `a` and a circular-orbit satellite
//! at radius `b > a`, elevation is a function of the central angle `γ`
//! between their direction vectors with derivative
//! `de/dγ = −b(b − a·cos γ) / d²` where `d² = a² + b² − 2ab·cos γ` is
//! the squared slant range. `|de/dγ|` is increasing in `cos γ`
//! (d/d(cos γ) ∝ a(b² − a²) > 0), so it is maximized overhead (γ = 0)
//! at `b/(b − a)`. The direction vectors themselves rotate at fixed
//! angular speeds — the satellite's at its mean motion `n`, the site's
//! at `ω_E·cos(lat) ≤ ω_E` — and the angle between two unit vectors
//! changes no faster than the sum of their angular speeds. Hence
//!
//! ```text
//! |de/dt| ≤ (n + ω_E) · b/(b − a)   =: rate(site, sat)
//! ```
//!
//! If a sample at grid time `t_i` shows elevation `e_i`, a visibility
//! flip (crossing `eff_min`) is impossible before
//! `t_i + |e_i − eff_min| / rate`. Every grid point strictly inside
//! that window provably carries the same visibility value, so the
//! scanner jumps straight to the first grid index at or beyond it
//! ([`SKIP_SAFETY`] shaves 0.1 % off the window to absorb the
//! floating-point rounding of the bound arithmetic itself).
//!
//! # Why the analytic skip is safe (the closed form)
//!
//! Expanding the same central angle via the plane basis
//! `p = (cos Ω, sin Ω, 0)`, `q = (−sin Ω·cos i, cos Ω·cos i, sin i)`
//! and the rotating site direction at latitude `φ`, longitude
//! `λ(t) = λ₀ + ω_E·t`:
//!
//! ```text
//! cos γ(t) = P(Δ)·cos u + Q(Δ)·sin u      u(t) = phase + n·t
//!     P(Δ) = cos φ · cos Δ                Δ(t) = λ(t) − Ω
//!     Q(Δ) = cos i · cos φ · sin Δ + sin i · sin φ
//! ```
//!
//! and `e ≥ e_min ⟺ γ ≤ γ_max` with the closed-form threshold
//! `γ_max = acos((a/b)·cos e_min) − e_min`
//! ([`crate::orbit::max_central_angle_rad`] — elevation is strictly
//! monotone in `γ`, so the inequality direction is exact). Visibility
//! is therefore a fixed region on the `(Δ, u)` torus, determined
//! entirely by `(altitude, inclination, φ, site altitude, e_min)`:
//! every satellite of a shell and every site on the same latitude band
//! share it — RAAN, phase, and site longitude only shift the
//! trajectory's starting point on the torus, not the region. That is
//! the **latitude-band equivalence**, and it is why
//! [`super::analytic::shared_pass_map`] memoizes one conservative
//! bucketed superset of the region per class, process-wide across
//! presets. `PassMap::next_possible` walks the torus trajectory
//! through that superset and returns a time before which visibility is
//! *provably impossible*; the scanner combines it (only while the pair
//! is invisible — the map proves nothing about staying visible) with
//! the rate bound by taking the larger skip: every skipped grid point
//! is proven constant-false by at least one of the two bounds.
//!
//! Whichever bound produced a skip, when a flip *is* detected at grid
//! index `j`, the previous grid point `j − 1` is inside some
//! proven-constant span (or was sampled), so the bisection bracket
//! `[t_{j−1}, t_j]` — and therefore the refined edge — is exactly the
//! reference scanner's.

use super::analytic::{self, PassMap};
use crate::orbit::{
    bisect_edge, elevation_deg, scan_grid, ContactWindow, GeodeticSite, PlaneBasis,
    SitePropagator, WalkerConstellation, EARTH_RADIUS_KM, EARTH_ROTATION_RAD_S,
};
use crate::util::Vec3;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Contact windows for all (satellite, site) pairs over `[0, horizon]`.
///
/// Storage is one flat arena: the windows of pair `(site, sat)` occupy
/// `arena[offsets[site·n_sats + sat] .. offsets[site·n_sats + sat + 1]]`,
/// sorted by start time. Two allocations for the whole plan, O(1)
/// pair lookup, no per-pair `Vec` headers — on a 10k-satellite world
/// the old `Vec<Vec<Vec<_>>>` layout spent more memory on vector
/// bookkeeping than on windows.
pub struct ContactPlan {
    arena: Vec<ContactWindow>,
    /// `n_sites · n_sats + 1` prefix offsets into `arena`.
    offsets: Vec<usize>,
    n_sites: usize,
    n_sats: usize,
    pub horizon_s: f64,
}

/// Sampling step for window extraction (edges refined by bisection).
/// Public as [`ContactPlan::SCAN_STEP_S`] so bench artifacts report the
/// actual scan resolution instead of duplicating the number.
const SCAN_STEP_S: f64 = 30.0;

/// Safety margin on the provable skip window: strictly conservative
/// against the (at most a-few-ulp) floating-point rounding of the
/// bound arithmetic, while giving up a negligible amount of skipping.
const SKIP_SAFETY: f64 = 0.999;

/// Grid steps per scan chunk: per-chunk site tables stay cache-sized
/// and horizon-independent (~2048 × 24 B per site), the knob behind
/// the flat-memory claim of module-docs item 5.
const CHUNK_STEPS: usize = 2048;

/// Worker-thread count for `n_units` independent units of work: the
/// requested count clamped to `[1, n_units]`. One policy shared by the
/// parallel plan builder (per-satellite rows) and the sweep executor
/// (`experiments::executor::effective_jobs`, per-cell grid).
pub fn worker_count(requested: usize, n_units: usize) -> usize {
    requested.clamp(1, n_units.max(1))
}

/// Provable bound on |d(elevation)/dt| for one (site, satellite) pair,
/// rad/s — the module-docs rate bound `(n + ω_E) · b/(b − a)`.
fn elevation_rate_bound_rad_s(site: &GeodeticSite, basis: &PlaneBasis) -> f64 {
    let a = EARTH_RADIUS_KM + site.alt_km;
    let b = basis.radius_km();
    assert!(b > a, "rate bound needs the satellite above the site ({b} km vs {a} km)");
    (basis.mean_motion_rad_s() + EARTH_ROTATION_RAD_S) * b / (b - a)
}

/// First grid index after `i` at which the pair must actually be
/// sampled: the elevation deficit from the visibility threshold closes
/// no faster than `rate_rad_s`, so every grid point strictly inside the
/// deficit/rate window provably keeps the current visibility value.
fn next_check_index(
    i: usize,
    elev_deg: f64,
    eff_min_deg: f64,
    rate_rad_s: f64,
    step_s: f64,
) -> usize {
    let deficit_rad = (elev_deg - eff_min_deg).abs().to_radians();
    let dt = SKIP_SAFETY * deficit_rad / rate_rad_s;
    i + ((dt / step_s).ceil() as usize).max(1)
}

/// Grid-index form of an analytic `next_possible` time: every grid
/// point *strictly below* index `floor(t/step)` has `t_i < t_possible`
/// and is proven invisible; backing off one more index makes the first
/// evaluated point provably-invisible too (one extra safe sample, and
/// the flip-detection bracket `[j−1, j]` always has a proven `j−1`).
fn analytic_index(t_possible: f64) -> usize {
    if t_possible.is_finite() {
        ((t_possible / SCAN_STEP_S) as usize).saturating_sub(1)
    } else {
        usize::MAX
    }
}

/// Per-(site, sat) scan state of the skipping scanner.
struct PairScan {
    prev_v: bool,
    start: Option<f64>,
    /// Earliest grid index at which a visibility flip is possible.
    next_check: usize,
    /// Cached rate bound of the pair.
    rate: f64,
    /// Torus offset `Δ(0) = λ₀ − Ω` for the pair's pass-map queries.
    dlon0: f64,
}

/// One satellite's persistent scan state across horizon chunks.
struct SatScan {
    /// Next grid index to process (`n_steps` when finished).
    i: usize,
    pairs: Vec<PairScan>,
    /// Detected windows as `(site, window)` events, per-pair in time
    /// order — one growable vector per satellite, not per pair.
    events: Vec<(u32, ContactWindow)>,
}

impl ContactPlan {
    /// The grid resolution every plan is scanned at, seconds.
    pub const SCAN_STEP_S: f64 = SCAN_STEP_S;

    /// Build the plan with the fast scanner on an automatically sized
    /// worker pool (available parallelism, clamped to the satellite
    /// count). The result is bit-identical at any thread count, so the
    /// sweep executor's byte-equality contract is unaffected.
    pub fn build(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
    ) -> Self {
        let requested = std::thread::available_parallelism().map_or(1, |n| n.get());
        Self::build_with_threads(
            constellation,
            sites,
            min_elev_deg,
            horizon_s,
            worker_count(requested, constellation.len()),
        )
    }

    /// Build the plan with the fast scanner on exactly `jobs` worker
    /// threads (1 = scan on the calling thread). Windows are
    /// bit-identical to [`Self::build_reference`] regardless of `jobs`
    /// (asserted by `tests/contact_equivalence.rs`).
    pub fn build_with_threads(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
        jobs: usize,
    ) -> Self {
        Self::build_with_options(constellation, sites, min_elev_deg, horizon_s, jobs, true)
    }

    /// [`Self::build_with_threads`] with the analytic pass-map layer
    /// switchable: `use_analytic = false` runs the pure rate-bound scanner
    /// (PR 4 behavior). Both settings produce bit-identical plans —
    /// the flag exists so benches can report analytic-vs-scan build
    /// time and tests can pin the equality.
    pub fn build_with_options(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
        jobs: usize,
        use_analytic: bool,
    ) -> Self {
        let grid = scan_grid(horizon_s, SCAN_STEP_S);
        let n_steps = grid.len();
        let n_sats = constellation.len();
        let n_sites = sites.len();
        let site_props: Vec<SitePropagator> = sites.iter().map(SitePropagator::new).collect();
        // HAPs gain horizon dip: theta_min is measured from the
        // apparent horizon (the paper's "slightly better visibility"
        // of elevated platforms).
        let eff_min: Vec<f64> =
            sites.iter().map(|s| s.effective_min_elevation_deg(min_elev_deg)).collect();
        let site_lon0: Vec<f64> = sites.iter().map(|s| s.lon_deg.to_radians()).collect();

        // shared analytic pass maps, one per (shell, site) class —
        // fetched from the process-wide cache before the parallel scan
        let maps: Option<Vec<Vec<Arc<PassMap>>>> = use_analytic.then(|| {
            constellation
                .shells
                .iter()
                .map(|sh| {
                    let inc = sh.inclination_deg.to_radians();
                    sites
                        .iter()
                        .zip(&eff_min)
                        .map(|(site, &em)| {
                            analytic::shared_pass_map(sh.altitude_km, inc, site, em)
                        })
                        .collect()
                })
                .collect()
        });

        let mut states: Vec<Mutex<SatScan>> = (0..n_sats)
            .map(|_| Mutex::new(SatScan { i: 0, pairs: Vec::new(), events: Vec::new() }))
            .collect();

        // per-chunk time-major site tables, reused across chunks
        let mut site_chunk: Vec<Vec<Vec3>> = vec![Vec::new(); n_sites];
        let mut chunk_lo = 0usize;
        while chunk_lo < n_steps {
            let chunk_hi = (chunk_lo + CHUNK_STEPS).min(n_steps);
            for (s, buf) in site_chunk.iter_mut().enumerate() {
                buf.clear();
                buf.extend(grid[chunk_lo..chunk_hi].iter().map(|&t| site_props[s].position_at(t)));
            }

            // One satellite's scan over this chunk: all its site pairs
            // swept together, so its position is computed at most once
            // per step — and not at all on steps every pair provably
            // skips. The evaluated-index set per pair depends only on
            // the skip bounds, never on chunk or thread boundaries.
            let scan_sat_chunk = |st: &mut SatScan, sat: usize| {
                if st.i >= n_steps {
                    return;
                }
                let basis = constellation.propagator(sat);
                let shell_maps = maps.as_ref().map(|m| &m[constellation.shell_of(sat)]);
                let raan = constellation.satellites[sat].elements.raan_rad;
                let u0 = basis.phase_rad();
                let n_rad = basis.mean_motion_rad_s();

                if st.i == 0 {
                    // first chunk: initialize every pair at grid[0].
                    // A pair whose pass map proves t = 0 invisible
                    // skips the initial sample outright (prev_v =
                    // false is proven, not sampled — the reference
                    // would have sampled false).
                    debug_assert_eq!(chunk_lo, 0);
                    let mut sat0: Option<Vec3> = None;
                    for s in 0..n_sites {
                        let rate = elevation_rate_bound_rad_s(&sites[s], basis);
                        let dlon0 = site_lon0[s] - raan;
                        let t_poss = shell_maps
                            .map(|m| m[s].next_possible(dlon0, u0, n_rad, horizon_s, 0.0));
                        if let Some(tp) = t_poss.filter(|&tp| tp > 0.0) {
                            st.pairs.push(PairScan {
                                prev_v: false,
                                start: None,
                                next_check: analytic_index(tp).max(1),
                                rate,
                                dlon0,
                            });
                            continue;
                        }
                        let sp = *sat0.get_or_insert_with(|| basis.position_at(grid[0]));
                        let e = elevation_deg(site_chunk[s][0], sp);
                        let v = e >= eff_min[s];
                        let mut next = next_check_index(0, e, eff_min[s], rate, SCAN_STEP_S);
                        if !v {
                            if let Some(m) = shell_maps {
                                let tp = m[s].next_possible(dlon0, u0, n_rad, horizon_s, grid[0]);
                                next = next.max(analytic_index(tp));
                            }
                        }
                        st.pairs.push(PairScan {
                            prev_v: v,
                            start: if v { Some(0.0) } else { None },
                            next_check: next,
                            rate,
                            dlon0,
                        });
                    }
                    st.i = 1;
                }

                while st.i < chunk_hi {
                    // jump straight past steps every pair provably skips
                    let due = st.pairs.iter().map(|p| p.next_check).min().unwrap_or(usize::MAX);
                    if due > st.i {
                        if due >= n_steps {
                            st.i = n_steps;
                            return;
                        }
                        st.i = due;
                        continue;
                    }
                    let i = st.i;
                    let t = grid[i];
                    let mut sat_pos: Option<Vec3> = None;
                    for s in 0..n_sites {
                        if st.pairs[s].next_check > i {
                            continue;
                        }
                        let sp = *sat_pos.get_or_insert_with(|| basis.position_at(t));
                        let e = elevation_deg(site_chunk[s][i - chunk_lo], sp);
                        let v = e >= eff_min[s];
                        let pair = &mut st.pairs[s];
                        if v != pair.prev_v {
                            // grid[i-1] provably carries prev_v (it is
                            // inside the span that let us skip to i, or
                            // it was sampled), so this is the reference
                            // scanner's bracket — and the same edge
                            let edge = bisect_edge(
                                &mut |tt: f64| {
                                    elevation_deg(
                                        site_props[s].position_at(tt),
                                        basis.position_at(tt),
                                    ) >= eff_min[s]
                                },
                                grid[i - 1],
                                t,
                                pair.prev_v,
                            );
                            if v {
                                pair.start = Some(edge);
                            } else if let Some(ws) = pair.start.take() {
                                st.events
                                    .push((s as u32, ContactWindow { start_s: ws, end_s: edge }));
                            }
                        }
                        let pair = &mut st.pairs[s];
                        pair.prev_v = v;
                        let mut next = next_check_index(i, e, eff_min[s], pair.rate, SCAN_STEP_S);
                        if !v {
                            // invisible: the pass map may prove the
                            // whole gap to the next pass; take the
                            // larger of the two proofs
                            if let Some(m) = shell_maps {
                                let tp = m[s].next_possible(pair.dlon0, u0, n_rad, horizon_s, t);
                                next = next.max(analytic_index(tp));
                            }
                        }
                        pair.next_check = next;
                    }
                    st.i += 1;
                }
            };

            let workers = worker_count(jobs, n_sats);
            if workers <= 1 {
                for (sat, st) in states.iter_mut().enumerate() {
                    scan_sat_chunk(st.get_mut().unwrap(), sat);
                }
            } else {
                // fan satellites across a scoped pool; each satellite
                // owns its state, so scheduling cannot affect output
                let next = AtomicUsize::new(0);
                std::thread::scope(|scope| {
                    for _ in 0..workers {
                        scope.spawn(|| loop {
                            let sat = next.fetch_add(1, Ordering::Relaxed);
                            if sat >= n_sats {
                                break;
                            }
                            scan_sat_chunk(&mut states[sat].lock().unwrap(), sat);
                        });
                    }
                });
            }
            chunk_lo = chunk_hi;
        }

        // close still-open windows at the horizon (reference behavior)
        let mut states: Vec<SatScan> =
            states.into_iter().map(|m| m.into_inner().unwrap()).collect();
        for st in &mut states {
            for (s, pair) in st.pairs.iter_mut().enumerate() {
                if let Some(ws) = pair.start.take() {
                    st.events.push((s as u32, ContactWindow { start_s: ws, end_s: horizon_s }));
                }
            }
        }

        // counting-sort the per-satellite event streams into the flat
        // (site, sat) arena: count → prefix offsets → stable scatter
        // (satellites ascending, events in detection order preserves
        // each pair's time order)
        let n_pairs = n_sites * n_sats;
        let mut offsets = vec![0usize; n_pairs + 1];
        for (sat, st) in states.iter().enumerate() {
            for &(s, _) in &st.events {
                offsets[s as usize * n_sats + sat + 1] += 1;
            }
        }
        for p in 0..n_pairs {
            offsets[p + 1] += offsets[p];
        }
        let total = offsets[n_pairs];
        let mut arena = vec![ContactWindow { start_s: 0.0, end_s: 0.0 }; total];
        let mut cursor: Vec<usize> = offsets[..n_pairs].to_vec();
        for (sat, st) in states.into_iter().enumerate() {
            for (s, w) in st.events {
                let p = s as usize * n_sats + sat;
                arena[cursor[p]] = w;
                cursor[p] += 1;
            }
        }
        Self::finish(arena, offsets, n_sites, n_sats, horizon_s)
    }

    /// The naive pre-PR-4 scanner, kept as the executable
    /// specification: one dense [`crate::orbit::contact_windows`] sweep
    /// per (site, sat) pair, no sharing, no skipping, single thread.
    /// `tests/contact_equivalence.rs` asserts the fast scanner matches
    /// it bit for bit on every scenario preset, and
    /// `benches/bench_micro.rs` times the two against each other.
    pub fn build_reference(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
    ) -> Self {
        let n_sats = constellation.len();
        let mut arena = Vec::new();
        let mut offsets = Vec::with_capacity(sites.len() * n_sats + 1);
        offsets.push(0);
        for site in sites {
            let eff_min = site.effective_min_elevation_deg(min_elev_deg);
            for sat in 0..n_sats {
                let ws = crate::orbit::contact_windows(
                    |t| {
                        elevation_deg(site.position_eci(t), constellation.position(sat, t))
                            >= eff_min
                    },
                    horizon_s,
                    SCAN_STEP_S,
                );
                arena.extend_from_slice(&ws);
                offsets.push(arena.len());
            }
        }
        Self::finish(arena, offsets, sites.len(), n_sats, horizon_s)
    }

    /// Assemble the plan and assert the finite-window invariant.
    fn finish(
        arena: Vec<ContactWindow>,
        offsets: Vec<usize>,
        n_sites: usize,
        n_sats: usize,
        horizon_s: f64,
    ) -> Self {
        debug_assert_eq!(offsets.len(), n_sites * n_sats + 1);
        // Window times are finite by construction (finite horizon/step,
        // bisection only averages); assert it once here so every
        // downstream total-order min / sort / event push can rely on it
        // instead of carrying per-call `partial_cmp(..).unwrap()` panic
        // paths.
        for w in &arena {
            assert!(
                w.start_s.is_finite() && w.end_s.is_finite(),
                "non-finite contact window {w:?}"
            );
        }
        ContactPlan { arena, offsets, n_sites, n_sats, horizon_s }
    }

    pub fn n_sites(&self) -> usize {
        self.n_sites
    }

    pub fn n_sats(&self) -> usize {
        self.n_sats
    }

    pub fn windows(&self, site: usize, sat: usize) -> &[ContactWindow] {
        let p = site * self.n_sats + sat;
        &self.arena[self.offsets[p]..self.offsets[p + 1]]
    }

    /// Total number of windows across all pairs (O(1) on the arena).
    pub fn total_windows(&self) -> usize {
        self.arena.len()
    }

    /// Is `sat` visible from `site` at time `t`?
    pub fn visible(&self, site: usize, sat: usize, t: f64) -> bool {
        self.window_at(site, sat, t).is_some()
    }

    /// The window containing `t`, if any (binary search).
    pub fn window_at(&self, site: usize, sat: usize, t: f64) -> Option<ContactWindow> {
        let ws = self.windows(site, sat);
        let idx = ws.partition_point(|w| w.end_s < t);
        ws.get(idx).filter(|w| w.contains(t)).copied()
    }

    /// Earliest time ≥ `t` at which `sat` is visible from `site`
    /// (start of the next window, or `t` itself if inside one).
    pub fn next_visible(&self, site: usize, sat: usize, t: f64) -> Option<f64> {
        let ws = self.windows(site, sat);
        let idx = ws.partition_point(|w| w.end_s < t);
        ws.get(idx).map(|w| w.start_s.max(t))
    }

    /// All satellites visible from `site` at `t`, in id order.
    /// Allocation-free: callers iterate (or `collect` when they truly
    /// need a `Vec`) — this sits inside broadcast/relay hot loops.
    pub fn visible_sats(&self, site: usize, t: f64) -> impl Iterator<Item = usize> + '_ {
        (0..self.n_sats).filter(move |&s| self.visible(site, s, t))
    }

    /// Earliest time ≥ `t` at which `sat` is visible from *any* site;
    /// returns `(time, site)`. Window times are asserted finite at
    /// construction, so the total-order comparison here can never meet
    /// (or be confused by) a NaN — no panic path.
    pub fn next_visible_any(&self, sat: usize, t: f64) -> Option<(f64, usize)> {
        (0..self.n_sites())
            .filter_map(|site| self.next_visible(site, sat, t).map(|tt| (tt, site)))
            .min_by(|a, b| a.0.total_cmp(&b.0))
    }

    /// Fraction of the horizon that `sat` is visible from `site`.
    pub fn visibility_fraction(&self, site: usize, sat: usize) -> f64 {
        self.windows(site, sat).iter().map(|w| w.duration_s()).sum::<f64>() / self.horizon_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::GeodeticSite;

    fn plan() -> (WalkerConstellation, ContactPlan) {
        let c = WalkerConstellation::paper();
        let sites = [GeodeticSite::rolla_hap(), GeodeticSite::portland_hap()];
        let p = ContactPlan::build(&c, &sites, 10.0, 86_400.0);
        (c, p)
    }

    fn assert_plans_bit_identical(a: &ContactPlan, b: &ContactPlan, what: &str) {
        assert_eq!(a.n_sites(), b.n_sites());
        assert_eq!(a.n_sats(), b.n_sats());
        for site in 0..a.n_sites() {
            for sat in 0..a.n_sats() {
                let (x, y) = (a.windows(site, sat), b.windows(site, sat));
                assert_eq!(x.len(), y.len(), "{what}: site {site} sat {sat}");
                for (wa, wb) in x.iter().zip(y) {
                    assert_eq!(
                        wa.start_s.to_bits(),
                        wb.start_s.to_bits(),
                        "{what}: site {site} sat {sat}"
                    );
                    assert_eq!(
                        wa.end_s.to_bits(),
                        wb.end_s.to_bits(),
                        "{what}: site {site} sat {sat}"
                    );
                }
            }
        }
    }

    #[test]
    fn consistency_with_live_predicate() {
        let (c, p) = plan();
        let site = GeodeticSite::rolla_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        // away from window edges the plan matches the live predicate
        for sat in [0usize, 13, 39] {
            for i in 0..48 {
                let t = i as f64 * 1800.0;
                let live = elevation_deg(site.position_eci(t), c.position(sat, t)) >= eff;
                let planned = p.visible(0, sat, t);
                if live != planned {
                    // tolerate only near-edge disagreement (< 60 s)
                    let near_edge = p.windows(0, sat).iter().any(|w| {
                        (w.start_s - t).abs() < 60.0 || (w.end_s - t).abs() < 60.0
                    });
                    assert!(near_edge, "sat {sat} t {t}: live {live} vs plan {planned}");
                }
            }
        }
    }

    #[test]
    fn next_visible_is_window_start_or_now() {
        let (_, p) = plan();
        let ws = p.windows(0, 0);
        assert!(!ws.is_empty());
        let w0 = ws[0];
        if w0.start_s > 10.0 {
            assert_eq!(p.next_visible(0, 0, 0.0), Some(w0.start_s));
        }
        let inside = 0.5 * (w0.start_s + w0.end_s);
        assert_eq!(p.next_visible(0, 0, inside), Some(inside));
        // after the window: the next one
        if ws.len() > 1 {
            assert_eq!(p.next_visible(0, 0, w0.end_s + 1.0), Some(ws[1].start_s));
        }
    }

    #[test]
    fn every_sat_gets_contact_within_a_day() {
        let (_, p) = plan();
        for sat in 0..40 {
            assert!(
                p.next_visible_any(sat, 0.0).is_some(),
                "sat {sat} never visible from either HAP in 24 h"
            );
        }
    }

    #[test]
    fn visible_sats_matches_visible() {
        let (_, p) = plan();
        let t = 43_200.0;
        let vs: Vec<usize> = p.visible_sats(0, t).collect();
        for sat in 0..40 {
            assert_eq!(vs.contains(&sat), p.visible(0, sat, t));
        }
    }

    #[test]
    fn visibility_fraction_sporadic() {
        let (_, p) = plan();
        for sat in 0..40 {
            let f = p.visibility_fraction(0, sat);
            assert!((0.0..0.6).contains(&f), "sat {sat} fraction {f}");
        }
    }

    #[test]
    fn fast_scan_matches_reference_on_paper_world() {
        // the full per-preset bitwise sweep lives in
        // tests/contact_equivalence.rs; this in-module smoke keeps the
        // contract close to the implementation
        let c = WalkerConstellation::paper();
        let sites = [GeodeticSite::rolla_hap(), GeodeticSite::portland_hap()];
        let reference = ContactPlan::build_reference(&c, &sites, 10.0, 43_200.0);
        let fast = ContactPlan::build_with_threads(&c, &sites, 10.0, 43_200.0, 1);
        let scan_only = ContactPlan::build_with_options(&c, &sites, 10.0, 43_200.0, 1, false);
        assert_plans_bit_identical(&fast, &reference, "analytic vs reference");
        assert_plans_bit_identical(&scan_only, &reference, "scan-only vs reference");
        assert!(fast.total_windows() > 0);
    }

    #[test]
    fn chunked_scan_matches_reference_across_chunk_boundaries() {
        // a 3-day horizon spans several 2048-step chunks; windows
        // crossing chunk boundaries must still match the reference
        let c = WalkerConstellation::paper();
        let sites = [GeodeticSite::rolla_hap()];
        let horizon = 3.0 * 86_400.0;
        let reference = ContactPlan::build_reference(&c, &sites, 10.0, horizon);
        for jobs in [1, 3] {
            let fast = ContactPlan::build_with_threads(&c, &sites, 10.0, horizon, jobs);
            assert_plans_bit_identical(&fast, &reference, "multi-chunk");
        }
    }

    #[test]
    fn never_visible_class_is_pruned_to_empty_windows() {
        // a 5°-inclination shell can never be seen from Rolla: the
        // analytic layer proves it without sampling, and the result
        // still matches the (sampling) reference bitwise
        let c = WalkerConstellation::from_shells(&[crate::orbit::ShellSpec::delta(
            2, 4, 781.25, 5.0, 1,
        )]);
        let sites = [GeodeticSite::rolla_hap()];
        let reference = ContactPlan::build_reference(&c, &sites, 10.0, 86_400.0);
        let fast = ContactPlan::build_with_threads(&c, &sites, 10.0, 86_400.0, 1);
        assert_plans_bit_identical(&fast, &reference, "pruned class");
        assert_eq!(fast.total_windows(), 0);
    }

    #[test]
    fn worker_count_clamps() {
        assert_eq!(worker_count(0, 10), 1);
        assert_eq!(worker_count(4, 10), 4);
        assert_eq!(worker_count(16, 3), 3);
        assert_eq!(worker_count(2, 0), 1);
    }

    #[test]
    fn skip_never_returns_current_index() {
        // progress guarantee: the scanner always advances
        for (e, eff) in [(45.0, 10.0), (10.0, 10.0), (-80.0, 5.0)] {
            let rate = 3.8e-3;
            assert!(next_check_index(7, e, eff, rate, SCAN_STEP_S) > 7);
        }
    }

    #[test]
    fn analytic_index_is_conservative() {
        assert_eq!(analytic_index(f64::INFINITY), usize::MAX);
        assert_eq!(analytic_index(0.0), 0);
        // t_possible = 95 s: grid index 3 (t = 90) may be visible;
        // index computed = floor(95/30) − 1 = 2, one before it
        assert_eq!(analytic_index(95.0), 2);
        assert_eq!(analytic_index(60.0), 1);
    }
}
