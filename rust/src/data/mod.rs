//! Dataset substrate: synthetic Earth-observation stand-ins + FL
//! partitioning (paper Sec. V-A; substitution documented in DESIGN.md §1).
//!
//! No network access means no MNIST/CIFAR download, so we generate
//! class-structured, separable synthetic image datasets with the same
//! geometry (28x28x1 / 32x32x3, 10 classes) — what the FL dynamics
//! under test actually depend on — and partition them IID or with the
//! paper's exact non-IID split (two orbits hold 4 classes, the other
//! three hold the remaining 6).

pub mod partition;
pub mod synth;

pub use partition::{partition, partition_planes, Partition, Shard};
pub use synth::{Dataset, DatasetKind};
