//! Delay composition t_c = t_t + t_p + t_x + t_y (paper Eqs. 7–8).

use super::link::LinkParams;
use crate::util::SPEED_OF_LIGHT_KM_S;

/// The four delay components of one transfer over one hop.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DelayBreakdown {
    /// Transmission delay t_t = bits / R.
    pub transmission_s: f64,
    /// Propagation delay t_p = d / c.
    pub propagation_s: f64,
    /// Processing at both endpoints (t_x + t_y).
    pub processing_s: f64,
}

impl DelayBreakdown {
    pub fn total_s(&self) -> f64 {
        self.transmission_s + self.propagation_s + self.processing_s
    }

    /// How long the transfer *occupies the channel*: the transmission
    /// term only. Propagation is pipelined (bits in flight don't block
    /// the transmitter) and processing happens at the endpoints, so
    /// this is the physical floor for a FIFO link queue's service time
    /// (`faults::LinkQueue`).
    pub fn occupancy_s(&self) -> f64 {
        self.transmission_s
    }
}

/// Delay of transferring `payload_bits` over `distance_km` with `p`.
pub fn delay_breakdown(p: &LinkParams, payload_bits: f64, distance_km: f64) -> DelayBreakdown {
    DelayBreakdown {
        transmission_s: payload_bits / p.data_rate_bps,
        propagation_s: distance_km / SPEED_OF_LIGHT_KM_S,
        processing_s: 2.0 * p.processing_delay_s,
    }
}

/// Total single-hop delay in seconds (paper Eq. 7).
pub fn total_delay_s(p: &LinkParams, payload_bits: f64, distance_km: f64) -> f64 {
    delay_breakdown(p, payload_bits, distance_km).total_s()
}

/// Size of a serialized model in bits: D f32 parameters + metadata.
pub fn model_bits(n_params: usize) -> f64 {
    (n_params * 32 + 1024) as f64 // 1 kbit header: the metadata tuple
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_transfer_delay_dominated_by_transmission() {
        // A ~100k-param model at 16 Mb/s is ~0.2 s of transmission,
        // while 2000 km of propagation is only ~6.7 ms.
        let p = LinkParams::default();
        let d = delay_breakdown(&p, model_bits(101_770), 2000.0);
        assert!(d.transmission_s > d.propagation_s);
        assert!((0.1..0.5).contains(&d.transmission_s), "{d:?}");
        assert!((d.propagation_s - 2000.0 / SPEED_OF_LIGHT_KM_S).abs() < 1e-12);
    }

    #[test]
    fn total_is_sum_of_parts() {
        let p = LinkParams::default();
        let d = delay_breakdown(&p, 1e6, 1500.0);
        assert!((d.total_s() - (d.transmission_s + d.propagation_s + d.processing_s)).abs() < 1e-15);
        assert_eq!(d.total_s(), total_delay_s(&p, 1e6, 1500.0));
    }

    #[test]
    fn delay_monotone_in_payload_and_distance() {
        let p = LinkParams::default();
        assert!(total_delay_s(&p, 2e6, 1000.0) > total_delay_s(&p, 1e6, 1000.0));
        assert!(total_delay_s(&p, 1e6, 2000.0) > total_delay_s(&p, 1e6, 1000.0));
    }

    #[test]
    fn model_bits_counts_header() {
        assert_eq!(model_bits(0), 1024.0);
        assert_eq!(model_bits(10), 10.0 * 32.0 + 1024.0);
    }

    #[test]
    fn composition_matches_hand_computation() {
        // Eq. 7 pinned against hand numbers for Table I's fixed rate:
        // t_t = bits/R, t_p = d/c, and *two* endpoint processing delays.
        let p = LinkParams::default(); // R = 16 Mb/s, t_x = t_y = 50 ms
        let d = delay_breakdown(&p, 8e6, 1499.0);
        assert!((d.transmission_s - 0.5).abs() < 1e-12, "8 Mb / 16 Mb/s");
        assert!((d.propagation_s - 1499.0 / SPEED_OF_LIGHT_KM_S).abs() < 1e-15);
        assert!((d.processing_s - 0.1).abs() < 1e-12, "2 x 50 ms, not 1 x");
        let want = 0.5 + 1499.0 / SPEED_OF_LIGHT_KM_S + 0.1;
        assert!((total_delay_s(&p, 8e6, 1499.0) - want).abs() < 1e-12);
    }

    #[test]
    fn occupancy_is_the_transmission_term_only() {
        let p = LinkParams::default();
        let d = delay_breakdown(&p, 8e6, 1499.0);
        assert_eq!(d.occupancy_s(), d.transmission_s);
        assert!(d.occupancy_s() < d.total_s());
        assert_eq!(delay_breakdown(&p, 0.0, 1499.0).occupancy_s(), 0.0);
    }

    #[test]
    fn zero_payload_still_pays_propagation_and_processing() {
        let p = LinkParams::default();
        let d = delay_breakdown(&p, 0.0, 1000.0);
        assert_eq!(d.transmission_s, 0.0);
        assert!(d.propagation_s > 0.0);
        assert_eq!(d.processing_s, 2.0 * p.processing_delay_s);
    }
}
