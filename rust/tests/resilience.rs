//! Integration tests of the fault-injection subsystem across
//! strategies (surrogate backend, real geometry/topology/DES):
//!
//! * non-invasiveness — zero-intensity faults leave every strategy's
//!   RunResult bit-identical to the nominal code path;
//! * determinism — the same seed reproduces bit-identical RunResults
//!   under every fault scenario (draws come only from the seeded
//!   `util::Rng`, never wall-clock);
//! * end-to-end — every fault scenario runs to completion for
//!   AsyncFLEO and two baselines, with the fault accounting populated.

use asyncfleo::config::{ExperimentConfig, PsPlacement, SchemeKind};
use asyncfleo::coordinator::{RunResult, SimEnv};
use asyncfleo::faults::{FaultConfig, FaultScenario};
use asyncfleo::fl::make_strategy;
use asyncfleo::train::SurrogateBackend;

/// The scheme/placement triples the resilience experiment sweeps.
const SCHEMES: &[(SchemeKind, PsPlacement)] = &[
    (SchemeKind::AsyncFleo, PsPlacement::TwoHaps),
    (SchemeKind::FedHap, PsPlacement::TwoHaps),
    (SchemeKind::FedSat, PsPlacement::GsNorthPole),
];

fn run_with_faults(
    scheme: SchemeKind,
    placement: PsPlacement,
    faults: FaultConfig,
    horizon_h: f64,
) -> RunResult {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.fl.scheme = scheme;
    cfg.placement = placement;
    cfg.fl.horizon_s = horizon_h * 3600.0;
    cfg.fl.max_epochs = 25;
    cfg.faults = faults;
    let mut backend = SurrogateBackend::paper_split(5, 8, false, 100);
    let mut env = SimEnv::new(&cfg, &mut backend);
    make_strategy(scheme).run(&mut env)
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.epochs, b.epochs, "{what}: epochs");
    assert_eq!(a.transfers, b.transfers, "{what}: transfers");
    assert_eq!(a.fault_stats, b.fault_stats, "{what}: fault stats");
    assert_eq!(a.curve.points.len(), b.curve.points.len(), "{what}: curve length");
    for (x, y) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(x.time_s, y.time_s, "{what}: point time");
        assert_eq!(x.accuracy, y.accuracy, "{what}: point accuracy");
        assert_eq!(x.loss, y.loss, "{what}: point loss");
    }
}

#[test]
fn zero_intensity_is_bit_identical_to_nominal_for_every_scheme() {
    for &(scheme, placement) in SCHEMES {
        let clean = run_with_faults(scheme, placement, FaultConfig::nominal(), 24.0);
        for scenario in [FaultScenario::Lossy, FaultScenario::Eclipse, FaultScenario::Churn] {
            let zero = run_with_faults(
                scheme,
                placement,
                FaultConfig::preset(scenario, 0.0),
                24.0,
            );
            assert_bit_identical(
                &clean,
                &zero,
                &format!("{scheme:?} under zero-intensity {scenario:?}"),
            );
            assert_eq!(zero.fault_stats, Default::default());
        }
    }
}

#[test]
fn same_seed_reproduces_bit_identical_faulty_runs() {
    for scenario in [
        FaultScenario::Lossy,
        FaultScenario::Eclipse,
        FaultScenario::Churn,
        FaultScenario::HapFailure,
    ] {
        let faults = FaultConfig::preset(scenario, 1.0);
        let a = run_with_faults(SchemeKind::AsyncFleo, PsPlacement::TwoHaps, faults, 24.0);
        let b = run_with_faults(SchemeKind::AsyncFleo, PsPlacement::TwoHaps, faults, 24.0);
        assert_bit_identical(&a, &b, &format!("asyncfleo under {scenario:?}"));
    }
}

#[test]
fn every_scenario_runs_end_to_end_for_ours_and_two_baselines() {
    for scenario in [
        FaultScenario::Lossy,
        FaultScenario::Eclipse,
        FaultScenario::Churn,
        FaultScenario::HapFailure,
    ] {
        for &(scheme, placement) in SCHEMES {
            let r = run_with_faults(scheme, placement, FaultConfig::preset(scenario, 1.0), 24.0);
            assert!(
                !r.curve.points.is_empty(),
                "{scheme:?} under {scenario:?} must record a curve"
            );
            assert!(
                r.final_accuracy.is_finite() && (0.0..=1.0).contains(&r.final_accuracy),
                "{scheme:?} under {scenario:?}: accuracy {}",
                r.final_accuracy
            );
        }
    }
}

#[test]
fn lossy_links_produce_retransmissions_and_extra_transfers() {
    let clean =
        run_with_faults(SchemeKind::AsyncFleo, PsPlacement::TwoHaps, FaultConfig::nominal(), 24.0);
    let lossy = run_with_faults(
        SchemeKind::AsyncFleo,
        PsPlacement::TwoHaps,
        FaultConfig::preset(FaultScenario::Lossy, 1.0),
        24.0,
    );
    assert!(
        lossy.fault_stats.retransmits > 0,
        "30% loss over a day of transfers must retransmit"
    );
    assert_eq!(clean.fault_stats.retransmits, 0);
}

#[test]
fn eclipse_outages_defer_transfers() {
    let r = run_with_faults(
        SchemeKind::AsyncFleo,
        PsPlacement::TwoHaps,
        FaultConfig::preset(FaultScenario::Eclipse, 1.0),
        24.0,
    );
    assert!(
        r.fault_stats.deferrals > 0 && r.fault_stats.deferred_s > 0.0,
        "30-min windows every 2 h must defer some transfers: {:?}",
        r.fault_stats
    );
}

#[test]
fn asyncfleo_still_learns_under_full_churn() {
    // The headline resilience property: with satellites dropping out
    // for hours at a time, the asynchronous design keeps aggregating
    // whatever arrives and still improves on the untrained model.
    let r = run_with_faults(
        SchemeKind::AsyncFleo,
        PsPlacement::TwoHaps,
        FaultConfig::preset(FaultScenario::Churn, 1.0),
        48.0,
    );
    let first = r.curve.points.first().expect("initial eval").accuracy;
    assert!(r.epochs >= 1, "aggregation must still happen under churn");
    assert!(
        r.final_accuracy > first + 0.1,
        "must learn despite churn: {} -> {}",
        first,
        r.final_accuracy
    );
}
