//! Fault-scenario configuration: named presets + the raw knobs.
//!
//! A [`FaultConfig`] is a plain bag of numbers (so it round-trips
//! through the TOML subset and compares with `PartialEq`); the named
//! [`FaultScenario`] presets are constructors scaled by an `intensity`
//! in `[0, 1]`. Intensity 0 of *any* scenario is exactly
//! [`FaultConfig::nominal`] — the provably fault-free configuration.

/// Named resilience scenarios (the `experiments::resilience` sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultScenario {
    /// No impairments: the original perfect-network code path.
    Nominal,
    /// Per-link packet loss with retransmission (extra delay+transfers).
    Lossy,
    /// Periodic eclipse / solar-conjunction outage windows that black
    /// out SAT↔HAP contacts (and ISL contacts, per orbit).
    Eclipse,
    /// Satellite dropouts and rejoins: training results can be lost and
    /// deliveries deferred past a dead node's downtime.
    Churn,
    /// HAP failures with ring re-healing in `topology::HapRing`.
    HapFailure,
}

impl FaultScenario {
    /// All scenarios, in sweep order.
    pub const ALL: &'static [FaultScenario] = &[
        FaultScenario::Nominal,
        FaultScenario::Lossy,
        FaultScenario::Eclipse,
        FaultScenario::Churn,
        FaultScenario::HapFailure,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "nominal" => FaultScenario::Nominal,
            "lossy" => FaultScenario::Lossy,
            "eclipse" => FaultScenario::Eclipse,
            "churn" => FaultScenario::Churn,
            "hap-failure" | "hap_failure" => FaultScenario::HapFailure,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultScenario::Nominal => "nominal",
            FaultScenario::Lossy => "lossy",
            FaultScenario::Eclipse => "eclipse",
            FaultScenario::Churn => "churn",
            FaultScenario::HapFailure => "hap-failure",
        }
    }
}

/// The raw fault-injection knobs. A zero value disables the
/// corresponding impairment; [`FaultConfig::is_nop`] true means the
/// whole subsystem stays out of the hot path entirely.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Per-attempt packet-loss probability on every link transfer.
    pub loss_prob: f64,
    /// Cap on retransmission attempts per transfer.
    pub max_retransmits: u32,
    /// Fixed extra wait before each retransmission, seconds (ARQ
    /// turnaround), on top of re-sending the payload.
    pub retransmit_backoff_s: f64,
    /// Eclipse/outage cycle period, seconds (0 = no outages).
    pub outage_period_s: f64,
    /// Outage window length within each period, seconds.
    pub outage_duration_s: f64,
    /// Outages also black out intra-orbit ISL hops (per-orbit windows).
    pub isl_outage: bool,
    /// Mean time between satellite failures, seconds (0 = no churn).
    pub sat_mtbf_s: f64,
    /// Mean satellite downtime per failure, seconds.
    pub sat_mttr_s: f64,
    /// Mean time between HAP failures, seconds (0 = no HAP faults).
    pub hap_mtbf_s: f64,
    /// Mean HAP downtime per failure, seconds.
    pub hap_mttr_s: f64,
    /// Typed per-ISL-edge outage cycle period, seconds (0 = none).
    /// Unlike `isl_outage` (which blacks out whole orbits alongside
    /// eclipse windows), these windows hit individual graph edges with
    /// a per-edge deterministic phase.
    pub isl_edge_outage_period_s: f64,
    /// Per-ISL-edge outage window length within each period, seconds.
    pub isl_edge_outage_duration_s: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self::nominal()
    }
}

impl FaultConfig {
    /// The perfect network: every impairment off.
    pub fn nominal() -> Self {
        FaultConfig {
            loss_prob: 0.0,
            max_retransmits: 0,
            retransmit_backoff_s: 0.0,
            outage_period_s: 0.0,
            outage_duration_s: 0.0,
            isl_outage: false,
            sat_mtbf_s: 0.0,
            sat_mttr_s: 0.0,
            hap_mtbf_s: 0.0,
            hap_mttr_s: 0.0,
            isl_edge_outage_period_s: 0.0,
            isl_edge_outage_duration_s: 0.0,
        }
    }

    /// A named scenario scaled by `intensity` in `[0, 1]`. Intensity 0
    /// always yields [`Self::nominal`].
    pub fn preset(scenario: FaultScenario, intensity: f64) -> Self {
        let x = intensity.clamp(0.0, 1.0);
        let mut cfg = Self::nominal();
        if x == 0.0 {
            return cfg;
        }
        match scenario {
            FaultScenario::Nominal => {}
            FaultScenario::Lossy => {
                // up to 30% per-attempt loss at full intensity
                cfg.loss_prob = 0.3 * x;
                cfg.max_retransmits = 4;
                cfg.retransmit_backoff_s = 0.5;
            }
            FaultScenario::Eclipse => {
                // one outage window per ~2 h cycle, up to 30 min long
                cfg.outage_period_s = 7200.0;
                cfg.outage_duration_s = 1800.0 * x;
                cfg.isl_outage = true;
            }
            FaultScenario::Churn => {
                // at full intensity a satellite fails every ~6 h on
                // average and stays dark ~2 h
                cfg.sat_mtbf_s = 21600.0 / x;
                cfg.sat_mttr_s = 7200.0;
            }
            FaultScenario::HapFailure => {
                // at full intensity one HAP failure every ~8 h, down
                // ~2 h; mild link loss rides along (degraded backhaul)
                cfg.hap_mtbf_s = 28800.0 / x;
                cfg.hap_mttr_s = 7200.0;
                cfg.loss_prob = 0.05 * x;
                cfg.max_retransmits = 2;
                cfg.retransmit_backoff_s = 0.5;
            }
        }
        cfg
    }

    /// True when every impairment is disabled — the fault plan then
    /// never touches the delay path or the RNG.
    pub fn is_nop(&self) -> bool {
        self.loss_prob <= 0.0
            && (self.outage_period_s <= 0.0 || self.outage_duration_s <= 0.0)
            && self.sat_mtbf_s <= 0.0
            && self.hap_mtbf_s <= 0.0
            && (self.isl_edge_outage_period_s <= 0.0 || self.isl_edge_outage_duration_s <= 0.0)
    }

    /// Validate invariants; returns a list of problems (empty = OK).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if !(0.0..1.0).contains(&self.loss_prob) {
            errs.push(format!("faults.loss_prob {} out of [0, 1)", self.loss_prob));
        }
        if self.loss_prob > 0.0 && self.max_retransmits == 0 {
            errs.push("faults.loss_prob needs max_retransmits > 0".into());
        }
        if self.outage_period_s > 0.0 && self.outage_duration_s >= self.outage_period_s {
            errs.push(format!(
                "faults.outage_duration_s {} must be shorter than the period {}",
                self.outage_duration_s, self.outage_period_s
            ));
        }
        if self.sat_mtbf_s > 0.0 && self.sat_mttr_s <= 0.0 {
            errs.push("faults.sat_mtbf_s needs sat_mttr_s > 0".into());
        }
        if self.hap_mtbf_s > 0.0 && self.hap_mttr_s <= 0.0 {
            errs.push("faults.hap_mtbf_s needs hap_mttr_s > 0".into());
        }
        if self.isl_edge_outage_period_s > 0.0
            && self.isl_edge_outage_duration_s >= self.isl_edge_outage_period_s
        {
            errs.push(format!(
                "faults.isl_edge_outage_duration_s {} must be shorter than the period {}",
                self.isl_edge_outage_duration_s, self.isl_edge_outage_period_s
            ));
        }
        for (name, v) in [
            ("retransmit_backoff_s", self.retransmit_backoff_s),
            ("outage_period_s", self.outage_period_s),
            ("outage_duration_s", self.outage_duration_s),
            ("sat_mtbf_s", self.sat_mtbf_s),
            ("sat_mttr_s", self.sat_mttr_s),
            ("hap_mtbf_s", self.hap_mtbf_s),
            ("hap_mttr_s", self.hap_mttr_s),
            ("isl_edge_outage_period_s", self.isl_edge_outage_period_s),
            ("isl_edge_outage_duration_s", self.isl_edge_outage_duration_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                errs.push(format!("faults.{name} {v} must be finite and >= 0"));
            }
        }
        errs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_is_nop_and_valid() {
        let c = FaultConfig::nominal();
        assert!(c.is_nop());
        assert!(c.validate().is_empty());
    }

    #[test]
    fn zero_intensity_of_any_scenario_is_nominal() {
        for &s in FaultScenario::ALL {
            assert_eq!(FaultConfig::preset(s, 0.0), FaultConfig::nominal(), "{s:?}");
        }
    }

    #[test]
    fn presets_are_active_and_valid() {
        for &s in FaultScenario::ALL {
            let c = FaultConfig::preset(s, 1.0);
            assert!(c.validate().is_empty(), "{s:?}: {:?}", c.validate());
            if s != FaultScenario::Nominal {
                assert!(!c.is_nop(), "{s:?} at full intensity must be active");
            }
        }
    }

    #[test]
    fn intensity_scales_monotonically() {
        let half = FaultConfig::preset(FaultScenario::Lossy, 0.5);
        let full = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        assert!(half.loss_prob < full.loss_prob);
        let ch = FaultConfig::preset(FaultScenario::Churn, 0.5);
        let cf = FaultConfig::preset(FaultScenario::Churn, 1.0);
        assert!(ch.sat_mtbf_s > cf.sat_mtbf_s, "higher intensity = more frequent failures");
    }

    #[test]
    fn scenario_parse_roundtrip() {
        for &s in FaultScenario::ALL {
            assert_eq!(FaultScenario::parse(s.name()), Some(s));
        }
        assert_eq!(FaultScenario::parse("bogus"), None);
    }

    #[test]
    fn validation_catches_bad_knobs() {
        let mut c = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        c.loss_prob = 1.5;
        c.max_retransmits = 0;
        assert_eq!(c.validate().len(), 2, "{:?}", c.validate());
        let mut c = FaultConfig::preset(FaultScenario::Eclipse, 1.0);
        c.outage_duration_s = c.outage_period_s + 1.0;
        assert_eq!(c.validate().len(), 1);
    }

    #[test]
    fn isl_edge_outage_knobs_activate_and_validate() {
        let mut c = FaultConfig::nominal();
        c.isl_edge_outage_period_s = 3600.0;
        assert!(c.is_nop(), "period without duration stays a no-op");
        c.isl_edge_outage_duration_s = 600.0;
        assert!(!c.is_nop());
        assert!(c.validate().is_empty(), "{:?}", c.validate());
        c.isl_edge_outage_duration_s = 3700.0;
        assert_eq!(c.validate().len(), 1, "duration must fit inside the period");
        c.isl_edge_outage_duration_s = f64::NAN;
        assert!(!c.validate().is_empty());
    }
}
