//! The HAP "backbone" ring: roles, arcs, relay routing, and re-healing
//! around failed nodes (fault injection, `crate::faults`).

/// The ring of HAPs with current source/sink designation and a
/// liveness mask.
///
/// Indices are positions on the ring (HAPs are placed on the ring in
/// construction order; with the paper's 2-HAP setup the ring degenerates
/// to a single bidirectional link, and with 1 HAP to a no-op).
///
/// Failed HAPs ([`Self::set_alive`]) are routed *around*: arcs, relay
/// plans and role assignment all operate on the compacted ring of alive
/// nodes, preserving construction order — the "re-healed" ring. With
/// every node alive the behaviour is bit-identical to the pre-faults
/// ring.
#[derive(Clone, Debug)]
pub struct HapRing {
    n: usize,
    source: usize,
    sink: usize,
    alive: Vec<bool>,
}

impl HapRing {
    /// Build a ring of `n` HAPs, all alive. The initial source is index
    /// 0 and the sink is the farthest node around the ring (paper
    /// Sec. IV-B1).
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "ring needs at least one HAP");
        let source = 0;
        let sink = if n == 1 { 0 } else { n / 2 };
        HapRing { n, source, sink, alive: vec![true; n] }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn source(&self) -> usize {
        self.source
    }

    pub fn sink(&self) -> usize {
        self.sink
    }

    pub fn is_alive(&self, i: usize) -> bool {
        self.alive[i]
    }

    /// Number of currently-alive HAPs (always ≥ 1).
    pub fn alive_len(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Mark HAP `i` failed (`up = false`) or recovered (`up = true`)
    /// and re-heal: roles held by a dead node move to alive ones. The
    /// last alive HAP cannot be failed (the request is ignored) — a
    /// parameter-server constellation with zero PSs is not a scenario,
    /// it is the end of the experiment.
    pub fn set_alive(&mut self, i: usize, up: bool) {
        assert!(i < self.n);
        if self.alive[i] == up {
            return;
        }
        if !up && self.alive_len() == 1 {
            return;
        }
        self.alive[i] = up;
        self.reheal();
    }

    /// Re-assign source/sink after a liveness change: a dead source
    /// moves clockwise to the next alive node, and the sink moves to
    /// the alive node farthest from the source along the healed ring.
    fn reheal(&mut self) {
        if !self.alive[self.source] {
            self.source = (1..self.n)
                .map(|k| (self.source + k) % self.n)
                .find(|&j| self.alive[j])
                .expect("at least one HAP alive");
        }
        if self.alive_len() == 1 {
            self.sink = self.source;
        } else if !self.alive[self.sink] || self.sink == self.source {
            self.sink = self.farthest_alive_from(self.source);
        }
    }

    /// Alive nodes plus `extras`, in ring (construction) order — the
    /// compacted ring all routing operates on.
    fn members_with(&self, extras: &[usize]) -> Vec<usize> {
        (0..self.n).filter(|&j| self.alive[j] || extras.contains(&j)).collect()
    }

    /// The alive node with the greatest min-arc distance from `from`
    /// on the healed ring (first in ring order on ties).
    fn farthest_alive_from(&self, from: usize) -> usize {
        let m = self.members_with(&[from]);
        let len = m.len();
        let pf = m.iter().position(|&x| x == from).expect("from in members");
        let mut best = from;
        let mut best_d = 0usize;
        for (p, &j) in m.iter().enumerate() {
            if j == from || !self.alive[j] {
                continue;
            }
            let cw = (p + len - pf) % len;
            let d = cw.min(len - cw);
            if d > best_d {
                best_d = d;
                best = j;
            }
        }
        best
    }

    /// Ring neighbours (prev, next) of HAP `i` on the healed ring
    /// (dead nodes are skipped).
    pub fn neighbors(&self, i: usize) -> (usize, usize) {
        assert!(i < self.n);
        let m = self.members_with(&[i]);
        let len = m.len();
        let p = m.iter().position(|&x| x == i).expect("i in members");
        (m[(p + len - 1) % len], m[(p + 1) % len])
    }

    /// Swap source and sink roles (done after each aggregation so the
    /// fresh global model flows back along the reverse path, IV-B3).
    pub fn swap_roles(&mut self) {
        std::mem::swap(&mut self.source, &mut self.sink);
    }

    /// Next hop from `i` toward `target` along the shorter healed arc
    /// (ties broken clockwise). Returns `None` when already there.
    /// Dead endpoints keep their ring position (a recovering or
    /// draining node can still be routed to/from).
    pub fn next_hop_toward(&self, i: usize, target: usize) -> Option<usize> {
        assert!(i < self.n && target < self.n);
        if i == target {
            return None;
        }
        let m = self.members_with(&[i, target]);
        let len = m.len();
        let pi = m.iter().position(|&x| x == i).expect("i in members");
        let pt = m.iter().position(|&x| x == target).expect("target in members");
        let cw = (pt + len - pi) % len;
        let ccw = len - cw;
        Some(if cw <= ccw { m[(pi + 1) % len] } else { m[(pi + len - 1) % len] })
    }

    /// The broadcast relay plan from `from`: each entry is
    /// `(hap, forwards_to)` in BFS order along both healed arcs; the
    /// sink forwards to nobody (Sec. IV-B1: "stop relaying at the
    /// sink"). Every *alive* HAP appears exactly once; dead HAPs are
    /// routed around and receive nothing.
    pub fn relay_plan(&self, from: usize) -> Vec<(usize, Vec<usize>)> {
        assert!(from < self.n);
        let m = self.members_with(&[from]);
        let len = m.len();
        if len == 1 {
            return vec![(from, vec![])];
        }
        let pf = m.iter().position(|&x| x == from).expect("from in members");
        let cw_from = |p: usize| (p + len - pf) % len;
        // Each node p != pf receives from exactly one parent: the
        // neighbour one hop closer to `from` along p's shorter arc
        // (clockwise on ties). Invert the parent relation into
        // forwarding lists, ordered by arc distance (= relay order).
        let mut order: Vec<usize> = (0..len).collect();
        order.sort_by_key(|&p| {
            let cw = cw_from(p);
            cw.min(len - cw)
        });
        let mut fwd: Vec<Vec<usize>> = vec![Vec::new(); len];
        for &p in &order {
            if p == pf {
                continue;
            }
            let cw = cw_from(p);
            let ccw = len - cw;
            let parent = if cw <= ccw {
                (p + len - 1) % len // came from the cw direction
            } else {
                (p + 1) % len // came from the ccw direction
            };
            fwd[parent].push(p);
        }
        order
            .iter()
            .map(|&p| (m[p], fwd[p].iter().map(|&q| m[q]).collect()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn single_hap_degenerate() {
        let r = HapRing::new(1);
        assert_eq!(r.source(), 0);
        assert_eq!(r.sink(), 0);
        assert_eq!(r.next_hop_toward(0, 0), None);
        assert_eq!(r.relay_plan(0), vec![(0, vec![])]);
    }

    #[test]
    fn two_haps_link() {
        let r = HapRing::new(2);
        assert_eq!(r.sink(), 1);
        assert_eq!(r.next_hop_toward(0, 1), Some(1));
        assert_eq!(r.next_hop_toward(1, 0), Some(0));
    }

    #[test]
    fn sink_is_farthest() {
        for n in 1..10 {
            let r = HapRing::new(n);
            let d = |i: usize, j: usize| {
                let cw = (j + n - i) % n;
                cw.min(n - cw)
            };
            let dist_sink = d(r.source(), r.sink());
            for j in 0..n {
                assert!(d(r.source(), j) <= dist_sink);
            }
        }
    }

    #[test]
    fn swap_roles_swaps() {
        let mut r = HapRing::new(4);
        let (s0, k0) = (r.source(), r.sink());
        r.swap_roles();
        assert_eq!(r.source(), k0);
        assert_eq!(r.sink(), s0);
    }

    #[test]
    fn next_hop_reaches_target() {
        for n in 2..9 {
            let r = HapRing::new(n);
            for i in 0..n {
                for j in 0..n {
                    let mut cur = i;
                    let mut hops = 0;
                    while cur != j {
                        cur = r.next_hop_toward(cur, j).unwrap();
                        hops += 1;
                        assert!(hops <= n, "routing loop {i}->{j}");
                    }
                    assert!(hops <= n / 2 + 1, "not shortest arc: {i}->{j} took {hops}");
                }
            }
        }
    }

    #[test]
    fn relay_plan_covers_all_once() {
        for n in 1..9 {
            let r = HapRing::new(n);
            for from in 0..n {
                let plan = r.relay_plan(from);
                let nodes: HashSet<usize> = plan.iter().map(|(h, _)| *h).collect();
                assert_eq!(nodes.len(), n, "n={n} from={from}");
                // Each non-origin node receives the model exactly once.
                let mut recv_count = vec![0usize; n];
                for (_, fwd) in &plan {
                    for &t in fwd {
                        recv_count[t] += 1;
                    }
                }
                for j in 0..n {
                    if j == from {
                        assert_eq!(recv_count[j], 0, "origin must not receive");
                    } else {
                        assert_eq!(recv_count[j], 1, "n={n} from={from} node={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn relay_plan_first_entry_is_origin() {
        let r = HapRing::new(5);
        let plan = r.relay_plan(2);
        assert_eq!(plan[0].0, 2);
        assert_eq!(plan[0].1.len(), 2, "origin transmits to both neighbors");
    }

    // --- re-healing (fault injection) ---

    #[test]
    fn failing_the_sink_moves_it_to_an_alive_node() {
        let mut r = HapRing::new(4); // source 0, sink 2
        r.set_alive(2, false);
        assert!(r.is_alive(r.sink()), "sink must re-heal onto an alive node");
        assert_ne!(r.sink(), 2);
        assert_eq!(r.source(), 0, "source untouched");
        assert_eq!(r.alive_len(), 3);
    }

    #[test]
    fn failing_the_source_moves_it_clockwise() {
        let mut r = HapRing::new(4);
        r.set_alive(0, false);
        assert_eq!(r.source(), 1, "next alive clockwise");
        assert!(r.is_alive(r.sink()));
        assert_ne!(r.source(), r.sink());
    }

    #[test]
    fn healed_ring_routes_around_dead_node() {
        let mut r = HapRing::new(4);
        r.set_alive(1, false);
        // 0 -> 2 now hops directly (1 is skipped)
        assert_eq!(r.next_hop_toward(0, 2), Some(2));
        let (prev, next) = r.neighbors(0);
        assert_eq!(next, 2);
        assert_eq!(prev, 3);
    }

    #[test]
    fn relay_plan_skips_dead_nodes() {
        let mut r = HapRing::new(5);
        r.set_alive(3, false);
        let plan = r.relay_plan(0);
        let nodes: Vec<usize> = plan.iter().map(|(h, _)| *h).collect();
        assert!(!nodes.contains(&3), "dead HAP must not relay");
        assert_eq!(nodes.len(), 4);
        let mut recv = vec![0usize; 5];
        for (_, fwd) in &plan {
            for &t in fwd {
                recv[t] += 1;
            }
        }
        assert_eq!(recv, vec![0, 1, 1, 0, 1]);
    }

    #[test]
    fn last_alive_hap_cannot_fail() {
        let mut r = HapRing::new(2);
        r.set_alive(0, false);
        assert_eq!(r.alive_len(), 1);
        r.set_alive(1, false); // ignored
        assert!(r.is_alive(1));
        assert_eq!(r.source(), 1);
        assert_eq!(r.sink(), 1);
    }

    #[test]
    fn recovery_rejoins_the_ring() {
        let mut r = HapRing::new(4);
        r.set_alive(2, false);
        r.set_alive(2, true);
        assert_eq!(r.alive_len(), 4);
        let plan = r.relay_plan(r.source());
        assert_eq!(plan.len(), 4, "recovered HAP relays again");
        // roles still on alive, distinct nodes
        assert!(r.is_alive(r.source()) && r.is_alive(r.sink()));
        assert_ne!(r.source(), r.sink());
    }

    #[test]
    fn roles_stay_valid_under_churn_sequences() {
        let mut r = HapRing::new(6);
        for &(i, up) in
            &[(3usize, false), (0, false), (3, true), (1, false), (5, false), (0, true)]
        {
            r.set_alive(i, up);
            assert!(r.is_alive(r.source()), "source alive after ({i},{up})");
            assert!(r.is_alive(r.sink()), "sink alive after ({i},{up})");
            if r.alive_len() > 1 {
                assert_ne!(r.source(), r.sink());
            }
        }
    }
}
