//! The paper's satellite metadata tuple ⟨ID, size, loc, ts, epoch⟩
//! (Sec. IV-C1) attached to every relayed local model.

/// Metadata accompanying a local model on its way to the PS.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelMetadata {
    /// Satellite ID (dense index; the paper's (orbit#, sat#) maps to it).
    pub sat_id: usize,
    /// Orbit index — used for orbit-wise partial models (Eq. 10–11).
    pub orbit: usize,
    /// Training-data size of the satellite (paper `size`, enters Eq. 12/13).
    pub data_size: usize,
    /// Angular position (argument of latitude) when transmitted, rad
    /// (paper `loc`; the PS uses it to predict the next visit).
    pub loc_rad: f64,
    /// Simulated timestamp of transmission (paper `ts`), seconds.
    pub ts_s: f64,
    /// Last global epoch this satellite's model was trained against
    /// (paper `epoch`; freshness = epoch == current β).
    pub epoch: u64,
}

impl ModelMetadata {
    /// Freshness test (Sec. IV-C1): trained against the current global
    /// epoch?
    pub fn is_fresh(&self, current_epoch: u64) -> bool {
        self.epoch == current_epoch
    }

    /// Staleness ratio k_n/β of Eq. 13 (1.0 when fresh; →0 with age).
    /// β = 0 is defined as fresh (first epoch has nothing to be stale
    /// against).
    pub fn staleness_ratio(&self, current_epoch: u64) -> f64 {
        if current_epoch == 0 {
            1.0
        } else {
            (self.epoch.min(current_epoch) as f64) / (current_epoch as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn md(epoch: u64) -> ModelMetadata {
        ModelMetadata { sat_id: 1, orbit: 0, data_size: 100, loc_rad: 0.0, ts_s: 0.0, epoch }
    }

    #[test]
    fn freshness() {
        assert!(md(5).is_fresh(5));
        assert!(!md(4).is_fresh(5));
    }

    #[test]
    fn staleness_ratio_bounds() {
        assert_eq!(md(5).staleness_ratio(5), 1.0);
        assert_eq!(md(0).staleness_ratio(0), 1.0);
        assert_eq!(md(2).staleness_ratio(4), 0.5);
        // future-tagged models clamp to 1 (defensive)
        assert_eq!(md(9).staleness_ratio(4), 1.0);
    }
}
