//! Satellite grouping demo (paper Sec. IV-C1, Fig. 5): infer data
//! distributions from model weights alone.
//!
//! Trains one local model per orbit on the paper's non-IID split (two
//! orbits hold classes 0–3, three orbits hold classes 4–9), computes
//! each orbit partial model's weight divergence to w⁰ on the AOT
//! Pallas `dist` kernel, and shows that the grouping algorithm
//! recovers the hidden 2-group structure without ever seeing data.
//!
//! ```bash
//! make artifacts && cargo run --release --example non_iid_grouping
//! ```

use asyncfleo::data::{synth, DatasetKind, Partition};
use asyncfleo::fl::grouping::{orbit_partial_model, GroupingState};
use asyncfleo::model::ModelParams;
use asyncfleo::runtime::Runtime;
use asyncfleo::train::{Backend, PjrtBackend};
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let runtime = Rc::new(Runtime::new(Runtime::default_dir())?);
    let (train, test) = synth::generate_split(DatasetKind::Digits, 7, 2400, 400);
    let mut backend = PjrtBackend::new(
        runtime,
        "mlp_digits",
        train,
        test,
        Partition::NonIidPaper,
        5,
        8,
        0.05,
        7,
    )?;

    let w0 = backend.init_global(0);
    println!("training one representative satellite per orbit (non-IID split)...");

    // per-orbit: train 2 members, build the orbit partial model (Eq. 11)
    let mut partials: Vec<ModelParams> = Vec::new();
    for orbit in 0..5 {
        let sats = [orbit * 8, orbit * 8 + 3];
        let mut models = Vec::new();
        let mut sizes = Vec::new();
        for &s in &sats {
            let (m, loss) = backend.train_local(s, &w0, 2);
            println!("  orbit {orbit} sat {s:>2}: local loss {loss:.4}");
            sizes.push(backend.shard_size(s));
            models.push(m);
        }
        let refs: Vec<&ModelParams> = models.iter().collect();
        partials.push(orbit_partial_model(&refs, &sizes));
    }

    // weight divergence to w0 on the Pallas dist kernel
    let refs: Vec<&ModelParams> = partials.iter().collect();
    let dists = backend.distances(&refs, &w0);
    println!("\norbit  ||S'_o - w0||   classes held");
    for (o, d) in dists.iter().enumerate() {
        let classes = if o < 2 { "0-3 (4 classes)" } else { "4-9 (6 classes)" };
        println!("{o:>5}  {d:>12.4}   {classes}");
    }

    // pairwise divergences between orbit partials (the discriminative
    // signal; the scalar distance-to-w0 bands overlap in practice)
    println!("\npairwise ||S'_a - S'_b|| (normalized by d0):");
    for a in 0..5 {
        let row = backend.distances(&refs, &partials[a]);
        let line: Vec<String> =
            row.iter().map(|&d| format!("{:5.2}", d / dists[a])).collect();
        println!("  orbit {a}: [{}]", line.join(" "));
    }

    // grouping (Sec. IV-C1; pairwise-divergence clustering, see the
    // reproduction note in fl::grouping)
    let mut grouping = GroupingState::new(5);
    let items: Vec<(usize, &ModelParams, f64)> = partials
        .iter()
        .enumerate()
        .map(|(o, p)| (o, p, dists[o]))
        .collect();
    grouping.assign_batch(&items);
    println!("\ngrouping result ({} groups):", grouping.n_groups());
    for o in 0..5 {
        println!("  orbit {o} -> group {}", grouping.group_of(o).unwrap());
    }

    let g0 = grouping.group_of(0);
    let ok = grouping.group_of(1) == g0
        && (2..5).all(|o| grouping.group_of(o) != g0)
        && (3..5).all(|o| grouping.group_of(o) == grouping.group_of(2));
    println!(
        "\nhidden structure (orbits {{0,1}} vs {{2,3,4}}) recovered: {}",
        if ok { "YES" } else { "NO (distances too noisy — try more training)" }
    );
    Ok(())
}
