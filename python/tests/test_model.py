"""L2 correctness: model graphs — shapes, packing, learning, eval."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model

jax.config.update("jax_platform_name", "cpu")

VARIANTS = [(k, d) for k in ("mlp", "cnn") for d in ("digits", "cifar")]


def _feat(ds):
    d = model.DATASETS[ds]
    return d["h"] * d["w"] * d["c"]


@pytest.mark.parametrize("kind,ds", VARIANTS)
def test_param_dim_matches_layer_shapes(kind, ds):
    total = 0
    for _, shape, _ in model.layer_shapes(kind, ds):
        n = 1
        for s in shape:
            n *= s
        total += n
    assert model.param_dim(kind, ds) == total


@pytest.mark.parametrize("kind,ds", VARIANTS)
def test_pack_unpack_roundtrip(kind, ds):
    dim = model.param_dim(kind, ds)
    flat = jnp.arange(dim, dtype=jnp.float32)
    tree = model.unpack(flat, kind, ds)
    back = model.pack(tree, kind, ds)
    assert_allclose(np.asarray(back), np.asarray(flat))


@pytest.mark.parametrize("kind,ds", VARIANTS)
def test_init_deterministic_and_shaped(kind, ds):
    f = jax.jit(model.make_init_fn(kind, ds))
    p1, p2 = f(7), f(7)
    assert p1.shape == (model.param_dim(kind, ds),)
    assert_allclose(np.asarray(p1), np.asarray(p2))
    p3 = f(8)
    assert float(jnp.max(jnp.abs(p1 - p3))) > 0.0


@pytest.mark.parametrize("kind,ds", VARIANTS)
def test_init_bias_zero_weights_scaled(kind, ds):
    p = jax.jit(model.make_init_fn(kind, ds))(0)
    tree = model.unpack(p, kind, ds)
    for name, shape, fan_in in model.layer_shapes(kind, ds):
        arr = np.asarray(tree[name])
        if len(shape) == 1:
            assert_allclose(arr, np.zeros(shape))
        else:
            # He-normal: std should be near sqrt(2/fan_in)
            expect = np.sqrt(2.0 / fan_in)
            assert 0.3 * expect < arr.std() < 3.0 * expect


@pytest.mark.parametrize("kind,ds", VARIANTS)
def test_forward_shapes(kind, ds):
    p = jax.jit(model.make_init_fn(kind, ds))(0)
    x = jnp.zeros((5, _feat(ds)), jnp.float32)
    logits = model.forward(p, x, kind, ds)
    assert logits.shape == (5, 10)


def test_conv_matches_lax_conv():
    from jax import lax

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(3, 3, 3, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    want = lax.conv_general_dilated(
        x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + b[None, None, None, :]
    want = jnp.maximum(want, 0.0)
    got = model._conv(x, k, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 9),
    h=st.sampled_from([4, 8]),
    c=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_patch_count_and_center(b, h, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, h, h, c)).astype(np.float32))
    cols = model._im2col3(x)
    assert cols.shape == (b * h * h, 9 * c)
    # the center column (di=dj=1) is the unpadded input itself
    center = np.asarray(cols).reshape(b, h, h, 9, c)[:, :, :, 4, :]
    assert_allclose(center, np.asarray(x), rtol=1e-6)


@pytest.mark.parametrize("kind,ds", VARIANTS)
def test_training_reduces_loss(kind, ds):
    """A few dispatches on separable synthetic data must cut loss >2x."""
    feat = _feat(ds)
    rng = np.random.default_rng(1)
    protos = rng.normal(size=(10, feat)).astype(np.float32)
    y = rng.integers(0, 10, 320)
    xs = jnp.asarray(protos[y] + 0.4 * rng.normal(size=(320, feat)).astype(np.float32))
    ys = jnp.asarray(np.eye(10, dtype=np.float32)[y])

    p = jax.jit(model.make_init_fn(kind, ds))(0)
    train = jax.jit(model.make_train_fn(kind, ds, 10, 32))
    p, l0 = train(p, xs, ys, jnp.float32(0.05))
    l_first = float(l0)
    for _ in range(3):
        p, l = train(p, xs, ys, jnp.float32(0.05))
    assert float(l) < l_first / 2.0


def test_eval_counts_and_padding():
    kind, ds = "mlp", "digits"
    p = jax.jit(model.make_init_fn(kind, ds))(0)
    ev = jax.jit(model.make_eval_fn(kind, ds))
    x = jnp.zeros((8, 784), jnp.float32)
    y = jnp.zeros((8, 10), jnp.float32)
    # all-padding chunk counts zero correct, zero loss
    c, ls = ev(p, x, y)
    assert float(c) == 0.0 and float(ls) == 0.0
    # real rows count at most their number
    y = y.at[0, 3].set(1.0).at[1, 4].set(1.0)
    c, _ = ev(p, x, y)
    assert 0.0 <= float(c) <= 2.0


def test_train_then_eval_accuracy_high():
    kind, ds = "mlp", "digits"
    feat = _feat(ds)
    rng = np.random.default_rng(2)
    protos = rng.normal(size=(10, feat)).astype(np.float32)

    def make(n):
        y = rng.integers(0, 10, n)
        x = protos[y] + 0.4 * rng.normal(size=(n, feat)).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(np.eye(10, dtype=np.float32)[y])

    p = jax.jit(model.make_init_fn(kind, ds))(0)
    train = jax.jit(model.make_train_fn(kind, ds, 10, 32))
    ev = jax.jit(model.make_eval_fn(kind, ds))
    xs, ys = make(320)
    for _ in range(5):
        p, _ = train(p, xs, ys, jnp.float32(0.05))
    xt, yt = make(256)
    c, _ = ev(p, xt, yt)
    assert float(c) / 256 > 0.9
