//! ISL topology benchmark: graph construction cost, shortest-delay
//! route throughput, and the sink-satellite scheme's whole-run
//! wall-time against AsyncFLEO, per scenario preset. Numbers are
//! determinism-gated: the router must reproduce bit-identical distance
//! tables and sinksat must reproduce bit-identical curves before
//! anything is timed.
//!
//! Emits `BENCH_topology.json` (graph builds/sec per topology, route
//! queries/sec, sinksat vs AsyncFLEO run seconds) so the graph
//! subsystem's perf trajectory is tracked across PRs.
//!
//! Run: `cargo bench --offline --bench bench_topology`
//!      (`-- --presets paper-40,starlink-lite` selects presets; default
//!      is paper-40 + the two-shell starlink-lite)

use asyncfleo::bench::{bench, print_header, BenchConfig};
use asyncfleo::comm::LinkParams;
use asyncfleo::config::{ExperimentConfig, SchemeKind};
use asyncfleo::coordinator::{Geometry, RunResult, SimEnv};
use asyncfleo::fl::make_strategy;
use asyncfleo::orbit::WalkerConstellation;
use asyncfleo::scenario::ScenarioRegistry;
use asyncfleo::testkit::assert_runs_identical;
use asyncfleo::topology::{IslConfig, IslGraph, IslTopology};
use asyncfleo::train::SurrogateBackend;
use std::io::Write;
use std::time::Instant;

/// Route queries per timed iteration.
const ROUTE_QUERIES: usize = 200;
/// Payload used for route-delay snapshots (1 Mbit model).
const PAYLOAD_BITS: f64 = 1.0e6;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let presets: Vec<String> = match args.iter().position(|a| a == "--presets") {
        Some(i) => {
            let value = args
                .get(i + 1)
                .unwrap_or_else(|| panic!("--presets needs a comma-separated preset list"));
            value.split(',').map(str::to_string).collect()
        }
        None => vec!["paper-40".to_string(), "starlink-lite".to_string()],
    };

    let reg = ScenarioRegistry::builtin();
    let mut rows: Vec<String> = Vec::new();
    for name in &presets {
        let sc = reg
            .get(name)
            .unwrap_or_else(|| panic!("unknown preset {name}; known: {:?}", reg.names()));
        let cfg = bench_cfg(sc.cfg.clone());
        let c = WalkerConstellation::from_shells(&cfg.constellation.shells());

        let (builds_ring, builds_grid) = build_benches(name, &c);
        let routes_per_sec = route_benches(name, &c);
        let (async_s, sink_s, async_r, sink_r) = run_benches(name, &cfg);

        rows.push(format!(
            "    {{\"name\": \"{name}\", \"sats\": {}, \"graph_builds_per_sec_ring\": {builds_ring:.1}, \"graph_builds_per_sec_grid\": {builds_grid:.1}, \"route_queries_per_sec\": {routes_per_sec:.1}, \"asyncfleo_run_s\": {async_s:.6}, \"sinksat_run_s\": {sink_s:.6}, \"asyncfleo_epochs\": {}, \"sinksat_epochs\": {}, \"asyncfleo_transfers\": {}, \"sinksat_transfers\": {}}}",
            cfg.n_sats(),
            async_r.epochs,
            sink_r.epochs,
            async_r.transfers,
            sink_r.transfers,
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"topology\",\n  \"route_queries_per_iter\": {ROUTE_QUERIES},\n  \"presets\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    let mut f =
        std::fs::File::create("BENCH_topology.json").expect("create BENCH_topology.json");
    f.write_all(json.as_bytes()).expect("write BENCH_topology.json");
    println!("\nwrote BENCH_topology.json");
}

/// Trim a preset to bench size (same policy as bench_runloop).
fn bench_cfg(mut cfg: ExperimentConfig) -> ExperimentConfig {
    if cfg.n_sats() >= 1000 {
        cfg.fl.horizon_s = cfg.fl.horizon_s.min(12.0 * 3600.0);
        cfg.fl.max_epochs = cfg.fl.max_epochs.min(6);
    } else {
        cfg.fl.horizon_s = cfg.fl.horizon_s.min(24.0 * 3600.0);
        cfg.fl.max_epochs = cfg.fl.max_epochs.min(12);
    }
    cfg
}

fn grid_cfg() -> IslConfig {
    IslConfig { topology: IslTopology::Grid, cross_shell: true, ..Default::default() }
}

/// Graph construction throughput, ring and grid edge sets.
/// Returns (builds/sec ring, builds/sec grid).
fn build_benches(name: &str, c: &WalkerConstellation) -> (f64, f64) {
    print_header(&format!("{name}: graph build, ring vs grid ({} sats)", c.len()));
    let link = LinkParams::default();
    let bcfg = BenchConfig { warmup_iters: 2, sample_iters: 10, max_seconds: 120.0 };
    let r_ring = bench(&format!("{name}: build ring"), &bcfg, || {
        IslGraph::build(c, &IslConfig::default(), &link).n_edges()
    });
    println!("{}", r_ring.report());
    let r_grid = bench(&format!("{name}: build grid+gateways"), &bcfg, || {
        IslGraph::build(c, &grid_cfg(), &link).n_edges()
    });
    println!("{}", r_grid.report());
    (1.0 / r_ring.stats.mean.max(1e-12), 1.0 / r_grid.stats.mean.max(1e-12))
}

/// Shortest-delay route throughput on the connected grid graph,
/// determinism-gated. Returns route queries/sec.
fn route_benches(name: &str, c: &WalkerConstellation) -> f64 {
    print_header(&format!("{name}: route queries ({ROUTE_QUERIES} per iter)"));
    let g = IslGraph::build(c, &grid_cfg(), &LinkParams::default());
    assert!(g.is_connected(), "{name}: bench graph must be connected");

    // determinism gate: repeated queries reproduce the distance table
    let p1 = g.shortest_delays(c, 0, 900.0, PAYLOAD_BITS);
    let p2 = g.shortest_delays(c, 0, 900.0, PAYLOAD_BITS);
    assert_eq!(p1.parent, p2.parent, "{name}: router parents must be deterministic");
    for (a, b) in p1.dist.iter().zip(&p2.dist) {
        assert_eq!(a.to_bits(), b.to_bits(), "{name}: router delays must be deterministic");
    }

    let n = c.len();
    let bcfg = BenchConfig { warmup_iters: 2, sample_iters: 10, max_seconds: 120.0 };
    let r = bench(&format!("{name}: shortest_delays"), &bcfg, || {
        let mut acc = 0.0f64;
        for k in 0..ROUTE_QUERIES {
            let t = (k as f64 * 61.0) % 5400.0;
            let plan = g.shortest_delays(c, k % n, t, PAYLOAD_BITS);
            acc += plan.dist[(k + n / 2) % n];
        }
        acc
    });
    println!("{}", r.report());
    let per_sec = ROUTE_QUERIES as f64 / r.stats.mean.max(1e-12);
    println!("{name}: {:.1} route queries/s", per_sec);
    per_sec
}

/// Whole-run wall-time: sinksat (graph-routed) vs AsyncFLEO, with a
/// sinksat determinism gate. Returns (async s, sinksat s, results).
fn run_benches(name: &str, cfg: &ExperimentConfig) -> (f64, f64, RunResult, RunResult) {
    print_header(&format!("{name}: whole runs, sinksat vs AsyncFLEO (surrogate)"));
    // prewarm the shared geometry so run timings measure the schemes
    Geometry::shared(cfg);

    let gate_a = timed_run(cfg, SchemeKind::SinkSat).0;
    let gate_b = timed_run(cfg, SchemeKind::SinkSat).0;
    assert_runs_identical(&gate_a, &gate_b, &format!("{name}/sinksat determinism"));

    let (async_r, async_s) = timed_run(cfg, SchemeKind::AsyncFleo);
    let (sink_r, sink_s) = timed_run(cfg, SchemeKind::SinkSat);
    println!(
        "{name}: asyncfleo {async_s:.3} s ({} epochs), sinksat {sink_s:.3} s ({} plane updates)",
        async_r.epochs, sink_r.epochs
    );
    (async_s, sink_s, async_r, sink_r)
}

fn timed_run(cfg: &ExperimentConfig, scheme: SchemeKind) -> (RunResult, f64) {
    let mut c = cfg.clone();
    c.fl.scheme = scheme;
    let mut strategy = make_strategy(scheme);
    let mut b = SurrogateBackend::for_config(&c);
    let mut env = SimEnv::new(&c, &mut b);
    let t0 = Instant::now();
    let r = strategy.run(&mut env);
    (r, t0.elapsed().as_secs_f64())
}
