//! Shared, immutable run geometry: everything that is a pure function
//! of the *geometry-relevant* subset of an [`ExperimentConfig`] —
//! constellation, PS sites, the pre-computed [`ContactPlan`] and the RF
//! link parameters.
//!
//! Building a [`ContactPlan`] propagates the whole constellation over
//! the full horizon (30 s steps + bisection), which dominates `SimEnv`
//! construction. Two layers keep that cheap: [`Geometry::shared`]
//! builds each unique geometry exactly once per process and hands out
//! `Arc`s, so sweep cells (including the parallel executor's worker
//! threads) share one immutable instance; and the one build that does
//! run goes through the fast contact scanner (plane-basis propagation,
//! time-major position sharing, provable interval skipping, analytic
//! pass-gap prediction, chunked flat-arena materialization, parallel
//! per-satellite rows — see `contact`'s module docs), which is
//! bit-identical to the naive reference sweep at any thread count, so
//! the cache key → plan mapping stays deterministic. The analytic
//! layer (`super::analytic`) has its own process-wide cache one level
//! below this one, keyed by (shell, site-latitude-band) rather than
//! full geometry — presets that share a shell share those pass maps
//! even when their `Geometry` entries differ. Per-run mutable state
//! lives in [`super::env::RunState`]; `Geometry` is strictly
//! `Send + Sync`.

use super::contact::ContactPlan;
use crate::comm::LinkParams;
use crate::config::{ExperimentConfig, PsPlacement};
use crate::orbit::{GeodeticSite, SitePropagator, WalkerConstellation, WalkerPattern};
use crate::topology::IslGraph;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Immutable cross-run geometry (see module docs).
pub struct Geometry {
    pub constellation: WalkerConstellation,
    pub sites: Vec<GeodeticSite>,
    pub plan: ContactPlan,
    pub link: LinkParams,
    /// The explicit ISL graph (typed edges, per-shell link budgets,
    /// Doppler-derated delays — `topology::graph`), built once per
    /// geometry from the config's `[isl]` section. The default `ring`
    /// topology reproduces `ring_neighbors` exactly; the pre-graph
    /// schemes keep evaluating the implicit ring directly, so they are
    /// bit-identical with the graph present (pinned by
    /// `tests/topology_equivalence.rs`).
    pub isl: IslGraph,
    /// Per-site hoisted position formulas (latitude trigonometry paid
    /// once here): the run loop's delay calls evaluate site positions
    /// through these, bit-identical to `GeodeticSite::position_eci` —
    /// the same hoist the contact scanner uses (PR 4), now shared with
    /// `coordinator::env`.
    site_props: Vec<SitePropagator>,
}

/// The geometry-relevant subset of an [`ExperimentConfig`], with every
/// `f64` keyed by its bit pattern (configs are either copied or parsed
/// from the same text, so bit equality is the right identity here —
/// NaN never appears, `validate` and the constructors reject it). The
/// full shell list keys the entry, so every distinct scenario (single-
/// or multi-shell) gets its own cached geometry.
#[derive(Clone, PartialEq, Eq, Hash)]
struct GeometryKey {
    shells: Vec<ShellKey>,
    placement: PsPlacement,
    min_elevation_bits: u64,
    horizon_bits: u64,
    link_bits: [u64; 8],
    /// The `[isl]` section's contribution (topology, cross-shell,
    /// Doppler flag, per-shell link budgets) — the ISL graph lives on
    /// the geometry, so its knobs must key the cache.
    isl_bits: Vec<u64>,
}

/// One shell's geometry-relevant bits.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ShellKey {
    pattern: WalkerPattern,
    n_orbits: usize,
    sats_per_orbit: usize,
    altitude_bits: u64,
    inclination_bits: u64,
    phasing: usize,
}

impl GeometryKey {
    fn of(cfg: &ExperimentConfig) -> Self {
        let l = &cfg.link;
        GeometryKey {
            shells: cfg
                .constellation
                .shells()
                .iter()
                .map(|sh| ShellKey {
                    pattern: sh.pattern,
                    n_orbits: sh.n_orbits,
                    sats_per_orbit: sh.sats_per_orbit,
                    altitude_bits: sh.altitude_km.to_bits(),
                    inclination_bits: sh.inclination_deg.to_bits(),
                    phasing: sh.phasing,
                })
                .collect(),
            placement: cfg.placement,
            min_elevation_bits: cfg.min_elevation_deg.to_bits(),
            horizon_bits: cfg.fl.horizon_s.to_bits(),
            link_bits: [
                l.tx_power_dbm.to_bits(),
                l.tx_gain_dbi.to_bits(),
                l.rx_gain_dbi.to_bits(),
                l.carrier_hz.to_bits(),
                l.noise_temp_k.to_bits(),
                l.bandwidth_hz.to_bits(),
                l.data_rate_bps.to_bits(),
                l.processing_delay_s.to_bits(),
            ],
            isl_bits: cfg.isl.key_bits(),
        }
    }
}

/// Cache of per-key build cells. The map lock is only held to fetch or
/// insert a cell; the expensive build runs inside the cell's own
/// `OnceLock`, so concurrent requests for *different* keys never
/// serialize while same-key requests still build exactly once.
type BuildCell = Arc<OnceLock<Arc<Geometry>>>;

fn cache() -> &'static Mutex<HashMap<GeometryKey, BuildCell>> {
    static CACHE: OnceLock<Mutex<HashMap<GeometryKey, BuildCell>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Per-key count of [`Geometry::build`] invocations — the evidence for
/// the cache's exactly-once contract (sweep tests assert it is 1).
fn build_counts() -> &'static Mutex<HashMap<GeometryKey, u64>> {
    static COUNTS: OnceLock<Mutex<HashMap<GeometryKey, u64>>> = OnceLock::new();
    COUNTS.get_or_init(|| Mutex::new(HashMap::new()))
}

impl Geometry {
    /// Build from scratch, bypassing the cache (benches time this; the
    /// rest of the crate goes through [`Geometry::shared`]).
    pub fn build(cfg: &ExperimentConfig) -> Geometry {
        let _phase = crate::obs::global_phase("geometry_build");
        *build_counts()
            .lock()
            .unwrap()
            .entry(GeometryKey::of(cfg))
            .or_insert(0) += 1;
        let constellation = WalkerConstellation::from_shells(&cfg.constellation.shells());
        let sites = cfg.placement.sites();
        let plan = {
            let _phase = crate::obs::global_phase("contact_scan");
            ContactPlan::build(
                &constellation,
                &sites,
                cfg.min_elevation_deg,
                cfg.fl.horizon_s,
            )
        };
        let site_props = sites.iter().map(SitePropagator::new).collect();
        let isl = IslGraph::build(&constellation, &cfg.isl, &cfg.link);
        Geometry { constellation, sites, plan, link: cfg.link, isl, site_props }
    }

    /// The hoisted position formula of site `site` (what the run loop's
    /// delay calls evaluate; bit-identical to
    /// `self.sites[site].position_eci(t)`).
    pub fn site_prop(&self, site: usize) -> &SitePropagator {
        &self.site_props[site]
    }

    /// The process-wide shared instance for `cfg`'s geometry subset.
    ///
    /// Each unique geometry is constructed exactly once per process no
    /// matter how many threads ask concurrently (same-key callers block
    /// on one build; different keys build in parallel); everyone gets
    /// the same `Arc`.
    pub fn shared(cfg: &ExperimentConfig) -> Arc<Geometry> {
        let key = GeometryKey::of(cfg);
        let cell: BuildCell = {
            let mut map = cache().lock().unwrap();
            map.entry(key).or_default().clone()
        };
        cell.get_or_init(|| Arc::new(Geometry::build(cfg))).clone()
    }

    /// How many times [`Geometry::build`] actually ran for `cfg`'s key
    /// (0 = never; 1 = the cache's exactly-once contract held).
    pub fn build_count(cfg: &ExperimentConfig) -> u64 {
        build_counts()
            .lock()
            .unwrap()
            .get(&GeometryKey::of(cfg))
            .copied()
            .unwrap_or(0)
    }
}

// The parallel executor shares `Arc<Geometry>` across worker threads;
// keep the bound explicit so a non-Sync field is caught here, not in a
// distant thread-spawn error.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Geometry>();
};

#[cfg(test)]
mod tests {
    use super::*;

    /// A geometry-unique config so parallel-running tests elsewhere in
    /// the binary can never collide with this test's cache keys.
    fn unique_cfg(altitude_km: f64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::test_small();
        cfg.constellation.altitude_km = altitude_km;
        cfg
    }

    #[test]
    fn shared_returns_same_arc_and_builds_once() {
        let cfg = unique_cfg(1234.25);
        let a = Geometry::shared(&cfg);
        let b = Geometry::shared(&cfg);
        assert!(Arc::ptr_eq(&a, &b), "geometry-identical configs must share");
        assert_eq!(Geometry::build_count(&cfg), 1, "built exactly once");
        // non-geometry knobs (seed, scheme, lr, fault intensities) hit
        // the same cache entry
        let mut c = cfg.clone();
        c.seed = 9999;
        c.fl.lr = 0.5;
        c.fl.max_epochs = 1;
        assert!(Arc::ptr_eq(&a, &Geometry::shared(&c)));
        assert_eq!(Geometry::build_count(&cfg), 1);
    }

    #[test]
    fn geometry_knobs_key_fresh_instances() {
        let base = unique_cfg(1235.75);
        let a = Geometry::shared(&base);

        let mut alt = base.clone();
        alt.constellation.altitude_km = 1236.75;
        assert!(!Arc::ptr_eq(&a, &Geometry::shared(&alt)), "altitude keys");

        let mut elev = base.clone();
        elev.min_elevation_deg = 12.125;
        assert!(!Arc::ptr_eq(&a, &Geometry::shared(&elev)), "elevation keys");

        let mut hor = base.clone();
        hor.fl.horizon_s = base.fl.horizon_s + 1800.0;
        assert!(!Arc::ptr_eq(&a, &Geometry::shared(&hor)), "horizon keys");

        let mut pl = base.clone();
        pl.placement = PsPlacement::TwoHaps;
        assert!(!Arc::ptr_eq(&a, &Geometry::shared(&pl)), "placement keys");

        // the base entry is still shared and still built once
        assert!(Arc::ptr_eq(&a, &Geometry::shared(&base)));
        assert_eq!(Geometry::build_count(&base), 1);
    }

    #[test]
    fn isl_knobs_key_fresh_instances() {
        let base = unique_cfg(1240.125);
        let a = Geometry::shared(&base);
        assert!(a.isl.n_edges() > 0, "ring edges built by default");

        let mut grid = base.clone();
        grid.isl.topology = crate::topology::IslTopology::Grid;
        let g = Geometry::shared(&grid);
        assert!(!Arc::ptr_eq(&a, &g), "isl topology keys");
        assert!(g.isl.n_edges() > a.isl.n_edges(), "grid adds cross-plane edges");

        let mut linked = base.clone();
        linked.isl.shell_links =
            vec![LinkParams { data_rate_bps: 2.0e6, ..LinkParams::default() }];
        assert!(!Arc::ptr_eq(&a, &Geometry::shared(&linked)), "shell links key");

        assert!(Arc::ptr_eq(&a, &Geometry::shared(&base)));
        assert_eq!(Geometry::build_count(&base), 1);
    }

    #[test]
    fn extra_shells_key_fresh_instances() {
        let base = unique_cfg(1238.25);
        let a = Geometry::shared(&base);
        let mut two = base.clone();
        two.constellation.extra_shells =
            vec![crate::orbit::ShellSpec::delta(1, 2, 900.25, 60.0, 0)];
        let b = Geometry::shared(&two);
        assert!(!Arc::ptr_eq(&a, &b), "shell list must key the cache");
        assert_eq!(b.constellation.len(), base.n_sats() + 2);
        assert_eq!(b.constellation.n_shells(), 2);
        assert_eq!(Geometry::build_count(&two), 1);
        assert_eq!(Geometry::build_count(&base), 1);
    }

    #[test]
    fn build_matches_config() {
        let cfg = unique_cfg(1237.5);
        let g = Geometry::shared(&cfg);
        assert_eq!(g.constellation.len(), cfg.n_sats());
        assert_eq!(g.sites.len(), cfg.placement.sites().len());
        assert_eq!(g.plan.n_sites(), g.sites.len());
        assert_eq!(g.plan.horizon_s, cfg.fl.horizon_s);
        assert_eq!(g.link, cfg.link);
    }

    #[test]
    fn cached_site_props_match_position_eci_bitwise() {
        let mut cfg = unique_cfg(1239.5);
        cfg.placement = PsPlacement::TwoHaps;
        let g = Geometry::shared(&cfg);
        for site in 0..g.sites.len() {
            for i in 0..50 {
                let t = i as f64 * 977.375;
                let a = g.sites[site].position_eci(t);
                let b = g.site_prop(site).position_at(t);
                assert_eq!(a.x.to_bits(), b.x.to_bits());
                assert_eq!(a.y.to_bits(), b.y.to_bits());
                assert_eq!(a.z.to_bits(), b.z.to_bits());
            }
        }
    }
}
