//! Typed, validated experiment configuration (Table I defaults).
//!
//! Configs load from a TOML-subset file ([`parser`]) or start from
//! [`ExperimentConfig::paper_defaults`] and are adjusted
//! programmatically by the experiment drivers. Every run embeds its
//! full config in the output CSV header for reproducibility.

pub mod parser;

use crate::comm::LinkParams;
use crate::data::{DatasetKind, Partition};
use crate::faults::{FaultConfig, FaultScenario, NetworkConfig, PartitionScope};
use crate::orbit::{ShellSpec, WalkerPattern};
use crate::topology::{IslConfig, IslTopology};
use parser::{Doc, ParseError, Value};

/// FL scheme under test (AsyncFLEO + the paper's baselines, Sec. V-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// This paper's contribution (Algorithms 1 & 2).
    AsyncFleo,
    /// Plain synchronous FedAvg star topology (McMahan et al.).
    FedAvg,
    /// FedISL: synchronous + intra-orbit ISL relay (Razmi et al.).
    FedIsl,
    /// FedISL's "ideal setup": GS at the North Pole.
    FedIslIdeal,
    /// FedSat: asynchronous, per-visit update, NP ground station.
    FedSat,
    /// FedSpace: scheduled aggregation needing uploaded data fractions.
    FedSpace,
    /// FedHAP: synchronous FL with HAP parameter servers.
    FedHap,
    /// Sink-satellite scheduling (arXiv 2302.13447): per-plane ring
    /// collection into a PS-visibility-scheduled sink satellite,
    /// asynchronous per-plane global updates.
    SinkSat,
}

impl SchemeKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "asyncfleo" => SchemeKind::AsyncFleo,
            "fedavg" => SchemeKind::FedAvg,
            "fedisl" => SchemeKind::FedIsl,
            "fedisl-ideal" => SchemeKind::FedIslIdeal,
            "fedsat" => SchemeKind::FedSat,
            "fedspace" => SchemeKind::FedSpace,
            "fedhap" => SchemeKind::FedHap,
            "sinksat" => SchemeKind::SinkSat,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::AsyncFleo => "asyncfleo",
            SchemeKind::FedAvg => "fedavg",
            SchemeKind::FedIsl => "fedisl",
            SchemeKind::FedIslIdeal => "fedisl-ideal",
            SchemeKind::FedSat => "fedsat",
            SchemeKind::FedSpace => "fedspace",
            SchemeKind::FedHap => "fedhap",
            SchemeKind::SinkSat => "sinksat",
        }
    }

    /// Synchronous schemes wait for every satellite each round.
    pub fn is_synchronous(&self) -> bool {
        matches!(
            self,
            SchemeKind::FedAvg | SchemeKind::FedIsl | SchemeKind::FedIslIdeal | SchemeKind::FedHap
        )
    }
}

/// Model architecture (paper: CNN and MLP).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Mlp,
    Cnn,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mlp" => Some(ModelKind::Mlp),
            "cnn" => Some(ModelKind::Cnn),
            _ => None,
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Cnn => "cnn",
        }
    }
}

/// Where the parameter server(s) sit (paper Sec. V-A scenarios).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PsPlacement {
    /// Single GS in Rolla, MO.
    GsRolla,
    /// Single HAP above Rolla, MO.
    HapRolla,
    /// Two HAPs: Rolla + Portland.
    TwoHaps,
    /// The FedISL/FedSat "ideal setup": GS at the North Pole.
    GsNorthPole,
    /// Single HAP above Quito (equatorial-shell scenarios).
    HapQuito,
}

impl PsPlacement {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "gs" | "gs-rolla" => PsPlacement::GsRolla,
            "hap" | "hap-rolla" => PsPlacement::HapRolla,
            "two-haps" | "twohap" => PsPlacement::TwoHaps,
            "gs-np" | "north-pole" => PsPlacement::GsNorthPole,
            "hap-quito" | "quito" => PsPlacement::HapQuito,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PsPlacement::GsRolla => "gs-rolla",
            PsPlacement::HapRolla => "hap-rolla",
            PsPlacement::TwoHaps => "two-haps",
            PsPlacement::GsNorthPole => "gs-np",
            PsPlacement::HapQuito => "hap-quito",
        }
    }

    pub fn sites(&self) -> Vec<crate::orbit::GeodeticSite> {
        use crate::orbit::GeodeticSite as S;
        match self {
            PsPlacement::GsRolla => vec![S::rolla_gs()],
            PsPlacement::HapRolla => vec![S::rolla_hap()],
            PsPlacement::TwoHaps => vec![S::rolla_hap(), S::portland_hap()],
            PsPlacement::GsNorthPole => vec![S::north_pole_gs()],
            PsPlacement::HapQuito => vec![S::quito_hap()],
        }
    }
}

/// Constellation geometry (paper Sec. V-A defaults). The scalar fields
/// describe the *primary* shell; `extra_shells` appends further shells
/// for multi-shell scenarios (each with its own pattern, altitude,
/// inclination, planes and phasing — globally unique satellite ids
/// follow shell order, see [`crate::orbit::WalkerConstellation`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ConstellationConfig {
    pub n_orbits: usize,
    pub sats_per_orbit: usize,
    pub altitude_km: f64,
    pub inclination_deg: f64,
    pub phasing: usize,
    /// Walker pattern of the primary shell.
    pub pattern: WalkerPattern,
    /// Additional shells beyond the primary (empty = single-shell).
    pub extra_shells: Vec<ShellSpec>,
}

impl ConstellationConfig {
    /// The primary shell described by the scalar fields.
    pub fn primary_shell(&self) -> ShellSpec {
        ShellSpec {
            pattern: self.pattern,
            n_orbits: self.n_orbits,
            sats_per_orbit: self.sats_per_orbit,
            altitude_km: self.altitude_km,
            inclination_deg: self.inclination_deg,
            phasing: self.phasing,
        }
    }

    /// All shells: the primary followed by `extra_shells`.
    pub fn shells(&self) -> Vec<ShellSpec> {
        let mut out = Vec::with_capacity(1 + self.extra_shells.len());
        out.push(self.primary_shell());
        out.extend_from_slice(&self.extra_shells);
        out
    }

    /// Total satellites across all shells.
    pub fn n_sats(&self) -> usize {
        self.shells().iter().map(ShellSpec::n_sats).sum()
    }

    /// Total orbital planes across all shells.
    pub fn n_planes(&self) -> usize {
        self.shells().iter().map(|s| s.n_orbits).sum()
    }

    /// Global plane index of every satellite id (what the data
    /// partitioner and fault scheduler shard by).
    pub fn plane_of(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.n_sats());
        let mut plane = 0usize;
        for sh in self.shells() {
            for _ in 0..sh.n_orbits {
                out.extend(std::iter::repeat(plane).take(sh.sats_per_orbit));
                plane += 1;
            }
        }
        out
    }

    /// Compact form for catalogs, e.g. `5x8@2000km/80°` or
    /// `12x20@550km/53° + 6x10@1110km/53.8°`.
    pub fn summary(&self) -> String {
        self.shells()
            .iter()
            .map(ShellSpec::summary)
            .collect::<Vec<_>>()
            .join(" + ")
    }
}

/// FL hyper-parameters and run control.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlConfig {
    pub scheme: SchemeKind,
    pub model: ModelKind,
    pub dataset: DatasetKind,
    pub partition: Partition,
    /// Learning rate η (Table I: 0.01).
    pub lr: f32,
    /// Local training dispatches per global-model receipt. Each
    /// dispatch runs the AOT-folded J SGD steps; the paper's I = 100
    /// local epochs map to `dispatches * J` steps through the on-board
    /// compute-time model (DESIGN.md §5).
    pub local_dispatches: usize,
    /// Stop after this many global epochs (safety bound).
    pub max_epochs: u64,
    /// Stop when simulated time exceeds this horizon, seconds.
    pub horizon_s: f64,
    /// On-board seconds of compute the satellite spends per dispatch
    /// (models the paper's I=100 local epochs of on-board training).
    pub train_time_s: f64,
}

/// Data sizing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DataConfig {
    pub train_samples: usize,
    pub test_samples: usize,
}

/// The complete experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub constellation: ConstellationConfig,
    pub placement: PsPlacement,
    pub link: LinkParams,
    /// ISL graph topology + per-shell link budgets (the `[isl]` and
    /// `[isl_linkN]` TOML sections; defaults reproduce the paper's
    /// intra-plane rings under the global link budget).
    pub isl: IslConfig,
    pub fl: FlConfig,
    pub data: DataConfig,
    /// Fault-injection knobs (nominal = the perfect network).
    pub faults: FaultConfig,
    /// Network-impairment knobs: latency jitter, bandwidth queueing,
    /// partitions, Sun-vector eclipses (nominal = provably invisible).
    pub network: NetworkConfig,
    pub seed: u64,
    /// Minimum elevation angle θ_min, degrees (Table: 10°).
    pub min_elevation_deg: f64,
}

impl ExperimentConfig {
    /// The paper's Table I + Sec. V-A setup.
    pub fn paper_defaults() -> Self {
        ExperimentConfig {
            constellation: ConstellationConfig {
                n_orbits: 5,
                sats_per_orbit: 8,
                altitude_km: 2000.0,
                inclination_deg: 80.0,
                phasing: 1,
                pattern: WalkerPattern::Delta,
                extra_shells: Vec::new(),
            },
            placement: PsPlacement::HapRolla,
            link: LinkParams::default(),
            isl: IslConfig::default(),
            fl: FlConfig {
                scheme: SchemeKind::AsyncFleo,
                model: ModelKind::Cnn,
                dataset: DatasetKind::Digits,
                partition: Partition::NonIidPaper,
                lr: 0.01,
                local_dispatches: 2,
                max_epochs: 60,
                horizon_s: 3.0 * 86_400.0, // paper: 3-day trajectories
                // on-board compute model: the paper's I = 100 local
                // epochs of on-board training take ~20 min of satellite
                // compute (DESIGN.md §5 maps I to dispatches*J steps)
                train_time_s: 1200.0,
            },
            data: DataConfig { train_samples: 8000, test_samples: 2000 },
            faults: FaultConfig::nominal(),
            network: NetworkConfig::nominal(),
            seed: 42,
            min_elevation_deg: 10.0,
        }
    }

    /// A reduced configuration for fast tests: 2 orbits x 3 sats, tiny
    /// datasets, short horizon.
    pub fn test_small() -> Self {
        let mut c = Self::paper_defaults();
        c.constellation.n_orbits = 2;
        c.constellation.sats_per_orbit = 3;
        c.data = DataConfig { train_samples: 600, test_samples: 200 };
        c.fl.max_epochs = 3;
        c.fl.horizon_s = 6.0 * 3600.0;
        c.fl.model = ModelKind::Mlp;
        c
    }

    pub fn n_sats(&self) -> usize {
        self.constellation.n_sats()
    }

    /// Artifact-name fragment, e.g. "cnn_digits".
    pub fn model_tag(&self) -> String {
        format!("{}_{}", self.fl.model.tag(), self.fl.dataset.tag())
    }

    /// Validate invariants; returns a list of problems (empty = OK).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        for (i, sh) in self.constellation.shells().iter().enumerate() {
            let which =
                if i == 0 { "constellation".to_string() } else { format!("shell{}", i + 1) };
            if sh.n_orbits == 0 || sh.sats_per_orbit == 0 {
                errs.push(format!("{which} must have at least one satellite"));
            }
            if !(100.0..=3000.0).contains(&sh.altitude_km) {
                errs.push(format!("{which}: altitude {} km outside LEO band", sh.altitude_km));
            }
            if !(0.0..=180.0).contains(&sh.inclination_deg) {
                errs.push(format!("{which}: inclination {} out of range", sh.inclination_deg));
            }
        }
        // [shell2]..[shell9] is the parseable range (the sorted
        // flattened doc would order [shell10] before [shell2]); reject
        // configs whose to_toml dump could not round-trip
        if self.constellation.extra_shells.len() > 8 {
            errs.push(format!(
                "at most 8 extra shells are supported ({} given)",
                self.constellation.extra_shells.len()
            ));
        }
        // [isl_link1]..[isl_link9] is the parseable range, and a link
        // override beyond the shell list would silently do nothing
        let n_shells = 1 + self.constellation.extra_shells.len();
        if self.isl.shell_links.len() > 9 {
            errs.push(format!(
                "at most 9 per-shell ISL link overrides are supported ({} given)",
                self.isl.shell_links.len()
            ));
        } else if self.isl.shell_links.len() > n_shells {
            errs.push(format!(
                "{} ISL link overrides for {n_shells} shell(s)",
                self.isl.shell_links.len()
            ));
        }
        if self.fl.lr <= 0.0 || self.fl.lr > 1.0 {
            errs.push(format!("lr {} out of (0, 1]", self.fl.lr));
        }
        if self.fl.horizon_s <= 0.0 {
            errs.push("horizon must be positive".into());
        }
        if self.data.train_samples < self.n_sats() {
            errs.push("fewer training samples than satellites".into());
        }
        if !(0.0..90.0).contains(&self.min_elevation_deg) {
            errs.push(format!("min elevation {} out of [0, 90)", self.min_elevation_deg));
        }
        errs.extend(self.faults.validate());
        errs.extend(self.network.validate());
        errs
    }

    /// Load from a TOML-subset string; unspecified keys keep paper
    /// defaults.
    pub fn from_toml(input: &str) -> Result<Self, ParseError> {
        let doc = parser::parse(input)?;
        let mut cfg = Self::paper_defaults();
        cfg.apply_doc(&doc)
            .map_err(|msg| ParseError { line: 0, msg })?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_toml(&text).map_err(|e| e.to_string())
    }

    fn apply_doc(&mut self, doc: &Doc) -> Result<(), String> {
        // The fault scenario is a whole-preset assignment the
        // individual faults.* knobs then refine — apply it first so
        // overrides win regardless of the map's key order.
        if let Some(val) = doc.get("faults.scenario") {
            self.apply_key("faults.scenario", val)?;
        }
        for (key, val) in doc {
            if key == "faults.scenario" {
                continue;
            }
            self.apply_key(key, val)?;
        }
        Ok(())
    }

    fn apply_key(&mut self, key: &str, val: &Value) -> Result<(), String> {
        let need_f64 = || val.as_f64().ok_or(format!("{key}: expected number"));
        let need_usize = || {
            val.as_i64()
                .filter(|v| *v >= 0)
                .map(|v| v as usize)
                .ok_or(format!("{key}: expected non-negative integer"))
        };
        let need_str = || val.as_str().ok_or(format!("{key}: expected string"));
        match key {
            "constellation.orbits" => self.constellation.n_orbits = need_usize()?,
            "constellation.sats_per_orbit" => self.constellation.sats_per_orbit = need_usize()?,
            "constellation.altitude_km" => self.constellation.altitude_km = need_f64()?,
            "constellation.inclination_deg" => self.constellation.inclination_deg = need_f64()?,
            "constellation.phasing" => self.constellation.phasing = need_usize()?,
            "constellation.pattern" => {
                self.constellation.pattern = WalkerPattern::parse(need_str()?)
                    .ok_or(format!("{key}: unknown pattern (delta|star)"))?
            }
            "ps.placement" => {
                self.placement = PsPlacement::parse(need_str()?)
                    .ok_or(format!("{key}: unknown placement"))?
            }
            "ps.min_elevation_deg" => self.min_elevation_deg = need_f64()?,
            "link.tx_power_dbm" => self.link.tx_power_dbm = need_f64()?,
            "link.antenna_gain_dbi" => {
                let g = need_f64()?;
                self.link.tx_gain_dbi = g;
                self.link.rx_gain_dbi = g;
            }
            "link.carrier_ghz" => self.link.carrier_hz = need_f64()? * 1e9,
            "link.noise_temp_k" => self.link.noise_temp_k = need_f64()?,
            "link.data_rate_mbps" => self.link.data_rate_bps = need_f64()? * 1e6,
            "link.bandwidth_mhz" => self.link.bandwidth_hz = need_f64()? * 1e6,
            // ISL graph topology ([isl]; per-shell budgets live in the
            // [isl_linkN] sections handled below)
            "isl.topology" => {
                self.isl.topology = IslTopology::parse(need_str()?)
                    .ok_or(format!("{key}: unknown topology (ring|grid)"))?
            }
            "isl.cross_shell" => {
                self.isl.cross_shell = val.as_bool().ok_or(format!("{key}: expected bool"))?
            }
            "isl.doppler" => {
                self.isl.doppler = val.as_bool().ok_or(format!("{key}: expected bool"))?
            }
            "fl.scheme" => {
                self.fl.scheme =
                    SchemeKind::parse(need_str()?).ok_or(format!("{key}: unknown scheme"))?
            }
            "fl.model" => {
                self.fl.model =
                    ModelKind::parse(need_str()?).ok_or(format!("{key}: unknown model"))?
            }
            "fl.dataset" => {
                self.fl.dataset = match need_str()? {
                    "digits" | "mnist" => DatasetKind::Digits,
                    "cifar" | "cifar10" => DatasetKind::Cifar,
                    other => return Err(format!("{key}: unknown dataset {other}")),
                }
            }
            "fl.partition" => {
                self.fl.partition = match need_str()? {
                    "iid" => Partition::Iid,
                    "non-iid" | "noniid" => Partition::NonIidPaper,
                    other => return Err(format!("{key}: unknown partition {other}")),
                }
            }
            "fl.lr" => self.fl.lr = need_f64()? as f32,
            "fl.local_dispatches" => self.fl.local_dispatches = need_usize()?,
            "fl.max_epochs" => self.fl.max_epochs = need_usize()? as u64,
            "fl.horizon_hours" => self.fl.horizon_s = need_f64()? * 3600.0,
            "fl.train_time_s" => self.fl.train_time_s = need_f64()?,
            "data.train_samples" => self.data.train_samples = need_usize()?,
            "data.test_samples" => self.data.test_samples = need_usize()?,
            // Fault injection: a named preset at full intensity
            // (applied before the per-knob keys, see `apply_doc`), then
            // optional per-knob overrides.
            "faults.scenario" => {
                self.faults = FaultScenario::parse(need_str()?)
                    .map(|s| FaultConfig::preset(s, 1.0))
                    .ok_or(format!("{key}: unknown fault scenario"))?;
            }
            "faults.loss_prob" => self.faults.loss_prob = need_f64()?,
            "faults.max_retransmits" => self.faults.max_retransmits = need_usize()? as u32,
            "faults.retransmit_backoff_s" => self.faults.retransmit_backoff_s = need_f64()?,
            "faults.outage_period_s" => self.faults.outage_period_s = need_f64()?,
            "faults.outage_duration_s" => self.faults.outage_duration_s = need_f64()?,
            "faults.isl_outage" => {
                self.faults.isl_outage =
                    val.as_bool().ok_or(format!("{key}: expected bool"))?
            }
            "faults.sat_mtbf_s" => self.faults.sat_mtbf_s = need_f64()?,
            "faults.sat_mttr_s" => self.faults.sat_mttr_s = need_f64()?,
            "faults.hap_mtbf_s" => self.faults.hap_mtbf_s = need_f64()?,
            "faults.hap_mttr_s" => self.faults.hap_mttr_s = need_f64()?,
            "faults.isl_edge_outage_period_s" => {
                self.faults.isl_edge_outage_period_s = need_f64()?
            }
            "faults.isl_edge_outage_duration_s" => {
                self.faults.isl_edge_outage_duration_s = need_f64()?
            }
            // Network impairment engine ([network]): jitter, queueing,
            // partitions, Sun-vector eclipses.
            "network.jitter_sigma" => self.network.jitter_sigma = need_f64()?,
            "network.queue_service_factor" => self.network.queue_service_factor = need_f64()?,
            "network.queue_max_wait_s" => self.network.queue_max_wait_s = need_f64()?,
            "network.partition_period_s" => self.network.partition_period_s = need_f64()?,
            "network.partition_duration_s" => self.network.partition_duration_s = need_f64()?,
            "network.partition_scope" => {
                self.network.partition_scope = PartitionScope::parse(need_str()?)
                    .ok_or(format!("{key}: unknown scope (ground|hap|shell)"))?
            }
            "network.partition_shell" => self.network.partition_shell = need_usize()?,
            "network.eclipse_from_sun" => {
                self.network.eclipse_from_sun =
                    val.as_bool().ok_or(format!("{key}: expected bool"))?
            }
            "seed" => self.seed = need_usize()? as u64,
            other => {
                // [shellN] sections (N >= 2) declare extra constellation
                // shells; shell 1 is the [constellation] section itself.
                if let Some((idx, field)) = parse_shell_key(other) {
                    return self.apply_shell_key(idx, field, key, val);
                }
                // [isl_linkN] sections (N >= 1) declare per-shell ISL
                // link budgets; N = 1 is the primary shell.
                if let Some((idx, field)) = parse_isl_link_key(other) {
                    return self.apply_isl_link_key(idx, field, key, val);
                }
                return Err(format!("unknown config key: {other}"));
            }
        }
        Ok(())
    }

    /// Apply one `[shellN]` key. Shells must be declared contiguously
    /// (`shell3` without `shell2` is an error); the flattened document
    /// is sorted, so all of `shellN`'s keys arrive before `shellN+1`'s.
    fn apply_shell_key(
        &mut self,
        idx: usize,
        field: &str,
        key: &str,
        val: &Value,
    ) -> Result<(), String> {
        let shells = &mut self.constellation.extra_shells;
        if idx > shells.len() {
            return Err(format!("{key}: shell{} declared without shell{}", idx + 2, idx + 1));
        }
        if idx == shells.len() {
            // unspecified fields of a new shell default to a minimal
            // 1x1 delta; to_toml always dumps every field, so presets
            // round-trip exactly
            shells.push(ShellSpec::delta(1, 1, 550.0, 53.0, 0));
        }
        let sh = &mut shells[idx];
        let need_f64 = || val.as_f64().ok_or(format!("{key}: expected number"));
        let need_usize = || {
            val.as_i64()
                .filter(|v| *v >= 0)
                .map(|v| v as usize)
                .ok_or(format!("{key}: expected non-negative integer"))
        };
        match field {
            "pattern" => {
                sh.pattern = val
                    .as_str()
                    .and_then(WalkerPattern::parse)
                    .ok_or(format!("{key}: unknown pattern (delta|star)"))?
            }
            "orbits" => sh.n_orbits = need_usize()?,
            "sats_per_orbit" => sh.sats_per_orbit = need_usize()?,
            "altitude_km" => sh.altitude_km = need_f64()?,
            "inclination_deg" => sh.inclination_deg = need_f64()?,
            "phasing" => sh.phasing = need_usize()?,
            other => return Err(format!("unknown shell key: {other}")),
        }
        Ok(())
    }

    /// Apply one `[isl_linkN]` key. Like shells, the link overrides
    /// must be declared contiguously from `isl_link1`; unspecified
    /// fields of a new entry default to the paper's Table-I budget
    /// (order-independent — `to_toml` always dumps every field, so
    /// configs round-trip exactly).
    fn apply_isl_link_key(
        &mut self,
        idx: usize,
        field: &str,
        key: &str,
        val: &Value,
    ) -> Result<(), String> {
        let links = &mut self.isl.shell_links;
        if idx > links.len() {
            return Err(format!(
                "{key}: isl_link{} declared without isl_link{}",
                idx + 1,
                idx
            ));
        }
        if idx == links.len() {
            links.push(LinkParams::default());
        }
        let l = &mut links[idx];
        let need_f64 = || val.as_f64().ok_or(format!("{key}: expected number"));
        match field {
            "tx_power_dbm" => l.tx_power_dbm = need_f64()?,
            "antenna_gain_dbi" => {
                let g = need_f64()?;
                l.tx_gain_dbi = g;
                l.rx_gain_dbi = g;
            }
            "carrier_ghz" => l.carrier_hz = need_f64()? * 1e9,
            "noise_temp_k" => l.noise_temp_k = need_f64()?,
            "data_rate_mbps" => l.data_rate_bps = need_f64()? * 1e6,
            "bandwidth_mhz" => l.bandwidth_hz = need_f64()? * 1e6,
            "processing_delay_s" => l.processing_delay_s = need_f64()?,
            other => return Err(format!("unknown isl_link key: {other}")),
        }
        Ok(())
    }

    /// Serialize back to the TOML subset (round-trips through
    /// [`Self::from_toml`]; embedded in result CSVs). Extra shells are
    /// dumped as `[shellN]` sections (N starting at 2) and per-shell
    /// ISL budgets as `[isl_linkN]` sections (N starting at 1) after
    /// the main sections.
    pub fn to_toml(&self) -> String {
        let mut out = format!(
            "seed = {}\n\n[constellation]\npattern = \"{}\"\norbits = {}\nsats_per_orbit = {}\naltitude_km = {}\ninclination_deg = {}\nphasing = {}\n\n[ps]\nplacement = \"{}\"\nmin_elevation_deg = {}\n\n[link]\ntx_power_dbm = {}\nantenna_gain_dbi = {}\ncarrier_ghz = {}\nnoise_temp_k = {}\ndata_rate_mbps = {}\nbandwidth_mhz = {}\n\n[fl]\nscheme = \"{}\"\nmodel = \"{}\"\ndataset = \"{}\"\npartition = \"{}\"\nlr = {}\nlocal_dispatches = {}\nmax_epochs = {}\nhorizon_hours = {}\ntrain_time_s = {}\n\n[data]\ntrain_samples = {}\ntest_samples = {}\n\n[faults]\nloss_prob = {}\nmax_retransmits = {}\nretransmit_backoff_s = {}\noutage_period_s = {}\noutage_duration_s = {}\nisl_outage = {}\nsat_mtbf_s = {}\nsat_mttr_s = {}\nhap_mtbf_s = {}\nhap_mttr_s = {}\nisl_edge_outage_period_s = {}\nisl_edge_outage_duration_s = {}\n",
            self.seed,
            self.constellation.pattern.name(),
            self.constellation.n_orbits,
            self.constellation.sats_per_orbit,
            self.constellation.altitude_km,
            self.constellation.inclination_deg,
            self.constellation.phasing,
            self.placement.name(),
            self.min_elevation_deg,
            self.link.tx_power_dbm,
            self.link.tx_gain_dbi,
            self.link.carrier_hz / 1e9,
            self.link.noise_temp_k,
            self.link.data_rate_bps / 1e6,
            self.link.bandwidth_hz / 1e6,
            self.fl.scheme.name(),
            self.fl.model.tag(),
            self.fl.dataset.tag(),
            match self.fl.partition {
                Partition::Iid => "iid",
                Partition::NonIidPaper => "non-iid",
            },
            self.fl.lr,
            self.fl.local_dispatches,
            self.fl.max_epochs,
            self.fl.horizon_s / 3600.0,
            self.fl.train_time_s,
            self.data.train_samples,
            self.data.test_samples,
            self.faults.loss_prob,
            self.faults.max_retransmits,
            self.faults.retransmit_backoff_s,
            self.faults.outage_period_s,
            self.faults.outage_duration_s,
            self.faults.isl_outage,
            self.faults.sat_mtbf_s,
            self.faults.sat_mttr_s,
            self.faults.hap_mtbf_s,
            self.faults.hap_mttr_s,
            self.faults.isl_edge_outage_period_s,
            self.faults.isl_edge_outage_duration_s,
        );
        out.push_str(&format!(
            "\n[network]\njitter_sigma = {}\nqueue_service_factor = {}\nqueue_max_wait_s = {}\npartition_period_s = {}\npartition_duration_s = {}\npartition_scope = \"{}\"\npartition_shell = {}\neclipse_from_sun = {}\n",
            self.network.jitter_sigma,
            self.network.queue_service_factor,
            self.network.queue_max_wait_s,
            self.network.partition_period_s,
            self.network.partition_duration_s,
            self.network.partition_scope.name(),
            self.network.partition_shell,
            self.network.eclipse_from_sun,
        ));
        out.push_str(&format!(
            "\n[isl]\ntopology = \"{}\"\ncross_shell = {}\ndoppler = {}\n",
            self.isl.topology.name(),
            self.isl.cross_shell,
            self.isl.doppler,
        ));
        for (i, l) in self.isl.shell_links.iter().enumerate() {
            out.push_str(&format!(
                "\n[isl_link{}]\ntx_power_dbm = {}\nantenna_gain_dbi = {}\ncarrier_ghz = {}\nnoise_temp_k = {}\ndata_rate_mbps = {}\nbandwidth_mhz = {}\nprocessing_delay_s = {}\n",
                i + 1,
                l.tx_power_dbm,
                l.tx_gain_dbi,
                l.carrier_hz / 1e9,
                l.noise_temp_k,
                l.data_rate_bps / 1e6,
                l.bandwidth_hz / 1e6,
                l.processing_delay_s,
            ));
        }
        for (i, sh) in self.constellation.extra_shells.iter().enumerate() {
            out.push_str(&format!(
                "\n[shell{}]\npattern = \"{}\"\norbits = {}\nsats_per_orbit = {}\naltitude_km = {}\ninclination_deg = {}\nphasing = {}\n",
                i + 2,
                sh.pattern.name(),
                sh.n_orbits,
                sh.sats_per_orbit,
                sh.altitude_km,
                sh.inclination_deg,
                sh.phasing,
            ));
        }
        out
    }
}

/// `"shell2.orbits"` → `Some((0, "orbits"))`: index into
/// `extra_shells` plus the field name. Shell numbering starts at 2
/// (shell 1 is the `[constellation]` section); at most `[shell9]`, so
/// the sorted flattened document keeps shells in declaration order.
fn parse_shell_key(key: &str) -> Option<(usize, &str)> {
    let rest = key.strip_prefix("shell")?;
    let (num, field) = rest.split_once('.')?;
    let n: usize = num.parse().ok()?;
    if !(2..=9).contains(&n) {
        return None;
    }
    Some((n - 2, field))
}

/// `"isl_link1.data_rate_mbps"` → `Some((0, "data_rate_mbps"))`: index
/// into `isl.shell_links` plus the field name. Numbering starts at 1
/// (the primary shell); at most `[isl_link9]`, so the sorted flattened
/// document keeps the sections in declaration order.
fn parse_isl_link_key(key: &str) -> Option<(usize, &str)> {
    let rest = key.strip_prefix("isl_link")?;
    let (num, field) = rest.split_once('.')?;
    let n: usize = num.parse().ok()?;
    if !(1..=9).contains(&n) {
        return None;
    }
    Some((n - 1, field))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_table1() {
        let c = ExperimentConfig::paper_defaults();
        assert_eq!(c.n_sats(), 40);
        assert_eq!(c.constellation.altitude_km, 2000.0);
        assert_eq!(c.constellation.inclination_deg, 80.0);
        assert_eq!(c.link.tx_power_dbm, 40.0);
        assert_eq!(c.link.tx_gain_dbi, 6.98);
        assert_eq!(c.link.carrier_hz, 2.4e9);
        assert_eq!(c.link.noise_temp_k, 354.81);
        assert_eq!(c.link.data_rate_bps, 16.0e6);
        assert_eq!(c.fl.lr, 0.01);
        assert_eq!(c.min_elevation_deg, 10.0);
        assert!(c.validate().is_empty());
    }

    #[test]
    fn toml_roundtrip() {
        let c0 = ExperimentConfig::paper_defaults();
        let c1 = ExperimentConfig::from_toml(&c0.to_toml()).unwrap();
        assert_eq!(c0, c1);
    }

    #[test]
    fn overrides_apply() {
        let c = ExperimentConfig::from_toml(
            "[fl]\nscheme = \"fedhap\"\nmodel = \"mlp\"\n[constellation]\norbits = 3\n",
        )
        .unwrap();
        assert_eq!(c.fl.scheme, SchemeKind::FedHap);
        assert_eq!(c.fl.model, ModelKind::Mlp);
        assert_eq!(c.constellation.n_orbits, 3);
        assert_eq!(c.constellation.sats_per_orbit, 8); // default kept
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml("nope = 1\n").is_err());
    }

    #[test]
    fn validation_catches_problems() {
        let mut c = ExperimentConfig::paper_defaults();
        c.fl.lr = 0.0;
        c.constellation.altitude_km = 50_000.0;
        let errs = c.validate();
        assert_eq!(errs.len(), 2, "{errs:?}");
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in [
            SchemeKind::AsyncFleo,
            SchemeKind::FedAvg,
            SchemeKind::FedIsl,
            SchemeKind::FedIslIdeal,
            SchemeKind::FedSat,
            SchemeKind::FedSpace,
            SchemeKind::FedHap,
            SchemeKind::SinkSat,
        ] {
            assert_eq!(SchemeKind::parse(s.name()), Some(s));
        }
        assert_eq!(SchemeKind::parse("bogus"), None);
    }

    #[test]
    fn sync_flags() {
        assert!(SchemeKind::FedHap.is_synchronous());
        assert!(SchemeKind::FedIsl.is_synchronous());
        assert!(!SchemeKind::AsyncFleo.is_synchronous());
        assert!(!SchemeKind::FedSat.is_synchronous());
        assert!(!SchemeKind::FedSpace.is_synchronous());
        assert!(!SchemeKind::SinkSat.is_synchronous(), "per-plane async updates");
    }

    #[test]
    fn placement_sites() {
        assert_eq!(PsPlacement::TwoHaps.sites().len(), 2);
        assert_eq!(PsPlacement::GsRolla.sites().len(), 1);
        assert_eq!(PsPlacement::GsNorthPole.sites()[0].lat_deg, 90.0);
    }

    #[test]
    fn test_small_is_valid() {
        assert!(ExperimentConfig::test_small().validate().is_empty());
    }

    #[test]
    fn fault_scenario_key_applies_preset() {
        let c = ExperimentConfig::from_toml("[faults]\nscenario = \"lossy\"\n").unwrap();
        assert_eq!(c.faults, FaultConfig::preset(FaultScenario::Lossy, 1.0));
        assert!(ExperimentConfig::from_toml("[faults]\nscenario = \"bogus\"\n").is_err());
    }

    #[test]
    fn fault_knobs_override_scenario_regardless_of_key_order() {
        // "loss_prob" sorts before "scenario" in the flattened doc; the
        // override must still win over the preset value (0.3).
        let c = ExperimentConfig::from_toml(
            "[faults]\nloss_prob = 0.05\nscenario = \"lossy\"\n",
        )
        .unwrap();
        assert_eq!(c.faults.loss_prob, 0.05);
        assert_eq!(c.faults.max_retransmits, 4, "rest of the preset kept");
    }

    #[test]
    fn faulty_config_roundtrips_through_toml() {
        let mut c0 = ExperimentConfig::paper_defaults();
        c0.faults = FaultConfig::preset(FaultScenario::Eclipse, 0.7);
        let c1 = ExperimentConfig::from_toml(&c0.to_toml()).unwrap();
        assert_eq!(c0, c1);
        let mut c0 = ExperimentConfig::paper_defaults();
        c0.faults = FaultConfig::preset(FaultScenario::Churn, 0.3);
        let c1 = ExperimentConfig::from_toml(&c0.to_toml()).unwrap();
        assert_eq!(c0, c1);
    }

    #[test]
    fn fault_validation_surfaces_in_config_validate() {
        let mut c = ExperimentConfig::paper_defaults();
        c.faults.loss_prob = 2.0;
        assert!(!c.validate().is_empty());
    }

    #[test]
    fn multi_shell_config_roundtrips_through_toml() {
        let mut c0 = ExperimentConfig::paper_defaults();
        c0.constellation.extra_shells = vec![
            ShellSpec::delta(6, 10, 1110.0, 53.8, 1),
            ShellSpec::star(3, 4, 1200.0, 87.9, 0),
        ];
        assert_eq!(c0.n_sats(), 40 + 60 + 12);
        assert_eq!(c0.constellation.n_planes(), 5 + 6 + 3);
        let c1 = ExperimentConfig::from_toml(&c0.to_toml()).unwrap();
        assert_eq!(c0, c1);
    }

    #[test]
    fn star_pattern_roundtrips() {
        let mut c0 = ExperimentConfig::paper_defaults();
        c0.constellation.pattern = WalkerPattern::Star;
        let c1 = ExperimentConfig::from_toml(&c0.to_toml()).unwrap();
        assert_eq!(c1.constellation.pattern, WalkerPattern::Star);
        assert!(ExperimentConfig::from_toml("[constellation]\npattern = \"bogus\"\n").is_err());
    }

    #[test]
    fn shell_sections_parse() {
        let c = ExperimentConfig::from_toml(
            "[shell2]\norbits = 6\nsats_per_orbit = 10\naltitude_km = 1110\ninclination_deg = 53.8\nphasing = 1\npattern = \"delta\"\n",
        )
        .unwrap();
        assert_eq!(c.constellation.extra_shells.len(), 1);
        assert_eq!(c.constellation.extra_shells[0], ShellSpec::delta(6, 10, 1110.0, 53.8, 1));
        // non-contiguous shells are rejected
        assert!(ExperimentConfig::from_toml("[shell3]\norbits = 2\n").is_err());
        // unknown shell fields are rejected
        assert!(ExperimentConfig::from_toml("[shell2]\nbogus = 2\n").is_err());
    }

    #[test]
    fn plane_of_maps_shells_to_global_planes() {
        let mut c = ExperimentConfig::paper_defaults();
        c.constellation.n_orbits = 2;
        c.constellation.sats_per_orbit = 3;
        c.constellation.extra_shells = vec![ShellSpec::delta(1, 4, 550.0, 53.0, 0)];
        let plane_of = c.constellation.plane_of();
        assert_eq!(plane_of, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 2]);
        assert_eq!(c.constellation.summary(), "2x3@2000km/80° + 1x4@550km/53°");
    }

    #[test]
    fn shell_validation_reports_bad_extra_shell() {
        let mut c = ExperimentConfig::paper_defaults();
        c.constellation.extra_shells = vec![ShellSpec::delta(2, 2, 50_000.0, 53.0, 0)];
        let errs = c.validate();
        assert!(errs.iter().any(|e| e.contains("shell2")), "{errs:?}");
    }

    #[test]
    fn isl_config_roundtrips_through_toml() {
        let mut c0 = ExperimentConfig::paper_defaults();
        c0.isl.topology = IslTopology::Grid;
        c0.isl.cross_shell = true;
        c0.isl.doppler = false;
        c0.isl.shell_links =
            vec![LinkParams { data_rate_bps: 2.0e6, tx_power_dbm: 33.0, ..LinkParams::default() }];
        let c1 = ExperimentConfig::from_toml(&c0.to_toml()).unwrap();
        assert_eq!(c0, c1);
        // defaults round-trip too (the [isl] section is always dumped)
        let d0 = ExperimentConfig::paper_defaults();
        assert_eq!(ExperimentConfig::from_toml(&d0.to_toml()).unwrap(), d0);
    }

    #[test]
    fn isl_sections_parse() {
        let c = ExperimentConfig::from_toml(
            "[isl]\ntopology = \"grid\"\ncross_shell = true\n\n[isl_link1]\ndata_rate_mbps = 2\n",
        )
        .unwrap();
        assert_eq!(c.isl.topology, IslTopology::Grid);
        assert!(c.isl.cross_shell);
        assert!(c.isl.doppler, "default kept");
        assert_eq!(c.isl.shell_links.len(), 1);
        assert_eq!(c.isl.shell_links[0].data_rate_bps, 2.0e6);
        assert_eq!(c.isl.shell_links[0].tx_power_dbm, 40.0, "unset fields keep Table I");
        // non-contiguous link sections and unknown keys are rejected
        assert!(ExperimentConfig::from_toml("[isl_link2]\ndata_rate_mbps = 2\n").is_err());
        assert!(ExperimentConfig::from_toml("[isl_link1]\nbogus = 2\n").is_err());
        assert!(ExperimentConfig::from_toml("[isl]\ntopology = \"mesh\"\n").is_err());
    }

    #[test]
    fn isl_link_overrides_beyond_shells_fail_validation() {
        let mut c = ExperimentConfig::paper_defaults();
        c.isl.shell_links = vec![LinkParams::default(); 2]; // 2 overrides, 1 shell
        assert!(!c.validate().is_empty());
        c.constellation.extra_shells = vec![ShellSpec::delta(2, 2, 550.0, 53.0, 0)];
        assert!(c.validate().is_empty());
    }

    #[test]
    fn network_config_roundtrips_through_toml() {
        let mut c0 = ExperimentConfig::paper_defaults();
        c0.network = NetworkConfig::preset(FaultScenario::Partition, 0.8);
        c0.network.partition_scope = PartitionScope::Shell;
        c0.network.partition_shell = 1;
        let c1 = ExperimentConfig::from_toml(&c0.to_toml()).unwrap();
        assert_eq!(c0, c1);
        let mut c0 = ExperimentConfig::paper_defaults();
        c0.network = NetworkConfig::preset(FaultScenario::Jitter, 0.5);
        c0.network.eclipse_from_sun = true;
        assert_eq!(ExperimentConfig::from_toml(&c0.to_toml()).unwrap(), c0);
        // defaults round-trip (nominal [network] is always dumped)
        let d0 = ExperimentConfig::paper_defaults();
        assert_eq!(ExperimentConfig::from_toml(&d0.to_toml()).unwrap(), d0);
    }

    #[test]
    fn network_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[network]\njitter_sigma = 0.2\nqueue_service_factor = 1.5\npartition_scope = \"shell\"\npartition_shell = 1\neclipse_from_sun = true\n",
        )
        .unwrap();
        assert_eq!(c.network.jitter_sigma, 0.2);
        assert_eq!(c.network.queue_service_factor, 1.5);
        assert_eq!(c.network.partition_scope, PartitionScope::Shell);
        assert_eq!(c.network.partition_shell, 1);
        assert!(c.network.eclipse_from_sun);
        assert!(
            ExperimentConfig::from_toml("[network]\npartition_scope = \"bogus\"\n").is_err()
        );
        let mut bad = ExperimentConfig::paper_defaults();
        bad.network.jitter_sigma = -1.0;
        assert!(!bad.validate().is_empty());
    }

    #[test]
    fn hap_quito_placement_parses() {
        assert_eq!(PsPlacement::parse("hap-quito"), Some(PsPlacement::HapQuito));
        assert_eq!(PsPlacement::HapQuito.sites().len(), 1);
        assert!(PsPlacement::HapQuito.sites()[0].lat_deg.abs() < 1.0, "equatorial");
    }
}
