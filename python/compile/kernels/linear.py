"""L1 Pallas kernel: fused linear layer  o = act(x @ W + b).

This is the compute hot-spot of every satellite's on-board training step
(the dense layers of the MLP and the CNN head), and it dominates the
FLOPs of both the forward and — through its transposes — the backward
pass that `jax.grad` derives from it.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the
output into (BM, BN) blocks targeted at the MXU systolic array; each grid
step keeps one x-slab [BM, K], one W-panel [K, BN] and the accumulator
[BM, BN] resident in VMEM. For the model sizes in this repo
(K ≤ 3136) the full contraction axis fits comfortably in VMEM
(BM·K + K·BN + BM·BN ≈ 32·3136 + 3136·128 + 32·128 floats ≈ 2.0 MiB ≪
16 MiB), so K is not tiled; the BlockSpec index maps express the
HBM↔VMEM schedule that a CUDA implementation would express with
threadblocks.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact executes on the Rust side. Real-TPU VMEM/MXU estimates live in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shape: BM matches the training mini-batch (32); BN is an
# MXU-friendly 128 multiple (the hidden width). Both are overridable for
# the hypothesis sweep in python/tests/.
DEFAULT_BM = 32
DEFAULT_BN = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    """One (BM, BN) output block: full-K contraction in VMEM."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pad_to(n, mult):
    return (n + mult - 1) // mult * mult


def _fused_linear_impl(x, w, b, activation, bm, bn, interpret):
    """Fused act(x @ w + b) via a tiled Pallas kernel.

    x: [M, K], w: [K, N], b: [N]. Arbitrary M, N: inputs are zero-padded
    to the block grid and the result sliced back (zero columns of W and
    zero rows of x contribute zeros, so padding is exact for both
    activations).
    """
    if activation not in ("relu", "none"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)

    bm_eff = min(bm, _pad_to(m, 8))
    bn_eff = min(bn, _pad_to(n, 8))
    mp, np_ = _pad_to(m, bm_eff), _pad_to(n, bn_eff)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))

    grid = (mp // bm_eff, np_ // bn_eff)
    out = pl.pallas_call(
        functools.partial(_linear_kernel, activation=activation),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_eff, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn_eff), lambda i, j: (0, j)),
            pl.BlockSpec((bn_eff,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_eff, bn_eff), lambda i, j: (i, j)),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


# ----------------------------------------------------------------------
# Autodiff: Pallas calls have no built-in VJP, so we define one whose
# backward matmuls (dx = g·Wᵀ, dW = xᵀ·g) ALSO route through the kernel —
# the backward pass of on-board training is the other half of the
# hot-spot FLOPs.
# ----------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fused_linear(x, w, b, activation, bm, bn, interpret):
    return _fused_linear_impl(x, w, b, activation, bm, bn, interpret)


def _fused_linear_fwd(x, w, b, activation, bm, bn, interpret):
    o = _fused_linear_impl(x, w, b, activation, bm, bn, interpret)
    # For relu the mask (o > 0) is all we need; keep o as the residual.
    res = (x, w, o if activation == "relu" else None)
    return o, res


def _fused_linear_bwd(activation, bm, bn, interpret, res, g):
    x, w, o = res
    if activation == "relu":
        g = g * (o > 0.0).astype(g.dtype)
    k = x.shape[1]
    n = w.shape[1]
    zk = jnp.zeros((k,), g.dtype)
    zn = jnp.zeros((n,), g.dtype)
    dx = _fused_linear_impl(g, w.T, zk, "none", bm, bn, interpret)
    dw = _fused_linear_impl(x.T, g, zn, "none", bm, bn, interpret)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


_fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "interpret")
)
def fused_linear(x, w, b, activation="relu", bm=DEFAULT_BM, bn=DEFAULT_BN,
                 interpret=True):
    """Differentiable fused act(x @ w + b). See `_fused_linear_impl`."""
    return _fused_linear(x, w, b, activation, bm, bn, interpret)


def vmem_bytes(m, k, n, bm=DEFAULT_BM, bn=DEFAULT_BN, dtype_bytes=4):
    """Static VMEM footprint estimate for one grid step (perf model)."""
    del m
    return dtype_bytes * (bm * k + k * bn + bn + bm * bn)
