//! Analytic FL surrogate backend for fast coordinator/strategy tests.
//!
//! The model state is a 10-dim "per-class knowledge" vector. Local
//! training raises knowledge of the classes present in the satellite's
//! shard (with diminishing returns) and slightly decays the others —
//! reproducing the qualitative FL phenomena the coordinator logic must
//! handle: non-IID bias (a model trained on 4 classes can't classify
//! the other 6), the value of aggregating across groups, and the harm
//! of stale models. Accuracy is the class-frequency-weighted knowledge
//! with a 1/K guessing floor.
//!
//! Parameter vectors are `CLASSES`-dim [`ModelParams`], so every
//! aggregation/distance path exercises the same code as the real
//! backend.

use super::{Backend, EvalResult};
use crate::model::ModelParams;

pub const CLASSES: usize = 10;

/// Learning-rate of the knowledge update per dispatch.
const LEARN_RATE: f64 = 0.35;
/// Forgetting of classes absent from the local shard.
const FORGET: f64 = 0.02;

/// Fast surrogate backend.
pub struct SurrogateBackend {
    /// Per-satellite class histogram (normalized).
    class_mix: Vec<[f64; CLASSES]>,
    shard_sizes: Vec<usize>,
    /// Test-set class frequencies (uniform for our synth sets).
    test_mix: [f64; CLASSES],
    /// Max reachable per-class accuracy (irreducible noise).
    ceiling: f64,
}

impl SurrogateBackend {
    /// Build from explicit per-satellite class histograms.
    pub fn new(class_mix: Vec<[f64; CLASSES]>, shard_sizes: Vec<usize>) -> Self {
        assert_eq!(class_mix.len(), shard_sizes.len());
        SurrogateBackend {
            class_mix,
            shard_sizes,
            test_mix: [1.0 / CLASSES as f64; CLASSES],
            ceiling: 0.92,
        }
    }

    /// Build the paper's split: `n_orbits * sats_per_orbit` satellites;
    /// IID (all classes) or the paper non-IID split.
    pub fn paper_split(n_orbits: usize, sats_per_orbit: usize, iid: bool, base_size: usize) -> Self {
        Self::for_planes(&crate::orbit::uniform_plane_of(n_orbits, sats_per_orbit), iid, base_size)
    }

    /// The backend a config's surrogate run uses: one sizing rule
    /// shared by the experiment drivers, the run-equivalence suite and
    /// `bench_runloop` (so they can never drift apart).
    pub fn for_config(cfg: &crate::config::ExperimentConfig) -> Self {
        Self::for_planes(
            &cfg.constellation.plane_of(),
            cfg.fl.partition == crate::data::Partition::Iid,
            cfg.data.train_samples / cfg.n_sats().max(1),
        )
    }

    /// Build from an explicit satellite→plane mapping (multi-shell
    /// constellations; see `WalkerConstellation::plane_of`). The paper
    /// non-IID structure generalizes by *global* plane index: the first
    /// two planes hold classes 0..4, the rest classes 4..10.
    pub fn for_planes(plane_of: &[usize], iid: bool, base_size: usize) -> Self {
        let n = plane_of.len();
        let n_planes = plane_of.iter().max().map_or(0, |m| m + 1);
        let mut mixes = Vec::with_capacity(n);
        let mut sizes = Vec::with_capacity(n);
        for (sat, &orbit) in plane_of.iter().enumerate() {
            let mut mix = [0.0f64; CLASSES];
            if iid {
                mix = [1.0 / CLASSES as f64; CLASSES];
            } else if orbit < 2.min(n_planes) {
                for m in mix.iter_mut().take(4) {
                    *m = 0.25;
                }
            } else {
                for m in mix.iter_mut().skip(4) {
                    *m = 1.0 / 6.0;
                }
            }
            mixes.push(mix);
            // mild deterministic size variation
            sizes.push(base_size + (sat * 7) % (base_size / 2 + 1));
        }
        SurrogateBackend::new(mixes, sizes)
    }

    fn knowledge(params: &ModelParams) -> &[f32] {
        &params.data
    }
}

impl Backend for SurrogateBackend {
    fn dim(&self) -> usize {
        CLASSES
    }

    fn n_sats(&self) -> usize {
        self.class_mix.len()
    }

    fn shard_size(&self, sat: usize) -> usize {
        self.shard_sizes[sat]
    }

    fn init_global(&mut self, _seed: i32) -> ModelParams {
        ModelParams::zeros(CLASSES)
    }

    fn train_local(
        &mut self,
        sat: usize,
        params: &ModelParams,
        dispatches: usize,
    ) -> (ModelParams, f64) {
        let mut out = ModelParams { data: Vec::with_capacity(CLASSES) };
        let loss = self.train_local_into(sat, params, dispatches, &mut out);
        (out, loss)
    }

    /// Allocation-free training: a stack `[f64; CLASSES]` buffer plus
    /// the caller's reused `out` — nothing is heap-allocated on the
    /// event loop once `out` has capacity.
    fn train_local_into(
        &mut self,
        sat: usize,
        params: &ModelParams,
        dispatches: usize,
        out: &mut ModelParams,
    ) -> f64 {
        let mix = &self.class_mix[sat];
        // loud in release too: a mis-sized model must fail fast, not
        // train on a zero-filled tail (the old Vec path panicked here)
        assert_eq!(params.data.len(), CLASSES, "surrogate params dim");
        let mut k = [0.0f64; CLASSES];
        for (kc, &v) in k.iter_mut().zip(&params.data) {
            *kc = v as f64;
        }
        for _ in 0..dispatches {
            for c in 0..CLASSES {
                if mix[c] > 0.0 {
                    // diminishing-returns learning toward 1.0, faster
                    // for more-frequent classes
                    let rate = LEARN_RATE * (mix[c] * CLASSES as f64).min(2.0);
                    k[c] += rate * (1.0 - k[c]);
                } else {
                    k[c] *= 1.0 - FORGET;
                }
            }
        }
        out.data.clear();
        out.data.extend(k.iter().map(|&v| v as f32));
        // surrogate loss: cross-entropy-ish on local mix
        let local_acc: f64 = (0..CLASSES).map(|c| mix[c] * k[c]).sum();
        -(local_acc.clamp(1e-3, 1.0)).ln()
    }

    // evaluate is already allocation-free: the accuracy reduction runs
    // on the borrowed knowledge slice and returns a Copy struct.
    fn evaluate(&mut self, params: &ModelParams) -> EvalResult {
        let k = Self::knowledge(params);
        let floor = 1.0 / CLASSES as f64;
        let acc: f64 = (0..CLASSES)
            .map(|c| {
                let kn = (k[c] as f64).clamp(0.0, 1.0);
                self.test_mix[c] * (floor + (self.ceiling - floor) * kn)
            })
            .sum();
        EvalResult { accuracy: acc, loss: -acc.max(1e-3).ln() }
    }

    fn aggregate(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
    ) -> ModelParams {
        let mut out = ModelParams { data: Vec::with_capacity(prev.dim()) };
        self.aggregate_into(prev, models, coeffs, coeff_prev, &mut out);
        out
    }

    /// Allocation-free aggregation: the zero-init + axpy sequence of
    /// `weighted_sum([prev, models...], [coeff_prev, coeffs...])`
    /// applied directly to `out` — same floats, no ref/weight vectors.
    fn aggregate_into(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
        out: &mut ModelParams,
    ) {
        assert_eq!(models.len(), coeffs.len());
        out.reset_zeros(prev.dim());
        out.axpy(coeff_prev, prev);
        for (m, &c) in models.iter().zip(coeffs) {
            out.axpy(c, m);
        }
    }

    fn distances(&mut self, models: &[&ModelParams], reference: &ModelParams) -> Vec<f64> {
        let mut out = Vec::with_capacity(models.len());
        self.distances_into(models, reference, &mut out);
        out
    }

    /// Allocation-free distance batch into the caller's reused buffer.
    fn distances_into(
        &mut self,
        models: &[&ModelParams],
        reference: &ModelParams,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.extend(models.iter().map(|m| m.l2_distance(reference)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_ignorant() {
        let mut b = SurrogateBackend::paper_split(5, 8, true, 100);
        let g = b.init_global(0);
        let e = b.evaluate(&g);
        assert!((e.accuracy - 0.1).abs() < 1e-9, "guessing floor");
    }

    #[test]
    fn training_improves_local_knowledge() {
        let mut b = SurrogateBackend::paper_split(5, 8, true, 100);
        let g = b.init_global(0);
        let (m, _) = b.train_local(0, &g, 3);
        let e0 = b.evaluate(&g);
        let e1 = b.evaluate(&m);
        assert!(e1.accuracy > e0.accuracy + 0.1);
    }

    #[test]
    fn non_iid_single_sat_caps_accuracy() {
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let g = b.init_global(0);
        // satellite 0 holds only 4 classes: even infinite training
        // can't exceed 4/10 coverage (+ guessing floor on the rest)
        let (m, _) = b.train_local(0, &g, 50);
        let e = b.evaluate(&m);
        assert!(e.accuracy < 0.55, "acc {} should be capped", e.accuracy);
        assert!(e.accuracy > 0.3);
    }

    #[test]
    fn aggregating_across_groups_beats_single_group() {
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let g = b.init_global(0);
        let (low, _) = b.train_local(0, &g, 10); // classes 0..4
        let (high, _) = b.train_local(39, &g, 10); // classes 4..10
        let merged = b.aggregate(&g, &[&low, &high], &[0.5, 0.5], 0.0);
        let e_low = b.evaluate(&low);
        let e_merged = b.evaluate(&merged);
        assert!(
            e_merged.accuracy > e_low.accuracy + 0.05,
            "merged {} vs single-group {}",
            e_merged.accuracy,
            e_low.accuracy
        );
    }

    #[test]
    fn distances_separate_the_two_orbit_groups() {
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let g = b.init_global(0);
        let (a, _) = b.train_local(0, &g, 5); // low-class orbit
        let (a2, _) = b.train_local(8, &g, 5); // also low-class orbit
        let (c, _) = b.train_local(39, &g, 5); // high-class orbit
        let d = b.distances(&[&a, &a2, &c], &g);
        // same-group distances similar, cross-group clearly different
        assert!((d[0] - d[1]).abs() < 0.2 * d[0]);
        assert!((d[0] - d[2]).abs() > 0.1 * d[0]);
    }

    #[test]
    fn shard_sizes_vary() {
        let b = SurrogateBackend::paper_split(5, 8, true, 100);
        let sizes: Vec<usize> = (0..40).map(|s| b.shard_size(s)).collect();
        assert!(sizes.iter().any(|&s| s != sizes[0]));
    }

    #[test]
    fn in_place_variants_match_allocating_bitwise() {
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let g = b.init_global(0);
        let (m0, l0) = b.train_local(3, &g, 4);
        let mut m0b = ModelParams::zeros(0);
        let l0b = b.train_local_into(3, &g, 4, &mut m0b);
        assert_eq!(l0.to_bits(), l0b.to_bits());
        for (a, c) in m0.data.iter().zip(&m0b.data) {
            assert_eq!(a.to_bits(), c.to_bits());
        }

        let (m1, _) = b.train_local(39, &g, 4);
        let agg = b.aggregate(&g, &[&m0, &m1], &[0.3, 0.2], 0.5);
        let mut aggb = ModelParams::zeros(0);
        b.aggregate_into(&g, &[&m0, &m1], &[0.3, 0.2], 0.5, &mut aggb);
        for (a, c) in agg.data.iter().zip(&aggb.data) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
        // and against the original two-Vec weighted_sum assembly
        let want = ModelParams::weighted_sum(&[&g, &m0, &m1], &[0.5, 0.3, 0.2]);
        for (a, c) in want.data.iter().zip(&aggb.data) {
            assert_eq!(a.to_bits(), c.to_bits());
        }

        let d = b.distances(&[&m0, &m1], &g);
        let mut db = vec![99.0]; // dirty reused buffer
        b.distances_into(&[&m0, &m1], &g, &mut db);
        assert_eq!(d.len(), db.len());
        for (a, c) in d.iter().zip(&db) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }
}
