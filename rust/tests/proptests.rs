//! Property-based tests over the coordinator invariants (grouping,
//! routing, batching, staleness, event ordering) using the in-crate
//! testkit (`forall` with seeded, replayable cases).

use asyncfleo::coordinator::analytic::{pass_map_build_count, shared_pass_map};
use asyncfleo::coordinator::ContactPlan;
use asyncfleo::fl::aggregation::{select_and_weigh, Candidate};
use asyncfleo::fl::grouping::GroupingState;
use asyncfleo::model::{ModelMetadata, ModelParams};
use asyncfleo::orbit::{
    contact_windows, GeodeticSite, OrbitalElements, SiteKind, WalkerConstellation,
};
use asyncfleo::sim::{Event, EventKind, EventQueue, LanedQueue};
use asyncfleo::testkit::{forall, forall_seeded};
use asyncfleo::topology::HapRing;
use asyncfleo::util::Rng;

// ---------------------------------------------------------------------
// Aggregation (Eqs. 13–14)
// ---------------------------------------------------------------------

fn random_candidates(rng: &mut Rng, beta: u64) -> Vec<Candidate> {
    let n = rng.range_usize(0, 30);
    (0..n)
        .map(|i| Candidate {
            meta: ModelMetadata {
                sat_id: i,
                orbit: rng.below(5),
                data_size: rng.range_usize(1, 1000),
                loc_rad: rng.range_f64(0.0, 6.28),
                ts_s: rng.range_f64(0.0, 1e5),
                epoch: rng.below(beta as usize + 1) as u64,
            },
            group: rng.below(4),
        })
        .collect()
}

#[test]
fn aggregation_always_convex() {
    forall(|rng| {
        let beta = rng.range_usize(1, 12) as u64;
        let cs = random_candidates(rng, beta);
        let total: usize = cs.iter().map(|c| c.meta.data_size).sum();
        let sel = select_and_weigh(&cs, beta, total + 1000);
        let total: f64 =
            sel.coeff_prev as f64 + sel.chosen.iter().map(|&(_, w)| w as f64).sum::<f64>();
        assert!((total - 1.0).abs() < 1e-4, "not convex: {total}");
        assert!((0.0..=1.0 + 1e-6).contains(&(sel.gamma as f64)));
        for &(i, w) in &sel.chosen {
            assert!(i < cs.len());
            assert!((0.0..=1.0).contains(&w));
        }
    });
}

#[test]
fn aggregation_never_selects_stale_when_group_has_fresh() {
    forall(|rng| {
        let beta = rng.range_usize(1, 10) as u64;
        let cs = random_candidates(rng, beta);
        let total: usize = cs.iter().map(|c| c.meta.data_size).sum();
        let sel = select_and_weigh(&cs, beta, total + 1000);
        for &(i, _) in &sel.chosen {
            let g = cs[i].group;
            let group_has_fresh =
                cs.iter().any(|c| c.group == g && c.meta.is_fresh(beta));
            if group_has_fresh {
                assert!(
                    cs[i].meta.is_fresh(beta),
                    "stale model selected from group with fresh members"
                );
            }
        }
    });
}

#[test]
fn aggregation_weighted_sum_preserves_bounds() {
    // a convex combination of models stays inside the coordinate-wise
    // envelope of its inputs
    forall_seeded(0xBEEF, 50, |rng| {
        let dim = rng.range_usize(1, 64);
        let k = rng.range_usize(1, 6);
        let models: Vec<ModelParams> = (0..k)
            .map(|_| ModelParams {
                data: (0..dim).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
            })
            .collect();
        let mut ws: Vec<f32> = (0..k).map(|_| rng.f32()).collect();
        let total: f32 = ws.iter().sum();
        if total <= 0.0 {
            return;
        }
        ws.iter_mut().for_each(|w| *w /= total);
        let refs: Vec<&ModelParams> = models.iter().collect();
        let out = ModelParams::weighted_sum(&refs, &ws);
        for d in 0..dim {
            let lo = models.iter().map(|m| m.data[d]).fold(f32::INFINITY, f32::min);
            let hi = models.iter().map(|m| m.data[d]).fold(f32::NEG_INFINITY, f32::max);
            assert!(out.data[d] >= lo - 1e-4 && out.data[d] <= hi + 1e-4);
        }
    });
}

// ---------------------------------------------------------------------
// Grouping
// ---------------------------------------------------------------------

#[test]
fn grouping_is_a_partition() {
    forall(|rng| {
        let n_orbits = rng.range_usize(1, 10);
        let dim = rng.range_usize(4, 64);
        let mut g = GroupingState::new(n_orbits);
        for orbit in 0..n_orbits {
            let std = rng.range_f64(0.1, 10.0);
            let p = ModelParams { data: asyncfleo::testkit::gen_vec_f32(rng, dim, std) };
            let d0 = p.l2_norm();
            g.assign(orbit, &p, d0);
        }
        assert!(g.all_grouped());
        // group ids dense in [0, n_groups)
        for o in 0..n_orbits {
            assert!(g.group_of(o).unwrap() < g.n_groups());
        }
        // every group non-empty
        for gid in 0..g.n_groups() {
            assert!((0..n_orbits).any(|o| g.group_of(o) == Some(gid)));
        }
    });
}

#[test]
fn grouping_identical_partials_single_group() {
    forall(|rng| {
        let n = rng.range_usize(2, 8);
        let dim = rng.range_usize(4, 32);
        let p = ModelParams { data: asyncfleo::testkit::gen_vec_f32(rng, dim, 1.0) };
        let d0 = p.l2_norm().max(1e-6);
        let mut g = GroupingState::new(n);
        for o in 0..n {
            g.assign(o, &p, d0);
        }
        assert_eq!(g.n_groups(), 1, "identical partials must form one group");
    });
}

// ---------------------------------------------------------------------
// Topology / routing
// ---------------------------------------------------------------------

#[test]
fn ring_routing_terminates_via_shortest_arc() {
    forall(|rng| {
        let n = rng.range_usize(1, 12);
        let ring = HapRing::new(n);
        let i = rng.below(n);
        let j = rng.below(n);
        let mut cur = i;
        let mut hops = 0;
        while cur != j {
            cur = ring.next_hop_toward(cur, j).unwrap();
            hops += 1;
            assert!(hops <= n, "loop");
        }
        let cw = (j + n - i) % n;
        assert_eq!(hops, cw.min(n - cw), "not the shortest arc");
    });
}

#[test]
fn relay_plan_reaches_everyone_exactly_once() {
    forall(|rng| {
        let n = rng.range_usize(1, 12);
        let from = rng.below(n);
        let ring = HapRing::new(n);
        let plan = ring.relay_plan(from);
        let mut recv = vec![0usize; n];
        for (_, fwds) in &plan {
            for &f in fwds {
                recv[f] += 1;
            }
        }
        for (j, &r) in recv.iter().enumerate() {
            assert_eq!(r, usize::from(j != from), "node {j}");
        }
    });
}

#[test]
fn walker_ring_neighbors_consistent() {
    forall(|rng| {
        let orbits = rng.range_usize(1, 8);
        let spo = rng.range_usize(1, 10);
        let c = WalkerConstellation::new(orbits, spo, 1200.0, 70.0, 1);
        let id = rng.below(c.len());
        let (p, n) = c.ring_neighbors(id);
        assert_eq!(c.satellites[p].orbit, c.satellites[id].orbit);
        assert_eq!(c.satellites[n].orbit, c.satellites[id].orbit);
        if spo > 2 {
            assert_ne!(p, n);
        }
    });
}

// ---------------------------------------------------------------------
// Orbits / contact windows
// ---------------------------------------------------------------------

#[test]
fn orbit_radius_invariant_under_random_elements() {
    forall(|rng| {
        let e = OrbitalElements {
            altitude_km: rng.range_f64(300.0, 2500.0),
            inclination_rad: rng.range_f64(0.0, std::f64::consts::PI),
            raan_rad: rng.range_f64(0.0, 6.28),
            phase_rad: rng.range_f64(0.0, 6.28),
        };
        let t = rng.range_f64(0.0, 1e6);
        let r = asyncfleo::orbit::satellite_position_eci(&e, t).norm();
        assert!((r - e.semi_major_axis_km()).abs() < 1e-6);
    });
}

#[test]
fn contact_windows_are_sorted_disjoint_within_horizon() {
    forall_seeded(0xC0FFEE, 30, |rng| {
        // random periodic visibility pattern
        let period = rng.range_f64(100.0, 5000.0);
        let duty = rng.range_f64(0.05, 0.9);
        let horizon = rng.range_f64(1000.0, 50_000.0);
        let wins = contact_windows(
            |t| (t / period).fract() < duty,
            horizon,
            period / 7.3,
        );
        for w in &wins {
            assert!(w.start_s >= 0.0 && w.end_s <= horizon + 1e-9);
            assert!(w.end_s >= w.start_s);
        }
        for p in wins.windows(2) {
            assert!(p[0].end_s <= p[1].start_s);
        }
    });
}

// ---------------------------------------------------------------------
// Analytic pass maps (PR 7)
// ---------------------------------------------------------------------

#[test]
fn analytic_first_contact_never_later_than_reference() {
    // The pass map's `next_possible(…, 0.0)` is a conservative lower
    // bound on the first contact: everything before it is proven
    // invisible, so the reference scan's first window cannot start
    // earlier than one grid step below it — and an INFINITY verdict
    // means the reference must find no windows at all.
    let populated = std::sync::atomic::AtomicUsize::new(0);
    forall_seeded(0xA11C, 25, |rng| {
        let alt = rng.range_f64(500.0, 2000.0);
        let inc_deg = rng.range_f64(10.0, 170.0);
        let lat = rng.range_f64(-80.0, 80.0);
        let lon = rng.range_f64(-180.0, 180.0);
        let c = WalkerConstellation::new(1, 1, alt, inc_deg, 0);
        let site = GeodeticSite { kind: SiteKind::Hap, lat_deg: lat, lon_deg: lon, alt_km: 20.0 };
        let eff = site.effective_min_elevation_deg(10.0);
        let e = &c.satellites[0].elements;
        let horizon = 86_400.0;

        let map = shared_pass_map(alt, e.inclination_rad, &site, eff);
        let tp = map.next_possible(
            site.lon_deg.to_radians() - e.raan_rad,
            e.phase_rad,
            e.mean_motion_rad_s(),
            horizon,
            0.0,
        );
        let plan = ContactPlan::build_reference(&c, &[site], 10.0, horizon);
        let ws = plan.windows(0, 0);
        if tp.is_infinite() {
            assert!(
                ws.is_empty(),
                "map proved no pass within {horizon} s but reference found {} windows \
                 (alt {alt}, inc {inc_deg}, lat {lat})",
                ws.len()
            );
        } else if let Some(w) = ws.first() {
            // the bisected start lies within one 30 s grid step of the
            // true flip, and the true flip is >= tp
            assert!(
                w.start_s >= tp - 30.0 - 1e-6,
                "reference window starts {} but map promised nothing before {tp} \
                 (alt {alt}, inc {inc_deg}, lat {lat})",
                w.start_s
            );
            populated.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    });
    // the property must not hold vacuously: most draws see real passes
    assert!(
        populated.load(std::sync::atomic::Ordering::Relaxed) >= 5,
        "too few draws produced contact windows"
    );
}

#[test]
fn pass_map_is_memoized_across_a_whole_shell() {
    // One shell × one site = one pass-map build, however many
    // satellites the plan scans (raan/phase enter the query, not the
    // map key). The altitude is unique to this test so parallel tests
    // can't warm the process-wide cache for us.
    let alt = 913.7753;
    let inc_deg = 61.37;
    let c = WalkerConstellation::new(3, 5, alt, inc_deg, 1);
    let site = GeodeticSite::rolla_hap();
    let eff = site.effective_min_elevation_deg(10.0);
    let inc_rad = inc_deg.to_radians();

    let plan = ContactPlan::build_with_threads(&c, &[site], 10.0, 21_600.0, 2);
    assert_eq!(
        pass_map_build_count(alt, inc_rad, &site, eff),
        1,
        "15 satellites over one site must share a single pass map"
    );
    // a second build (any thread count) hits the cache, builds nothing
    let plan2 = ContactPlan::build_with_threads(&c, &[site], 10.0, 21_600.0, 1);
    assert_eq!(pass_map_build_count(alt, inc_rad, &site, eff), 1);
    assert_eq!(plan.total_windows(), plan2.total_windows());
}

// ---------------------------------------------------------------------
// Event queue
// ---------------------------------------------------------------------

#[test]
fn event_queue_total_order_random_times() {
    forall(|rng| {
        let mut q = EventQueue::new();
        let n = rng.range_usize(1, 200);
        for _ in 0..n {
            q.push(Event::new(rng.range_f64(0.0, 1e6), EventKind::Sweep));
        }
        let mut last = -1.0;
        let mut count = 0;
        while let Some(e) = q.pop() {
            assert!(e.time_s >= last);
            last = e.time_s;
            count += 1;
        }
        assert_eq!(count, n);
    });
}

#[test]
fn laned_queue_pop_order_matches_single_queue() {
    // The PR-9 determinism contract: a k-way merge over per-lane heaps
    // keyed by (time, global seq) pops in exactly single-queue order,
    // for any lane count, any plane map, time ties on purpose, and
    // pushes interleaved with partial drains (events landing in other
    // lanes mid-run must not reorder anything).
    fn random_kind(rng: &mut Rng) -> EventKind {
        let id = rng.below(64);
        match rng.below(6) {
            0 => EventKind::TrainingDone { sat: id },
            1 => EventKind::SatChurn { sat: id, up: true },
            2 => EventKind::HapLocalArrival { hap: id, origin_sat: id, epoch: 0 },
            3 => EventKind::OutageEnd { site: id },
            4 => EventKind::AggregationTick,
            _ => EventKind::Sweep,
        }
    }
    forall(|rng| {
        let lanes = rng.range_usize(1, 6);
        let n_planes = rng.range_usize(1, 8);
        let plane_of: Vec<usize> =
            (0..rng.range_usize(0, 48)).map(|_| rng.below(n_planes)).collect();
        let mut single = EventQueue::new();
        let mut laned = LanedQueue::new(lanes, plane_of);
        for _round in 0..3 {
            // the coarse half-second grid forces cross-lane time ties,
            // exercising the global-seq tie-break
            let n = rng.range_usize(1, 60);
            let base = single.now();
            for _ in 0..n {
                let t = base + (rng.below(40) as f64) * 0.5;
                let e = Event::new(t, random_kind(rng));
                single.push(e.clone());
                laned.push(e);
            }
            // drain part of the backlog, then push the next wave on top
            let drain = rng.below(single.len() + 1);
            for _ in 0..drain {
                assert_eq!(laned.pop(), single.pop());
                assert_eq!(laned.now(), single.now());
            }
        }
        loop {
            let a = single.pop();
            let b = laned.pop();
            assert_eq!(b, a);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(laned.high_water(), single.high_water());
    });
}

#[test]
fn metadata_staleness_ratio_always_in_unit_interval() {
    forall(|rng| {
        let md = ModelMetadata {
            sat_id: 0,
            orbit: 0,
            data_size: 1,
            loc_rad: 0.0,
            ts_s: 0.0,
            epoch: rng.below(50) as u64,
        };
        let beta = rng.below(50) as u64;
        let r = md.staleness_ratio(beta);
        assert!((0.0..=1.0).contains(&r), "ratio {r}");
    });
}
