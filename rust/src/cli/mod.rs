//! Command-line argument parser substrate (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options, and positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). `known_flags` lists
    /// boolean flags (which consume no value); everything else starting
    /// with `--` is a key-value option.
    pub fn parse(
        argv: &[String],
        expect_subcommand: bool,
        known_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if expect_subcommand {
            match it.peek() {
                Some(s) if !s.starts_with('-') => {
                    out.subcommand = Some(it.next().unwrap().clone());
                }
                _ => {}
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends option parsing
                    out.positional.extend(it.cloned());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("option --{body} expects a value"))?;
                    out.options.insert(body.to_string(), v.clone());
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                return Err(format!("unknown short option {arg} (use --long form)"));
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("option --{name}: cannot parse {s:?}")),
        }
    }

    /// Option names that were provided (for unknown-option checks).
    pub fn option_names(&self) -> impl Iterator<Item = &str> {
        self.options.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(&sv(&["exp", "--out", "results", "--seed=7"]), true, &[]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.opt("out"), Some("results"));
        assert_eq!(a.opt("seed"), Some("7"));
    }

    #[test]
    fn flags_consume_no_value() {
        let a = Args::parse(&sv(&["run", "--verbose", "pos1"]), true, &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(&sv(&["--out"]), false, &[]).unwrap_err();
        assert!(e.contains("--out"));
    }

    #[test]
    fn double_dash_ends_options() {
        let a = Args::parse(&sv(&["--", "--not-an-option"]), false, &[]).unwrap();
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn opt_parse_types() {
        let a = Args::parse(&sv(&["--n", "12", "--x", "1.5"]), false, &[]).unwrap();
        assert_eq!(a.opt_parse::<usize>("n").unwrap(), Some(12));
        assert_eq!(a.opt_parse::<f64>("x").unwrap(), Some(1.5));
        assert_eq!(a.opt_parse::<usize>("missing").unwrap(), None);
        let a = Args::parse(&sv(&["--n", "abc"]), false, &[]).unwrap();
        assert!(a.opt_parse::<usize>("n").is_err());
    }

    #[test]
    fn short_options_rejected() {
        assert!(Args::parse(&sv(&["-x"]), false, &[]).is_err());
    }

    #[test]
    fn no_subcommand_when_option_first() {
        let a = Args::parse(&sv(&["--out", "x"]), true, &[]).unwrap();
        assert_eq!(a.subcommand, None);
        assert_eq!(a.opt("out"), Some("x"));
    }
}
