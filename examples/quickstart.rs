//! Quickstart: the smallest end-to-end AsyncFLEO run.
//!
//! Builds the paper constellation, loads the AOT JAX/Pallas artifacts
//! through PJRT, and runs AsyncFLEO with a single HAP over a few
//! simulated hours on the SynthDigits MLP.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use asyncfleo::config::{ExperimentConfig, ModelKind, PsPlacement, SchemeKind};
use asyncfleo::coordinator::SimEnv;
use asyncfleo::data::Partition;
use asyncfleo::fl::make_strategy;
use asyncfleo::runtime::Runtime;
use asyncfleo::train::PjrtBackend;
use asyncfleo::util::fmt_hm;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    // 1. configuration: the paper's Table I defaults, scaled-down data
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    cfg.fl.model = ModelKind::Mlp;
    cfg.fl.partition = Partition::NonIidPaper;
    cfg.placement = PsPlacement::HapRolla;
    cfg.data.train_samples = 2000;
    cfg.data.test_samples = 500;
    cfg.fl.max_epochs = 12;
    cfg.fl.horizon_s = 24.0 * 3600.0;

    // 2. runtime: load + compile the AOT artifacts (L1/L2 compute)
    let runtime = Rc::new(Runtime::new(Runtime::default_dir())?);
    println!("PJRT platform: {}", runtime.platform());

    // 3. backend: synthetic data partitioned non-IID across 40 sats
    let mut backend = PjrtBackend::from_config(runtime, &cfg)?;

    // 4. run the paper's strategy over the simulated constellation
    let mut env = SimEnv::new(&cfg, &mut backend);
    let result = make_strategy(cfg.fl.scheme).run(&mut env);

    println!("\nepoch  sim-time  accuracy");
    for p in &result.curve.points {
        println!("{:>5}  {:>8}  {:>7.2}%", p.epoch, fmt_hm(p.time_s), p.accuracy * 100.0);
    }
    match result.converged {
        Some((t, acc)) => {
            println!("\nconverged at {} — plateau accuracy {:.2}%", fmt_hm(t), acc * 100.0)
        }
        None => println!("\nno plateau within horizon (final {:.2}%)", result.final_accuracy * 100.0),
    }
    println!("{} global epochs, {} model transfers", result.epochs, result.transfers);
    Ok(())
}
