//! Local training / evaluation / PS-compute backends.
//!
//! The FL strategies and the coordinator are generic over [`Backend`]:
//!
//! * [`PjrtBackend`] — the real thing: every train / eval / aggregate /
//!   distance call executes an AOT-compiled JAX+Pallas artifact through
//!   the PJRT runtime. Used by the experiment drivers and the
//!   end-to-end example.
//! * [`SurrogateBackend`] — a fast analytic stand-in with the same
//!   qualitative FL dynamics (per-class knowledge state, non-IID bias,
//!   staleness decay). Used by coordinator/strategy unit tests and the
//!   pure-L3 micro-benches, where PJRT would dominate runtime without
//!   adding signal.

pub mod pjrt;
pub mod sampler;
pub mod surrogate;

pub use pjrt::PjrtBackend;
pub use surrogate::SurrogateBackend;

use crate::model::ModelParams;

/// Evaluation result on the held-out test set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    /// Accuracy in [0, 1].
    pub accuracy: f64,
    /// Mean loss.
    pub loss: f64,
}

/// What the FL layer needs from the compute substrate.
pub trait Backend {
    /// Flat parameter dimension D.
    fn dim(&self) -> usize;

    /// Number of satellites (data shards) this backend serves.
    fn n_sats(&self) -> usize;

    /// Shard size m_n of satellite `sat` (enters Eqs. 12–13).
    fn shard_size(&self, sat: usize) -> usize;

    /// Deterministic global-model initialization.
    fn init_global(&mut self, seed: i32) -> ModelParams;

    /// One on-board visit: `dispatches` train-artifact executions (each
    /// folds J local SGD steps). Returns updated params + mean loss.
    fn train_local(
        &mut self,
        sat: usize,
        params: &ModelParams,
        dispatches: usize,
    ) -> (ModelParams, f64);

    /// Evaluate params on the held-out test set.
    fn evaluate(&mut self, params: &ModelParams) -> EvalResult;

    /// Staleness-discounted aggregation (paper Eq. 14):
    /// `coeff_prev * prev + Σ coeffs[i] * models[i]`.
    fn aggregate(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
    ) -> ModelParams;

    /// Weight divergences ‖mᵢ − reference‖₂ (grouping metric, IV-C1).
    fn distances(&mut self, models: &[&ModelParams], reference: &ModelParams) -> Vec<f64>;

    // --- in-place variants (the event-loop fast path) ---------------
    //
    // Strategies call these on every train/aggregate step so a run
    // allocates scratch once, not per event. The defaults delegate to
    // the allocating methods (the pre-fast-path behaviour, kept as the
    // executable reference — `testkit::ReferenceSurrogate` relies on
    // it); hot backends override them allocation-free. Contract: same
    // floats, same order of operations as the allocating calls.

    /// In-place [`Self::train_local`]: writes the updated params into
    /// `out` (reusing its allocation) and returns the mean loss.
    fn train_local_into(
        &mut self,
        sat: usize,
        params: &ModelParams,
        dispatches: usize,
        out: &mut ModelParams,
    ) -> f64 {
        let (m, loss) = self.train_local(sat, params, dispatches);
        *out = m;
        loss
    }

    /// In-place [`Self::aggregate`]: writes the aggregate into `out`,
    /// which must not alias `prev` or any of `models`.
    fn aggregate_into(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
        out: &mut ModelParams,
    ) {
        *out = self.aggregate(prev, models, coeffs, coeff_prev);
    }

    /// In-place [`Self::distances`]: clears and fills `out`.
    fn distances_into(
        &mut self,
        models: &[&ModelParams],
        reference: &ModelParams,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.extend(self.distances(models, reference));
    }
}

/// FedAvg data-size weights m_n/m over a set of shard sizes.
pub fn fedavg_weights(sizes: &[usize]) -> Vec<f32> {
    let mut out = Vec::new();
    fedavg_weights_into(sizes, &mut out);
    out
}

/// In-place [`fedavg_weights`]: clears and fills `out` (identical
/// values, reused allocation — per-tick callers like FedSpace use it).
pub fn fedavg_weights_into(sizes: &[usize], out: &mut Vec<f32>) {
    out.clear();
    let total: usize = sizes.iter().sum();
    if total == 0 {
        out.resize(sizes.len(), 0.0);
        return;
    }
    out.extend(sizes.iter().map(|&s| s as f32 / total as f32));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_weights_normalize() {
        let w = fedavg_weights(&[100, 300]);
        assert!((w[0] - 0.25).abs() < 1e-6);
        assert!((w[1] - 0.75).abs() < 1e-6);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn fedavg_weights_empty_total() {
        assert_eq!(fedavg_weights(&[0, 0]), vec![0.0, 0.0]);
    }
}
