//! Experiment drivers regenerating every paper table & figure
//! (DESIGN.md §4 maps each driver to its paper artifact), plus the
//! [`resilience`] sweep comparing graceful degradation across schemes
//! under the `crate::faults` scenarios.

pub mod drivers;
pub mod resilience;

pub use drivers::{run_experiment, ExpOptions, ALL_EXPERIMENTS, TABLE2_ROWS};
