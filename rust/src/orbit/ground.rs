//! Ground stations and high-altitude platforms anchored to the rotating
//! Earth (paper Sec. III / V-A).
//!
//! A HAP is modelled exactly as the paper describes: a semi-static
//! stratospheric platform hovering at a fixed geodetic location
//! (~20 km altitude), i.e. a ground site with extra altitude — which is
//! where its slightly better satellite visibility comes from.

use super::elements::{EARTH_RADIUS_KM, EARTH_ROTATION_RAD_S};
use crate::util::Vec3;

/// What kind of parameter-server site this is (affects nothing but
/// reporting; the geometry model is identical, per the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    GroundStation,
    Hap,
}

/// A fixed geodetic site: latitude/longitude in degrees, altitude km.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GeodeticSite {
    pub kind: SiteKind,
    pub lat_deg: f64,
    pub lon_deg: f64,
    pub alt_km: f64,
}

impl GeodeticSite {
    /// GS in Rolla, Missouri (paper Sec. V-A).
    pub fn rolla_gs() -> Self {
        GeodeticSite { kind: SiteKind::GroundStation, lat_deg: 37.95, lon_deg: -91.77, alt_km: 0.0 }
    }

    /// HAP above Rolla, Missouri at 20 km (paper Sec. V-A).
    pub fn rolla_hap() -> Self {
        GeodeticSite { kind: SiteKind::Hap, lat_deg: 37.95, lon_deg: -91.77, alt_km: 20.0 }
    }

    /// HAP above Portland, Oregon at 20 km (paper Sec. V-A).
    pub fn portland_hap() -> Self {
        GeodeticSite { kind: SiteKind::Hap, lat_deg: 45.52, lon_deg: -122.68, alt_km: 20.0 }
    }

    /// GS at the North Pole — the "ideal setup" of FedISL / FedSat.
    pub fn north_pole_gs() -> Self {
        GeodeticSite { kind: SiteKind::GroundStation, lat_deg: 90.0, lon_deg: 0.0, alt_km: 0.0 }
    }

    /// HAP above Quito, Ecuador at 20 km — an equatorial sink for the
    /// low-inclination scenario presets (an equatorial shell never
    /// rises over mid-latitude sites like Rolla).
    pub fn quito_hap() -> Self {
        GeodeticSite { kind: SiteKind::Hap, lat_deg: -0.19, lon_deg: -78.49, alt_km: 20.0 }
    }

    /// Horizon dip in degrees: an observer at altitude h sees the true
    /// horizon `acos(R_E/(R_E+h))` below the local horizontal. This is
    /// precisely the HAP's visibility advantage over a GS the paper
    /// leans on (a 20 km HAP gains ~4.5°).
    pub fn horizon_dip_deg(&self) -> f64 {
        let r = EARTH_RADIUS_KM;
        (r / (r + self.alt_km.max(0.0))).acos().to_degrees()
    }

    /// Effective minimum elevation for satellite visibility: the device
    /// constraint `theta_min` measured from the *apparent* horizon.
    pub fn effective_min_elevation_deg(&self, theta_min_deg: f64) -> f64 {
        theta_min_deg - self.horizon_dip_deg()
    }

    /// Position in ECI at simulated time `t` (spherical Earth + spin).
    ///
    /// The Earth rotation angle is `theta = omega * t` (we set GMST(0)=0;
    /// an arbitrary offset only shifts the whole contact pattern, which
    /// the paper's 3-day horizon averages out).
    ///
    /// One-shot convenience over [`SitePropagator`], the canonical
    /// formula; hot loops (the contact scanner) hoist one propagator
    /// per site instead of re-deriving the latitude trigonometry every
    /// call.
    pub fn position_eci(&self, t: f64) -> Vec3 {
        SitePropagator::new(self).position_at(t)
    }
}

/// A [`GeodeticSite`]'s position formula with the time-independent
/// parts hoisted: latitude trigonometry and the t = 0 longitude are
/// computed once, so [`Self::position_at`] is one `cos`/`sin` pair of
/// the rotated longitude plus two multiplies.
///
/// Bit-identity contract: the hoisted factors are exactly the
/// subexpressions of the original formula (`(r·cos lat)·cos lon` is how
/// `r * lat.cos() * lon.cos()` associates), so positions are
/// bit-for-bit unchanged — pinned by the `matches_direct_formula_bitwise`
/// test below.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SitePropagator {
    /// r · cos(lat): radius of the site's latitude circle, km.
    r_cos_lat: f64,
    /// r · sin(lat): the z coordinate, constant under Earth spin.
    z_km: f64,
    /// Longitude at t = 0, radians.
    lon0_rad: f64,
}

impl SitePropagator {
    pub fn new(site: &GeodeticSite) -> Self {
        let lat = site.lat_deg.to_radians();
        let r = EARTH_RADIUS_KM + site.alt_km;
        SitePropagator {
            r_cos_lat: r * lat.cos(),
            z_km: r * lat.sin(),
            lon0_rad: site.lon_deg.to_radians(),
        }
    }

    /// Site position in ECI at simulated time `t`, km.
    #[inline]
    pub fn position_at(&self, t: f64) -> Vec3 {
        let lon = self.lon0_rad + EARTH_ROTATION_RAD_S * t;
        Vec3::new(self.r_cos_lat * lon.cos(), self.r_cos_lat * lon.sin(), self.z_km)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn radius_includes_altitude() {
        let hap = GeodeticSite::rolla_hap();
        let r = hap.position_eci(0.0).norm();
        assert!((r - (EARTH_RADIUS_KM + 20.0)).abs() < 1e-9);
    }

    #[test]
    fn north_pole_is_on_axis_and_static() {
        let np = GeodeticSite::north_pole_gs();
        let p0 = np.position_eci(0.0);
        let p1 = np.position_eci(86_400.0);
        assert!(p0.x.abs() < 1e-6 && p0.y.abs() < 1e-6);
        assert!(p0.distance(p1) < 1e-6, "pole does not move with spin");
    }

    #[test]
    fn equatorial_site_rotates() {
        let eq = GeodeticSite { kind: SiteKind::GroundStation, lat_deg: 0.0, lon_deg: 0.0, alt_km: 0.0 };
        let p0 = eq.position_eci(0.0);
        // Quarter sidereal day ~ 21541 s -> ~90 degrees of rotation.
        let quarter = std::f64::consts::FRAC_PI_2 / EARTH_ROTATION_RAD_S;
        let p1 = eq.position_eci(quarter);
        assert!((p0.angle_to(p1) - std::f64::consts::FRAC_PI_2).abs() < 1e-9);
    }

    #[test]
    fn rotation_preserves_latitude() {
        let s = GeodeticSite::rolla_gs();
        for i in 0..10 {
            let p = s.position_eci(i as f64 * 10_000.0);
            let lat = (p.z / p.norm()).asin().to_degrees();
            assert!((lat - 37.95).abs() < 1e-9);
        }
    }

    #[test]
    fn horizon_dip_grows_with_altitude() {
        assert_eq!(GeodeticSite::rolla_gs().horizon_dip_deg(), 0.0);
        let dip = GeodeticSite::rolla_hap().horizon_dip_deg();
        assert!((4.0..5.2).contains(&dip), "20 km dip = {dip}");
        assert!(
            GeodeticSite::rolla_hap().effective_min_elevation_deg(10.0) < 10.0
        );
    }

    #[test]
    fn matches_direct_formula_bitwise() {
        // the hoisted propagator is the canonical formula; pin it
        // against the direct expression, bit for bit
        for site in [
            GeodeticSite::rolla_gs(),
            GeodeticSite::rolla_hap(),
            GeodeticSite::portland_hap(),
            GeodeticSite::north_pole_gs(),
            GeodeticSite::quito_hap(),
        ] {
            let prop = SitePropagator::new(&site);
            for i in 0..200 {
                let t = i as f64 * 431.6875 + 0.125;
                let lat = site.lat_deg.to_radians();
                let lon = site.lon_deg.to_radians() + EARTH_ROTATION_RAD_S * t;
                let r = EARTH_RADIUS_KM + site.alt_km;
                let direct =
                    Vec3::new(r * lat.cos() * lon.cos(), r * lat.cos() * lon.sin(), r * lat.sin());
                let fast = prop.position_at(t);
                assert_eq!(direct.x.to_bits(), fast.x.to_bits());
                assert_eq!(direct.y.to_bits(), fast.y.to_bits());
                assert_eq!(direct.z.to_bits(), fast.z.to_bits());
            }
        }
    }

    #[test]
    fn hap_sits_above_its_gs() {
        let gs = GeodeticSite::rolla_gs().position_eci(1234.0);
        let hap = GeodeticSite::rolla_hap().position_eci(1234.0);
        // Same direction from Earth center, larger radius.
        assert!(gs.angle_to(hap) < 1e-9);
        assert!(hap.norm() > gs.norm());
    }
}
