//! Network impairment engine bit-identity suite (the PR-10 contract,
//! `src/faults/network` + the `NetworkConfig` axes):
//!
//! * a **zero-intensity** network config — even one with non-default
//!   but disabled knobs (a wait cap with no queueing, a partition
//!   period with zero duration) — is provably invisible: every preset
//!   × scheme run is bit-identical to the default config, at lanes 1
//!   and 4, including the fault accounting and the JSONL trace;
//! * every **active** axis (jitter, congestion, partition,
//!   sun-eclipse) is deterministic — same seed, same run — and
//!   lane-count independent (queueing forces single-lane internally,
//!   the pure axes honor the lane-merge contract);
//! * active impairments actually *do* something: the swept counters
//!   (reorders, queueing delay, partition hits, eclipse blocks) are
//!   nonzero where the scenario promises them.

use asyncfleo::config::{ExperimentConfig, SchemeKind};
use asyncfleo::coordinator::{RunResult, SimEnv};
use asyncfleo::faults::{FaultScenario, NetworkConfig};
use asyncfleo::fl::{make_strategy, Strategy};
use asyncfleo::obs::RunObs;
use asyncfleo::scenario::ScenarioRegistry;
use asyncfleo::testkit::assert_runs_identical;
use asyncfleo::train::SurrogateBackend;

/// The schemes the contract covers (the scenario-sweep trio).
const SCHEMES: &[SchemeKind] = &[SchemeKind::AsyncFleo, SchemeKind::FedHap, SchemeKind::SinkSat];

/// Every built-in preset the suite sweeps.
const PRESETS: &[&str] = &[
    "paper-40",
    "starlink-lite",
    "polar-star",
    "sparse-iot",
    "equatorial-dense",
    "haps-degraded",
];

/// Trim a preset for the suite (same clamps as the run-loop and obs
/// equivalence suites): identity needs events, not convergence.
fn trimmed(cfg: &ExperimentConfig) -> ExperimentConfig {
    let mut c = cfg.clone();
    if c.n_sats() >= 1000 {
        c.fl.horizon_s = 2.0 * 3600.0;
        c.fl.max_epochs = 2;
    } else if c.n_sats() >= 100 {
        c.fl.horizon_s = 6.0 * 3600.0;
        c.fl.max_epochs = 3;
    } else {
        c.fl.horizon_s = 12.0 * 3600.0;
        c.fl.max_epochs = 4;
    }
    c
}

/// A network config whose every axis is *disabled* but whose bits are
/// not the default: the hardest zero-intensity case, because it only
/// stays invisible if `is_nop` gates the engine and the schedule cache
/// key normalizes to the pre-engine key.
fn disabled_but_nondefault() -> NetworkConfig {
    let mut net = NetworkConfig::nominal();
    net.queue_max_wait_s = 900.0; // a cap with no queueing
    net.partition_period_s = 14_400.0; // a period with zero duration
    net.partition_shell = 3;
    assert!(net.is_nop());
    net
}

fn run_lanes(cfg: &ExperimentConfig, lanes: usize) -> RunResult {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    env.set_lanes(lanes);
    make_strategy(cfg.fl.scheme).run(&mut env)
}

/// One traced run (memory sink) at the given lane count.
fn run_traced(cfg: &ExperimentConfig, lanes: usize) -> (RunResult, Box<RunObs>) {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    env.set_lanes(lanes);
    let mut obs = RunObs::to_memory();
    obs.meta(
        "test",
        cfg.fl.scheme.name(),
        cfg.seed,
        cfg.fl.horizon_s,
        cfg.n_sats(),
        cfg.placement.sites().len(),
    );
    env.enable_obs(obs);
    let r = make_strategy(cfg.fl.scheme).run(&mut env);
    let obs = env.take_obs().expect("run was observed");
    (r, obs)
}

#[test]
fn zero_intensity_network_is_bitwise_invisible_on_every_preset() {
    let reg = ScenarioRegistry::builtin();
    for name in PRESETS {
        let sc = reg.get(name).unwrap_or_else(|| panic!("missing preset {name}"));
        for &scheme in SCHEMES {
            let mut cfg = trimmed(&sc.cfg);
            cfg.fl.scheme = scheme;
            let baseline = run_lanes(&cfg, 1);
            let mut nop = cfg.clone();
            nop.network = disabled_but_nondefault();
            for lanes in [1, 4] {
                let r = run_lanes(&nop, lanes);
                assert_runs_identical(
                    &r,
                    &baseline,
                    &format!("{name}/{}/nop-net/lanes{lanes}", scheme.name()),
                );
            }
        }
    }
}

#[test]
fn zero_intensity_presets_are_exactly_nominal() {
    // `preset(_, 0.0)` is structurally the nominal config, so the
    // runtime invisibility above covers every zero-intensity preset.
    for &sc in FaultScenario::ALL {
        assert_eq!(NetworkConfig::preset(sc, 0.0), NetworkConfig::nominal(), "{sc:?}");
    }
}

#[test]
fn zero_intensity_network_leaves_the_trace_byte_identical() {
    let reg = ScenarioRegistry::builtin();
    let sc = reg.get("paper-40").expect("paper preset");
    let mut cfg = trimmed(&sc.cfg);
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    let (base_r, base_obs) = run_traced(&cfg, 1);
    let mut nop = cfg.clone();
    nop.network = disabled_but_nondefault();
    for lanes in [1, 4] {
        let (r, obs) = run_traced(&nop, lanes);
        assert_runs_identical(&r, &base_r, &format!("paper-40/trace/nop-net/lanes{lanes}"));
        assert_eq!(
            obs.sink.lines(),
            base_obs.sink.lines(),
            "nop-net JSONL trace must be byte-identical (lanes {lanes})"
        );
    }
}

/// The active network scenarios and the counter each must move.
const ACTIVE: &[FaultScenario] = &[
    FaultScenario::Jitter,
    FaultScenario::Congestion,
    FaultScenario::Partition,
    FaultScenario::SunEclipse,
];

#[test]
fn active_axes_are_deterministic_and_lane_count_independent() {
    let reg = ScenarioRegistry::builtin();
    let sc = reg.get("paper-40").expect("paper preset");
    for &scenario in ACTIVE {
        for &scheme in SCHEMES {
            let mut cfg = trimmed(&sc.cfg);
            cfg.fl.scheme = scheme;
            cfg.network = NetworkConfig::preset(scenario, 1.0);
            let what = format!("paper-40/{}/{}", scenario.name(), scheme.name());
            let one = run_lanes(&cfg, 1);
            let twin = run_lanes(&cfg, 1);
            assert_runs_identical(&twin, &one, &format!("{what}/twin"));
            // congestion forces lanes = 1 internally; the pure axes
            // satisfy the merge contract — either way, bit-identical
            let four = run_lanes(&cfg, 4);
            assert_runs_identical(&four, &one, &format!("{what}/lanes4"));
        }
    }
}

/// True when any result bit differs — the complement of
/// [`assert_runs_identical`], for asserting an impairment *did*
/// something.
fn runs_differ(a: &RunResult, b: &RunResult) -> bool {
    if a.epochs != b.epochs
        || a.transfers != b.transfers
        || a.fault_stats != b.fault_stats
        || a.curve.points.len() != b.curve.points.len()
    {
        return true;
    }
    for (x, y) in a.curve.points.iter().zip(&b.curve.points) {
        if x.time_s.to_bits() != y.time_s.to_bits()
            || x.accuracy.to_bits() != y.accuracy.to_bits()
        {
            return true;
        }
    }
    false
}

#[test]
fn active_axes_move_their_counters() {
    let reg = ScenarioRegistry::builtin();
    let sc = reg.get("paper-40").expect("paper preset");
    let mut cfg = trimmed(&sc.cfg);
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    let baseline = run_lanes(&cfg, 1);

    // jitter perturbs every channel delay multiplicatively, so the run
    // must leave the nominal trajectory (reorders need bursts on one
    // link, so they are pinned by the unit suite, not here)
    let mut jitter = cfg.clone();
    jitter.network = NetworkConfig::preset(FaultScenario::Jitter, 1.0);
    let r = run_lanes(&jitter, 1);
    assert!(runs_differ(&r, &baseline), "jitter left the run bit-identical");

    // an exaggerated service factor makes IHL/uplink contention
    // certain over a 12 h horizon; unbounded wait → no typed drops
    let mut congested = cfg.clone();
    congested.network.queue_service_factor = 600.0;
    congested.network.queue_max_wait_s = 0.0;
    let r = run_lanes(&congested, 1);
    assert!(r.fault_stats.queued_s > 0.0, "no queueing delay: {:?}", r.fault_stats);

    // a half-duty HAP-scope partition blocks every SAT<->HAP contact
    // half the time (paper-40 places the PS on HAPs, so `Hap` scope is
    // the one guaranteed to intersect traffic)
    let mut parted = cfg.clone();
    parted.network.partition_period_s = 7200.0;
    parted.network.partition_duration_s = 3600.0;
    parted.network.partition_scope = asyncfleo::faults::PartitionScope::Hap;
    let r = run_lanes(&parted, 1);
    assert!(r.fault_stats.partition_hits > 0, "no partition hits: {:?}", r.fault_stats);

    // LEO satellites spend ~1/3 of each orbit in umbra, so some
    // transfer must hit a shadow window
    let mut eclipsed = cfg.clone();
    eclipsed.network = NetworkConfig::preset(FaultScenario::SunEclipse, 1.0);
    let r = run_lanes(&eclipsed, 1);
    assert!(r.fault_stats.eclipse_blocked > 0, "no eclipse blocks: {:?}", r.fault_stats);
}
