//! Min-heap event queue with deterministic FIFO tie-breaking.

use super::event::Event;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: ordered by time, then insertion sequence (so two events
/// at the same instant pop in scheduling order — determinism matters
/// because experiment tables must regenerate bit-identically).
///
/// Shared with the multi-lane queue (`sim::lanes`), whose per-lane
/// heaps must order entries exactly like the single queue does.
pub(crate) struct Entry {
    pub(crate) time_s: f64,
    pub(crate) seq: u64,
    pub(crate) event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, o: &Self) -> bool {
        self.time_s == o.time_s && self.seq == o.seq
    }
}
impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Entry {
    fn cmp(&self, o: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for min-heap behaviour.
        o.time_s
            .partial_cmp(&self.time_s)
            .expect("event times are finite")
            .then_with(|| o.seq.cmp(&self.seq))
    }
}

/// The simulation event queue.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    now_s: f64,
    /// Deepest the queue has ever been (backlog accounting for the
    /// observability report).
    high_water: usize,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now_s: 0.0, high_water: 0 }
    }

    /// A queue whose heap starts out sized for `cap` events, so a run
    /// that knows its backlog shape skips the doubling re-allocations.
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), seq: 0, now_s: 0.0, high_water: 0 }
    }

    /// Reset the queue to its pristine state — clock at zero, sequence
    /// counter at zero, high-water mark at zero — while **retaining**
    /// the heap's allocation, so repeated runs in a sweep cell reuse
    /// one buffer instead of growing a fresh heap each time.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now_s = 0.0;
        self.high_water = 0;
    }

    /// Events the heap can hold without reallocating (capacity survives
    /// [`EventQueue::clear`]).
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now_s
    }

    /// Schedule an event. Panics if its time is non-finite or in the
    /// simulated past. Rejecting NaN/∞ up front matters: a NaN would
    /// otherwise only explode later inside the heap's `Ord` (the
    /// `expect("event times are finite")`), far from the buggy caller.
    pub fn push(&mut self, e: Event) {
        assert!(
            e.time_s.is_finite(),
            "event time must be finite, got {} ({:?})",
            e.time_s,
            e.kind
        );
        assert!(
            e.time_s >= self.now_s,
            "cannot schedule into the past: {} < {} ({:?})",
            e.time_s,
            self.now_s,
            e.kind
        );
        self.heap.push(Entry { time_s: e.time_s, seq: self.seq, event: e });
        self.seq += 1;
        if self.heap.len() > self.high_water {
            self.high_water = self.heap.len();
        }
    }

    /// Schedule `kind` at `now + delay`.
    pub fn push_in(&mut self, delay_s: f64, kind: super::event::EventKind) {
        let t = self.now_s + delay_s.max(0.0);
        self.push(Event::new(t, kind));
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|entry| {
            debug_assert!(entry.time_s >= self.now_s);
            self.now_s = entry.time_s;
            entry.event
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Deepest the queue has ever been over its lifetime.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::EventKind;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Event::new(3.0, EventKind::Sweep));
        q.push(Event::new(1.0, EventKind::AggregationTick));
        q.push(Event::new(2.0, EventKind::TrainingDone { sat: 1 }));
        assert_eq!(q.pop().unwrap().time_s, 1.0);
        assert_eq!(q.pop().unwrap().time_s, 2.0);
        assert_eq!(q.pop().unwrap().time_s, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for sat in 0..10 {
            q.push(Event::new(5.0, EventKind::TrainingDone { sat }));
        }
        for sat in 0..10 {
            match q.pop().unwrap().kind {
                EventKind::TrainingDone { sat: s } => assert_eq!(s, sat),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(Event::new(2.0, EventKind::Sweep));
        q.push(Event::new(7.0, EventKind::Sweep));
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 2.0);
        q.pop();
        assert_eq!(q.now(), 7.0);
    }

    #[test]
    #[should_panic]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push(Event::new(5.0, EventKind::Sweep));
        q.pop();
        q.push(Event::new(1.0, EventKind::Sweep));
    }

    #[test]
    fn push_in_is_relative_and_clamped() {
        let mut q = EventQueue::new();
        q.push(Event::new(10.0, EventKind::Sweep));
        q.pop();
        q.push_in(-3.0, EventKind::Sweep); // clamped to now
        assert_eq!(q.peek_time(), Some(10.0));
        q.push_in(5.0, EventKind::AggregationTick);
        q.pop();
        assert_eq!(q.peek_time(), Some(15.0));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nonfinite_time() {
        // Event::new rejects NaN, so smuggle an infinity through a
        // struct literal — push must still catch it up front.
        let mut q = EventQueue::new();
        q.push(Event { time_s: f64::INFINITY, kind: EventKind::Sweep });
    }

    #[test]
    fn len_tracks() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(Event::new(1.0, EventKind::Sweep));
        q.push(Event::new(2.0, EventKind::Sweep));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn high_water_is_monotone_max_of_len() {
        let mut q = EventQueue::new();
        assert_eq!(q.high_water(), 0);
        q.push(Event::new(1.0, EventKind::Sweep));
        q.push(Event::new(2.0, EventKind::Sweep));
        q.push(Event::new(3.0, EventKind::Sweep));
        assert_eq!(q.high_water(), 3);
        q.pop();
        q.pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.high_water(), 3, "draining must not lower the mark");
        q.push(Event::new(4.0, EventKind::Sweep));
        assert_eq!(q.high_water(), 3, "refilling below the mark keeps it");
        q.push(Event::new(5.0, EventKind::Sweep));
        q.push(Event::new(6.0, EventKind::Sweep));
        assert_eq!(q.high_water(), 4);
    }

    #[test]
    #[should_panic(expected = "Sweep")]
    fn past_event_panic_names_the_event_kind() {
        let mut q = EventQueue::new();
        q.push(Event::new(5.0, EventKind::Sweep));
        q.pop();
        q.push(Event::new(1.0, EventKind::Sweep));
    }

    #[test]
    fn with_capacity_preallocates() {
        let q = EventQueue::with_capacity(64);
        assert!(q.capacity() >= 64);
        assert!(q.is_empty());
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.high_water(), 0);
    }

    #[test]
    fn clear_resets_state_but_keeps_capacity() {
        let mut q = EventQueue::with_capacity(32);
        for i in 0..20 {
            q.push(Event::new(100.0 + i as f64, EventKind::Sweep));
        }
        q.pop();
        assert!(q.now() > 0.0);
        assert_eq!(q.high_water(), 20);
        let cap_before = q.capacity();
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.high_water(), 0);
        assert!(q.capacity() >= cap_before, "clear must retain the heap allocation");
        // the clock reset means early times are schedulable again …
        q.push(Event::new(1.0, EventKind::Sweep));
        assert_eq!(q.pop().unwrap().time_s, 1.0);
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn cleared_queue_still_rejects_past_events() {
        // … and the push asserts stay armed after a clear.
        let mut q = EventQueue::new();
        q.push(Event::new(5.0, EventKind::Sweep));
        q.pop();
        q.clear();
        q.push(Event::new(2.0, EventKind::Sweep));
        q.pop();
        q.push(Event::new(1.0, EventKind::Sweep));
    }
}
