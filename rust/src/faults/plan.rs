//! The fault plan: the seeded impairment timeline every strategy runs
//! against, plus the per-transfer injection oracle.
//!
//! Split along the sweep axis (PR 2): [`FaultSchedule`] is the
//! immutable, `Send + Sync` timeline — outage windows, churn intervals,
//! partition windows, Sun-vector umbra windows and the channel-state
//! seed, all precomputed from `(config, seed)` at build time — while
//! [`FaultPlan`] wraps it in an `Arc` and adds the per-run mutable
//! state (`seen` channel events, the FIFO [`LinkQueue`]s, reorder
//! tracking, [`FaultStats`]). Runs that share a `(config, seed)` pair
//! can therefore share one schedule without sharing accounting.
//!
//! The network axes ([`NetworkConfig`], PR 10) keep the PR-9 replay
//! split: jitter, partition deferral and umbra deferral are pure terms
//! of [`FaultSchedule::channel_outcome`]; queue waits, reorder counts
//! and every counter fold in [`FaultPlan::commit`]. Queueing is the one
//! order-sensitive axis, so an active queue forces single-lane runs
//! ([`FaultPlan::queueing_active`]).
//!
//! [`FaultPlan`] is carried by `coordinator::RunState`; the env's
//! `site_link_delay` / `isl_hop_delay` / `ihl_hop_delay` route every
//! transfer through [`FaultPlan::transfer`], so AsyncFLEO and all five
//! baselines transparently experience the same impairments. When the
//! config is a no-op the plan never draws from the RNG and returns the
//! base delay unchanged — the disabled subsystem is provably invisible.

use super::config::{FaultConfig, NetworkConfig, PartitionScope};
use super::network::{partition_blocks, LinkQueue, NetWorld};
use super::schedule::{exp_draw, ChurnSchedule, OutageWindows};
use crate::orbit::WalkerConstellation;
use crate::sim::{Event, EventKind, EventSink};
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Which physical link a transfer crosses (endpoints by dense id).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// SAT↔site (HAP or GS) star link.
    SatSite { sat: usize, site: usize },
    /// Intra-orbit inter-satellite link.
    Isl { sat_a: usize, sat_b: usize },
    /// HAP↔HAP (IHL) backbone link.
    Ihl { site_a: usize, site_b: usize },
}

/// What the oracle did to one transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkOutcome {
    /// Effective delay replacing the clean link delay (includes any
    /// deferral past outages/downtime and retransmission time).
    pub delay_s: f64,
    /// Retransmission attempts this transfer suffered.
    pub retransmits: u32,
    /// First observation of this (link, coherence-window) channel
    /// event. Path oracles probe the same hop many times (ring
    /// relaxation, route selection); only the first observation counts
    /// toward [`FaultStats`] and the transfer accounting.
    pub newly_observed: bool,
}

/// The **pure** half of one channel query: everything the oracle
/// decides about a transfer over `(class, t, base)` *before* any per-run
/// accounting — a function of the immutable [`FaultSchedule`] alone, so
/// probe lanes can evaluate it concurrently and replay it later through
/// [`FaultPlan::commit`] with bit-identical results.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelOutcome {
    /// Effective delay replacing the clean link delay (before any
    /// per-run queue wait, which [`FaultPlan::commit`] folds in).
    pub delay_s: f64,
    /// Retransmission attempts this transfer suffered.
    pub retransmits: u32,
    /// Channel-state key (identifies the (link, coherence-window)
    /// event for the per-run `seen` set).
    pub key: u64,
    /// How far the send instant was deferred (`start - t`; 0 when the
    /// link was immediately available).
    pub deferred_s: f64,
    /// Whether an outage window (not just endpoint churn) contributed
    /// to the deferral.
    pub outage_hit: bool,
    /// The deferred send instant (`t + deferred_s`) — the time the
    /// commit side offers this transfer to its link queue.
    pub send_t: f64,
    /// Link occupancy under bandwidth queueing
    /// (`queue_service_factor * clean_delay`; 0 when queueing is off).
    pub service_s: f64,
    /// Window-independent identity of the (endpoint-pair, link-class) —
    /// the key of the FIFO [`LinkQueue`] and of reorder tracking.
    pub queue_key: u64,
    /// Log-normal latency jitter already folded into `delay_s`
    /// (0 when `jitter_sigma` is 0; may be negative).
    pub jitter_s: f64,
    /// Whether a scheduled network partition contributed to the
    /// deferral.
    pub partition_hit: bool,
    /// Whether a Sun-vector umbra window contributed to the deferral.
    pub eclipse_hit: bool,
    /// The retry budget was exhausted: a typed drop — `delay_s` lands
    /// the arrival past every horizon so the strategies' past-horizon
    /// discard applies (never an infinite retry loop).
    pub dropped: bool,
}

/// Cumulative injection accounting for one run (reported in
/// `RunResult` and the resilience CSV).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultStats {
    /// Total retransmission attempts across all transfers.
    pub retransmits: u64,
    /// Transfers deferred by an outage window or a dead endpoint.
    pub deferrals: u64,
    /// Total deferral time across those transfers, seconds.
    pub deferred_s: f64,
    /// Training results that never reached a PS (dead satellite or
    /// past-horizon delivery).
    pub dropped_results: u64,
    /// Channel events that suffered at least one packet loss (each may
    /// contribute several `retransmits`).
    pub losses: u64,
    /// Deferred channel events whose deferral was (at least partly)
    /// caused by an outage window, as opposed to endpoint churn alone.
    pub outages_hit: u64,
    /// Churn down-transitions on the schedule within the horizon
    /// (satellite deaths + HAP failures) — a schedule property, set at
    /// plan construction rather than accumulated per transfer.
    pub churn_deaths: u64,
    /// Total FIFO queueing delay under bandwidth contention, seconds.
    pub queued_s: f64,
    /// Transfers dropped because their queue wait exceeded the cap.
    pub queue_drops: u64,
    /// Channel events deferred by a scheduled network partition.
    pub partition_hits: u64,
    /// Arrivals that landed before an earlier-committed arrival on the
    /// same link (message reordering under latency jitter).
    pub reorders: u64,
    /// Channel events deferred by a Sun-vector umbra window.
    pub eclipse_blocked: u64,
    /// Transfers dropped after exhausting their retransmission budget.
    pub retry_drops: u64,
}

/// Never defer a transfer more than this far past the horizon (keeps
/// every scheduled time finite; strategies drop past-horizon arrivals).
const DEFER_CAP_SLACK_S: f64 = 7200.0;

/// Loss channel coherence: within one window the channel state of a
/// link is fixed, so the delay oracles (which probe the same hop
/// repeatedly while routing) observe a consistent answer instead of
/// re-rolling the dice per query.
const LOSS_COHERENCE_S: f64 = 1.0;

/// Salt separating the latency-jitter stream from the loss stream of
/// the same channel event (both are pure functions of the channel key).
const JITTER_SALT: u64 = 0x4A17_7E2D;

/// The immutable half of the fault engine: everything precomputed from
/// `(config, seed)` — pure data, shareable across runs and threads.
pub struct FaultSchedule {
    cfg: FaultConfig,
    net: NetworkConfig,
    enabled: bool,
    horizon_s: f64,
    /// Seed for the per-(link, window) channel-state hash — loss draws
    /// are a pure function of it, never of call order.
    channel_seed: u64,
    /// Eclipse windows per PS site (SAT↔site links).
    site_outages: Vec<OutageWindows>,
    /// Conjunction windows per orbit (ISL hops), when `isl_outage`.
    orbit_outages: Vec<OutageWindows>,
    sat_churn: Vec<ChurnSchedule>,
    hap_churn: Vec<ChurnSchedule>,
    /// Global orbital-plane index per satellite id (multi-shell
    /// constellations have non-uniform plane sizes, so the mapping is
    /// explicit rather than a division by `sats_per_orbit`).
    plane_of: Vec<usize>,
    /// Scheduled partition windows (`OutageWindows::none()` when off);
    /// which links they cut is decided by `net.partition_scope` over
    /// `shell_of` / `hap_site`.
    partition: OutageWindows,
    /// Orbital shell per satellite id (partition scope `Shell`).
    shell_of: Vec<usize>,
    /// Which sites are HAPs (partition scopes `Ground` / `Hap`).
    hap_site: Vec<bool>,
    /// Per-satellite umbra windows from the actual Sun vector
    /// (`orbit::sun`), precomputed at build when `eclipse_from_sun`.
    sun_umbra: Vec<Vec<(f64, f64)>>,
}

/// Identity of a shareable [`FaultSchedule`]: every input of
/// [`FaultSchedule::build_with_network`], with `f64`s keyed by bit
/// pattern (configs are copied or parsed from the same text; NaN is
/// rejected by `validate`). Network inputs are normalized: a nominal
/// `NetworkConfig` contributes all-zero fields, the layout vectors are
/// kept only for the axes that read them (partitions) and the geometry
/// signature only when Sun eclipses are on — so a nominal-network key
/// is exactly the pre-engine key and old cache entries keep hitting.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ScheduleKey {
    cfg_bits: [u64; 10],
    max_retransmits: u32,
    isl_outage: bool,
    net_bits: [u64; 5],
    partition_scope: u8,
    partition_shell: usize,
    eclipse_from_sun: bool,
    seed: u64,
    plane_of: Vec<usize>,
    shell_of: Vec<usize>,
    hap_site: Vec<bool>,
    geom_sig: u64,
    n_sites: usize,
    horizon_bits: u64,
}

impl ScheduleKey {
    fn of(
        cfg: &FaultConfig,
        net: &NetworkConfig,
        seed: u64,
        plane_of: &[usize],
        world: &NetWorld,
        n_sites: usize,
        horizon_s: f64,
    ) -> Self {
        let net_on = !net.is_nop();
        let partition_on =
            net_on && net.partition_period_s > 0.0 && net.partition_duration_s > 0.0;
        let eclipse_on = net_on && net.eclipse_from_sun;
        ScheduleKey {
            cfg_bits: [
                cfg.loss_prob.to_bits(),
                cfg.retransmit_backoff_s.to_bits(),
                cfg.outage_period_s.to_bits(),
                cfg.outage_duration_s.to_bits(),
                cfg.sat_mtbf_s.to_bits(),
                cfg.sat_mttr_s.to_bits(),
                cfg.hap_mtbf_s.to_bits(),
                cfg.hap_mttr_s.to_bits(),
                cfg.isl_edge_outage_period_s.to_bits(),
                cfg.isl_edge_outage_duration_s.to_bits(),
            ],
            max_retransmits: cfg.max_retransmits,
            isl_outage: cfg.isl_outage,
            net_bits: if net_on {
                [
                    net.jitter_sigma.to_bits(),
                    net.queue_service_factor.to_bits(),
                    net.queue_max_wait_s.to_bits(),
                    net.partition_period_s.to_bits(),
                    net.partition_duration_s.to_bits(),
                ]
            } else {
                [0; 5]
            },
            partition_scope: if partition_on {
                match net.partition_scope {
                    PartitionScope::Ground => 0,
                    PartitionScope::Hap => 1,
                    PartitionScope::Shell => 2,
                }
            } else {
                0
            },
            partition_shell: if partition_on { net.partition_shell } else { 0 },
            eclipse_from_sun: eclipse_on,
            seed,
            plane_of: plane_of.to_vec(),
            shell_of: if partition_on { world.shell_of.to_vec() } else { Vec::new() },
            hap_site: if partition_on { world.hap_site.to_vec() } else { Vec::new() },
            geom_sig: if eclipse_on {
                geom_signature(world.constellation, plane_of.len())
            } else {
                0
            },
            n_sites,
            horizon_bits: horizon_s.to_bits(),
        }
    }
}

/// Positional fingerprint of the constellation geometry — part of the
/// schedule key when Sun-vector eclipse windows are baked in, so two
/// scenarios sharing fault knobs but flying different orbits never
/// share umbra timelines.
fn geom_signature(c: Option<&WalkerConstellation>, n_sats: usize) -> u64 {
    let Some(c) = c else { return 0 };
    let mut h = 0xEC11_u64;
    for sat in 0..n_sats.min(c.len()) {
        for t in [0.0, 1000.0] {
            let p = c.position(sat, t);
            for v in [p.x.to_bits(), p.y.to_bits(), p.z.to_bits()] {
                h = mix64(h ^ v.wrapping_mul(0x9E3779B97F4A7C15));
            }
        }
    }
    h
}

/// Cache of per-key build cells (the `coordinator::Geometry` pattern):
/// the map lock is only held to fetch or insert a cell, the build runs
/// inside the cell's own `OnceLock`, so concurrent requests for
/// *different* keys never serialize while same-key requests still
/// build exactly once.
type ScheduleCell = Arc<OnceLock<Arc<FaultSchedule>>>;

fn schedule_cache() -> &'static Mutex<HashMap<ScheduleKey, ScheduleCell>> {
    static CACHE: OnceLock<Mutex<HashMap<ScheduleKey, ScheduleCell>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

fn schedule_build_counts() -> &'static Mutex<HashMap<ScheduleKey, u64>> {
    static COUNTS: OnceLock<Mutex<HashMap<ScheduleKey, u64>>> = OnceLock::new();
    COUNTS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// SplitMix64 finalizer — the hash behind the channel-state keys.
fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

impl FaultSchedule {
    /// The no-fault schedule (what every run before this subsystem used).
    pub fn disabled() -> Self {
        FaultSchedule {
            cfg: FaultConfig::nominal(),
            net: NetworkConfig::nominal(),
            enabled: false,
            horizon_s: 0.0,
            channel_seed: 0,
            site_outages: Vec::new(),
            orbit_outages: Vec::new(),
            sat_churn: Vec::new(),
            hap_churn: Vec::new(),
            plane_of: Vec::new(),
            partition: OutageWindows::none(),
            shell_of: Vec::new(),
            hap_site: Vec::new(),
            sun_umbra: Vec::new(),
        }
    }

    /// Build the impairment timeline with a nominal network config (the
    /// pre-engine entry point; see [`Self::build_with_network`]).
    /// `plane_of` maps each satellite id to its global orbital-plane
    /// index (one entry per satellite; see
    /// `WalkerConstellation::plane_of`). All randomness comes from
    /// `seed`: the same seed gives bit-identical schedules and
    /// per-transfer draws for any strategy with deterministic call
    /// order (which all of ours are).
    pub fn build(
        cfg: &FaultConfig,
        seed: u64,
        plane_of: &[usize],
        n_sites: usize,
        horizon_s: f64,
    ) -> Self {
        Self::build_with_network(
            cfg,
            &NetworkConfig::nominal(),
            seed,
            plane_of,
            &NetWorld::empty(),
            n_sites,
            horizon_s,
        )
    }

    /// Build the impairment timeline including the network axes. The
    /// RNG draw order is exactly [`Self::build`]'s — the network terms
    /// are hash-derived (partition phase) or pure geometry (umbra
    /// windows), so a nominal `net` yields a bit-identical schedule.
    pub fn build_with_network(
        cfg: &FaultConfig,
        net: &NetworkConfig,
        seed: u64,
        plane_of: &[usize],
        world: &NetWorld,
        n_sites: usize,
        horizon_s: f64,
    ) -> Self {
        if cfg.is_nop() && net.is_nop() {
            let mut sched = Self::disabled();
            sched.cfg = *cfg;
            sched.net = *net;
            return sched;
        }
        let n_sats = plane_of.len();
        let mut rng = Rng::new(seed ^ 0xFA_0175);
        let mut phase_rng = rng.fork(1);
        let mut churn_rng = rng.fork(2);
        let mut hap_rng = rng.fork(3);
        let channel_seed = rng.next_u64();

        let (site_outages, orbit_outages) =
            if cfg.outage_period_s > 0.0 && cfg.outage_duration_s > 0.0 {
                let phase = |r: &mut Rng| r.range_f64(0.0, cfg.outage_period_s);
                let sites = (0..n_sites)
                    .map(|_| OutageWindows {
                        period_s: cfg.outage_period_s,
                        duration_s: cfg.outage_duration_s,
                        phase_s: phase(&mut phase_rng),
                    })
                    .collect();
                let n_orbits = plane_of.iter().max().map_or(0, |m| m + 1);
                let orbits = if cfg.isl_outage {
                    (0..n_orbits)
                        .map(|_| OutageWindows {
                            period_s: cfg.outage_period_s,
                            duration_s: cfg.outage_duration_s,
                            phase_s: phase(&mut phase_rng),
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                (sites, orbits)
            } else {
                (Vec::new(), Vec::new())
            };

        let sat_churn = (0..n_sats)
            .map(|_| {
                ChurnSchedule::generate(&mut churn_rng, cfg.sat_mtbf_s, cfg.sat_mttr_s, horizon_s)
            })
            .collect();

        // scheduled partitions: one global window train whose phase is
        // hash-derived from the channel seed (never an RNG draw, so the
        // legacy draw order above is untouched)
        let partition = if net.partition_period_s > 0.0 && net.partition_duration_s > 0.0 {
            let frac =
                (mix64(channel_seed ^ 0x9A27_1710) >> 11) as f64 / (1u64 << 53) as f64;
            OutageWindows {
                period_s: net.partition_period_s,
                duration_s: net.partition_duration_s,
                phase_s: frac * net.partition_period_s,
            }
        } else {
            OutageWindows::none()
        };

        // ground-truth eclipses: per-satellite umbra windows from the
        // actual Sun vector, pure geometry precomputed once per key
        let sun_umbra = match (net.eclipse_from_sun, world.constellation) {
            (true, Some(c)) => (0..n_sats.min(c.len()))
                .map(|sat| crate::orbit::umbra_windows(c, sat, horizon_s))
                .collect(),
            _ => Vec::new(),
        };

        FaultSchedule {
            cfg: *cfg,
            net: *net,
            enabled: true,
            horizon_s,
            channel_seed,
            site_outages,
            orbit_outages,
            sat_churn,
            hap_churn: generate_hap_schedules(
                &mut hap_rng,
                n_sites,
                cfg.hap_mtbf_s,
                cfg.hap_mttr_s,
                horizon_s,
            ),
            plane_of: plane_of.to_vec(),
            partition,
            shell_of: world.shell_of.to_vec(),
            hap_site: world.hap_site.to_vec(),
            sun_umbra,
        }
    }

    /// The process-wide shared schedule for this exact impairment key
    /// (config bits, seed, node layout, horizon). A resilience cell
    /// group runs every scheme against the same `(scenario, intensity,
    /// seed)` timeline; the schedule is a pure function of the key, so
    /// the schemes share one `Arc` instead of rebuilding it per run —
    /// each run still gets its own [`FaultPlan`] counters. No-op
    /// configs skip the cache (they build a trivial disabled schedule).
    pub fn shared(
        cfg: &FaultConfig,
        seed: u64,
        plane_of: &[usize],
        n_sites: usize,
        horizon_s: f64,
    ) -> Arc<FaultSchedule> {
        Self::shared_with_network(
            cfg,
            &NetworkConfig::nominal(),
            seed,
            plane_of,
            &NetWorld::empty(),
            n_sites,
            horizon_s,
        )
    }

    /// [`Self::shared`] including the network axes. The cache key is
    /// normalized so a nominal `net` resolves to exactly the pre-engine
    /// key (see [`ScheduleKey`]).
    pub fn shared_with_network(
        cfg: &FaultConfig,
        net: &NetworkConfig,
        seed: u64,
        plane_of: &[usize],
        world: &NetWorld,
        n_sites: usize,
        horizon_s: f64,
    ) -> Arc<FaultSchedule> {
        if cfg.is_nop() && net.is_nop() {
            let mut sched = Self::disabled();
            sched.cfg = *cfg;
            sched.net = *net;
            return Arc::new(sched);
        }
        let key = ScheduleKey::of(cfg, net, seed, plane_of, world, n_sites, horizon_s);
        let cell: ScheduleCell = {
            let mut map = schedule_cache().lock().unwrap();
            map.entry(key.clone()).or_default().clone()
        };
        cell.get_or_init(|| {
            *schedule_build_counts().lock().unwrap().entry(key).or_insert(0) += 1;
            Arc::new(Self::build_with_network(
                cfg, net, seed, plane_of, world, n_sites, horizon_s,
            ))
        })
        .clone()
    }

    /// How many times the shared cache actually built this key's
    /// schedule (0 = never requested; 1 = the share contract held).
    /// Keys with a nominal network config, as built by [`Self::shared`].
    pub fn shared_build_count(
        cfg: &FaultConfig,
        seed: u64,
        plane_of: &[usize],
        n_sites: usize,
        horizon_s: f64,
    ) -> u64 {
        let key = ScheduleKey::of(
            cfg,
            &NetworkConfig::nominal(),
            seed,
            plane_of,
            &NetWorld::empty(),
            n_sites,
            horizon_s,
        );
        schedule_build_counts().lock().unwrap().get(&key).copied().unwrap_or(0)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn network(&self) -> &NetworkConfig {
        &self.net
    }

    /// The umbra windows baked in for one satellite (empty unless
    /// `eclipse_from_sun` was built with a constellation).
    pub fn sun_umbra_windows(&self, sat: usize) -> &[(f64, f64)] {
        match self.sun_umbra.get(sat) {
            Some(ws) => ws,
            None => &[],
        }
    }

    /// Is satellite `sat` alive at `t`? (Always true when disabled.)
    pub fn sat_alive(&self, sat: usize, t: f64) -> bool {
        self.sat_churn.get(sat).map_or(true, |s| !s.is_down(t))
    }

    /// Is PS site `hap` alive at `t`?
    pub fn hap_alive(&self, hap: usize, t: f64) -> bool {
        self.hap_churn.get(hap).map_or(true, |s| !s.is_down(t))
    }

    /// Downtime intervals of one satellite (for reporting/tests).
    pub fn sat_downtime(&self, sat: usize) -> &[(f64, f64)] {
        match self.sat_churn.get(sat) {
            Some(s) => &s.down,
            None => &[],
        }
    }

    /// Channel-state key of a link at a send instant. Bidirectional
    /// links (ISL, IHL) are normalized so both directions share state.
    fn channel_key(&self, class: &LinkClass, send_t: f64) -> u64 {
        let (tag, a, b) = match *class {
            LinkClass::SatSite { sat, site } => (1u64, sat as u64, site as u64),
            LinkClass::Isl { sat_a, sat_b } => {
                (2, sat_a.min(sat_b) as u64, sat_a.max(sat_b) as u64)
            }
            LinkClass::Ihl { site_a, site_b } => {
                (3, site_a.min(site_b) as u64, site_a.max(site_b) as u64)
            }
        };
        let window = (send_t.max(0.0) / LOSS_COHERENCE_S).floor() as u64;
        let mut h = self.channel_seed;
        for v in [tag, a, b, window] {
            h = mix64(h ^ v.wrapping_mul(0x9E3779B97F4A7C15));
        }
        h
    }

    /// Earliest time `>= t` at which both endpoints are alive.
    fn avail_time(&self, class: &LinkClass, t: f64) -> f64 {
        let up = |sched: &[ChurnSchedule], i: usize, t: f64| -> f64 {
            sched.get(i).map_or(t, |s| s.up_time_after(t))
        };
        match *class {
            LinkClass::SatSite { sat, site } => {
                up(&self.sat_churn, sat, t).max(up(&self.hap_churn, site, t))
            }
            LinkClass::Isl { sat_a, sat_b } => {
                up(&self.sat_churn, sat_a, t).max(up(&self.sat_churn, sat_b, t))
            }
            LinkClass::Ihl { site_a, site_b } => {
                up(&self.hap_churn, site_a, t).max(up(&self.hap_churn, site_b, t))
            }
        }
    }

    /// Earliest time `>= t` outside the link's outage window.
    fn outage_clear(&self, class: &LinkClass, t: f64) -> f64 {
        match *class {
            LinkClass::SatSite { site, .. } => {
                self.site_outages.get(site).map_or(t, |o| o.clear_time(t))
            }
            LinkClass::Isl { sat_a, sat_b } => {
                let orbit = self.plane_of.get(sat_a).copied().unwrap_or(0);
                let t = self.orbit_outages.get(orbit).map_or(t, |o| o.clear_time(t));
                // the transfer fixpoint re-applies outage_clear, so a
                // clear instant that lands inside the other window
                // still converges
                self.edge_outage_clear(sat_a, sat_b, t)
            }
            LinkClass::Ihl { .. } => t,
        }
    }

    /// Window-independent identity of a link — the key of its FIFO
    /// transmission queue and its reorder tracker. Direction-normalized
    /// like [`Self::channel_key`].
    fn link_key(&self, class: &LinkClass) -> u64 {
        let (tag, a, b) = match *class {
            LinkClass::SatSite { sat, site } => (1u64, sat as u64, site as u64),
            LinkClass::Isl { sat_a, sat_b } => {
                (2, sat_a.min(sat_b) as u64, sat_a.max(sat_b) as u64)
            }
            LinkClass::Ihl { site_a, site_b } => {
                (3, site_a.min(site_b) as u64, site_a.max(site_b) as u64)
            }
        };
        let mut h = self.channel_seed ^ 0x11_4B_51;
        for v in [tag, a, b] {
            h = mix64(h ^ v.wrapping_mul(0x9E3779B97F4A7C15));
        }
        h
    }

    /// Earliest time `>= t` at which this link is not cut by a
    /// scheduled network partition. Identity when partitions are off or
    /// the link is outside the partitioned scope.
    fn partition_clear(&self, class: &LinkClass, t: f64) -> f64 {
        if self.partition.period_s <= 0.0 || self.partition.duration_s <= 0.0 {
            return t;
        }
        if !partition_blocks(
            self.net.partition_scope,
            self.net.partition_shell,
            class,
            &self.shell_of,
            &self.hap_site,
        ) {
            return t;
        }
        self.partition.clear_time(t)
    }

    /// Earliest time `>= t` at which satellite `sat` is out of Earth's
    /// umbra (per the precomputed Sun-vector windows).
    fn umbra_clear_sat(&self, sat: usize, t: f64) -> f64 {
        let Some(ws) = self.sun_umbra.get(sat) else {
            return t;
        };
        // windows are sorted and disjoint: find the first whose end is
        // past t and check whether it already covers t
        let i = ws.partition_point(|&(_, e)| e <= t);
        match ws.get(i) {
            Some(&(s, e)) if s <= t => e,
            _ => t,
        }
    }

    /// Earliest time `>= t` at which no satellite endpoint of this link
    /// sits in Earth's umbra. Identity unless Sun-vector eclipse
    /// windows were baked in.
    fn eclipse_clear(&self, class: &LinkClass, t: f64) -> f64 {
        if self.sun_umbra.is_empty() {
            return t;
        }
        match *class {
            LinkClass::SatSite { sat, .. } => self.umbra_clear_sat(sat, t),
            LinkClass::Isl { sat_a, sat_b } => {
                // a clear instant landing inside the partner's window
                // converges through the transfer fixpoint
                self.umbra_clear_sat(sat_a, t).max(self.umbra_clear_sat(sat_b, t))
            }
            LinkClass::Ihl { .. } => t,
        }
    }

    /// Earliest time `>= t` outside the typed per-edge outage window of
    /// ISL edge `(a, b)`. Each edge gets its own deterministic phase,
    /// hashed from the channel seed and the direction-normalized
    /// endpoint pair, so outages roll across the graph instead of
    /// blacking out every edge in lockstep. Identity when the
    /// edge-outage knobs are zero (every pre-existing scenario).
    pub fn edge_outage_clear(&self, a: usize, b: usize, t: f64) -> f64 {
        let period = self.cfg.isl_edge_outage_period_s;
        let duration = self.cfg.isl_edge_outage_duration_s;
        if !self.enabled || period <= 0.0 || duration <= 0.0 {
            return t;
        }
        let (lo, hi) = (a.min(b) as u64, a.max(b) as u64);
        let mut h = self.channel_seed;
        for v in [4u64, lo, hi] {
            h = mix64(h ^ v.wrapping_mul(0x9E3779B97F4A7C15));
        }
        // top 53 bits -> uniform [0, 1) phase fraction
        let frac = (h >> 11) as f64 / (1u64 << 53) as f64;
        let windows = OutageWindows {
            period_s: period,
            duration_s: duration,
            phase_s: frac * period,
        };
        windows.clear_time(t)
    }

    /// Churn down-transitions within the horizon (satellite deaths +
    /// HAP failures) on this schedule — the `churn_deaths` half of
    /// [`FaultStats`]. Zero when disabled.
    pub fn churn_deaths(&self) -> u64 {
        if !self.enabled {
            return 0;
        }
        self.sat_churn
            .iter()
            .chain(self.hap_churn.iter())
            .flat_map(|sched| sched.down.iter())
            .filter(|&&(s, _)| s <= self.horizon_s)
            .count() as u64
    }

    /// The pure channel oracle: what the impairment timeline does to a
    /// transfer over `class` starting at `t` with clean delay
    /// `base_delay_s` — deferral fixpoint, channel-state key, loss
    /// draws and the resulting delay, with **no** per-run state. This
    /// is [`FaultPlan::transfer`] minus the accounting: `&self` on the
    /// shared schedule, so probe lanes call it concurrently and the
    /// serial replay commits the identical outcome via
    /// [`FaultPlan::commit`].
    pub fn channel_outcome(&self, class: &LinkClass, t: f64, base_delay_s: f64) -> ChannelOutcome {
        // -- deferral: availability + outage + partition + umbra, to a
        // fixpoint (the network clears are identity when their axis is
        // off, so legacy configs converge through the same iterates) --
        let mut start = t;
        for _ in 0..4 {
            let before = start;
            start = self.avail_time(class, start);
            start = self.outage_clear(class, start);
            start = self.partition_clear(class, start);
            start = self.eclipse_clear(class, start);
            if start == before {
                break;
            }
        }
        let cap = self.horizon_s + DEFER_CAP_SLACK_S;
        if start > cap {
            start = cap;
        }
        // -- loss + retransmission from the channel state at send time:
        // bounded exponential backoff with seeded jitter per attempt; a
        // still-lossy channel past the budget is a typed drop, never a
        // longer loop --
        let key = self.channel_key(class, start);
        let backoff_s = self.cfg.retransmit_backoff_s;
        let mut retransmits = 0u32;
        let mut retry_wait_s = 0.0;
        let mut dropped = false;
        if self.cfg.loss_prob > 0.0 {
            let mut chan = Rng::new(key);
            while chan.f64() < self.cfg.loss_prob {
                if retransmits >= self.cfg.max_retransmits {
                    dropped = true;
                    break;
                }
                retransmits += 1;
                // attempt i backs off backoff * 2^(i-1), jittered by a
                // seeded [0.75, 1.25) factor to decorrelate contenders
                let expo = (1u64 << (retransmits - 1).min(6)) as f64;
                retry_wait_s += backoff_s * expo * (0.75 + 0.5 * chan.f64());
            }
        }
        // -- log-normal latency jitter, hash-derived per channel event
        // so draws are order-independent and idempotent per window --
        let jitter_s = if self.net.jitter_sigma > 0.0 {
            let z = Rng::new(mix64(key ^ JITTER_SALT)).gaussian();
            base_delay_s * ((self.net.jitter_sigma * z).exp() - 1.0)
        } else {
            0.0
        };
        let deferred_s = start - t;
        let delay = if dropped {
            // the model never arrives: land past every horizon so the
            // strategies' past-horizon discard applies
            (cap - t).max(0.0) + DEFER_CAP_SLACK_S + base_delay_s
        } else {
            deferred_s + base_delay_s + jitter_s + retransmits as f64 * base_delay_s + retry_wait_s
        };
        ChannelOutcome {
            delay_s: delay,
            retransmits,
            key,
            deferred_s,
            // attribute the deferral: did an outage window / partition /
            // umbra (not just endpoint churn) push the send time? pure
            // re-queries of the deterministic window oracles.
            outage_hit: self.outage_clear(class, t) > t,
            send_t: start,
            service_s: self.net.queue_service_factor * base_delay_s,
            queue_key: self.link_key(class),
            jitter_s,
            partition_hit: self.partition_clear(class, t) > t,
            eclipse_hit: self.eclipse_clear(class, t) > t,
            dropped,
        }
    }

    /// Push the schedule's discrete transitions (churn up/down, outage
    /// boundaries) as typed events. No-op when disabled, so clean runs
    /// see an untouched queue.
    pub fn schedule_events<Q: EventSink>(&self, queue: &mut Q) {
        if !self.enabled {
            return;
        }
        let horizon = self.horizon_s;
        for (sat, sched) in self.sat_churn.iter().enumerate() {
            for &(s, e) in &sched.down {
                if s <= horizon {
                    queue.push(Event::new(s, EventKind::SatChurn { sat, up: false }));
                }
                if e <= horizon {
                    queue.push(Event::new(e, EventKind::SatChurn { sat, up: true }));
                }
            }
        }
        for (hap, sched) in self.hap_churn.iter().enumerate() {
            for &(s, e) in &sched.down {
                if s <= horizon {
                    queue.push(Event::new(s, EventKind::HapChurn { hap, up: false }));
                }
                if e <= horizon {
                    queue.push(Event::new(e, EventKind::HapChurn { hap, up: true }));
                }
            }
        }
        for (site, outage) in self.site_outages.iter().enumerate() {
            for (s, e) in outage.windows_until(horizon) {
                queue.push(Event::new(s, EventKind::OutageStart { site }));
                queue.push(Event::new(e, EventKind::OutageEnd { site }));
            }
        }
    }
}

/// The deterministic fault engine one run carries: a shared immutable
/// [`FaultSchedule`] plus this run's observation set, FIFO link queues
/// and accounting.
pub struct FaultPlan {
    schedule: Arc<FaultSchedule>,
    /// Channel events already observed, each with its committed queue
    /// wait (0 without queueing) — stats idempotency *and* delay
    /// idempotency: repeated probes of one event see one answer.
    seen: HashMap<u64, f64>,
    /// FIFO transmission queue per (endpoint-pair, link-class), the one
    /// order-sensitive axis (active queues force single-lane runs).
    queues: HashMap<u64, LinkQueue>,
    /// Latest committed arrival per link (reorder detection under
    /// latency jitter).
    last_arrival: HashMap<u64, f64>,
    /// Model size offered to the link queues (set by the env; 0 keeps
    /// the bit ledger empty without changing any wait).
    payload_bits: u64,
    stats: FaultStats,
}

impl FaultPlan {
    /// The no-fault plan (what every run before this subsystem used).
    pub fn disabled() -> Self {
        Self::from_schedule(Arc::new(FaultSchedule::disabled()))
    }

    /// Build schedule + fresh counters for one run, for a uniform
    /// constellation of `n_sats` satellites in planes of
    /// `sats_per_orbit` (multi-shell callers go through
    /// [`FaultSchedule::build`]/[`FaultSchedule::shared`] with an
    /// explicit plane mapping). See [`FaultSchedule::build`] for the
    /// determinism contract.
    pub fn new(
        cfg: &FaultConfig,
        seed: u64,
        n_sats: usize,
        n_sites: usize,
        sats_per_orbit: usize,
        horizon_s: f64,
    ) -> Self {
        // like `orbit::uniform_plane_of`, but tolerant of an n_sats
        // that is not a multiple of the plane size (the tail becomes a
        // partial plane, matching the historical division rule)
        let spo = sats_per_orbit.max(1);
        let plane_of: Vec<usize> = (0..n_sats).map(|s| s / spo).collect();
        Self::from_schedule(Arc::new(FaultSchedule::build(
            cfg,
            seed,
            &plane_of,
            n_sites,
            horizon_s,
        )))
    }

    /// Fresh per-run counters over an existing (possibly shared)
    /// schedule.
    pub fn from_schedule(schedule: Arc<FaultSchedule>) -> Self {
        let stats = FaultStats {
            churn_deaths: schedule.churn_deaths(),
            ..FaultStats::default()
        };
        FaultPlan {
            schedule,
            seen: HashMap::new(),
            queues: HashMap::new(),
            last_arrival: HashMap::new(),
            payload_bits: 0,
            stats,
        }
    }

    /// Model size the link queues account per transfer (pure ledger —
    /// waits depend on service time only).
    pub fn set_payload_bits(&mut self, bits: u64) {
        self.payload_bits = bits;
    }

    /// Is per-link bandwidth queueing active? Queue waits depend on
    /// commit order, so an active queue forces the run to a single lane
    /// (`SimEnv::lanes`) — every other axis stays pure and probe-safe.
    pub fn queueing_active(&self) -> bool {
        self.schedule.enabled && self.schedule.net.queue_service_factor > 0.0
    }

    /// The queue wait committed for a channel event (0 for unseen keys
    /// and for every axis but queueing).
    pub fn committed_wait(&self, key: u64) -> f64 {
        self.seen.get(&key).copied().unwrap_or(0.0)
    }

    /// The immutable timeline this plan injects from.
    pub fn schedule(&self) -> &Arc<FaultSchedule> {
        &self.schedule
    }

    /// Is any impairment active? When false the env skips the oracle
    /// entirely, so disabled runs are bit-identical to the pre-faults
    /// code path.
    pub fn enabled(&self) -> bool {
        self.schedule.enabled
    }

    pub fn config(&self) -> &FaultConfig {
        &self.schedule.cfg
    }

    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// Is satellite `sat` alive at `t`? (Always true when disabled.)
    pub fn sat_alive(&self, sat: usize, t: f64) -> bool {
        self.schedule.sat_alive(sat, t)
    }

    /// Is PS site `hap` alive at `t`?
    pub fn hap_alive(&self, hap: usize, t: f64) -> bool {
        self.schedule.hap_alive(hap, t)
    }

    /// Downtime intervals of one satellite (for reporting/tests).
    pub fn sat_downtime(&self, sat: usize) -> &[(f64, f64)] {
        self.schedule.sat_downtime(sat)
    }

    /// Record a training result that never reached a PS.
    pub fn note_dropped(&mut self) {
        self.stats.dropped_results += 1;
    }

    /// The injection oracle: what actually happens to a transfer over
    /// `class` starting at `t` whose clean delay is `base_delay_s`.
    ///
    /// Order of impairments: (1) the transfer is deferred until both
    /// endpoints are alive and the link is outside its outage window
    /// (store-and-forward abstraction), then (2) loss draws add
    /// retransmissions, each costing one backoff plus a re-send.
    ///
    /// Loss is *channel state*, not a per-call dice roll: the draw is a
    /// pure function of (link, send-time coherence window, seed). The
    /// path oracles in `fl::propagation` probe the same hop many times
    /// while routing; with per-call draws the relaxation would keep the
    /// luckiest roll (biasing relayed delays toward fault-free) and
    /// every probe would inflate the stats. Deterministic channel state
    /// makes repeated queries consistent, and [`FaultStats`] counts
    /// each channel event once ([`LinkOutcome::newly_observed`]).
    pub fn transfer(&mut self, class: LinkClass, t: f64, base_delay_s: f64) -> LinkOutcome {
        if !self.schedule.enabled {
            return LinkOutcome { delay_s: base_delay_s, retransmits: 0, newly_observed: false };
        }
        let out = self.schedule.channel_outcome(&class, t, base_delay_s);
        let newly_observed = self.commit(&out);
        LinkOutcome {
            delay_s: out.delay_s + self.committed_wait(out.key),
            retransmits: out.retransmits,
            newly_observed,
        }
    }

    /// Fold one pure [`ChannelOutcome`] (from
    /// [`FaultSchedule::channel_outcome`], possibly computed on a probe
    /// lane) into this run's accounting — counters, the FIFO link
    /// queues and reorder tracking. Returns whether the channel event
    /// was newly observed; the committed queue wait is readable via
    /// [`Self::committed_wait`]. `transfer` ≡ `channel_outcome` +
    /// `commit` + `committed_wait`, bit for bit — the replay contract
    /// the lane probes stand on.
    pub fn commit(&mut self, out: &ChannelOutcome) -> bool {
        if self.seen.contains_key(&out.key) {
            return false;
        }
        if out.deferred_s > 0.0 {
            self.stats.deferrals += 1;
            self.stats.deferred_s += out.deferred_s;
            if out.outage_hit {
                self.stats.outages_hit += 1;
            }
        }
        if out.partition_hit {
            self.stats.partition_hits += 1;
        }
        if out.eclipse_hit {
            self.stats.eclipse_blocked += 1;
        }
        if out.retransmits > 0 {
            self.stats.losses += 1;
        }
        self.stats.retransmits += out.retransmits as u64;
        if out.dropped {
            self.stats.retry_drops += 1;
        }
        // per-link bandwidth queueing: the one order-sensitive fold,
        // applied in serial commit order (active queues force lanes = 1)
        let mut wait = 0.0;
        if out.service_s > 0.0 && !out.dropped {
            let max_wait = self.schedule.net.queue_max_wait_s;
            let q = self.queues.entry(out.queue_key).or_default();
            let qo = q.offer(out.send_t, self.payload_bits, out.service_s, max_wait);
            if qo.dropped {
                self.stats.queue_drops += 1;
                // past-horizon arrival: the strategies' discard applies
                wait = (self.schedule.horizon_s - out.send_t).max(0.0) + 2.0 * DEFER_CAP_SLACK_S;
            } else {
                wait = qo.wait_s;
                self.stats.queued_s += wait;
            }
        }
        // jitter reorders messages: count arrivals landing before an
        // earlier-committed arrival on the same link
        if self.schedule.net.jitter_sigma > 0.0 && !out.dropped {
            let arrival = out.send_t - out.deferred_s + out.delay_s + wait;
            let last = self.last_arrival.entry(out.queue_key).or_insert(f64::NEG_INFINITY);
            if arrival < *last {
                self.stats.reorders += 1;
            } else {
                *last = arrival;
            }
        }
        self.seen.insert(out.key, wait);
        true
    }

    /// [`Self::transfer`] for one typed ISL graph edge `(a, b)` — the
    /// entry point `topology::IslGraph` routing uses per hop. The edge's
    /// own outage window participates in the deferral fixpoint alongside
    /// endpoint churn and orbit-level outages.
    pub fn edge_transfer(&mut self, a: usize, b: usize, t: f64, base_delay_s: f64) -> LinkOutcome {
        self.transfer(LinkClass::Isl { sat_a: a, sat_b: b }, t, base_delay_s)
    }

    /// Push the plan's discrete transitions (churn up/down, outage
    /// boundaries) as typed events. No-op when disabled, so clean runs
    /// see an untouched queue.
    pub fn schedule_events<Q: EventSink>(&self, queue: &mut Q) {
        self.schedule.schedule_events(queue);
    }
}

/// HAP failures drawn on one global timeline so at most one PS is ever
/// down at a time — the ring always keeps at least one alive node to
/// re-heal around. A single-site deployment gets no HAP failures (the
/// lone PS cannot be removed).
fn generate_hap_schedules(
    rng: &mut Rng,
    n_sites: usize,
    mtbf_s: f64,
    mttr_s: f64,
    horizon_s: f64,
) -> Vec<ChurnSchedule> {
    let mut scheds = vec![ChurnSchedule::default(); n_sites];
    if n_sites < 2 || mtbf_s <= 0.0 || mttr_s <= 0.0 {
        return scheds;
    }
    let mut t = exp_draw(rng, mtbf_s);
    while t < horizon_s {
        let hap = rng.below(n_sites);
        let dur = mttr_s * (0.5 + rng.f64());
        scheds[hap].down.push((t, t + dur));
        t += dur + exp_draw(rng, mtbf_s);
    }
    scheds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::config::FaultScenario;
    use crate::sim::EventQueue;

    fn plan(scenario: FaultScenario, intensity: f64, seed: u64) -> FaultPlan {
        let cfg = FaultConfig::preset(scenario, intensity);
        FaultPlan::new(&cfg, seed, 40, 2, 8, 72.0 * 3600.0)
    }

    #[test]
    fn nop_plan_is_transparent() {
        let mut p = plan(FaultScenario::Nominal, 1.0, 42);
        assert!(!p.enabled());
        let out = p.transfer(LinkClass::SatSite { sat: 3, site: 0 }, 100.0, 0.25);
        assert_eq!(
            out,
            LinkOutcome { delay_s: 0.25, retransmits: 0, newly_observed: false }
        );
        assert_eq!(p.stats(), FaultStats::default());
        assert!(p.sat_alive(3, 1e6));
        let mut q = EventQueue::new();
        p.schedule_events(&mut q);
        assert!(q.is_empty());
    }

    #[test]
    fn zero_intensity_equals_nominal_plan() {
        let mut a = plan(FaultScenario::Eclipse, 0.0, 42);
        assert!(!a.enabled());
        let out = a.transfer(LinkClass::Ihl { site_a: 0, site_b: 1 }, 7.0, 0.5);
        assert_eq!(out.delay_s, 0.5);
    }

    #[test]
    fn shared_schedule_keeps_counters_per_run() {
        // two runs over one Arc'd schedule: identical timelines,
        // independent accounting — the schedule-vs-counters split.
        let cfg = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        let plane_of: Vec<usize> = (0..40).map(|s| s / 8).collect();
        let sched = Arc::new(FaultSchedule::build(&cfg, 7, &plane_of, 2, 72.0 * 3600.0));
        let mut a = FaultPlan::from_schedule(sched.clone());
        let mut b = FaultPlan::from_schedule(sched.clone());
        let class = LinkClass::SatSite { sat: 1, site: 0 };
        let oa = a.transfer(class, 50.0, 0.2);
        let ob = b.transfer(class, 50.0, 0.2);
        assert_eq!(oa.delay_s, ob.delay_s, "one channel truth per schedule");
        assert!(oa.newly_observed && ob.newly_observed, "per-run observation sets");
        assert_eq!(a.stats(), b.stats());
        a.note_dropped();
        assert_ne!(a.stats(), b.stats(), "counters must not leak across runs");
        assert!(Arc::ptr_eq(a.schedule(), b.schedule()));
    }

    #[test]
    fn shared_returns_one_arc_per_key() {
        // intensity unique to this test so parallel tests in the binary
        // can't collide with its cache keys
        let cfg = FaultConfig::preset(FaultScenario::Eclipse, 0.85);
        let plane_of: Vec<usize> = (0..12).map(|s| s / 4).collect();
        let horizon = 36.0 * 3600.0;
        let a = FaultSchedule::shared(&cfg, 77, &plane_of, 2, horizon);
        let b = FaultSchedule::shared(&cfg, 77, &plane_of, 2, horizon);
        assert!(Arc::ptr_eq(&a, &b), "same key must share one schedule");
        assert_eq!(FaultSchedule::shared_build_count(&cfg, 77, &plane_of, 2, horizon), 1);
        let c = FaultSchedule::shared(&cfg, 78, &plane_of, 2, horizon);
        assert!(!Arc::ptr_eq(&a, &c), "seed keys the cache");
        // no-op configs bypass the cache entirely
        let nop = FaultConfig::nominal();
        let d = FaultSchedule::shared(&nop, 77, &plane_of, 2, horizon);
        assert!(!d.enabled());
        assert_eq!(FaultSchedule::shared_build_count(&nop, 77, &plane_of, 2, horizon), 0);
    }

    #[test]
    fn multi_shell_plane_mapping_drives_isl_outages() {
        // two planes of different sizes: ISL outage windows must follow
        // the explicit plane mapping, not a uniform division
        let cfg = FaultConfig::preset(FaultScenario::Eclipse, 1.0);
        let plane_of = vec![0, 0, 0, 1, 1, 1, 1, 1];
        let sched = FaultSchedule::build(&cfg, 19, &plane_of, 1, 72.0 * 3600.0);
        assert_eq!(sched.orbit_outages.len(), 2, "one window set per plane");
        let mut p = FaultPlan::from_schedule(Arc::new(sched));
        // an ISL hop inside the *second* plane uses that plane's window
        let o = p.schedule.orbit_outages[1];
        let t_in = o.phase_s + 0.25 * o.duration_s;
        let out = p.transfer(LinkClass::Isl { sat_a: 4, sat_b: 5 }, t_in, 0.1);
        assert!(out.delay_s > 0.1, "mid-window hop must be deferred");
    }

    #[test]
    fn lossy_adds_retransmissions_deterministically() {
        let run = |seed: u64| {
            let mut p = plan(FaultScenario::Lossy, 1.0, seed);
            let mut total = 0.0;
            for i in 0..200 {
                let out =
                    p.transfer(LinkClass::SatSite { sat: i % 40, site: 0 }, i as f64, 0.2);
                assert!(out.delay_s >= 0.2);
                assert!(out.retransmits <= p.config().max_retransmits);
                total += out.delay_s;
            }
            (total, p.stats())
        };
        let (t1, s1) = run(7);
        let (t2, s2) = run(7);
        assert_eq!(t1, t2, "same seed, same draws");
        assert_eq!(s1, s2);
        assert!(s1.retransmits > 0, "30% loss over 200 transfers must retransmit");
        let (t3, _) = run(8);
        assert_ne!(t1, t3, "different seed, different draws");
    }

    #[test]
    fn channel_state_is_idempotent_per_window() {
        let mut p = plan(FaultScenario::Lossy, 1.0, 13);
        let class = LinkClass::Isl { sat_a: 2, sat_b: 3 };
        let a = p.transfer(class, 100.25, 0.2);
        let s1 = p.stats();
        let b = p.transfer(class, 100.75, 0.2); // same 1 s coherence window
        assert_eq!(a.delay_s, b.delay_s, "probe and commit must see one channel truth");
        assert_eq!(a.retransmits, b.retransmits);
        assert!(a.newly_observed && !b.newly_observed);
        assert_eq!(p.stats(), s1, "repeated probes must not inflate stats");
        // the reverse direction shares the same channel
        let c = p.transfer(LinkClass::Isl { sat_a: 3, sat_b: 2 }, 100.5, 0.2);
        assert_eq!(c.retransmits, a.retransmits);
        assert!(!c.newly_observed);
        // a different window re-draws
        let d = p.transfer(class, 4242.0, 0.2);
        assert!(d.newly_observed);
    }

    #[test]
    fn eclipse_defers_transfers_out_of_windows() {
        let mut p = plan(FaultScenario::Eclipse, 1.0, 11);
        let o = p.schedule.site_outages[0];
        assert!(o.active());
        // a transfer started mid-window is deferred to the window end
        let t_in = o.phase_s + 0.5 * o.duration_s;
        let out = p.transfer(LinkClass::SatSite { sat: 0, site: 0 }, t_in, 0.2);
        let expect = (o.duration_s - 0.5 * o.duration_s) + 0.2;
        assert!((out.delay_s - expect).abs() < 1e-9, "{} vs {}", out.delay_s, expect);
        assert_eq!(p.stats().deferrals, 1);
        // a transfer outside the window is untouched
        let t_clear = o.clear_time(t_in) + 1.0;
        let out = p.transfer(LinkClass::SatSite { sat: 0, site: 0 }, t_clear, 0.2);
        assert_eq!(out.delay_s, 0.2);
    }

    #[test]
    fn churn_blocks_links_of_dead_sats() {
        let p = plan(FaultScenario::Churn, 1.0, 5);
        let sat = (0..40)
            .find(|&s| !p.sat_downtime(s).is_empty())
            .expect("full-intensity churn over 72 h must hit someone");
        let (down, up) = p.sat_downtime(sat)[0];
        let mid = 0.5 * (down + up);
        assert!(!p.sat_alive(sat, mid));
        assert!(p.sat_alive(sat, down - 1.0));
        let mut p = p;
        let out = p.transfer(LinkClass::SatSite { sat, site: 0 }, mid, 0.2);
        assert!((out.delay_s - ((up - mid) + 0.2)).abs() < 1e-9);
        // the partner side of an ISL hop is equally blocking
        let partner = if sat % 8 == 0 { sat + 1 } else { sat - 1 };
        let out = p.transfer(LinkClass::Isl { sat_a: partner, sat_b: sat }, mid, 0.1);
        assert!(out.delay_s >= (up - mid) + 0.1 - 1e-9);
    }

    #[test]
    fn hap_failures_never_overlap() {
        let p = plan(FaultScenario::HapFailure, 1.0, 3);
        let a = &p.schedule.hap_churn[0].down;
        let b = &p.schedule.hap_churn[1].down;
        assert!(
            !a.is_empty() || !b.is_empty(),
            "72 h at 8 h MTBF must fail a HAP"
        );
        for &(s0, e0) in a {
            for &(s1, e1) in b {
                assert!(e0 <= s1 || e1 <= s0, "overlap: ({s0},{e0}) vs ({s1},{e1})");
            }
        }
    }

    #[test]
    fn single_site_gets_no_hap_failures() {
        let cfg = FaultConfig::preset(FaultScenario::HapFailure, 1.0);
        let p = FaultPlan::new(&cfg, 9, 40, 1, 8, 72.0 * 3600.0);
        assert!(p.schedule.hap_churn[0].down.is_empty());
    }

    #[test]
    fn schedule_events_matches_timeline() {
        let p = plan(FaultScenario::Churn, 1.0, 5);
        let mut q = EventQueue::new();
        p.schedule_events(&mut q);
        let horizon = p.schedule.horizon_s;
        let expected: usize = (0..40)
            .map(|s| {
                p.sat_downtime(s)
                    .iter()
                    .map(|&(a, b)| (a <= horizon) as usize + (b <= horizon) as usize)
                    .sum::<usize>()
            })
            .sum();
        assert_eq!(q.len(), expected);
        // events pop in time order and alternate down/up per sat
        let mut last = 0.0;
        while let Some(ev) = q.pop() {
            assert!(ev.time_s >= last);
            last = ev.time_s;
            assert!(matches!(ev.kind, EventKind::SatChurn { .. }));
        }
    }

    #[test]
    fn typed_edge_outages_defer_single_edges() {
        let mut cfg = FaultConfig::nominal();
        cfg.isl_edge_outage_period_s = 7200.0;
        cfg.isl_edge_outage_duration_s = 1800.0;
        assert!(!cfg.is_nop());
        assert!(cfg.validate().is_empty());
        let mut p = FaultPlan::new(&cfg, 33, 40, 2, 8, 72.0 * 3600.0);
        assert!(p.enabled());
        let sched = p.schedule().clone();
        // find an instant inside edge (2,3)'s window (25% duty cycle)
        let t_in = (0..72)
            .map(|i| i as f64 * 100.0)
            .find(|&t| sched.edge_outage_clear(2, 3, t) > t)
            .expect("a 25% duty cycle must be hit by a 100 s scan");
        // the window is direction-normalized and deferral-visible
        let clear = sched.edge_outage_clear(2, 3, t_in);
        assert_eq!(clear, sched.edge_outage_clear(3, 2, t_in));
        let out = p.edge_transfer(2, 3, t_in, 0.1);
        assert!((out.delay_s - ((clear - t_in) + 0.1)).abs() < 1e-9);
        assert_eq!(p.stats().deferrals, 1);
        // phases are per-edge: some other ring edge is clear at t_in
        let other = (4..40)
            .find(|&a| sched.edge_outage_clear(a, a + 1, t_in) == t_in)
            .expect("independent phases cannot all cover one instant");
        let out = p.edge_transfer(other, other + 1, t_in, 0.1);
        assert_eq!(out.delay_s, 0.1, "clear edge is untouched");
        // star links never see edge outages
        let out = p.transfer(LinkClass::SatSite { sat: 2, site: 0 }, t_in, 0.2);
        assert_eq!(out.delay_s, 0.2);
    }

    #[test]
    fn edge_outages_are_deterministic_and_off_by_default() {
        let mut cfg = FaultConfig::nominal();
        cfg.isl_edge_outage_period_s = 3600.0;
        cfg.isl_edge_outage_duration_s = 900.0;
        let a = FaultPlan::new(&cfg, 5, 24, 2, 8, 36.0 * 3600.0);
        let b = FaultPlan::new(&cfg, 5, 24, 2, 8, 36.0 * 3600.0);
        for t in [0.0, 500.0, 2000.0, 3500.0] {
            assert_eq!(
                a.schedule().edge_outage_clear(7, 8, t),
                b.schedule().edge_outage_clear(7, 8, t),
                "same seed, same windows"
            );
        }
        // every pre-existing preset leaves the edge oracle as identity
        for &s in crate::faults::config::FaultScenario::ALL {
            let p = plan(s, 1.0, 9);
            for t in [0.0, 1234.5, 50_000.0] {
                assert_eq!(p.schedule().edge_outage_clear(0, 1, t), t, "{s:?}");
            }
        }
    }

    #[test]
    fn channel_outcome_plus_commit_equals_transfer() {
        // the probe/replay contract: splitting the oracle into its pure
        // half and the accounting fold changes nothing, bit for bit —
        // outcomes, stats and the seen-set behaviour all match a
        // monolithic transfer on a twin plan.
        for scenario in [FaultScenario::Lossy, FaultScenario::Eclipse, FaultScenario::Churn] {
            let mut mono = plan(scenario, 1.0, 31);
            let mut split = plan(scenario, 1.0, 31);
            for i in 0..100 {
                let class = match i % 3 {
                    0 => LinkClass::SatSite { sat: i % 40, site: i % 2 },
                    1 => LinkClass::Isl { sat_a: i % 40, sat_b: (i + 1) % 40 },
                    _ => LinkClass::Ihl { site_a: 0, site_b: 1 },
                };
                let t = (i as f64) * 37.5;
                let a = mono.transfer(class, t, 0.2);
                let out = split.schedule().clone().channel_outcome(&class, t, 0.2);
                let newly = split.commit(&out);
                let replayed = out.delay_s + split.committed_wait(out.key);
                assert_eq!(a.delay_s.to_bits(), replayed.to_bits(), "{scenario:?} #{i}");
                assert_eq!(a.retransmits, out.retransmits);
                assert_eq!(a.newly_observed, newly);
            }
            assert_eq!(mono.stats(), split.stats(), "{scenario:?}");
            assert_eq!(
                mono.stats().deferred_s.to_bits(),
                split.stats().deferred_s.to_bits(),
                "float accumulation order must match exactly"
            );
        }
    }

    #[test]
    fn schedule_events_accepts_laned_queues() {
        let p = plan(FaultScenario::Churn, 1.0, 5);
        let mut single = EventQueue::new();
        let mut laned = crate::sim::LanedQueue::new(4, Vec::new());
        p.schedule_events(&mut single);
        p.schedule_events(&mut laned);
        assert_eq!(single.len(), laned.len());
        loop {
            let a = single.pop();
            let b = laned.pop();
            assert_eq!(a, b, "lane sharding must not reorder the fault timeline");
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn deferral_is_capped_finite() {
        // a sat that dies at the very end of the horizon defers past it
        // but never to infinity
        let cfg = FaultConfig::preset(FaultScenario::Churn, 1.0);
        let mut p = FaultPlan::new(&cfg, 21, 40, 2, 8, 3600.0);
        for sat in 0..40 {
            for t in [0.0, 1800.0, 3599.0] {
                let out = p.transfer(LinkClass::SatSite { sat, site: 0 }, t, 0.2);
                assert!(out.delay_s.is_finite());
                assert!(t + out.delay_s <= 3600.0 + DEFER_CAP_SLACK_S + 1.0);
            }
        }
    }

    #[test]
    fn retry_budget_exhaustion_is_a_typed_drop_not_a_loop() {
        // the satellite-task boundary test: a channel that stays lossy
        // past the retry budget surfaces as a typed drop whose arrival
        // lands past every horizon — never an unbounded retry loop
        let mut cfg = FaultConfig::nominal();
        cfg.loss_prob = 1.0; // every attempt lost, budget must bound it
        cfg.retransmit_backoff_s = 0.05;
        cfg.max_retransmits = 4;
        let horizon = 3600.0;
        let mut p = FaultPlan::new(&cfg, 17, 8, 2, 8, horizon);
        let t = 100.0;
        let out = p.transfer(LinkClass::SatSite { sat: 1, site: 0 }, t, 0.2);
        assert_eq!(out.retransmits, 4, "every budgeted attempt was spent");
        assert!(
            t + out.delay_s > horizon + DEFER_CAP_SLACK_S,
            "a dropped transfer must arrive past the discard horizon"
        );
        assert_eq!(p.stats().retry_drops, 1);
        assert_eq!(p.stats().losses, 1);
        assert_eq!(p.stats().retransmits, 4);
        // idempotent like every channel event: a re-probe of the same
        // window replays the drop without recounting it
        let again = p.transfer(LinkClass::SatSite { sat: 1, site: 0 }, t + 0.4, 0.2);
        assert_eq!(again.delay_s.to_bits(), out.delay_s.to_bits());
        assert_eq!(p.stats().retry_drops, 1);
    }

    #[test]
    fn retransmission_backoff_is_exponential_with_bounded_jitter() {
        // attempt i waits backoff * 2^(i-1), jittered in [0.75, 1.25):
        // a k-retransmit transfer pays between 0.75 and 1.25 times
        // backoff * (2^k - 1) on top of deferral and re-sends
        let mut cfg = FaultConfig::nominal();
        cfg.loss_prob = 0.5;
        cfg.retransmit_backoff_s = 0.1;
        cfg.max_retransmits = 6;
        let sched = FaultSchedule::build(&cfg, 23, &[0; 8], 2, 72.0 * 3600.0);
        let base = 0.2;
        let mut saw_multi = false;
        for i in 0..400 {
            let t = i as f64 * 3.0;
            let out = sched.channel_outcome(&LinkClass::SatSite { sat: 0, site: 0 }, t, base);
            if out.dropped {
                continue;
            }
            let k = out.retransmits;
            saw_multi |= k >= 2;
            let resend = base * (1.0 + k as f64);
            let geo = cfg.retransmit_backoff_s * ((1u64 << k) - 1) as f64;
            let wait = out.delay_s - out.deferred_s - resend;
            assert!(
                wait >= 0.75 * geo - 1e-12 && wait < 1.25 * geo + 1e-12,
                "#{i}: k={k}, backoff wait {wait} outside [{}, {})",
                0.75 * geo,
                1.25 * geo
            );
        }
        assert!(saw_multi, "50% loss over 400 windows must back off at least twice");
    }

    #[test]
    fn nominal_network_is_bit_identical_to_the_legacy_build() {
        // the zero-intensity contract at the oracle level: an explicit
        // nominal NetworkConfig (with a populated NetWorld) changes no
        // bit of any channel outcome vs the legacy entry point
        let cfg = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        let plane_of: Vec<usize> = (0..40).map(|s| s / 8).collect();
        let shell_of = vec![0usize; 40];
        let hap_site = vec![true, false];
        let horizon = 72.0 * 3600.0;
        let legacy = FaultSchedule::build(&cfg, 41, &plane_of, 2, horizon);
        let net = FaultSchedule::build_with_network(
            &cfg,
            &NetworkConfig::nominal(),
            41,
            &plane_of,
            &NetWorld { shell_of: &shell_of, hap_site: &hap_site, constellation: None },
            2,
            horizon,
        );
        for i in 0..120 {
            let class = match i % 3 {
                0 => LinkClass::SatSite { sat: i % 40, site: i % 2 },
                1 => LinkClass::Isl { sat_a: i % 40, sat_b: (i + 1) % 40 },
                _ => LinkClass::Ihl { site_a: 0, site_b: 1 },
            };
            let t = i as f64 * 211.7;
            let a = legacy.channel_outcome(&class, t, 0.2);
            let b = net.channel_outcome(&class, t, 0.2);
            assert_eq!(a.delay_s.to_bits(), b.delay_s.to_bits(), "#{i}");
            assert_eq!(a, b);
            assert_eq!(b.jitter_s, 0.0);
            assert_eq!(b.service_s, 0.0);
            assert!(!b.partition_hit && !b.eclipse_hit);
        }
    }

    #[test]
    fn latency_jitter_is_seeded_idempotent_and_reorders_messages() {
        let cfg = FaultConfig::nominal();
        let net = NetworkConfig::preset(FaultScenario::Jitter, 1.0);
        assert!(net.jitter_sigma > 0.0);
        let plane_of: Vec<usize> = (0..8).collect();
        let sched = Arc::new(FaultSchedule::build_with_network(
            &cfg,
            &net,
            29,
            &plane_of,
            &NetWorld::empty(),
            2,
            72.0 * 3600.0,
        ));
        let class = LinkClass::SatSite { sat: 2, site: 0 };
        // hash-derived per channel event: order-independent, idempotent,
        // and multiplicative around the clean delay
        let a = sched.channel_outcome(&class, 50.25, 10.0);
        let b = sched.channel_outcome(&class, 50.75, 10.0);
        assert_eq!(a, b, "one jitter truth per coherence window");
        assert!(a.jitter_s != 0.0);
        assert!(a.delay_s > 0.0, "log-normal jitter keeps delays positive");
        let c = sched.channel_outcome(&class, 999.0, 10.0);
        assert_ne!(a.jitter_s.to_bits(), c.jitter_s.to_bits(), "windows re-draw");
        // consequent reordering: a long-delay link with 1 s send spacing
        // must commit some arrival before an earlier one
        let mut p = FaultPlan::from_schedule(sched);
        for i in 0..300 {
            p.transfer(class, i as f64, 10.0);
        }
        assert!(p.stats().reorders > 0, "σ=0.35 on a 10 s link must reorder");
        // deterministic accounting: a twin run sees the same count
        let twin = {
            let mut q = FaultPlan::from_schedule(p.schedule().clone());
            for i in 0..300 {
                q.transfer(class, i as f64, 10.0);
            }
            q.stats()
        };
        assert_eq!(p.stats(), twin);
    }

    #[test]
    fn partitions_defer_scoped_links_and_count_hits() {
        let cfg = FaultConfig::nominal();
        let net = NetworkConfig::preset(FaultScenario::Partition, 1.0);
        assert_eq!(net.partition_scope, PartitionScope::Ground);
        let plane_of: Vec<usize> = (0..8).map(|s| s / 4).collect();
        let hap_site = vec![true, false]; // site 0 = HAP, site 1 = GS
        let sched = Arc::new(FaultSchedule::build_with_network(
            &cfg,
            &net,
            59,
            &plane_of,
            &NetWorld { shell_of: &[], hap_site: &hap_site, constellation: None },
            2,
            72.0 * 3600.0,
        ));
        let o = sched.partition;
        assert!(o.active(), "partition preset must schedule windows");
        let t_in = o.phase_s + 0.5 * o.duration_s;
        let mut p = FaultPlan::from_schedule(sched);
        // a GS star link inside the window defers to the heal instant
        let out = p.transfer(LinkClass::SatSite { sat: 0, site: 1 }, t_in, 0.2);
        let expect = 0.5 * o.duration_s + 0.2;
        assert!((out.delay_s - expect).abs() < 1e-9, "{} vs {expect}", out.delay_s);
        assert_eq!(p.stats().partition_hits, 1);
        assert_eq!(p.stats().deferrals, 1);
        // the HAP layer keeps flying: HAP star links and ISLs untouched
        let out = p.transfer(LinkClass::SatSite { sat: 1, site: 0 }, t_in, 0.2);
        assert_eq!(out.delay_s, 0.2);
        let out = p.transfer(LinkClass::Isl { sat_a: 2, sat_b: 3 }, t_in, 0.1);
        assert_eq!(out.delay_s, 0.1);
        assert_eq!(p.stats().partition_hits, 1);
    }

    #[test]
    fn sun_vector_eclipses_defer_transfers_through_umbra_windows() {
        let c = WalkerConstellation::paper();
        let cfg = FaultConfig::nominal();
        let net = NetworkConfig::preset(FaultScenario::SunEclipse, 1.0);
        assert!(net.eclipse_from_sun);
        let horizon = 7200.0;
        let plane_of = c.plane_of();
        let sched = Arc::new(FaultSchedule::build_with_network(
            &cfg,
            &net,
            67,
            &plane_of,
            &NetWorld { shell_of: &[], hap_site: &[], constellation: Some(&c) },
            2,
            horizon,
        ));
        // find a satellite with an umbra window strictly inside the
        // horizon (most LEO sats cross the shadow within two hours)
        let (sat, s, e) = (0..c.len())
            .find_map(|sat| {
                sched
                    .sun_umbra_windows(sat)
                    .iter()
                    .find(|&&(s, e)| s > 1.0 && e < horizon - 1.0)
                    .map(|&(s, e)| (sat, s, e))
            })
            .expect("a LEO constellation must cross Earth's shadow within 2 h");
        let t_in = 0.5 * (s + e);
        let mut p = FaultPlan::from_schedule(sched);
        let out = p.transfer(LinkClass::SatSite { sat, site: 0 }, t_in, 0.2);
        let expect = (e - t_in) + 0.2;
        assert!((out.delay_s - expect).abs() < 1e-9, "{} vs {expect}", out.delay_s);
        assert_eq!(p.stats().eclipse_blocked, 1);
        // the site-to-site backbone has no satellite endpoint to shadow
        let out = p.transfer(LinkClass::Ihl { site_a: 0, site_b: 1 }, t_in, 0.5);
        assert_eq!(out.delay_s, 0.5);
        // just after the exit edge the link is clear again
        let out = p.transfer(LinkClass::SatSite { sat, site: 0 }, e + 1.0, 0.2);
        assert_eq!(out.delay_s, 0.2);
    }

    #[test]
    fn queueing_serializes_contending_transfers_and_replays_idempotently() {
        let cfg = FaultConfig::nominal();
        let net = NetworkConfig::preset(FaultScenario::Congestion, 1.0);
        assert!(net.queue_service_factor > 0.0);
        let plane_of: Vec<usize> = (0..8).collect();
        let sched = Arc::new(FaultSchedule::build_with_network(
            &cfg,
            &net,
            71,
            &plane_of,
            &NetWorld::empty(),
            2,
            72.0 * 3600.0,
        ));
        let mut p = FaultPlan::from_schedule(sched);
        p.set_payload_bits(1_000);
        assert!(p.queueing_active(), "congestion preset must force single-lane runs");
        let class = LinkClass::SatSite { sat: 3, site: 0 };
        // first transfer occupies the link for service = factor * base
        let a = p.transfer(class, 0.0, 10.0);
        assert_eq!(a.delay_s, 10.0, "an idle link adds no wait");
        // a second window one second later waits for the residual 9 s
        let b = p.transfer(class, 1.0, 10.0);
        assert!((b.delay_s - 19.0).abs() < 1e-9, "FIFO residual wait: {}", b.delay_s);
        assert!((p.stats().queued_s - 9.0).abs() < 1e-9);
        // a replayed probe of the same window sees the committed wait,
        // bit for bit, without re-offering to the queue
        let b2 = p.transfer(class, 1.5, 10.0);
        assert_eq!(b2.delay_s.to_bits(), b.delay_s.to_bits());
        assert!((p.stats().queued_s - 9.0).abs() < 1e-9, "no double offer");
        // a different link has its own queue
        let other = p.transfer(LinkClass::SatSite { sat: 4, site: 0 }, 1.0, 10.0);
        assert_eq!(other.delay_s, 10.0);
        // and a nominal plan never queues
        assert!(!FaultPlan::disabled().queueing_active());
    }

    #[test]
    fn queue_wait_cap_surfaces_as_past_horizon_drop() {
        let cfg = FaultConfig::nominal();
        let mut net = NetworkConfig::preset(FaultScenario::Congestion, 1.0);
        net.queue_max_wait_s = 5.0;
        let plane_of: Vec<usize> = (0..8).collect();
        let horizon = 3600.0;
        let sched = Arc::new(FaultSchedule::build_with_network(
            &cfg,
            &net,
            73,
            &plane_of,
            &NetWorld::empty(),
            2,
            horizon,
        ));
        let mut p = FaultPlan::from_schedule(sched);
        let class = LinkClass::SatSite { sat: 0, site: 0 };
        p.transfer(class, 0.0, 10.0); // occupies the link until t = 10
        let dropped = p.transfer(class, 1.0, 10.0); // 9 s wait > 5 s cap
        assert!(
            1.0 + dropped.delay_s > horizon + DEFER_CAP_SLACK_S,
            "a queue drop must arrive past the discard horizon"
        );
        assert_eq!(p.stats().queue_drops, 1);
        assert_eq!(p.stats().queued_s, 0.0, "drops never accumulate wait time");
    }
}
