//! Declarative experiment scenarios: a named preset or a TOML file
//! turns into a complete, reproducible experiment world.
//!
//! A [`Scenario`] bundles everything that defines a constellation FL
//! deployment: a (possibly multi-shell) constellation spec
//! (`[constellation]` + `[shellN]` sections — delta or star pattern,
//! altitude, inclination, planes, phasing, see
//! [`crate::orbit::ShellSpec`]), a PS site layout
//! ([`crate::config::PsPlacement`] named real-world sets), a data
//! distribution (IID / paper non-IID), and an optional fault scenario
//! ([`crate::faults::FaultConfig`]). All of that already lives in
//! [`ExperimentConfig`], so a scenario is a named, documented config —
//! and it round-trips losslessly through the TOML subset
//! ([`Scenario::to_toml`] / [`Scenario::from_toml`]).
//!
//! **ISL topology sections**: the `[isl]` section configures the
//! explicit ISL graph ([`crate::topology::IslGraph`]) the world is
//! built with, and `[isl_linkN]` sections override the RF budget per
//! shell:
//!
//! ```toml
//! [isl]
//! topology = "grid"      # "ring" (paper default) | "grid"
//! cross_shell = true     # gateway edges between stacked shells
//! doppler = true         # Doppler-derate per-edge rates
//!
//! [isl_link1]            # shell 0's ISL budget (contiguous from 1)
//! tx_power_dbm = 30
//! antenna_gain_dbi = 30
//! carrier_ghz = 2.4
//! noise_temp_k = 290
//! data_rate_mbps = 16
//! bandwidth_mhz = 20
//! processing_delay_s = 0.1
//! ```
//!
//! Shells without an `[isl_linkN]` entry fall back to the global
//! `[link]` budget. Typed per-ISL-edge outage windows ride the
//! `[faults]` section (`isl_edge_outage_period_s` /
//! `isl_edge_outage_duration_s`). Everything round-trips through
//! `to_toml`/`from_toml` like the rest of the config.
//!
//! The built-in catalog ([`ScenarioRegistry::builtin`]) ships ≥8
//! presets spanning the design space the related work evaluates on
//! (paper 5×8, a two-shell Starlink-like mix, a OneWeb-like polar star,
//! a sparse IoT constellation, an equatorial shell, a HAP-degraded
//! world, the 1584-satellite `starlink-phase1` stress shell the
//! run-loop bench drives, and the 10,440-satellite four-shell
//! `starlink-gen2` world that stresses the analytic contact
//! predictor). `asyncfleo scenario` lists the catalog, dumps
//! presets to TOML, and sweeps scheme×scenario comparison grids through
//! `experiments::scenarios` into `results/scenarios.csv`.
//!
//! **Adding a preset**: write a `fn my_preset() -> Scenario` below that
//! derives its `ExperimentConfig` from `paper_defaults()`, register it
//! in [`ScenarioRegistry::builtin`], and the CLI list/dump/run paths,
//! the registry-completeness test and the TOML round-trip test all pick
//! it up automatically. Geometry is cached per unique scenario key
//! (`coordinator::Geometry::shared`), so sweeps across presets build
//! each world exactly once per process.

use crate::config::{ExperimentConfig, PsPlacement, SchemeKind};
use crate::data::Partition;
use crate::faults::{FaultConfig, FaultScenario};
use crate::orbit::ShellSpec;

/// A named, documented experiment world.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Catalog key, e.g. `starlink-lite`.
    pub name: String,
    /// One-line description for `--list`.
    pub summary: String,
    /// The complete experiment configuration (constellation shells,
    /// placement, partition, faults, sizes, seed).
    pub cfg: ExperimentConfig,
}

/// Header line prefix of a dumped scenario file.
const HEADER_PREFIX: &str = "# scenario: ";
/// Separates name from summary in the header line.
const HEADER_SEP: &str = " -- ";

impl Scenario {
    pub fn new(name: impl Into<String>, summary: impl Into<String>, cfg: ExperimentConfig) -> Self {
        Scenario { name: name.into(), summary: summary.into(), cfg }
    }

    /// Serialize: a `# scenario:` header followed by the config TOML.
    /// Round-trips through [`Self::from_toml`].
    pub fn to_toml(&self) -> String {
        format!("{HEADER_PREFIX}{}{HEADER_SEP}{}\n{}", self.name, self.summary, self.cfg.to_toml())
    }

    /// Parse a scenario file. The `# scenario: name -- summary` header
    /// is optional (a plain config TOML becomes scenario "custom");
    /// the config must validate.
    pub fn from_toml(text: &str) -> Result<Scenario, String> {
        let cfg = ExperimentConfig::from_toml(text).map_err(|e| e.to_string())?;
        let errs = cfg.validate();
        if !errs.is_empty() {
            return Err(format!("invalid scenario config: {}", errs.join("; ")));
        }
        let (name, summary) = text
            .lines()
            .find_map(|l| l.strip_prefix(HEADER_PREFIX))
            .map(|h| match h.split_once(HEADER_SEP) {
                Some((n, s)) => (n.trim().to_string(), s.trim().to_string()),
                None => (h.trim().to_string(), String::new()),
            })
            .unwrap_or_else(|| ("custom".to_string(), String::new()));
        Ok(Scenario { name, summary, cfg })
    }

    pub fn from_file(path: &str) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_toml(&text)
    }

    /// One catalog line: name, constellation, placement, partition,
    /// fault state.
    pub fn describe(&self) -> String {
        let c = &self.cfg;
        format!(
            "{:<18} {:>4} sats  {:<28} {:<10} {:<8} {}  {}",
            self.name,
            c.n_sats(),
            c.constellation.summary(),
            c.placement.name(),
            match c.fl.partition {
                Partition::Iid => "iid",
                Partition::NonIidPaper => "non-iid",
            },
            if c.faults.is_nop() { "clean " } else { "faulty" },
            self.summary,
        )
    }
}

/// The ordered catalog of built-in scenarios (plus lookup by name).
#[derive(Clone, Debug, Default)]
pub struct ScenarioRegistry {
    items: Vec<Scenario>,
}

impl ScenarioRegistry {
    /// The built-in catalog, in presentation order.
    pub fn builtin() -> Self {
        ScenarioRegistry {
            items: vec![
                paper_40(),
                starlink_lite(),
                polar_star(),
                sparse_iot(),
                equatorial_dense(),
                haps_degraded(),
                starlink_phase1(),
                starlink_gen2(),
            ],
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Scenario> {
        self.items.iter()
    }

    pub fn names(&self) -> Vec<&str> {
        self.items.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&Scenario> {
        self.items.iter().find(|s| s.name == name)
    }
}

/// Shared base: paper defaults with the scheme left to the comparison
/// driver (it sweeps schemes over each scenario).
fn base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    cfg
}

/// The paper's own world: 5×8 delta at 2000 km, one HAP over Rolla,
/// the paper non-IID split.
fn paper_40() -> Scenario {
    Scenario::new("paper-40", "the paper's Sec. V-A evaluation world", base())
}

/// A Starlink-flavored two-shell mix: a dense low shell plus a sparser
/// high shell, two HAP sinks. Exercises multi-shell geometry end to
/// end (disjoint id ranges, per-shell planes, mixed contact patterns).
fn starlink_lite() -> Scenario {
    let mut cfg = base();
    cfg.constellation.n_orbits = 12;
    cfg.constellation.sats_per_orbit = 20;
    cfg.constellation.altitude_km = 550.0;
    cfg.constellation.inclination_deg = 53.0;
    cfg.constellation.phasing = 1;
    cfg.constellation.extra_shells = vec![ShellSpec::delta(6, 10, 1110.0, 53.8, 1)];
    cfg.placement = PsPlacement::TwoHaps;
    Scenario::new(
        "starlink-lite",
        "two-shell 12x20@550 + 6x10@1110 Starlink-like mix, two HAPs",
        cfg,
    )
}

/// A OneWeb-like polar star shell: near-polar planes over 180° of
/// RAAN, the FedISL/FedSat "ideal" polar ground station as the sink.
fn polar_star() -> Scenario {
    let mut cfg = base();
    cfg.constellation.pattern = crate::orbit::WalkerPattern::Star;
    cfg.constellation.n_orbits = 6;
    cfg.constellation.sats_per_orbit = 12;
    cfg.constellation.altitude_km = 1200.0;
    cfg.constellation.inclination_deg = 87.9;
    cfg.constellation.phasing = 1;
    cfg.placement = PsPlacement::GsNorthPole;
    cfg.fl.partition = Partition::Iid;
    Scenario::new("polar-star", "OneWeb-like 6x12 polar star, North-Pole GS sink", cfg)
}

/// A sparse IoT data-collection constellation: 2×4 at 600 km, a single
/// mid-latitude ground station — long gaps, few simultaneous contacts.
fn sparse_iot() -> Scenario {
    let mut cfg = base();
    cfg.constellation.n_orbits = 2;
    cfg.constellation.sats_per_orbit = 4;
    cfg.constellation.altitude_km = 600.0;
    cfg.constellation.inclination_deg = 70.0;
    cfg.constellation.phasing = 1;
    cfg.placement = PsPlacement::GsRolla;
    Scenario::new("sparse-iot", "sparse 2x4 IoT constellation, single Rolla GS", cfg)
}

/// A dense single-plane equatorial shell with an equatorial HAP sink
/// (a mid-latitude site would never see these satellites).
fn equatorial_dense() -> Scenario {
    let mut cfg = base();
    cfg.constellation.n_orbits = 1;
    cfg.constellation.sats_per_orbit = 16;
    cfg.constellation.altitude_km = 550.0;
    cfg.constellation.inclination_deg = 5.0;
    cfg.constellation.phasing = 0;
    cfg.placement = PsPlacement::HapQuito;
    cfg.fl.partition = Partition::Iid;
    Scenario::new("equatorial-dense", "1x16 equatorial ring, HAP sink over Quito", cfg)
}

/// The paper world under full-intensity HAP failures: the two-HAP ring
/// loses nodes and re-heals while training runs.
fn haps_degraded() -> Scenario {
    let mut cfg = base();
    cfg.placement = PsPlacement::TwoHaps;
    cfg.faults = FaultConfig::preset(FaultScenario::HapFailure, 1.0);
    Scenario::new("haps-degraded", "paper world + HAP failures at full intensity", cfg)
}

/// Starlink phase-1 first shell at production scale: 72 planes × 22
/// satellites at 550 km / 53° (1584 satellites, Walker delta with the
/// F=17 phasing of the FCC filing), two HAP sinks. The
/// mega-constellation stress world for the run-loop fast path —
/// `benches/bench_runloop.rs` drives a three-scheme compare on it and
/// the run-equivalence suite smokes it.
fn starlink_phase1() -> Scenario {
    let mut cfg = base();
    cfg.constellation.n_orbits = 72;
    cfg.constellation.sats_per_orbit = 22;
    cfg.constellation.altitude_km = 550.0;
    cfg.constellation.inclination_deg = 53.0;
    cfg.constellation.phasing = 17;
    cfg.placement = PsPlacement::TwoHaps;
    Scenario::new(
        "starlink-phase1",
        "Starlink phase-1 shell, 72x22@550 km (1584 sats), two HAPs",
        cfg,
    )
}

/// Starlink Gen2-flavored four-shell constellation at 10k+ scale: three
/// dense 28×110 shells stacked at 525/530/535 km with spread
/// inclinations (53°/43°/33°) plus a 12×100 high-inclination shell at
/// 604 km — 10,440 satellites total, two HAP sinks. The geometry stress
/// world for the analytic contact predictor: three shells share
/// latitude bands per site, so the (shell, site-latitude-band) pass-map
/// cache and the pass-gap skip are both load-bearing here. Training
/// sample count is raised so every satellite still gets a shard.
fn starlink_gen2() -> Scenario {
    let mut cfg = base();
    cfg.constellation.n_orbits = 28;
    cfg.constellation.sats_per_orbit = 110;
    cfg.constellation.altitude_km = 525.0;
    cfg.constellation.inclination_deg = 53.0;
    cfg.constellation.phasing = 1;
    cfg.constellation.extra_shells = vec![
        ShellSpec::delta(28, 110, 530.0, 43.0, 1),
        ShellSpec::delta(28, 110, 535.0, 33.0, 1),
        ShellSpec::delta(12, 100, 604.0, 70.0, 1),
    ];
    cfg.placement = PsPlacement::TwoHaps;
    cfg.data.train_samples = 20_880; // 2 samples per satellite
    Scenario::new(
        "starlink-gen2",
        "Starlink Gen2-like four-shell mix, 10440 sats, two HAPs",
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Geometry;

    #[test]
    fn catalog_has_at_least_six_presets() {
        let reg = ScenarioRegistry::builtin();
        assert!(reg.len() >= 8, "catalog has {}", reg.len());
        for name in [
            "paper-40",
            "starlink-lite",
            "polar-star",
            "sparse-iot",
            "equatorial-dense",
            "haps-degraded",
            "starlink-phase1",
            "starlink-gen2",
        ] {
            assert!(reg.get(name).is_some(), "missing preset {name}");
        }
        // names are unique
        let mut names = reg.names();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), reg.len());
    }

    #[test]
    fn starlink_phase1_is_mega_scale() {
        let sc = ScenarioRegistry::builtin().get("starlink-phase1").unwrap().clone();
        assert_eq!(sc.cfg.n_sats(), 1584, "72 x 22");
        assert_eq!(sc.cfg.constellation.n_planes(), 72);
        assert!(sc.cfg.validate().is_empty(), "{:?}", sc.cfg.validate());
        // dumps + reloads like every other preset (also covered by the
        // round-trip test, pinned here so the stress preset never
        // silently drops out of the catalog)
        let reloaded = Scenario::from_toml(&sc.to_toml()).unwrap();
        assert_eq!(reloaded, sc);
    }

    #[test]
    fn starlink_gen2_is_ten_thousand_sats_four_shells() {
        let sc = ScenarioRegistry::builtin().get("starlink-gen2").unwrap().clone();
        assert_eq!(sc.cfg.n_sats(), 10_440, "3x(28x110) + 12x100");
        assert_eq!(sc.cfg.constellation.shells().len(), 4, "four shells");
        assert!(sc.cfg.data.train_samples >= sc.cfg.n_sats(), "every sat gets a shard");
        assert!(sc.cfg.validate().is_empty(), "{:?}", sc.cfg.validate());
        let reloaded = Scenario::from_toml(&sc.to_toml()).unwrap();
        assert_eq!(reloaded, sc);
    }

    #[test]
    fn every_preset_round_trips_through_toml() {
        for sc in ScenarioRegistry::builtin().iter() {
            let dumped = sc.to_toml();
            let parsed = Scenario::from_toml(&dumped)
                .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
            assert_eq!(&parsed, sc, "{} must round-trip dump→parse→equal", sc.name);
        }
    }

    #[test]
    fn every_preset_builds_a_valid_geometry() {
        for sc in ScenarioRegistry::builtin().iter() {
            let errs = sc.cfg.validate();
            assert!(errs.is_empty(), "{}: {errs:?}", sc.name);
            // shortened horizon: construction paths (multi-shell
            // constellation, contact scan, finite-window assertion) are
            // what this test exercises, not the 3-day plan itself
            let mut cfg = sc.cfg.clone();
            cfg.fl.horizon_s = 2.0 * 3600.0;
            let geo = Geometry::shared(&cfg);
            assert_eq!(geo.constellation.len(), sc.cfg.n_sats(), "{}", sc.name);
            assert_eq!(geo.plan.n_sites(), sc.cfg.placement.sites().len(), "{}", sc.name);
            assert_eq!(Geometry::build_count(&cfg), 1, "{}", sc.name);
        }
    }

    #[test]
    fn equatorial_shell_actually_sees_its_sink() {
        // the preset exists because mid-latitude sites never see an
        // equatorial shell; the Quito HAP must
        let mut cfg = ScenarioRegistry::builtin().get("equatorial-dense").unwrap().cfg.clone();
        cfg.fl.horizon_s = 6.0 * 3600.0;
        let geo = Geometry::shared(&cfg);
        let with_contact = (0..geo.constellation.len())
            .filter(|&s| !geo.plan.windows(0, s).is_empty())
            .count();
        assert!(with_contact > 0, "equatorial ring never visible from Quito HAP");
    }

    #[test]
    fn header_is_optional_and_custom_configs_parse() {
        let sc = Scenario::from_toml("[constellation]\norbits = 3\n").unwrap();
        assert_eq!(sc.name, "custom");
        assert_eq!(sc.cfg.constellation.n_orbits, 3);
        // invalid configs are rejected with the validation message
        let err = Scenario::from_toml("[constellation]\naltitude_km = 50000\n").unwrap_err();
        assert!(err.contains("LEO band"), "{err}");
    }
}
