//! Flat model parameter buffers and the linear algebra the coordinator
//! needs on them.

use crate::util::Rng;

/// A model's parameters: one contiguous f32 vector whose layout is
/// defined by the AOT manifest (python/compile/model.py).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelParams {
    pub data: Vec<f32>,
}

impl ModelParams {
    pub fn zeros(dim: usize) -> Self {
        ModelParams { data: vec![0.0; dim] }
    }

    /// Random init for simulator-only runs / tests (the real runs use
    /// the AOT `init_*` artifact so L2/L3 agree on numerics).
    pub fn random(dim: usize, std: f32, rng: &mut Rng) -> Self {
        ModelParams { data: (0..dim).map(|_| rng.normal(0.0, std as f64) as f32).collect() }
    }

    pub fn dim(&self) -> usize {
        self.data.len()
    }

    /// Euclidean distance ‖self − other‖₂ (pure-Rust fallback of the
    /// `dist_*` artifact; used for grouping in simulator-only mode and
    /// to cross-check the kernel in tests).
    pub fn l2_distance(&self, other: &ModelParams) -> f64 {
        assert_eq!(self.dim(), other.dim());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|a| (*a as f64) * (*a as f64)).sum::<f64>().sqrt()
    }

    /// self += k * other.
    pub fn axpy(&mut self, k: f32, other: &ModelParams) {
        assert_eq!(self.dim(), other.dim());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += k * b;
        }
    }

    /// self *= k.
    pub fn scale(&mut self, k: f32) {
        for a in self.data.iter_mut() {
            *a *= k;
        }
    }

    /// Weighted sum Σ wᵢ·modelsᵢ (pure-Rust fallback of the `agg_*`
    /// artifact — Eq. 14 with coeffs computed by the caller).
    pub fn weighted_sum(models: &[&ModelParams], weights: &[f32]) -> ModelParams {
        assert_eq!(models.len(), weights.len());
        assert!(!models.is_empty());
        let dim = models[0].dim();
        let mut out = ModelParams::zeros(dim);
        for (m, &w) in models.iter().zip(weights) {
            out.axpy(w, m);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_dim() {
        let p = ModelParams::zeros(10);
        assert_eq!(p.dim(), 10);
        assert_eq!(p.l2_norm(), 0.0);
    }

    #[test]
    fn distance_triangle_symmetric() {
        let mut rng = Rng::new(0);
        let a = ModelParams::random(100, 1.0, &mut rng);
        let b = ModelParams::random(100, 1.0, &mut rng);
        let c = ModelParams::random(100, 1.0, &mut rng);
        assert!((a.l2_distance(&b) - b.l2_distance(&a)).abs() < 1e-9);
        assert!(a.l2_distance(&c) <= a.l2_distance(&b) + b.l2_distance(&c) + 1e-9);
        assert_eq!(a.l2_distance(&a), 0.0);
    }

    #[test]
    fn axpy_scale() {
        let mut a = ModelParams { data: vec![1.0, 2.0] };
        let b = ModelParams { data: vec![10.0, 20.0] };
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.data, vec![12.0, 24.0]);
    }

    #[test]
    fn weighted_sum_is_convex_mean_for_uniform() {
        let a = ModelParams { data: vec![1.0, 3.0] };
        let b = ModelParams { data: vec![3.0, 5.0] };
        let m = ModelParams::weighted_sum(&[&a, &b], &[0.5, 0.5]);
        assert_eq!(m.data, vec![2.0, 4.0]);
    }

    #[test]
    fn weighted_sum_identity() {
        let a = ModelParams { data: vec![1.0, 3.0] };
        let b = ModelParams { data: vec![9.0, 9.0] };
        let m = ModelParams::weighted_sum(&[&a, &b], &[1.0, 0.0]);
        assert_eq!(m.data, a.data);
    }

    #[test]
    #[should_panic]
    fn mismatched_dims_panic() {
        let a = ModelParams::zeros(3);
        let b = ModelParams::zeros(4);
        a.l2_distance(&b);
    }
}
