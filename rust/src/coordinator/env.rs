//! The simulation environment handed to every FL strategy.
//!
//! Split across the sweep axis (PR 2): everything immutable across runs
//! lives in a shared [`Geometry`] (`Arc`-cached per unique geometry
//! config, see [`super::geometry`]); everything a single run mutates —
//! the RNG, the accuracy curve, the transfer counter, the fault plan
//! and the compute backend — lives in [`RunState`]. [`SimEnv`] is a
//! thin facade over the two: strategies keep calling the same delay /
//! record methods, and sweep executors can run many `RunState`s against
//! one `Geometry` concurrently.
//!
//! # The run-loop fast path (PR 5) and its bit-identity contract
//!
//! The delay calls ([`SimEnv::site_link_delay`],
//! [`SimEnv::isl_hop_delay`], [`SimEnv::ihl_hop_delay`]) are the
//! event loop's hottest operations — every broadcast, ISL relay sweep
//! and uplink route probes them thousands of times per run. They
//! evaluate through values hoisted once per run/geometry:
//!
//! * site positions come from the [`Geometry`]'s cached per-site
//!   `orbit::SitePropagator`s (latitude trigonometry paid at geometry
//!   build) and satellite positions from the constellation's cached
//!   `orbit::PlaneBasis` values (PR 4) — one `sin`/`cos` pair plus
//!   multiply-adds per position;
//! * the payload size never changes within a run, so the transmission
//!   term `model_bits(dim)/R` and the endpoint-processing term are
//!   computed once at [`RunState`] construction instead of paying a
//!   virtual `backend.dim()` call plus a division per transfer.
//!
//! Contract: the fast path performs *the same arithmetic in the same
//! order* as the original formulas — `(transmission + distance/c) +
//! processing` associates exactly like `DelayBreakdown::total_s`, and
//! the hoisted trigonometry is bitwise-pinned by tests in
//! `orbit::ground` / `orbit::propagation` — so delays, accuracy curves
//! and `results/*.csv` are bit-for-bit unchanged. The pre-cache
//! formulas are kept runnable behind
//! [`SimEnv::set_reference_path`] (per-call `SitePropagator`
//! construction, per-call `backend.dim()`): the executable
//! specification that `tests/runloop_equivalence.rs` pins every preset
//! against and `benches/bench_runloop.rs` measures the speedup with.

use super::contact::ContactPlan;
use super::geometry::Geometry;
use crate::comm::delay::{model_bits, total_delay_s};
use crate::config::ExperimentConfig;
use crate::faults::{FaultPlan, FaultSchedule, FaultStats, LinkClass, NetWorld};
use crate::metrics::{Curve, CurvePoint};
use crate::obs::{ObsReport, RunObs};
use crate::orbit::{GeodeticSite, SiteKind, WalkerConstellation};
use crate::sim::RunOptions;
use crate::train::Backend;
use crate::util::{Rng, SPEED_OF_LIGHT_KM_S};
use std::sync::Arc;

/// Everything one run mutates: seeded randomness, metrics, the fault
/// injection counters and the compute backend.
pub struct RunState<'a> {
    pub backend: &'a mut dyn Backend,
    pub rng: Rng,
    pub curve: Curve,
    /// Count of model transfers (uplink+downlink+relay hops), for the
    /// communication-cost accounting in EXPERIMENTS.md.
    pub transfers: u64,
    /// The fault-injection timeline every link transfer runs through.
    /// Disabled (a guaranteed no-op) unless `cfg.faults` is active.
    pub faults: FaultPlan,
    /// Cached `model_bits(backend.dim())` — the payload is constant
    /// within a run, so the virtual `dim()` call is paid once here,
    /// not per transfer.
    payload_bits: f64,
    /// Cached transmission term `payload_bits / R` (identical operands
    /// to the per-call division, hence identical bits).
    transmission_s: f64,
    /// Cached endpoint-processing term `2 · t_proc`.
    processing_s: f64,
    /// Route delay calls through the pre-cache reference formulas
    /// (see the module docs). Off on every normal run.
    reference_path: bool,
    /// How to run (lane count for intra-run parallelism) — execution
    /// shape only, never results. See `sim::lanes`.
    options: RunOptions,
    /// Observability state (trace sink + metrics registry + phase
    /// timers), `None` unless this run is observed. Strictly
    /// observe-only: every hook draws nothing from the RNG and changes
    /// no arithmetic, so observed runs stay bit-identical to
    /// unobserved ones (`tests/obs_equivalence.rs`).
    pub obs: Option<Box<RunObs>>,
}

/// Everything a strategy needs: geometry, contacts, delays, compute.
pub struct SimEnv<'a> {
    pub cfg: ExperimentConfig,
    /// Shared immutable geometry (constellation, sites, contact plan,
    /// link params). Clone the `Arc` to iterate contact-plan data while
    /// mutating run state.
    pub geo: Arc<Geometry>,
    /// Per-run mutable state.
    pub state: RunState<'a>,
}

impl<'a> SimEnv<'a> {
    /// Build the environment, fetching (or building) the shared
    /// geometry for `cfg` from the process-wide cache.
    pub fn new(cfg: &ExperimentConfig, backend: &'a mut dyn Backend) -> Self {
        let geo = Geometry::shared(cfg);
        Self::with_geometry(cfg, geo, backend)
    }

    /// Build the environment on an explicitly provided geometry (sweep
    /// executors pass a pre-fetched `Arc` here).
    pub fn with_geometry(
        cfg: &ExperimentConfig,
        geo: Arc<Geometry>,
        backend: &'a mut dyn Backend,
    ) -> Self {
        assert_eq!(
            geo.constellation.len(),
            backend.n_sats(),
            "backend shard count must match constellation size"
        );
        // The immutable timeline is fetched from the process-wide
        // schedule cache: schemes of a sweep cell group that share
        // (scenario, intensity, seed, layout) share one schedule and
        // only the per-run counters are fresh. The network axes get the
        // node layout (shells, HAP sites, geometry) for partition
        // scoping and Sun-vector umbra windows; the cache key is
        // normalized so a nominal network config keys exactly like the
        // pre-engine code.
        let shell_of: Vec<usize> =
            (0..geo.constellation.len()).map(|s| geo.constellation.shell_of(s)).collect();
        let hap_site: Vec<bool> = geo.sites.iter().map(|s| s.kind == SiteKind::Hap).collect();
        let mut faults = FaultPlan::from_schedule(FaultSchedule::shared_with_network(
            &cfg.faults,
            &cfg.network,
            cfg.seed,
            &geo.constellation.plane_of(),
            &NetWorld {
                shell_of: &shell_of,
                hap_site: &hap_site,
                constellation: Some(&geo.constellation),
            },
            geo.sites.len(),
            cfg.fl.horizon_s,
        ));
        // run-constant delay terms, hoisted out of the per-transfer path
        let payload_bits = model_bits(backend.dim());
        faults.set_payload_bits(payload_bits as u64);
        let transmission_s = payload_bits / geo.link.data_rate_bps;
        let processing_s = 2.0 * geo.link.processing_delay_s;
        SimEnv {
            cfg: cfg.clone(),
            geo,
            state: RunState {
                backend,
                rng: Rng::new(cfg.seed ^ 0xE5E57),
                curve: Curve::default(),
                transfers: 0,
                faults,
                payload_bits,
                transmission_s,
                processing_s,
                reference_path: false,
                options: RunOptions::default(),
                obs: None,
            },
        }
    }

    /// Set the lane count for intra-run parallelism (default 1 — the
    /// historical single-lane path). Any value is bit-identical to 1 by
    /// the `sim::lanes` merge contract; only wall-clock changes.
    pub fn set_lanes(&mut self, lanes: usize) {
        self.state.options.lanes = lanes.max(1);
    }

    /// Effective lane count for this run. The reference path always
    /// runs single-lane: probe lanes evaluate the *fast-path* base
    /// formulas, so the executable specification keeps its own serial
    /// call sequence. Active bandwidth queueing also forces one lane —
    /// queue waits depend on commit order, the one impairment axis the
    /// pure probe oracle cannot replay.
    pub fn lanes(&self) -> usize {
        if self.state.reference_path || self.state.faults.queueing_active() {
            1
        } else {
            self.state.options.lanes.max(1)
        }
    }

    /// Attach observability state to this run (trace sink + metrics +
    /// phase timers). Observation is strictly observe-only — see the
    /// `obs` module docs for the bit-identity contract.
    pub fn enable_obs(&mut self, obs: RunObs) {
        self.state.obs = Some(Box::new(obs));
    }

    /// The run's observability state, if observed. Strategies emit
    /// through this (`if let Some(obs) = env.obs() { ... }` — one
    /// branch when observation is off).
    #[inline]
    pub fn obs(&mut self) -> Option<&mut RunObs> {
        self.state.obs.as_deref_mut()
    }

    /// Detach the observability state (flush/inspect the sink after
    /// the strategy returned).
    pub fn take_obs(&mut self) -> Option<Box<RunObs>> {
        self.state.obs.take()
    }

    /// Start a per-run phase timer — `None` (and free) when the run is
    /// not observed. Close with [`SimEnv::phase_end`].
    #[inline]
    pub fn phase_start(&self) -> Option<std::time::Instant> {
        if self.state.obs.is_some() {
            Some(std::time::Instant::now())
        } else {
            None
        }
    }

    /// Charge the elapsed time since `phase_start` to `name`.
    #[inline]
    pub fn phase_end(&mut self, name: &'static str, t0: Option<std::time::Instant>) {
        if let (Some(t0), Some(obs)) = (t0, self.state.obs.as_deref_mut()) {
            obs.phases.add(name, t0.elapsed().as_secs_f64());
        }
    }

    /// Facade accessors over the shared geometry.
    pub fn constellation(&self) -> &WalkerConstellation {
        &self.geo.constellation
    }

    pub fn sites(&self) -> &[GeodeticSite] {
        &self.geo.sites
    }

    pub fn plan(&self) -> &ContactPlan {
        &self.geo.plan
    }

    /// Model payload size in bits for the current model dimension
    /// (cached at construction — the payload is run-constant).
    pub fn payload_bits(&self) -> f64 {
        self.state.payload_bits
    }

    /// Route every delay call through the kept pre-cache formulas
    /// (per-call site-trig derivation, per-call virtual
    /// `backend.dim()`): the executable specification the
    /// run-equivalence suite pins the fast path against, and the
    /// "before" side of `BENCH_runloop.json`. Never enabled on normal
    /// runs.
    pub fn set_reference_path(&mut self, on: bool) {
        self.state.reference_path = on;
    }

    /// Base (fault-free) delay of one transfer over `d_km`: the cached
    /// run-constant terms + the per-call propagation division,
    /// associating exactly like `DelayBreakdown::total_s` —
    /// `(transmission + propagation) + processing`.
    #[inline]
    fn base_delay_s(&self, d_km: f64) -> f64 {
        (self.state.transmission_s + d_km / SPEED_OF_LIGHT_KM_S) + self.state.processing_s
    }

    /// SAT↔site transfer delay at time `t` (Eq. 7), fault-adjusted.
    pub fn site_link_delay(&mut self, site: usize, sat: usize, t: f64) -> f64 {
        self.state.transfers += 1;
        let base = if self.state.reference_path {
            let d = self.geo.sites[site]
                .position_eci(t)
                .distance(self.geo.constellation.position(sat, t));
            total_delay_s(&self.geo.link, model_bits(self.state.backend.dim()), d)
        } else {
            let d = self
                .geo
                .site_prop(site)
                .position_at(t)
                .distance(self.geo.constellation.position(sat, t));
            self.base_delay_s(d)
        };
        self.apply_faults(LinkClass::SatSite { sat, site }, t, base)
    }

    /// Intra-orbit ISL hop delay between ring neighbours at time `t`,
    /// fault-adjusted.
    pub fn isl_hop_delay(&mut self, sat_a: usize, sat_b: usize, t: f64) -> f64 {
        self.state.transfers += 1;
        let d = self
            .geo
            .constellation
            .position(sat_a, t)
            .distance(self.geo.constellation.position(sat_b, t));
        let base = if self.state.reference_path {
            total_delay_s(&self.geo.link, model_bits(self.state.backend.dim()), d)
        } else {
            self.base_delay_s(d)
        };
        self.apply_faults(LinkClass::Isl { sat_a, sat_b }, t, base)
    }

    /// One-hop transfer delay over typed ISL graph edge `e` at time
    /// `t`: the Doppler-derated, per-shell-budget base delay from
    /// [`crate::topology::IslGraph::edge_delay_s`], fault-adjusted
    /// (endpoint churn, orbit outages and the typed per-edge outage
    /// windows all participate). The hop primitive of graph-routed
    /// schemes (`fl::baselines::sinksat`).
    pub fn graph_edge_delay(&mut self, e: usize, t: f64) -> f64 {
        self.state.transfers += 1;
        let edge = self.geo.isl.edges()[e];
        let base =
            self.geo
                .isl
                .edge_delay_s(&self.geo.constellation, e, t, self.state.payload_bits);
        self.apply_faults(
            LinkClass::Isl { sat_a: edge.a as usize, sat_b: edge.b as usize },
            t,
            base,
        )
    }

    /// HAP↔HAP (IHL) hop delay at time `t`, fault-adjusted.
    pub fn ihl_hop_delay(&mut self, site_a: usize, site_b: usize, t: f64) -> f64 {
        self.state.transfers += 1;
        let base = if self.state.reference_path {
            let d = self.geo.sites[site_a]
                .position_eci(t)
                .distance(self.geo.sites[site_b].position_eci(t));
            total_delay_s(&self.geo.link, model_bits(self.state.backend.dim()), d)
        } else {
            let d = self
                .geo
                .site_prop(site_a)
                .position_at(t)
                .distance(self.geo.site_prop(site_b).position_at(t));
            self.base_delay_s(d)
        };
        self.apply_faults(LinkClass::Ihl { site_a, site_b }, t, base)
    }

    /// Route one transfer through the fault oracle. With faults
    /// disabled this returns `base` untouched and draws nothing, so
    /// clean runs stay bit-identical to the pre-faults code path.
    ///
    /// The unobserved branch is the exact historical code path; the
    /// observed branch performs the same arithmetic in the same order
    /// and only *reads* the outcome (one `model_tx` record per call —
    /// aligned 1:1 with the `transfers` accounting — plus `fault_hit`
    /// records derived from the stats deltas), so observed and
    /// unobserved runs return bit-identical delays.
    fn apply_faults(&mut self, class: LinkClass, t: f64, base: f64) -> f64 {
        if self.state.obs.is_none() {
            if !self.state.faults.enabled() {
                return base;
            }
            let out = self.state.faults.transfer(class, t, base);
            // every retransmission re-sends the payload: communication
            // cost — counted once per channel event, not per probe of it
            if out.newly_observed {
                self.state.transfers += out.retransmits as u64;
            }
            return out.delay_s;
        }
        let (delay, counted_retransmits) = if self.state.faults.enabled() {
            let before = self.state.faults.stats();
            let out = self.state.faults.transfer(class, t, base);
            if out.newly_observed {
                self.state.transfers += out.retransmits as u64;
            }
            let after = self.state.faults.stats();
            let obs = self.state.obs.as_deref_mut().unwrap();
            if after.retransmits > before.retransmits {
                obs.fault_hit(t, "loss", after.retransmits - before.retransmits);
            }
            if after.deferrals > before.deferrals {
                obs.fault_hit(t, "defer", after.deferrals - before.deferrals);
            }
            if after.queued_s > before.queued_s {
                obs.fault_hit(t, "queue", 1);
            }
            if after.queue_drops > before.queue_drops {
                obs.fault_hit(t, "queue_drop", after.queue_drops - before.queue_drops);
            }
            if after.partition_hits > before.partition_hits {
                obs.fault_hit(t, "partition", after.partition_hits - before.partition_hits);
            }
            if after.reorders > before.reorders {
                obs.fault_hit(t, "reorder", after.reorders - before.reorders);
            }
            if after.eclipse_blocked > before.eclipse_blocked {
                obs.fault_hit(t, "eclipse", after.eclipse_blocked - before.eclipse_blocked);
            }
            if after.retry_drops > before.retry_drops {
                obs.fault_hit(t, "retry_drop", after.retry_drops - before.retry_drops);
            }
            (
                out.delay_s,
                if out.newly_observed { out.retransmits } else { 0 },
            )
        } else {
            (base, 0)
        };
        let payload_bits = self.state.payload_bits;
        let obs = self.state.obs.as_deref_mut().unwrap();
        obs.model_tx(t, &class, base, delay, counted_retransmits, payload_bits);
        delay
    }

    /// Replay one probe-recorded transfer against the run's mutable
    /// state: counts the transfer and routes it through the exact
    /// serial fault/observability path (`apply_faults`), so the
    /// returned delay, stats, `seen`-set evolution and trace records
    /// are bit-identical to the env having made the original delay
    /// call itself. The delay is deterministic in `(class, t, base)`,
    /// so it also equals what the probe lane computed.
    pub fn replay_tx(&mut self, a: &TxAction) -> f64 {
        self.state.transfers += 1;
        self.apply_faults(a.class, a.t, a.base)
    }

    /// A handle for probe lanes: the shared immutable inputs of the
    /// fast-path delay calls (geometry, fault schedule, run-constant
    /// delay terms), detached from the mutable `RunState` so worker
    /// threads can evaluate delays concurrently. See [`LaneProbe`].
    pub fn lane_probe(&self) -> LaneProbe {
        debug_assert!(
            !self.state.reference_path,
            "probe lanes evaluate fast-path formulas only"
        );
        LaneProbe {
            geo: self.geo.clone(),
            schedule: self.state.faults.schedule().clone(),
            payload_bits: self.state.payload_bits,
            transmission_s: self.state.transmission_s,
            processing_s: self.state.processing_s,
        }
    }

    /// Record an evaluation point on the run curve.
    pub fn record(&mut self, t: f64, epoch: u64, accuracy: f64, loss: f64) {
        self.state.curve.push(CurvePoint { time_s: t, epoch, accuracy, loss });
        if let Some(obs) = self.state.obs.as_deref_mut() {
            obs.eval(t, epoch, accuracy, loss);
        }
    }

    /// On-board training wall time per visit (the compute-time model:
    /// the paper's I=100 local epochs of on-board compute).
    pub fn train_time_s(&self) -> f64 {
        self.cfg.fl.train_time_s
    }
}

/// One transfer a probe lane computed and the serial loop must still
/// *account for*: the inputs of a delay call, not its outcome. Replay
/// ([`SimEnv::replay_tx`]) re-runs the serial fault/obs path on these
/// inputs — the delay is a pure function of them, so replay reproduces
/// the probe's answer while mutating `transfers`/stats/trace exactly as
/// the historical single-lane code would have.
#[derive(Clone, Copy, Debug)]
pub struct TxAction {
    pub class: LinkClass,
    /// Send instant.
    pub t: f64,
    /// Clean (fault-free) fast-path delay.
    pub base: f64,
}

/// The immutable inputs of the fast-path delay calls, cloneable into
/// probe lanes (`Arc`s + three `f64`s): worker threads compute
/// `(delay, TxAction)` pairs concurrently with **zero** access to
/// `RunState`, and the serial loop replays the actions in merged order.
/// The probe's delay equals the replay's delay bit for bit because both
/// evaluate the same pure functions — cached kinematics for the base,
/// [`FaultSchedule::channel_outcome`] for the impairment (the per-run
/// `seen` set affects only accounting, never delays).
#[derive(Clone)]
pub struct LaneProbe {
    geo: Arc<Geometry>,
    schedule: Arc<FaultSchedule>,
    payload_bits: f64,
    transmission_s: f64,
    processing_s: f64,
}

impl LaneProbe {
    /// The shared geometry (contact plan, constellation, ISL graph) —
    /// lanes read visibility and routing through this.
    pub fn geo(&self) -> &Geometry {
        &self.geo
    }

    /// The immutable fault timeline (for liveness queries on lanes).
    pub fn schedule(&self) -> &FaultSchedule {
        &self.schedule
    }

    #[inline]
    fn base_delay_s(&self, d_km: f64) -> f64 {
        (self.transmission_s + d_km / SPEED_OF_LIGHT_KM_S) + self.processing_s
    }

    /// Fault-adjusted delay for `action` — the pure half of
    /// `SimEnv::apply_faults` (identical arithmetic, no accounting).
    /// Matches the serial delay bit for bit because the only stateful
    /// delay term — the FIFO queue wait — forces single-lane runs
    /// (`SimEnv::lanes`), so probes never race it.
    #[inline]
    fn channel_delay(&self, action: &TxAction) -> f64 {
        if !self.schedule.enabled() {
            return action.base;
        }
        self.schedule.channel_outcome(&action.class, action.t, action.base).delay_s
    }

    /// Probe-side twin of [`SimEnv::site_link_delay`] (fast path).
    pub fn site_link_delay(&self, site: usize, sat: usize, t: f64) -> (f64, TxAction) {
        let d = self
            .geo
            .site_prop(site)
            .position_at(t)
            .distance(self.geo.constellation.position(sat, t));
        let action =
            TxAction { class: LinkClass::SatSite { sat, site }, t, base: self.base_delay_s(d) };
        (self.channel_delay(&action), action)
    }

    /// Probe-side twin of [`SimEnv::isl_hop_delay`] (fast path).
    pub fn isl_hop_delay(&self, sat_a: usize, sat_b: usize, t: f64) -> (f64, TxAction) {
        let d = self
            .geo
            .constellation
            .position(sat_a, t)
            .distance(self.geo.constellation.position(sat_b, t));
        let action =
            TxAction { class: LinkClass::Isl { sat_a, sat_b }, t, base: self.base_delay_s(d) };
        (self.channel_delay(&action), action)
    }

    /// Probe-side twin of [`SimEnv::graph_edge_delay`].
    pub fn graph_edge_delay(&self, e: usize, t: f64) -> (f64, TxAction) {
        let edge = self.geo.isl.edges()[e];
        let base = self.geo.isl.edge_delay_s(&self.geo.constellation, e, t, self.payload_bits);
        let action = TxAction {
            class: LinkClass::Isl { sat_a: edge.a as usize, sat_b: edge.b as usize },
            t,
            base,
        };
        (self.channel_delay(&action), action)
    }
}

/// Outcome of one strategy run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub scheme: &'static str,
    pub curve: Curve,
    /// (convergence time s, plateau accuracy) per Curve::convergence.
    pub converged: Option<(f64, f64)>,
    pub final_accuracy: f64,
    pub epochs: u64,
    pub transfers: u64,
    /// Fault-injection accounting (all zero on clean runs).
    pub fault_stats: FaultStats,
    /// Observability snapshot (metrics, link loads, phase times) when
    /// the run was observed, `None` otherwise. Boxed: the report is
    /// cold data and most runs never carry one.
    pub obs: Option<Box<ObsReport>>,
}

impl RunResult {
    /// Summarize a finished run, *taking* the curve out of the env
    /// (the run's largest artifact is moved, not cloned — the env is
    /// done producing points once its strategy returns).
    pub fn from_env(scheme: &'static str, env: &mut SimEnv, epochs: u64) -> Self {
        let converged = env.state.curve.convergence(0.005, 3);
        let final_accuracy = env.state.curve.final_accuracy().unwrap_or(0.0);
        RunResult {
            scheme,
            converged,
            final_accuracy,
            curve: std::mem::take(&mut env.state.curve),
            epochs,
            transfers: env.state.transfers,
            fault_stats: env.state.faults.stats(),
            // snapshot (not take): the sink stays on the env so the
            // caller can still flush / inspect the trace afterwards
            obs: env.state.obs.as_ref().map(|o| Box::new(o.report())),
        }
    }

    /// Convergence time in simulated hours (horizon if never converged).
    pub fn convergence_hours(&self) -> f64 {
        self.converged.map(|(t, _)| t / 3600.0).unwrap_or(f64::INFINITY)
    }

    /// Earliest simulated time (seconds) the accuracy curve reaches
    /// `target` — a stopping-rule-independent speed metric for
    /// cross-scheme comparisons.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.curve.points.iter().find(|p| p.accuracy >= target).map(|p| p.time_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::train::SurrogateBackend;

    fn small_env(backend: &mut SurrogateBackend) -> SimEnv<'_> {
        let mut cfg = ExperimentConfig::test_small();
        cfg.fl.horizon_s = 3600.0 * 12.0;
        SimEnv::new(&cfg, backend)
    }

    #[test]
    fn env_builds_and_delays_positive() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(
            cfg.constellation.n_orbits,
            cfg.constellation.sats_per_orbit,
            true,
            100,
        );
        let mut env = small_env(&mut b);
        let d = env.site_link_delay(0, 0, 1000.0);
        assert!(d > 0.0 && d < 10.0, "delay {d}");
        let d2 = env.isl_hop_delay(0, 1, 1000.0);
        assert!(d2 > 0.0 && d2 < 10.0);
        assert_eq!(env.state.transfers, 2);
    }

    #[test]
    fn envs_with_identical_geometry_share_one_instance() {
        let mut cfg = ExperimentConfig::test_small();
        cfg.fl.horizon_s = 3600.0 * 12.0;
        let mut cfg2 = cfg.clone();
        cfg2.seed = 1234; // non-geometry knob: same shared geometry
        let mut b1 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut b2 = SurrogateBackend::paper_split(2, 3, false, 100);
        let env1 = SimEnv::new(&cfg, &mut b1);
        let env2 = SimEnv::new(&cfg2, &mut b2);
        assert!(Arc::ptr_eq(&env1.geo, &env2.geo));
    }

    #[test]
    fn facade_accessors_project_geometry() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(2, 3, true, 100);
        let env = small_env(&mut b);
        assert_eq!(env.constellation().len(), cfg.n_sats());
        assert_eq!(env.sites().len(), cfg.placement.sites().len());
        assert_eq!(env.plan().n_sites(), env.sites().len());
    }

    #[test]
    #[should_panic]
    fn backend_size_mismatch_panics() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(5, 8, true, 100); // 40 != 6
        SimEnv::new(&cfg, &mut b);
    }

    #[test]
    fn nominal_config_disables_faults() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(
            cfg.constellation.n_orbits,
            cfg.constellation.sats_per_orbit,
            true,
            100,
        );
        let env = small_env(&mut b);
        assert!(
            !env.state.faults.enabled(),
            "nominal faults must stay out of the hot path"
        );
        assert_eq!(env.state.faults.stats(), crate::faults::FaultStats::default());
    }

    #[test]
    fn faulty_env_delays_never_below_clean() {
        use crate::faults::{FaultConfig, FaultScenario};
        let mut cfg = ExperimentConfig::test_small();
        cfg.fl.horizon_s = 3600.0 * 12.0;
        let mut cfg_faulty = cfg.clone();
        cfg_faulty.faults = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        let mut b1 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut clean = SimEnv::new(&cfg, &mut b1);
        let mut b2 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut faulty = SimEnv::new(&cfg_faulty, &mut b2);
        for i in 0..50 {
            let t = 100.0 * i as f64;
            let dc = clean.site_link_delay(0, 0, t);
            let df = faulty.site_link_delay(0, 0, t);
            assert!(df >= dc - 1e-12, "fault delay {df} below clean {dc}");
        }
        assert!(
            faulty.state.faults.stats().retransmits > 0,
            "30% loss over 50 sends"
        );
        assert!(
            faulty.state.transfers > clean.state.transfers,
            "retransmissions must show up in the communication cost"
        );
    }

    #[test]
    fn schemes_share_one_fault_schedule() {
        use crate::faults::{FaultConfig, FaultScenario};
        let mut cfg = ExperimentConfig::test_small();
        cfg.fl.horizon_s = 3600.0 * 12.0;
        cfg.faults = FaultConfig::preset(FaultScenario::Churn, 0.65);
        let mut cfg2 = cfg.clone();
        cfg2.fl.scheme = crate::config::SchemeKind::FedHap; // non-layout knob
        let mut b1 = SurrogateBackend::paper_split(2, 3, true, 100);
        let env1 = SimEnv::new(&cfg, &mut b1);
        let mut b2 = SurrogateBackend::paper_split(2, 3, true, 100);
        let env2 = SimEnv::new(&cfg2, &mut b2);
        assert!(
            Arc::ptr_eq(env1.state.faults.schedule(), env2.state.faults.schedule()),
            "same (faults, seed, layout, horizon) must share one schedule"
        );
        assert_eq!(
            crate::faults::FaultSchedule::shared_build_count(
                &cfg.faults,
                cfg.seed,
                &env1.geo.constellation.plane_of(),
                env1.geo.sites.len(),
                cfg.fl.horizon_s,
            ),
            1,
            "schedule built exactly once for the shared key"
        );
    }

    #[test]
    fn record_builds_curve() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(
            cfg.constellation.n_orbits,
            cfg.constellation.sats_per_orbit,
            true,
            100,
        );
        let mut env = small_env(&mut b);
        env.record(0.0, 0, 0.1, 2.3);
        env.record(100.0, 1, 0.5, 1.0);
        let r = RunResult::from_env("test", &mut env, 2);
        assert_eq!(r.final_accuracy, 0.5);
        assert_eq!(r.epochs, 2);
        assert_eq!(r.curve.points.len(), 2);
        // the curve moved out of the env instead of being cloned
        assert!(env.state.curve.points.is_empty());
    }

    #[test]
    fn lanes_default_to_one_and_reference_path_forces_one() {
        let mut b = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut env = small_env(&mut b);
        assert_eq!(env.lanes(), 1);
        env.set_lanes(4);
        assert_eq!(env.lanes(), 4);
        env.set_lanes(0);
        assert_eq!(env.lanes(), 1, "lane count clamps to >= 1");
        env.set_lanes(4);
        env.set_reference_path(true);
        assert_eq!(env.lanes(), 1, "the executable spec stays serial");
    }

    #[test]
    fn lane_probe_and_replay_match_env_delays_bitwise() {
        use crate::faults::{FaultConfig, FaultScenario};
        // a faulty config so the channel oracle participates in probes
        let mut cfg = ExperimentConfig::test_small();
        cfg.placement = crate::config::PsPlacement::TwoHaps;
        cfg.fl.horizon_s = 3600.0 * 12.0;
        cfg.faults = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        let mut b1 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut serial = SimEnv::new(&cfg, &mut b1);
        let mut b2 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut replayed = SimEnv::new(&cfg, &mut b2);
        let probe = replayed.lane_probe();
        for i in 0..200 {
            let t = 83.5 * i as f64;
            let a = serial.site_link_delay(i % 2, i % 6, t);
            let (p, act) = probe.site_link_delay(i % 2, i % 6, t);
            assert_eq!(a.to_bits(), p.to_bits(), "probe delay at t={t}");
            assert_eq!(a.to_bits(), replayed.replay_tx(&act).to_bits(), "replay at t={t}");
            let a = serial.isl_hop_delay(i % 6, (i + 1) % 6, t);
            let (p, act) = probe.isl_hop_delay(i % 6, (i + 1) % 6, t);
            assert_eq!(a.to_bits(), p.to_bits());
            assert_eq!(a.to_bits(), replayed.replay_tx(&act).to_bits());
        }
        assert_eq!(serial.state.transfers, replayed.state.transfers);
        assert_eq!(serial.state.faults.stats(), replayed.state.faults.stats());
    }

    #[test]
    fn reference_path_delays_match_fast_path_bitwise() {
        let mut cfg = ExperimentConfig::test_small();
        cfg.placement = crate::config::PsPlacement::TwoHaps;
        cfg.fl.horizon_s = 3600.0 * 12.0;
        let mut b1 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut fast = SimEnv::new(&cfg, &mut b1);
        let mut b2 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut slow = SimEnv::new(&cfg, &mut b2);
        slow.set_reference_path(true);
        for i in 0..200 {
            let t = 83.5 * i as f64;
            let a = fast.site_link_delay(i % 2, i % 6, t);
            let b = slow.site_link_delay(i % 2, i % 6, t);
            assert_eq!(a.to_bits(), b.to_bits(), "site delay at t={t}");
            let a = fast.isl_hop_delay(i % 6, (i + 1) % 6, t);
            let b = slow.isl_hop_delay(i % 6, (i + 1) % 6, t);
            assert_eq!(a.to_bits(), b.to_bits(), "isl delay at t={t}");
            let a = fast.ihl_hop_delay(0, 1, t);
            let b = slow.ihl_hop_delay(0, 1, t);
            assert_eq!(a.to_bits(), b.to_bits(), "ihl delay at t={t}");
        }
        assert_eq!(fast.state.transfers, slow.state.transfers);
    }
}
