"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has an entry here with the *same signature*;
pytest (python/tests/) asserts allclose between kernel and oracle across a
hypothesis sweep of shapes and dtypes. The oracles are also what the L2
model would use if the Pallas path were disabled, so they double as the
semantic spec.
"""

import jax.numpy as jnp


def fused_linear_ref(x, w, b, activation="relu"):
    """o = act(x @ w + b).

    x: [M, K] float, w: [K, N], b: [N].
    activation: "relu" | "none".
    """
    o = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        o = jnp.maximum(o, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return o.astype(x.dtype)


def aggregate_ref(models_ext, coeffs):
    """Staleness-discounted model aggregation (paper Eq. 14).

    models_ext: [N+1, D] — row 0 is the previous global model w^beta,
        rows 1..N are the selected local models.
    coeffs: [N+1] — coeffs[0] = (1 - gamma), coeffs[1:] = per-model
        discounted weights gamma_n (zero for excluded models).
    Returns [D]: sum_n coeffs[n] * models_ext[n].
    """
    return jnp.einsum("n,nd->d", coeffs, models_ext).astype(models_ext.dtype)


def distance_ref(models, ref):
    """Weight divergence used for satellite grouping (paper Sec. IV-C1).

    models: [N, D] local (or orbit-partial) models, ref: [D] the initial
    global model w^0. Returns [N] Euclidean distances ||w_n - w^0||_2.
    """
    diff = models - ref[None, :]
    return jnp.sqrt(jnp.sum(diff * diff, axis=1)).astype(models.dtype)
