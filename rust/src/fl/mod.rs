//! Federated-learning strategies: AsyncFLEO (the paper's contribution,
//! Sec. IV), the five baselines it is evaluated against (Sec. V), and
//! the authors' follow-up sink-satellite scheme
//! (`baselines::sinksat`, arXiv 2302.13447).
//!
//! Every strategy implements [`Strategy`] and runs against a
//! [`SimEnv`]: geometry and link delays drive the *simulated clock*
//! (the paper's convergence-time axis) while all model compute goes
//! through the env's [`crate::train::Backend`] (AOT JAX/Pallas
//! artifacts in real runs).

pub mod aggregation;
pub mod asyncfleo;
pub mod baselines;
pub mod grouping;
pub mod propagation;

use crate::config::SchemeKind;
use crate::coordinator::{RunResult, SimEnv};

/// A runnable FL scheme.
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn run(&mut self, env: &mut SimEnv) -> RunResult;
}

/// Instantiate the strategy for a scheme.
pub fn make_strategy(kind: SchemeKind) -> Box<dyn Strategy> {
    match kind {
        SchemeKind::AsyncFleo => Box::new(asyncfleo::AsyncFleo::default()),
        SchemeKind::FedAvg => Box::new(baselines::fedavg::FedAvg),
        SchemeKind::FedIsl => Box::new(baselines::fedisl::FedIsl),
        SchemeKind::FedIslIdeal => Box::new(baselines::fedisl::FedIsl),
        SchemeKind::FedSat => Box::new(baselines::fedsat::FedSat::default()),
        SchemeKind::FedSpace => Box::new(baselines::fedspace::FedSpace::default()),
        SchemeKind::FedHap => Box::new(baselines::fedhap::FedHap),
        SchemeKind::SinkSat => Box::new(baselines::sinksat::SinkSat),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_all_schemes() {
        for kind in [
            SchemeKind::AsyncFleo,
            SchemeKind::FedAvg,
            SchemeKind::FedIsl,
            SchemeKind::FedIslIdeal,
            SchemeKind::FedSat,
            SchemeKind::FedSpace,
            SchemeKind::FedHap,
            SchemeKind::SinkSat,
        ] {
            let s = make_strategy(kind);
            assert!(!s.name().is_empty());
        }
    }
}
