//! Sink-satellite scheduling (the AsyncFLEO authors' follow-up,
//! arXiv 2302.13447): per-plane intra-plane model propagation with one
//! *sink satellite* per orbital plane.
//!
//! Each plane runs its own pipelined round: every live member trains
//! from the current global model, the plane's models are collected at
//! the sink over the ISL graph (shortest-delay routes on the plane
//! ring, Doppler-derated per-shell budgets — `topology::IslGraph`), and
//! the sink uploads the plane aggregate at its next PS visibility. The
//! sink is *scheduled*: the round picks the live member whose next PS
//! contact after training is earliest, so the collected aggregate waits
//! the least before reaching the parameter server. The PS applies an
//! immediate asynchronous update `w ← (1-α)·w + α·w_plane`, the sink
//! downloads the fresh global and the plane starts over — planes never
//! wait for each other, which is where the scheme's delay win over
//! synchronous ISL baselines comes from.
//!
//! Faults are consumed as typed events: dark members skip the round's
//! pass, a plane with no live members retries later, a failed PS site
//! at contact time pushes the upload to the next live visibility, and
//! every collection hop runs through the per-edge fault oracle
//! (including the typed per-ISL-edge outage windows). All guards are
//! provably inert when faults are disabled.

use crate::coordinator::{RunResult, SimEnv, TxAction};
use crate::fl::Strategy;
use crate::metrics::ConvergenceDetector;
use crate::model::ModelParams;

/// Mixing rate of one asynchronous plane update (scaled by the plane's
/// relative data share, clipped for stability — the `fedsat` rule
/// lifted from satellites to planes).
const BASE_ALPHA: f64 = 0.12;
/// Evaluate the global model every this many async plane updates.
const EVAL_EVERY: usize = 10;
/// Retry delay when a plane has no live member at a round start.
const DEAD_PLANE_RETRY_S: f64 = 600.0;
/// Retry delay past a failed PS site's contact, and the cap on upload
/// retries per round (bounded so a round always terminates).
const SITE_RETRY_S: f64 = 300.0;
const MAX_UPLOAD_TRIES: usize = 8;

#[derive(Default)]
pub struct SinkSat;

impl Strategy for SinkSat {
    fn name(&self) -> &'static str {
        "sinksat"
    }

    fn run(&mut self, env: &mut SimEnv) -> RunResult {
        let geo = env.geo.clone();
        let c = &geo.constellation;
        let n_planes = c.n_orbits;
        let dispatches = env.cfg.fl.local_dispatches;
        let train_time = env.cfg.fl.train_time_s;
        let horizon = env.cfg.fl.horizon_s;
        let payload = env.payload_bits();
        let mut detector = ConvergenceDetector::new(8, 0.003);

        let mut global = env.state.backend.init_global(env.cfg.seed as i32);
        let e0 = env.state.backend.evaluate(&global);
        env.record(0.0, 0, e0.accuracy, e0.loss);

        let total_shard: f64 =
            (0..c.len()).map(|s| env.state.backend.shard_size(s) as f64).sum();
        let mean_plane_shard = total_shard / n_planes.max(1) as f64;

        // reused round buffers: one local slot per largest-plane member,
        // plus the plane-aggregate / global double buffers (in-place
        // backend API — no per-round allocation of model storage)
        let max_plane = (0..n_planes).map(|p| c.orbit_members(p).len()).max().unwrap_or(0);
        let mut locals: Vec<ModelParams> =
            (0..max_plane).map(|_| ModelParams { data: Vec::new() }).collect();
        let mut plane_model = ModelParams { data: Vec::new() };
        let mut next = ModelParams { data: Vec::with_capacity(global.dim()) };

        // multi-lane runs pre-walk the collection hop chains as pure
        // probes on lane threads; the replay below keeps the serial
        // call order (see `sim::lanes`)
        let lane_probe = if env.lanes() > 1 { Some(env.lane_probe()) } else { None };

        // per-plane pipeline clock: when the plane's sink holds the
        // global model and the next round may begin
        let mut next_start = vec![0.0f64; n_planes];
        let mut updates: u64 = 0;
        let mut converged = false;
        let mut last_t = 0.0f64;

        let ph_loop = env.phase_start();
        loop {
            // earliest-starting plane next; ties break toward the lower
            // plane index (strict less keeps the first minimum)
            let mut p_best: Option<usize> = None;
            for p in 0..n_planes {
                let better = match p_best {
                    None => next_start[p].is_finite(),
                    Some(bp) => next_start[p] < next_start[bp],
                };
                if better {
                    p_best = Some(p);
                }
            }
            let Some(p) = p_best else { break };
            let t0 = next_start[p];
            if t0 > horizon || converged {
                break;
            }

            // typed churn: a dark member's pass simply doesn't happen;
            // an empty plane retries later (always all-live when faults
            // are disabled)
            let alive: Vec<usize> =
                c.orbit_members(p).filter(|&m| env.state.faults.sat_alive(m, t0)).collect();
            if alive.is_empty() {
                next_start[p] = t0 + DEAD_PLANE_RETRY_S;
                continue;
            }

            // sink scheduling: the live member whose next PS contact
            // after training is earliest (ties: lower id, because the
            // ascending scan only replaces on strictly-earlier)
            let t_train = t0 + train_time;
            let mut sink: Option<(f64, usize)> = None;
            for &m in &alive {
                if let Some((tv, _)) = geo.plan.next_visible_any(m, t_train) {
                    if sink.map_or(true, |(bt, _)| tv < bt) {
                        sink = Some((tv, m));
                    }
                }
            }
            let Some((_, sink)) = sink else {
                next_start[p] = f64::INFINITY; // plane never sees a PS again
                continue;
            };

            // members train from the current global, then the models
            // ride the ISL graph to the sink (one Dijkstra snapshot per
            // round; per-hop delays through the edge fault oracle)
            let routes = geo.isl.shortest_delays(c, sink, t_train, payload);
            // multi-lane: pre-walk every member's hop chain in parallel
            // as pure probes (the Dijkstra snapshot and the fault oracle
            // are immutable); the train loop below replays each chain in
            // the serial member order, so counters, stats and obs lines
            // are bit-identical to the single-lane walk
            let chains: Option<Vec<Vec<(usize, usize, TxAction)>>> =
                lane_probe.as_ref().map(|pr| {
                    let lanes = env.lanes();
                    let chunk = ((alive.len() + lanes - 1) / lanes).max(1);
                    let routes_ref = &routes;
                    std::thread::scope(|scope| {
                        let handles: Vec<_> = alive
                            .chunks(chunk)
                            .map(|ch| {
                                scope.spawn(move || {
                                    ch.iter()
                                        .map(|&m| {
                                            if m == sink {
                                                return Vec::new();
                                            }
                                            let Some(path) = routes_ref.path_to(m) else {
                                                return Vec::new();
                                            };
                                            let mut arr = t_train;
                                            let mut chain = Vec::new();
                                            for w in path.windows(2).rev() {
                                                let e = pr
                                                    .geo()
                                                    .isl
                                                    .edge_between(w[0], w[1])
                                                    .expect("route uses graph edges");
                                                let (d, act) = pr.graph_edge_delay(e, arr);
                                                chain.push((w[0], w[1], act));
                                                arr += d;
                                            }
                                            chain
                                        })
                                        .collect::<Vec<_>>()
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .flat_map(|h| h.join().expect("collection probe lane panicked"))
                            .collect()
                    })
                });
            let mut t_collect = t_train;
            let mut shards: Vec<f64> = Vec::with_capacity(alive.len());
            for (i, &m) in alive.iter().enumerate() {
                env.state.backend.train_local_into(m, &global, dispatches, &mut locals[i]);
                shards.push(env.state.backend.shard_size(m) as f64);
                if m == sink {
                    continue;
                }
                if let Some(chains) = chains.as_ref() {
                    let mut arr = t_train;
                    for (a, b, act) in &chains[i] {
                        let d = env.replay_tx(act);
                        if let Some(obs) = env.obs() {
                            obs.relay_hop(arr, "isl_route", *a, *b, d);
                        }
                        arr += d;
                    }
                    t_collect = t_collect.max(arr);
                    continue;
                }
                let Some(path) = routes.path_to(m) else { continue };
                // walk the sink→m path backwards: the hop sequence the
                // member's model takes toward the sink
                let mut arr = t_train;
                for w in path.windows(2).rev() {
                    let e = geo.isl.edge_between(w[0], w[1]).expect("route uses graph edges");
                    let d = env.graph_edge_delay(e, arr);
                    if let Some(obs) = env.obs() {
                        obs.relay_hop(arr, "isl_route", w[0], w[1], d);
                    }
                    arr += d;
                }
                t_collect = t_collect.max(arr);
            }

            // plane aggregate: FedAvg over the collected members
            let plane_shard: f64 = shards.iter().sum();
            let wts: Vec<f32> = shards.iter().map(|&s| (s / plane_shard) as f32).collect();
            let refs: Vec<&ModelParams> = locals[..alive.len()].iter().collect();
            env.state.backend.aggregate_into(&global, &refs, &wts, 0.0, &mut plane_model);

            // upload at the sink's next visibility with a live PS site
            // (the hap_alive guard never fires with faults disabled)
            let mut t_try = t_collect;
            let mut upload = None;
            for _ in 0..MAX_UPLOAD_TRIES {
                match geo.plan.next_visible_any(sink, t_try) {
                    Some((tv, site)) if env.state.faults.hap_alive(site, tv) => {
                        upload = Some((tv, site));
                        break;
                    }
                    Some((tv, _)) => t_try = tv + SITE_RETRY_S,
                    None => break,
                }
            }
            let Some((tv, site)) = upload.filter(|&(tv, _)| tv <= horizon) else {
                if env.state.faults.enabled() {
                    for _ in &alive {
                        env.state.faults.note_dropped();
                    }
                }
                if let Some(obs) = env.obs() {
                    for &m in &alive {
                        obs.model_dropped(t_collect, m, updates, "past_horizon");
                    }
                }
                next_start[p] = f64::INFINITY;
                continue;
            };
            let d_up = env.site_link_delay(site, sink, tv);
            let t_arr = tv + d_up;

            // immediate asynchronous update, α scaled by the plane's
            // share of the data (fedsat's rule, per plane)
            let alpha =
                (BASE_ALPHA * plane_shard / mean_plane_shard).clamp(0.01, 0.5) as f32;
            env.state
                .backend
                .aggregate_into(&global, &[&plane_model], &[alpha], 1.0 - alpha, &mut next);
            std::mem::swap(&mut global, &mut next);
            updates += 1;
            last_t = t_arr;
            if let Some(obs) = env.obs() {
                // one plane folded per update, mixed in at rate alpha
                obs.staleness(0.0);
                obs.aggregate(t_arr, 1, alive.len(), 0.0, alpha as f64);
            }
            if updates as usize % EVAL_EVERY == 0 {
                let e = env.state.backend.evaluate(&global);
                env.record(t_arr, updates, e.accuracy, e.loss);
                converged = detector.update(e.accuracy) && updates >= 30;
            }

            // the sink downloads the fresh global; the plane pipeline
            // restarts as soon as it lands
            let d_down = env.site_link_delay(site, sink, t_arr);
            next_start[p] = t_arr + d_down;
        }

        env.phase_end("event_loop", ph_loop);
        if env.state.curve.points.len() < 2 {
            let e = env.state.backend.evaluate(&global);
            env.record(last_t.max(1.0), updates, e.accuracy, e.loss);
        }
        RunResult::from_env("sinksat", env, updates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement, SchemeKind};
    use crate::coordinator::SimEnv;
    use crate::train::SurrogateBackend;

    fn run_with(cfg: &ExperimentConfig) -> RunResult {
        let mut b = SurrogateBackend::for_config(cfg);
        let mut env = SimEnv::new(cfg, &mut b);
        SinkSat.run(&mut env)
    }

    fn paper_cfg(horizon_h: f64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = PsPlacement::TwoHaps;
        cfg.fl.horizon_s = horizon_h * 3600.0;
        cfg
    }

    #[test]
    fn plane_updates_accumulate_and_learn() {
        let r = run_with(&paper_cfg(24.0));
        assert!(r.epochs > 10, "plane updates {}", r.epochs);
        assert!(r.final_accuracy > 0.5, "acc {}", r.final_accuracy);
        assert!(r.transfers > r.epochs, "collection hops must show up in transfers");
    }

    #[test]
    fn runs_are_deterministic() {
        let cfg = paper_cfg(12.0);
        let a = run_with(&cfg);
        let b = run_with(&cfg);
        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.transfers, b.transfers);
        assert_eq!(a.curve.points.len(), b.curve.points.len());
        for (x, y) in a.curve.points.iter().zip(&b.curve.points) {
            assert_eq!(x.time_s.to_bits(), y.time_s.to_bits());
            assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits());
        }
    }

    #[test]
    fn survives_churn_with_typed_skips() {
        use crate::faults::{FaultConfig, FaultScenario};
        let mut cfg = paper_cfg(24.0);
        cfg.faults = FaultConfig::preset(FaultScenario::Churn, 1.0);
        let r = run_with(&cfg);
        assert!(r.epochs > 0, "churn must not starve every plane");
        let clean = run_with(&paper_cfg(24.0));
        assert_eq!(clean.fault_stats, crate::faults::FaultStats::default());
    }

    #[test]
    fn factory_builds_sinksat() {
        let s = crate::fl::make_strategy(SchemeKind::SinkSat);
        assert_eq!(s.name(), "sinksat");
    }
}
