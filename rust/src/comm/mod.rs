//! RF link-budget and delay model (paper Sec. III-B, Eqs. 5–9).
//!
//! All links (SAT↔SAT ISL, SAT↔HAP, HAP↔HAP IHL, SAT↔GS) are modelled
//! as RF for a fair comparison with the paper's baselines; Table I's
//! parameters are the defaults. The model computes free-space path
//! loss, SNR, Shannon capacity, and the total delay decomposition
//! `t_c = t_t + t_p + t_x + t_y`.
//!
//! The network impairment engine (`crate::faults`) layers on top of
//! this one-shot model: its per-link FIFO queues serialize *channel
//! occupancy* — physically the transmission term `t_t`
//! ([`DelayBreakdown::occupancy_s`]) — which the engine approximates
//! as `queue_service_factor × total delay` since the configured data
//! rate is already folded into the delay it is handed. Jitter,
//! partitions and eclipses likewise perturb or gate the total, never
//! the underlying link budget.

pub mod delay;
pub mod link;

pub use delay::{total_delay_s, DelayBreakdown};
pub use link::LinkParams;
