//! Drivers regenerating every table & figure of the paper's evaluation
//! (Sec. V), plus the ablations called out in DESIGN.md §4.
//!
//! Each driver writes `results/<name>.csv` with the full experiment
//! config embedded as header comments, and prints the paper-style
//! summary rows to stdout.

use super::executor::{run_cells_streaming, Cell};
use crate::config::{ExperimentConfig, ModelKind, PsPlacement, SchemeKind};
use crate::coordinator::{RunResult, SimEnv};
use crate::data::{DatasetKind, Partition};
use crate::fl::{asyncfleo::AsyncFleo, make_strategy, Strategy};
use crate::metrics::csv::{f, i, s, CsvWriter};
use crate::train::{PjrtBackend, SurrogateBackend};
use crate::util::fmt_hm;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Options common to all experiment drivers.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub out_dir: PathBuf,
    /// Reduced sizes for a quick pass (CI / smoke).
    pub fast: bool,
    /// Use the analytic surrogate backend instead of PJRT (pure-L3
    /// topology studies; also what the coordinator benches use).
    pub surrogate: bool,
    pub seed: u64,
    /// Worker threads for sweep grids (`--jobs N`). Surrogate mode
    /// only; PJRT sweeps stay sequential (`executor::effective_jobs`).
    /// Output is bit-identical to `jobs = 1` at any value.
    pub jobs: usize,
    /// Attach metrics-only observation (`obs::RunObs::metrics_only`) to
    /// every run, so sweep drivers can fold an aggregate
    /// `results/report.json`. Observe-only: CSV bytes are unchanged
    /// (`tests/obs_equivalence.rs`).
    pub report: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            out_dir: PathBuf::from("results"),
            fast: false,
            surrogate: false,
            seed: 42,
            jobs: 1,
            report: false,
        }
    }
}

/// All experiment names, in DESIGN.md §4 order (+ the resilience sweep).
pub const ALL_EXPERIMENTS: &[&str] = &[
    "table2", "fig7a", "fig7b", "fig7c", "fig8a", "fig8b", "fig8c",
    "ablate-grouping", "ablate-staleness", "ablate-relay", "resilience",
];

/// Entry point: run one experiment (or "all" / "fig6" alias).
pub fn run_experiment(name: &str, opts: &ExpOptions) -> Result<()> {
    match name {
        "table2" | "fig6" => table2(opts),
        "resilience" => super::resilience::run(opts),
        "fig7a" => fig_grid(opts, "fig7a", DatasetKind::Digits, Partition::Iid, false),
        "fig7b" => fig_grid(opts, "fig7b", DatasetKind::Digits, Partition::NonIidPaper, false),
        "fig7c" => fig_grid(opts, "fig7c", DatasetKind::Digits, Partition::Iid, true),
        "fig8a" => fig_grid(opts, "fig8a", DatasetKind::Cifar, Partition::Iid, false),
        "fig8b" => fig_grid(opts, "fig8b", DatasetKind::Cifar, Partition::NonIidPaper, false),
        "fig8c" => fig_grid(opts, "fig8c", DatasetKind::Cifar, Partition::Iid, true),
        "ablate-grouping" => ablation(opts, "ablate-grouping"),
        "ablate-staleness" => ablation(opts, "ablate-staleness"),
        "ablate-relay" => ablation(opts, "ablate-relay"),
        "all" => {
            for e in ALL_EXPERIMENTS {
                run_experiment(e, opts)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; try one of {ALL_EXPERIMENTS:?} or `all`"),
    }
}

/// Base config for an experiment run.
pub(crate) fn base_config(opts: &ExpOptions) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.seed = opts.seed;
    // sized so the full suite completes on a CPU testbed; the FL
    // dynamics (visit pattern, staleness, grouping) are unaffected
    cfg.data.train_samples = if opts.fast { 2000 } else { 4000 };
    cfg.data.test_samples = if opts.fast { 500 } else { 1000 };
    if opts.fast {
        // simulated time is free; only compute per epoch costs wall
        // time. 60 epochs x 40 MLP dispatches is still < 1 min/run.
        cfg.fl.max_epochs = 60;
        cfg.fl.horizon_s = 72.0 * 3600.0;
    }
    cfg
}

/// Run one configured scheme with the scheme's default strategy.
pub fn run_one(cfg: &ExperimentConfig, opts: &ExpOptions) -> Result<RunResult> {
    run_one_with(cfg, opts, make_strategy(cfg.fl.scheme))
}

/// Run one configured scheme with an explicit strategy object
/// (ablations pass customized AsyncFLEO instances).
pub fn run_one_with(
    cfg: &ExperimentConfig,
    opts: &ExpOptions,
    mut strategy: Box<dyn Strategy>,
) -> Result<RunResult> {
    if opts.surrogate {
        let mut backend = SurrogateBackend::for_config(cfg);
        let mut env = SimEnv::new(cfg, &mut backend);
        attach_report_obs(cfg, opts, &mut env);
        Ok(strategy.run(&mut env))
    } else {
        let runtime = runtime_handle()?;
        let mut backend = PjrtBackend::from_config(runtime, cfg)?;
        let mut env = SimEnv::new(cfg, &mut backend);
        attach_report_obs(cfg, opts, &mut env);
        Ok(strategy.run(&mut env))
    }
}

/// With `--report`, attach metrics-only observation (no trace sink, no
/// record formatting) so the run's `RunResult` carries an `ObsReport`
/// snapshot. Observe-only: output bytes are pinned unchanged by
/// `tests/obs_equivalence.rs`.
fn attach_report_obs(cfg: &ExperimentConfig, opts: &ExpOptions, env: &mut SimEnv<'_>) {
    if !opts.report {
        return;
    }
    let mut obs = crate::obs::RunObs::metrics_only();
    obs.meta(
        "sweep-cell",
        cfg.fl.scheme.name(),
        cfg.seed,
        cfg.fl.horizon_s,
        cfg.n_sats(),
        cfg.placement.sites().len(),
    );
    env.enable_obs(obs);
}

thread_local! {
    static RUNTIME: std::cell::RefCell<Option<Rc<crate::runtime::Runtime>>> =
        const { std::cell::RefCell::new(None) };
}

/// Process-wide PJRT runtime (artifact compilations are cached in it).
pub fn runtime_handle() -> Result<Rc<crate::runtime::Runtime>> {
    RUNTIME.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let rt = crate::runtime::Runtime::new(crate::runtime::Runtime::default_dir())
                .context("creating PJRT runtime (run `make artifacts`?)")?;
            *slot = Some(Rc::new(rt));
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

// ----------------------------------------------------------------------
// E2: Table II + Fig. 6 — scheme comparison, SynthDigits non-IID, CNN
// ----------------------------------------------------------------------

/// The paper's Table II rows: (label, scheme, placement).
pub const TABLE2_ROWS: &[(&str, SchemeKind, PsPlacement)] = &[
    ("FedISL", SchemeKind::FedIsl, PsPlacement::GsRolla),
    ("FedISL-ideal", SchemeKind::FedIslIdeal, PsPlacement::GsNorthPole),
    ("FedSat-ideal", SchemeKind::FedSat, PsPlacement::GsNorthPole),
    ("FedSpace", SchemeKind::FedSpace, PsPlacement::GsRolla),
    ("FedHAP", SchemeKind::FedHap, PsPlacement::HapRolla),
    ("AsyncFLEO-GS", SchemeKind::AsyncFleo, PsPlacement::GsRolla),
    ("AsyncFLEO-HAP", SchemeKind::AsyncFleo, PsPlacement::HapRolla),
    ("AsyncFLEO-twoHAP", SchemeKind::AsyncFleo, PsPlacement::TwoHaps),
];

/// The Table II grid as executor cells (also reused by the sweep bench
/// and the jobs-determinism tests).
pub fn table2_cells(opts: &ExpOptions) -> Vec<Cell> {
    let cfg0 = table2_base_config(opts);
    TABLE2_ROWS
        .iter()
        .map(|&(label, scheme, placement)| {
            let mut cfg = cfg0.clone();
            cfg.fl.scheme = scheme;
            cfg.placement = placement;
            Cell::new(label, cfg)
        })
        .collect()
}

fn table2_base_config(opts: &ExpOptions) -> ExperimentConfig {
    let mut cfg0 = base_config(opts);
    // paper: CNN. On a single-core testbed the full-fidelity CNN table
    // takes ~1 h of wall time; --fast records the MLP variant (same
    // coordinator dynamics, ~40x cheaper dispatch) — the CNN path is
    // exercised end-to-end by examples/end_to_end_train.
    cfg0.fl.model = if opts.fast { ModelKind::Mlp } else { ModelKind::Cnn };
    cfg0.fl.dataset = DatasetKind::Digits;
    cfg0.fl.partition = Partition::NonIidPaper;
    cfg0
}

fn table2(opts: &ExpOptions) -> Result<()> {
    let cfg0 = table2_base_config(opts);
    let cells = table2_cells(opts);

    let mut table = CsvWriter::create(
        opts.out_dir.join("table2.csv"),
        &[&format!("Table II: comparison with SOTA (SynthDigits non-IID, {})", cfg0.fl.model.tag()), &cfg0.to_toml()],
        &["label", "scheme", "placement", "accuracy_pct", "convergence_h", "convergence_hm",
          "epochs", "transfers"],
    )?
    .autoflush(true);
    let mut fig6 = CsvWriter::create(
        opts.out_dir.join("fig6.csv"),
        &["Fig. 6: accuracy vs convergence time (same runs as Table II)"],
        &["label", "time_h", "epoch", "accuracy", "loss"],
    )?
    .autoflush(true);

    println!("\n=== Table II (SynthDigits non-IID, {}) ===", cfg0.fl.model.tag());
    println!("{:<20} {:>9} {:>12} {:>7}", "scheme", "acc(%)", "conv(h:mm)", "epochs");
    // rows stream to disk as cells finish (in cell order): a late error
    // in a long PJRT sweep keeps every completed row
    run_cells_streaming(&cells, opts, |idx, r| {
        let cell = &cells[idx];
        let (conv_t, acc) = summary_of(r);
        table.row(&[
            s(&cell.label),
            s(cell.cfg.fl.scheme.name()),
            s(cell.cfg.placement.name()),
            f(acc * 100.0),
            f(conv_t / 3600.0),
            s(&fmt_hm(conv_t)),
            i(r.epochs),
            i(r.transfers),
        ])?;
        for p in &r.curve.points {
            fig6.row(&[
                s(&cell.label),
                f(p.time_s / 3600.0),
                i(p.epoch),
                f(p.accuracy),
                f(p.loss),
            ])?;
        }
        println!(
            "{:<20} {:>9.2} {:>12} {:>7}",
            cell.label,
            acc * 100.0,
            fmt_hm(conv_t),
            r.epochs
        );
        Ok(())
    })?;
    table.flush()?;
    fig6.flush()?;
    Ok(())
}

/// Convergence summary: (time, accuracy) — plateau if detected, else
/// (last-time, final accuracy).
pub(crate) fn summary_of(r: &RunResult) -> (f64, f64) {
    match r.converged {
        Some((t, acc)) => (t, acc),
        None => (
            r.curve.points.last().map(|p| p.time_s).unwrap_or(0.0),
            r.final_accuracy,
        ),
    }
}

// ----------------------------------------------------------------------
// E3–E8: Fig. 7 / Fig. 8 grids — AsyncFLEO across settings
// ----------------------------------------------------------------------

fn fig_grid(
    opts: &ExpOptions,
    name: &str,
    dataset: DatasetKind,
    partition: Partition,
    two_haps: bool,
) -> Result<()> {
    let mut w = CsvWriter::create(
        opts.out_dir.join(format!("{name}.csv")),
        &[&format!(
            "{name}: AsyncFLEO on {dataset:?} partition {partition:?} two_haps={two_haps}"
        )],
        &["model", "placement", "partition", "time_h", "epoch", "accuracy", "loss"],
    )?
    .autoflush(true);
    println!("\n=== {name} ({dataset:?}) ===");

    // fig7c/fig8c sweep partitions at the fixed two-HAP placement; the
    // a/b panels sweep placement at a fixed partition.
    let grid: Vec<(ModelKind, PsPlacement, Partition)> = if two_haps {
        [Partition::Iid, Partition::NonIidPaper]
            .iter()
            .flat_map(|&p| {
                [
                    (ModelKind::Cnn, PsPlacement::TwoHaps, p),
                    (ModelKind::Mlp, PsPlacement::TwoHaps, p),
                ]
            })
            .collect()
    } else {
        [PsPlacement::HapRolla, PsPlacement::GsRolla]
            .iter()
            .flat_map(|&pl| [(ModelKind::Cnn, pl, partition), (ModelKind::Mlp, pl, partition)])
            .collect()
    };

    let cells: Vec<Cell> = grid
        .iter()
        .map(|&(model, placement, part)| {
            let mut cfg = base_config(opts);
            cfg.fl.scheme = SchemeKind::AsyncFleo;
            cfg.fl.model = model;
            cfg.fl.dataset = dataset;
            cfg.fl.partition = part;
            cfg.placement = placement;
            Cell::new(format!("{}/{}", model.tag(), placement.name()), cfg)
        })
        .collect();
    run_cells_streaming(&cells, opts, |idx, r| {
        let (model, placement, part) = grid[idx];
        let part_name = if part == Partition::Iid { "iid" } else { "non-iid" };
        for p in &r.curve.points {
            w.row(&[
                s(model.tag()),
                s(placement.name()),
                s(part_name),
                f(p.time_s / 3600.0),
                i(p.epoch),
                f(p.accuracy),
                f(p.loss),
            ])?;
        }
        let (conv_t, acc) = summary_of(r);
        println!(
            "{:<5} {:<10} {:<8} acc {:>6.2}%  conv {}",
            model.tag(),
            placement.name(),
            part_name,
            acc * 100.0,
            fmt_hm(conv_t)
        );
        Ok(())
    })?;
    w.flush()?;
    Ok(())
}

// ----------------------------------------------------------------------
// A1–A3: ablations of AsyncFLEO's design choices
// ----------------------------------------------------------------------

fn ablation(opts: &ExpOptions, which: &str) -> Result<()> {
    let mut cfg = base_config(opts);
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    cfg.fl.model = ModelKind::Mlp; // ablations probe the coordinator
    cfg.fl.dataset = DatasetKind::Digits;
    cfg.fl.partition = Partition::NonIidPaper;
    cfg.placement = PsPlacement::HapRolla;

    let variants: Vec<(&str, AsyncFleo)> = match which {
        "ablate-grouping" => vec![
            ("grouping-on", AsyncFleo::default()),
            ("grouping-off", AsyncFleo { disable_grouping: true, ..Default::default() }),
        ],
        "ablate-staleness" => vec![
            ("discount-on", AsyncFleo::default()),
            ("discount-off", AsyncFleo { disable_staleness_discount: true, ..Default::default() }),
        ],
        "ablate-relay" => vec![
            ("relay-on", AsyncFleo::default()),
            ("relay-off", AsyncFleo { disable_isl_relay: true, ..Default::default() }),
        ],
        other => bail!("unknown ablation {other}"),
    };

    let cells: Vec<Cell> = variants
        .into_iter()
        .map(|(label, strat)| Cell::custom(label, cfg.clone(), strat))
        .collect();

    let mut w = CsvWriter::create(
        opts.out_dir.join(format!("{which}.csv")),
        &[&format!("{which}: AsyncFLEO design ablation (SynthDigits non-IID, MLP)"), &cfg.to_toml()],
        &["variant", "accuracy_pct", "convergence_h", "epochs", "transfers"],
    )?
    .autoflush(true);
    println!("\n=== {which} ===");
    run_cells_streaming(&cells, opts, |idx, r| {
        let cell = &cells[idx];
        let (conv_t, acc) = summary_of(r);
        w.row(&[
            s(&cell.label),
            f(acc * 100.0),
            f(conv_t / 3600.0),
            i(r.epochs),
            i(r.transfers),
        ])?;
        println!(
            "{:<14} acc {:>6.2}%  conv {}  epochs {}",
            cell.label,
            acc * 100.0,
            fmt_hm(conv_t),
            r.epochs
        );
        Ok(())
    })?;
    w.flush()?;
    Ok(())
}

/// Print environment / manifest information (CLI `info`).
pub fn print_info(artifact_dir: &Path) -> Result<()> {
    println!("asyncfleo — paper reproduction build");
    match crate::runtime::Manifest::load(artifact_dir) {
        Ok(m) => {
            println!(
                "artifacts: {} ({} models, {} artifacts)",
                artifact_dir.display(),
                m.models.len(),
                m.artifacts.len()
            );
            println!(
                "train geometry: J={} steps x b={} per dispatch, eval chunk {}",
                m.local_steps, m.batch, m.eval_batch
            );
            for (name, me) in &m.models {
                println!("  model {:<12} D={:>7} feat={:>5}", name, me.dim, me.feat);
            }
        }
        Err(e) => println!("artifacts: NOT READY ({e})"),
    }
    let cfg = ExperimentConfig::paper_defaults();
    println!(
        "paper constellation: {} orbits x {} sats @ {} km, incl {} deg",
        cfg.constellation.n_orbits,
        cfg.constellation.sats_per_orbit,
        cfg.constellation.altitude_km,
        cfg.constellation.inclination_deg
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Geometry;
    use std::sync::Arc;

    #[test]
    fn unknown_experiment_rejected() {
        let opts = ExpOptions { surrogate: true, ..Default::default() };
        assert!(run_experiment("nope", &opts).is_err());
    }

    #[test]
    fn table2_rows_cover_paper() {
        assert_eq!(TABLE2_ROWS.len(), 8);
        // three AsyncFLEO variants as in the paper
        let ours = TABLE2_ROWS
            .iter()
            .filter(|(_, s, _)| *s == SchemeKind::AsyncFleo)
            .count();
        assert_eq!(ours, 3);
    }

    #[test]
    fn table2_builds_one_geometry_per_unique_placement() {
        let opts = ExpOptions { fast: true, surrogate: true, ..Default::default() };
        let cells = table2_cells(&opts);
        assert_eq!(cells.len(), TABLE2_ROWS.len());
        let arcs: Vec<Arc<Geometry>> =
            cells.iter().map(|c| Geometry::shared(&c.cfg)).collect();
        let mut ptrs: Vec<*const Geometry> = arcs.iter().map(Arc::as_ptr).collect();
        ptrs.sort();
        ptrs.dedup();
        // 8 rows share 4 geometries: gs-rolla, gs-np, hap-rolla, two-haps
        assert_eq!(ptrs.len(), 4, "one geometry per unique placement");
        for cell in &cells {
            assert_eq!(
                Geometry::build_count(&cell.cfg),
                1,
                "{}: geometry must be built exactly once",
                cell.label
            );
        }
    }
}
