//! FedISL (Razmi et al. [5]): synchronous FL where satellites of the
//! same orbit relay models over intra-orbit ISLs, so each orbit only
//! needs *one* member in view of the PS per direction. The paper's
//! "ideal setup" places the GS at the North Pole (every orbit of the
//! 80°-inclined constellation passes within view twice per period);
//! with an arbitrary GS the same scheme takes ~72 h (Table II).
//!
//! The variant is selected through the experiment placement
//! (`GsNorthPole` = ideal, `GsRolla` = arbitrary).

use crate::coordinator::{RunResult, SimEnv};
use crate::fl::Strategy;

pub struct FedIsl;

impl Strategy for FedIsl {
    fn name(&self) -> &'static str {
        "fedisl"
    }

    fn run(&mut self, env: &mut SimEnv) -> RunResult {
        super::run_synchronous(env, "fedisl", true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement};
    use crate::coordinator::SimEnv;
    use crate::train::SurrogateBackend;

    fn run(placement: PsPlacement, horizon_h: f64) -> RunResult {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = placement;
        cfg.fl.horizon_s = horizon_h * 3600.0;
        cfg.fl.max_epochs = 10;
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        FedIsl.run(&mut env)
    }

    #[test]
    fn ideal_np_converges_fast() {
        let r = run(PsPlacement::GsNorthPole, 24.0);
        assert!(r.epochs >= 3, "NP should allow several rounds in 24 h, got {}", r.epochs);
        assert!(r.final_accuracy > 0.6);
    }

    #[test]
    fn ideal_much_faster_than_arbitrary() {
        let ideal = run(PsPlacement::GsNorthPole, 24.0);
        let arb = run(PsPlacement::GsRolla, 24.0);
        assert!(
            ideal.epochs > arb.epochs || ideal.convergence_hours() < arb.convergence_hours(),
            "ideal ({} rounds) should beat arbitrary ({} rounds) in 24h",
            ideal.epochs,
            arb.epochs
        );
    }
}
