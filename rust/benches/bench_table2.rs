//! Table II end-to-end bench: runs every scheme of the paper's
//! comparison on the surrogate backend (pure-L3: geometry + DES +
//! coordinator — the thing this bench is supposed to measure) and
//! reports both wall-clock cost and the regenerated table rows.
//!
//! The PJRT (real-training) version of the same table is
//! `asyncfleo exp table2`; its compute is dominated by L1/L2 and is
//! benchmarked per-artifact in bench_micro.
//!
//! Run: `cargo bench --offline --bench bench_table2`

use asyncfleo::bench::{bench, print_header, BenchConfig};
use asyncfleo::config::ExperimentConfig;
use asyncfleo::coordinator::SimEnv;
use asyncfleo::experiments::TABLE2_ROWS;
use asyncfleo::fl::make_strategy;
use asyncfleo::train::SurrogateBackend;
use asyncfleo::util::fmt_hm;

fn main() {
    print_header("Table II end-to-end (surrogate backend, 40 sats, 72 h horizon)");
    let bcfg = BenchConfig::endtoend();

    println!(
        "\n{:<20} {:>9} {:>12} {:>7}   (regenerated rows)",
        "scheme", "acc(%)", "conv(h:mm)", "epochs"
    );
    let mut reports = Vec::new();
    for &(label, scheme, placement) in TABLE2_ROWS {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.fl.scheme = scheme;
        cfg.placement = placement;
        cfg.fl.horizon_s = 72.0 * 3600.0;
        cfg.fl.max_epochs = 40;

        // regenerate the row once (printed), then time repeated runs
        let run_once = || {
            let mut backend = SurrogateBackend::paper_split(
                cfg.constellation.n_orbits,
                cfg.constellation.sats_per_orbit,
                false,
                100,
            );
            let mut env = SimEnv::new(&cfg, &mut backend);
            make_strategy(scheme).run(&mut env)
        };
        let r = run_once();
        let (conv_t, acc) = match r.converged {
            Some((t, a)) => (t, a),
            None => (r.curve.points.last().map(|p| p.time_s).unwrap_or(0.0), r.final_accuracy),
        };
        println!(
            "{:<20} {:>9.2} {:>12} {:>7}",
            label,
            acc * 100.0,
            fmt_hm(conv_t),
            r.epochs
        );
        reports.push(bench(label, &bcfg, run_once));
    }

    print_header("wall-clock per full run (coordinator + DES + surrogate)");
    for r in &reports {
        println!("{}", r.report());
    }
}
