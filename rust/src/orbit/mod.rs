//! Orbital-mechanics substrate (paper Sec. III).
//!
//! The paper's experiments need, for every instant over a multi-day
//! horizon: the position of each LEO satellite, the position of each
//! HAP/GS anchored to the rotating Earth, the elevation-angle
//! visibility predicate between any pair, and the resulting *contact
//! windows* whose sporadic, irregular pattern is the whole reason
//! AsyncFLEO exists.
//!
//! We implement circular two-body (Keplerian) propagation — the paper's
//! TLE propagation over a simulated Walker-delta constellation differs
//! only by perturbation noise that does not change the contact-pattern
//! statistics (DESIGN.md §1).
//!
//! Hot-path layout (PR 4): positions evaluate through precomputed
//! per-satellite [`PlaneBasis`] and per-site [`SitePropagator`] values
//! — all time-independent trigonometry hoisted to construction,
//! bit-identical to the original rotation-chain formulas (pinned by
//! bitwise tests in `propagation`/`ground`). [`scan_grid`] defines the
//! exact sample grid shared by the reference scanner
//! ([`contact_windows`]) and the fast plan scanner in
//! `coordinator::contact`.

pub mod doppler;
pub mod elements;
pub mod ground;
pub mod propagation;
pub mod sun;
pub mod visibility;
pub mod walker;

pub use doppler::{doppler_shift_hz, sat_sat_doppler_hz};
pub use elements::{OrbitalElements, EARTH_RADIUS_KM, EARTH_ROTATION_RAD_S, MU_EARTH};
pub use ground::{GeodeticSite, SiteKind, SitePropagator};
pub use propagation::{satellite_position_eci, satellite_velocity_eci, PlaneBasis};
pub use sun::{in_umbra, sat_in_umbra, sun_direction_eci, umbra_windows};
pub use visibility::{
    contact_windows, elevation_deg, max_central_angle_rad, sat_sat_los, scan_grid, ContactWindow,
};
// the fast scanner (coordinator::contact) refines the same brackets
// with the same bisection as the reference scanner
pub(crate) use visibility::bisect_edge;
pub use walker::{uniform_plane_of, Satellite, ShellSpec, WalkerConstellation, WalkerPattern};

// All geometry types are shared across the parallel sweep executor's
// worker threads (via `Arc<coordinator::Geometry>`); pin the auto
// traits here so a future non-Sync field (say, an interior-mutability
// cache) fails at its source instead of in a distant thread spawn.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<WalkerConstellation>();
    assert_send_sync::<Satellite>();
    assert_send_sync::<ShellSpec>();
    assert_send_sync::<OrbitalElements>();
    assert_send_sync::<GeodeticSite>();
    assert_send_sync::<ContactWindow>();
    assert_send_sync::<PlaneBasis>();
    assert_send_sync::<SitePropagator>();
};
