//! AsyncFLEO: the paper's asynchronous FL framework (Sec. IV,
//! Algorithms 1 & 2), run as an event-driven simulation.
//!
//! Per global epoch β:
//!
//! 1. the source HAP relays w^β around the HAP ring and every HAP
//!    broadcasts to its visible satellites; intra-orbit ISLs spread it
//!    to invisible ones ([`super::propagation`] — Algorithm 1);
//! 2. each satellite trains on receipt (J·dispatch local SGD steps via
//!    the AOT train artifact) and routes its local model + metadata
//!    back to a HAP, which forwards along the ring to the *sink*;
//! 3. the sink collects models; on quorum or timeout it (a) groups
//!    newly-seen orbits by weight divergence to w⁰ (the `dist` kernel),
//!    (b) applies the fresh/stale selection rule and the staleness
//!    discount γ (Eq. 13), (c) aggregates on the `agg` kernel (Eq. 14),
//!    (d) swaps source/sink roles and broadcasts w^{β+1}.
//!
//! Satellites always train against the newest global model they have
//! received; a model that trained against an old β arrives stale and is
//! handled by the selection rule — the straggler problem the paper
//! targets.

use super::aggregation::{select_and_weigh_into, Candidate, Selection, SelectionScratch};
use super::grouping::{orbit_partial_model, GroupingState};
use super::propagation::{
    hap_ring_receive_times_into, ihl_to_sink, sat_receive_times_lanes_into, uplink_route,
    uplink_route_probe, uplink_route_replay, RouteProbe,
};
use super::Strategy;
use crate::coordinator::{LaneProbe, RunResult, SimEnv};
use crate::metrics::ConvergenceDetector;
use crate::model::{ModelMetadata, ModelParams};
use crate::sim::{EventKind, LanedQueue};
use crate::topology::HapRing;
use std::collections::HashMap;

/// Tunables of the sink's collection policy (ablated in
/// `experiments::ablations`).
#[derive(Clone, Debug)]
pub struct AsyncFleo {
    /// Aggregate when this fraction of the constellation has fresh-ish
    /// models buffered at the sink.
    pub quorum_frac: f64,
    /// ... or when this much time has passed since the first arrival of
    /// the collection round.
    pub timeout_s: f64,
    /// Keep unselected stale models for at most this many epochs.
    pub stale_retention_epochs: u64,
    /// Convergence: stop after `patience` evaluations without
    /// `min_delta` improvement (but not before `min_epochs`).
    pub min_epochs: u64,
    pub patience: usize,
    pub min_delta: f64,
    /// Ablation switches (A1/A3 in DESIGN.md §4).
    pub disable_grouping: bool,
    pub disable_staleness_discount: bool,
    pub disable_isl_relay: bool,
}

impl Default for AsyncFleo {
    fn default() -> Self {
        AsyncFleo {
            quorum_frac: 0.25,
            timeout_s: 1800.0,
            // dedup already bounds the buffer to one (freshest) model
            // per satellite; keep unselected models around long enough
            // that perpetual stragglers still contribute through the
            // staleness discount whenever their group has nothing fresh
            stale_retention_epochs: 1000,
            min_epochs: 8,
            patience: 6,
            min_delta: 0.003,
            disable_grouping: false,
            disable_staleness_discount: false,
            disable_isl_relay: false,
        }
    }
}

/// Per-satellite run state.
#[derive(Clone, Debug, Default)]
struct SatState {
    /// Newest global epoch received.
    latest_epoch: Option<u64>,
    /// Epoch currently being trained against (while busy).
    training_epoch: Option<u64>,
    /// Received a newer global while training.
    pending_epoch: Option<u64>,
    /// Exact completion instant of the in-flight training run. A
    /// `TrainingDone` event whose time doesn't match is stale — its
    /// run was cancelled by churn and possibly restarted since.
    train_done_at: Option<f64>,
}

/// A model buffered at (or in flight to) the sink.
struct Buffered {
    params: ModelParams,
    meta: ModelMetadata,
    /// β at the time of arrival (for stale retention).
    arrived_epoch: u64,
}

/// Reusable per-run buffers for the broadcast + aggregation paths:
/// allocated once per run and recycled every epoch, so the event
/// loop's recurring steps are allocation-free (the per-epoch model-ref
/// list is the one exception — it borrows the sink buffer that is
/// compacted right after, so its lifetime cannot outlive one epoch).
#[derive(Default)]
struct RunScratch {
    /// HAP ring receive times of the current broadcast.
    hap_times: Vec<f64>,
    /// Per-satellite receive times of the current broadcast.
    sat_times: Vec<f64>,
    /// Aggregation candidates of the current epoch.
    candidates: Vec<Candidate>,
    /// Selection working set + output (reused `chosen` allocation).
    sel_scratch: SelectionScratch,
    selection: Selection,
    /// Aggregation coefficients of the chosen models.
    coeffs: Vec<f32>,
    /// Grouping distances of newly-seen orbit partials.
    dists: Vec<f64>,
    /// Per-buffer-slot "aggregated this epoch" flags (retention).
    used: Vec<bool>,
    /// Distinct-orbit working set of the pre-grouping trigger check.
    orbit_ids: Vec<usize>,
    /// Free-pool of per-arriving-model owned buffers: training results
    /// check a `ModelParams` out, buffer eviction / dedup replacement /
    /// undeliverable results return it. Bounded, so a long run recycles
    /// a small working set instead of allocating per arrival.
    pool: Vec<ModelParams>,
    /// Buffers returned to the pool over the run (observability: the
    /// `pool_recycles` counter).
    recycles: u64,
}

/// Upper bound on pooled model buffers (more than the sink ever holds
/// in flight per epoch in practice; beyond it, buffers just drop).
const MODEL_POOL_CAP: usize = 32;

impl RunScratch {
    /// Check a model buffer out of the pool (empty if the pool is dry;
    /// `train_local_into` sizes it).
    fn take_model(&mut self) -> ModelParams {
        self.pool.pop().unwrap_or(ModelParams { data: Vec::new() })
    }

    /// Return a no-longer-needed model buffer to the pool.
    fn recycle(&mut self, m: ModelParams) {
        self.recycles += 1;
        if self.pool.len() < MODEL_POOL_CAP {
            self.pool.push(m);
        }
    }
}

/// Push-time uplink-route prefetcher (lanes > 1 only): every scheduled
/// `TrainingDone` files a request here; pending requests are probed in
/// parallel over the shared [`LaneProbe`] the next time a
/// `TrainingDone` pops, and the popped event replays its own probe
/// serially ([`uplink_route_replay`]) so transfer counts, fault stats
/// and obs lines land in exactly the single-lane order. Routes depend
/// only on immutable geometry and the fault schedule, so a probe taken
/// at push time is bit-identical to the serial route at pop time.
///
/// A satellite has at most one live training run, so the ready map is
/// keyed per satellite and a re-request (churn restart) overwrites the
/// cancelled probe; probes that are never replayed (satellite died, or
/// the stale event was filtered before routing) are pure and therefore
/// unobservable.
struct RoutePrefetcher {
    lanes: usize,
    pending: Vec<(usize, f64)>,
    ready: HashMap<usize, RouteProbe>,
}

impl RoutePrefetcher {
    fn new(lanes: usize) -> Self {
        RoutePrefetcher { lanes, pending: Vec::new(), ready: HashMap::new() }
    }

    /// File a route request for `sat` finishing training at `t_done`.
    fn request(&mut self, sat: usize, t_done: f64) {
        if self.lanes <= 1 {
            return;
        }
        self.pending.push((sat, t_done));
    }

    /// Probe all pending requests in parallel lane chunks.
    fn flush(&mut self, probe: &LaneProbe) {
        if self.pending.is_empty() {
            return;
        }
        let chunk = ((self.pending.len() + self.lanes - 1) / self.lanes).max(1);
        let pending = &self.pending;
        let probes: Vec<RouteProbe> = std::thread::scope(|scope| {
            let handles: Vec<_> = pending
                .chunks(chunk)
                .map(|ch| {
                    scope.spawn(move || {
                        ch.iter()
                            .map(|&(sat, t)| uplink_route_probe(probe, sat, t))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("route probe lane panicked"))
                .collect()
        });
        for rp in probes {
            self.ready.insert(rp.sat, rp);
        }
        self.pending.clear();
    }

    /// Take the probe for `sat`'s run completing at exactly `t` (the
    /// time match rejects probes of cancelled runs).
    fn take(&mut self, sat: usize, t: f64) -> Option<RouteProbe> {
        match self.ready.remove(&sat) {
            Some(rp) if rp.t_ready == t => Some(rp),
            _ => None,
        }
    }
}

impl Strategy for AsyncFleo {
    fn name(&self) -> &'static str {
        "asyncfleo"
    }

    fn run(&mut self, env: &mut SimEnv) -> RunResult {
        let n_sats = env.geo.constellation.len();
        let n_sites = env.geo.sites.len();
        let quorum = ((n_sats as f64 * self.quorum_frac).ceil() as usize).max(1);
        let horizon = env.cfg.fl.horizon_s;
        let dispatches = env.cfg.fl.local_dispatches;

        let mut ring = HapRing::new(n_sites);
        // Laned queue: events shard by orbital plane / HAP / site, pops
        // are provably in single-queue order (see `sim::lanes`), so
        // every lane count replays the identical history.
        let mut queue = LanedQueue::new(env.lanes(), env.geo.constellation.plane_of());
        // Shared pure probe + prefetcher power the parallel route scans
        // between pops; on the single-lane path neither is ever used.
        let lane_probe = if env.lanes() > 1 { Some(env.lane_probe()) } else { None };
        let mut prefetcher = RoutePrefetcher::new(env.lanes());
        let mut sats: Vec<SatState> = vec![SatState::default(); n_sats];
        let mut grouping = GroupingState::new(env.geo.constellation.n_orbits);
        let mut detector = ConvergenceDetector::new(self.patience, self.min_delta);

        // On-board compute time scales with local data size (the I=100
        // local epochs sweep the whole shard) — this also breaks the
        // lock-step of identical training times, giving the realistic
        // spread of completion instants the async design exploits.
        let mean_size: f64 = (0..n_sats)
            .map(|s| env.state.backend.shard_size(s) as f64)
            .sum::<f64>()
            / n_sats as f64;
        let train_time = |sat: usize, env: &SimEnv| -> f64 {
            let ratio = env.state.backend.shard_size(sat) as f64 / mean_size;
            env.cfg.fl.train_time_s * ratio.clamp(0.5, 1.6)
        };
        // D of Eq. 13: the whole constellation's data — shard sizes are
        // fixed for the run, so the sum is hoisted out of the epoch loop
        let total_data: usize =
            (0..n_sats).map(|s| env.state.backend.shard_size(s)).sum();

        // Global model history: sats train against the epoch they hold.
        let mut globals: Vec<ModelParams> =
            vec![env.state.backend.init_global(env.cfg.seed as i32)];
        let mut beta: u64 = 0;

        let e0 = env.state.backend.evaluate(&globals[0]);
        env.record(0.0, 0, e0.accuracy, e0.loss);

        // Sink collection state.
        let mut in_flight: HashMap<(usize, u64), (ModelParams, ModelMetadata)> = HashMap::new();
        let mut buffer: Vec<Buffered> = Vec::new();
        let mut tick_deadline = f64::INFINITY;
        let mut scratch = RunScratch::default();

        // Initial broadcast of w^0 from the source HAP at t = 0.
        self.broadcast(env, &ring, &mut queue, 0, 0.0, &mut scratch);

        // Fault-plan transitions (churn, outage boundaries) become
        // typed events; with faults disabled nothing is pushed and the
        // run is bit-identical to the clean code path.
        env.state.faults.schedule_events(&mut queue);

        let mut converged = false;
        let ph_loop = env.phase_start();
        while let Some(ev) = queue.pop() {
            let t = ev.time_s;
            if let Some(obs) = env.obs() {
                obs.queue_depth(queue.len());
            }
            if t > horizon || converged || beta >= env.cfg.fl.max_epochs {
                break;
            }
            match ev.kind {
                EventKind::SatModelArrival { sat, epoch, global: true, .. } => {
                    // a model delivered into a dead receiver is lost;
                    // the satellite catches up on rejoin or at the next
                    // broadcast / post-outage re-offer
                    if !env.state.faults.sat_alive(sat, t) {
                        continue;
                    }
                    let done = t + train_time(sat, env);
                    let s = &mut sats[sat];
                    if s.latest_epoch.map_or(true, |e| epoch > e) {
                        s.latest_epoch = Some(epoch);
                        if s.training_epoch.is_none() {
                            s.training_epoch = Some(epoch);
                            s.train_done_at = Some(done);
                            queue.push(crate::sim::Event::new(
                                done,
                                EventKind::TrainingDone { sat },
                            ));
                            if !self.disable_isl_relay {
                                prefetcher.request(sat, done);
                            }
                        } else {
                            s.pending_epoch = Some(epoch);
                        }
                    }
                }
                EventKind::TrainingDone { sat } => {
                    // churn may have wiped the state (result lost), or
                    // this event may belong to a cancelled run that was
                    // since restarted — only the completion instant of
                    // the *current* run is live
                    let Some(epoch) = sats[sat].training_epoch else {
                        continue;
                    };
                    if sats[sat].train_done_at != Some(t) {
                        continue;
                    }
                    if !env.state.faults.sat_alive(sat, t) {
                        sats[sat].training_epoch = None;
                        sats[sat].pending_epoch = None;
                        sats[sat].train_done_at = None;
                        env.state.faults.note_dropped();
                        if let Some(obs) = env.obs() {
                            obs.model_dropped(t, sat, epoch, "dead");
                        }
                        continue;
                    }
                    // the result buffer comes from the free-pool (same
                    // in-place training API, same floats — the fresh
                    // allocation only happens while the pool is dry)
                    let mut model = scratch.take_model();
                    env.state.backend.train_local_into(
                        sat,
                        &globals[epoch as usize],
                        dispatches,
                        &mut model,
                    );
                    let meta = self.metadata(env, sat, t, epoch);
                    // route to a HAP, then along the ring to the sink
                    let route = if self.disable_isl_relay {
                        // ablation A3: wait for own next contact
                        let next = env.geo.plan.next_visible_any(sat, t);
                        next.map(|(tv, site)| {
                            let d = env.site_link_delay(site, sat, tv);
                            (site, tv + d, 0usize)
                        })
                    } else if let Some(p) = lane_probe.as_ref() {
                        // multi-lane: drain the probe backlog in
                        // parallel, then replay this event's own probe
                        // in pop order (serial fallback covers a miss)
                        prefetcher.flush(p);
                        match prefetcher.take(sat, t) {
                            Some(rp) => uplink_route_replay(env, &rp),
                            None => uplink_route(env, sat, t),
                        }
                    } else {
                        uplink_route(env, sat, t)
                    };
                    let delivered = match route {
                        Some((site, t_site, _hops)) => {
                            let t_sink = ihl_to_sink(env, &ring, site, t_site);
                            if t_sink <= horizon {
                                queue.push(crate::sim::Event::new(
                                    t_sink,
                                    EventKind::HapLocalArrival {
                                        hap: ring.sink(),
                                        origin_sat: sat,
                                        epoch,
                                    },
                                ));
                                true
                            } else {
                                false // deferred past horizon
                            }
                        }
                        None => false, // no reachable PS anymore
                    };
                    if delivered {
                        if let Some((old, _)) = in_flight.insert((sat, epoch), (model, meta)) {
                            scratch.recycle(old);
                        }
                    } else {
                        scratch.recycle(model);
                        if env.state.faults.enabled() {
                            env.state.faults.note_dropped();
                        }
                        if let Some(obs) = env.obs() {
                            obs.model_dropped(t, sat, epoch, "past_horizon");
                        }
                    }
                    // start next training round if a newer global arrived
                    let done = t + train_time(sat, env);
                    let s = &mut sats[sat];
                    s.training_epoch = None;
                    s.train_done_at = None;
                    if let Some(p) = s.pending_epoch.take() {
                        s.training_epoch = Some(p);
                        s.train_done_at = Some(done);
                        queue.push(crate::sim::Event::new(done, EventKind::TrainingDone { sat }));
                        if !self.disable_isl_relay {
                            prefetcher.request(sat, done);
                        }
                    }
                }
                EventKind::HapLocalArrival { origin_sat, epoch, .. } => {
                    if let Some((params, meta)) = in_flight.remove(&(origin_sat, epoch)) {
                        // duplicate filtering (Sec. IV-C1): keep the
                        // freshest model per satellite
                        if let Some(existing) =
                            buffer.iter_mut().find(|b| b.meta.sat_id == origin_sat)
                        {
                            // either the displaced or the discarded
                            // model's buffer returns to the free-pool
                            if meta.epoch >= existing.meta.epoch {
                                let old = std::mem::replace(
                                    existing,
                                    Buffered { params, meta, arrived_epoch: beta },
                                );
                                scratch.recycle(old.params);
                            } else {
                                scratch.recycle(params);
                            }
                        } else {
                            buffer.push(Buffered { params, meta, arrived_epoch: beta });
                        }
                        if buffer.len() == 1 {
                            tick_deadline = t + self.timeout_s;
                            queue.push_in(self.timeout_s, EventKind::AggregationTick);
                        }
                        // Trigger policy (Sec. IV-C: the selection
                        // "takes into account the staleness ... the
                        // number of satellites of each group, and the
                        // total size of data in each group"):
                        // * quorum counts models *fresh for the current
                        //   epoch* (leftovers wait for the timeout);
                        // * every known group must be represented by a
                        //   fresh model, so the aggregation never feeds
                        //   on one data distribution only.
                        let fresh = buffer.iter().filter(|b| b.meta.epoch == beta).count();
                        let covered = if self.disable_grouping || !grouping.all_grouped() {
                            // before grouping is known: require models
                            // from at least two distinct orbits
                            let orbits = &mut scratch.orbit_ids;
                            orbits.clear();
                            orbits.extend(buffer.iter().map(|b| b.meta.orbit));
                            orbits.sort_unstable();
                            orbits.dedup();
                            orbits.len() >= 2.min(env.geo.constellation.n_orbits)
                        } else {
                            // every group must be *represented* among the
                            // candidates — fresh if it has any (selection
                            // prefers those), otherwise its stale models
                            // enter with the Eq. 13 discount. Straggler
                            // orbits that are never fresh still
                            // contribute every epoch this way.
                            (0..grouping.n_groups()).all(|g| {
                                buffer.iter().any(|b| {
                                    grouping.group_of(b.meta.orbit) == Some(g)
                                })
                            })
                        };
                        if fresh >= quorum && covered {
                            converged = self.aggregate_now(
                                env, &mut ring, &mut queue, &mut grouping, &mut globals,
                                &mut beta, &mut buffer, &mut detector, t, total_data,
                                &mut scratch,
                            );
                            tick_deadline = f64::INFINITY;
                        }
                    }
                }
                EventKind::AggregationTick => {
                    if !buffer.is_empty() && t + 1e-9 >= tick_deadline {
                        converged = self.aggregate_now(
                            env, &mut ring, &mut queue, &mut grouping, &mut globals,
                            &mut beta, &mut buffer, &mut detector, t, total_data,
                            &mut scratch,
                        );
                        tick_deadline = f64::INFINITY;
                    }
                }
                EventKind::SatChurn { sat, up } => {
                    if !up {
                        // dropout: an in-flight training run is lost
                        if let Some(ep) = sats[sat].training_epoch.take() {
                            env.state.faults.note_dropped();
                            if let Some(obs) = env.obs() {
                                obs.model_dropped(t, sat, ep, "churn");
                            }
                        }
                        sats[sat].pending_epoch = None;
                        sats[sat].train_done_at = None;
                    } else if sats[sat].training_epoch.is_none() {
                        // rejoin: restart training on the newest global
                        // the satellite still holds (reboot-and-resume)
                        if sats[sat].latest_epoch.is_some() {
                            let done = t + train_time(sat, env);
                            let s = &mut sats[sat];
                            s.training_epoch = s.latest_epoch;
                            s.train_done_at = Some(done);
                            queue.push(crate::sim::Event::new(
                                done,
                                EventKind::TrainingDone { sat },
                            ));
                            if !self.disable_isl_relay {
                                prefetcher.request(sat, done);
                            }
                        }
                    }
                }
                EventKind::HapChurn { hap, up } => {
                    // the backbone re-heals around the change; in-flight
                    // sink batches are assumed re-routed by the ring
                    ring.set_alive(hap, up);
                }
                EventKind::OutageStart { .. } => {
                    // nothing to do: the delay oracle gates every link
                    // transfer crossing the window
                }
                EventKind::OutageEnd { site } => {
                    // post-eclipse catch-up: the PS re-offers the newest
                    // global to whoever is visible now; satellites that
                    // already have this epoch ignore the duplicate
                    let geo = env.geo.clone();
                    for sat in geo.plan.visible_sats(site, t) {
                        let d = env.site_link_delay(site, sat, t);
                        let tr = t + d;
                        if tr <= horizon {
                            queue.push(crate::sim::Event::new(
                                tr,
                                EventKind::SatModelArrival {
                                    sat,
                                    from_sat: sat,
                                    epoch: beta,
                                    global: true,
                                    origin_sat: sat,
                                },
                            ));
                        }
                    }
                }
                _ => {}
            }
        }
        env.phase_end("event_loop", ph_loop);
        if let Some(obs) = env.obs() {
            obs.metrics.set_max("queue_high_water", queue.high_water() as u64);
            obs.metrics.add("pool_recycles", scratch.recycles);
        }
        RunResult::from_env("asyncfleo", env, beta)
    }
}

impl AsyncFleo {
    fn metadata(&self, env: &SimEnv, sat: usize, t: f64, epoch: u64) -> ModelMetadata {
        let s = &env.geo.constellation.satellites[sat];
        let u = s.elements.phase_rad + s.elements.mean_motion_rad_s() * t;
        ModelMetadata {
            sat_id: sat,
            orbit: s.orbit,
            data_size: env.state.backend.shard_size(sat),
            loc_rad: u % (2.0 * std::f64::consts::PI),
            ts_s: t,
            epoch,
        }
    }

    /// Broadcast `globals[epoch]` from the current source HAP at `t`:
    /// queue per-satellite receive events (Algorithm 1). Receive-time
    /// vectors live in `scratch`, reused across broadcasts.
    fn broadcast(
        &self,
        env: &mut SimEnv,
        ring: &HapRing,
        queue: &mut LanedQueue,
        epoch: u64,
        t: f64,
        scratch: &mut RunScratch,
    ) {
        hap_ring_receive_times_into(env, ring, ring.source(), t, &mut scratch.hap_times);
        if self.disable_isl_relay {
            // ablation A3: star-only distribution — each satellite
            // receives at its own next site contact
            let geo = env.geo.clone();
            let recv = &mut scratch.sat_times;
            recv.clear();
            recv.resize(geo.constellation.len(), f64::INFINITY);
            for (sat, r) in recv.iter_mut().enumerate() {
                for (site, &tb) in scratch.hap_times.iter().enumerate() {
                    if let Some(tv) = geo.plan.next_visible(site, sat, tb) {
                        let d = env.site_link_delay(site, sat, tv);
                        *r = r.min(tv + d);
                    }
                }
            }
        } else {
            sat_receive_times_lanes_into(env, &scratch.hap_times, &mut scratch.sat_times);
        }
        for (sat, &tr) in scratch.sat_times.iter().enumerate() {
            if tr.is_finite() && tr <= env.cfg.fl.horizon_s && tr >= queue.now() {
                queue.push(crate::sim::Event::new(
                    tr,
                    EventKind::SatModelArrival {
                        sat,
                        from_sat: sat,
                        epoch,
                        global: true,
                        origin_sat: sat,
                    },
                ));
            }
        }
    }

    /// The sink's convergence operation (Algorithm 2): group, select,
    /// discount, aggregate, evaluate, swap roles, rebroadcast.
    /// Returns true when the run has converged. Recurring buffers come
    /// from `scratch`; only the first-sighting grouping path (cold: it
    /// runs until every orbit has been grouped once) and the per-epoch
    /// model-ref list allocate.
    #[allow(clippy::too_many_arguments)]
    fn aggregate_now(
        &self,
        env: &mut SimEnv,
        ring: &mut HapRing,
        queue: &mut LanedQueue,
        grouping: &mut GroupingState,
        globals: &mut Vec<ModelParams>,
        beta: &mut u64,
        buffer: &mut Vec<Buffered>,
        detector: &mut ConvergenceDetector,
        t: f64,
        total_data: usize,
        scratch: &mut RunScratch,
    ) -> bool {
        let ph = env.phase_start();
        // --- grouping of newly-seen orbits (Sec. IV-C1) ---
        // cold path: once every buffered orbit is grouped, the guard is
        // false for the rest of the run and nothing below allocates
        if buffer.iter().any(|b| grouping.group_of(b.meta.orbit).is_none()) {
            let mut orbit_members: HashMap<usize, Vec<usize>> = HashMap::new();
            for (i, b) in buffer.iter().enumerate() {
                orbit_members.entry(b.meta.orbit).or_default().push(i);
            }
            let new_orbits: Vec<usize> = orbit_members
                .keys()
                .copied()
                .filter(|&o| grouping.group_of(o).is_none())
                .collect();
            let partials: Vec<ModelParams> = new_orbits
                .iter()
                .map(|o| {
                    let idxs = &orbit_members[o];
                    let models: Vec<&ModelParams> =
                        idxs.iter().map(|&i| &buffer[i].params).collect();
                    let sizes: Vec<usize> =
                        idxs.iter().map(|&i| buffer[i].meta.data_size).collect();
                    orbit_partial_model(&models, &sizes)
                })
                .collect();
            let refs: Vec<&ModelParams> = partials.iter().collect();
            // divergence to w^0 on the dist kernel (the scale reference)
            env.state.backend.distances_into(&refs, &globals[0], &mut scratch.dists);
            let items: Vec<(usize, &ModelParams, f64)> = new_orbits
                .iter()
                .copied()
                .zip(refs.iter().copied())
                .zip(scratch.dists.iter().copied())
                .map(|((o, p), d)| (o, p, d))
                .collect();
            grouping.assign_batch(&items);
        }

        // --- selection + staleness discounting (Sec. IV-C2) ---
        scratch.candidates.clear();
        scratch.candidates.extend(buffer.iter().map(|b| Candidate {
            meta: b.meta,
            group: if self.disable_grouping {
                0 // ablation A1: one big group
            } else {
                grouping.group_of(b.meta.orbit).unwrap_or(0)
            },
        }));
        select_and_weigh_into(
            &scratch.candidates,
            *beta,
            total_data,
            &mut scratch.sel_scratch,
            &mut scratch.selection,
        );
        if self.disable_staleness_discount && !scratch.selection.chosen.is_empty() {
            // ablation A2: ignore staleness — plain FedAvg over the
            // selected models
            let d_total: f64 = scratch
                .selection
                .chosen
                .iter()
                .map(|&(i, _)| scratch.candidates[i].meta.data_size as f64)
                .sum();
            for (i, w) in scratch.selection.chosen.iter_mut() {
                *w = (scratch.candidates[*i].meta.data_size as f64 / d_total.max(1.0)) as f32;
            }
            scratch.selection.coeff_prev = 0.0;
        }

        if !scratch.selection.chosen.is_empty() {
            if let Some(obs) = env.obs() {
                let mut worst = 0.0f64;
                for &(i, _) in &scratch.selection.chosen {
                    let s =
                        beta.saturating_sub(scratch.candidates[i].meta.epoch) as f64;
                    obs.staleness(s);
                    if s > worst {
                        worst = s;
                    }
                }
                obs.aggregate(
                    t,
                    grouping.n_groups() as u64,
                    scratch.selection.chosen.len(),
                    worst,
                    scratch.selection.gamma,
                );
            }
            // the ref list borrows the buffer compacted just below, so
            // it cannot live in the cross-epoch scratch
            let models: Vec<&ModelParams> = scratch
                .selection
                .chosen
                .iter()
                .map(|&(i, _)| &buffer[i].params)
                .collect();
            scratch.coeffs.clear();
            scratch.coeffs.extend(scratch.selection.chosen.iter().map(|&(_, w)| w));
            let prev = globals.last().unwrap();
            let mut next = ModelParams { data: Vec::with_capacity(prev.dim()) };
            env.state.backend.aggregate_into(
                prev,
                &models,
                &scratch.coeffs,
                scratch.selection.coeff_prev,
                &mut next,
            );
            globals.push(next);
            *beta += 1;
        }

        // retention: drop used models and over-aged stale ones
        // (order-preserving in-place compaction — same survivors, same
        // order as the old drain-into-keep pass; evicted model buffers
        // go back to the free-pool)
        scratch.used.clear();
        scratch.used.resize(buffer.len(), false);
        for &(i, _) in &scratch.selection.chosen {
            scratch.used[i] = true;
        }
        let retention = self.stale_retention_epochs;
        let cur = *beta;
        if let Some(obs) = env.obs() {
            for (i, b) in buffer.iter().enumerate() {
                if scratch.used[i] {
                    continue; // aggregated, neither kept nor dropped
                }
                if cur.saturating_sub(b.arrived_epoch) < retention {
                    obs.model_retained(t, b.meta.sat_id, b.meta.epoch);
                } else {
                    obs.model_dropped(t, b.meta.sat_id, b.meta.epoch, "stale");
                }
            }
        }
        let mut kept = 0;
        for i in 0..buffer.len() {
            let keep =
                !scratch.used[i] && cur.saturating_sub(buffer[i].arrived_epoch) < retention;
            if keep {
                buffer.swap(kept, i);
                kept += 1;
            }
        }
        for b in buffer.drain(kept..) {
            scratch.recycle(b.params);
        }

        // evaluate + record + convergence
        let e = env.state.backend.evaluate(globals.last().unwrap());
        if std::env::var_os("ASYNCFLEO_DEBUG").is_some() {
            let mut per_orbit = vec![(0usize, 0usize); env.geo.constellation.n_orbits];
            for &(i, _) in &scratch.selection.chosen {
                per_orbit[scratch.candidates[i].meta.orbit].0 += 1;
            }
            for c in &scratch.candidates {
                per_orbit[c.meta.orbit].1 += 1;
            }
            eprintln!(
                "[agg] beta={} t={:.0} cand={} sel={} gamma={:.3} groups={} per-orbit(sel/cand)={:?} acc={:.4}",
                *beta,
                t,
                scratch.candidates.len(),
                scratch.selection.chosen.len(),
                scratch.selection.gamma,
                grouping.n_groups(),
                per_orbit,
                e.accuracy
            );
        }
        env.record(t, *beta, e.accuracy, e.loss);
        let converged = detector.update(e.accuracy) && *beta >= self.min_epochs;

        // role swap + rebroadcast (Sec. IV-B3)
        ring.swap_roles();
        self.broadcast(env, ring, queue, *beta, t, scratch);
        env.phase_end("aggregate", ph);
        converged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement};
    use crate::fl::Strategy;
    use crate::train::SurrogateBackend;

    fn run_with(placement: PsPlacement, iid: bool, horizon_h: f64) -> RunResult {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = placement;
        cfg.fl.horizon_s = horizon_h * 3600.0;
        cfg.fl.max_epochs = 30;
        let mut b = SurrogateBackend::paper_split(5, 8, iid, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        AsyncFleo::default().run(&mut env)
    }

    #[test]
    fn learns_on_surrogate_noniid() {
        let r = run_with(PsPlacement::HapRolla, false, 24.0);
        assert!(r.epochs >= 3, "epochs {}", r.epochs);
        assert!(
            r.final_accuracy > 0.70,
            "non-IID accuracy {} too low (curve {:?})",
            r.final_accuracy,
            r.curve.points.len()
        );
    }

    #[test]
    fn iid_at_least_as_good_as_noniid() {
        let iid = run_with(PsPlacement::HapRolla, true, 24.0);
        let non = run_with(PsPlacement::HapRolla, false, 24.0);
        assert!(iid.final_accuracy >= non.final_accuracy - 0.03);
    }

    #[test]
    fn two_haps_no_slower_than_one() {
        // compare with a stopping-rule-independent metric: the time to
        // reach a fixed accuracy level
        let one = run_with(PsPlacement::HapRolla, false, 24.0);
        let two = run_with(PsPlacement::TwoHaps, false, 24.0);
        let t1 = one.time_to_accuracy(0.70).expect("one-HAP reaches 70%");
        let t2 = two.time_to_accuracy(0.70).expect("two-HAP reaches 70%");
        assert!(
            t2 <= t1 + 1800.0,
            "two-HAP to 70%: {} h vs one-HAP {} h",
            t2 / 3600.0,
            t1 / 3600.0
        );
    }

    #[test]
    fn converges_within_hours_not_days() {
        let r = run_with(PsPlacement::HapRolla, false, 48.0);
        let (t, _) = r.converged.expect("should converge in 48h");
        assert!(t < 24.0 * 3600.0, "took {} h", t / 3600.0);
    }

    #[test]
    fn ablation_isl_relay_off_is_slower() {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = PsPlacement::HapRolla;
        cfg.fl.horizon_s = 48.0 * 3600.0;
        cfg.fl.max_epochs = 20;
        let mut b1 = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env1 = SimEnv::new(&cfg, &mut b1);
        let on = AsyncFleo::default().run(&mut env1);
        let mut b2 = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env2 = SimEnv::new(&cfg, &mut b2);
        let off = AsyncFleo { disable_isl_relay: true, ..Default::default() }.run(&mut env2);
        // without relay every model waits for its own pass: fewer epochs
        // in the same horizon or later convergence
        assert!(
            off.epochs <= on.epochs || off.convergence_hours() >= on.convergence_hours(),
            "relay off should not be faster: on=({}, {}h) off=({}, {}h)",
            on.epochs,
            on.convergence_hours(),
            off.epochs,
            off.convergence_hours()
        );
    }
}
