//! The simulation environment handed to every FL strategy.

use super::contact::ContactPlan;
use crate::comm::delay::{model_bits, total_delay_s};
use crate::comm::LinkParams;
use crate::config::ExperimentConfig;
use crate::faults::{FaultPlan, FaultStats, LinkClass};
use crate::metrics::{Curve, CurvePoint};
use crate::orbit::{GeodeticSite, WalkerConstellation};
use crate::train::Backend;
use crate::util::Rng;

/// Everything a strategy needs: geometry, contacts, delays, compute.
pub struct SimEnv<'a> {
    pub cfg: ExperimentConfig,
    pub constellation: WalkerConstellation,
    pub sites: Vec<GeodeticSite>,
    pub plan: ContactPlan,
    pub link: LinkParams,
    pub backend: &'a mut dyn Backend,
    pub rng: Rng,
    pub curve: Curve,
    /// Count of model transfers (uplink+downlink+relay hops), for the
    /// communication-cost accounting in EXPERIMENTS.md.
    pub transfers: u64,
    /// The fault-injection timeline every link transfer runs through.
    /// Disabled (a guaranteed no-op) unless `cfg.faults` is active.
    pub faults: FaultPlan,
}

impl<'a> SimEnv<'a> {
    /// Build the environment: constellation + contact plan from config.
    pub fn new(cfg: &ExperimentConfig, backend: &'a mut dyn Backend) -> Self {
        let constellation = WalkerConstellation::new(
            cfg.constellation.n_orbits,
            cfg.constellation.sats_per_orbit,
            cfg.constellation.altitude_km,
            cfg.constellation.inclination_deg,
            cfg.constellation.phasing,
        );
        assert_eq!(
            constellation.len(),
            backend.n_sats(),
            "backend shard count must match constellation size"
        );
        let sites = cfg.placement.sites();
        let plan = ContactPlan::build(
            &constellation,
            &sites,
            cfg.min_elevation_deg,
            cfg.fl.horizon_s,
        );
        let faults = FaultPlan::new(
            &cfg.faults,
            cfg.seed,
            constellation.len(),
            sites.len(),
            cfg.constellation.sats_per_orbit,
            cfg.fl.horizon_s,
        );
        SimEnv {
            cfg: cfg.clone(),
            constellation,
            sites,
            plan,
            link: cfg.link,
            backend,
            rng: Rng::new(cfg.seed ^ 0xE5E57),
            curve: Curve::default(),
            transfers: 0,
            faults,
        }
    }

    /// Model payload size in bits for the current model dimension.
    pub fn payload_bits(&self) -> f64 {
        model_bits(self.backend.dim())
    }

    /// SAT↔site transfer delay at time `t` (Eq. 7), fault-adjusted.
    pub fn site_link_delay(&mut self, site: usize, sat: usize, t: f64) -> f64 {
        self.transfers += 1;
        let d = self.sites[site]
            .position_eci(t)
            .distance(self.constellation.position(sat, t));
        let base = total_delay_s(&self.link, self.payload_bits(), d);
        self.apply_faults(LinkClass::SatSite { sat, site }, t, base)
    }

    /// Intra-orbit ISL hop delay between ring neighbours at time `t`,
    /// fault-adjusted.
    pub fn isl_hop_delay(&mut self, sat_a: usize, sat_b: usize, t: f64) -> f64 {
        self.transfers += 1;
        let d = self
            .constellation
            .position(sat_a, t)
            .distance(self.constellation.position(sat_b, t));
        let base = total_delay_s(&self.link, self.payload_bits(), d);
        self.apply_faults(LinkClass::Isl { sat_a, sat_b }, t, base)
    }

    /// HAP↔HAP (IHL) hop delay at time `t`, fault-adjusted.
    pub fn ihl_hop_delay(&mut self, site_a: usize, site_b: usize, t: f64) -> f64 {
        self.transfers += 1;
        let d = self.sites[site_a]
            .position_eci(t)
            .distance(self.sites[site_b].position_eci(t));
        let base = total_delay_s(&self.link, self.payload_bits(), d);
        self.apply_faults(LinkClass::Ihl { site_a, site_b }, t, base)
    }

    /// Route one transfer through the fault oracle. With faults
    /// disabled this returns `base` untouched and draws nothing, so
    /// clean runs stay bit-identical to the pre-faults code path.
    fn apply_faults(&mut self, class: LinkClass, t: f64, base: f64) -> f64 {
        if !self.faults.enabled() {
            return base;
        }
        let out = self.faults.transfer(class, t, base);
        // every retransmission re-sends the payload: communication
        // cost — counted once per channel event, not per probe of it
        if out.newly_observed {
            self.transfers += out.retransmits as u64;
        }
        out.delay_s
    }

    /// Record an evaluation point on the run curve.
    pub fn record(&mut self, t: f64, epoch: u64, accuracy: f64, loss: f64) {
        self.curve.push(CurvePoint { time_s: t, epoch, accuracy, loss });
    }

    /// On-board training wall time per visit (the compute-time model:
    /// the paper's I=100 local epochs of on-board compute).
    pub fn train_time_s(&self) -> f64 {
        self.cfg.fl.train_time_s
    }
}

/// Outcome of one strategy run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub scheme: &'static str,
    pub curve: Curve,
    /// (convergence time s, plateau accuracy) per Curve::convergence.
    pub converged: Option<(f64, f64)>,
    pub final_accuracy: f64,
    pub epochs: u64,
    pub transfers: u64,
    /// Fault-injection accounting (all zero on clean runs).
    pub fault_stats: FaultStats,
}

impl RunResult {
    pub fn from_env(scheme: &'static str, env: &SimEnv, epochs: u64) -> Self {
        RunResult {
            scheme,
            converged: env.curve.convergence(0.005, 3),
            final_accuracy: env.curve.final_accuracy().unwrap_or(0.0),
            curve: env.curve.clone(),
            epochs,
            transfers: env.transfers,
            fault_stats: env.faults.stats(),
        }
    }

    /// Convergence time in simulated hours (horizon if never converged).
    pub fn convergence_hours(&self) -> f64 {
        self.converged.map(|(t, _)| t / 3600.0).unwrap_or(f64::INFINITY)
    }

    /// Earliest simulated time (seconds) the accuracy curve reaches
    /// `target` — a stopping-rule-independent speed metric for
    /// cross-scheme comparisons.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.curve.points.iter().find(|p| p.accuracy >= target).map(|p| p.time_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::train::SurrogateBackend;

    fn small_env(backend: &mut SurrogateBackend) -> SimEnv<'_> {
        let mut cfg = ExperimentConfig::test_small();
        cfg.fl.horizon_s = 3600.0 * 12.0;
        SimEnv::new(&cfg, backend)
    }

    #[test]
    fn env_builds_and_delays_positive() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(
            cfg.constellation.n_orbits,
            cfg.constellation.sats_per_orbit,
            true,
            100,
        );
        let mut env = small_env(&mut b);
        let d = env.site_link_delay(0, 0, 1000.0);
        assert!(d > 0.0 && d < 10.0, "delay {d}");
        let d2 = env.isl_hop_delay(0, 1, 1000.0);
        assert!(d2 > 0.0 && d2 < 10.0);
        assert_eq!(env.transfers, 2);
    }

    #[test]
    #[should_panic]
    fn backend_size_mismatch_panics() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(5, 8, true, 100); // 40 != 6
        SimEnv::new(&cfg, &mut b);
    }

    #[test]
    fn nominal_config_disables_faults() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(
            cfg.constellation.n_orbits,
            cfg.constellation.sats_per_orbit,
            true,
            100,
        );
        let env = small_env(&mut b);
        assert!(!env.faults.enabled(), "nominal faults must stay out of the hot path");
        assert_eq!(env.faults.stats(), crate::faults::FaultStats::default());
    }

    #[test]
    fn faulty_env_delays_never_below_clean() {
        use crate::faults::{FaultConfig, FaultScenario};
        let mut cfg = ExperimentConfig::test_small();
        cfg.fl.horizon_s = 3600.0 * 12.0;
        let mut cfg_faulty = cfg.clone();
        cfg_faulty.faults = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        let mut b1 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut clean = SimEnv::new(&cfg, &mut b1);
        let mut b2 = SurrogateBackend::paper_split(2, 3, true, 100);
        let mut faulty = SimEnv::new(&cfg_faulty, &mut b2);
        for i in 0..50 {
            let t = 100.0 * i as f64;
            let dc = clean.site_link_delay(0, 0, t);
            let df = faulty.site_link_delay(0, 0, t);
            assert!(df >= dc - 1e-12, "fault delay {df} below clean {dc}");
        }
        assert!(faulty.faults.stats().retransmits > 0, "30% loss over 50 sends");
        assert!(
            faulty.transfers > clean.transfers,
            "retransmissions must show up in the communication cost"
        );
    }

    #[test]
    fn record_builds_curve() {
        let cfg = ExperimentConfig::test_small();
        let mut b = SurrogateBackend::paper_split(
            cfg.constellation.n_orbits,
            cfg.constellation.sats_per_orbit,
            true,
            100,
        );
        let mut env = small_env(&mut b);
        env.record(0.0, 0, 0.1, 2.3);
        env.record(100.0, 1, 0.5, 1.0);
        let r = RunResult::from_env("test", &env, 2);
        assert_eq!(r.final_accuracy, 0.5);
        assert_eq!(r.epochs, 2);
    }
}
