//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! crates.io is unreachable in this environment (DESIGN.md §1), so the
//! few pieces of `anyhow` the workspace actually uses are reimplemented
//! here: [`Error`], [`Result`], the [`Context`] extension trait and the
//! [`anyhow!`] / [`bail!`] macros. Semantics match the real crate for
//! those pieces: `{}` prints the outermost message, `{:#}` prints the
//! whole context chain separated by `": "`.

use std::fmt;

/// A context-carrying error value. The first entry of `chain` is the
/// outermost (most recently attached) message; the last is the root
/// cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (the `anyhow::Error::msg`
    /// constructor the workspace uses with `map_err`).
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display + Send + Sync + 'static>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, like anyhow's alternate display
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow renders Debug as the message plus a cause list
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any std error. Mirrors anyhow: `Error` itself
// deliberately does NOT implement `std::error::Error`, which is what
// keeps this blanket impl coherent next to `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` / `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: no such file");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().root_cause(), "no such file");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn g() -> Result<()> {
            bail!("nope: {}", "reason");
        }
        assert_eq!(g().unwrap_err().to_string(), "nope: reason");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("root");
        }
        let e = inner().context("mid").context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(e.chain().count(), 3);
    }
}
