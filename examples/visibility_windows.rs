//! Constellation visibility study — the Satcom problem the paper
//! starts from (Sec. I): how sporadic and irregular are satellite↔PS
//! contacts?
//!
//! Prints, for the paper constellation over one day: per-satellite
//! visibility fractions, contact counts, mean gap between contacts,
//! and the GS-vs-HAP comparison the paper uses to motivate HAPs.
//!
//! ```bash
//! cargo run --release --example visibility_windows
//! ```

use asyncfleo::coordinator::ContactPlan;
use asyncfleo::orbit::{GeodeticSite, WalkerConstellation};
use asyncfleo::util::fmt_hms;

fn main() {
    let constellation = WalkerConstellation::paper();
    let horizon = 86_400.0;
    let sites = [
        ("GS  Rolla", GeodeticSite::rolla_gs()),
        ("HAP Rolla", GeodeticSite::rolla_hap()),
        ("GS  North Pole", GeodeticSite::north_pole_gs()),
    ];

    for (name, site) in &sites {
        let plan = ContactPlan::build(&constellation, &[*site], 10.0, horizon);
        let mut total_frac = 0.0;
        let mut total_contacts = 0usize;
        let mut worst_gap: f64 = 0.0;
        println!("\n=== {name} (min elevation 10°, 24 h) ===");
        println!("sat  orbit  windows  visible%  longest-gap");
        for sat in 0..constellation.len() {
            let ws = plan.windows(0, sat);
            let frac = plan.visibility_fraction(0, sat);
            let mut gap: f64 = 0.0;
            let mut prev_end = 0.0;
            for w in ws {
                gap = gap.max(w.start_s - prev_end);
                prev_end = w.end_s;
            }
            gap = gap.max(horizon - prev_end);
            if sat % 8 == 0 {
                println!(
                    "{:>3}  {:>5}  {:>7}  {:>7.2}%  {:>11}",
                    sat,
                    constellation.satellites[sat].orbit,
                    ws.len(),
                    frac * 100.0,
                    fmt_hms(gap)
                );
            }
            total_frac += frac;
            total_contacts += ws.len();
            worst_gap = worst_gap.max(gap);
        }
        println!("---");
        println!(
            "mean visibility {:.2}%  total contacts {}  worst gap {}",
            total_frac / constellation.len() as f64 * 100.0,
            total_contacts,
            fmt_hms(worst_gap)
        );
    }

    println!(
        "\nThe arbitrary-location sites see each satellite only sporadically \
         (the paper's core challenge); the North-Pole site sees every orbit \
         each half-period (the 'ideal setup' of FedISL/FedSat); the HAP adds \
         a small but consistent visibility margin over its GS."
    );
}
