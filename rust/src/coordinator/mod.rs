//! The L3 orchestrator: wires constellation geometry, contact plans,
//! link delays, the event queue and a compute [`crate::train::Backend`]
//! into a [`SimEnv`] that FL strategies run against.
//!
//! Layering (PR 2): [`Geometry`] holds everything immutable across runs
//! (constellation, sites, contact plan, link params) behind a
//! process-wide `Arc` cache keyed by the geometry-relevant config
//! subset; [`env::RunState`] holds what a single run mutates; `SimEnv`
//! is the facade strategies program against. Underneath the plan,
//! [`analytic`] holds the closed-form `γ(t) = γ_max` pass maps (PR 7)
//! — shared per (shell, site-latitude-band) through their own
//! process-wide cache — that [`contact`]'s scanner uses to skip whole
//! pass gaps without sampling.

pub mod analytic;
pub mod contact;
pub mod env;
pub mod geometry;

pub use contact::{worker_count, ContactPlan};
pub use env::{LaneProbe, RunResult, RunState, SimEnv, TxAction};
pub use geometry::Geometry;
