//! Property-testing substrate (no `proptest` offline), plus shared
//! reference fixtures ([`ReferenceSurrogate`]) for the run-loop
//! equivalence suite and benches.
//!
//! A seeded forall-runner over closures of `Rng`: each case draws
//! random inputs and asserts a property; on failure the failing seed is
//! printed so the case replays deterministically.
//!
//! ```ignore
//! forall(100, |rng| {
//!     let n = rng.range_usize(1, 50);
//!     // ... property ...
//! });
//! ```

use crate::coordinator::RunResult;
use crate::model::ModelParams;
use crate::train::{Backend, EvalResult, SurrogateBackend};
use crate::util::Rng;

/// Assert two finished runs are **bit-identical**: epochs, transfers,
/// fault accounting and every curve point. The shared equality gate of
/// `tests/runloop_equivalence.rs` and `benches/bench_runloop.rs` — a
/// speedup must never be reported on diverged results.
#[track_caller]
pub fn assert_runs_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.epochs, b.epochs, "{what}: epochs");
    assert_eq!(a.transfers, b.transfers, "{what}: transfers");
    assert_eq!(a.fault_stats, b.fault_stats, "{what}: fault stats");
    assert_eq!(a.curve.points.len(), b.curve.points.len(), "{what}: curve length");
    for (i, (x, y)) in a.curve.points.iter().zip(&b.curve.points).enumerate() {
        assert_eq!(x.time_s.to_bits(), y.time_s.to_bits(), "{what}: point {i} time");
        assert_eq!(x.epoch, y.epoch, "{what}: point {i} epoch");
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{what}: point {i} accuracy");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: point {i} loss");
    }
}

/// The pre-fast-path model plumbing, kept executable: wraps a
/// [`SurrogateBackend`] but implements the allocating [`Backend`]
/// methods with the original per-call ref/weight vector assembly, and
/// leaves every `*_into` variant at its allocating trait default.
/// Running a strategy against this wrapper with
/// `SimEnv::set_reference_path(true)` reproduces the pre-cache run
/// loop op-for-op — the "before" side of `tests/runloop_equivalence.rs`
/// and `benches/bench_runloop.rs`, and the proof that the fast path
/// left every float untouched.
pub struct ReferenceSurrogate(pub SurrogateBackend);

impl Backend for ReferenceSurrogate {
    fn dim(&self) -> usize {
        self.0.dim()
    }

    fn n_sats(&self) -> usize {
        self.0.n_sats()
    }

    fn shard_size(&self, sat: usize) -> usize {
        self.0.shard_size(sat)
    }

    fn init_global(&mut self, seed: i32) -> ModelParams {
        self.0.init_global(seed)
    }

    fn train_local(
        &mut self,
        sat: usize,
        params: &ModelParams,
        dispatches: usize,
    ) -> (ModelParams, f64) {
        self.0.train_local(sat, params, dispatches)
    }

    fn evaluate(&mut self, params: &ModelParams) -> EvalResult {
        self.0.evaluate(params)
    }

    fn aggregate(
        &mut self,
        prev: &ModelParams,
        models: &[&ModelParams],
        coeffs: &[f32],
        coeff_prev: f32,
    ) -> ModelParams {
        // the pre-PR-5 two-vector assembly, verbatim
        let mut refs: Vec<&ModelParams> = vec![prev];
        refs.extend_from_slice(models);
        let mut weights = vec![coeff_prev];
        weights.extend_from_slice(coeffs);
        ModelParams::weighted_sum(&refs, &weights)
    }

    fn distances(&mut self, models: &[&ModelParams], reference: &ModelParams) -> Vec<f64> {
        models.iter().map(|m| m.l2_distance(reference)).collect()
    }
}

/// Number of cases the default `forall` runs.
pub const DEFAULT_CASES: usize = 100;

/// Run `cases` property checks with derived seeds. The property panics
/// to signal failure; we wrap to report the seed.
pub fn forall_seeded(base_seed: u64, cases: usize, prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed on case {case} (replay seed: {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// `forall` with the default seed/case count.
pub fn forall(prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    forall_seeded(0xA5_F1EE7, DEFAULT_CASES, prop);
}

/// Draw a random f32 vector of length `n` ~ N(0, std).
pub fn gen_vec_f32(rng: &mut Rng, n: usize, std: f64) -> Vec<f32> {
    (0..n).map(|_| rng.normal(0.0, std) as f32).collect()
}

/// Assert two floats are within `tol` (absolute + relative).
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol})"
    );
}

/// Assert two f32 slices are element-wise within `tol`.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f64.max((*x as f64).abs()).max((*y as f64).abs());
        assert!(
            (*x as f64 - *y as f64).abs() <= tol * scale,
            "allclose failed at index {i}: {x} vs {y}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        forall_seeded(1, 25, |_rng| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 25);
    }

    #[test]
    fn forall_is_deterministic() {
        use std::sync::Mutex;
        let seen1 = Mutex::new(Vec::new());
        forall_seeded(9, 10, |rng| {
            seen1.lock().unwrap().push(rng.next_u64());
        });
        let seen2 = Mutex::new(Vec::new());
        forall_seeded(9, 10, |rng| {
            seen2.lock().unwrap().push(rng.next_u64());
        });
        assert_eq!(*seen1.lock().unwrap(), *seen2.lock().unwrap());
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall_seeded(2, 10, |rng| {
            assert!(rng.f64() < 0.5, "will fail ~half the time");
        });
    }

    #[test]
    fn assert_close_relative() {
        assert_close(1e9, 1e9 + 10.0, 1e-6);
    }

    #[test]
    #[should_panic]
    fn assert_close_fails_when_far() {
        assert_close(1.0, 2.0, 1e-3);
    }

    #[test]
    fn gen_vec_shape() {
        let mut rng = Rng::new(3);
        let v = gen_vec_f32(&mut rng, 17, 1.0);
        assert_eq!(v.len(), 17);
    }
}
