//! The ring-of-stars communication topology (paper Sec. IV-A, Fig. 3).
//!
//! Two layers:
//!
//! * **HAP layer** — the HAPs form a ring; one is designated *source*
//!   and one *sink* (typically the farthest around the ring); global
//!   models flow source→sink along both arcs, local-model sets flow the
//!   same way toward the sink, and the roles swap each global epoch
//!   (Sec. IV-B3).
//! * **SAT layer** — each HAP runs a star over its currently visible
//!   satellites, and satellites in the same orbit form intra-orbit
//!   ISL rings ([`crate::orbit::WalkerConstellation::ring_neighbors`]).
//!   Inter-orbit ISLs are deliberately absent (Doppler, Sec. IV-A).

pub mod ring;

pub use ring::HapRing;
