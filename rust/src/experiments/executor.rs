//! Deterministic parallel sweep executor.
//!
//! Experiment drivers describe their grid as a list of [`Cell`]s (one
//! configured run each) and hand it to [`run_cells`], which dispatches
//! cells to `--jobs N` worker threads (plain `std::thread::scope` —
//! the crate is offline/vendored, no rayon) and returns the results
//! **in the original cell order**, so CSV rows and stdout summaries are
//! byte-identical to a sequential run.
//!
//! Determinism contract:
//! * each cell builds its own backend and [`SimEnv`] from its own
//!   config (per-run seeding is untouched), so a cell's `RunResult` is
//!   a pure function of its config — independent of scheduling;
//! * the shared [`Geometry`] cache is prewarmed in cell order before
//!   workers start, so each unique geometry is built exactly once and
//!   workers only ever read;
//! * results are collected into order-indexed slots; writers consume
//!   them sequentially after the scope joins.
//!
//! PJRT mode stays sequential regardless of `--jobs`: the runtime
//! handle is a `thread_local` `Rc` (artifact caches are not `Sync`),
//! and compute-bound PJRT dispatch is where the wall-clock goes anyway.
//! The surrogate sweeps — the pure-L3 topology studies this executor
//! targets — parallelize fully.

use super::drivers::{run_one_with, ExpOptions};
use crate::config::ExperimentConfig;
use crate::coordinator::{Geometry, RunResult};
use crate::fl::asyncfleo::AsyncFleo;
use crate::fl::{make_strategy, Strategy};
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Which strategy a cell runs. `Clone + Send` so cells can cross into
/// worker threads; the `Box<dyn Strategy>` itself is built inside the
/// worker.
#[derive(Clone)]
pub enum CellStrategy {
    /// The stock strategy for the cell's `cfg.fl.scheme`.
    Scheme,
    /// A customized AsyncFLEO instance (ablation variants).
    Custom(AsyncFleo),
}

/// One configured run of a sweep grid.
pub struct Cell {
    /// Row label carried through to CSV/stdout in original order.
    pub label: String,
    pub cfg: ExperimentConfig,
    pub strategy: CellStrategy,
}

impl Cell {
    /// A cell running its scheme's stock strategy.
    pub fn new(label: impl Into<String>, cfg: ExperimentConfig) -> Self {
        Cell { label: label.into(), cfg, strategy: CellStrategy::Scheme }
    }

    /// A cell running a customized AsyncFLEO instance.
    pub fn custom(label: impl Into<String>, cfg: ExperimentConfig, strategy: AsyncFleo) -> Self {
        Cell { label: label.into(), cfg, strategy: CellStrategy::Custom(strategy) }
    }

    fn build_strategy(&self) -> Box<dyn Strategy> {
        match &self.strategy {
            CellStrategy::Scheme => make_strategy(self.cfg.fl.scheme),
            CellStrategy::Custom(a) => Box::new(a.clone()),
        }
    }
}

/// The worker count actually used for a grid: `--jobs`, clamped to the
/// grid size, and forced to 1 in PJRT mode (see module docs).
pub fn effective_jobs(opts: &ExpOptions, n_cells: usize) -> usize {
    if !opts.surrogate {
        return 1;
    }
    opts.jobs.clamp(1, n_cells.max(1))
}

/// Run one cell (worker body; also the `--jobs 1` path).
fn run_cell(cell: &Cell, opts: &ExpOptions) -> Result<RunResult> {
    run_one_with(&cell.cfg, opts, cell.build_strategy())
}

/// Run every cell and return results in cell order. See the module
/// docs for the determinism contract.
pub fn run_cells(cells: &[Cell], opts: &ExpOptions) -> Result<Vec<RunResult>> {
    let jobs = effective_jobs(opts, cells.len());
    if jobs <= 1 {
        return cells.iter().map(|c| run_cell(c, opts)).collect();
    }

    // Prewarm the geometry cache in deterministic cell order: each
    // unique geometry is built exactly once, before any worker races
    // for it.
    for cell in cells {
        Geometry::shared(&cell.cfg);
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<RunResult>>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let result = run_cell(&cells[i], opts);
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("executor worker left a cell unfinished")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PsPlacement, SchemeKind};
    use crate::metrics::Curve;

    fn small_cells(n: usize) -> Vec<Cell> {
        (0..n)
            .map(|i| {
                let mut cfg = ExperimentConfig::test_small();
                cfg.fl.scheme = SchemeKind::AsyncFleo;
                cfg.placement = PsPlacement::HapRolla;
                cfg.fl.horizon_s = 12.0 * 3600.0;
                cfg.fl.max_epochs = 4;
                cfg.seed = 42 + (i as u64 % 2); // two distinct seeds
                Cell::new(format!("cell{i}"), cfg)
            })
            .collect()
    }

    fn assert_curves_identical(a: &Curve, b: &Curve, what: &str) {
        assert_eq!(a.points.len(), b.points.len(), "{what}: curve length");
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.time_s, y.time_s, "{what}: point time");
            assert_eq!(x.accuracy, y.accuracy, "{what}: point accuracy");
            assert_eq!(x.loss, y.loss, "{what}: point loss");
        }
    }

    #[test]
    fn parallel_matches_sequential_bit_for_bit() {
        let cells = small_cells(6);
        let seq = ExpOptions { surrogate: true, jobs: 1, ..Default::default() };
        let par = ExpOptions { surrogate: true, jobs: 4, ..Default::default() };
        let a = run_cells(&cells, &seq).unwrap();
        let b = run_cells(&cells, &par).unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.epochs, y.epochs, "cell {i} epochs");
            assert_eq!(x.transfers, y.transfers, "cell {i} transfers");
            assert_curves_identical(&x.curve, &y.curve, &format!("cell {i}"));
        }
    }

    #[test]
    fn pjrt_mode_is_forced_sequential() {
        let opts = ExpOptions { surrogate: false, jobs: 8, ..Default::default() };
        assert_eq!(effective_jobs(&opts, 10), 1);
        let opts = ExpOptions { surrogate: true, jobs: 8, ..Default::default() };
        assert_eq!(effective_jobs(&opts, 3), 3, "clamped to grid size");
        assert_eq!(effective_jobs(&opts, 10), 8);
        let opts = ExpOptions { surrogate: true, jobs: 0, ..Default::default() };
        assert_eq!(effective_jobs(&opts, 10), 1, "jobs 0 means sequential");
    }
}
