//! FedHAP (Elmahallawy & Luo [6]): synchronous FL with HAPs as
//! collaborative parameter servers. Satellites exchange models with
//! whichever HAP sees them first; the round still waits for the whole
//! constellation (synchronous), which is why the paper reports ~30 h
//! convergence despite the improved HAP visibility.

use crate::coordinator::{RunResult, SimEnv};
use crate::fl::Strategy;

pub struct FedHap;

impl Strategy for FedHap {
    fn name(&self) -> &'static str {
        "fedhap"
    }

    fn run(&mut self, env: &mut SimEnv) -> RunResult {
        run_synchronous_hap(env)
    }
}

fn run_synchronous_hap(env: &mut SimEnv) -> RunResult {
    // Mechanically the sync engine with the configured HAP placement;
    // multi-HAP collaboration enters through next_visible_any (a
    // satellite deals with the HAP that sees it first).
    super::run_synchronous(env, "fedhap", false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement};
    use crate::coordinator::SimEnv;
    use crate::train::SurrogateBackend;

    fn run(placement: PsPlacement) -> RunResult {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = placement;
        cfg.fl.horizon_s = 96.0 * 3600.0;
        cfg.fl.max_epochs = 10;
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        FedHap.run(&mut env)
    }

    #[test]
    fn hap_rounds_complete() {
        let r = run(PsPlacement::HapRolla);
        assert!(r.epochs >= 1);
        assert!(r.final_accuracy > 0.5);
    }

    #[test]
    fn two_haps_round_no_slower() {
        let one = run(PsPlacement::HapRolla);
        let two = run(PsPlacement::TwoHaps);
        if one.epochs >= 1 && two.epochs >= 1 {
            let t1 = one.curve.points[1].time_s;
            let t2 = two.curve.points[1].time_s;
            assert!(t2 <= t1 + 60.0, "two-HAP first round {t2} vs one-HAP {t1}");
        }
    }
}
