//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! PCG32 (O'Neill 2014) seeded through SplitMix64, plus the sampling
//! helpers the rest of the crate needs: uniform ranges, Gaussian
//! (Box–Muller), Fisher–Yates shuffle and choice. Everything is
//! reproducible from a single `u64` seed — experiment configs carry the
//! seed so every paper table regenerates bit-identically.

/// PCG32: 64-bit state, 32-bit output, period 2^64.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 — used to expand one u64 seed into PCG's (state, inc).
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1; // stream must be odd
        let mut rng = Rng { state, inc, gauss_spare: None };
        rng.next_u32(); // advance past the seed-correlated first output
        rng
    }

    /// Derive an independent child stream (for per-satellite RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire rejection-free is overkill;
    /// modulo bias is < 2^-32 * n and n here is tiny).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (caches the paired variate).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.fork(0);
        let mut c2 = parent.fork(1);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
