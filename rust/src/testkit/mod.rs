//! Property-testing substrate (no `proptest` offline).
//!
//! A seeded forall-runner over closures of `Rng`: each case draws
//! random inputs and asserts a property; on failure the failing seed is
//! printed so the case replays deterministically.
//!
//! ```ignore
//! forall(100, |rng| {
//!     let n = rng.range_usize(1, 50);
//!     // ... property ...
//! });
//! ```

use crate::util::Rng;

/// Number of cases the default `forall` runs.
pub const DEFAULT_CASES: usize = 100;

/// Run `cases` property checks with derived seeds. The property panics
/// to signal failure; we wrap to report the seed.
pub fn forall_seeded(base_seed: u64, cases: usize, prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed on case {case} (replay seed: {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// `forall` with the default seed/case count.
pub fn forall(prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    forall_seeded(0xA5_F1EE7, DEFAULT_CASES, prop);
}

/// Draw a random f32 vector of length `n` ~ N(0, std).
pub fn gen_vec_f32(rng: &mut Rng, n: usize, std: f64) -> Vec<f32> {
    (0..n).map(|_| rng.normal(0.0, std) as f32).collect()
}

/// Assert two floats are within `tol` (absolute + relative).
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol})"
    );
}

/// Assert two f32 slices are element-wise within `tol`.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f64.max((*x as f64).abs()).max((*y as f64).abs());
        assert!(
            (*x as f64 - *y as f64).abs() <= tol * scale,
            "allclose failed at index {i}: {x} vs {y}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        forall_seeded(1, 25, |_rng| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 25);
    }

    #[test]
    fn forall_is_deterministic() {
        use std::sync::Mutex;
        let seen1 = Mutex::new(Vec::new());
        forall_seeded(9, 10, |rng| {
            seen1.lock().unwrap().push(rng.next_u64());
        });
        let seen2 = Mutex::new(Vec::new());
        forall_seeded(9, 10, |rng| {
            seen2.lock().unwrap().push(rng.next_u64());
        });
        assert_eq!(*seen1.lock().unwrap(), *seen2.lock().unwrap());
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall_seeded(2, 10, |rng| {
            assert!(rng.f64() < 0.5, "will fail ~half the time");
        });
    }

    #[test]
    fn assert_close_relative() {
        assert_close(1e9, 1e9 + 10.0, 1e-6);
    }

    #[test]
    #[should_panic]
    fn assert_close_fails_when_far() {
        assert_close(1.0, 2.0, 1e-3);
    }

    #[test]
    fn gen_vec_shape() {
        let mut rng = Rng::new(3);
        let v = gen_vec_f32(&mut rng, 17, 1.0);
        assert_eq!(v.len(), 17);
    }
}
