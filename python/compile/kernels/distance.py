"""L1 Pallas kernel: batched model weight-divergence (grouping metric).

    dist[n] = || models[n, :] - ref[:] ||_2

Used by the sink HAP for satellite grouping (paper Sec. IV-C1): orbit
partial models are compared against the initial global model w^0 and
orbits with similar divergence are grouped together.

TPU mapping: sequential-grid reduction — the D axis streams in TILE_D
slabs; the [N] partial sum-of-squares accumulates in the output ref
across grid steps (all steps map to the same output block), initialised
at step 0 with `pl.when`. The sqrt is applied on the final grid step so
the artifact's output is directly the Euclidean distance.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_D = 2048


def _dist_kernel(m_ref, r_ref, o_ref, *, nsteps):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    diff = m_ref[...] - r_ref[...][None, :]
    o_ref[...] += jnp.sum(diff * diff, axis=1).astype(o_ref.dtype)

    @pl.when(pl.program_id(0) == nsteps - 1)
    def _finish():
        o_ref[...] = jnp.sqrt(o_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def distance(models, ref, tile_d=DEFAULT_TILE_D, interpret=True):
    """models: [N, D], ref: [D] -> [N] Euclidean distances."""
    n, d = models.shape
    assert ref.shape == (d,)
    td = min(tile_d, d)
    dp = (d + td - 1) // td * td
    mp = jnp.pad(models, ((0, 0), (0, dp - d)))
    rp = jnp.pad(ref, (0, dp - d))
    nsteps = dp // td
    return pl.pallas_call(
        functools.partial(_dist_kernel, nsteps=nsteps),
        out_shape=jax.ShapeDtypeStruct((n,), models.dtype),
        grid=(nsteps,),
        in_specs=[
            pl.BlockSpec((n, td), lambda i: (0, i)),
            pl.BlockSpec((td,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        interpret=interpret,
    )(mp, rp)


def vmem_bytes(n, tile_d=DEFAULT_TILE_D, dtype_bytes=4):
    """Static VMEM footprint estimate for one grid step (perf model)."""
    return dtype_bytes * (n * tile_d + tile_d + n)
