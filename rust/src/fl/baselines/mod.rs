//! Baseline FL-Satcom schemes the paper compares against (Sec. II, V).
//!
//! Each is a faithful *timing + aggregation* model of the published
//! system, run over the same geometry/link substrate and the same
//! compute backend as AsyncFLEO:
//!
//! * [`fedavg`]   — vanilla synchronous FedAvg (star topology);
//! * [`fedhap`]   — FedHAP: synchronous FL with HAP PSs;
//! * [`fedisl`]   — FedISL: synchronous + intra-orbit ISL relay
//!   (arbitrary-GS and North-Pole "ideal" variants via placement);
//! * [`fedsat`]   — FedSat: asynchronous per-visit updates, NP GS;
//! * [`fedspace`] — FedSpace: scheduled aggregation + raw-data uploads;
//! * [`sinksat`]  — sink-satellite scheduling (arXiv 2302.13447):
//!   per-plane collection over the ISL graph, async plane updates.

pub mod fedavg;
pub mod fedhap;
pub mod fedisl;
pub mod fedsat;
pub mod fedspace;
pub mod sinksat;

use crate::coordinator::{SimEnv, TxAction};
use crate::fl::propagation::{
    next_live_contact, sat_receive_times, sat_receive_times_lanes_into, uplink_route_probe,
    uplink_route_replay, RouteProbe,
};
use crate::metrics::ConvergenceDetector;
use crate::model::ModelParams;
use crate::train::fedavg_weights;

/// Patience settings shared by the sync baselines.
pub(crate) const SYNC_PATIENCE: usize = 4;
pub(crate) const SYNC_MIN_DELTA: f64 = 0.003;
pub(crate) const SYNC_MIN_ROUNDS: u64 = 4;

/// One synchronous FL round starting at `t`:
///
/// 1. compute every satellite's global-model receive time (star
///    downlink, or + intra-orbit ISL when `use_isl`);
/// 2. each satellite trains for `train_time`;
/// 3. compute every local model's upload time (own next contact, or
///    ISL relay to the soonest-visible ring member when `use_isl`);
/// 4. the round completes at the *maximum* upload time — the straggler
///    bottleneck synchronous FL suffers from (paper Sec. I).
///
/// Returns `None` if any satellite cannot complete within the horizon.
pub(crate) fn sync_round_end(env: &mut SimEnv, t: f64, use_isl: bool) -> Option<f64> {
    sync_round(env, t, use_isl).map(|(end, _)| end)
}

/// [`sync_round_end`] plus typed churn consumption (the PR-1 gap):
/// returns the round end and the per-satellite participation mask. A
/// satellite dark at the round start skips the pass — it is neither
/// waited on nor aggregated — and a PS contact at a failed site slides
/// to the next live one. Both predicates are always-true with faults
/// disabled, so clean rounds make the exact same delay calls in the
/// same order and stay bit-identical.
pub(crate) fn sync_round(
    env: &mut SimEnv,
    t: f64,
    use_isl: bool,
) -> Option<(f64, Vec<bool>)> {
    if env.lanes() > 1 {
        return sync_round_lanes(env, t, use_isl);
    }
    let geo = env.geo.clone();
    let n_sats = geo.constellation.len();
    let horizon = env.cfg.fl.horizon_s;
    let train = env.cfg.fl.train_time_s;

    let participants: Vec<bool> =
        (0..n_sats).map(|sat| env.state.faults.sat_alive(sat, t)).collect();

    // the sink-side guard: first contact whose site is alive at contact
    // time (the first contact unconditionally when faults are disabled)
    fn next_live_contact(env: &mut SimEnv, sat: usize, from: f64) -> Option<(f64, usize)> {
        let plan = env.geo.clone();
        let mut t_try = from;
        for _ in 0..8 {
            match plan.plan.next_visible_any(sat, t_try) {
                Some((tv, site)) if env.state.faults.hap_alive(site, tv) => {
                    return Some((tv, site));
                }
                Some((tv, _)) => t_try = tv + 300.0,
                None => return None,
            }
        }
        None
    }

    // --- delivery ---
    let recv: Vec<f64> = if use_isl {
        let bcasts: Vec<f64> = (0..geo.sites.len()).map(|_| t).collect();
        sat_receive_times(env, &bcasts)
    } else {
        (0..n_sats)
            .map(|sat| {
                if !participants[sat] {
                    return f64::INFINITY; // skipped pass: no downlink happens
                }
                match next_live_contact(env, sat, t) {
                    Some((tv, site)) => {
                        let d = env.site_link_delay(site, sat, tv);
                        tv + d
                    }
                    None => f64::INFINITY,
                }
            })
            .collect()
    };

    // --- training + upload (skipped sats don't gate the round) ---
    let mut round_end: f64 = t;
    for sat in 0..n_sats {
        if !participants[sat] {
            continue;
        }
        if !recv[sat].is_finite() || recv[sat] > horizon {
            return None;
        }
        let done = recv[sat] + train;
        let up = if use_isl {
            crate::fl::propagation::uplink_route(env, sat, done).map(|(_, arr, _)| arr)
        } else {
            next_live_contact(env, sat, done).map(|(tv, site)| {
                let d = env.site_link_delay(site, sat, tv);
                tv + d
            })
        };
        match up {
            Some(u) if u <= horizon => round_end = round_end.max(u),
            _ => return None,
        }
    }
    Some((round_end, participants))
}

/// Multi-lane [`sync_round`]: the per-satellite contact scans run as
/// pure probes on parallel lane threads, then every fault-channel
/// outcome is replayed serially in ascending satellite order — the
/// exact call sequence of the single-lane body, so delays, transfer
/// counts and fault stats are bit-identical. Probes of satellites past
/// a serial early-return point are simply never replayed (probes are
/// pure, so an unreplayed one is unobservable).
fn sync_round_lanes(env: &mut SimEnv, t: f64, use_isl: bool) -> Option<(f64, Vec<bool>)> {
    let geo = env.geo.clone();
    let n_sats = geo.constellation.len();
    let horizon = env.cfg.fl.horizon_s;
    let train = env.cfg.fl.train_time_s;
    let lanes = env.lanes();
    let probe = env.lane_probe();
    let chunk = ((n_sats + lanes - 1) / lanes).max(1);
    let sat_ids: Vec<usize> = (0..n_sats).collect();

    let participants: Vec<bool> =
        (0..n_sats).map(|sat| env.state.faults.sat_alive(sat, t)).collect();

    // --- delivery: probe in lanes, replay in satellite order ---
    let mut recv: Vec<f64> = Vec::new();
    if use_isl {
        let bcasts: Vec<f64> = (0..geo.sites.len()).map(|_| t).collect();
        sat_receive_times_lanes_into(env, &bcasts, &mut recv);
    } else {
        let parts = &participants;
        let pr = &probe;
        let probed: Vec<(f64, Option<TxAction>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = sat_ids
                .chunks(chunk)
                .map(|ch| {
                    scope.spawn(move || {
                        ch.iter()
                            .map(|&sat| {
                                if !parts[sat] {
                                    return (f64::INFINITY, None);
                                }
                                match next_live_contact(pr.geo(), pr.schedule(), sat, t) {
                                    Some((tv, site)) => {
                                        let (d, a) = pr.site_link_delay(site, sat, tv);
                                        (tv + d, Some(a))
                                    }
                                    None => (f64::INFINITY, None),
                                }
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("delivery probe lane panicked"))
                .collect()
        });
        recv.reserve(n_sats);
        for (r, action) in probed {
            if let Some(a) = action.as_ref() {
                let _ = env.replay_tx(a);
            }
            recv.push(r);
        }
    }

    // --- training + upload: probe in lanes, replay in satellite order ---
    enum UploadProbe {
        /// Non-participant or undeliverable: the serial body never
        /// reaches this satellite's upload scan.
        Skipped,
        Isl(RouteProbe),
        Star(Option<(f64, TxAction)>),
    }
    let parts = &participants;
    let pr = &probe;
    let recv_ref = &recv;
    let probed: Vec<UploadProbe> = std::thread::scope(|scope| {
        let handles: Vec<_> = sat_ids
            .chunks(chunk)
            .map(|ch| {
                scope.spawn(move || {
                    ch.iter()
                        .map(|&sat| {
                            if !parts[sat]
                                || !recv_ref[sat].is_finite()
                                || recv_ref[sat] > horizon
                            {
                                return UploadProbe::Skipped;
                            }
                            let done = recv_ref[sat] + train;
                            if use_isl {
                                UploadProbe::Isl(uplink_route_probe(pr, sat, done))
                            } else {
                                UploadProbe::Star(
                                    next_live_contact(pr.geo(), pr.schedule(), sat, done).map(
                                        |(tv, site)| {
                                            let (d, a) = pr.site_link_delay(site, sat, tv);
                                            (tv + d, a)
                                        },
                                    ),
                                )
                            }
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("upload probe lane panicked"))
            .collect()
    });

    let mut round_end: f64 = t;
    for (sat, up) in probed.into_iter().enumerate() {
        if !participants[sat] {
            continue;
        }
        if !recv[sat].is_finite() || recv[sat] > horizon {
            return None; // same early return as the serial body
        }
        let arrival = match up {
            UploadProbe::Skipped => None, // unreachable: guarded above
            UploadProbe::Isl(rp) => uplink_route_replay(env, &rp).map(|(_, arr, _)| arr),
            UploadProbe::Star(Some((arr, a))) => {
                let _ = env.replay_tx(&a);
                Some(arr)
            }
            UploadProbe::Star(None) => None,
        };
        match arrival {
            Some(u) if u <= horizon => round_end = round_end.max(u),
            _ => return None,
        }
    }
    Some((round_end, participants))
}

/// The synchronous outer loop shared by FedAvg / FedHAP / FedISL:
/// rounds of (deliver, train-all, FedAvg-aggregate) until convergence,
/// horizon, or an incompletable round. Model buffers (`locals`, the
/// aggregate double-buffer) are allocated once and reused every round
/// through the in-place backend API — floats unchanged.
pub(crate) fn run_synchronous(
    env: &mut SimEnv,
    name: &'static str,
    use_isl: bool,
) -> crate::coordinator::RunResult {
    let n_sats = env.geo.constellation.len();
    let dispatches = env.cfg.fl.local_dispatches;
    let mut detector = ConvergenceDetector::new(SYNC_PATIENCE, SYNC_MIN_DELTA);

    let mut global = env.state.backend.init_global(env.cfg.seed as i32);
    let e0 = env.state.backend.evaluate(&global);
    env.record(0.0, 0, e0.accuracy, e0.loss);

    let sizes: Vec<usize> = (0..n_sats).map(|s| env.state.backend.shard_size(s)).collect();
    let weights = fedavg_weights(&sizes);

    let mut locals: Vec<ModelParams> =
        (0..n_sats).map(|_| ModelParams { data: Vec::new() }).collect();
    let mut next = ModelParams { data: Vec::with_capacity(global.dim()) };
    let mut t = 0.0f64;
    let mut round: u64 = 0;
    let ph_loop = env.phase_start();
    while round < env.cfg.fl.max_epochs {
        let Some((end, participants)) = sync_round(env, t, use_isl) else {
            break; // straggler cannot complete within horizon
        };
        // typed churn: a round with no live satellite produces nothing;
        // retry once the next one can start (progress is guaranteed —
        // churn downtimes are finite)
        if participants.iter().all(|&p| !p) {
            t = end.max(t) + 600.0;
            if t >= env.cfg.fl.horizon_s {
                break;
            }
            continue;
        }
        // all participating satellites train from the same global model
        // (Eq. 4); dark ones skip the pass. Clean rounds keep the full
        // set and the precomputed weights — bit-identical.
        for (sat, local) in locals.iter_mut().enumerate() {
            if participants[sat] {
                env.state.backend.train_local_into(sat, &global, dispatches, local);
            }
        }
        let ph_agg = env.phase_start();
        let n_in = if participants.iter().all(|&p| p) {
            let refs: Vec<&ModelParams> = locals.iter().collect();
            env.state.backend.aggregate_into(&global, &refs, &weights, 0.0, &mut next);
            n_sats
        } else {
            let idx: Vec<usize> = (0..n_sats).filter(|&s| participants[s]).collect();
            let sub_sizes: Vec<usize> = idx.iter().map(|&s| sizes[s]).collect();
            let sub_weights = fedavg_weights(&sub_sizes);
            let refs: Vec<&ModelParams> = idx.iter().map(|&s| &locals[s]).collect();
            env.state.backend.aggregate_into(&global, &refs, &sub_weights, 0.0, &mut next);
            idx.len()
        };
        std::mem::swap(&mut global, &mut next);
        round += 1;
        t = end;
        // synchronous rounds are staleness-free by construction: every
        // model is one round behind, no discount applies
        if let Some(obs) = env.obs() {
            obs.staleness(0.0);
            obs.aggregate(t, 1, n_in, 0.0, 1.0);
        }
        env.phase_end("aggregate", ph_agg);
        let e = env.state.backend.evaluate(&global);
        env.record(t, round, e.accuracy, e.loss);
        if detector.update(e.accuracy) && round >= SYNC_MIN_ROUNDS {
            break;
        }
        if t >= env.cfg.fl.horizon_s {
            break;
        }
    }
    env.phase_end("event_loop", ph_loop);
    crate::coordinator::RunResult::from_env(name, env, round)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement};
    use crate::train::SurrogateBackend;

    fn env_cfg(placement: PsPlacement, horizon_h: f64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = placement;
        cfg.fl.horizon_s = horizon_h * 3600.0;
        cfg
    }

    #[test]
    fn sync_round_completes_with_hap() {
        let cfg = env_cfg(PsPlacement::HapRolla, 72.0);
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        let end = sync_round_end(&mut env, 0.0, false).expect("round completes in 72h");
        assert!(end > 0.0 && end <= 72.0 * 3600.0);
    }

    #[test]
    fn sync_round_mask_consumes_typed_churn() {
        use crate::faults::{FaultConfig, FaultScenario};
        // clean: everyone participates
        let cfg = env_cfg(PsPlacement::HapRolla, 72.0);
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        let (_, mask) = sync_round(&mut env, 0.0, false).expect("clean round");
        assert!(mask.iter().all(|&p| p), "no faults, no skips");
        // churn: a dark satellite skips the pass instead of gating it
        let mut cfg = env_cfg(PsPlacement::HapRolla, 72.0);
        cfg.faults = FaultConfig::preset(FaultScenario::Churn, 1.0);
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        let sat = (0..40)
            .find(|&s| !env.state.faults.sat_downtime(s).is_empty())
            .expect("full-intensity churn over 72 h must hit someone");
        let (down, up) = env.state.faults.sat_downtime(sat)[0];
        let mid = 0.5 * (down + up);
        if let Some((_, mask)) = sync_round(&mut env, mid, false) {
            assert!(!mask[sat], "dark satellite must skip the pass");
            assert!(mask.iter().filter(|&&p| p).count() > 0);
        }
    }

    #[test]
    fn isl_round_faster_than_star_round() {
        let cfg = env_cfg(PsPlacement::GsRolla, 72.0);
        let mut b1 = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env1 = SimEnv::new(&cfg, &mut b1);
        let star = sync_round_end(&mut env1, 0.0, false);
        let mut b2 = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env2 = SimEnv::new(&cfg, &mut b2);
        let isl = sync_round_end(&mut env2, 0.0, true);
        match (star, isl) {
            (Some(s), Some(i)) => assert!(i <= s, "ISL {i} should beat star {s}"),
            (None, Some(_)) => {} // star couldn't even finish: ISL wins
            (s, i) => panic!("unexpected: star {s:?} isl {i:?}"),
        }
    }

    #[test]
    fn np_round_much_faster_than_arbitrary_gs() {
        let np = {
            let cfg = env_cfg(PsPlacement::GsNorthPole, 72.0);
            let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
            let mut env = SimEnv::new(&cfg, &mut b);
            sync_round_end(&mut env, 0.0, true).expect("NP round")
        };
        let gs = {
            let cfg = env_cfg(PsPlacement::GsRolla, 72.0);
            let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
            let mut env = SimEnv::new(&cfg, &mut b);
            sync_round_end(&mut env, 0.0, true)
        };
        if let Some(gs) = gs {
            assert!(np < gs, "NP {np} should beat arbitrary GS {gs}");
        }
        // NP sees every orbit every half period (~64 min) + train time
        assert!(np < 6.0 * 3600.0, "NP round took {} h", np / 3600.0);
    }
}
