//! Typed simulation events.

/// What happened. Payload indices refer to satellites / HAPs / orbits
/// by their dense IDs; model payloads live in the coordinator's stores
/// (events carry handles, not buffers — zero-copy hot path).
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// A satellite finished its local training dispatch.
    TrainingDone { sat: usize },
    /// A model buffer arrived at a satellite over an ISL hop.
    /// `global` tells whether it is the global model (being broadcast
    /// outward) or a local model (being relayed toward a HAP).
    SatModelArrival { sat: usize, from_sat: usize, epoch: u64, global: bool, origin_sat: usize },
    /// A local model (from `origin_sat`) arrived at a HAP (uplink or relay).
    HapLocalArrival { hap: usize, origin_sat: usize, epoch: u64 },
    /// The global model of `epoch` arrived at HAP `hap` over the IHL ring.
    HapGlobalArrival { hap: usize, epoch: u64 },
    /// A batch of local models finished the IHL trip to the sink HAP.
    SinkBatchArrival { from_hap: usize, count: usize },
    /// Time to run the aggregation decision at the sink (Sec. IV-C).
    AggregationTick,
    /// Periodic bookkeeping (visibility refresh / scheduling sweep).
    Sweep,
    /// Fault injection: a lost transfer is re-sent (`attempt` counts up
    /// from 1 for one logical transfer).
    Retransmit { sat: usize, attempt: u32 },
    /// Fault injection: a scheduled outage window opens at PS `site`.
    OutageStart { site: usize },
    /// Fault injection: the outage window at PS `site` closes (HAPs
    /// re-offer the current global model to whoever is visible).
    OutageEnd { site: usize },
    /// Fault injection: satellite `sat` drops out (`up = false`, its
    /// in-progress training result is lost) or rejoins (`up = true`).
    SatChurn { sat: usize, up: bool },
    /// Fault injection: HAP `hap` fails or recovers; the HAP ring
    /// re-heals around the change.
    HapChurn { hap: usize, up: bool },
}

/// A scheduled event.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub time_s: f64,
    pub kind: EventKind,
}

impl Event {
    pub fn new(time_s: f64, kind: EventKind) -> Self {
        assert!(time_s.is_finite(), "event time must be finite");
        Event { time_s, kind }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_construction() {
        let e = Event::new(1.5, EventKind::Sweep);
        assert_eq!(e.time_s, 1.5);
        assert_eq!(e.kind, EventKind::Sweep);
    }

    #[test]
    #[should_panic]
    fn rejects_nan_time() {
        Event::new(f64::NAN, EventKind::Sweep);
    }

    #[test]
    fn fault_events_construct() {
        let e = Event::new(2.0, EventKind::SatChurn { sat: 3, up: false });
        assert_eq!(e.kind, EventKind::SatChurn { sat: 3, up: false });
        let e = Event::new(3.0, EventKind::Retransmit { sat: 1, attempt: 2 });
        assert_ne!(e.kind, EventKind::Retransmit { sat: 1, attempt: 1 });
        assert_eq!(
            Event::new(1.0, EventKind::OutageEnd { site: 0 }).kind,
            EventKind::OutageEnd { site: 0 }
        );
    }
}
