//! Doppler-shift analysis (paper Sec. IV-A).
//!
//! The paper restricts ISLs to satellites *within the same orbit*
//! because "satellites from different orbits have very high relative
//! velocity and hence the impact of Doppler shift will become
//! prominent and make communication unstable". This module quantifies
//! that claim: the radial-velocity Doppler shift between any two
//! constellation nodes, used by `examples/visibility_windows` and the
//! topology tests to verify the design rule the paper asserts.

use super::propagation::{satellite_position_eci, satellite_velocity_eci};
use super::walker::WalkerConstellation;
use crate::util::SPEED_OF_LIGHT_KM_S;

/// Doppler shift in Hz between a transmitter and receiver with the
/// given positions (km) and velocities (km/s), at carrier `f_hz`.
///
/// Non-relativistic: Δf = -(dR/dt) · f / c where dR/dt is the radial
/// (range-rate) component of the relative velocity.
pub fn doppler_shift_hz(
    pos_tx: crate::util::Vec3,
    vel_tx: crate::util::Vec3,
    pos_rx: crate::util::Vec3,
    vel_rx: crate::util::Vec3,
    f_hz: f64,
) -> f64 {
    let rel = pos_rx - pos_tx;
    let dist = rel.norm();
    if dist == 0.0 {
        return 0.0;
    }
    let range_rate = (vel_rx - vel_tx).dot(rel) * (1.0 / dist); // km/s
    -range_rate * f_hz / SPEED_OF_LIGHT_KM_S
}

/// Doppler shift between two satellites of a constellation at time `t`.
pub fn sat_sat_doppler_hz(
    c: &WalkerConstellation,
    a: usize,
    b: usize,
    t: f64,
    f_hz: f64,
) -> f64 {
    let ea = &c.satellites[a].elements;
    let eb = &c.satellites[b].elements;
    doppler_shift_hz(
        satellite_position_eci(ea, t),
        satellite_velocity_eci(ea, t),
        satellite_position_eci(eb, t),
        satellite_velocity_eci(eb, t),
        f_hz,
    )
}

/// Worst-case |Doppler| between two satellites over a sampled window.
pub fn max_abs_doppler_hz(
    c: &WalkerConstellation,
    a: usize,
    b: usize,
    horizon_s: f64,
    step_s: f64,
    f_hz: f64,
) -> f64 {
    let mut worst: f64 = 0.0;
    let mut t = 0.0;
    while t <= horizon_s {
        worst = worst.max(sat_sat_doppler_hz(c, a, b, t, f_hz).abs());
        t += step_s;
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    const F: f64 = 2.4e9; // Table I carrier

    #[test]
    fn intra_orbit_doppler_is_negligible() {
        // Same-orbit satellites keep constant separation: range rate ~0.
        let c = WalkerConstellation::paper();
        for (a, b) in [(0usize, 1usize), (3, 4), (6, 7)] {
            let worst = max_abs_doppler_hz(&c, a, b, 7200.0, 60.0, F);
            assert!(
                worst < 100.0,
                "intra-orbit pair ({a},{b}) Doppler {worst} Hz should be ~0"
            );
        }
    }

    #[test]
    fn inter_orbit_doppler_is_prominent() {
        // Cross-plane pairs close at up to ~2x orbital velocity:
        // tens of kHz at 2.4 GHz — the paper's instability argument.
        let c = WalkerConstellation::paper();
        let worst = max_abs_doppler_hz(&c, 0, 8, 7200.0, 60.0, F);
        assert!(
            worst > 10_000.0,
            "inter-orbit Doppler {worst} Hz should be prominent"
        );
    }

    #[test]
    fn inter_orbit_dwarfs_intra_orbit() {
        let c = WalkerConstellation::paper();
        let intra = max_abs_doppler_hz(&c, 0, 1, 7200.0, 60.0, F);
        let inter = max_abs_doppler_hz(&c, 0, 8, 7200.0, 60.0, F);
        assert!(
            inter > 100.0 * intra.max(1.0),
            "inter {inter} Hz vs intra {intra} Hz"
        );
    }

    #[test]
    fn doppler_sign_matches_geometry() {
        // Approaching -> positive shift; receding -> negative.
        use crate::util::Vec3;
        let p1 = Vec3::new(0.0, 0.0, 0.0);
        let p2 = Vec3::new(1000.0, 0.0, 0.0);
        let approaching = doppler_shift_hz(
            p1,
            Vec3::new(0.0, 0.0, 0.0),
            p2,
            Vec3::new(-5.0, 0.0, 0.0), // rx moving toward tx
            F,
        );
        assert!(approaching > 0.0);
        let receding = doppler_shift_hz(
            p1,
            Vec3::new(0.0, 0.0, 0.0),
            p2,
            Vec3::new(5.0, 0.0, 0.0),
            F,
        );
        assert!(receding < 0.0);
        assert!((approaching + receding).abs() < 1e-9);
    }

    #[test]
    fn zero_shift_at_closest_approach() {
        use crate::util::Vec3;
        // Velocity perpendicular to the line of sight is exactly the
        // closest-approach condition: range rate 0, shift 0.
        let d = doppler_shift_hz(
            Vec3::ZERO,
            Vec3::ZERO,
            Vec3::new(1000.0, 0.0, 0.0),
            Vec3::new(0.0, 7.5, 0.0),
            F,
        );
        assert_eq!(d, 0.0);

        // Same fact on real orbits: at the sampled distance minimum of
        // a cross-plane pair the shift passes through ~0, far below the
        // pair's worst case.
        let c = WalkerConstellation::paper();
        let (a, b) = (0usize, 8usize);
        let mut t_min = 0.0;
        let mut d_min = f64::INFINITY;
        let mut t = 0.0;
        while t <= 7200.0 {
            let ea = &c.satellites[a].elements;
            let eb = &c.satellites[b].elements;
            let d = (satellite_position_eci(ea, t) - satellite_position_eci(eb, t)).norm();
            if d < d_min {
                d_min = d;
                t_min = t;
            }
            t += 1.0;
        }
        let at_min = sat_sat_doppler_hz(&c, a, b, t_min, F).abs();
        let worst = max_abs_doppler_hz(&c, a, b, 7200.0, 60.0, F);
        assert!(
            at_min < 0.05 * worst,
            "closest approach shift {at_min} Hz vs worst {worst} Hz"
        );
    }

    #[test]
    fn shift_is_endpoint_symmetric() {
        // Swapping tx and rx negates both the separation vector and the
        // relative velocity, leaving the range rate — and the shift —
        // bit-identical. The graph relies on this to keep edge delays
        // direction-free.
        let c = WalkerConstellation::paper();
        for (a, b) in [(0usize, 1usize), (0, 8), (5, 23)] {
            for &t in &[0.0, 900.0, 3600.0] {
                let ab = sat_sat_doppler_hz(&c, a, b, t, F);
                let ba = sat_sat_doppler_hz(&c, b, a, t, F);
                assert_eq!(ab.to_bits(), ba.to_bits(), "pair ({a},{b}) at t={t}");
            }
        }
    }

    #[test]
    fn shift_magnitude_bounded_by_relative_speed() {
        // |Δf| <= |v_rel| f / c: the radial component never exceeds the
        // full relative speed, itself at most |v_a| + |v_b|.
        let c = WalkerConstellation::paper();
        for (a, b) in [(0usize, 1usize), (0, 8), (10, 30)] {
            let mut t = 0.0;
            while t <= 7200.0 {
                let va = satellite_velocity_eci(&c.satellites[a].elements, t).norm();
                let vb = satellite_velocity_eci(&c.satellites[b].elements, t).norm();
                let bound = (va + vb) * F / SPEED_OF_LIGHT_KM_S;
                let shift = sat_sat_doppler_hz(&c, a, b, t, F).abs();
                assert!(
                    shift <= bound * (1.0 + 1e-12),
                    "pair ({a},{b}) at t={t}: {shift} Hz > bound {bound} Hz"
                );
                t += 120.0;
            }
        }
    }

    #[test]
    fn doppler_scale_sanity() {
        // 5 km/s radial at 2.4 GHz is ~40 kHz.
        use crate::util::Vec3;
        let d = doppler_shift_hz(
            Vec3::ZERO,
            Vec3::ZERO,
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(-5.0, 0.0, 0.0),
            F,
        );
        assert!((d - 5.0 * F / SPEED_OF_LIGHT_KM_S).abs() < 1e-6);
        assert!((d - 40_028.0).abs() < 100.0, "{d}");
    }
}
