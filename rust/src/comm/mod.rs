//! RF link-budget and delay model (paper Sec. III-B, Eqs. 5–9).
//!
//! All links (SAT↔SAT ISL, SAT↔HAP, HAP↔HAP IHL, SAT↔GS) are modelled
//! as RF for a fair comparison with the paper's baselines; Table I's
//! parameters are the defaults. The model computes free-space path
//! loss, SNR, Shannon capacity, and the total delay decomposition
//! `t_c = t_t + t_p + t_x + t_y`.

pub mod delay;
pub mod link;

pub use delay::{total_delay_s, DelayBreakdown};
pub use link::LinkParams;
