//! Bit-identity contract of the fast contact scanner (PR 4).
//!
//! `ContactPlan::build` is a four-layer rework of the geometry hot path
//! (plane-basis propagation, time-major position sharing, provable
//! interval skipping, parallel per-satellite rows). Its entire license
//! to exist is that the output is **bit-for-bit** the naive pre-PR
//! sweep's — kept in-tree as `ContactPlan::build_reference`, the
//! executable specification. This test sweeps every scenario preset
//! and asserts:
//!
//! * fast single-thread scan ≡ reference scan (to_bits equality on
//!   every window edge of every (site, sat) pair);
//! * the scan with the analytic pass-gap predictor disabled ≡ reference
//!   (PR 7: the closed-form skip may only remove provably-invisible
//!   samples, never change which grid points flip);
//! * 4-thread build ≡ 1-thread build (the parallel builder writes rows
//!   by index, so thread count must never leak into the plan);
//! * the default `build` entry point (auto thread count) ≡ both.

use asyncfleo::coordinator::ContactPlan;
use asyncfleo::orbit::WalkerConstellation;
use asyncfleo::scenario::ScenarioRegistry;

fn assert_bit_identical(a: &ContactPlan, b: &ContactPlan, n_sats: usize, what: &str) {
    assert_eq!(a.n_sites(), b.n_sites(), "{what}: site count");
    for site in 0..a.n_sites() {
        for sat in 0..n_sats {
            let wa = a.windows(site, sat);
            let wb = b.windows(site, sat);
            assert_eq!(wa.len(), wb.len(), "{what}: site {site} sat {sat} window count");
            for (x, y) in wa.iter().zip(wb) {
                assert_eq!(
                    x.start_s.to_bits(),
                    y.start_s.to_bits(),
                    "{what}: site {site} sat {sat} start {} vs {}",
                    x.start_s,
                    y.start_s
                );
                assert_eq!(
                    x.end_s.to_bits(),
                    y.end_s.to_bits(),
                    "{what}: site {site} sat {sat} end {} vs {}",
                    x.end_s,
                    y.end_s
                );
            }
        }
    }
}

#[test]
fn fast_scanner_bit_identical_to_reference_on_every_preset() {
    for sc in ScenarioRegistry::builtin().iter() {
        let cfg = &sc.cfg;
        let constellation = WalkerConstellation::from_shells(&cfg.constellation.shells());
        let sites = cfg.placement.sites();
        // the reference is a dense O(sites × sats × steps) sweep;
        // shorten the horizon on big worlds so the debug-mode test
        // stays affordable (the scan logic has no horizon-dependent
        // branches — every code path runs within hours of simulated
        // time)
        let horizon_s = if constellation.len() > 5000 {
            2.0 * 3600.0
        } else if constellation.len() > 100 {
            6.0 * 3600.0
        } else {
            86_400.0
        };
        let min_elev = cfg.min_elevation_deg;

        let reference = ContactPlan::build_reference(&constellation, &sites, min_elev, horizon_s);
        let fast1 = ContactPlan::build_with_threads(&constellation, &sites, min_elev, horizon_s, 1);
        assert_bit_identical(
            &reference,
            &fast1,
            constellation.len(),
            &format!("{}: fast(1) vs reference", sc.name),
        );

        // the rate-bound-only scanner (analytic layer disabled) must
        // also match: the pass-gap skip may only remove work, never
        // change which grid samples flip
        let scan_only =
            ContactPlan::build_with_options(&constellation, &sites, min_elev, horizon_s, 1, false);
        assert_bit_identical(
            &reference,
            &scan_only,
            constellation.len(),
            &format!("{}: scan-only vs reference", sc.name),
        );

        let fast4 = ContactPlan::build_with_threads(&constellation, &sites, min_elev, horizon_s, 4);
        assert_bit_identical(
            &fast1,
            &fast4,
            constellation.len(),
            &format!("{}: fast(4) vs fast(1)", sc.name),
        );

        let auto = ContactPlan::build(&constellation, &sites, min_elev, horizon_s);
        assert_bit_identical(
            &fast1,
            &auto,
            constellation.len(),
            &format!("{}: build() vs fast(1)", sc.name),
        );

        // the comparison must not be vacuous: every preset world has
        // contacts within the tested horizon
        let total: usize = (0..sites.len())
            .map(|site| {
                (0..constellation.len())
                    .map(|sat| reference.windows(site, sat).len())
                    .sum::<usize>()
            })
            .sum();
        assert!(total > 0, "{}: no contact windows in {horizon_s} s", sc.name);
    }
}
