//! Pre-computed contact plan: visibility windows between every
//! satellite and every PS site over the experiment horizon.
//!
//! The PS knows each satellite's TLE (paper Sec. V-A) and can predict
//! visits; pre-computing the windows once keeps the event loop free of
//! trigonometry (perf: the coordinator must never be the bottleneck).

use crate::orbit::{
    contact_windows, elevation_deg, ContactWindow, GeodeticSite, WalkerConstellation,
};

/// Contact windows for all (satellite, site) pairs over `[0, horizon]`.
pub struct ContactPlan {
    /// windows[site][sat] sorted by start time.
    windows: Vec<Vec<Vec<ContactWindow>>>,
    pub horizon_s: f64,
}

/// Sampling step for window extraction (edges refined by bisection).
const SCAN_STEP_S: f64 = 30.0;

impl ContactPlan {
    pub fn build(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
    ) -> Self {
        let windows = sites
            .iter()
            .map(|site| {
                // HAPs gain horizon dip: theta_min is measured from the
                // apparent horizon (the paper's "slightly better
                // visibility" of elevated platforms).
                let eff_min = site.effective_min_elevation_deg(min_elev_deg);
                (0..constellation.len())
                    .map(|sat| {
                        contact_windows(
                            |t| {
                                elevation_deg(
                                    site.position_eci(t),
                                    constellation.position(sat, t),
                                ) >= eff_min
                            },
                            horizon_s,
                            SCAN_STEP_S,
                        )
                    })
                    .collect()
            })
            .collect();
        let plan = ContactPlan { windows, horizon_s };
        // Window times are finite by construction (finite horizon/step,
        // bisection only averages); assert it once here so every
        // downstream total-order min / sort / event push can rely on it
        // instead of carrying per-call `partial_cmp(..).unwrap()` panic
        // paths.
        for site_windows in &plan.windows {
            for sat_windows in site_windows {
                for w in sat_windows {
                    assert!(
                        w.start_s.is_finite() && w.end_s.is_finite(),
                        "non-finite contact window {w:?}"
                    );
                }
            }
        }
        plan
    }

    pub fn n_sites(&self) -> usize {
        self.windows.len()
    }

    pub fn windows(&self, site: usize, sat: usize) -> &[ContactWindow] {
        &self.windows[site][sat]
    }

    /// Is `sat` visible from `site` at time `t`?
    pub fn visible(&self, site: usize, sat: usize, t: f64) -> bool {
        self.window_at(site, sat, t).is_some()
    }

    /// The window containing `t`, if any (binary search).
    pub fn window_at(&self, site: usize, sat: usize, t: f64) -> Option<ContactWindow> {
        let ws = &self.windows[site][sat];
        let idx = ws.partition_point(|w| w.end_s < t);
        ws.get(idx).filter(|w| w.contains(t)).copied()
    }

    /// Earliest time ≥ `t` at which `sat` is visible from `site`
    /// (start of the next window, or `t` itself if inside one).
    pub fn next_visible(&self, site: usize, sat: usize, t: f64) -> Option<f64> {
        let ws = &self.windows[site][sat];
        let idx = ws.partition_point(|w| w.end_s < t);
        ws.get(idx).map(|w| w.start_s.max(t))
    }

    /// All satellites visible from `site` at `t`, in id order.
    /// Allocation-free: callers iterate (or `collect` when they truly
    /// need a `Vec`) — this sits inside broadcast/relay hot loops.
    pub fn visible_sats(&self, site: usize, t: f64) -> impl Iterator<Item = usize> + '_ {
        (0..self.windows[site].len()).filter(move |&s| self.visible(site, s, t))
    }

    /// Earliest time ≥ `t` at which `sat` is visible from *any* site;
    /// returns `(time, site)`. Window times are asserted finite at
    /// construction, so the total-order comparison here can never meet
    /// (or be confused by) a NaN — no panic path.
    pub fn next_visible_any(&self, sat: usize, t: f64) -> Option<(f64, usize)> {
        (0..self.n_sites())
            .filter_map(|site| self.next_visible(site, sat, t).map(|tt| (tt, site)))
            .min_by(|a, b| a.0.total_cmp(&b.0))
    }

    /// Fraction of the horizon that `sat` is visible from `site`.
    pub fn visibility_fraction(&self, site: usize, sat: usize) -> f64 {
        self.windows[site][sat].iter().map(|w| w.duration_s()).sum::<f64>() / self.horizon_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::GeodeticSite;

    fn plan() -> (WalkerConstellation, ContactPlan) {
        let c = WalkerConstellation::paper();
        let sites = [GeodeticSite::rolla_hap(), GeodeticSite::portland_hap()];
        let p = ContactPlan::build(&c, &sites, 10.0, 86_400.0);
        (c, p)
    }

    #[test]
    fn consistency_with_live_predicate() {
        let (c, p) = plan();
        let site = GeodeticSite::rolla_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        // away from window edges the plan matches the live predicate
        for sat in [0usize, 13, 39] {
            for i in 0..48 {
                let t = i as f64 * 1800.0;
                let live =
                    elevation_deg(site.position_eci(t), c.position(sat, t)) >= eff;
                let planned = p.visible(0, sat, t);
                if live != planned {
                    // tolerate only near-edge disagreement (< 60 s)
                    let near_edge = p.windows(0, sat).iter().any(|w| {
                        (w.start_s - t).abs() < 60.0 || (w.end_s - t).abs() < 60.0
                    });
                    assert!(near_edge, "sat {sat} t {t}: live {live} vs plan {planned}");
                }
            }
        }
    }

    #[test]
    fn next_visible_is_window_start_or_now() {
        let (_, p) = plan();
        let ws = p.windows(0, 0);
        assert!(!ws.is_empty());
        let w0 = ws[0];
        if w0.start_s > 10.0 {
            assert_eq!(p.next_visible(0, 0, 0.0), Some(w0.start_s));
        }
        let inside = 0.5 * (w0.start_s + w0.end_s);
        assert_eq!(p.next_visible(0, 0, inside), Some(inside));
        // after the window: the next one
        if ws.len() > 1 {
            assert_eq!(p.next_visible(0, 0, w0.end_s + 1.0), Some(ws[1].start_s));
        }
    }

    #[test]
    fn every_sat_gets_contact_within_a_day() {
        let (_, p) = plan();
        for sat in 0..40 {
            assert!(
                p.next_visible_any(sat, 0.0).is_some(),
                "sat {sat} never visible from either HAP in 24 h"
            );
        }
    }

    #[test]
    fn visible_sats_matches_visible() {
        let (_, p) = plan();
        let t = 43_200.0;
        let vs: Vec<usize> = p.visible_sats(0, t).collect();
        for sat in 0..40 {
            assert_eq!(vs.contains(&sat), p.visible(0, sat, t));
        }
    }

    #[test]
    fn visibility_fraction_sporadic() {
        let (_, p) = plan();
        for sat in 0..40 {
            let f = p.visibility_fraction(0, sat);
            assert!((0.0..0.6).contains(&f), "sat {sat} fraction {f}");
        }
    }
}
