//! ASCII line charts for accuracy/loss curves (terminal-friendly
//! rendering of the paper's Fig. 6–8 series; used by the CLI and the
//! examples — no plotting library offline).

use super::Curve;

/// Render one curve as an ASCII chart of `width` x `height` cells.
/// X = simulated hours, Y = accuracy in [0, 1].
pub fn render_curve(curve: &Curve, width: usize, height: usize) -> String {
    render_multi(&[("", curve)], width, height)
}

/// Render several named curves on shared axes; each series gets a
/// distinct glyph.
pub fn render_multi(series: &[(&str, &Curve)], width: usize, height: usize) -> String {
    assert!(width >= 16 && height >= 4, "chart too small");
    const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

    let t_max = series
        .iter()
        .flat_map(|(_, c)| c.points.last())
        .map(|p| p.time_s)
        .fold(0.0f64, f64::max)
        .max(1e-9);

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, curve)) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        // piecewise-linear resample onto the grid columns
        for col in 0..width {
            let t = t_max * col as f64 / (width - 1) as f64;
            if let Some(acc) = sample_at(curve, t) {
                let row = ((1.0 - acc.clamp(0.0, 1.0)) * (height - 1) as f64).round() as usize;
                grid[row.min(height - 1)][col] = glyph;
            }
        }
    }

    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        let y = 1.0 - r as f64 / (height - 1) as f64;
        out.push_str(&format!("{:>5.2} |", y));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("      +{}\n", "-".repeat(width)));
    out.push_str(&format!(
        "       0h{:>width$.1}h\n",
        t_max / 3600.0,
        width = width - 2
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        if !name.is_empty() {
            out.push_str(&format!("       {} {}\n", GLYPHS[si % GLYPHS.len()], name));
        }
    }
    out
}

/// Linear interpolation of the accuracy curve at time `t` (None before
/// the first point).
fn sample_at(curve: &Curve, t: f64) -> Option<f64> {
    let pts = &curve.points;
    if pts.is_empty() || t < pts[0].time_s {
        return None;
    }
    match pts.iter().position(|p| p.time_s > t) {
        None => Some(pts.last().unwrap().accuracy),
        Some(0) => Some(pts[0].accuracy),
        Some(i) => {
            let (a, b) = (&pts[i - 1], &pts[i]);
            let span = (b.time_s - a.time_s).max(1e-12);
            let w = (t - a.time_s) / span;
            Some(crate::util::lerp(a.accuracy, b.accuracy, w))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::CurvePoint;

    fn curve(pts: &[(f64, f64)]) -> Curve {
        let mut c = Curve::default();
        for (i, &(t, a)) in pts.iter().enumerate() {
            c.push(CurvePoint { time_s: t, epoch: i as u64, accuracy: a, loss: 0.0 });
        }
        c
    }

    #[test]
    fn renders_with_axes() {
        let c = curve(&[(0.0, 0.1), (3600.0, 0.5), (7200.0, 0.9)]);
        let s = render_curve(&c, 40, 10);
        assert!(s.contains('*'));
        assert!(s.contains("0h"));
        assert!(s.contains("2.0h"));
        assert_eq!(s.lines().count(), 12);
    }

    #[test]
    fn sample_interpolates() {
        let c = curve(&[(0.0, 0.0), (100.0, 1.0)]);
        assert_eq!(sample_at(&c, 50.0), Some(0.5));
        assert_eq!(sample_at(&c, 0.0), Some(0.0));
        assert_eq!(sample_at(&c, 1000.0), Some(1.0));
        assert_eq!(sample_at(&Curve::default(), 1.0), None);
    }

    #[test]
    fn multi_series_distinct_glyphs() {
        let a = curve(&[(0.0, 0.2), (1000.0, 0.8)]);
        let b = curve(&[(0.0, 0.8), (1000.0, 0.2)]);
        let s = render_multi(&[("up", &a), ("down", &b)], 30, 8);
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("up") && s.contains("down"));
    }

    #[test]
    #[should_panic]
    fn too_small_panics() {
        render_curve(&Curve::default(), 4, 2);
    }
}
