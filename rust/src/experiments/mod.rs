//! Experiment drivers regenerating every paper table & figure
//! (DESIGN.md §4 maps each driver to its paper artifact), plus the
//! [`resilience`] sweep comparing graceful degradation across schemes
//! under the `crate::faults` scenarios.
//!
//! Every driver describes its grid as [`executor::Cell`]s and runs it
//! through the deterministic parallel [`executor`] (`--jobs N`);
//! results come back in cell order so output files are byte-identical
//! at any job count.

pub mod drivers;
pub mod executor;
pub mod resilience;

pub use drivers::{run_experiment, ExpOptions, ALL_EXPERIMENTS, TABLE2_ROWS};
pub use executor::{run_cells, Cell, CellStrategy};
