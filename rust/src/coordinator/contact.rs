//! Pre-computed contact plan: visibility windows between every
//! satellite and every PS site over the experiment horizon.
//!
//! The PS knows each satellite's TLE (paper Sec. V-A) and can predict
//! visits; pre-computing the windows once keeps the event loop free of
//! trigonometry (perf: the coordinator must never be the bottleneck).
//!
//! # The fast scanner (PR 4)
//!
//! [`ContactPlan::build`] used to re-propagate the whole constellation
//! per (site, sat) pair over the full horizon — ~8 M predicate calls on
//! a `starlink-lite` world, each paying two rotation matrices and fresh
//! site trig, on one thread. The production path now stacks four
//! optimizations, all of them **bit-identity preserving** (the naive
//! per-pair sweep is kept as [`ContactPlan::build_reference`], and
//! `tests/contact_equivalence.rs` asserts bitwise-equal windows on
//! every scenario preset):
//!
//! 1. **Plane-basis propagation** — satellite positions evaluate
//!    through the constellation's cached [`PlaneBasis`] values (one
//!    sin/cos pair + multiply-adds per call instead of a fresh
//!    `rot_x`+`rot_z` chain).
//! 2. **Time-major sharing** — each site's position is computed once
//!    per grid step into a shared table (instead of once per
//!    (pair, step)), and each satellite's position once per step across
//!    all its site pairs; per grid step the scan does O(sites + sats)
//!    position work, not O(sites × sats).
//! 3. **Provable interval skipping** — see below: whole grid intervals
//!    where no visibility flip can occur evaluate *nothing*; the
//!    remaining steps sample the exact same grid points and bisection
//!    brackets as the reference.
//! 4. **Parallel build** — per-satellite scan rows fan out across a
//!    `std::thread::scope` pool ([`worker_count`] governs the pool size
//!    here and in the sweep executor), each row writing its result slot
//!    by index, so the plan is deterministic — and bit-identical —
//!    regardless of thread count.
//!
//! # Why interval skipping is safe (the rate bound)
//!
//! For a site at geocentric radius `a` and a circular-orbit satellite
//! at radius `b > a`, elevation is a function of the central angle `γ`
//! between their direction vectors with derivative
//! `de/dγ = −b(b − a·cos γ) / d²` where `d² = a² + b² − 2ab·cos γ` is
//! the squared slant range. `|de/dγ|` is increasing in `cos γ`
//! (d/d(cos γ) ∝ a(b² − a²) > 0), so it is maximized overhead (γ = 0)
//! at `b/(b − a)`. The direction vectors themselves rotate at fixed
//! angular speeds — the satellite's at its mean motion `n`, the site's
//! at `ω_E·cos(lat) ≤ ω_E` — and the angle between two unit vectors
//! changes no faster than the sum of their angular speeds. Hence
//!
//! ```text
//! |de/dt| ≤ (n + ω_E) · b/(b − a)   =: rate(site, sat)
//! ```
//!
//! If a sample at grid time `t_i` shows elevation `e_i`, a visibility
//! flip (crossing `eff_min`) is impossible before
//! `t_i + |e_i − eff_min| / rate`. Every grid point strictly inside
//! that window provably carries the same visibility value, so the
//! scanner jumps straight to the first grid index at or beyond it
//! ([`SKIP_SAFETY`] shaves 0.1 % off the window to absorb the
//! floating-point rounding of the bound arithmetic itself). When a flip
//! *is* detected at grid index `j`, the previous grid point `j − 1` is
//! by construction inside some earlier sample's proven-constant window,
//! so the bisection bracket `[t_{j−1}, t_j]` — and therefore the
//! refined edge — is exactly the reference scanner's.

use crate::orbit::{
    bisect_edge, elevation_deg, scan_grid, ContactWindow, GeodeticSite, PlaneBasis,
    SitePropagator, WalkerConstellation, EARTH_RADIUS_KM, EARTH_ROTATION_RAD_S,
};
use crate::util::Vec3;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Contact windows for all (satellite, site) pairs over `[0, horizon]`.
pub struct ContactPlan {
    /// windows[site][sat] sorted by start time.
    windows: Vec<Vec<Vec<ContactWindow>>>,
    pub horizon_s: f64,
}

/// Sampling step for window extraction (edges refined by bisection).
/// Public as [`ContactPlan::SCAN_STEP_S`] so bench artifacts report the
/// actual scan resolution instead of duplicating the number.
const SCAN_STEP_S: f64 = 30.0;

/// Safety margin on the provable skip window: strictly conservative
/// against the (at most a-few-ulp) floating-point rounding of the
/// bound arithmetic, while giving up a negligible amount of skipping.
const SKIP_SAFETY: f64 = 0.999;

/// Worker-thread count for `n_units` independent units of work: the
/// requested count clamped to `[1, n_units]`. One policy shared by the
/// parallel plan builder (per-satellite rows) and the sweep executor
/// (`experiments::executor::effective_jobs`, per-cell grid).
pub fn worker_count(requested: usize, n_units: usize) -> usize {
    requested.clamp(1, n_units.max(1))
}

/// Provable bound on |d(elevation)/dt| for one (site, satellite) pair,
/// rad/s — the module-docs rate bound `(n + ω_E) · b/(b − a)`.
fn elevation_rate_bound_rad_s(site: &GeodeticSite, basis: &PlaneBasis) -> f64 {
    let a = EARTH_RADIUS_KM + site.alt_km;
    let b = basis.radius_km();
    assert!(b > a, "rate bound needs the satellite above the site ({b} km vs {a} km)");
    (basis.mean_motion_rad_s() + EARTH_ROTATION_RAD_S) * b / (b - a)
}

/// First grid index after `i` at which the pair must actually be
/// sampled: the elevation deficit from the visibility threshold closes
/// no faster than `rate_rad_s`, so every grid point strictly inside the
/// deficit/rate window provably keeps the current visibility value.
fn next_check_index(
    i: usize,
    elev_deg: f64,
    eff_min_deg: f64,
    rate_rad_s: f64,
    step_s: f64,
) -> usize {
    let deficit_rad = (elev_deg - eff_min_deg).abs().to_radians();
    let dt = SKIP_SAFETY * deficit_rad / rate_rad_s;
    i + ((dt / step_s).ceil() as usize).max(1)
}

/// Per-(site, sat) scan state of the skipping scanner.
struct PairScan {
    prev_v: bool,
    start: Option<f64>,
    windows: Vec<ContactWindow>,
    /// Earliest grid index at which a visibility flip is possible.
    next_check: usize,
}

impl ContactPlan {
    /// The grid resolution every plan is scanned at, seconds.
    pub const SCAN_STEP_S: f64 = SCAN_STEP_S;

    /// Build the plan with the fast scanner on an automatically sized
    /// worker pool (available parallelism, clamped to the satellite
    /// count). The result is bit-identical at any thread count, so the
    /// sweep executor's byte-equality contract is unaffected.
    pub fn build(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
    ) -> Self {
        let requested = std::thread::available_parallelism().map_or(1, |n| n.get());
        Self::build_with_threads(
            constellation,
            sites,
            min_elev_deg,
            horizon_s,
            worker_count(requested, constellation.len()),
        )
    }

    /// Build the plan with the fast scanner on exactly `jobs` worker
    /// threads (1 = scan on the calling thread). Windows are
    /// bit-identical to [`Self::build_reference`] regardless of `jobs`
    /// (asserted by `tests/contact_equivalence.rs`).
    pub fn build_with_threads(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
        jobs: usize,
    ) -> Self {
        let grid = scan_grid(horizon_s, SCAN_STEP_S);
        let n_sats = constellation.len();
        let n_sites = sites.len();
        let site_props: Vec<SitePropagator> = sites.iter().map(SitePropagator::new).collect();
        // time-major site table: every site position computed once per
        // grid step, shared by all satellite rows (and worker threads)
        let site_grids: Vec<Vec<Vec3>> = site_props
            .iter()
            .map(|p| grid.iter().map(|&t| p.position_at(t)).collect())
            .collect();
        // HAPs gain horizon dip: theta_min is measured from the
        // apparent horizon (the paper's "slightly better visibility"
        // of elevated platforms).
        let eff_min: Vec<f64> =
            sites.iter().map(|s| s.effective_min_elevation_deg(min_elev_deg)).collect();

        // One satellite's scan row: all its site pairs swept together
        // over the grid, so its position is computed at most once per
        // step — and not at all on steps every pair provably skips.
        let scan_sat = |sat: usize| -> Vec<Vec<ContactWindow>> {
            let basis = constellation.propagator(sat);
            let rates: Vec<f64> =
                sites.iter().map(|s| elevation_rate_bound_rad_s(s, basis)).collect();
            let sat0 = basis.position_at(grid[0]);
            let mut pairs: Vec<PairScan> = (0..n_sites)
                .map(|s| {
                    let e = elevation_deg(site_grids[s][0], sat0);
                    let v = e >= eff_min[s];
                    PairScan {
                        prev_v: v,
                        start: if v { Some(0.0) } else { None },
                        windows: Vec::new(),
                        next_check: next_check_index(0, e, eff_min[s], rates[s], SCAN_STEP_S),
                    }
                })
                .collect();
            let mut i = 1;
            while i < grid.len() {
                // jump straight past steps every pair provably skips
                let due = pairs.iter().map(|p| p.next_check).min().unwrap_or(usize::MAX);
                if due > i {
                    if due >= grid.len() {
                        break;
                    }
                    i = due;
                    continue;
                }
                let t = grid[i];
                let mut sat_pos: Option<Vec3> = None;
                for s in 0..n_sites {
                    if pairs[s].next_check > i {
                        continue;
                    }
                    let sp = *sat_pos.get_or_insert_with(|| basis.position_at(t));
                    let e = elevation_deg(site_grids[s][i], sp);
                    let v = e >= eff_min[s];
                    let pair = &mut pairs[s];
                    if v != pair.prev_v {
                        // grid[i-1] provably carries prev_v (it is
                        // inside the window that let us skip to i, or
                        // it was sampled), so this is the reference
                        // scanner's bracket — and the same edge
                        let edge = bisect_edge(
                            &mut |tt: f64| {
                                elevation_deg(
                                    site_props[s].position_at(tt),
                                    basis.position_at(tt),
                                ) >= eff_min[s]
                            },
                            grid[i - 1],
                            t,
                            pair.prev_v,
                        );
                        if v {
                            pair.start = Some(edge);
                        } else if let Some(ws) = pair.start.take() {
                            pair.windows.push(ContactWindow { start_s: ws, end_s: edge });
                        }
                    }
                    pair.prev_v = v;
                    pair.next_check = next_check_index(i, e, eff_min[s], rates[s], SCAN_STEP_S);
                }
                i += 1;
            }
            pairs
                .into_iter()
                .map(|mut pair| {
                    if let Some(ws) = pair.start.take() {
                        pair.windows.push(ContactWindow { start_s: ws, end_s: horizon_s });
                    }
                    pair.windows
                })
                .collect()
        };

        let per_sat: Vec<Vec<Vec<ContactWindow>>> = if jobs <= 1 {
            (0..n_sats).map(scan_sat).collect()
        } else {
            // fan satellite rows across a scoped pool; every row lands
            // in its index-addressed slot, so the assembled plan is
            // independent of scheduling
            let next = AtomicUsize::new(0);
            let slots: Mutex<Vec<Option<Vec<Vec<ContactWindow>>>>> =
                Mutex::new((0..n_sats).map(|_| None).collect());
            std::thread::scope(|scope| {
                for _ in 0..jobs {
                    scope.spawn(|| loop {
                        let sat = next.fetch_add(1, Ordering::Relaxed);
                        if sat >= n_sats {
                            break;
                        }
                        let row = scan_sat(sat);
                        slots.lock().unwrap()[sat] = Some(row);
                    });
                }
            });
            slots
                .into_inner()
                .unwrap()
                .into_iter()
                .map(|row| row.expect("scanned satellite row"))
                .collect()
        };

        // transpose the per-satellite rows into the windows[site][sat]
        // layout the query API serves
        let mut windows: Vec<Vec<Vec<ContactWindow>>> =
            (0..n_sites).map(|_| Vec::with_capacity(n_sats)).collect();
        for row in per_sat {
            debug_assert_eq!(row.len(), n_sites);
            for (site, w) in row.into_iter().enumerate() {
                windows[site].push(w);
            }
        }
        Self::finish(windows, horizon_s)
    }

    /// The naive pre-PR-4 scanner, kept as the executable
    /// specification: one dense [`crate::orbit::contact_windows`] sweep
    /// per (site, sat) pair, no sharing, no skipping, single thread.
    /// `tests/contact_equivalence.rs` asserts the fast scanner matches
    /// it bit for bit on every scenario preset, and
    /// `benches/bench_micro.rs` times the two against each other.
    pub fn build_reference(
        constellation: &WalkerConstellation,
        sites: &[GeodeticSite],
        min_elev_deg: f64,
        horizon_s: f64,
    ) -> Self {
        let windows = sites
            .iter()
            .map(|site| {
                let eff_min = site.effective_min_elevation_deg(min_elev_deg);
                (0..constellation.len())
                    .map(|sat| {
                        crate::orbit::contact_windows(
                            |t| {
                                elevation_deg(
                                    site.position_eci(t),
                                    constellation.position(sat, t),
                                ) >= eff_min
                            },
                            horizon_s,
                            SCAN_STEP_S,
                        )
                    })
                    .collect()
            })
            .collect();
        Self::finish(windows, horizon_s)
    }

    /// Assemble the plan and assert the finite-window invariant.
    fn finish(windows: Vec<Vec<Vec<ContactWindow>>>, horizon_s: f64) -> Self {
        let plan = ContactPlan { windows, horizon_s };
        // Window times are finite by construction (finite horizon/step,
        // bisection only averages); assert it once here so every
        // downstream total-order min / sort / event push can rely on it
        // instead of carrying per-call `partial_cmp(..).unwrap()` panic
        // paths.
        for site_windows in &plan.windows {
            for sat_windows in site_windows {
                for w in sat_windows {
                    assert!(
                        w.start_s.is_finite() && w.end_s.is_finite(),
                        "non-finite contact window {w:?}"
                    );
                }
            }
        }
        plan
    }

    pub fn n_sites(&self) -> usize {
        self.windows.len()
    }

    pub fn windows(&self, site: usize, sat: usize) -> &[ContactWindow] {
        &self.windows[site][sat]
    }

    /// Is `sat` visible from `site` at time `t`?
    pub fn visible(&self, site: usize, sat: usize, t: f64) -> bool {
        self.window_at(site, sat, t).is_some()
    }

    /// The window containing `t`, if any (binary search).
    pub fn window_at(&self, site: usize, sat: usize, t: f64) -> Option<ContactWindow> {
        let ws = &self.windows[site][sat];
        let idx = ws.partition_point(|w| w.end_s < t);
        ws.get(idx).filter(|w| w.contains(t)).copied()
    }

    /// Earliest time ≥ `t` at which `sat` is visible from `site`
    /// (start of the next window, or `t` itself if inside one).
    pub fn next_visible(&self, site: usize, sat: usize, t: f64) -> Option<f64> {
        let ws = &self.windows[site][sat];
        let idx = ws.partition_point(|w| w.end_s < t);
        ws.get(idx).map(|w| w.start_s.max(t))
    }

    /// All satellites visible from `site` at `t`, in id order.
    /// Allocation-free: callers iterate (or `collect` when they truly
    /// need a `Vec`) — this sits inside broadcast/relay hot loops.
    pub fn visible_sats(&self, site: usize, t: f64) -> impl Iterator<Item = usize> + '_ {
        (0..self.windows[site].len()).filter(move |&s| self.visible(site, s, t))
    }

    /// Earliest time ≥ `t` at which `sat` is visible from *any* site;
    /// returns `(time, site)`. Window times are asserted finite at
    /// construction, so the total-order comparison here can never meet
    /// (or be confused by) a NaN — no panic path.
    pub fn next_visible_any(&self, sat: usize, t: f64) -> Option<(f64, usize)> {
        (0..self.n_sites())
            .filter_map(|site| self.next_visible(site, sat, t).map(|tt| (tt, site)))
            .min_by(|a, b| a.0.total_cmp(&b.0))
    }

    /// Fraction of the horizon that `sat` is visible from `site`.
    pub fn visibility_fraction(&self, site: usize, sat: usize) -> f64 {
        self.windows[site][sat].iter().map(|w| w.duration_s()).sum::<f64>() / self.horizon_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::GeodeticSite;

    fn plan() -> (WalkerConstellation, ContactPlan) {
        let c = WalkerConstellation::paper();
        let sites = [GeodeticSite::rolla_hap(), GeodeticSite::portland_hap()];
        let p = ContactPlan::build(&c, &sites, 10.0, 86_400.0);
        (c, p)
    }

    #[test]
    fn consistency_with_live_predicate() {
        let (c, p) = plan();
        let site = GeodeticSite::rolla_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        // away from window edges the plan matches the live predicate
        for sat in [0usize, 13, 39] {
            for i in 0..48 {
                let t = i as f64 * 1800.0;
                let live =
                    elevation_deg(site.position_eci(t), c.position(sat, t)) >= eff;
                let planned = p.visible(0, sat, t);
                if live != planned {
                    // tolerate only near-edge disagreement (< 60 s)
                    let near_edge = p.windows(0, sat).iter().any(|w| {
                        (w.start_s - t).abs() < 60.0 || (w.end_s - t).abs() < 60.0
                    });
                    assert!(near_edge, "sat {sat} t {t}: live {live} vs plan {planned}");
                }
            }
        }
    }

    #[test]
    fn next_visible_is_window_start_or_now() {
        let (_, p) = plan();
        let ws = p.windows(0, 0);
        assert!(!ws.is_empty());
        let w0 = ws[0];
        if w0.start_s > 10.0 {
            assert_eq!(p.next_visible(0, 0, 0.0), Some(w0.start_s));
        }
        let inside = 0.5 * (w0.start_s + w0.end_s);
        assert_eq!(p.next_visible(0, 0, inside), Some(inside));
        // after the window: the next one
        if ws.len() > 1 {
            assert_eq!(p.next_visible(0, 0, w0.end_s + 1.0), Some(ws[1].start_s));
        }
    }

    #[test]
    fn every_sat_gets_contact_within_a_day() {
        let (_, p) = plan();
        for sat in 0..40 {
            assert!(
                p.next_visible_any(sat, 0.0).is_some(),
                "sat {sat} never visible from either HAP in 24 h"
            );
        }
    }

    #[test]
    fn visible_sats_matches_visible() {
        let (_, p) = plan();
        let t = 43_200.0;
        let vs: Vec<usize> = p.visible_sats(0, t).collect();
        for sat in 0..40 {
            assert_eq!(vs.contains(&sat), p.visible(0, sat, t));
        }
    }

    #[test]
    fn visibility_fraction_sporadic() {
        let (_, p) = plan();
        for sat in 0..40 {
            let f = p.visibility_fraction(0, sat);
            assert!((0.0..0.6).contains(&f), "sat {sat} fraction {f}");
        }
    }

    #[test]
    fn fast_scan_matches_reference_on_paper_world() {
        // the full per-preset bitwise sweep lives in
        // tests/contact_equivalence.rs; this in-module smoke keeps the
        // contract close to the implementation
        let c = WalkerConstellation::paper();
        let sites = [GeodeticSite::rolla_hap(), GeodeticSite::portland_hap()];
        let fast = ContactPlan::build_with_threads(&c, &sites, 10.0, 43_200.0, 1);
        let reference = ContactPlan::build_reference(&c, &sites, 10.0, 43_200.0);
        for site in 0..2 {
            for sat in 0..c.len() {
                let (a, b) = (fast.windows(site, sat), reference.windows(site, sat));
                assert_eq!(a.len(), b.len(), "site {site} sat {sat}");
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.start_s.to_bits(), y.start_s.to_bits(), "site {site} sat {sat}");
                    assert_eq!(x.end_s.to_bits(), y.end_s.to_bits(), "site {site} sat {sat}");
                }
            }
        }
    }

    #[test]
    fn worker_count_clamps() {
        assert_eq!(worker_count(0, 10), 1);
        assert_eq!(worker_count(4, 10), 4);
        assert_eq!(worker_count(16, 3), 3);
        assert_eq!(worker_count(2, 0), 1);
    }

    #[test]
    fn skip_never_returns_current_index() {
        // progress guarantee: the scanner always advances
        for (e, eff) in [(45.0, 10.0), (10.0, 10.0), (-80.0, 5.0)] {
            let rate = 3.8e-3;
            assert!(next_check_index(7, e, eff, rate, SCAN_STEP_S) > 7);
        }
    }
}
