//! Solar ephemeris + Earth-shadow (umbra) test.
//!
//! The fault engine's original eclipse model is a periodic
//! approximation (fixed outage windows per site/orbit). This module
//! provides the ground-truth alternative behind the
//! `network.eclipse_from_sun` switch: a circular-ecliptic Sun vector
//! and a cylindrical umbra test, from which `faults::plan` precomputes
//! per-satellite shadow windows at schedule build time.
//!
//! The ephemeris is deliberately simple — a mean Sun on a circular
//! ecliptic orbit (no equation of time, no eccentricity): eclipse
//! *timing* in LEO is dominated by the orbit geometry and the ~23.4°
//! obliquity, which this captures, while the neglected terms shift
//! window edges by well under the contact-scan resolution. Everything
//! is a pure function of simulated time, so schedules stay
//! byte-deterministic per (config, seed).

use super::elements::EARTH_RADIUS_KM;
use super::walker::WalkerConstellation;
use crate::util::Vec3;

/// Mean obliquity of the ecliptic, degrees (J2000).
pub const OBLIQUITY_DEG: f64 = 23.439_291;

/// One Julian year, seconds — the period of the mean Sun.
pub const YEAR_S: f64 = 365.25 * 86_400.0;

/// Unit vector from Earth's center toward the Sun in the ECI frame at
/// simulated time `t` (seconds). The mean ecliptic longitude is zero at
/// `t = 0`, i.e. the simulation epoch is aligned with a vernal equinox.
pub fn sun_direction_eci(t: f64) -> Vec3 {
    let lon = std::f64::consts::TAU * (t / YEAR_S);
    let (sin_l, cos_l) = lon.sin_cos();
    let (sin_e, cos_e) = OBLIQUITY_DEG.to_radians().sin_cos();
    Vec3::new(cos_l, cos_e * sin_l, sin_e * sin_l)
}

/// Is an ECI position (km, Earth-centered) inside Earth's umbra? The
/// shadow is modeled as the classical cylinder: behind the terminator
/// plane and within one Earth radius of the anti-Sun axis (the Sun is
/// ~215 Earth-orbit-radii away, so the cone/cylinder difference is
/// negligible at LEO altitudes).
pub fn in_umbra(pos_km: Vec3, sun_dir: Vec3) -> bool {
    let along = pos_km.dot(sun_dir);
    if along >= 0.0 {
        return false; // sunside of the terminator plane
    }
    let radial2 = pos_km.norm2() - along * along;
    radial2 < EARTH_RADIUS_KM * EARTH_RADIUS_KM
}

/// Is satellite `sat` of `c` in Earth's shadow at `t`?
pub fn sat_in_umbra(c: &WalkerConstellation, sat: usize, t: f64) -> bool {
    in_umbra(c.position(sat, t), sun_direction_eci(t))
}

/// The umbra windows of one satellite over `[0, horizon_s]`, as sorted
/// disjoint `(enter, exit)` pairs. Found by a grid scan at 1/128 of the
/// orbital period (a LEO shadow arc spans dozens of steps, so none is
/// skipped) with each crossing refined by bisection to ~1 ms.
pub fn umbra_windows(c: &WalkerConstellation, sat: usize, horizon_s: f64) -> Vec<(f64, f64)> {
    let n = c.propagator(sat).mean_motion_rad_s();
    if n <= 0.0 || horizon_s <= 0.0 {
        return Vec::new();
    }
    let step = std::f64::consts::TAU / n / 128.0;
    let shadowed = |t: f64| sat_in_umbra(c, sat, t);
    let mut windows = Vec::new();
    let mut prev_t = 0.0;
    let mut prev_in = shadowed(0.0);
    let mut open = if prev_in { Some(0.0) } else { None };
    let mut k = 1u64;
    loop {
        let t = (k as f64 * step).min(horizon_s);
        let cur = shadowed(t);
        if cur != prev_in {
            let edge = bisect_flip(&shadowed, prev_t, t, prev_in);
            if cur {
                open = Some(edge);
            } else if let Some(s) = open.take() {
                windows.push((s, edge));
            }
        }
        prev_t = t;
        prev_in = cur;
        if t >= horizon_s {
            break;
        }
        k += 1;
    }
    if let Some(s) = open.take() {
        windows.push((s, horizon_s));
    }
    windows
}

/// Refine the flip instant of `f` inside `(lo, hi]`, where
/// `f(lo) == before != f(hi)`. Returns a point on the *after* side.
fn bisect_flip(f: &impl Fn(f64) -> bool, mut lo: f64, mut hi: f64, before: bool) -> f64 {
    for _ in 0..40 {
        if hi - lo < 1e-3 {
            break;
        }
        let mid = 0.5 * (lo + hi);
        if f(mid) == before {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sun_direction_is_unit_periodic_and_equinox_aligned() {
        for t in [0.0, 1e4, 1e6, 0.37 * YEAR_S] {
            assert!((sun_direction_eci(t).norm() - 1.0).abs() < 1e-12, "unit at t={t}");
            let next_year = sun_direction_eci(t + YEAR_S);
            assert!(sun_direction_eci(t).distance(next_year) < 1e-9, "period = 1 year");
        }
        // vernal equinox at epoch: the Sun on +x, in the equator plane
        let s0 = sun_direction_eci(0.0);
        assert!(s0.distance(Vec3::new(1.0, 0.0, 0.0)) < 1e-12);
        // half a year later: the anti-direction
        let s_half = sun_direction_eci(0.5 * YEAR_S);
        assert!(s_half.distance(Vec3::new(-1.0, 0.0, 0.0)) < 1e-9);
        // the Sun leaves the equator plane by up to the obliquity
        let s_quarter = sun_direction_eci(0.25 * YEAR_S);
        let max_z = OBLIQUITY_DEG.to_radians().sin();
        assert!((s_quarter.z - max_z).abs() < 1e-9, "solstice z = sin(obliquity)");
    }

    #[test]
    fn umbra_is_the_anti_sun_cylinder() {
        let sun = Vec3::new(1.0, 0.0, 0.0);
        // directly behind Earth at LEO radius: shadowed
        assert!(in_umbra(Vec3::new(-6921.0, 0.0, 0.0), sun));
        // sunside at the same radius: lit
        assert!(!in_umbra(Vec3::new(6921.0, 0.0, 0.0), sun));
        // behind the terminator but outside the cylinder: lit
        assert!(!in_umbra(Vec3::new(-100.0, 6500.0, 0.0), sun));
        // inside the cylinder radius: shadowed
        assert!(in_umbra(Vec3::new(-3000.0, 6000.0, 0.0), sun));
    }

    #[test]
    fn umbra_windows_are_sorted_disjoint_and_truly_dark() {
        let c = WalkerConstellation::paper();
        let horizon = 86_400.0;
        let mut total_dark = 0.0;
        let mut any = false;
        for sat in 0..c.len() {
            let windows = umbra_windows(&c, sat, horizon);
            let mut prev_end = 0.0;
            for &(s, e) in &windows {
                assert!(s < e, "sat {sat}: empty window ({s}, {e})");
                assert!(s >= prev_end, "sat {sat}: overlapping windows");
                assert!(e <= horizon);
                prev_end = e;
                total_dark += e - s;
                // the midpoint is genuinely in shadow; just before the
                // entry edge the satellite is still lit
                assert!(sat_in_umbra(&c, sat, 0.5 * (s + e)));
                if s > 1.0 {
                    assert!(!sat_in_umbra(&c, sat, s - 1.0));
                }
            }
            any |= !windows.is_empty();
        }
        assert!(any, "a LEO constellation over a day must cross Earth's shadow");
        let frac = total_dark / (horizon * c.len() as f64);
        assert!(
            (0.05..0.60).contains(&frac),
            "constellation-mean shadow fraction {frac} outside the plausible LEO band"
        );
    }

    #[test]
    fn umbra_windows_are_deterministic() {
        let c = WalkerConstellation::paper();
        let a = umbra_windows(&c, 3, 43_200.0);
        let b = umbra_windows(&c, 3, 43_200.0);
        assert_eq!(a, b);
        assert!(umbra_windows(&c, 3, 0.0).is_empty());
    }
}
