//! Scenario subsystem contract tests:
//!
//! * the scheme×scenario comparison grid writes `scenarios.csv`
//!   byte-identical at `--jobs 1` and `--jobs 4` (streaming executor +
//!   longest-first scheduling must never change output bytes);
//! * a two-shell scenario runs end-to-end through the multi-shell
//!   `Geometry` (disjoint shell id ranges, finite ordered contact
//!   windows) and the geometry cache builds once per unique scenario;
//! * built-in presets resolve by name and dumped TOML reloads into the
//!   same world.

use asyncfleo::config::ExperimentConfig;
use asyncfleo::coordinator::Geometry;
use asyncfleo::experiments::drivers::ExpOptions;
use asyncfleo::experiments::scenarios::{compare_cells, run_compare};
use asyncfleo::orbit::ShellSpec;
use asyncfleo::scenario::{Scenario, ScenarioRegistry};
use std::path::PathBuf;

fn temp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("asyncfleo_scenario_sweep_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small fast worlds (altitudes unique to this test binary so geometry
/// build counts can't collide with other tests).
fn small_scenarios() -> Vec<Scenario> {
    let mut single = ExperimentConfig::test_small();
    single.constellation.altitude_km = 913.5;
    single.fl.horizon_s = 12.0 * 3600.0;
    single.fl.max_epochs = 4;

    let mut two_shell = ExperimentConfig::test_small();
    two_shell.constellation.altitude_km = 914.5;
    two_shell.constellation.extra_shells = vec![ShellSpec::delta(1, 4, 1475.5, 60.0, 0)];
    two_shell.fl.horizon_s = 12.0 * 3600.0;
    two_shell.fl.max_epochs = 4;

    vec![
        Scenario::new("tiny-single", "2x3 single shell", single),
        Scenario::new("tiny-two-shell", "2x3 + 1x4 two-shell", two_shell),
    ]
}

fn opts(out: PathBuf, jobs: usize) -> ExpOptions {
    ExpOptions { out_dir: out, fast: true, surrogate: true, seed: 42, jobs, report: false }
}

#[test]
fn scenarios_csv_is_byte_identical_across_jobs() {
    let scenarios = small_scenarios();
    let dir1 = temp_out("jobs1");
    let dir4 = temp_out("jobs4");
    run_compare(&scenarios, &opts(dir1.clone(), 1)).expect("--jobs 1 run");
    run_compare(&scenarios, &opts(dir4.clone(), 4)).expect("--jobs 4 run");
    let a = std::fs::read(dir1.join("scenarios.csv")).unwrap();
    let b = std::fs::read(dir4.join("scenarios.csv")).unwrap();
    assert!(!a.is_empty(), "scenarios.csv must not be empty");
    assert_eq!(a, b, "scenarios.csv: --jobs 4 bytes must equal --jobs 1 bytes");
    // at least AsyncFLEO and FedHAP rows per scenario
    let text = String::from_utf8(a).unwrap();
    for sc in &scenarios {
        assert!(text.contains(&format!("{},asyncfleo", sc.name)), "{}", sc.name);
        assert!(text.contains(&format!("{},fedhap", sc.name)), "{}", sc.name);
    }
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir4);
}

#[test]
fn geometry_cache_keys_per_scenario_and_builds_once() {
    let scenarios = small_scenarios();
    let o = opts(temp_out("geo"), 4);
    let cells = compare_cells(&scenarios, &o);
    run_compare(&scenarios, &o).expect("compare run");
    // one geometry per scenario, each built exactly once even with the
    // parallel pool racing for it
    let mut ptrs: Vec<*const Geometry> = cells
        .iter()
        .map(|c| std::sync::Arc::as_ptr(&Geometry::shared(&c.cfg)))
        .collect();
    ptrs.sort();
    ptrs.dedup();
    assert_eq!(ptrs.len(), scenarios.len(), "one geometry per scenario");
    for cell in &cells {
        assert_eq!(Geometry::build_count(&cell.cfg), 1, "{}", cell.label);
    }
}

#[test]
fn two_shell_geometry_end_to_end() {
    let scenarios = small_scenarios();
    let o = opts(temp_out("shell"), 1);
    let cells = compare_cells(&scenarios, &o);
    let two = cells
        .iter()
        .find(|c| c.label.starts_with("tiny-two-shell"))
        .expect("two-shell cell");
    let geo = Geometry::shared(&two.cfg);
    let c = &geo.constellation;
    // disjoint, dense id ranges per shell
    assert_eq!(c.n_shells(), 2);
    assert_eq!(c.shell_id_range(0), 0..6);
    assert_eq!(c.shell_id_range(1), 6..10);
    assert_eq!(c.len(), 10);
    // finite, ordered contact windows for both shells
    for site in 0..geo.plan.n_sites() {
        for sat in 0..c.len() {
            let ws = geo.plan.windows(site, sat);
            for w in ws {
                assert!(w.start_s.is_finite() && w.end_s.is_finite());
                assert!(w.end_s >= w.start_s);
            }
            for pair in ws.windows(2) {
                assert!(pair[0].end_s <= pair[1].start_s, "windows ordered and disjoint");
            }
        }
    }
}

#[test]
fn preset_dump_reloads_into_same_world() {
    let reg = ScenarioRegistry::builtin();
    assert!(reg.len() >= 6);
    let starlink = reg.get("starlink-lite").expect("preset exists");
    assert_eq!(starlink.cfg.constellation.shells().len(), 2, "two-shell preset");
    let reloaded = Scenario::from_toml(&starlink.to_toml()).expect("dump parses");
    assert_eq!(&reloaded, starlink);
}
