//! Small self-contained substrates: PRNG, statistics, 3-D vector math.
//!
//! crates.io is unreachable in this environment, so the usual `rand` /
//! `statrs` / `nalgebra` dependencies are replaced by these minimal,
//! well-tested implementations (see DESIGN.md §1 "No-network note").

pub mod rng;
pub mod stats;
pub mod vec3;

pub use rng::Rng;
pub use vec3::Vec3;

/// Speed of light in km/s (used by the link-delay model, Eq. 8).
pub const SPEED_OF_LIGHT_KM_S: f64 = 299_792.458;

/// Clamp a float into `[lo, hi]`.
pub fn clamp(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

/// Linear interpolation.
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Format simulated seconds as `h:mm` (the unit of the paper's
/// "convergence time" column in Table II).
pub fn fmt_hm(seconds: f64) -> String {
    let total_min = (seconds / 60.0).round() as i64;
    format!("{}:{:02}", total_min / 60, total_min % 60)
}

/// Format simulated seconds as `h:mm:ss`.
pub fn fmt_hms(seconds: f64) -> String {
    let s = seconds.round() as i64;
    format!("{}:{:02}:{:02}", s / 3600, (s % 3600) / 60, s % 60)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_basics() {
        assert_eq!(clamp(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn lerp_endpoints() {
        assert_eq!(lerp(2.0, 4.0, 0.0), 2.0);
        assert_eq!(lerp(2.0, 4.0, 1.0), 4.0);
        assert_eq!(lerp(2.0, 4.0, 0.5), 3.0);
    }

    #[test]
    fn fmt_hm_matches_paper_style() {
        assert_eq!(fmt_hm(3.5 * 3600.0), "3:30");
        assert_eq!(fmt_hm(72.0 * 3600.0), "72:00");
        assert_eq!(fmt_hm(200.0 * 60.0), "3:20");
    }

    #[test]
    fn fmt_hms_rounds() {
        assert_eq!(fmt_hms(3661.0), "1:01:01");
        assert_eq!(fmt_hms(59.6), "0:01:00");
    }
}
