//! Fault-scenario configuration: named presets + the raw knobs.
//!
//! A [`FaultConfig`] is a plain bag of numbers (so it round-trips
//! through the TOML subset and compares with `PartialEq`); the named
//! [`FaultScenario`] presets are constructors scaled by an `intensity`
//! in `[0, 1]`. Intensity 0 of *any* scenario is exactly
//! [`FaultConfig::nominal`] — the provably fault-free configuration.
//!
//! [`NetworkConfig`] is the companion knob bag for the network
//! impairment engine (`faults::network`): latency jitter, per-link
//! bandwidth queueing, scheduled partitions and Sun-vector eclipses.
//! The same contracts hold: `PartialEq` + TOML round-trip through the
//! `[network]` section, and intensity 0 of any scenario is exactly
//! [`NetworkConfig::nominal`].

/// Named resilience scenarios (the `experiments::resilience` sweep).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultScenario {
    /// No impairments: the original perfect-network code path.
    Nominal,
    /// Per-link packet loss with retransmission (extra delay+transfers).
    Lossy,
    /// Periodic eclipse / solar-conjunction outage windows that black
    /// out SAT↔HAP contacts (and ISL contacts, per orbit).
    Eclipse,
    /// Satellite dropouts and rejoins: training results can be lost and
    /// deliveries deferred past a dead node's downtime.
    Churn,
    /// HAP failures with ring re-healing in `topology::HapRing`.
    HapFailure,
    /// Log-normal latency jitter around the geometric delay (network
    /// axis): deterministic per-link draws reorder messages through the
    /// event queue without any loss.
    Jitter,
    /// Per-link bandwidth queueing (network axis): concurrent transfers
    /// contend FIFO for each link's capacity instead of all seeing a
    /// fixed rate.
    Congestion,
    /// Scheduled network partitions (network axis): the ground segment
    /// is isolated for minutes at a time; async schemes hold models and
    /// re-relay on heal, sync baselines stall honestly.
    Partition,
    /// Eclipse windows computed from the actual Sun vector
    /// (`orbit::sun` umbra test) instead of the periodic approximation.
    SunEclipse,
}

impl FaultScenario {
    /// All scenarios, in sweep order.
    pub const ALL: &'static [FaultScenario] = &[
        FaultScenario::Nominal,
        FaultScenario::Lossy,
        FaultScenario::Eclipse,
        FaultScenario::Churn,
        FaultScenario::HapFailure,
        FaultScenario::Jitter,
        FaultScenario::Congestion,
        FaultScenario::Partition,
        FaultScenario::SunEclipse,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "nominal" => FaultScenario::Nominal,
            "lossy" => FaultScenario::Lossy,
            "eclipse" => FaultScenario::Eclipse,
            "churn" => FaultScenario::Churn,
            "hap-failure" | "hap_failure" => FaultScenario::HapFailure,
            "jitter" => FaultScenario::Jitter,
            "congestion" => FaultScenario::Congestion,
            "partition" => FaultScenario::Partition,
            "sun-eclipse" | "sun_eclipse" => FaultScenario::SunEclipse,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultScenario::Nominal => "nominal",
            FaultScenario::Lossy => "lossy",
            FaultScenario::Eclipse => "eclipse",
            FaultScenario::Churn => "churn",
            FaultScenario::HapFailure => "hap-failure",
            FaultScenario::Jitter => "jitter",
            FaultScenario::Congestion => "congestion",
            FaultScenario::Partition => "partition",
            FaultScenario::SunEclipse => "sun-eclipse",
        }
    }
}

/// The raw fault-injection knobs. A zero value disables the
/// corresponding impairment; [`FaultConfig::is_nop`] true means the
/// whole subsystem stays out of the hot path entirely.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Per-attempt packet-loss probability on every link transfer.
    pub loss_prob: f64,
    /// Cap on retransmission attempts per transfer.
    pub max_retransmits: u32,
    /// Fixed extra wait before each retransmission, seconds (ARQ
    /// turnaround), on top of re-sending the payload.
    pub retransmit_backoff_s: f64,
    /// Eclipse/outage cycle period, seconds (0 = no outages).
    pub outage_period_s: f64,
    /// Outage window length within each period, seconds.
    pub outage_duration_s: f64,
    /// Outages also black out intra-orbit ISL hops (per-orbit windows).
    pub isl_outage: bool,
    /// Mean time between satellite failures, seconds (0 = no churn).
    pub sat_mtbf_s: f64,
    /// Mean satellite downtime per failure, seconds.
    pub sat_mttr_s: f64,
    /// Mean time between HAP failures, seconds (0 = no HAP faults).
    pub hap_mtbf_s: f64,
    /// Mean HAP downtime per failure, seconds.
    pub hap_mttr_s: f64,
    /// Typed per-ISL-edge outage cycle period, seconds (0 = none).
    /// Unlike `isl_outage` (which blacks out whole orbits alongside
    /// eclipse windows), these windows hit individual graph edges with
    /// a per-edge deterministic phase.
    pub isl_edge_outage_period_s: f64,
    /// Per-ISL-edge outage window length within each period, seconds.
    pub isl_edge_outage_duration_s: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self::nominal()
    }
}

impl FaultConfig {
    /// The perfect network: every impairment off.
    pub fn nominal() -> Self {
        FaultConfig {
            loss_prob: 0.0,
            max_retransmits: 0,
            retransmit_backoff_s: 0.0,
            outage_period_s: 0.0,
            outage_duration_s: 0.0,
            isl_outage: false,
            sat_mtbf_s: 0.0,
            sat_mttr_s: 0.0,
            hap_mtbf_s: 0.0,
            hap_mttr_s: 0.0,
            isl_edge_outage_period_s: 0.0,
            isl_edge_outage_duration_s: 0.0,
        }
    }

    /// A named scenario scaled by `intensity` in `[0, 1]`. Intensity 0
    /// always yields [`Self::nominal`].
    pub fn preset(scenario: FaultScenario, intensity: f64) -> Self {
        let x = intensity.clamp(0.0, 1.0);
        let mut cfg = Self::nominal();
        if x == 0.0 {
            return cfg;
        }
        match scenario {
            FaultScenario::Nominal => {}
            FaultScenario::Lossy => {
                // up to 30% per-attempt loss at full intensity
                cfg.loss_prob = 0.3 * x;
                cfg.max_retransmits = 4;
                cfg.retransmit_backoff_s = 0.5;
            }
            FaultScenario::Eclipse => {
                // one outage window per ~2 h cycle, up to 30 min long
                cfg.outage_period_s = 7200.0;
                cfg.outage_duration_s = 1800.0 * x;
                cfg.isl_outage = true;
            }
            FaultScenario::Churn => {
                // at full intensity a satellite fails every ~6 h on
                // average and stays dark ~2 h
                cfg.sat_mtbf_s = 21600.0 / x;
                cfg.sat_mttr_s = 7200.0;
            }
            FaultScenario::HapFailure => {
                // at full intensity one HAP failure every ~8 h, down
                // ~2 h; mild link loss rides along (degraded backhaul)
                cfg.hap_mtbf_s = 28800.0 / x;
                cfg.hap_mttr_s = 7200.0;
                cfg.loss_prob = 0.05 * x;
                cfg.max_retransmits = 2;
                cfg.retransmit_backoff_s = 0.5;
            }
            // pure network axes: the fault knobs stay nominal, the
            // impairment lives in `NetworkConfig::preset`
            FaultScenario::Jitter
            | FaultScenario::Congestion
            | FaultScenario::Partition
            | FaultScenario::SunEclipse => {}
        }
        cfg
    }

    /// True when every impairment is disabled — the fault plan then
    /// never touches the delay path or the RNG.
    pub fn is_nop(&self) -> bool {
        self.loss_prob <= 0.0
            && (self.outage_period_s <= 0.0 || self.outage_duration_s <= 0.0)
            && self.sat_mtbf_s <= 0.0
            && self.hap_mtbf_s <= 0.0
            && (self.isl_edge_outage_period_s <= 0.0 || self.isl_edge_outage_duration_s <= 0.0)
    }

    /// Validate invariants; returns a list of problems (empty = OK).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if !(0.0..1.0).contains(&self.loss_prob) {
            errs.push(format!("faults.loss_prob {} out of [0, 1)", self.loss_prob));
        }
        if self.loss_prob > 0.0 && self.max_retransmits == 0 {
            errs.push("faults.loss_prob needs max_retransmits > 0".into());
        }
        if self.outage_period_s > 0.0 && self.outage_duration_s >= self.outage_period_s {
            errs.push(format!(
                "faults.outage_duration_s {} must be shorter than the period {}",
                self.outage_duration_s, self.outage_period_s
            ));
        }
        if self.sat_mtbf_s > 0.0 && self.sat_mttr_s <= 0.0 {
            errs.push("faults.sat_mtbf_s needs sat_mttr_s > 0".into());
        }
        if self.hap_mtbf_s > 0.0 && self.hap_mttr_s <= 0.0 {
            errs.push("faults.hap_mtbf_s needs hap_mttr_s > 0".into());
        }
        if self.isl_edge_outage_period_s > 0.0
            && self.isl_edge_outage_duration_s >= self.isl_edge_outage_period_s
        {
            errs.push(format!(
                "faults.isl_edge_outage_duration_s {} must be shorter than the period {}",
                self.isl_edge_outage_duration_s, self.isl_edge_outage_period_s
            ));
        }
        for (name, v) in [
            ("retransmit_backoff_s", self.retransmit_backoff_s),
            ("outage_period_s", self.outage_period_s),
            ("outage_duration_s", self.outage_duration_s),
            ("sat_mtbf_s", self.sat_mtbf_s),
            ("sat_mttr_s", self.sat_mttr_s),
            ("hap_mtbf_s", self.hap_mtbf_s),
            ("hap_mttr_s", self.hap_mttr_s),
            ("isl_edge_outage_period_s", self.isl_edge_outage_period_s),
            ("isl_edge_outage_duration_s", self.isl_edge_outage_duration_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                errs.push(format!("faults.{name} {v} must be finite and >= 0"));
            }
        }
        errs
    }
}

/// What a scheduled network partition isolates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartitionScope {
    /// The whole ground segment: every ground-station site is
    /// unreachable (HAPs keep flying and relaying).
    Ground,
    /// The HAP layer: HAP sites and the IHL backbone are unreachable.
    Hap,
    /// One orbital shell: its satellites lose every link that crosses
    /// the shell boundary (intra-shell ISLs keep working — the island
    /// stays internally connected, but isolated).
    Shell,
}

impl PartitionScope {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "ground" => PartitionScope::Ground,
            "hap" => PartitionScope::Hap,
            "shell" => PartitionScope::Shell,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionScope::Ground => "ground",
            PartitionScope::Hap => "hap",
            PartitionScope::Shell => "shell",
        }
    }
}

/// The network impairment knobs (`faults::network`). A zero value
/// disables the corresponding axis; [`NetworkConfig::is_nop`] true
/// means the engine stays out of the hot path entirely — the
/// zero-intensity-is-bit-identical contract of the fault subsystem.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkConfig {
    /// Log-normal latency jitter: sigma of the per-transfer multiplier
    /// `exp(sigma * z)` applied to the clean link delay (0 = off).
    /// Draws are hash-derived per (link, coherence window), so they are
    /// order-independent and idempotent within a window.
    pub jitter_sigma: f64,
    /// Per-link bandwidth queueing: each committed transfer occupies
    /// its link FIFO for `factor * clean_delay` seconds; later offers
    /// wait for the residual capacity (0 = off).
    pub queue_service_factor: f64,
    /// Queue waits beyond this cap become typed drops instead of
    /// unbounded head-of-line blocking (0 = unbounded).
    pub queue_max_wait_s: f64,
    /// Partition cycle period, seconds (0 = no partitions).
    pub partition_period_s: f64,
    /// Partition window length within each period, seconds.
    pub partition_duration_s: f64,
    /// What each partition window isolates.
    pub partition_scope: PartitionScope,
    /// Shell index isolated when `partition_scope` is `Shell`.
    pub partition_shell: usize,
    /// Replace the periodic eclipse approximation with per-satellite
    /// umbra windows computed from the actual Sun vector
    /// (`orbit::sun`).
    pub eclipse_from_sun: bool,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self::nominal()
    }
}

impl NetworkConfig {
    /// The perfect network: every impairment off.
    pub fn nominal() -> Self {
        NetworkConfig {
            jitter_sigma: 0.0,
            queue_service_factor: 0.0,
            queue_max_wait_s: 0.0,
            partition_period_s: 0.0,
            partition_duration_s: 0.0,
            partition_scope: PartitionScope::Ground,
            partition_shell: 0,
            eclipse_from_sun: false,
        }
    }

    /// The network half of a named scenario scaled by `intensity` in
    /// `[0, 1]`. Intensity 0 always yields [`Self::nominal`]; the
    /// pre-network scenarios yield it at any intensity.
    pub fn preset(scenario: FaultScenario, intensity: f64) -> Self {
        let x = intensity.clamp(0.0, 1.0);
        let mut net = Self::nominal();
        if x == 0.0 {
            return net;
        }
        match scenario {
            FaultScenario::Jitter => {
                // up to sigma 0.35 at full intensity: occasional 2x+
                // delay spikes, visible message reordering
                net.jitter_sigma = 0.35 * x;
            }
            FaultScenario::Congestion => {
                // each transfer occupies its link for up to its whole
                // clean delay; contenders queue FIFO, waits beyond
                // 15 min become typed drops
                net.queue_service_factor = x;
                net.queue_max_wait_s = 900.0;
            }
            FaultScenario::Partition => {
                // the ground segment drops out for up to 30 min every
                // 4 h
                net.partition_period_s = 14_400.0;
                net.partition_duration_s = 1800.0 * x;
                net.partition_scope = PartitionScope::Ground;
            }
            FaultScenario::SunEclipse => {
                // a switch, not a dial: any positive intensity turns
                // the Sun-vector umbra model on
                net.eclipse_from_sun = true;
            }
            _ => {}
        }
        net
    }

    /// True when every network axis is disabled — the engine then never
    /// touches the delay path, the RNG or the schedule cache key.
    pub fn is_nop(&self) -> bool {
        self.jitter_sigma <= 0.0
            && self.queue_service_factor <= 0.0
            && (self.partition_period_s <= 0.0 || self.partition_duration_s <= 0.0)
            && !self.eclipse_from_sun
    }

    /// Validate invariants; returns a list of problems (empty = OK).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if self.partition_period_s > 0.0 && self.partition_duration_s >= self.partition_period_s {
            errs.push(format!(
                "network.partition_duration_s {} must be shorter than the period {}",
                self.partition_duration_s, self.partition_period_s
            ));
        }
        for (name, v) in [
            ("jitter_sigma", self.jitter_sigma),
            ("queue_service_factor", self.queue_service_factor),
            ("queue_max_wait_s", self.queue_max_wait_s),
            ("partition_period_s", self.partition_period_s),
            ("partition_duration_s", self.partition_duration_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                errs.push(format!("network.{name} {v} must be finite and >= 0"));
            }
        }
        errs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_is_nop_and_valid() {
        let c = FaultConfig::nominal();
        assert!(c.is_nop());
        assert!(c.validate().is_empty());
    }

    #[test]
    fn zero_intensity_of_any_scenario_is_nominal() {
        for &s in FaultScenario::ALL {
            assert_eq!(FaultConfig::preset(s, 0.0), FaultConfig::nominal(), "{s:?}");
        }
    }

    #[test]
    fn presets_are_active_and_valid() {
        for &s in FaultScenario::ALL {
            let c = FaultConfig::preset(s, 1.0);
            let n = NetworkConfig::preset(s, 1.0);
            assert!(c.validate().is_empty(), "{s:?}: {:?}", c.validate());
            assert!(n.validate().is_empty(), "{s:?}: {:?}", n.validate());
            if s != FaultScenario::Nominal {
                assert!(
                    !(c.is_nop() && n.is_nop()),
                    "{s:?} at full intensity must be active on some axis"
                );
            }
        }
    }

    #[test]
    fn network_nominal_is_nop_and_valid() {
        let n = NetworkConfig::nominal();
        assert!(n.is_nop());
        assert!(n.validate().is_empty());
        assert_eq!(n, NetworkConfig::default());
    }

    #[test]
    fn zero_intensity_network_of_any_scenario_is_nominal() {
        for &s in FaultScenario::ALL {
            assert_eq!(NetworkConfig::preset(s, 0.0), NetworkConfig::nominal(), "{s:?}");
        }
    }

    #[test]
    fn partition_scope_parse_roundtrip() {
        for scope in [PartitionScope::Ground, PartitionScope::Hap, PartitionScope::Shell] {
            assert_eq!(PartitionScope::parse(scope.name()), Some(scope));
        }
        assert_eq!(PartitionScope::parse("bogus"), None);
    }

    #[test]
    fn network_validation_catches_bad_knobs() {
        let mut n = NetworkConfig::preset(FaultScenario::Partition, 1.0);
        n.partition_duration_s = n.partition_period_s + 1.0;
        assert_eq!(n.validate().len(), 1, "{:?}", n.validate());
        let mut n = NetworkConfig::nominal();
        n.jitter_sigma = f64::NAN;
        assert_eq!(n.validate().len(), 1);
        n.jitter_sigma = -0.5;
        assert_eq!(n.validate().len(), 1);
    }

    #[test]
    fn network_presets_only_touch_their_axis() {
        let j = NetworkConfig::preset(FaultScenario::Jitter, 1.0);
        assert!(j.jitter_sigma > 0.0 && j.queue_service_factor == 0.0);
        let c = NetworkConfig::preset(FaultScenario::Congestion, 1.0);
        assert!(c.queue_service_factor > 0.0 && c.jitter_sigma == 0.0);
        let p = NetworkConfig::preset(FaultScenario::Partition, 1.0);
        assert!(p.partition_period_s > 0.0 && !p.eclipse_from_sun);
        let e = NetworkConfig::preset(FaultScenario::SunEclipse, 1.0);
        assert!(e.eclipse_from_sun && e.partition_period_s == 0.0);
        // the pre-network scenarios leave the network axes untouched
        let l = NetworkConfig::preset(FaultScenario::Lossy, 1.0);
        assert!(l.is_nop());
    }

    #[test]
    fn intensity_scales_monotonically() {
        let half = FaultConfig::preset(FaultScenario::Lossy, 0.5);
        let full = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        assert!(half.loss_prob < full.loss_prob);
        let ch = FaultConfig::preset(FaultScenario::Churn, 0.5);
        let cf = FaultConfig::preset(FaultScenario::Churn, 1.0);
        assert!(ch.sat_mtbf_s > cf.sat_mtbf_s, "higher intensity = more frequent failures");
    }

    #[test]
    fn scenario_parse_roundtrip() {
        for &s in FaultScenario::ALL {
            assert_eq!(FaultScenario::parse(s.name()), Some(s));
        }
        assert_eq!(FaultScenario::parse("bogus"), None);
    }

    #[test]
    fn validation_catches_bad_knobs() {
        let mut c = FaultConfig::preset(FaultScenario::Lossy, 1.0);
        c.loss_prob = 1.5;
        c.max_retransmits = 0;
        assert_eq!(c.validate().len(), 2, "{:?}", c.validate());
        let mut c = FaultConfig::preset(FaultScenario::Eclipse, 1.0);
        c.outage_duration_s = c.outage_period_s + 1.0;
        assert_eq!(c.validate().len(), 1);
    }

    #[test]
    fn isl_edge_outage_knobs_activate_and_validate() {
        let mut c = FaultConfig::nominal();
        c.isl_edge_outage_period_s = 3600.0;
        assert!(c.is_nop(), "period without duration stays a no-op");
        c.isl_edge_outage_duration_s = 600.0;
        assert!(!c.is_nop());
        assert!(c.validate().is_empty(), "{:?}", c.validate());
        c.isl_edge_outage_duration_s = 3700.0;
        assert_eq!(c.validate().len(), 1, "duration must fit inside the period");
        c.isl_edge_outage_duration_s = f64::NAN;
        assert!(!c.validate().is_empty());
    }
}
