//! Vanilla synchronous FedAvg over the star topology (McMahan et al.,
//! as applied to Satcom by Chen et al. [9]): the PS waits for every
//! satellite to download, train and upload each round (paper Eq. 4).

use crate::coordinator::{RunResult, SimEnv};
use crate::fl::Strategy;

pub struct FedAvg;

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn run(&mut self, env: &mut SimEnv) -> RunResult {
        super::run_synchronous(env, "fedavg", false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement};
    use crate::coordinator::SimEnv;
    use crate::train::SurrogateBackend;

    #[test]
    fn fedavg_learns_given_enough_time() {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = PsPlacement::HapRolla;
        cfg.fl.horizon_s = 72.0 * 3600.0;
        cfg.fl.max_epochs = 12;
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        let r = FedAvg.run(&mut env);
        assert!(r.epochs >= 1, "at least one sync round in 72 h");
        assert!(r.final_accuracy > 0.5, "acc {}", r.final_accuracy);
    }
}
