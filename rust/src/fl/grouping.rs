//! Satellite grouping by model weight divergence (paper Sec. IV-C1).
//!
//! The PS can't see data distributions (FL), so AsyncFLEO infers them
//! from weight space: during the first epoch each orbit's local models
//! are averaged into an *orbit partial model* S'_o (Eq. 11) and orbits
//! with similar weight divergence are grouped; later-arriving orbits
//! join the closest existing group. The grouping persists across
//! epochs.
//!
//! **Reproduction note (documented in DESIGN.md):** the paper proposes
//! grouping on the *scalar* distance ‖S'_o − w⁰‖₂. Measured on real
//! training (examples/non_iid_grouping.rs) that scalar is not
//! discriminative — the 4-class and 6-class orbit partials land at
//! 0.85–0.89 vs 0.85–0.87, overlapping bands — because every orbit
//! moves a similar *distance* from w⁰ while moving in a different
//! *direction*. The pairwise divergence between partials separates
//! cleanly (same distribution ≈ 0.8·d₀, different ≈ 1.4·d₀, the
//! orthogonal-updates signature), so we cluster on
//! ‖S'_a − S'_b‖ ≤ τ·max(d₀) with τ between the two bands, keeping
//! the scalar d₀ as the scale reference. This implements the paper's
//! *goal* ("group satellites based on the similarity among their data
//! distributions... inferred from model weights") with a metric that
//! actually works; both distances run on the AOT `dist` kernel.

use crate::model::ModelParams;

/// Persistent grouping state held by the sink HAP.
#[derive(Clone, Debug, Default)]
pub struct GroupingState {
    /// orbit -> group id.
    assignment: Vec<Option<usize>>,
    /// Representative partial model of each group (first member).
    reps: Vec<ModelParams>,
    /// ‖rep − w⁰‖₂ of each representative (the distance scale).
    rep_d0: Vec<f64>,
    /// Join threshold: pairwise divergence ≤ this × max(d₀ scale).
    pub pairwise_tolerance: f64,
}

impl GroupingState {
    pub fn new(n_orbits: usize) -> Self {
        GroupingState {
            assignment: vec![None; n_orbits],
            reps: Vec::new(),
            rep_d0: Vec::new(),
            // midway between the same-distribution (~0.8 d0) and
            // different-distribution (~1.4 d0) pairwise bands
            pairwise_tolerance: 1.15,
        }
    }

    pub fn n_groups(&self) -> usize {
        self.reps.len()
    }

    pub fn group_of(&self, orbit: usize) -> Option<usize> {
        self.assignment[orbit]
    }

    pub fn all_grouped(&self) -> bool {
        self.assignment.iter().all(|a| a.is_some())
    }

    /// Assign `orbit` given its partial model and its divergence `d0`
    /// to the initial global model w⁰.
    ///
    /// Joins the group whose representative is nearest in weight space
    /// if within tolerance, otherwise opens a new group. Re-calling for
    /// an already-grouped orbit is a no-op returning its group ("if the
    /// orbit is already in one of the stored groups, assign directly").
    pub fn assign(&mut self, orbit: usize, partial: &ModelParams, d0: f64) -> usize {
        if let Some(g) = self.assignment[orbit] {
            return g;
        }
        let best = (0..self.reps.len())
            .map(|g| (g, partial.l2_distance(&self.reps[g])))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let g = match best {
            Some((g, pd)) => {
                let scale = d0.max(self.rep_d0[g]).max(1e-12);
                if pd <= self.pairwise_tolerance * scale {
                    g
                } else {
                    self.new_group(partial, d0)
                }
            }
            None => self.new_group(partial, d0),
        };
        self.assignment[orbit] = Some(g);
        g
    }

    fn new_group(&mut self, partial: &ModelParams, d0: f64) -> usize {
        self.reps.push(partial.clone());
        self.rep_d0.push(d0);
        self.reps.len() - 1
    }

    /// Batch-assign several orbits (first-epoch grouping). Processed in
    /// ascending-d₀ order so cluster seeds are deterministic.
    pub fn assign_batch(&mut self, items: &[(usize, &ModelParams, f64)]) {
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by(|&a, &b| items[a].2.partial_cmp(&items[b].2).unwrap());
        for idx in order {
            let (orbit, partial, d0) = items[idx];
            self.assign(orbit, partial, d0);
        }
    }
}

/// Size-weighted average of per-orbit member models → the orbit partial
/// model S'_o of Eq. 11 (pure-buffer op; the PJRT `agg` kernel computes
/// the same quantity on the hot path — both are tested for agreement).
pub fn orbit_partial_model(models: &[&ModelParams], sizes: &[usize]) -> ModelParams {
    let mut out = ModelParams { data: Vec::new() };
    orbit_partial_model_into(models, sizes, &mut out);
    out
}

/// In-place [`orbit_partial_model`]: no intermediate weight vector —
/// each weight is computed exactly as before, right at its axpy, so
/// the floats are bit-identical to the allocating path.
pub fn orbit_partial_model_into(models: &[&ModelParams], sizes: &[usize], out: &mut ModelParams) {
    assert_eq!(models.len(), sizes.len());
    assert!(!models.is_empty());
    let total: f64 = sizes.iter().map(|&s| s as f64).sum();
    out.reset_zeros(models[0].dim());
    if total > 0.0 {
        for (m, &s) in models.iter().zip(sizes) {
            out.axpy((s as f64 / total) as f32, m);
        }
    } else {
        let w = 1.0 / models.len() as f32;
        for m in models {
            out.axpy(w, m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two synthetic "distributions": partials pointing along different
    /// axes (the orthogonal-update signature of disjoint class sets).
    fn partial(direction: usize, magnitude: f32, jitter: f32, dim: usize) -> ModelParams {
        let mut data = vec![0.0f32; dim];
        for (i, v) in data.iter_mut().enumerate() {
            if i % 2 == direction % 2 {
                *v = magnitude + jitter * ((i % 7) as f32 - 3.0) / 3.0;
            } else {
                *v = jitter * ((i % 5) as f32 - 2.0) / 2.0;
            }
        }
        ModelParams { data }
    }

    fn d0(p: &ModelParams) -> f64 {
        p.l2_norm()
    }

    #[test]
    fn same_direction_partials_share_group() {
        let mut g = GroupingState::new(4);
        let ps: Vec<ModelParams> = vec![
            partial(0, 1.0, 0.1, 64),
            partial(0, 1.1, 0.1, 64),
            partial(1, 1.0, 0.1, 64),
            partial(1, 0.9, 0.1, 64),
        ];
        let items: Vec<(usize, &ModelParams, f64)> =
            ps.iter().enumerate().map(|(o, p)| (o, p, d0(p))).collect();
        g.assign_batch(&items);
        assert!(g.all_grouped());
        assert_eq!(g.group_of(0), g.group_of(1));
        assert_eq!(g.group_of(2), g.group_of(3));
        assert_ne!(g.group_of(0), g.group_of(2));
        assert_eq!(g.n_groups(), 2);
    }

    #[test]
    fn reassign_is_stable() {
        let mut g = GroupingState::new(3);
        let p = partial(0, 1.0, 0.0, 32);
        let far = partial(1, 5.0, 0.0, 32);
        let first = g.assign(0, &p, d0(&p));
        let second = g.assign(0, &far, d0(&far)); // ignored: already grouped
        assert_eq!(first, second);
        assert_eq!(g.n_groups(), 1);
    }

    #[test]
    fn late_orbit_joins_nearest_group() {
        let mut g = GroupingState::new(4);
        let a = partial(0, 1.0, 0.05, 64);
        let b = partial(1, 1.0, 0.05, 64);
        g.assign(0, &a, d0(&a));
        g.assign(1, &b, d0(&b));
        assert_eq!(g.n_groups(), 2);
        let a2 = partial(0, 1.05, 0.08, 64);
        let joined = g.assign(2, &a2, d0(&a2));
        assert_eq!(Some(joined), g.group_of(0));
        let b2 = partial(1, 0.95, 0.08, 64);
        let joined = g.assign(3, &b2, d0(&b2));
        assert_eq!(Some(joined), g.group_of(1));
    }

    #[test]
    fn identical_partials_single_group() {
        let mut g = GroupingState::new(5);
        let p = partial(0, 1.0, 0.0, 32);
        for o in 0..5 {
            g.assign(o, &p, d0(&p));
        }
        assert_eq!(g.n_groups(), 1);
    }

    #[test]
    fn orbit_partial_model_weighted() {
        let a = ModelParams { data: vec![0.0, 0.0] };
        let b = ModelParams { data: vec![4.0, 8.0] };
        let m = orbit_partial_model(&[&a, &b], &[300, 100]);
        assert_eq!(m.data, vec![1.0, 2.0]);
    }

    #[test]
    fn orbit_partial_model_zero_sizes_uniform() {
        let a = ModelParams { data: vec![2.0] };
        let b = ModelParams { data: vec![4.0] };
        let m = orbit_partial_model(&[&a, &b], &[0, 0]);
        assert_eq!(m.data, vec![3.0]);
    }

    #[test]
    fn property_every_assignment_valid() {
        crate::testkit::forall(|rng| {
            let n = rng.range_usize(1, 12);
            let dim = rng.range_usize(4, 40);
            let mut g = GroupingState::new(n);
            for orbit in 0..n {
                let p = ModelParams {
                    data: crate::testkit::gen_vec_f32(rng, dim, 1.0),
                };
                g.assign(orbit, &p, p.l2_norm());
            }
            assert!(g.all_grouped());
            for o in 0..n {
                assert!(g.group_of(o).unwrap() < g.n_groups());
            }
            assert!(g.n_groups() <= n);
        });
    }

    #[test]
    fn batch_order_independent_for_well_separated() {
        for perm in [[0usize, 1, 2], [2, 1, 0], [1, 2, 0]] {
            let ps =
                [partial(0, 1.0, 0.05, 64), partial(0, 1.02, 0.05, 64), partial(1, 1.0, 0.05, 64)];
            let mut g = GroupingState::new(3);
            let items: Vec<(usize, &ModelParams, f64)> =
                perm.iter().map(|&i| (i, &ps[i], d0(&ps[i]))).collect();
            g.assign_batch(&items);
            assert_eq!(g.n_groups(), 2, "perm {perm:?}");
            assert_eq!(g.group_of(0), g.group_of(1));
            assert_ne!(g.group_of(0), g.group_of(2));
        }
    }
}
