//! Circular two-body propagation: elements + time -> ECI position.
//!
//! The canonical position formula is [`PlaneBasis`]: the per-satellite
//! orbital-plane basis with all time-independent trigonometry hoisted
//! out. Constructing it pays the two rotation `sin_cos` calls once;
//! evaluating a position afterwards is one `cos` + one `sin` of the
//! argument of latitude plus a handful of multiply-adds. The free
//! functions below delegate to it, and `WalkerConstellation` caches one
//! basis per satellite at build time — the contact-plan scanner's hot
//! path (`coordinator::contact`) therefore never recomputes plane
//! trigonometry.
//!
//! Bit-identity contract: `PlaneBasis::position_at` performs, operation
//! for operation, the same arithmetic as the original
//! `in_plane.rot_x(inc).rot_z(raan)` rotation chain (the hoisted
//! factors are kept as the rotations' own `sin_cos` values, never
//! re-associated into combined products), so positions — and every
//! contact window derived from them — are bit-for-bit unchanged. The
//! `matches_rotation_chain_bitwise` test below pins this down against
//! the literal rotation chain.

use super::elements::OrbitalElements;
use crate::util::Vec3;

/// Precomputed orthonormal in-plane basis of one satellite's orbit,
/// kept in factored form: `cos`/`sin` of RAAN and inclination (the
/// basis vectors are `p = rot_z(raan)·x̂`, `q = rot_z(raan)·rot_x(inc)·ŷ`
/// — storing their products instead of the factors would re-associate
/// the arithmetic and break bit-identity with the rotation chain).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlaneBasis {
    /// Orbit radius (semi-major axis), km.
    r_km: f64,
    /// Orbital speed, km/s (circular orbit).
    v_km_s: f64,
    /// Mean motion, rad/s.
    n_rad_s: f64,
    /// Argument of latitude at t = 0, radians.
    phase_rad: f64,
    cos_raan: f64,
    sin_raan: f64,
    cos_inc: f64,
    sin_inc: f64,
}

impl PlaneBasis {
    pub fn new(e: &OrbitalElements) -> Self {
        let (sin_raan, cos_raan) = e.raan_rad.sin_cos();
        let (sin_inc, cos_inc) = e.inclination_rad.sin_cos();
        PlaneBasis {
            r_km: e.semi_major_axis_km(),
            v_km_s: e.velocity_km_s(),
            n_rad_s: e.mean_motion_rad_s(),
            phase_rad: e.phase_rad,
            cos_raan,
            sin_raan,
            cos_inc,
            sin_inc,
        }
    }

    /// Orbit radius (semi-major axis), km.
    pub fn radius_km(&self) -> f64 {
        self.r_km
    }

    /// Mean motion, rad/s — the angular rate of the satellite's
    /// direction vector (the contact scanner's skip bound uses this).
    pub fn mean_motion_rad_s(&self) -> f64 {
        self.n_rad_s
    }

    /// Argument of latitude at t = 0, radians. Together with
    /// [`Self::mean_motion_rad_s`] this determines `u(t) = phase + n·t`,
    /// which the analytic contact predictor (`coordinator::analytic`)
    /// inverts for first-possible-contact times.
    pub fn phase_rad(&self) -> f64 {
        self.phase_rad
    }

    /// Rotate an in-plane vector `(x, y, 0)` into ECI. Op-for-op the
    /// original `rot_x(inc)` + `rot_z(raan)` chain with the per-call
    /// trigonometry hoisted into the constructor (the dropped
    /// `± sin·0.0` terms of the z = 0 input affect at most the sign of
    /// a zero, which no downstream comparison can observe).
    #[inline]
    fn to_eci(&self, x: f64, y: f64) -> Vec3 {
        let y1 = self.cos_inc * y;
        Vec3::new(
            self.cos_raan * x - self.sin_raan * y1,
            self.sin_raan * x + self.cos_raan * y1,
            self.sin_inc * y,
        )
    }

    /// Position in ECI at simulated time `t` seconds, km.
    ///
    /// For a circular orbit the argument of latitude advances
    /// uniformly, `u(t) = phase + n·t`.
    #[inline]
    pub fn position_at(&self, t: f64) -> Vec3 {
        let u = self.phase_rad + self.n_rad_s * t;
        self.to_eci(self.r_km * u.cos(), self.r_km * u.sin())
    }

    /// Velocity in ECI at time `t`, km/s (tangential, circular orbit).
    #[inline]
    pub fn velocity_at(&self, t: f64) -> Vec3 {
        let u = self.phase_rad + self.n_rad_s * t;
        self.to_eci(-self.v_km_s * u.sin(), self.v_km_s * u.cos())
    }
}

/// Position of a satellite in the Earth-centered inertial frame at
/// simulated time `t` seconds (one-shot convenience; hot paths cache a
/// [`PlaneBasis`] instead).
pub fn satellite_position_eci(e: &OrbitalElements, t: f64) -> Vec3 {
    PlaneBasis::new(e).position_at(t)
}

/// Velocity vector in ECI, km/s (tangential for circular orbits).
pub fn satellite_velocity_eci(e: &OrbitalElements, t: f64) -> Vec3 {
    PlaneBasis::new(e).velocity_at(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::elements::{EARTH_RADIUS_KM, MU_EARTH};
    use crate::util::Rng;

    fn e() -> OrbitalElements {
        OrbitalElements {
            altitude_km: 2000.0,
            inclination_rad: 80f64.to_radians(),
            raan_rad: 0.7,
            phase_rad: 0.3,
        }
    }

    #[test]
    fn radius_constant_over_time() {
        let e = e();
        let r0 = e.semi_major_axis_km();
        for i in 0..50 {
            let t = i as f64 * 431.7;
            let r = satellite_position_eci(&e, t).norm();
            assert!((r - r0).abs() < 1e-6, "t={t}: r={r} vs {r0}");
        }
    }

    #[test]
    fn returns_to_start_after_one_period() {
        let e = e();
        let p0 = satellite_position_eci(&e, 0.0);
        let p1 = satellite_position_eci(&e, e.period_s());
        assert!(p0.distance(p1) < 1e-6);
    }

    #[test]
    fn half_period_is_antipodal() {
        let e = e();
        let p0 = satellite_position_eci(&e, 0.0);
        let ph = satellite_position_eci(&e, e.period_s() / 2.0);
        assert!(p0.distance(-ph) < 1e-6);
    }

    #[test]
    fn velocity_orthogonal_to_position() {
        let e = e();
        for i in 0..10 {
            let t = i as f64 * 997.0;
            let p = satellite_position_eci(&e, t);
            let v = satellite_velocity_eci(&e, t);
            assert!(p.dot(v).abs() < 1e-6);
        }
    }

    #[test]
    fn speed_matches_vis_viva() {
        let e = e();
        let v = satellite_velocity_eci(&e, 123.0).norm();
        let expect = (MU_EARTH / (EARTH_RADIUS_KM + 2000.0)).sqrt();
        assert!((v - expect).abs() < 1e-9);
    }

    #[test]
    fn inclination_bounds_z_extent() {
        let e = e();
        // |z| <= a * sin(i)
        let bound = e.semi_major_axis_km() * e.inclination_rad.sin() + 1e-6;
        for i in 0..200 {
            let p = satellite_position_eci(&e, i as f64 * 61.3);
            assert!(p.z.abs() <= bound);
        }
    }

    /// The bit-identity contract of the module docs: the cached basis
    /// reproduces the literal rotation chain exactly, bit for bit, over
    /// random elements and times. Every contact window in the system
    /// rests on this equality.
    #[test]
    fn matches_rotation_chain_bitwise() {
        let mut rng = Rng::new(0x9E0);
        for _ in 0..500 {
            let e = OrbitalElements {
                altitude_km: rng.range_f64(300.0, 2500.0),
                inclination_rad: rng.range_f64(0.01, 3.1),
                raan_rad: rng.range_f64(0.0, 6.28),
                phase_rad: rng.range_f64(0.0, 6.28),
            };
            let basis = PlaneBasis::new(&e);
            for k in 0..8 {
                let t = k as f64 * 17_351.75 + rng.range_f64(0.0, 1e6);
                // the pre-basis formula, verbatim
                let u = e.phase_rad + e.mean_motion_rad_s() * t;
                let r = e.semi_major_axis_km();
                let chain = Vec3::new(r * u.cos(), r * u.sin(), 0.0)
                    .rot_x(e.inclination_rad)
                    .rot_z(e.raan_rad);
                let fast = basis.position_at(t);
                assert_eq!(chain.x.to_bits(), fast.x.to_bits(), "x at t={t}");
                assert_eq!(chain.y.to_bits(), fast.y.to_bits(), "y at t={t}");
                assert_eq!(chain.z.to_bits(), fast.z.to_bits(), "z at t={t}");
                let v = e.velocity_km_s();
                let vchain = Vec3::new(-v * u.sin(), v * u.cos(), 0.0)
                    .rot_x(e.inclination_rad)
                    .rot_z(e.raan_rad);
                let vfast = basis.velocity_at(t);
                assert_eq!(vchain.x.to_bits(), vfast.x.to_bits(), "vx at t={t}");
                assert_eq!(vchain.y.to_bits(), vfast.y.to_bits(), "vy at t={t}");
                assert_eq!(vchain.z.to_bits(), vfast.z.to_bits(), "vz at t={t}");
            }
        }
    }
}
