//! The metrics registry: named counters, fixed-bucket histograms and
//! per-link load accumulators, folded into an
//! [`super::report::ObsReport`] at the end of a run.
//!
//! Everything is keyed by `&'static str` in `BTreeMap`s (plus one
//! `HashMap` for the per-link loads, sorted at report time), so a
//! report's serialization order is deterministic — two identical runs
//! produce byte-identical `report.json` metric sections.

use std::collections::{BTreeMap, HashMap};

/// Buckets for the staleness-at-aggregation histogram (global epochs a
/// model lagged the round it was folded into; AsyncFLEO's discounting
/// lever — paper Sec. V).
pub const STALENESS_BUCKETS: &[f64] = &[0.0, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0];

/// Buckets for the event-queue depth histogram (sampled at pops).
pub const DEPTH_BUCKETS: &[f64] =
    &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 2048.0];

/// Buckets for per-transfer effective delay, seconds (fault deferrals
/// push the tail into the hours).
pub const DELAY_BUCKETS: &[f64] =
    &[0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 300.0, 1800.0, 7200.0];

/// A fixed-bucket histogram: `bounds[i]` is the inclusive upper edge of
/// bucket `i`, with one extra overflow bucket past the last bound.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: &'static [f64],
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    pub fn new(bounds: &'static [f64]) -> Self {
        Histogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            total: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn bounds(&self) -> &'static [f64] {
        self.bounds
    }

    /// Per-bucket counts (`bounds.len() + 1` entries, last = overflow).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Human label of bucket `i` (`<=bound` or `>last`).
    pub fn bucket_label(&self, i: usize) -> String {
        if i < self.bounds.len() {
            format!("<={}", self.bounds[i])
        } else {
            format!(">{}", self.bounds.last().copied().unwrap_or(0.0))
        }
    }
}

/// Identity of one physical link in the load table. Bidirectional
/// classes (ISL, IHL) are direction-normalized by the caller so both
/// directions accumulate into one row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkKey {
    /// Link class tag (`"site"`, `"isl"`, `"ihl"`).
    pub class: &'static str,
    pub a: u32,
    pub b: u32,
}

/// Accumulated load of one link.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkLoad {
    /// Total seconds the link spent carrying (or deferring) transfers.
    pub busy_s: f64,
    /// Total payload bits sent, retransmissions included.
    pub bits: f64,
    /// Transfer count.
    pub count: u64,
}

/// The per-run metrics registry (see module docs).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    histograms: BTreeMap<&'static str, Histogram>,
    links: HashMap<LinkKey, LinkLoad>,
}

impl Metrics {
    pub fn inc(&mut self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &'static str, n: u64) {
        *self.counters.entry(name).or_insert(0) += n;
    }

    /// Keep the maximum of all reported values (high-water marks).
    pub fn set_max(&mut self, name: &'static str, v: u64) {
        let e = self.counters.entry(name).or_insert(0);
        if v > *e {
            *e = v;
        }
    }

    /// Observe `v` into the named histogram, creating it with `bounds`
    /// on first use.
    pub fn observe(&mut self, name: &'static str, bounds: &'static [f64], v: f64) {
        self.histograms
            .entry(name)
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    /// Accumulate load on one link.
    pub fn link(&mut self, class: &'static str, a: u32, b: u32, busy_s: f64, bits: f64) {
        let e = self.links.entry(LinkKey { class, a, b }).or_default();
        e.busy_s += busy_s;
        e.bits += bits;
        e.count += 1;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn counters(&self) -> &BTreeMap<&'static str, u64> {
        &self.counters
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn histograms(&self) -> &BTreeMap<&'static str, Histogram> {
        &self.histograms
    }

    /// Links sorted busiest-first (ties broken by key), for the top-N
    /// utilization tables. The underlying `HashMap` iteration order
    /// never leaks into output.
    pub fn sorted_links(&self) -> Vec<(LinkKey, LinkLoad)> {
        let mut rows: Vec<(LinkKey, LinkLoad)> =
            self.links.iter().map(|(k, v)| (*k, *v)).collect();
        rows.sort_by(|x, y| y.1.busy_s.total_cmp(&x.1.busy_s).then(x.0.cmp(&y.0)));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.0, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.counts(), &[2, 1, 1, 1], "<=1 twice, <=2 once, <=4 once, overflow once");
        assert_eq!(h.total(), 5);
        assert!((h.mean() - 21.2).abs() < 1e-12);
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.bucket_label(0), "<=1");
        assert_eq!(h.bucket_label(3), ">4");
    }

    #[test]
    fn empty_histogram_is_zeroed() {
        let h = Histogram::new(STALENESS_BUCKETS);
        assert_eq!(h.total(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn counters_accumulate_and_high_water() {
        let mut m = Metrics::default();
        m.inc("evals");
        m.add("evals", 2);
        assert_eq!(m.counter("evals"), 3);
        m.set_max("hw", 5);
        m.set_max("hw", 3);
        assert_eq!(m.counter("hw"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn links_sort_busiest_first_deterministically() {
        let mut m = Metrics::default();
        m.link("isl", 1, 2, 0.5, 100.0);
        m.link("isl", 1, 2, 0.5, 100.0);
        m.link("site", 3, 0, 0.25, 100.0);
        m.link("ihl", 0, 1, 1.5, 100.0);
        let rows = m.sorted_links();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].0, LinkKey { class: "ihl", a: 0, b: 1 });
        assert_eq!(rows[1].0, LinkKey { class: "isl", a: 1, b: 2 });
        assert_eq!(rows[1].1.count, 2);
        assert_eq!(rows[1].1.busy_s, 1.0);
        assert_eq!(rows[2].0.class, "site");
    }
}
