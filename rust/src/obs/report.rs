//! The per-run observation report ([`ObsReport`]): counters,
//! histograms, link loads and phase timings folded into one
//! serializable value, plus [`summarize_trace`] — the renderer behind
//! `asyncfleo report` (staleness histogram, top links by utilization,
//! fault/network-impairment table from the `fault_hit` record kinds,
//! time-in-phase table, accuracy curve via [`crate::metrics::chart`]).
//!
//! JSON is emitted by the same hand-rolled writer as the trace
//! ([`super::trace`]); map-backed sections serialize in key order, so
//! identical runs produce byte-identical reports (modulo the
//! wall-clock phase values, which are explicitly non-deterministic).

use super::metrics::{Histogram, LinkKey, LinkLoad};
use super::trace::{jnum, json_escape};
use super::RunObs;
use crate::metrics::{chart, Curve, CurvePoint};
use std::collections::HashMap;

/// How many links `to_json` and the trace summary keep (the full table
/// can be 4·n_sats wide on mega-constellations; the report states the
/// total so the cap is never silent).
const TOP_LINKS: usize = 20;

/// One link's aggregated load row.
#[derive(Clone, Copy, Debug)]
pub struct LinkRow {
    pub class: &'static str,
    pub a: u32,
    pub b: u32,
    pub busy_s: f64,
    pub bits: f64,
    pub count: u64,
}

/// Snapshot of one run's observation state (see module docs). Carried
/// by `coordinator::RunResult` when the run was observed, so sweep
/// executors stream it with the result rows.
#[derive(Clone, Debug)]
pub struct ObsReport {
    pub horizon_s: f64,
    pub counters: Vec<(&'static str, u64)>,
    pub histograms: Vec<(&'static str, Histogram)>,
    /// All links, busiest first (serialization caps at [`TOP_LINKS`]).
    pub links: Vec<LinkRow>,
    /// Per-run phases: `(name, total seconds, times entered)`.
    pub phases: Vec<(&'static str, f64, u64)>,
}

impl ObsReport {
    pub(super) fn of(obs: &RunObs) -> ObsReport {
        ObsReport {
            horizon_s: obs.horizon_s,
            counters: obs.metrics.counters().iter().map(|(&k, &v)| (k, v)).collect(),
            histograms: obs
                .metrics
                .histograms()
                .iter()
                .map(|(&k, v)| (k, v.clone()))
                .collect(),
            links: obs
                .metrics
                .sorted_links()
                .into_iter()
                .map(|(LinkKey { class, a, b }, LinkLoad { busy_s, bits, count })| LinkRow {
                    class,
                    a,
                    b,
                    busy_s,
                    bits,
                    count,
                })
                .collect(),
            phases: obs.phases.entries().collect(),
        }
    }

    /// Fraction of the horizon a link spent busy (0 when the horizon
    /// is unknown).
    pub fn utilization(&self, row: &LinkRow) -> f64 {
        if self.horizon_s > 0.0 {
            row.busy_s / self.horizon_s
        } else {
            0.0
        }
    }

    /// Serialize as a JSON object, indented under `pad` (the object's
    /// own braces are flush with `pad`).
    pub fn to_json(&self, pad: &str) -> String {
        let q = format!("{pad}  ");
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("{q}\"horizon_s\": {},\n", jnum(self.horizon_s)));
        let counters: Vec<String> = self
            .counters
            .iter()
            .map(|(k, v)| format!("\"{}\": {v}", json_escape(k)))
            .collect();
        out.push_str(&format!("{q}\"counters\": {{{}}},\n", counters.join(", ")));
        let hists: Vec<String> = self
            .histograms
            .iter()
            .map(|(k, h)| {
                let bounds: Vec<String> = h.bounds().iter().map(|&b| jnum(b)).collect();
                let counts: Vec<String> = h.counts().iter().map(|c| c.to_string()).collect();
                format!(
                    "\"{}\": {{\"bounds\": [{}], \"counts\": [{}], \"total\": {}, \"mean\": {}, \"max\": {}}}",
                    json_escape(k),
                    bounds.join(", "),
                    counts.join(", "),
                    h.total(),
                    jnum(h.mean()),
                    jnum(h.max()),
                )
            })
            .collect();
        if hists.is_empty() {
            out.push_str(&format!("{q}\"histograms\": {{}},\n"));
        } else {
            out.push_str(&format!(
                "{q}\"histograms\": {{\n{q}  {}\n{q}}},\n",
                hists.join(&format!(",\n{q}  "))
            ));
        }
        out.push_str(&format!("{q}\"links_total\": {},\n", self.links.len()));
        let links: Vec<String> = self
            .links
            .iter()
            .take(TOP_LINKS)
            .map(|r| {
                format!(
                    "{{\"class\": \"{}\", \"a\": {}, \"b\": {}, \"busy_s\": {}, \"bits\": {}, \"count\": {}, \"utilization\": {}}}",
                    r.class,
                    r.a,
                    r.b,
                    jnum(r.busy_s),
                    jnum(r.bits),
                    r.count,
                    jnum(self.utilization(r)),
                )
            })
            .collect();
        if links.is_empty() {
            out.push_str(&format!("{q}\"links\": [],\n"));
        } else {
            out.push_str(&format!(
                "{q}\"links\": [\n{q}  {}\n{q}],\n",
                links.join(&format!(",\n{q}  "))
            ));
        }
        let phases: Vec<String> = self
            .phases
            .iter()
            .map(|(n, s, c)| {
                format!(
                    "{{\"name\": \"{}\", \"secs\": {}, \"count\": {c}}}",
                    json_escape(n),
                    jnum(*s),
                )
            })
            .collect();
        if phases.is_empty() {
            out.push_str(&format!("{q}\"phases\": []\n"));
        } else {
            out.push_str(&format!(
                "{q}\"phases\": [\n{q}  {}\n{q}]\n",
                phases.join(&format!(",\n{q}  "))
            ));
        }
        out.push_str(&format!("{pad}}}"));
        out
    }
}

/// Extract the raw value of `"key":` from one flat JSON record line
/// (string quotes stripped). Only valid for the flat single-object
/// lines this crate's trace writer emits.
fn field<'x>(line: &'x str, key: &str) -> Option<&'x str> {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat)? + pat.len();
    let rest = &line[i..];
    let end = rest
        .find(|c| c == ',' || c == '}')
        .unwrap_or(rest.len());
    Some(rest[..end].trim().trim_matches('"'))
}

fn fnum(line: &str, key: &str) -> Option<f64> {
    field(line, key)?.parse().ok()
}

/// One ASCII histogram bar, scaled to `width` at `max`.
fn bar(count: u64, max: u64, width: usize) -> String {
    if max == 0 {
        return String::new();
    }
    let n = ((count as f64 / max as f64) * width as f64).round() as usize;
    "#".repeat(n.min(width))
}

/// Render a human summary of one trace: record counts, the staleness
/// histogram, the top links by utilization, the accuracy curve, and —
/// when the sibling `report.json` text is supplied — the time-in-phase
/// table (wall-clock phases live only in the report, never in the
/// deterministic trace).
pub fn summarize_trace(trace: &str, report_json: Option<&str>) -> String {
    let mut out = String::new();
    let mut counts: Vec<(String, u64)> = Vec::new();
    let mut horizon_s = 0.0f64;
    let mut staleness: Vec<f64> = Vec::new();
    let mut fault_kinds: Vec<(String, u64)> = Vec::new();
    let mut links: HashMap<(String, String, String), (f64, u64)> = HashMap::new();
    let mut curve = Curve::default();
    let mut n_lines = 0u64;

    for line in trace.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        n_lines += 1;
        let ev = field(line, "ev").unwrap_or("?").to_string();
        match counts.iter_mut().find(|(k, _)| *k == ev) {
            Some((_, c)) => *c += 1,
            None => counts.push((ev.clone(), 1)),
        }
        match ev.as_str() {
            "meta" => {
                horizon_s = fnum(line, "horizon_s").unwrap_or(0.0);
                out.push_str(&format!(
                    "trace: preset {} · scheme {} · seed {} · horizon {:.1} h · {} sats, {} sites\n",
                    field(line, "preset").unwrap_or("?"),
                    field(line, "scheme").unwrap_or("?"),
                    field(line, "seed").unwrap_or("?"),
                    horizon_s / 3600.0,
                    field(line, "n_sats").unwrap_or("?"),
                    field(line, "n_sites").unwrap_or("?"),
                ));
            }
            "aggregate" => {
                if let Some(s) = fnum(line, "staleness") {
                    staleness.push(s);
                }
            }
            "model_tx" => {
                let key = (
                    field(line, "link").unwrap_or("?").to_string(),
                    field(line, "src").unwrap_or("?").to_string(),
                    field(line, "dst").unwrap_or("?").to_string(),
                );
                let e = links.entry(key).or_insert((0.0, 0));
                e.0 += fnum(line, "delay_s").unwrap_or(0.0);
                e.1 += 1;
            }
            "fault_hit" => {
                let kind = field(line, "kind").unwrap_or("?").to_string();
                let n = fnum(line, "n").unwrap_or(1.0) as u64;
                match fault_kinds.iter_mut().find(|(k, _)| *k == kind) {
                    Some((_, c)) => *c += n,
                    None => fault_kinds.push((kind, n)),
                }
            }
            "eval" => {
                curve.push(CurvePoint {
                    time_s: fnum(line, "t").unwrap_or(0.0),
                    epoch: fnum(line, "epoch").unwrap_or(0.0) as u64,
                    accuracy: fnum(line, "accuracy").unwrap_or(0.0),
                    loss: fnum(line, "loss").unwrap_or(0.0),
                });
            }
            _ => {}
        }
    }

    out.push_str(&format!("records: {n_lines} ("));
    let parts: Vec<String> = counts.iter().map(|(k, c)| format!("{k} {c}")).collect();
    out.push_str(&parts.join(", "));
    out.push_str(")\n");

    // -- staleness histogram (from aggregate records) --
    out.push_str("\n== staleness at aggregation ==\n");
    if staleness.is_empty() {
        out.push_str("  (no aggregate records)\n");
    } else {
        let bounds = super::metrics::STALENESS_BUCKETS;
        let mut h = Histogram::new(bounds);
        for &s in &staleness {
            h.observe(s);
        }
        out.push_str(&format!(
            "  {} aggregations, mean {:.2}, max {:.0}\n",
            h.total(),
            h.mean(),
            h.max()
        ));
        let peak = h.counts().iter().copied().max().unwrap_or(0);
        for (i, &c) in h.counts().iter().enumerate() {
            out.push_str(&format!(
                "  {:>6} {:>6}  {}\n",
                h.bucket_label(i),
                c,
                bar(c, peak, 40)
            ));
        }
    }

    // -- top links by utilization (busy time / horizon) --
    out.push_str("\n== top links by utilization ==\n");
    if links.is_empty() {
        out.push_str("  (no model_tx records)\n");
    } else {
        let mut rows: Vec<((String, String, String), (f64, u64))> = links.into_iter().collect();
        rows.sort_by(|x, y| y.1 .0.total_cmp(&x.1 .0).then(x.0.cmp(&y.0)));
        out.push_str(&format!(
            "  {:<6} {:>6} {:>6} {:>10} {:>9} {:>12}\n",
            "link", "a", "b", "busy_s", "transfers", "utilization"
        ));
        for ((class, a, b), (busy, count)) in rows.iter().take(10) {
            let util = if horizon_s > 0.0 { busy / horizon_s } else { 0.0 };
            out.push_str(&format!(
                "  {class:<6} {a:>6} {b:>6} {busy:>10.3} {count:>9} {util:>11.4}%\n",
                util = util * 100.0
            ));
        }
        if rows.len() > 10 {
            out.push_str(&format!("  ({} more links)\n", rows.len() - 10));
        }
    }

    // -- fault & network impairments (from fault_hit records) --
    if !fault_kinds.is_empty() {
        out.push_str("\n== fault & network impairments ==\n");
        out.push_str(&format!("  {:<12} {:>8}\n", "kind", "events"));
        for (kind, n) in &fault_kinds {
            out.push_str(&format!("  {kind:<12} {n:>8}\n"));
        }
    }

    // -- time in phase (wall clock; from report.json when available) --
    out.push_str("\n== time in phase ==\n");
    match report_json.map(phase_rows) {
        Some(rows) if !rows.is_empty() => {
            out.push_str(&format!(
                "  {:<24} {:>10} {:>8}\n",
                "phase", "secs", "count"
            ));
            for (name, secs, count) in rows {
                out.push_str(&format!("  {name:<24} {secs:>10.4} {count:>8}\n"));
            }
        }
        _ => out.push_str("  (no report.json alongside the trace — wall-clock phases unavailable)\n"),
    }

    // -- accuracy curve (from eval records) --
    if curve.points.len() >= 2 {
        out.push_str("\n== accuracy ==\n");
        out.push_str(&chart::render_curve(&curve, 64, 12));
        out.push('\n');
    }
    out
}

/// Pull every `{"name": ..., "secs": ..., "count": ...}` row out of the
/// report's `"phases"` arrays (per-run and substrate alike).
fn phase_rows(report: &str) -> Vec<(String, f64, u64)> {
    let mut rows = Vec::new();
    let mut rest = report;
    while let Some(i) = rest.find("\"name\":") {
        let tail = &rest[i..];
        let end = tail.find('}').unwrap_or(tail.len());
        let obj = &tail[..end];
        if let (Some(name), Some(secs)) = (field(obj, "name"), fnum(obj, "secs")) {
            let count = fnum(obj, "count").unwrap_or(0.0) as u64;
            rows.push((name.to_string(), secs, count));
        }
        rest = &tail[end..];
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::LinkClass;

    fn sample_obs() -> RunObs {
        let mut o = RunObs::to_memory();
        o.meta("paper-40", "asyncfleo", 42, 7200.0, 6, 2);
        o.model_tx(
            10.0,
            &LinkClass::SatSite { sat: 1, site: 0 },
            0.1,
            0.3,
            1,
            1000.0,
        );
        o.model_tx(
            20.0,
            &LinkClass::SatSite { sat: 1, site: 0 },
            0.1,
            0.1,
            0,
            1000.0,
        );
        o.staleness(0.0);
        o.staleness(3.0);
        o.aggregate(30.0, 2, 2, 3.0, 0.5);
        o.eval(30.0, 1, 0.4, 1.2);
        o.eval(60.0, 2, 0.6, 0.8);
        o.phases.add("aggregate", 0.5);
        o
    }

    #[test]
    fn report_serializes_deterministic_json() {
        let obs = sample_obs();
        let r = obs.report();
        assert_eq!(r.horizon_s, 7200.0);
        let json = r.to_json("");
        assert!(json.contains("\"counters\""));
        assert!(json.contains("\"tx.site\": 2"));
        assert!(json.contains("\"staleness\""));
        assert!(json.contains("\"phases\""));
        assert!(json.contains("\"name\": \"aggregate\""));
        // byte-determinism of the metric sections
        assert_eq!(json, sample_obs().report().to_json(""));
        // link rows carry utilization against the meta horizon
        let row = r.links.first().expect("one link row");
        assert_eq!(row.count, 2);
        assert!((r.utilization(row) - 0.4 / 7200.0).abs() < 1e-15);
    }

    #[test]
    fn summarize_renders_histogram_links_and_phases() {
        let obs = sample_obs();
        let trace = obs.sink.lines().join("\n");
        let report = obs.report().to_json("");
        let s = summarize_trace(&trace, Some(&report));
        assert!(s.contains("preset paper-40"), "{s}");
        assert!(s.contains("staleness at aggregation"), "{s}");
        assert!(s.contains("top links by utilization"), "{s}");
        assert!(s.contains("time in phase"), "{s}");
        assert!(s.contains("aggregate"), "{s}");
        assert!(s.contains("site"), "{s}");
        // without a report, phases degrade gracefully
        let s2 = summarize_trace(&trace, None);
        assert!(s2.contains("wall-clock phases unavailable"), "{s2}");
    }

    #[test]
    fn summarize_tabulates_fault_hit_kinds() {
        let mut obs = sample_obs();
        obs.fault_hit(5.0, "loss", 1);
        obs.fault_hit(6.0, "queue", 3);
        obs.fault_hit(7.0, "queue", 2);
        obs.fault_hit(8.0, "partition", 1);
        let trace = obs.sink.lines().join("\n");
        let s = summarize_trace(&trace, None);
        assert!(s.contains("fault & network impairments"), "{s}");
        assert!(s.contains("loss"), "{s}");
        // the two queue records fold into one row of 5 events
        assert!(s.contains("queue              5"), "{s}");
        assert!(s.contains("partition"), "{s}");
        // a trace with no fault_hit records omits the section entirely
        let s2 = summarize_trace(&sample_obs().sink.lines().join("\n"), None);
        assert!(!s2.contains("impairments"), "{s2}");
    }

    #[test]
    fn field_extractor_handles_strings_and_numbers() {
        let line = "{\"ev\":\"meta\",\"preset\":\"paper-40\",\"seed\":42,\"horizon_s\":259200}";
        assert_eq!(field(line, "ev"), Some("meta"));
        assert_eq!(field(line, "preset"), Some("paper-40"));
        assert_eq!(fnum(line, "seed"), Some(42.0));
        assert_eq!(fnum(line, "horizon_s"), Some(259200.0));
        assert_eq!(field(line, "missing"), None);
    }
}
