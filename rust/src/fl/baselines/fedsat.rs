//! FedSat (Razmi et al. [10]): asynchronous FL assuming a ground
//! station at the North Pole so every satellite visits at regular
//! intervals. On each visit the satellite uploads its freshly trained
//! model and the PS applies an immediate asynchronous update
//! `w ← (1-α)·w + α·w_n`; the satellite then downloads the new global
//! model and trains during its flight until the next visit.
//!
//! No staleness handling is needed *because* of the regular-visit
//! assumption — which is exactly the restrictive "ideal setup" the
//! paper criticizes (Sec. II).

use crate::coordinator::{RunResult, SimEnv};
use crate::fl::Strategy;
use crate::metrics::ConvergenceDetector;
use crate::model::ModelParams;

/// Mixing rate of one asynchronous update (scaled by relative shard
/// size, clipped for stability).
const BASE_ALPHA: f64 = 0.12;
/// Evaluate the global model every this many async updates.
const EVAL_EVERY: usize = 10;

#[derive(Default)]
pub struct FedSat;

impl Strategy for FedSat {
    fn name(&self) -> &'static str {
        "fedsat"
    }

    fn run(&mut self, env: &mut SimEnv) -> RunResult {
        let n_sats = env.geo.constellation.len();
        let dispatches = env.cfg.fl.local_dispatches;
        let train_time = env.cfg.fl.train_time_s;
        let horizon = env.cfg.fl.horizon_s;
        let mut detector = ConvergenceDetector::new(8, 0.003);

        let mut global = env.state.backend.init_global(env.cfg.seed as i32);
        let e0 = env.state.backend.evaluate(&global);
        env.record(0.0, 0, e0.accuracy, e0.loss);

        let mean_size: f64 = (0..n_sats)
            .map(|s| env.state.backend.shard_size(s) as f64)
            .sum::<f64>()
            / n_sats as f64;

        // Merge all (contact, sat, site) events over the horizon.
        let mut visits: Vec<(f64, usize, usize)> = Vec::new();
        for sat in 0..n_sats {
            for site in 0..env.geo.sites.len() {
                for w in env.geo.plan.windows(site, sat) {
                    visits.push((w.start_s, sat, site));
                }
            }
        }
        // window times are finite by construction: total_cmp never
        // meets a NaN and keeps the sort panic-free
        visits.sort_by(|a, b| a.0.total_cmp(&b.0));

        // Per-sat: time its current training completes (ready to upload
        // at the first visit after that) — sats start training on w^0
        // received at their *first* visit.
        let mut ready_at: Vec<Option<f64>> = vec![None; n_sats];
        let mut updates: u64 = 0;
        let mut converged = false;
        let mut last_t = 0.0;
        // reused across visits: the trained local model and the
        // aggregate double-buffer (in-place backend API, same floats)
        let mut local = ModelParams { data: Vec::new() };
        let mut next = ModelParams { data: Vec::with_capacity(global.dim()) };

        let ph_loop = env.phase_start();
        for (t, sat, site) in visits {
            if t > horizon || converged {
                break;
            }
            // typed churn consumption (ROADMAP PR-1 follow-up): a dark
            // satellite's pass simply doesn't happen, and neither does
            // a pass at a failed PS site — both predicates are always
            // true with faults disabled, so clean runs are unchanged
            if !env.state.faults.sat_alive(sat, t) || !env.state.faults.hap_alive(site, t) {
                continue;
            }
            last_t = t;
            match ready_at[sat] {
                None => {
                    // first visit: download w^0 (or current), train in flight
                    let d = env.site_link_delay(site, sat, t);
                    ready_at[sat] = Some(t + d + train_time);
                }
                Some(ready) if ready <= t => {
                    // upload trained model; async update; download new global
                    env.state.backend.train_local_into(sat, &global, dispatches, &mut local);
                    let d_up = env.site_link_delay(site, sat, t);
                    let alpha = (BASE_ALPHA * env.state.backend.shard_size(sat) as f64
                        / mean_size)
                        .clamp(0.01, 0.5) as f32;
                    env.state
                        .backend
                        .aggregate_into(&global, &[&local], &[alpha], 1.0 - alpha, &mut next);
                    std::mem::swap(&mut global, &mut next);
                    updates += 1;
                    if let Some(obs) = env.obs() {
                        // immediate per-visit update: one model, never
                        // stale, mixed in at rate alpha
                        obs.staleness(0.0);
                        obs.aggregate(t, 1, 1, 0.0, alpha as f64);
                    }
                    let d_down = env.site_link_delay(site, sat, t + d_up);
                    ready_at[sat] = Some(t + d_up + d_down + train_time);
                    if updates as usize % EVAL_EVERY == 0 {
                        let e = env.state.backend.evaluate(&global);
                        env.record(t, updates, e.accuracy, e.loss);
                        converged = detector.update(e.accuracy) && updates >= 30;
                    }
                }
                Some(_) => {} // still training: skip this pass
            }
        }
        env.phase_end("event_loop", ph_loop);
        if env.state.curve.points.len() < 2 {
            let e = env.state.backend.evaluate(&global);
            env.record(last_t.max(1.0), updates, e.accuracy, e.loss);
        }
        RunResult::from_env("fedsat", env, updates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PsPlacement};
    use crate::coordinator::SimEnv;
    use crate::train::SurrogateBackend;

    fn run(placement: PsPlacement, horizon_h: f64) -> RunResult {
        let mut cfg = ExperimentConfig::paper_defaults();
        cfg.placement = placement;
        cfg.fl.horizon_s = horizon_h * 3600.0;
        let mut b = SurrogateBackend::paper_split(5, 8, false, 100);
        let mut env = SimEnv::new(&cfg, &mut b);
        FedSat.run(&mut env)
    }

    #[test]
    fn np_gs_gives_many_updates() {
        let r = run(PsPlacement::GsNorthPole, 24.0);
        // 40 sats visiting ~ every period: hundreds of updates/day
        assert!(r.epochs > 50, "updates {}", r.epochs);
        assert!(r.final_accuracy > 0.6, "acc {}", r.final_accuracy);
    }

    #[test]
    fn arbitrary_gs_much_fewer_updates() {
        let np = run(PsPlacement::GsNorthPole, 12.0);
        let gs = run(PsPlacement::GsRolla, 12.0);
        assert!(np.epochs > gs.epochs, "np {} vs gs {}", np.epochs, gs.epochs);
    }
}
