//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the bridge between L3 (this crate) and the L2/L1 compute:
//! the rust binary is self-contained once `make artifacts` has run —
//! Python never executes on the request path.
//!
//! Interchange format is HLO *text*: jax ≥ 0.5 emits HloModuleProto
//! with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

pub mod executor;
pub mod manifest;

pub use executor::{Executable, Runtime};
pub use manifest::{ArtifactEntry, Manifest, TensorSpec};
