//! Run-loop fast-path equivalence suite (the PR-5 bit-identity
//! contract):
//!
//! * for every built-in preset, each compared scheme produces
//!   **bit-identical** accuracy curves and transfer counts on the
//!   cached-kinematics fast path and on the kept pre-cache reference
//!   (`SimEnv::set_reference_path(true)` + the allocating
//!   `testkit::ReferenceSurrogate` plumbing);
//! * the scheme×scenario sweep writes byte-identical `scenarios.csv`
//!   at `--jobs 1` and `--jobs 4` with the fast path underneath —
//!   together the two assertions pin `results/*.csv` to the pre-PR
//!   bytes on all presets;
//! * the 1584-satellite `starlink-phase1` stress preset passes the
//!   same equivalence as a smoke (shortened horizon).

use asyncfleo::config::{ExperimentConfig, SchemeKind};
use asyncfleo::coordinator::{RunResult, SimEnv};
use asyncfleo::experiments::drivers::ExpOptions;
use asyncfleo::experiments::scenarios::run_compare;
use asyncfleo::fl::{make_strategy, Strategy};
use asyncfleo::scenario::{Scenario, ScenarioRegistry};
use asyncfleo::testkit::{assert_runs_identical, ReferenceSurrogate};
use asyncfleo::train::SurrogateBackend;
use std::path::PathBuf;

/// The schemes the equivalence contract covers: ours plus one
/// synchronous and one asynchronous baseline (the scenario sweep trio).
const SCHEMES: &[SchemeKind] = &[SchemeKind::AsyncFleo, SchemeKind::FedHap, SchemeKind::FedSat];

/// The six presets that existed before the fast path landed.
const EXISTING_PRESETS: &[&str] = &[
    "paper-40",
    "starlink-lite",
    "polar-star",
    "sparse-iot",
    "equatorial-dense",
    "haps-degraded",
];

/// Trim a preset for the suite: equivalence needs events, not
/// convergence — short horizons keep the debug-mode run fast while
/// still driving broadcasts, relays, training and aggregations through
/// both paths.
fn trimmed(cfg: &ExperimentConfig) -> ExperimentConfig {
    let mut c = cfg.clone();
    if c.n_sats() >= 1000 {
        c.fl.horizon_s = 2.0 * 3600.0;
        c.fl.max_epochs = 2;
    } else if c.n_sats() >= 100 {
        c.fl.horizon_s = 6.0 * 3600.0;
        c.fl.max_epochs = 3;
    } else {
        c.fl.horizon_s = 12.0 * 3600.0;
        c.fl.max_epochs = 4;
    }
    c
}

/// One run on the cached-kinematics fast path.
fn run_fast(cfg: &ExperimentConfig) -> RunResult {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    make_strategy(cfg.fl.scheme).run(&mut env)
}

/// One run on the pre-cache reference: per-call site trig + virtual
/// `dim()` delays, allocating model plumbing.
fn run_reference(cfg: &ExperimentConfig) -> RunResult {
    let mut b = ReferenceSurrogate(SurrogateBackend::for_config(cfg));
    let mut env = SimEnv::new(cfg, &mut b);
    env.set_reference_path(true);
    make_strategy(cfg.fl.scheme).run(&mut env)
}

/// One run on the fast path with the PR-9 multi-lane event core.
fn run_lanes(cfg: &ExperimentConfig, lanes: usize) -> RunResult {
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    env.set_lanes(lanes);
    make_strategy(cfg.fl.scheme).run(&mut env)
}

/// The schemes with laned run loops (PR 9): the async event core, one
/// synchronous baseline, and the ISL-graph collection scheme.
const LANE_SCHEMES: &[SchemeKind] =
    &[SchemeKind::AsyncFleo, SchemeKind::FedHap, SchemeKind::SinkSat];

#[test]
fn all_existing_presets_bitwise_equal_across_lane_counts() {
    let reg = ScenarioRegistry::builtin();
    for name in EXISTING_PRESETS {
        let sc = reg.get(name).unwrap_or_else(|| panic!("missing preset {name}"));
        for &scheme in LANE_SCHEMES {
            let mut cfg = trimmed(&sc.cfg);
            cfg.fl.scheme = scheme;
            let one = run_lanes(&cfg, 1);
            for lanes in [2, 4] {
                let n = run_lanes(&cfg, lanes);
                assert_runs_identical(
                    &n,
                    &one,
                    &format!("{name}/{}/lanes{lanes}", scheme.name()),
                );
            }
        }
    }
}

#[test]
fn starlink_gen2_smoke_bitwise_equal_lanes_1_vs_4() {
    let reg = ScenarioRegistry::builtin();
    let sc = reg.get("starlink-gen2").expect("mega preset in catalog");
    let mut cfg = trimmed(&sc.cfg);
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    let one = run_lanes(&cfg, 1);
    let four = run_lanes(&cfg, 4);
    assert_runs_identical(&four, &one, "starlink-gen2/asyncfleo/lanes4");
    assert!(
        !one.curve.points.is_empty(),
        "the mega-constellation run must record at least the initial evaluation"
    );
}

#[test]
fn all_existing_presets_bitwise_equal_fast_vs_reference() {
    let reg = ScenarioRegistry::builtin();
    for name in EXISTING_PRESETS {
        let sc = reg.get(name).unwrap_or_else(|| panic!("missing preset {name}"));
        for &scheme in SCHEMES {
            let mut cfg = trimmed(&sc.cfg);
            cfg.fl.scheme = scheme;
            let fast = run_fast(&cfg);
            let reference = run_reference(&cfg);
            assert_runs_identical(
                &fast,
                &reference,
                &format!("{name}/{}", scheme.name()),
            );
        }
    }
}

#[test]
fn starlink_phase1_smoke_bitwise_equal() {
    let reg = ScenarioRegistry::builtin();
    let sc = reg.get("starlink-phase1").expect("stress preset in catalog");
    let mut cfg = trimmed(&sc.cfg);
    assert_eq!(cfg.n_sats(), 1584);
    cfg.fl.scheme = SchemeKind::AsyncFleo;
    let fast = run_fast(&cfg);
    let reference = run_reference(&cfg);
    assert_runs_identical(&fast, &reference, "starlink-phase1/asyncfleo");
    assert!(
        !fast.curve.points.is_empty(),
        "the mega-constellation run must record at least the initial evaluation"
    );
}

fn temp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("asyncfleo_runloop_equiv_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn scenario_csv_byte_identical_jobs_1_vs_4_on_existing_presets() {
    let reg = ScenarioRegistry::builtin();
    let scenarios: Vec<Scenario> = EXISTING_PRESETS
        .iter()
        .map(|name| {
            let sc = reg.get(name).unwrap();
            Scenario::new(sc.name.clone(), sc.summary.clone(), trimmed(&sc.cfg))
        })
        .collect();
    let dir1 = temp_out("jobs1");
    let dir4 = temp_out("jobs4");
    let opts1 = ExpOptions {
        out_dir: dir1.clone(),
        fast: true,
        surrogate: true,
        seed: 42,
        jobs: 1,
        report: false,
    };
    let opts4 = ExpOptions { out_dir: dir4.clone(), jobs: 4, ..opts1.clone() };
    run_compare(&scenarios, &opts1).expect("--jobs 1 sweep");
    run_compare(&scenarios, &opts4).expect("--jobs 4 sweep");
    let a = std::fs::read(dir1.join("scenarios.csv")).unwrap();
    let b = std::fs::read(dir4.join("scenarios.csv")).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "scenarios.csv must be byte-identical at --jobs 1 and --jobs 4");
    let text = String::from_utf8(a).unwrap();
    for name in EXISTING_PRESETS {
        assert!(text.contains(&format!("{name},asyncfleo")), "{name} row present");
    }
    let _ = std::fs::remove_dir_all(&dir1);
    let _ = std::fs::remove_dir_all(&dir4);
}
