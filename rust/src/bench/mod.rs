//! Micro-benchmark harness substrate (no `criterion` offline).
//!
//! Warmup + timed iterations + summary statistics, with the classic
//! `black_box` to defeat constant folding. `cargo bench` targets under
//! `rust/benches/` (harness = false) drive this.

use crate::util::stats::{summarize, Summary};
use std::time::Instant;

/// Prevent the optimizer from eliding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    /// Hard cap on total wall time, seconds (long end-to-end benches
    /// sample fewer iterations rather than exceeding it).
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, sample_iters: 20, max_seconds: 60.0 }
    }
}

impl BenchConfig {
    /// Config for expensive end-to-end benches (one warmup, few samples).
    pub fn endtoend() -> Self {
        BenchConfig { warmup_iters: 1, sample_iters: 3, max_seconds: 600.0 }
    }
}

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub stats: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.stats;
        format!(
            "{:<42} {:>12} {:>12} {:>12} {:>12}  (n={})",
            self.name,
            fmt_duration(s.mean),
            fmt_duration(s.p50),
            fmt_duration(s.p95),
            fmt_duration(s.std),
            s.n
        )
    }
}

/// Human-friendly duration formatting.
pub fn fmt_duration(seconds: f64) -> String {
    if seconds < 1e-6 {
        format!("{:.1} ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.2} ms", seconds * 1e3)
    } else {
        format!("{:.3} s", seconds)
    }
}

/// Run one benchmark: `f` is invoked repeatedly; its return value is
/// black-boxed.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let started = Instant::now();
    let mut samples = Vec::with_capacity(cfg.sample_iters);
    for _ in 0..cfg.sample_iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if started.elapsed().as_secs_f64() > cfg.max_seconds && samples.len() >= 3 {
            break;
        }
    }
    BenchResult { name: name.to_string(), stats: summarize(&samples).expect("samples") }
}

/// Peak resident set size of this process in MiB (`VmHWM` from
/// `/proc/self/status`), or `None` off Linux / if the field is absent.
/// Benches report it next to their timings so memory regressions on
/// the mega-constellation presets show up in the same JSON artifact.
pub fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

/// Print the standard report header (aligns with [`BenchResult::report`]).
pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<42} {:>12} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p95", "std"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let cfg = BenchConfig { warmup_iters: 1, sample_iters: 5, max_seconds: 10.0 };
        let r = bench("noop", &cfg, || 1 + 1);
        assert_eq!(r.stats.n, 5);
        assert!(r.stats.mean >= 0.0);
    }

    #[test]
    fn bench_time_cap() {
        let cfg = BenchConfig { warmup_iters: 0, sample_iters: 1000, max_seconds: 0.05 };
        let r = bench("sleepy", &cfg, || std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(r.stats.n < 1000, "cap should stop early, got {}", r.stats.n);
        assert!(r.stats.n >= 3);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with("s"));
    }

    #[test]
    fn peak_rss_is_positive_on_linux() {
        if let Some(mb) = peak_rss_mb() {
            assert!(mb > 0.0, "VmHWM parsed as {mb} MiB");
        }
    }

    #[test]
    fn report_contains_name() {
        let cfg = BenchConfig { warmup_iters: 0, sample_iters: 3, max_seconds: 1.0 };
        let r = bench("my_bench", &cfg, || 42);
        assert!(r.report().contains("my_bench"));
    }
}
