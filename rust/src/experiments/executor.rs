//! Deterministic parallel sweep executor.
//!
//! Experiment drivers describe their grid as a list of [`Cell`]s (one
//! configured run each) and hand it to [`run_cells_streaming`], which
//! dispatches cells to `--jobs N` worker threads (plain
//! `std::thread::scope` — the crate is offline/vendored, no rayon) and
//! invokes a per-result callback **in the original cell order** as the
//! ordered prefix completes, so CSV rows and stdout summaries are
//! byte-identical to a sequential run *and* stream to disk while the
//! grid is still running. A long sequential PJRT sweep therefore writes
//! each row as its cell finishes, and an error late in the grid keeps
//! every already-streamed row instead of discarding completed work.
//! [`run_cells`] is the collect-everything convenience wrapper.
//!
//! Scheduling: workers pick cells **longest-first** by the cell's
//! [`Cell::cost_hint`] (ties broken by cell index), which keeps the
//! pool busy at the tail of an uneven grid. Results are still emitted
//! in cell order — a cell's `RunResult` is a pure function of its
//! config, so the pick order affects wall-clock only, never bytes.
//!
//! Determinism contract:
//! * each cell builds its own backend and [`SimEnv`] from its own
//!   config (per-run seeding is untouched), so a cell's `RunResult` is
//!   a pure function of its config — independent of scheduling;
//! * the shared [`Geometry`] cache is prewarmed in cell order before
//!   workers start, so each unique geometry is built exactly once and
//!   workers only ever read;
//! * results land in order-indexed slots; the caller's callback
//!   consumes them strictly in cell order.
//!
//! PJRT mode stays sequential regardless of `--jobs`: the runtime
//! handle is a `thread_local` `Rc` (artifact caches are not `Sync`),
//! and compute-bound PJRT dispatch is where the wall-clock goes anyway.
//! The surrogate sweeps — the pure-L3 topology studies this executor
//! targets — parallelize fully.

use super::drivers::{run_one_with, ExpOptions};
use crate::config::ExperimentConfig;
use crate::coordinator::{Geometry, RunResult};
use crate::fl::asyncfleo::AsyncFleo;
use crate::fl::{make_strategy, Strategy};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Which strategy a cell runs. `Clone + Send` so cells can cross into
/// worker threads; the `Box<dyn Strategy>` itself is built inside the
/// worker.
#[derive(Clone)]
pub enum CellStrategy {
    /// The stock strategy for the cell's `cfg.fl.scheme`.
    Scheme,
    /// A customized AsyncFLEO instance (ablation variants).
    Custom(AsyncFleo),
}

/// One configured run of a sweep grid.
pub struct Cell {
    /// Row label carried through to CSV/stdout in original order.
    pub label: String,
    pub cfg: ExperimentConfig,
    pub strategy: CellStrategy,
    /// Estimated relative cost of the run (any unit). The worker pool
    /// schedules the most expensive cells first; results are still
    /// collected in cell order, so the hint never changes output bytes.
    pub cost_hint: f64,
}

impl Cell {
    /// A cell running its scheme's stock strategy.
    pub fn new(label: impl Into<String>, cfg: ExperimentConfig) -> Self {
        let cost_hint = Self::default_cost(&cfg);
        Cell { label: label.into(), cfg, strategy: CellStrategy::Scheme, cost_hint }
    }

    /// A cell running a customized AsyncFLEO instance.
    pub fn custom(label: impl Into<String>, cfg: ExperimentConfig, strategy: AsyncFleo) -> Self {
        let cost_hint = Self::default_cost(&cfg);
        Cell { label: label.into(), cfg, strategy: CellStrategy::Custom(strategy), cost_hint }
    }

    /// Override the scheduling cost hint.
    pub fn with_cost_hint(mut self, cost_hint: f64) -> Self {
        self.cost_hint = cost_hint;
        self
    }

    /// Default estimate: event-loop work scales with constellation size
    /// × simulated horizon (epoch-capped runs finish earlier, but the
    /// hint only has to rank cells, not predict seconds).
    fn default_cost(cfg: &ExperimentConfig) -> f64 {
        cfg.n_sats() as f64 * cfg.fl.horizon_s
    }

    fn build_strategy(&self) -> Box<dyn Strategy> {
        match &self.strategy {
            CellStrategy::Scheme => make_strategy(self.cfg.fl.scheme),
            CellStrategy::Custom(a) => Box::new(a.clone()),
        }
    }
}

/// The worker count actually used for a grid: `--jobs`, clamped to the
/// grid size (one policy with the parallel contact-plan builder —
/// [`crate::coordinator::worker_count`]), and forced to 1 in PJRT mode
/// (see module docs).
pub fn effective_jobs(opts: &ExpOptions, n_cells: usize) -> usize {
    if !opts.surrogate {
        return 1;
    }
    crate::coordinator::worker_count(opts.jobs, n_cells)
}

/// The deterministic longest-first pick order: indices sorted by
/// descending [`Cell::cost_hint`], ties by ascending cell index.
pub fn schedule_order(cells: &[Cell]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..cells.len()).collect();
    order.sort_by(|&a, &b| {
        cells[b]
            .cost_hint
            .total_cmp(&cells[a].cost_hint)
            .then(a.cmp(&b))
    });
    order
}

/// Run one cell (worker body; also the `--jobs 1` path).
fn run_cell(cell: &Cell, opts: &ExpOptions) -> Result<RunResult> {
    run_one_with(&cell.cfg, opts, cell.build_strategy())
}

/// Run every cell, invoking `on_result(index, result)` strictly in cell
/// order as the ordered prefix of the grid completes. On the first cell
/// error or callback error the sweep stops handing out new cells and
/// returns that error; everything the callback already consumed (e.g.
/// streamed CSV rows) is preserved. See the module docs for the
/// determinism contract.
pub fn run_cells_streaming(
    cells: &[Cell],
    opts: &ExpOptions,
    mut on_result: impl FnMut(usize, &RunResult) -> Result<()>,
) -> Result<()> {
    let jobs = effective_jobs(opts, cells.len());
    if jobs <= 1 {
        for (i, cell) in cells.iter().enumerate() {
            let r = run_cell(cell, opts)?;
            on_result(i, &r)?;
        }
        return Ok(());
    }

    // Prewarm the geometry cache in deterministic cell order: each
    // unique geometry is built exactly once, before any worker races
    // for it.
    for cell in cells {
        Geometry::shared(&cell.cfg);
    }

    let order = schedule_order(cells);
    let next = AtomicUsize::new(0);
    let cancel = AtomicBool::new(false);
    let slots: Mutex<Vec<Option<Result<RunResult>>>> =
        Mutex::new((0..cells.len()).map(|_| None).collect());
    let ready = Condvar::new();
    let mut outcome: Result<()> = Ok(());
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                if cancel.load(Ordering::Relaxed) {
                    break;
                }
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= order.len() {
                    break;
                }
                let i = order[k];
                // a panicking cell must still fill its slot, or the
                // consumer would wait on the condvar forever (the
                // default panic hook has already printed the message)
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_cell(&cells[i], opts)
                }))
                .unwrap_or_else(|_| {
                    Err(anyhow::anyhow!("worker panicked on cell {} ({})", i, cells[i].label))
                });
                slots.lock().unwrap()[i] = Some(result);
                ready.notify_all();
            });
        }
        // However the consumer loop exits — completion, callback error,
        // or a callback panic unwinding past it — stop handing out new
        // cells (workers already mid-cell finish theirs and exit).
        struct CancelOnDrop<'a>(&'a AtomicBool);
        impl Drop for CancelOnDrop<'_> {
            fn drop(&mut self) {
                self.0.store(true, Ordering::Relaxed);
            }
        }
        let _stop_workers = CancelOnDrop(&cancel);
        // Consume the ordered prefix on this thread, streaming the
        // callback while later cells are still running.
        for i in 0..cells.len() {
            let mut guard = slots.lock().unwrap();
            let taken = loop {
                if let Some(r) = guard[i].take() {
                    break r;
                }
                guard = ready.wait(guard).unwrap();
            };
            drop(guard);
            let step = taken.and_then(|r| on_result(i, &r));
            if let Err(e) = step {
                outcome = Err(e);
                break;
            }
        }
    });
    outcome
}

/// Run every cell and return results in cell order (the collect-all
/// wrapper over [`run_cells_streaming`]).
pub fn run_cells(cells: &[Cell], opts: &ExpOptions) -> Result<Vec<RunResult>> {
    let mut out = Vec::with_capacity(cells.len());
    run_cells_streaming(cells, opts, |_, r| {
        out.push(r.clone());
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PsPlacement, SchemeKind};
    use crate::metrics::Curve;
    use anyhow::bail;

    fn small_cells(n: usize) -> Vec<Cell> {
        (0..n)
            .map(|i| {
                let mut cfg = ExperimentConfig::test_small();
                cfg.fl.scheme = SchemeKind::AsyncFleo;
                cfg.placement = PsPlacement::HapRolla;
                cfg.fl.horizon_s = 12.0 * 3600.0;
                cfg.fl.max_epochs = 4;
                cfg.seed = 42 + (i as u64 % 2); // two distinct seeds
                Cell::new(format!("cell{i}"), cfg)
            })
            .collect()
    }

    fn assert_curves_identical(a: &Curve, b: &Curve, what: &str) {
        assert_eq!(a.points.len(), b.points.len(), "{what}: curve length");
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.time_s, y.time_s, "{what}: point time");
            assert_eq!(x.accuracy, y.accuracy, "{what}: point accuracy");
            assert_eq!(x.loss, y.loss, "{what}: point loss");
        }
    }

    #[test]
    fn parallel_matches_sequential_bit_for_bit() {
        let cells = small_cells(6);
        let seq = ExpOptions { surrogate: true, jobs: 1, ..Default::default() };
        let par = ExpOptions { surrogate: true, jobs: 4, ..Default::default() };
        let a = run_cells(&cells, &seq).unwrap();
        let b = run_cells(&cells, &par).unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.epochs, y.epochs, "cell {i} epochs");
            assert_eq!(x.transfers, y.transfers, "cell {i} transfers");
            assert_curves_identical(&x.curve, &y.curve, &format!("cell {i}"));
        }
    }

    #[test]
    fn pjrt_mode_is_forced_sequential() {
        let opts = ExpOptions { surrogate: false, jobs: 8, ..Default::default() };
        assert_eq!(effective_jobs(&opts, 10), 1);
        let opts = ExpOptions { surrogate: true, jobs: 8, ..Default::default() };
        assert_eq!(effective_jobs(&opts, 3), 3, "clamped to grid size");
        assert_eq!(effective_jobs(&opts, 10), 8);
        let opts = ExpOptions { surrogate: true, jobs: 0, ..Default::default() };
        assert_eq!(effective_jobs(&opts, 10), 1, "jobs 0 means sequential");
    }

    #[test]
    fn streaming_emits_in_cell_order_at_any_job_count() {
        let cells = small_cells(5);
        for jobs in [1usize, 3] {
            let opts = ExpOptions { surrogate: true, jobs, ..Default::default() };
            let mut seen = Vec::new();
            run_cells_streaming(&cells, &opts, |i, r| {
                assert!(!r.curve.points.is_empty());
                seen.push(i);
                Ok(())
            })
            .unwrap();
            assert_eq!(seen, vec![0, 1, 2, 3, 4], "jobs={jobs}");
        }
    }

    #[test]
    fn streaming_error_keeps_prefix_and_stops() {
        let cells = small_cells(5);
        let opts = ExpOptions { surrogate: true, jobs: 2, ..Default::default() };
        let mut seen = Vec::new();
        let err = run_cells_streaming(&cells, &opts, |i, _| {
            if i == 2 {
                bail!("synthetic failure at cell 2");
            }
            seen.push(i);
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("cell 2"));
        assert_eq!(seen, vec![0, 1], "rows before the error are preserved");
    }

    #[test]
    fn schedule_order_is_longest_first_and_deterministic() {
        let mut cells = small_cells(4);
        cells[0].cost_hint = 1.0;
        cells[1].cost_hint = 9.0;
        cells[2].cost_hint = 9.0; // tie with 1 → index order
        cells[3].cost_hint = 4.0;
        assert_eq!(schedule_order(&cells), vec![1, 2, 3, 0]);
        // bigger constellations rank ahead of small ones by default
        let mut big = ExperimentConfig::test_small();
        big.constellation.sats_per_orbit = 30;
        let small = ExperimentConfig::test_small();
        let pair = vec![Cell::new("small", small), Cell::new("big", big)];
        assert_eq!(schedule_order(&pair), vec![1, 0]);
    }
}
