//! Deterministic fault injection: link impairments, outages, and node
//! churn for resilience scenarios.
//!
//! The paper's whole argument is robustness to stragglers, so the
//! reproduction must be able to *create* stragglers. This subsystem
//! injects four failure modes into the simulated network:
//!
//! * **packet loss with retransmission** — per-transfer Bernoulli
//!   draws add ARQ retries (extra delay + extra `transfers`);
//! * **scheduled link outages** — periodic eclipse/solar-conjunction
//!   windows black out SAT↔HAP contacts and (optionally) ISL hops;
//! * **satellite churn** — dropouts and rejoins, so a training result
//!   can be lost in flight or simply never arrive;
//! * **HAP failures** — a PS node goes dark and the
//!   [`crate::topology::HapRing`] re-heals around it.
//!
//! The network impairment engine ([`NetworkConfig`], PR 10) layers four
//! more axes on the same delay path:
//!
//! * **latency jitter** — log-normal distributions around the geometric
//!   delay, with consequent message reordering through the event queue;
//! * **bandwidth queueing** — a FIFO [`LinkQueue`] per (endpoint-pair,
//!   link-class) serializes contending transfers over the residual
//!   capacity instead of a fixed rate;
//! * **network partitions** — scheduled windows isolate a shell, the
//!   HAP layer or the ground segment ([`PartitionScope`]);
//! * **Sun-vector eclipses** — umbra windows from the actual solar
//!   ephemeris (`orbit::sun`) replace the periodic approximation.
//!
//! Everything is derived from the experiment seed through
//! [`crate::util::Rng`] (never wall-clock), so the same seed reproduces
//! bit-identical impairment timelines, and a [`FaultConfig`] +
//! [`NetworkConfig`] with all intensities at zero is provably
//! invisible: the plan never touches the delay path or the RNG
//! ([`FaultPlan::enabled`] is false) and the schedule cache key
//! normalizes to the pre-engine key.
//!
//! # The oracle / commit split, per axis
//!
//! The multi-lane event core (PR 9) probes delays concurrently and
//! replays effects in pop order, so every axis declares which side of
//! `FaultSchedule::channel_outcome` (pure oracle) vs
//! `FaultPlan::commit` (per-run fold) it lives on:
//!
//! * *loss + exponential backoff*: oracle — channel-state hash per
//!   (link, coherence window); commit counts `losses` / `retransmits` /
//!   `retry_drops` once per event.
//! * *jitter*: oracle — the draw is hash-derived per channel event
//!   (order-independent); commit counts `reorders` against the
//!   per-link last-arrival watermark.
//! * *partitions / eclipses / outages / churn*: oracle — deferral to
//!   the next clear instant of precomputed windows; commit counts
//!   `partition_hits` / `eclipse_blocked` / `deferrals`.
//! * *queueing*: the **one stateful axis** — the oracle supplies the
//!   pure terms (send instant, service time, queue identity), the FIFO
//!   wait itself is folded in commit order. Active queues therefore
//!   force single-lane runs ([`FaultPlan::queueing_active`]).
//!
//! Integration: `coordinator::RunState` carries a [`FaultPlan`] and
//! the env routes every `site_link_delay` / `isl_hop_delay` /
//! `ihl_hop_delay` call through [`FaultPlan::transfer`], so AsyncFLEO
//! and all five baselines transparently experience the same
//! impairments. The engine is split along the sweep axis: the
//! immutable seeded timeline lives in a shareable [`FaultSchedule`],
//! the per-run counters in [`FaultPlan`]. `experiments::resilience`
//! sweeps the named [`FaultScenario`] presets across schemes and
//! intensities.

pub mod config;
pub mod network;
pub mod plan;
pub mod schedule;

pub use config::{FaultConfig, FaultScenario, NetworkConfig, PartitionScope};
pub use network::{partition_blocks, LinkQueue, NetWorld, QueueOutcome};
pub use plan::{ChannelOutcome, FaultPlan, FaultSchedule, FaultStats, LinkClass, LinkOutcome};
pub use schedule::{ChurnSchedule, OutageWindows};
