//! Link budget: FSPL, SNR, Shannon rate (paper Eqs. 5, 6, 9; Table I).

/// Boltzmann constant, J/K.
pub const BOLTZMANN: f64 = 1.380_649e-23;

/// RF link parameters. Defaults are the paper's Table I.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkParams {
    /// Transmission power, dBm (Table I: 40 dBm).
    pub tx_power_dbm: f64,
    /// Transmitter antenna gain, dBi (Table I: 6.98 dBi).
    pub tx_gain_dbi: f64,
    /// Receiver antenna gain, dBi (Table I: 6.98 dBi).
    pub rx_gain_dbi: f64,
    /// Carrier frequency, Hz (Table I: 2.4 GHz).
    pub carrier_hz: f64,
    /// Noise temperature, K (Table I: 354.81 K).
    pub noise_temp_k: f64,
    /// Channel bandwidth, Hz.
    pub bandwidth_hz: f64,
    /// Fixed data rate actually provisioned, bits/s (Table I: 16 Mb/s).
    /// The paper fixes R rather than running at Shannon capacity; we
    /// keep both and assert R is achievable (see `rate_feasible`).
    pub data_rate_bps: f64,
    /// Per-endpoint processing delay t_x = t_y, seconds.
    pub processing_delay_s: f64,
}

impl Default for LinkParams {
    fn default() -> Self {
        LinkParams {
            tx_power_dbm: 40.0,
            tx_gain_dbi: 6.98,
            rx_gain_dbi: 6.98,
            carrier_hz: 2.4e9,
            noise_temp_k: 354.81,
            bandwidth_hz: 20.0e6,
            data_rate_bps: 16.0e6,
            processing_delay_s: 0.05,
        }
    }
}

impl LinkParams {
    /// Free-space path loss (linear), Eq. 6: (4*pi*d*f/c)^2.
    pub fn fspl_linear(&self, distance_km: f64) -> f64 {
        let d_m = distance_km * 1000.0;
        let c = 299_792_458.0;
        let x = 4.0 * std::f64::consts::PI * d_m * self.carrier_hz / c;
        x * x
    }

    /// SNR (linear), Eq. 5: P_t G_x G_y / (k_B T B L).
    pub fn snr_linear(&self, distance_km: f64) -> f64 {
        let p_t = 10f64.powf((self.tx_power_dbm - 30.0) / 10.0); // dBm -> W
        let g = 10f64.powf((self.tx_gain_dbi + self.rx_gain_dbi) / 10.0);
        let noise = BOLTZMANN * self.noise_temp_k * self.bandwidth_hz;
        p_t * g / (noise * self.fspl_linear(distance_km))
    }

    /// SNR in dB.
    pub fn snr_db(&self, distance_km: f64) -> f64 {
        10.0 * self.snr_linear(distance_km).log10()
    }

    /// Shannon capacity, Eq. 9: B log2(1 + SNR), bits/s.
    pub fn shannon_rate_bps(&self, distance_km: f64) -> f64 {
        self.bandwidth_hz * (1.0 + self.snr_linear(distance_km)).log2()
    }

    /// Is the provisioned fixed rate within Shannon capacity at range?
    pub fn rate_feasible(&self, distance_km: f64) -> bool {
        self.data_rate_bps <= self.shannon_rate_bps(distance_km)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fspl_grows_quadratically() {
        let p = LinkParams::default();
        let l1 = p.fspl_linear(1000.0);
        let l2 = p.fspl_linear(2000.0);
        assert!((l2 / l1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn snr_decreases_with_distance() {
        let p = LinkParams::default();
        assert!(p.snr_db(500.0) > p.snr_db(2000.0));
        assert!(p.snr_db(2000.0) > p.snr_db(8000.0));
    }

    #[test]
    fn paper_rate_feasible_at_short_range_only() {
        // Table I provisions a fixed 16 Mb/s. With the table's own
        // 40 dBm / 6.98 dBi / 2.4 GHz numbers that rate is within
        // Shannon capacity only at short range — at 2000 km slant range
        // capacity is ~1.8 Mb/s. The paper nevertheless uses R = 16 Mb/s
        // for its delay model, so we follow it (delays use the fixed
        // provisioned rate) and record the inconsistency here.
        let p = LinkParams::default();
        assert!(p.rate_feasible(100.0), "snr={} dB", p.snr_db(100.0));
        assert!(
            !p.rate_feasible(2000.0),
            "Table I params cannot actually sustain 16 Mb/s at 2000 km \
             (snr={} dB) — documented paper inconsistency",
            p.snr_db(2000.0)
        );
    }

    #[test]
    fn rate_feasible_boundary_is_sharp_and_midrange() {
        // The documented Table-I inconsistency, pinned quantitatively:
        // the provisioned 16 Mb/s is within Shannon capacity at short
        // slant range and beyond it at the 2000 km design range.
        // Bisect the crossover distance and check it sits at realistic
        // LEO ranges — the inconsistency bites mid-pass, not at some
        // extreme geometry.
        let p = LinkParams::default();
        let (mut lo, mut hi) = (100.0, 2000.0);
        assert!(p.rate_feasible(lo), "short range must be feasible");
        assert!(!p.rate_feasible(hi), "design range must be infeasible");
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if p.rate_feasible(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        assert!(hi - lo < 1e-6, "monotone => bisection converges");
        let d_star = 0.5 * (lo + hi);
        // at the boundary, capacity equals the provisioned rate
        let r = p.shannon_rate_bps(d_star);
        assert!(
            (r - p.data_rate_bps).abs() / p.data_rate_bps < 1e-6,
            "capacity {r} vs provisioned {} at {d_star} km",
            p.data_rate_bps
        );
        assert!(
            (150.0..1000.0).contains(&d_star),
            "crossover at {d_star} km should be mid-range (≈590 km)"
        );
    }

    #[test]
    fn shannon_rate_monotone_in_bandwidth_at_fixed_snr() {
        // Doubling B with noise scaled by B: capacity still increases.
        let p1 = LinkParams::default();
        let p2 = LinkParams { bandwidth_hz: 2.0 * p1.bandwidth_hz, ..p1 };
        assert!(p2.shannon_rate_bps(3000.0) > p1.shannon_rate_bps(3000.0));
    }

    #[test]
    fn snr_db_linear_roundtrip() {
        let p = LinkParams::default();
        let lin = p.snr_linear(1234.0);
        let db = p.snr_db(1234.0);
        assert!((10f64.powf(db / 10.0) - lin).abs() / lin < 1e-12);
    }

    #[test]
    fn more_tx_power_more_snr() {
        let p1 = LinkParams::default();
        let p2 = LinkParams { tx_power_dbm: 43.0, ..p1 };
        let d = p2.snr_db(2000.0) - p1.snr_db(2000.0);
        assert!((d - 3.0).abs() < 1e-9, "3 dB power = 3 dB SNR, got {d}");
    }
}
