//! Discrete-event simulation engine (the coordinator's event loop).
//!
//! Built from scratch (no `tokio` offline): a monotonic clock plus a
//! binary-heap event queue with deterministic FIFO tie-breaking. The
//! coordinator schedules typed [`event::Event`]s (contact edges, model
//! arrivals, training completions, aggregations) and consumes them in
//! time order.

pub mod event;
pub mod queue;

pub use event::{Event, EventKind};
pub use queue::EventQueue;
