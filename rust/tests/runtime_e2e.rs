//! End-to-end integration of the PJRT runtime against the real AOT
//! artifacts (requires `make artifacts`).
//!
//! This is the load-bearing proof that the three layers compose: HLO
//! text produced by JAX (L2) embedding Pallas kernels (L1) loads,
//! compiles and executes correctly from Rust (L3).

use asyncfleo::model::ModelParams;
use asyncfleo::runtime::executor::Input;
use asyncfleo::runtime::Runtime;
use asyncfleo::testkit::assert_allclose;
use asyncfleo::train::{Backend, PjrtBackend};
use asyncfleo::util::Rng;
use std::rc::Rc;

/// The PJRT runtime, or `None` when this build cannot provide one —
/// either the AOT artifacts are missing (`make artifacts`) or the
/// crate is linked against the offline `xla` stub. Tests skip
/// gracefully in that case instead of failing the whole tier-1 suite;
/// the surrogate-backed integration tests still cover the coordinator.
///
/// Caveat: a skipped test still reports `ok`, so a PJRT-less CI run
/// shows this suite green without executing it. Environments that DO
/// expect working artifacts should set `ASYNCFLEO_REQUIRE_PJRT=1`,
/// which turns an unavailable runtime into a hard failure.
fn runtime() -> Option<Rc<Runtime>> {
    match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => Some(Rc::new(rt)),
        Err(e) => {
            if std::env::var_os("ASYNCFLEO_REQUIRE_PJRT").is_some() {
                panic!("ASYNCFLEO_REQUIRE_PJRT set but PJRT runtime unavailable: {e:#}");
            }
            eprintln!("skipping PJRT e2e test: {e:#} (run `make artifacts` with the real xla crate)");
            None
        }
    }
}

#[test]
fn manifest_loaded_with_all_variants() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest.models.len(), 4);
    assert_eq!(rt.manifest.artifacts.len(), 20);
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn init_artifact_deterministic_and_nontrivial() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("init_mlp_digits").unwrap();
    let a = exe.run(&[Input::I32(&[7])]).unwrap();
    let b = exe.run(&[Input::I32(&[7])]).unwrap();
    let c = exe.run(&[Input::I32(&[8])]).unwrap();
    assert_eq!(a[0].len(), 101_770);
    assert_allclose(&a[0], &b[0], 0.0);
    assert!(a[0].iter().zip(&c[0]).any(|(x, y)| x != y));
    // He-init: weights have plausible scale
    let w1_std = {
        let n = 784 * 128;
        let mean: f32 = a[0][..n].iter().sum::<f32>() / n as f32;
        (a[0][..n].iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32).sqrt()
    };
    let expect = (2.0f32 / 784.0).sqrt();
    assert!((w1_std / expect - 1.0).abs() < 0.1, "std {w1_std} vs {expect}");
}

#[test]
fn train_artifact_reduces_loss_over_dispatches() {
    let Some(rt) = runtime() else { return };
    let init = rt.compile("init_mlp_digits").unwrap();
    let train = rt.compile("train_mlp_digits").unwrap();
    let mut params = init.run(&[Input::I32(&[0])]).unwrap().remove(0);

    // separable random data
    let mut rng = Rng::new(5);
    let mut protos = vec![0.0f32; 10 * 784];
    for v in protos.iter_mut() {
        *v = rng.normal(0.0, 1.0) as f32;
    }
    let n = 320;
    let mut xs = vec![0.0f32; n * 784];
    let mut ys = vec![0.0f32; n * 10];
    for i in 0..n {
        let c = i % 10;
        for j in 0..784 {
            xs[i * 784 + j] = protos[c * 784 + j] + rng.normal(0.0, 0.4) as f32;
        }
        ys[i * 10 + c] = 1.0;
    }

    let mut losses = Vec::new();
    for _ in 0..4 {
        let out = train
            .run(&[
                Input::F32(&params),
                Input::F32(&xs),
                Input::F32(&ys),
                Input::F32(&[0.05]),
            ])
            .unwrap();
        params = out[0].clone();
        losses.push(out[1][0]);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] / 2.0),
        "losses should halve: {losses:?}"
    );
}

#[test]
fn agg_artifact_matches_pure_rust() {
    let Some(rt) = runtime() else { return };
    let agg = rt.compile("agg_mlp_digits").unwrap();
    let dim = 101_770usize;
    let n_slab = 41usize;
    let mut rng = Rng::new(9);
    let slab: Vec<f32> = (0..n_slab * dim).map(|_| rng.normal(0.0, 0.1) as f32).collect();
    let mut coeffs = vec![0.0f32; n_slab];
    coeffs[0] = 0.4;
    coeffs[1] = 0.35;
    coeffs[2] = 0.25;
    let out = agg.run(&[Input::F32(&slab), Input::F32(&coeffs)]).unwrap().remove(0);

    // pure-rust oracle
    let rows: Vec<ModelParams> = (0..3)
        .map(|r| ModelParams { data: slab[r * dim..(r + 1) * dim].to_vec() })
        .collect();
    let refs: Vec<&ModelParams> = rows.iter().collect();
    let want = ModelParams::weighted_sum(&refs, &coeffs[..3]);
    assert_allclose(&out, &want.data, 1e-4);
}

#[test]
fn dist_artifact_matches_pure_rust() {
    let Some(rt) = runtime() else { return };
    let dist = rt.compile("dist_mlp_digits").unwrap();
    let dim = 101_770usize;
    let rows = 40usize;
    let mut rng = Rng::new(11);
    let slab: Vec<f32> = (0..rows * dim).map(|_| rng.normal(0.0, 0.05) as f32).collect();
    let reference: Vec<f32> = (0..dim).map(|_| rng.normal(0.0, 0.05) as f32).collect();
    let out = dist.run(&[Input::F32(&slab), Input::F32(&reference)]).unwrap().remove(0);
    let refp = ModelParams { data: reference };
    for r in 0..5 {
        let row = ModelParams { data: slab[r * dim..(r + 1) * dim].to_vec() };
        let want = row.l2_distance(&refp) as f32;
        assert!(
            (out[r] - want).abs() / want < 1e-3,
            "row {r}: kernel {} vs rust {want}",
            out[r]
        );
    }
}

#[test]
fn eval_artifact_counts_padding_correctly() {
    let Some(rt) = runtime() else { return };
    let init = rt.compile("init_mlp_digits").unwrap();
    let eval = rt.compile("eval_mlp_digits").unwrap();
    let params = init.run(&[Input::I32(&[0])]).unwrap().remove(0);
    let xs = vec![0.0f32; 256 * 784];
    let ys = vec![0.0f32; 256 * 10]; // all padding
    let out = eval.run(&[Input::F32(&params), Input::F32(&xs), Input::F32(&ys)]).unwrap();
    assert_eq!(out[0][0], 0.0, "all-padding chunk has zero correct");
    assert_eq!(out[1][0], 0.0, "all-padding chunk has zero loss");
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(rt) = runtime() else { return };
    let train = rt.compile("train_mlp_digits").unwrap();
    let bad = vec![0.0f32; 10];
    assert!(train.run(&[Input::F32(&bad)]).is_err(), "arity");
    let p = vec![0.0f32; 101_770];
    let xs = vec![0.0f32; 320 * 784];
    let ys = vec![0.0f32; 320 * 10];
    assert!(
        train
            .run(&[Input::F32(&p), Input::F32(&xs), Input::F32(&ys), Input::F32(&[0.1, 0.2])])
            .is_err(),
        "scalar given 2 elements"
    );
    assert!(
        train
            .run(&[Input::F32(&bad), Input::F32(&xs), Input::F32(&ys), Input::F32(&[0.1])])
            .is_err(),
        "wrong params length"
    );
}

#[test]
fn pjrt_backend_full_fl_epoch() {
    // One miniature FL "epoch" through the backend: init -> local
    // training on two shards -> distances -> aggregate -> evaluate.
    let Some(rt) = runtime() else { return };
    let (train_data, test_data) = asyncfleo::data::synth::generate_split(
        asyncfleo::data::DatasetKind::Digits,
        3,
        800,
        200,
    );
    let plane_of: Vec<usize> = (0..40).map(|s| s / 8).collect();
    let mut backend = PjrtBackend::new(
        rt,
        "mlp_digits",
        train_data,
        test_data,
        asyncfleo::data::Partition::NonIidPaper,
        &plane_of,
        0.05,
        3,
    )
    .unwrap();

    let global = backend.init_global(0);
    let e0 = backend.evaluate(&global);
    assert!((0.0..=0.3).contains(&e0.accuracy), "untrained acc {}", e0.accuracy);

    let (m_low, loss_low) = backend.train_local(0, &global, 5); // classes 0..4
    let (m_high, _) = backend.train_local(39, &global, 5); // classes 4..10
    assert!(loss_low.is_finite());

    let d = backend.distances(&[&m_low, &m_high], &global);
    assert!(d[0] > 0.0 && d[1] > 0.0);

    let merged = backend.aggregate(&global, &[&m_low, &m_high], &[0.5, 0.5], 0.0);
    let e_merged = backend.evaluate(&merged);
    let e_low = backend.evaluate(&m_low);
    assert!(
        e_merged.accuracy > e0.accuracy,
        "aggregated model should beat init: {} vs {}",
        e_merged.accuracy,
        e0.accuracy
    );
    // the single-orbit model is biased toward its 4 classes; the merge
    // covers all 10 (allow early-training noise)
    assert!(
        e_merged.accuracy >= e_low.accuracy - 0.10,
        "merged {} vs low {}",
        e_merged.accuracy,
        e_low.accuracy
    );
}
