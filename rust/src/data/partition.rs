//! FL data partitioning across the constellation (paper Sec. V-A).
//!
//! * **IID** — samples shuffled and spread evenly: every satellite holds
//!   all 10 classes.
//! * **Non-IID (the paper's split)** — satellites of two orbits hold 4
//!   classes, satellites of the other three orbits hold the remaining
//!   6 classes. Because orbits sweep different geographic bands this is
//!   the natural non-IID structure for Satcom.
//!
//! Shard sizes vary mildly (±25%) to exercise the data-size weighting
//! in Eq. (12)–(13).

use super::synth::Dataset;
use crate::util::Rng;

/// How data is spread over satellites.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Partition {
    Iid,
    /// The paper's orbit-wise label split (2 orbits: classes 0..4,
    /// 3 orbits: classes 4..10).
    NonIidPaper,
}

/// One satellite's shard: indices into the shared [`Dataset`].
#[derive(Clone, Debug, Default)]
pub struct Shard {
    pub indices: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

/// Split `data` into `n_orbits * sats_per_orbit` shards (uniform
/// single-shell constellations; multi-shell callers use
/// [`partition_planes`] with an explicit plane mapping).
pub fn partition(
    data: &Dataset,
    scheme: Partition,
    n_orbits: usize,
    sats_per_orbit: usize,
    seed: u64,
) -> Vec<Shard> {
    partition_planes(data, scheme, &crate::orbit::uniform_plane_of(n_orbits, sats_per_orbit), seed)
}

/// Split `data` into one shard per satellite; `plane_of` maps each
/// satellite id to its global orbital-plane index (see
/// `WalkerConstellation::plane_of`). The paper's non-IID split assigns
/// classes 0..4 to the satellites of the first two *global* planes and
/// classes 4..10 to everyone else, so a multi-shell constellation keeps
/// the same orbit-band structure.
pub fn partition_planes(
    data: &Dataset,
    scheme: Partition,
    plane_of: &[usize],
    seed: u64,
) -> Vec<Shard> {
    let n_sats = plane_of.len();
    let mut rng = Rng::new(seed ^ 0x5A4D);
    match scheme {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..data.len()).collect();
            rng.shuffle(&mut idx);
            deal_with_jitter(&idx, n_sats, &mut rng)
        }
        Partition::NonIidPaper => {
            // Planes 0..2 -> classes 0..4; planes 2..n -> classes 4..10.
            let k = data.kind.classes() as u8;
            let split = 4u8.min(k);
            let mut low: Vec<usize> = (0..data.len()).filter(|&i| data.y[i] < split).collect();
            let mut high: Vec<usize> = (0..data.len()).filter(|&i| data.y[i] >= split).collect();
            rng.shuffle(&mut low);
            rng.shuffle(&mut high);
            let n_planes = plane_of.iter().max().map_or(0, |m| m + 1);
            let low_planes = 2.min(n_planes);
            let low_ids: Vec<usize> =
                (0..n_sats).filter(|&s| plane_of[s] < low_planes).collect();
            let high_ids: Vec<usize> =
                (0..n_sats).filter(|&s| plane_of[s] >= low_planes).collect();
            let low_shards = deal_with_jitter(&low, low_ids.len().max(1), &mut rng);
            let high_shards = if high_ids.is_empty() {
                Vec::new()
            } else {
                deal_with_jitter(&high, high_ids.len(), &mut rng)
            };
            let mut shards = vec![Shard::default(); n_sats];
            for (&sat, shard) in low_ids.iter().zip(low_shards) {
                shards[sat] = shard;
            }
            for (&sat, shard) in high_ids.iter().zip(high_shards) {
                shards[sat] = shard;
            }
            shards
        }
    }
}

/// Deal indices across `n` shards with ±25% size jitter.
fn deal_with_jitter(idx: &[usize], n: usize, rng: &mut Rng) -> Vec<Shard> {
    assert!(n > 0);
    // draw relative weights in [0.75, 1.25], normalize to partition.
    let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.75, 1.25)).collect();
    let total: f64 = weights.iter().sum();
    let mut shards = Vec::with_capacity(n);
    let mut cursor = 0usize;
    for (i, w) in weights.iter().enumerate() {
        let take = if i + 1 == n {
            idx.len() - cursor
        } else {
            ((w / total) * idx.len() as f64).round() as usize
        };
        let take = take.min(idx.len() - cursor);
        shards.push(Shard { indices: idx[cursor..cursor + take].to_vec() });
        cursor += take;
    }
    shards
}

/// Distinct classes present in a shard.
pub fn shard_classes(data: &Dataset, shard: &Shard) -> Vec<u8> {
    let mut seen = [false; 256];
    for &i in &shard.indices {
        seen[data.y[i] as usize] = true;
    }
    (0..=255u8).filter(|&c| seen[c as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, DatasetKind};

    fn data() -> Dataset {
        generate(DatasetKind::Digits, 0, 4000)
    }

    #[test]
    fn iid_partition_covers_all_disjointly() {
        let d = data();
        let shards = partition(&d, Partition::Iid, 5, 8, 1);
        assert_eq!(shards.len(), 40);
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..4000).collect::<Vec<_>>());
    }

    #[test]
    fn iid_shards_have_most_classes() {
        let d = data();
        let shards = partition(&d, Partition::Iid, 5, 8, 1);
        for s in &shards {
            assert!(shard_classes(&d, s).len() >= 8, "IID shard missing classes");
        }
    }

    #[test]
    fn non_iid_respects_orbit_class_split() {
        let d = data();
        let shards = partition(&d, Partition::NonIidPaper, 5, 8, 1);
        assert_eq!(shards.len(), 40);
        // first two orbits (sats 0..16): only classes 0..4
        for s in &shards[..16] {
            for c in shard_classes(&d, s) {
                assert!(c < 4, "low orbit has class {c}");
            }
        }
        // remaining orbits: only classes 4..10
        for s in &shards[16..] {
            for c in shard_classes(&d, s) {
                assert!((4..10).contains(&c), "high orbit has class {c}");
            }
        }
    }

    #[test]
    fn non_iid_covers_all_disjointly() {
        let d = data();
        let shards = partition(&d, Partition::NonIidPaper, 5, 8, 1);
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..4000).collect::<Vec<_>>());
    }

    #[test]
    fn shard_sizes_vary_but_bounded() {
        let d = data();
        let shards = partition(&d, Partition::Iid, 5, 8, 2);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(min > 0);
        assert!(max as f64 / min as f64 <= 2.0, "sizes {min}..{max}");
        assert!(max != min, "jitter should vary sizes");
    }

    #[test]
    fn deterministic_in_seed() {
        let d = data();
        let a = partition(&d, Partition::NonIidPaper, 5, 8, 3);
        let b = partition(&d, Partition::NonIidPaper, 5, 8, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn plane_mapping_respects_class_split_across_shells() {
        let d = data();
        // two 3-sat planes (first shell) + one 4-sat plane (second)
        let plane_of = vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 2];
        let shards = partition_planes(&d, Partition::NonIidPaper, &plane_of, 1);
        assert_eq!(shards.len(), 10);
        for s in &shards[..6] {
            for c in shard_classes(&d, s) {
                assert!(c < 4, "first two planes hold low classes");
            }
        }
        for s in &shards[6..] {
            for c in shard_classes(&d, s) {
                assert!((4..10).contains(&c), "later planes hold high classes");
            }
        }
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, d.len(), "every sample dealt exactly once");
    }

    #[test]
    fn uniform_delegation_matches_plane_mapping() {
        let d = data();
        let a = partition(&d, Partition::NonIidPaper, 5, 8, 3);
        let plane_of: Vec<usize> = (0..40).map(|s| s / 8).collect();
        let b = partition_planes(&d, Partition::NonIidPaper, &plane_of, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn small_constellations_work() {
        let d = generate(DatasetKind::Digits, 1, 300);
        let shards = partition(&d, Partition::NonIidPaper, 3, 2, 0);
        assert_eq!(shards.len(), 6);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 300);
    }
}
