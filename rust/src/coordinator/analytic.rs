//! Analytic first-contact prediction: closed-form `γ(t) = γ_max`
//! pass maps shared per (shell, site-latitude-band).
//!
//! # The closed form
//!
//! For a circular orbit the satellite's geocentric direction is
//! `d(t) = p·cos u + q·sin u` with `u(t) = phase + n·t` and the plane
//! basis `p = (cos Ω, sin Ω, 0)`,
//! `q = (−sin Ω·cos i, cos Ω·cos i, sin i)`. The site direction on the
//! rotating Earth is
//! `s(t) = (cos φ·cos λ, cos φ·sin λ, sin φ)` with geodetic latitude
//! `φ` and `λ(t) = λ₀ + ω_E·t`. Taking dot products,
//!
//! ```text
//! cos γ(t) = P(Δ)·cos u + Q(Δ)·sin u
//!     P(Δ) = cos φ · cos Δ
//!     Q(Δ) = cos i · cos φ · sin Δ + sin i · sin φ
//!     Δ(t) = λ(t) − Ω      (site longitude relative to the node)
//! ```
//!
//! so visibility `e(t) ≥ e_min ⟺ cos γ(t) ≥ cos γ_max` (see
//! [`max_central_angle_rad`]) is a condition on the two-angle torus
//! `(Δ, u)`. For fixed `Δ`, the set of visible `u` is a single arc
//! centered on `atan2(Q, P)` with half-width `acos(τ / hypot(P, Q))`.
//!
//! # The pass map and why it is shared
//!
//! A [`PassMap`] discretizes `Δ` into [`DELTA_BUCKETS`] buckets and
//! stores, per bucket, a conservative superset of the visible `u` arc:
//! `P` and `Q` are monotone images of `cos Δ` / `sin Δ`, so interval
//! bounds over the bucket give a box `[P_lo,P_hi]×[Q_lo,Q_hi]`;
//! `cos γ` is *linear* in `(P, Q)` for fixed `u`, hence its maximum
//! over the box is attained at a corner, and the union of the four
//! corner arcs (enclosed in one padded arc) covers every visible `u`
//! anywhere in the bucket. A bucket whose four corners cannot reach
//! the threshold is `Never` — provably invisible for the full bucket
//! dwell time (`2π/K/ω_E ≈ 337 s` at K = 256).
//!
//! The map depends only on `(shell altitude, shell inclination,
//! site latitude, site altitude, effective min elevation)` — not on
//! RAAN, phase, site *longitude*, horizon, or scan step. Those enter
//! only through the per-pair offsets `Δ(0) = λ₀ − Ω` and
//! `u(0) = phase` at query time. Every satellite of a shell therefore
//! shares one map with every site at the same latitude (the
//! "latitude-band equivalence"), and a process-wide cache
//! ([`shared_pass_map`]) shares maps across presets and builds, like
//! the `Geometry` Arc cache one level up.
//!
//! # Safety contract
//!
//! [`PassMap::next_possible`] returns a time `t* ≥ t` such that the
//! pair is **provably invisible on `[t, t*)`** (or `∞` when nothing
//! remains before the horizon). It may be conservative (early) but
//! never late; the scanner (`coordinator::contact`) uses it only to
//! *skip* grid points inside the proven-invisible span, never to emit
//! a window, so bit-identity with the dense reference scan is
//! preserved by construction. The comparison threshold is padded by
//! [`COS_MARGIN`] in cos-units and every arc by `ARC_PAD_RAD` radians
//! — orders of magnitude above the ~1e-13 floating-point error of the
//! closed form, and far below any real pass geometry.

use crate::orbit::{max_central_angle_rad, GeodeticSite, EARTH_RADIUS_KM, EARTH_ROTATION_RAD_S};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Number of `Δ` buckets on the torus. 256 keeps the per-bucket dwell
/// (`2π/256/ω_E ≈ 337 s`) above ten 30 s grid steps — coarse enough
/// that a map is 4 KiB, fine enough that `Never` buckets skip real
/// time.
pub const DELTA_BUCKETS: usize = 256;

/// Threshold padding in cos-units: the map tests
/// `cos γ ≥ cos γ_max − COS_MARGIN`, so floating-point error in the
/// closed form (~1e-13) can never flip a truly-visible instant into a
/// proven-invisible one.
pub const COS_MARGIN: f64 = 1e-7;

/// Extra half-width added to every stored arc, radians (~0.8 ms of
/// orbital motion — pure safety margin).
const ARC_PAD_RAD: f64 = 1e-6;

/// Outward padding of the per-bucket `(P, Q)` interval box.
const BOX_PAD: f64 = 1e-12;

const TAU: f64 = 2.0 * std::f64::consts::PI;
const PI: f64 = std::f64::consts::PI;

/// Conservative visible-`u` superset of one `Δ` bucket.
#[derive(Clone, Copy, Debug)]
enum Bucket {
    /// No `u` anywhere in the bucket can reach the threshold.
    Never,
    /// Every `u` might be visible (the enclosing arc wrapped).
    Always,
    /// Visibility is impossible outside `|u − center| ≤ half_width`.
    Arc { center: f64, half_width: f64 },
}

/// The shared (shell × site-latitude-band) pass map. Immutable after
/// construction; handed out as `Arc<PassMap>` by [`shared_pass_map`].
#[derive(Debug)]
pub struct PassMap {
    buckets: Vec<Bucket>,
    any_possible: bool,
    /// The padded cos-threshold `cos γ_max − COS_MARGIN` (diagnostics).
    threshold: f64,
}

/// Wrap to `[−π, π]`.
fn wrap_pm_pi(x: f64) -> f64 {
    x - TAU * (x / TAU).round()
}

/// `[min, max]` of `cos` over the angle interval `[lo, hi]` (assumes
/// `hi − lo < π`, true for one bucket).
fn cos_bounds(lo: f64, hi: f64) -> (f64, f64) {
    let (a, b) = (lo.cos(), hi.cos());
    let mut min = a.min(b);
    let mut max = a.max(b);
    // interior extrema at multiples of π inside [lo, hi]
    if (lo / TAU).ceil() * TAU <= hi {
        max = 1.0;
    }
    if ((lo - PI) / TAU).ceil() * TAU + PI <= hi {
        min = -1.0;
    }
    (min, max)
}

/// `[min, max]` of `sin` over `[lo, hi]` (same contract).
fn sin_bounds(lo: f64, hi: f64) -> (f64, f64) {
    cos_bounds(lo - PI / 2.0, hi - PI / 2.0)
}

/// The conservative arc of one bucket from its `(P, Q)` interval box:
/// union of the four corner arcs `{u : P·cos u + Q·sin u ≥ τ}`,
/// enclosed in one padded arc. `cos γ` is linear in `(P, Q)` for fixed
/// `u`, so its maximum over the box sits at a corner — the union
/// covers every visible `u` for every `Δ` in the bucket.
fn bucket_from_box(p_lo: f64, p_hi: f64, q_lo: f64, q_hi: f64, tau: f64) -> Bucket {
    let mut lo_edge = f64::INFINITY;
    let mut hi_edge = f64::NEG_INFINITY;
    let mut anchor = f64::NAN;
    for (p, q) in [(p_lo, q_lo), (p_lo, q_hi), (p_hi, q_lo), (p_hi, q_hi)] {
        let r = p.hypot(q);
        // cos(u − φ) ≥ τ/r: empty above 1, the full circle at/below −1
        let x = if r > 0.0 {
            tau / r
        } else if tau > 0.0 {
            2.0
        } else {
            -2.0
        };
        if x > 1.0 {
            continue;
        }
        if x <= -1.0 {
            return Bucket::Always;
        }
        let w = x.acos() + ARC_PAD_RAD;
        let phi = q.atan2(p);
        if anchor.is_nan() {
            anchor = phi;
        }
        // normalize this corner's center next to the first one so the
        // enclosing interval is well-defined on the circle
        let c = anchor + wrap_pm_pi(phi - anchor);
        lo_edge = lo_edge.min(c - w);
        hi_edge = hi_edge.max(c + w);
    }
    if anchor.is_nan() {
        return Bucket::Never;
    }
    let half_width = 0.5 * (hi_edge - lo_edge);
    if half_width >= PI {
        return Bucket::Always;
    }
    Bucket::Arc { center: 0.5 * (lo_edge + hi_edge), half_width }
}

fn build_map(
    sat_altitude_km: f64,
    inclination_rad: f64,
    site_lat_deg: f64,
    site_alt_km: f64,
    eff_min_elev_deg: f64,
) -> PassMap {
    let a = EARTH_RADIUS_KM + site_alt_km;
    let b = EARTH_RADIUS_KM + sat_altitude_km;
    let gamma_max = max_central_angle_rad(a, b, eff_min_elev_deg);
    let tau = gamma_max.cos() - COS_MARGIN;
    let lat = site_lat_deg.to_radians();
    let (sin_lat, cos_lat) = lat.sin_cos();
    let (sin_inc, cos_inc) = inclination_rad.sin_cos();

    // class-level prune: the sub-satellite track never exceeds
    // latitude λ_max = asin(|sin i|); a site whose latitude is farther
    // from the track than the visibility cone is never visible at all
    // (cos of the best-case central angle below threshold)
    let lam_max = sin_inc.abs().min(1.0).asin();
    if lat.abs() > lam_max && (lat.abs() - lam_max).cos() < tau {
        return PassMap {
            buckets: vec![Bucket::Never; DELTA_BUCKETS],
            any_possible: false,
            threshold: tau,
        };
    }

    let bw = TAU / DELTA_BUCKETS as f64;
    let mut any_possible = false;
    let buckets: Vec<Bucket> = (0..DELTA_BUCKETS)
        .map(|k| {
            let lo = k as f64 * bw;
            let hi = lo + bw;
            let (c_lo, c_hi) = cos_bounds(lo, hi);
            let (s_lo, s_hi) = sin_bounds(lo, hi);
            // P = cos φ · cos Δ  (cos φ ≥ 0)
            let p_lo = cos_lat * c_lo - BOX_PAD;
            let p_hi = cos_lat * c_hi + BOX_PAD;
            // Q = (cos i · cos φ) · sin Δ + sin i · sin φ
            let ci_cl = cos_inc * cos_lat;
            let q_off = sin_inc * sin_lat;
            let (q_lo, q_hi) = if ci_cl >= 0.0 {
                (ci_cl * s_lo + q_off - BOX_PAD, ci_cl * s_hi + q_off + BOX_PAD)
            } else {
                (ci_cl * s_hi + q_off - BOX_PAD, ci_cl * s_lo + q_off + BOX_PAD)
            };
            let bucket = bucket_from_box(p_lo, p_hi, q_lo, q_hi, tau);
            if !matches!(bucket, Bucket::Never) {
                any_possible = true;
            }
            bucket
        })
        .collect();
    PassMap { buckets, any_possible, threshold: tau }
}

impl PassMap {
    /// Can this (shell, site-latitude) class ever be visible? `false`
    /// means every pair of the class is pruned outright — zero
    /// predicate evaluations for the whole build.
    pub fn any_possible(&self) -> bool {
        self.any_possible
    }

    /// Number of `Δ` buckets proven never-visible (diagnostics).
    pub fn never_bucket_count(&self) -> usize {
        self.buckets.iter().filter(|b| matches!(b, Bucket::Never)).count()
    }

    /// The padded cos-threshold the map was built against.
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Earliest time `≥ t` at which visibility is *possible* for the
    /// pair with torus offsets `Δ(0) = dlon0_rad` (site longitude −
    /// RAAN) and `u(0) = u0_rad`, mean motion `n_rad_s`, searched up to
    /// `horizon_s`. Everything in `[t, return)` is provably invisible;
    /// `∞` means provably invisible through the horizon.
    pub fn next_possible(
        &self,
        dlon0_rad: f64,
        u0_rad: f64,
        n_rad_s: f64,
        horizon_s: f64,
        t: f64,
    ) -> f64 {
        if !self.any_possible {
            return f64::INFINITY;
        }
        let bw = TAU / DELTA_BUCKETS as f64;
        let mut t = t;
        while t <= horizon_s {
            let delta = (dlon0_rad + EARTH_ROTATION_RAD_S * t).rem_euclid(TAU);
            let k = ((delta / bw) as usize).min(DELTA_BUCKETS - 1);
            // time the site rotates into the next bucket; the 1 µs
            // floor guarantees progress (1 µs of Earth rotation is
            // ~7e-11 rad, far inside the arc pads)
            let t_exit = t + (((k + 1) as f64 * bw - delta) / EARTH_ROTATION_RAD_S).max(1e-6);
            match self.buckets[k] {
                Bucket::Always => return t,
                Bucket::Never => t = t_exit,
                Bucket::Arc { center, half_width } => {
                    let u = (u0_rad + n_rad_s * t).rem_euclid(TAU);
                    if wrap_pm_pi(u - center).abs() <= half_width {
                        return t;
                    }
                    // u advances monotonically: next arc entry is at
                    // center − half_width (mod 2π) ahead of u
                    let du = (center - half_width - u).rem_euclid(TAU);
                    let t_enter = t + du / n_rad_s;
                    if t_enter < t_exit {
                        return t_enter;
                    }
                    t = t_exit;
                }
            }
        }
        f64::INFINITY
    }
}

/// Cache key: exact bit patterns of the five class parameters (the
/// same idiom as the `Geometry` cache key one level up).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct MapKey {
    sat_altitude: u64,
    inclination: u64,
    site_lat: u64,
    site_alt: u64,
    eff_min_elev: u64,
}

impl MapKey {
    fn new(
        sat_altitude_km: f64,
        inclination_rad: f64,
        site: &GeodeticSite,
        eff_min_elev_deg: f64,
    ) -> Self {
        MapKey {
            sat_altitude: sat_altitude_km.to_bits(),
            inclination: inclination_rad.to_bits(),
            site_lat: site.lat_deg.to_bits(),
            site_alt: site.alt_km.to_bits(),
            eff_min_elev: eff_min_elev_deg.to_bits(),
        }
    }
}

fn cache() -> &'static Mutex<HashMap<MapKey, Arc<PassMap>>> {
    static CACHE: OnceLock<Mutex<HashMap<MapKey, Arc<PassMap>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

fn build_counts() -> &'static Mutex<HashMap<MapKey, u64>> {
    static COUNTS: OnceLock<Mutex<HashMap<MapKey, u64>>> = OnceLock::new();
    COUNTS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// The process-wide shared pass map of one (shell, site-latitude-band)
/// class: built once per unique `(altitude, inclination, site
/// latitude, site altitude, effective min elevation)` and shared
/// across satellites, sites, plan builds, and presets. Note the key
/// has no site *longitude* — sites on the same latitude band share.
pub fn shared_pass_map(
    sat_altitude_km: f64,
    inclination_rad: f64,
    site: &GeodeticSite,
    eff_min_elev_deg: f64,
) -> Arc<PassMap> {
    let key = MapKey::new(sat_altitude_km, inclination_rad, site, eff_min_elev_deg);
    if let Some(map) = cache().lock().unwrap().get(&key) {
        return Arc::clone(map);
    }
    // build outside the cache lock (maps are deterministic — a rare
    // double build is wasted work, not divergence; last insert wins)
    let _phase = crate::obs::global_phase("pass_map");
    let map = Arc::new(build_map(
        sat_altitude_km,
        inclination_rad,
        site.lat_deg,
        site.alt_km,
        eff_min_elev_deg,
    ));
    *build_counts().lock().unwrap().entry(key).or_insert(0) += 1;
    cache().lock().unwrap().insert(key, Arc::clone(&map));
    map
}

/// How many times the map of this class was actually built (tests
/// assert `1` for shared classes).
pub fn pass_map_build_count(
    sat_altitude_km: f64,
    inclination_rad: f64,
    site: &GeodeticSite,
    eff_min_elev_deg: f64,
) -> u64 {
    let key = MapKey::new(sat_altitude_km, inclination_rad, site, eff_min_elev_deg);
    build_counts().lock().unwrap().get(&key).copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::{elevation_deg, satellite_position_eci, OrbitalElements};

    fn paper_like_elements() -> OrbitalElements {
        OrbitalElements {
            altitude_km: 2000.0,
            inclination_rad: 80f64.to_radians(),
            raan_rad: 0.7,
            phase_rad: 0.3,
        }
    }

    /// The soundness contract, sampled densely: whenever the real
    /// geometry says *visible*, the map must say *possible at exactly
    /// that instant* — `next_possible(t) == t`.
    #[test]
    fn map_never_contradicts_real_visibility() {
        let e = paper_like_elements();
        let site = GeodeticSite::rolla_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        let map = build_map(e.altitude_km, e.inclination_rad, site.lat_deg, site.alt_km, eff);
        let dlon0 = site.lon_deg.to_radians() - e.raan_rad;
        let n = e.mean_motion_rad_s();
        let horizon = 86_400.0;
        let mut visible_samples = 0u32;
        for i in 0..(86_400 / 60) {
            let t = i as f64 * 60.0;
            let elev = elevation_deg(site.position_eci(t), satellite_position_eci(&e, t));
            // skip knife-edge samples within the margin of the threshold
            if elev >= eff + 0.01 {
                visible_samples += 1;
                let tp = map.next_possible(dlon0, e.phase_rad, n, horizon, t);
                assert_eq!(tp, t, "visible at t={t} (elev {elev:.3}) but map says {tp}");
            }
        }
        assert!(visible_samples > 10, "test must exercise real passes");
    }

    /// Same dense sweep, but checking the map is not vacuously
    /// `Always`: when the map proves a span invisible, the geometry
    /// must agree.
    #[test]
    fn proven_invisible_spans_are_really_invisible() {
        let e = paper_like_elements();
        let site = GeodeticSite::rolla_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        let map = build_map(e.altitude_km, e.inclination_rad, site.lat_deg, site.alt_km, eff);
        let dlon0 = site.lon_deg.to_radians() - e.raan_rad;
        let n = e.mean_motion_rad_s();
        let horizon = 86_400.0;
        let mut proven = 0u32;
        for i in 0..(86_400 / 60) {
            let t = i as f64 * 60.0;
            let tp = map.next_possible(dlon0, e.phase_rad, n, horizon, t);
            if tp > t {
                proven += 1;
                let elev = elevation_deg(site.position_eci(t), satellite_position_eci(&e, t));
                assert!(elev < eff, "map proved t={t} invisible but elev is {elev:.3}");
            }
        }
        assert!(proven > 100, "map must prove real spans invisible, proved {proven}");
    }

    #[test]
    fn out_of_reach_latitude_class_is_pruned() {
        // 5°-inclination shell never climbs anywhere near Rolla.
        let site = GeodeticSite::rolla_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        let map = build_map(550.0, 5f64.to_radians(), site.lat_deg, site.alt_km, eff);
        assert!(!map.any_possible());
        assert_eq!(map.never_bucket_count(), DELTA_BUCKETS);
        assert_eq!(map.next_possible(1.234, 0.5, 0.001, 86_400.0, 0.0), f64::INFINITY);
    }

    #[test]
    fn low_inclination_shell_has_never_buckets_at_mid_latitude() {
        // 33° shell seen from Portland (45.5°): reachable, but only in
        // a narrow Δ band — most buckets must be proven Never.
        let site = GeodeticSite::portland_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        let map = build_map(535.0, 33f64.to_radians(), site.lat_deg, site.alt_km, eff);
        assert!(map.any_possible());
        let never = map.never_bucket_count();
        assert!(
            never > DELTA_BUCKETS / 4 && never < DELTA_BUCKETS,
            "expected a partial Never band, got {never}/{DELTA_BUCKETS}"
        );
    }

    #[test]
    fn shared_map_is_built_once_and_pointer_shared() {
        // altitude unique to this test so parallel test binaries can't
        // collide on the process-wide key
        let alt = 913.6251;
        let inc = 0.9251;
        let site = GeodeticSite::rolla_hap();
        let eff = site.effective_min_elevation_deg(10.0);
        let a = shared_pass_map(alt, inc, &site, eff);
        let b = shared_pass_map(alt, inc, &site, eff);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(pass_map_build_count(alt, inc, &site, eff), 1);
        // a site at the same latitude but different longitude shares
        let mut moved = site;
        moved.lon_deg += 47.0;
        let c = shared_pass_map(alt, inc, &moved, eff);
        assert!(Arc::ptr_eq(&a, &c), "longitude must not enter the key");
    }

    #[test]
    fn wrap_and_interval_helpers() {
        // 3π wraps to ±π (either boundary representative is fine)
        assert!((wrap_pm_pi(3.0 * PI).abs() - PI).abs() < 1e-12);
        assert!((wrap_pm_pi(-0.25) + 0.25).abs() < 1e-12);
        assert!((wrap_pm_pi(TAU + 0.5) - 0.5).abs() < 1e-12);
        let (lo, hi) = cos_bounds(0.1, 0.3);
        assert!(lo <= 0.3f64.cos() && hi >= 0.1f64.cos());
        // interval straddling 0 must include cos = 1
        let (_, hi) = cos_bounds(-0.1, 0.1);
        assert_eq!(hi, 1.0);
        // interval straddling π must include cos = −1
        let (lo, _) = cos_bounds(PI - 0.05, PI + 0.05);
        assert_eq!(lo, -1.0);
        let (lo, hi) = sin_bounds(PI / 2.0 - 0.1, PI / 2.0 + 0.1);
        assert_eq!(hi, 1.0);
        assert!(lo <= (PI / 2.0 - 0.1).sin());
    }
}
