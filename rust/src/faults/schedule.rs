//! Deterministic fault schedules: churn intervals and outage windows.
//!
//! Everything here is precomputed (or closed-form) from the seeded
//! [`Rng`] at plan-construction time, so the same seed always yields
//! the same impairment timeline regardless of how the strategy under
//! test interleaves its link calls.

use crate::util::Rng;

/// Exponential draw with the given mean (inverse-CDF on a `[0,1)`
/// uniform; `1 - u` keeps the argument of `ln` strictly positive).
pub(crate) fn exp_draw(rng: &mut Rng, mean_s: f64) -> f64 {
    -mean_s * (1.0 - rng.f64()).ln()
}

/// Alternating up/down timeline for one node over the horizon.
#[derive(Clone, Debug, Default)]
pub struct ChurnSchedule {
    /// Sorted, disjoint `[start, end)` downtime intervals.
    pub down: Vec<(f64, f64)>,
}

impl ChurnSchedule {
    /// Draw a failure/repair process: exponential(mtbf) uptimes,
    /// uniform `[0.5, 1.5] * mttr` downtimes, truncated at `horizon_s`.
    pub fn generate(rng: &mut Rng, mtbf_s: f64, mttr_s: f64, horizon_s: f64) -> Self {
        let mut down = Vec::new();
        if mtbf_s <= 0.0 || mttr_s <= 0.0 {
            return ChurnSchedule { down };
        }
        let mut t = exp_draw(rng, mtbf_s);
        while t < horizon_s {
            let dur = mttr_s * (0.5 + rng.f64());
            down.push((t, t + dur));
            t += dur + exp_draw(rng, mtbf_s);
        }
        ChurnSchedule { down }
    }

    /// Is the node down at time `t`?
    pub fn is_down(&self, t: f64) -> bool {
        self.down.iter().any(|&(s, e)| t >= s && t < e)
    }

    /// Earliest time `>= t` at which the node is up (i.e. `t` itself
    /// when up, else the end of the covering downtime interval).
    pub fn up_time_after(&self, t: f64) -> f64 {
        for &(s, e) in &self.down {
            if t >= s && t < e {
                return e;
            }
        }
        t
    }

    /// Total downtime within `[0, horizon_s]`.
    pub fn total_down_s(&self, horizon_s: f64) -> f64 {
        self.down.iter().map(|&(s, e)| e.min(horizon_s) - s.min(horizon_s)).sum()
    }
}

/// Closed-form periodic outage windows (eclipse / conjunction model):
/// the entity is dark during `[k*period + phase, k*period + phase +
/// duration)` for every integer `k`.
#[derive(Clone, Copy, Debug)]
pub struct OutageWindows {
    pub period_s: f64,
    pub duration_s: f64,
    /// Per-entity phase offset in `[0, period)`, drawn at plan build.
    pub phase_s: f64,
}

impl OutageWindows {
    /// A window set that is never dark.
    pub fn none() -> Self {
        OutageWindows { period_s: 0.0, duration_s: 0.0, phase_s: 0.0 }
    }

    pub fn active(&self) -> bool {
        self.period_s > 0.0 && self.duration_s > 0.0
    }

    /// Position of `t` within the cycle, in `[0, period)`.
    fn cycle_pos(&self, t: f64) -> f64 {
        (t - self.phase_s).rem_euclid(self.period_s)
    }

    /// Is the entity dark at `t`?
    pub fn is_out(&self, t: f64) -> bool {
        self.active() && self.cycle_pos(t) < self.duration_s
    }

    /// Earliest time `>= t` outside any outage window.
    pub fn clear_time(&self, t: f64) -> f64 {
        if !self.is_out(t) {
            t
        } else {
            t + (self.duration_s - self.cycle_pos(t))
        }
    }

    /// All `(start, end)` windows intersecting `[0, horizon_s]`, for
    /// event scheduling.
    pub fn windows_until(&self, horizon_s: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        if !self.active() {
            return out;
        }
        // first cycle whose window could intersect t >= 0
        let mut start = self.phase_s.rem_euclid(self.period_s) - self.period_s;
        while start <= horizon_s {
            let end = start + self.duration_s;
            if end > 0.0 {
                out.push((start.max(0.0), end.min(horizon_s)));
            }
            start += self.period_s;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_deterministic_from_seed() {
        let a = ChurnSchedule::generate(&mut Rng::new(7), 3600.0, 600.0, 86_400.0);
        let b = ChurnSchedule::generate(&mut Rng::new(7), 3600.0, 600.0, 86_400.0);
        assert_eq!(a.down, b.down);
        assert!(!a.down.is_empty(), "a day at 1 h MTBF must produce failures");
    }

    #[test]
    fn churn_intervals_sorted_disjoint() {
        let c = ChurnSchedule::generate(&mut Rng::new(3), 1800.0, 900.0, 86_400.0);
        for w in c.down.windows(2) {
            assert!(w[0].1 <= w[1].0, "overlap: {:?}", w);
        }
        for &(s, e) in &c.down {
            assert!(e > s);
        }
    }

    #[test]
    fn churn_up_down_queries() {
        let c = ChurnSchedule { down: vec![(10.0, 20.0), (50.0, 60.0)] };
        assert!(!c.is_down(5.0));
        assert!(c.is_down(10.0));
        assert!(c.is_down(19.9));
        assert!(!c.is_down(20.0));
        assert_eq!(c.up_time_after(15.0), 20.0);
        assert_eq!(c.up_time_after(30.0), 30.0);
        assert_eq!(c.up_time_after(59.0), 60.0);
        assert!((c.total_down_s(100.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn churn_disabled_when_zero() {
        let c = ChurnSchedule::generate(&mut Rng::new(1), 0.0, 600.0, 86_400.0);
        assert!(c.down.is_empty());
        assert!(!c.is_down(100.0));
    }

    #[test]
    fn outage_periodicity() {
        let o = OutageWindows { period_s: 100.0, duration_s: 10.0, phase_s: 5.0 };
        assert!(o.is_out(5.0));
        assert!(o.is_out(14.9));
        assert!(!o.is_out(15.0));
        assert!(o.is_out(105.0));
        assert_eq!(o.clear_time(7.0), 15.0);
        assert_eq!(o.clear_time(50.0), 50.0);
        assert_eq!(o.clear_time(107.0), 115.0);
    }

    #[test]
    fn outage_none_is_clear() {
        let o = OutageWindows::none();
        assert!(!o.is_out(0.0));
        assert_eq!(o.clear_time(42.0), 42.0);
        assert!(o.windows_until(1000.0).is_empty());
    }

    #[test]
    fn outage_windows_until_covers_horizon() {
        let o = OutageWindows { period_s: 100.0, duration_s: 10.0, phase_s: 95.0 };
        let ws = o.windows_until(350.0);
        // phase 95: windows [-5,5], [95,105], [195,205], [295,305]
        assert_eq!(ws, vec![(0.0, 5.0), (95.0, 105.0), (195.0, 205.0), (295.0, 305.0)]);
        for w in ws.windows(2) {
            assert!(w[0].1 <= w[1].0);
        }
    }
}
