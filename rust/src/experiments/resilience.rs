//! E9: the resilience sweep — graceful degradation under fault
//! scenarios (`crate::faults`).
//!
//! For every named [`FaultScenario`] and intensity level, run AsyncFLEO
//! and two representative baselines (the synchronous FedHAP and the
//! asynchronous FedSat) over the same seeded impairment timeline and
//! tabulate accuracy, convergence time and the fault accounting. The
//! paper's qualitative claim this driver probes: asynchronous
//! collection with staleness handling degrades gracefully where
//! synchronous rounds stall behind the slowest (or dead) satellite.
//!
//! Comparability note: every scheme sees the same link-level
//! impairments (deferrals, loss, dead-endpoint blocking) through the
//! shared delay oracle, and FedSat additionally skips the passes of
//! dark satellites. The *event-level* reactions — mid-training result
//! loss, ring re-healing, post-outage re-offers — exist only in
//! AsyncFLEO's event loop, so the `dropped_results` column is
//! AsyncFLEO instrumentation, not a cross-scheme metric.
//!
//! The network impairment scenarios (PR 10: `jitter`, `congestion`,
//! `partition`, `sun-eclipse`) sweep through the same grid — each cell
//! sets the matching [`NetworkConfig`] preset alongside the (nominal)
//! fault knobs, and the new counters (queueing delay, partition hits,
//! reorders, eclipse blocks, retry drops) land in their own CSV
//! columns.

use super::drivers::{base_config, summary_of, ExpOptions};
use super::executor::{run_cells_streaming, Cell};
use crate::config::{ModelKind, PsPlacement, SchemeKind};
use crate::data::{DatasetKind, Partition};
use crate::faults::{FaultConfig, FaultScenario, NetworkConfig};
use crate::metrics::csv::{f, i, s, CsvWriter};
use crate::util::fmt_hm;
use anyhow::Result;

/// Schemes compared in the sweep: ours plus one synchronous and one
/// asynchronous baseline, each at its natural placement.
pub const RESILIENCE_SCHEMES: &[(&str, SchemeKind, PsPlacement)] = &[
    ("AsyncFLEO", SchemeKind::AsyncFleo, PsPlacement::TwoHaps),
    ("FedHAP", SchemeKind::FedHap, PsPlacement::TwoHaps),
    ("FedSat", SchemeKind::FedSat, PsPlacement::GsNorthPole),
];

/// Fault intensity levels swept per scenario (plus the nominal run).
pub const INTENSITIES: &[f64] = &[0.5, 1.0];

/// The (scenario, intensity) grid: one nominal cell, then every
/// non-nominal scenario at every intensity.
pub fn sweep_cells() -> Vec<(FaultScenario, f64)> {
    let mut cells = vec![(FaultScenario::Nominal, 0.0)];
    for &scenario in FaultScenario::ALL {
        if scenario == FaultScenario::Nominal {
            continue;
        }
        for &x in INTENSITIES {
            cells.push((scenario, x));
        }
    }
    cells
}

/// [`sweep_cells`] restricted to a scenario subset (the nominal
/// reference cell is always kept). `None` = the full grid.
pub fn sweep_cells_filtered(filter: Option<&[FaultScenario]>) -> Vec<(FaultScenario, f64)> {
    sweep_cells()
        .into_iter()
        .filter(|&(sc, _)| {
            filter.map_or(true, |keep| sc == FaultScenario::Nominal || keep.contains(&sc))
        })
        .collect()
}

/// Run the sweep, writing `results/resilience.csv`.
pub fn run(opts: &ExpOptions) -> Result<()> {
    run_filtered(opts, None)
}

/// [`run`] restricted to a scenario subset (what the CLI's
/// `--scenarios` flag and the CI resilience smoke use). `None` runs
/// the full grid.
pub fn run_filtered(opts: &ExpOptions, filter: Option<&[FaultScenario]>) -> Result<()> {
    let mut cfg0 = base_config(opts);
    // the coordinator dynamics are the object of study: MLP keeps the
    // compute cheap without changing visit/staleness behaviour
    cfg0.fl.model = ModelKind::Mlp;
    cfg0.fl.dataset = DatasetKind::Digits;
    cfg0.fl.partition = Partition::NonIidPaper;
    cfg0.fl.horizon_s = 48.0 * 3600.0;
    cfg0.fl.max_epochs = 30;

    let mut w = CsvWriter::create(
        opts.out_dir.join("resilience.csv"),
        &[
            "resilience: graceful degradation under fault scenarios (SynthDigits non-IID, mlp)",
            &cfg0.to_toml(),
        ],
        &[
            "scenario",
            "intensity",
            "label",
            "scheme",
            "placement",
            "accuracy_pct",
            "convergence_h",
            "convergence_hm",
            "epochs",
            "transfers",
            "retransmits",
            "deferrals",
            "deferred_h",
            "dropped_results",
            "losses",
            "outages_hit",
            "churn_deaths",
            "queued_s",
            "queue_drops",
            "partition_hits",
            "reorders",
            "eclipse_blocked",
            "retry_drops",
        ],
    )?
    .autoflush(true);

    // grid rows (scenario × intensity × scheme) and their executor
    // cells, in the deterministic order the CSV has always used
    let mut rows: Vec<(FaultScenario, f64, &str, SchemeKind, PsPlacement)> = Vec::new();
    let mut cells: Vec<Cell> = Vec::new();
    for (scenario, intensity) in sweep_cells_filtered(filter) {
        for &(label, scheme, placement) in RESILIENCE_SCHEMES {
            let mut cfg = cfg0.clone();
            cfg.fl.scheme = scheme;
            cfg.placement = placement;
            cfg.faults = FaultConfig::preset(scenario, intensity);
            cfg.network = NetworkConfig::preset(scenario, intensity);
            rows.push((scenario, intensity, label, scheme, placement));
            cells.push(Cell::new(format!("{}@{intensity}/{label}", scenario.name()), cfg));
        }
    }
    println!("\n=== resilience (SynthDigits non-IID, mlp) ===");
    println!(
        "{:<12} {:>4} {:<10} {:>8} {:>10} {:>7} {:>9} {:>8}",
        "scenario", "x", "scheme", "acc(%)", "conv(h:mm)", "epochs", "retrans", "dropped"
    );
    // The schemes of one (scenario, intensity) group share a seed and a
    // node layout, so the coordinator's `FaultSchedule` cache hands all
    // of them one Arc'd timeline; rows stream to disk in cell order.
    run_cells_streaming(&cells, opts, |idx, r| {
        let (scenario, intensity, label, scheme, placement) = rows[idx];
        let (conv_t, acc) = summary_of(r);
        let fs = r.fault_stats;
        w.row(&[
            s(scenario.name()),
            f(intensity),
            s(label),
            s(scheme.name()),
            s(placement.name()),
            f(acc * 100.0),
            f(conv_t / 3600.0),
            s(&fmt_hm(conv_t)),
            i(r.epochs),
            i(r.transfers),
            i(fs.retransmits),
            i(fs.deferrals),
            f(fs.deferred_s / 3600.0),
            i(fs.dropped_results),
            i(fs.losses),
            i(fs.outages_hit),
            i(fs.churn_deaths),
            f(fs.queued_s),
            i(fs.queue_drops),
            i(fs.partition_hits),
            i(fs.reorders),
            i(fs.eclipse_blocked),
            i(fs.retry_drops),
        ])?;
        println!(
            "{:<12} {:>4.2} {:<10} {:>8.2} {:>10} {:>7} {:>9} {:>8}",
            scenario.name(),
            intensity,
            label,
            acc * 100.0,
            fmt_hm(conv_t),
            r.epochs,
            fs.retransmits,
            fs.dropped_results
        );
        Ok(())
    })?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_every_scenario() {
        let cells = sweep_cells();
        assert_eq!(cells[0], (FaultScenario::Nominal, 0.0));
        assert_eq!(cells.len(), 1 + (FaultScenario::ALL.len() - 1) * INTENSITIES.len());
        for &scenario in FaultScenario::ALL {
            assert!(cells.iter().any(|&(sc, _)| sc == scenario), "{scenario:?} missing");
        }
    }

    #[test]
    fn filtered_sweep_keeps_nominal_and_the_requested_scenarios() {
        let keep = [FaultScenario::Partition, FaultScenario::Congestion];
        let cells = sweep_cells_filtered(Some(&keep));
        assert_eq!(cells[0], (FaultScenario::Nominal, 0.0));
        assert_eq!(cells.len(), 1 + keep.len() * INTENSITIES.len());
        assert!(cells.iter().skip(1).all(|&(sc, _)| keep.contains(&sc)));
        // no filter = the full grid
        assert_eq!(sweep_cells_filtered(None), sweep_cells());
    }

    #[test]
    fn scheme_table_has_ours_plus_two_baselines() {
        assert_eq!(RESILIENCE_SCHEMES.len(), 3);
        assert!(RESILIENCE_SCHEMES
            .iter()
            .any(|&(_, s, _)| s == SchemeKind::AsyncFleo));
        let baselines = RESILIENCE_SCHEMES
            .iter()
            .filter(|&&(_, s, _)| s != SchemeKind::AsyncFleo)
            .count();
        assert_eq!(baselines, 2);
    }
}
