//! Visibility predicates and contact-window extraction (paper Sec. III-B).
//!
//! A satellite is visible from a site when the elevation angle above
//! the local horizon is at least `theta_min` (the paper's
//! `vartheta(t) <= pi/2 - vartheta_min` condition expressed the usual
//! way). Satellite-to-satellite line-of-sight requires the chord not to
//! intersect the Earth (plus an atmospheric grazing margin).

use super::elements::EARTH_RADIUS_KM;
use crate::util::Vec3;

/// Atmospheric grazing margin for ISL line-of-sight, km. Links whose
/// chord dips below R_E + this margin are considered blocked.
pub const LOS_ATMOSPHERE_MARGIN_KM: f64 = 80.0;

/// Elevation of `target` above the local horizon of `site`, degrees.
///
/// elevation = 90 deg − angle(r_site, target − site).
pub fn elevation_deg(site: Vec3, target: Vec3) -> f64 {
    let rho = target - site;
    90.0 - site.angle_to(rho).to_degrees()
}

/// Is `target` visible from `site` with minimum elevation `min_elev_deg`?
pub fn site_visible(site: Vec3, target: Vec3, min_elev_deg: f64) -> bool {
    elevation_deg(site, target) >= min_elev_deg
}

/// Line-of-sight between two satellites: does the segment a—b stay
/// above the (margin-padded) Earth sphere?
pub fn sat_sat_los(a: Vec3, b: Vec3) -> bool {
    let r_block = EARTH_RADIUS_KM + LOS_ATMOSPHERE_MARGIN_KM;
    let ab = b - a;
    let t = crate::util::clamp(-a.dot(ab) / ab.norm2(), 0.0, 1.0);
    let closest = a + ab * t;
    closest.norm() >= r_block
}

/// Maximum central angle between a site's and a satellite's geocentric
/// direction vectors at which the satellite still clears the minimum
/// elevation, radians.
///
/// In the Earth-center / site / satellite triangle, the angle at the
/// site is `90° + e` (elevation measured from the local tangent plane)
/// and the angle at the satellite is `90° − γ − e`. The law of sines
/// with site radius `a` and satellite radius `b` gives
/// `a / cos(γ + e) = b / cos e`, hence
///
/// ```text
/// γ_max = acos((a / b) · cos e_min) − e_min
/// ```
///
/// Elevation decreases strictly monotonically in γ
/// (`de/dγ = −b(b − a·cos γ)/d² < 0` for `b > a`), so
/// `e(t) ≥ e_min  ⟺  γ(t) ≤ γ_max` — the scalar threshold the analytic
/// contact predictor (`coordinator::analytic`) tests instead of the
/// full elevation formula. Negative `min_elev_deg` (an elevated site's
/// horizon dip) is valid and simply widens the cone.
pub fn max_central_angle_rad(site_radius_km: f64, sat_radius_km: f64, min_elev_deg: f64) -> f64 {
    assert!(
        sat_radius_km > site_radius_km && site_radius_km > 0.0,
        "max central angle needs sat above site, got {site_radius_km}/{sat_radius_km}"
    );
    let e = min_elev_deg.to_radians();
    ((site_radius_km / sat_radius_km) * e.cos()).acos() - e
}

/// A closed interval of continuous visibility.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ContactWindow {
    pub start_s: f64,
    pub end_s: f64,
}

impl ContactWindow {
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }

    pub fn contains(&self, t: f64) -> bool {
        t >= self.start_s && t <= self.end_s
    }
}

/// The scanner's shared sample grid over `[0, horizon_s]`: `t_i = i ·
/// step_s`, derived from the integer step index — one correctly-rounded
/// multiply per point, so the grid cannot drift the way an accumulated
/// `t += step_s` does over 8 640+ steps (for the 30 s plan step every
/// point is exactly representable, so old and new grids coincide). The
/// final point is clamped to the horizon.
///
/// Both the reference scanner ([`contact_windows`]) and the fast plan
/// scanner (`coordinator::contact`) sample exactly this grid; keeping
/// it exact is what makes "the same grid point" well-defined across the
/// two, which the interval-skipping equivalence argument relies on.
pub fn scan_grid(horizon_s: f64, step_s: f64) -> Vec<f64> {
    assert!(
        step_s > 0.0 && horizon_s > 0.0 && step_s.is_finite() && horizon_s.is_finite(),
        "contact scan needs finite positive horizon/step, got {horizon_s}/{step_s}"
    );
    let mut grid = Vec::with_capacity((horizon_s / step_s) as usize + 2);
    grid.push(0.0);
    let mut i: u64 = 1;
    loop {
        let t = i as f64 * step_s;
        if t > horizon_s + step_s * 0.5 {
            break;
        }
        let tc = t.min(horizon_s);
        grid.push(tc);
        if (tc - horizon_s).abs() < 1e-9 {
            break;
        }
        i += 1;
    }
    grid
}

/// Extract contact windows of a time-dependent visibility predicate
/// over `[0, horizon_s]`, sampling the [`scan_grid`] points and
/// refining each edge by bisection to ~1 s accuracy.
///
/// This is the *reference* scanner: a plain dense sweep of one
/// predicate. `coordinator::contact` has the production fast path
/// (time-major, interval-skipping, parallel) that is bit-identical to
/// running this per (site, satellite) pair.
///
/// Every window edge is guaranteed finite: the bounds are asserted
/// finite here, and bisection only ever averages them. Downstream
/// consumers (`ContactPlan::next_visible_any`'s total-order min, the
/// event queue's finite-time invariant) rely on this.
pub fn contact_windows(
    mut visible: impl FnMut(f64) -> bool,
    horizon_s: f64,
    step_s: f64,
) -> Vec<ContactWindow> {
    let grid = scan_grid(horizon_s, step_s);
    let mut windows = Vec::new();
    let mut prev_t = grid[0];
    let mut prev_v = visible(grid[0]);
    let mut start = if prev_v { Some(0.0) } else { None };

    for &tc in &grid[1..] {
        let v = visible(tc);
        if v != prev_v {
            let edge = bisect_edge(&mut visible, prev_t, tc, prev_v);
            if v {
                start = Some(edge);
            } else if let Some(s) = start.take() {
                windows.push(ContactWindow { start_s: s, end_s: edge });
            }
        }
        prev_t = tc;
        prev_v = v;
    }
    if let Some(s) = start {
        windows.push(ContactWindow { start_s: s, end_s: horizon_s });
    }
    windows
}

/// Bisection: predicate flips between lo (value `lo_v`) and hi. Shared
/// with the fast scanner (`coordinator::contact`), which must refine
/// the same brackets to the same edges.
pub(crate) fn bisect_edge(
    visible: &mut impl FnMut(f64) -> bool,
    mut lo: f64,
    mut hi: f64,
    lo_v: bool,
) -> f64 {
    for _ in 0..32 {
        if hi - lo < 1.0 {
            break;
        }
        let mid = 0.5 * (lo + hi);
        if visible(mid) == lo_v {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::ground::GeodeticSite;
    use crate::orbit::walker::WalkerConstellation;

    #[test]
    fn zenith_has_90_elevation() {
        let site = Vec3::new(EARTH_RADIUS_KM, 0.0, 0.0);
        let sat = Vec3::new(EARTH_RADIUS_KM + 2000.0, 0.0, 0.0);
        assert!((elevation_deg(site, sat) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn horizon_has_zero_elevation() {
        let site = Vec3::new(EARTH_RADIUS_KM, 0.0, 0.0);
        // A target in the local tangent plane (pure +Y offset).
        let sat = Vec3::new(EARTH_RADIUS_KM, 500.0, 0.0);
        assert!(elevation_deg(site, sat).abs() < 1e-9);
    }

    #[test]
    fn antipodal_satellite_invisible() {
        let site = Vec3::new(EARTH_RADIUS_KM, 0.0, 0.0);
        let sat = Vec3::new(-(EARTH_RADIUS_KM + 2000.0), 0.0, 0.0);
        assert!(!site_visible(site, sat, 10.0));
    }

    #[test]
    fn los_blocked_through_earth() {
        let a = Vec3::new(EARTH_RADIUS_KM + 2000.0, 0.0, 0.0);
        let b = Vec3::new(-(EARTH_RADIUS_KM + 2000.0), 0.0, 0.0);
        assert!(!sat_sat_los(a, b));
    }

    #[test]
    fn los_clear_for_neighbors() {
        let c = WalkerConstellation::paper();
        let a = c.position(0, 0.0);
        let b = c.position(1, 0.0); // 45 deg apart at 8371 km: chord clears Earth
        assert!(sat_sat_los(a, b));
    }

    #[test]
    fn los_symmetric() {
        let c = WalkerConstellation::paper();
        for t in [0.0, 3000.0] {
            for (i, j) in [(0usize, 3usize), (2, 9), (5, 20)] {
                let a = c.position(i, t);
                let b = c.position(j, t);
                assert_eq!(sat_sat_los(a, b), sat_sat_los(b, a));
            }
        }
    }

    #[test]
    fn paper_geometry_produces_sporadic_contacts() {
        // A Rolla HAP must see each satellite only a fraction of the
        // time — the irregular visit pattern motivating the paper.
        let c = WalkerConstellation::paper();
        let hap = GeodeticSite::rolla_hap();
        let horizon = 86_400.0;
        let wins = contact_windows(
            |t| site_visible(hap.position_eci(t), c.position(0, t), 10.0),
            horizon,
            30.0,
        );
        assert!(!wins.is_empty(), "satellite never visible in a day");
        let total: f64 = wins.iter().map(|w| w.duration_s()).sum();
        let frac = total / horizon;
        assert!(
            (0.005..0.5).contains(&frac),
            "visibility fraction {frac} should be sporadic"
        );
    }

    #[test]
    fn windows_ordered_and_disjoint() {
        let c = WalkerConstellation::paper();
        let hap = GeodeticSite::rolla_hap();
        let wins = contact_windows(
            |t| site_visible(hap.position_eci(t), c.position(3, t), 10.0),
            86_400.0,
            30.0,
        );
        for w in &wins {
            assert!(w.end_s > w.start_s);
        }
        for pair in wins.windows(2) {
            assert!(pair[0].end_s < pair[1].start_s);
        }
    }

    #[test]
    fn window_edges_are_tight() {
        // Just inside a window the predicate is true; just outside, false.
        let c = WalkerConstellation::paper();
        let hap = GeodeticSite::rolla_hap();
        let vis = |t: f64| site_visible(hap.position_eci(t), c.position(0, t), 10.0);
        let wins = contact_windows(vis, 86_400.0, 30.0);
        let w = wins[0];
        if w.start_s > 2.0 {
            assert!(vis(w.start_s + 1.0));
            assert!(!vis(w.start_s - 2.0));
        }
    }

    #[test]
    fn higher_min_elevation_shrinks_windows() {
        let c = WalkerConstellation::paper();
        let hap = GeodeticSite::rolla_hap();
        let total = |min_elev: f64| -> f64 {
            contact_windows(
                |t| site_visible(hap.position_eci(t), c.position(0, t), min_elev),
                86_400.0,
                30.0,
            )
            .iter()
            .map(|w| w.duration_s())
            .sum()
        };
        assert!(total(5.0) > total(25.0));
    }

    #[test]
    fn max_central_angle_matches_elevation_threshold() {
        // Place the site on the x axis and sweep satellites at central
        // angle γ: elevation crosses min_elev exactly at γ_max.
        let a = EARTH_RADIUS_KM;
        let b = EARTH_RADIUS_KM + 550.0;
        let site = Vec3::new(a, 0.0, 0.0);
        for min_elev in [0.0, 10.0, 25.0, -1.5] {
            let gamma_max = max_central_angle_rad(a, b, min_elev);
            assert!(gamma_max > 0.0 && gamma_max < std::f64::consts::FRAC_PI_2);
            let at = |gamma: f64| {
                elevation_deg(site, Vec3::new(b * gamma.cos(), b * gamma.sin(), 0.0))
            };
            assert!((at(gamma_max) - min_elev).abs() < 1e-9, "edge at {min_elev}");
            assert!(at(gamma_max - 0.01) > min_elev);
            assert!(at(gamma_max + 0.01) < min_elev);
        }
    }

    #[test]
    fn max_central_angle_shrinks_with_elevation_and_grows_with_altitude() {
        let a = EARTH_RADIUS_KM;
        assert!(
            max_central_angle_rad(a, a + 550.0, 5.0) > max_central_angle_rad(a, a + 550.0, 25.0)
        );
        assert!(
            max_central_angle_rad(a, a + 1200.0, 10.0) > max_central_angle_rad(a, a + 550.0, 10.0)
        );
    }

    #[test]
    fn hap_sees_no_less_than_gs() {
        // The paper's rationale for HAPs: slightly better visibility.
        // The advantage is the horizon dip of the elevated platform
        // (theta_min is measured from the apparent horizon).
        let c = WalkerConstellation::paper();
        let gs = GeodeticSite::rolla_gs();
        let hap = GeodeticSite::rolla_hap();
        let count_visible = |site: &GeodeticSite, t: f64| -> usize {
            let eff = site.effective_min_elevation_deg(10.0);
            (0..c.len())
                .filter(|&i| site_visible(site.position_eci(t), c.position(i, t), eff))
                .count()
        };
        let mut hap_total = 0usize;
        let mut gs_total = 0usize;
        for i in 0..288 {
            let t = i as f64 * 300.0;
            hap_total += count_visible(&hap, t);
            gs_total += count_visible(&gs, t);
        }
        assert!(hap_total > gs_total, "HAP {hap_total} vs GS {gs_total}");
    }
}
